"""End-to-end behaviour tests for the paper's system + the LM runtime."""

import numpy as np
import pytest

from repro.core import PartitionerConfig, hash_partition, partition
from repro.core.metrics import comm_volume_np, cut_np, quotient_graph_np
from repro.graph import planted_partition


def test_partition_quality_end_to_end():
    g = planted_partition(8192, 16, p_in=0.015, p_out=0.0003, seed=5)
    rep = partition(g, PartitionerConfig(k=4, preset="fast", coarsest_factor=50,
                                         seed=0))
    hb = cut_np(g, hash_partition(g.n, 4))
    assert rep.feasible
    assert rep.cut < hb / 2  # community graphs: far better than hashing
    q, bw = quotient_graph_np(g, rep.labels, 4)
    assert np.isclose(q.sum(), rep.cut)
    assert comm_volume_np(g, rep.labels, 4) > 0


def test_train_driver_smoke(tmp_path):
    """Few steps of real training on a reduced arch: loss must drop."""
    from repro.launch.train import main

    losses = main([
        "--arch", "granite-moe-1b-a400m", "--smoke", "--steps", "30",
        "--batch", "8", "--seq", "64", "--lr", "3e-3", "--log-every", "10",
    ])
    assert losses[-1] < losses[0] - 0.3


def test_train_resume_exact(tmp_path):
    """Kill/restart fault-tolerance: resumed run reproduces the uninterrupted
    run exactly (deterministic pipeline + exact state restore)."""
    from repro.launch.train import main

    full = main(["--arch", "qwen2.5-3b", "--smoke", "--steps", "12",
                 "--batch", "4", "--seq", "32", "--ckpt-dir",
                 str(tmp_path / "a"), "--ckpt-every", "6"])
    part = main(["--arch", "qwen2.5-3b", "--smoke", "--steps", "6",
                 "--batch", "4", "--seq", "32", "--ckpt-dir",
                 str(tmp_path / "b"), "--ckpt-every", "6"])
    resumed = main(["--arch", "qwen2.5-3b", "--smoke", "--steps", "12",
                    "--batch", "4", "--seq", "32", "--ckpt-dir",
                    str(tmp_path / "b"), "--ckpt-every", "6", "--resume"])
    np.testing.assert_allclose(full[6:], resumed, rtol=1e-5)


def test_serve_driver_smoke():
    from repro.launch.serve import main

    toks = main(["--arch", "qwen2.5-3b", "--smoke", "--batch", "2",
                 "--prompt-len", "16", "--gen", "8"])
    assert toks.shape == (2, 8)
