"""Size-constrained label propagation: both engines, both modes."""

import numpy as np

from repro.core import lp_cluster, lp_refine, sclap_numpy
from repro.core.metrics import cut_np, imbalance_np, lmax
from repro.graph import mesh2d, planted_partition


def _noisy_split(g, side, p=0.15, seed=1):
    truth = (np.arange(g.n) // side >= side // 2).astype(np.int32)
    rng = np.random.default_rng(seed)
    lab = truth.copy()
    lab[rng.random(g.n) < p] ^= 1
    return truth, lab


def test_cluster_respects_soft_bound():
    g = planted_partition(2048, 8, p_in=0.04, p_out=0.001, seed=0)
    U = 60.0
    res = lp_cluster(g, U=U, iters=3, seed=1, max_nodes=512)
    cw = np.bincount(res.labels, weights=g.nw)
    # chunked-synchronous moves may overshoot within a chunk; the paper's
    # constraint is soft — bound the overshoot instead of requiring exactness
    assert cw.max() <= 2.5 * U
    assert np.unique(res.labels).size < g.n / 4  # actually clusters


def test_cluster_restriction_invariant():
    g = planted_partition(1024, 4, seed=1)
    restrict = (np.arange(g.n) % 2).astype(np.int64)
    res = lp_cluster(g, U=100.0, iters=3, seed=0, restrict=restrict, max_nodes=256)
    # no cluster may straddle a restriction cell (V-cycle guarantee)
    for c in np.unique(res.labels):
        cells = np.unique(restrict[res.labels == c])
        assert cells.size == 1


def test_refine_recovers_noisy_mesh_split():
    side = 48
    g = mesh2d(side)
    truth, noisy = _noisy_split(g, side)
    L = lmax(g.n, 2, 0.03)
    before = cut_np(g, noisy)
    res = lp_refine(g, noisy, k=2, U=L, iters=6, seed=3, max_nodes=256)
    after = cut_np(g, res.labels)
    assert after < before / 5
    assert imbalance_np(g, res.labels, 2) <= 0.031


def test_numpy_engine_matches_quality():
    side = 48
    g = mesh2d(side)
    truth, noisy = _noisy_split(g, side)
    L = lmax(g.n, 2, 0.03)
    res = sclap_numpy(g, noisy, U=L, iters=6, seed=3, refine_mode=True, num_labels=2)
    assert cut_np(g, res.labels) < cut_np(g, noisy) / 5


def test_refine_fixes_overload():
    side = 32
    g = mesh2d(side)
    lab = np.zeros(g.n, dtype=np.int32)
    lab[: g.n // 8] = 1  # heavily imbalanced
    L = lmax(g.n, 2, 0.03)
    res = lp_refine(g, lab, k=2, U=L, iters=8, seed=0, max_nodes=128)
    bw = np.bincount(res.labels, weights=g.nw, minlength=2)
    assert bw.max() <= L * 1.05  # overload rule pushes toward feasibility
