"""Graph substrate: CSR validity, generators, packing layouts."""

import numpy as np
import pytest

from repro.graph import (
    barabasi_albert, ell_pack, from_edges, mesh2d, pack_chunks,
    planted_partition, rgg, ring, rmat, shard_graph, star, validate,
)


@pytest.mark.parametrize("maker", [
    lambda: rmat(10, 8, seed=1),
    lambda: rgg(10, seed=1),
    lambda: mesh2d(20),
    lambda: barabasi_albert(1500, 4, seed=1),
    lambda: planted_partition(1024, 4, seed=1),
    lambda: ring(64),
    lambda: star(64),
])
def test_generators_valid(maker):
    g = maker()
    validate(g)
    assert g.n > 0 and g.m > 0


def test_from_edges_dedup():
    g = from_edges(4, np.array([0, 0, 1]), np.array([1, 1, 0]))
    # three parallel arcs merged into one undirected edge with weight 3
    assert g.m == 2
    assert g.ew.sum() == 6.0


def test_chunk_pack_covers_everything():
    g = rmat(11, 8, seed=2)
    cp = pack_chunks(g, np.argsort(g.degrees()), max_nodes=256, max_edges=2048)
    nodes = cp.nodes[cp.node_valid]
    assert np.array_equal(np.sort(nodes), np.arange(g.n))
    assert int(cp.edge_valid.sum()) == g.m
    c = cp.num_chunks // 2
    sel = cp.node_valid[c]
    ids = cp.nodes[c][sel]
    dst = cp.edge_dst[c][cp.edge_valid[c]]
    exp = np.concatenate([g.indices[g.indptr[v]:g.indptr[v + 1]] for v in ids])
    assert np.array_equal(dst, exp)


def test_ell_pack_row_splitting():
    g = star(500)  # hub degree 499 >> width
    ep = ell_pack(g, width=32, tile_rows=64)
    assert (ep.dst < g.n).sum() == g.m
    hub_rows = np.flatnonzero(ep.row_node == 0)
    assert hub_rows.size == -(-499 // 32)
    got = ep.dst[hub_rows].ravel()
    assert np.array_equal(np.sort(got[got < g.n]), np.arange(1, 500))


def test_shard_graph_roundtrip():
    g = rmat(11, 8, seed=3)
    P = 4
    sg = shard_graph(g, P)
    assert int(sg.m_local.sum()) == g.m
    assert int(sg.n_local.sum()) == g.n
    # every ghost is an interface node of its owner
    for p in range(P):
        gp = int(sg.n_ghost[p])
        for gi in range(0, gp, max(1, gp // 13)):
            owner = int(sg.ghost_owner[p, gi])
            slot = int(sg.ghost_slot[p, gi])
            glob = int(sg.ghost_global[p, gi])
            assert sg.iface_nodes[owner, slot] + sg.range_start[owner] == glob
