"""Dynamic partitioning subsystem (ISSUE 4): mutable device-resident store,
incremental size-constrained repair, and the batched update-serving session.

The contract under test: a net-no-op update batch leaves the resident labels
BIT-identical; an inverse update stream (add then remove the same batch)
compacts back to the original CSR bit-for-bit; repair touches only the
h-hop affected region, keeps the partition feasible, and compiles once per
shape bucket across a multi-batch stream (repair_compiles ==
repair_bucket_count); the quality guard escalates to a full V-cycle when
local repair can no longer hold the cut.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import LPEngine, PartitionerConfig, partition
from repro.core.metrics import cut_np, lmax
from repro.dynamic import (
    DynamicGraphStore,
    GraphUpdate,
    PartitionSession,
    SessionConfig,
)
from repro.graph import GraphDev, barabasi_albert, mesh2d, planted_partition, validate

pytestmark = pytest.mark.dynamic


def _assert_csr_equal(a, b):
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    np.testing.assert_array_equal(a.ew, b.ew)
    np.testing.assert_array_equal(a.nw, b.nw.astype(np.float32))


# --------------------------------------------------------------------- store


def test_store_inverse_batches_round_trip_to_original_csr():
    """add_edges then remove_edges of the same batch (separate calls, so the
    overlay really holds both) must compact back to the exact original CSR —
    same arc order, bit-identical float32 weights."""
    g = barabasi_albert(1024, 4, seed=2)
    st = DynamicGraphStore(g)
    rng = np.random.default_rng(0)
    u = rng.integers(0, g.n, 64)
    v = (u + 1 + rng.integers(0, g.n - 1, 64)) % g.n
    w = rng.integers(1, 5, 64)
    st.add_edges(u, v, w)
    assert st.dirty and st.overlay_len == 2 * 64   # symmetric arcs
    st.remove_edges(u, v, w)
    assert st.overlay_len == 4 * 64                # + the inverse batch
    g2 = st.csr_host()
    assert not st.dirty
    _assert_csr_equal(g2, g)
    validate(g2)


def test_store_add_remove_changes_csr_and_validates():
    """Adding brand-new edges grows m by 2 per edge; removing an existing
    unit-weight edge deletes it; the merged CSR stays a valid symmetric
    graph and matches a host-rebuilt oracle."""
    g = mesh2d(16)  # unit weights, no parallel edges
    st = DynamicGraphStore(g)
    # add edges that do not exist (the 8-neighbourhood mesh has +1/+15/+16/
    # +17 arcs; distance-2 pairs are new), remove existing ones
    d_u = np.arange(0, 64, dtype=np.int64)
    d_v = d_u + 2
    st.add_edges(d_u, d_v)
    e_u = np.arange(100, 110, dtype=np.int64)
    e_v = e_u + 1                 # existing horizontal edges, weight 1
    st.remove_edges(e_u, e_v)
    g2 = st.csr_host()
    validate(g2)
    assert g2.m == g.m + 2 * 64 - 2 * 10
    # oracle: rebuild from the merged edge list on host
    from repro.graph import from_edges

    src = g.arc_sources()
    keep = np.ones(g.m, bool)
    for uu, vv in zip(e_u, e_v):
        keep &= ~(((src == uu) & (g.indices == vv)) | ((src == vv) & (g.indices == uu)))
    ou = np.concatenate([src[keep], d_u, d_v])
    ov = np.concatenate([g.indices[keep], d_v, d_u])
    ow = np.concatenate([g.ew[keep], np.ones(128, np.float32)])
    ghost = from_edges(g.n, ou, ov, w=ow, symmetrize=False)
    _assert_csr_equal(g2, ghost)


def test_store_add_nodes_then_wire_them_in_one_batch():
    g = barabasi_albert(500, 3, seed=1)
    st = DynamicGraphStore(g)
    upd = GraphUpdate.add_nodes([2, 3]).merged(
        GraphUpdate.add_edges([500, 501, 500], [0, 7, 501])
    )
    st.apply(upd)
    g2 = st.csr_host()
    validate(g2)
    assert st.n == 502 and g2.n == 502
    assert g2.m == g.m + 6
    np.testing.assert_array_equal(g2.nw[500:], np.array([2.0, 3.0], np.float32))
    assert st.total_node_weight == pytest.approx(g.nw.sum() + 5)


def test_store_rejected_batch_leaves_store_untouched():
    """Validation runs before any mutation: a batch with an out-of-range
    edge must not half-apply its node adds."""
    g = mesh2d(8)
    st = DynamicGraphStore(g)
    bad = GraphUpdate.add_nodes([1]).merged(
        GraphUpdate.add_edges([0], [10**6])
    )
    with pytest.raises(ValueError):
        st.apply(bad)
    assert st.n == g.n and st.overlay_len == 0
    assert st.total_node_weight == pytest.approx(g.nw.sum())


def test_tiny_graph_device_csr_fits_engine_arena():
    """to_device_csr floors the node bucket at 8; the engine arena must not
    underrun it on graphs with n <= 3."""
    from repro.graph import from_edges, to_device_csr

    g = from_edges(3, [0, 1], [1, 2])
    eng = LPEngine(g, seed=0)
    gd = to_device_csr(g)
    lab = eng.to_arena(np.array([0, 1, 1], np.int32), 3, fill=2)
    assert float(eng.cut(gd, lab)) == 1.0
    np.testing.assert_allclose(eng.block_weights(gd, lab, 2), [1.0, 2.0])


def test_store_overlay_cap_triggers_auto_compaction():
    g = mesh2d(8)
    st = DynamicGraphStore(g, overlay_cap=16)
    u = np.arange(0, 10, dtype=np.int64)
    st.add_edges(u, u + 16)   # 20 overlay arcs > cap
    assert st.stats.compact_calls == 1 and not st.dirty
    assert isinstance(st.base, GraphDev)


def test_store_compact_is_compile_bounded_across_a_stream():
    """Same-bucket batches reuse ONE merge executable: compiles == buckets
    even across many compactions."""
    g = barabasi_albert(1024, 4, seed=3)
    st = DynamicGraphStore(g)
    rng = np.random.default_rng(1)
    for i in range(5):
        u = rng.integers(0, g.n, 32)
        v = (u + 1 + rng.integers(0, g.n - 1, 32)) % g.n
        st.add_edges(u, v)
        st.compact()
    assert st.stats.compact_calls == 5
    assert st.stats.compact_compiles == st.stats.compact_bucket_count
    assert st.stats.compact_compiles < st.stats.compact_calls


# -------------------------------------------------------------------- repair


def _bfs_hops(g, seeds, hops):
    mask = np.zeros(g.n, bool)
    mask[seeds] = True
    for _ in range(hops):
        nxt = mask.copy()
        for v in np.flatnonzero(mask):
            nxt[g.indices[g.indptr[v]:g.indptr[v + 1]]] = True
        mask = nxt
    return mask


def test_repair_moves_only_region_nodes():
    """Nodes outside the h-hop region keep their labels bit-identically —
    the locality guarantee every session-level invariant builds on."""
    g = mesh2d(32)
    k = 2
    L = lmax(g.n, k, 0.03)
    eng = LPEngine(g, seed=0)
    rng = np.random.default_rng(0)
    lab0 = (np.arange(g.n) // (g.n // k)).clip(0, k - 1).astype(np.int32)
    noisy = lab0.copy()
    flip = rng.random(g.n) < 0.2
    noisy[flip] ^= 1
    touched = np.array([100, 505], dtype=np.int64)
    hops = 2
    out, rsize, cut, bw = eng.repair(
        g, noisy, touched, k, L, hops=hops, iters=4, seed=3
    )
    out_np = np.asarray(out[: g.n])
    region = _bfs_hops(g, touched, hops)
    assert rsize == int(region.sum())
    np.testing.assert_array_equal(out_np[~region], noisy[~region])
    assert eng.stats.repair_calls == 1
    assert eng.stats.repair_compiles == eng.stats.repair_bucket_count
    # the returned score really is the returned labels' score
    assert cut == pytest.approx(cut_np(g, out_np))
    np.testing.assert_allclose(
        bw, np.bincount(out_np, weights=g.nw, minlength=k), rtol=1e-6
    )


def test_hub_bounded_frontier_keeps_region_local_on_powerlaw():
    """ROADMAP repair-locality item: on an R-MAT graph a 2-hop region
    through the hubs is ~the whole graph; the degree-capped expansion must
    bound it while the uncapped expansion reproduces the old behaviour, and
    the repair guard (cut never worsens unless feasibility is restored)
    holds either way."""
    from repro.graph import rmat

    g = rmat(12, 8, seed=5)
    k = 4
    L = lmax(g.n, k, 0.03)
    rng = np.random.default_rng(0)
    lab = rng.integers(0, k, g.n).astype(np.int32)
    deg = g.degrees()
    cap = max(64, int(8 * g.m / g.n))
    # the serving case the ROADMAP item describes: an ORDINARY node whose
    # neighbourhood contains a hub — at hop 2 the uncapped frontier fans
    # out through the hub and engulfs the (reachable) graph
    hub = int(np.argmax(deg))
    nb_hub = g.indices[g.indptr[hub]:g.indptr[hub + 1]]
    spoke = int(nb_hub[np.argmin(deg[nb_hub])])
    assert deg[hub] > cap and deg[spoke] <= cap
    touched = np.array([spoke], dtype=np.int64)
    eng = LPEngine(g, seed=0)
    lab_dev = eng.to_arena(lab, g.n, fill=k)
    before_cut = cut_np(g, lab)
    hops = 3
    out_u, rsize_u, cut_u, bw_u = eng.repair(
        g, lab_dev, touched, k, L, hops=hops, iters=2, seed=1
    )
    out_c, rsize_c, cut_c, bw_c = eng.repair(
        g, lab_dev, touched, k, L, hops=hops, iters=2, seed=1,
        hop_degree_cap=cap,
    )
    assert rsize_u > 0.5 * g.n          # the hub really engulfs the graph
    assert rsize_c < 0.1 * rsize_u      # the cap restores locality
    # cut guard unchanged: neither path may worsen the cut
    assert cut_u <= before_cut + 1e-6 and cut_c <= before_cut + 1e-6
    # capped region oracle: hop 1 full, later hops only through deg <= cap
    src = g.arc_sources()
    mask_np = np.zeros(g.n, bool)
    mask_np[spoke] = True
    for i in range(hops):
        allow = mask_np[src] & ((i == 0) | (deg[src] <= cap))
        reach = np.zeros(g.n, bool)
        np.logical_or.at(reach, g.indices, allow)
        mask_np |= reach
    assert mask_np[hub]                 # the hub is IN the region, gated
    assert rsize_c == int(mask_np.sum())
    np.testing.assert_array_equal(
        np.asarray(out_c[: g.n])[~mask_np], lab[~mask_np]
    )


def test_session_auto_hop_cap_binds_only_on_powerlaw():
    """SessionConfig.hop_degree_cap=None (auto) must cap hub expansion on
    social graphs but stay inert on bounded-degree meshes."""
    from repro.graph import rmat

    g = rmat(12, 8, seed=7)
    deg = g.degrees()
    hub = int(np.argmax(deg))
    nb_hub = g.indices[g.indptr[hub]:g.indptr[hub + 1]]
    # churn between two ordinary hub neighbours: the touched set is
    # low-degree, but the uncapped 2-hop region fans out through the hub
    spokes = nb_hub[np.argsort(deg[nb_hub])[:2]].astype(np.int64)
    sess_auto = PartitionSession(g, SessionConfig(k=4, seed=0))
    sess_off = PartitionSession(
        g, SessionConfig(k=4, seed=0, hop_degree_cap=0)
    )
    for sess in (sess_auto, sess_off):
        res = sess.update(GraphUpdate.add_edges([spokes[0]], [spokes[1]]))
        assert res.feasible
    r_auto = sess_auto.trajectory[-1].region_size
    r_off = sess_off.trajectory[-1].region_size
    # uncapped: the 2-hop region swallows the hub's whole fan-out; capped:
    # the hub joins the region but its fan-out stays outside
    assert r_off > int(deg[hub]) and r_auto < 0.2 * r_off
    # meshes: auto cap (floor 64 >= max degree 8) is inert — identical labels
    gm = mesh2d(24)
    s1 = PartitionSession(gm, SessionConfig(k=2, seed=0))
    s2 = PartitionSession(gm, SessionConfig(k=2, seed=0, hop_degree_cap=0))
    for s in (s1, s2):
        s.update(GraphUpdate.add_edges([0, 30], [5, 80]))
    np.testing.assert_array_equal(s1.labels_np(), s2.labels_np())
    assert (s1.trajectory[-1].region_size == s2.trajectory[-1].region_size)


def test_escalation_seeds_vcycle_with_current_labels():
    """ROADMAP item: PartitionerConfig.initial_labels routes an existing
    partition through the restrict machinery, and the session's escalation
    uses it — a seeded re-partition of a community graph must not lose to
    the seed it was given."""
    g = planted_partition(2048, 16, p_in=0.04, p_out=0.001, seed=8)
    k = 4
    rep0 = partition(g, PartitionerConfig(k=k, preset="fast", seed=0))
    cfg = PartitionerConfig(k=k, preset="minimal", seed=1)
    cfg.initial_labels = rep0.labels
    rep1 = partition(g, cfg)
    assert rep1.feasible
    assert rep1.cut <= 1.05 * rep0.cut + 1e-6
    # invalid seeds are rejected, not silently mangled
    bad = PartitionerConfig(k=k, preset="minimal", seed=1)
    bad.initial_labels = np.full(g.n, k, np.int64)
    with pytest.raises(ValueError):
        partition(g, bad)
    bad.initial_labels = rep0.labels[:-1]
    with pytest.raises(ValueError):
        partition(g, bad)


def test_repair_gain_round_device_matches_fm_spec():
    """gain_round_device == fm.gain_round_np(region=..., influx_gate=True),
    op for op."""
    from repro.core.fm import gain_round_np
    from repro.dynamic.repair import gain_round_device

    g = planted_partition(300, 4, p_in=0.06, p_out=0.01, seed=2)
    k = 3
    Ab = 512
    rng = np.random.default_rng(0)
    lab = np.full(Ab, k, np.int32)
    lab[: g.n] = rng.integers(0, k, g.n)
    nw = np.zeros(Ab, np.float32)
    nw[: g.n] = g.nw
    region = np.zeros(Ab, bool)
    region[rng.integers(0, g.n, 80)] = True
    src = g.arc_sources().astype(np.int32)
    dst = g.indices.astype(np.int32)
    L = lmax(g.n, k, 0.03)
    want = gain_round_np(
        src, dst, g.ew, nw, lab, g.n, k, k + 1, np.float32(L),
        0x1234, 0x5678, region=region, influx_gate=True,
    )
    got = gain_round_device(
        jnp.asarray(src), jnp.asarray(dst), jnp.asarray(g.ew),
        jnp.asarray(nw), jnp.asarray(lab), jnp.asarray(region),
        jnp.int32(g.n), jnp.int32(k), jnp.float32(L),
        jnp.uint32(0x1234), jnp.uint32(0x5678), Kb=k + 1,
    )
    np.testing.assert_array_equal(np.asarray(got), want)
    assert np.any(want != lab)          # the round actually moved something
    np.testing.assert_array_equal(np.asarray(got)[~region], lab[~region])


# ------------------------------------------------------------------- session


def _mk_session(g, k=2, **kw):
    return PartitionSession(g, SessionConfig(k=k, seed=0, **kw))


def test_session_noop_batch_keeps_labels_bit_identical():
    g = planted_partition(1500, 8, p_in=0.03, p_out=0.002, seed=1)
    sess = _mk_session(g, k=2)
    lab0 = sess.labels_np().copy()
    dev0 = sess.labels
    # an empty batch and a self-cancelling batch are both net no-ops
    res = sess.update(GraphUpdate())
    assert res.noop
    u = np.array([3, 10, 77])
    v = np.array([500, 900, 1200])
    res = sess.update(
        GraphUpdate.add_edges(u, v, [2, 1, 3]).merged(
            GraphUpdate.remove_edges(u, v, [2, 1, 3])
        )
    )
    assert res.noop
    assert sess.labels is dev0          # not even re-dispatched
    np.testing.assert_array_equal(sess.labels_np(), lab0)
    assert sess.engine.stats.repair_calls == 0
    assert not sess.store.dirty and sess.store.stats.compact_calls == 0


def test_session_stream_stays_feasible_and_compile_bounded():
    """A multi-batch add/remove stream: every step feasible (imbalance <=
    eps), repair compiles bounded by buckets with actual cache reuse, and
    the final cut stays within a sane factor of a fresh full re-partition."""
    g = barabasi_albert(4096, 5, seed=1)
    sess = _mk_session(g, k=4)
    eps = sess.cfg.eps
    rng = np.random.default_rng(7)
    src = g.arc_sources()
    for step in range(4):
        nb = 40
        au = rng.integers(0, g.n, nb)
        av = (au + 1 + rng.integers(0, g.n - 1, nb)) % g.n
        pick = rng.integers(0, g.m, nb)          # existing arcs to remove
        ru, rv = src[pick], g.indices[pick]
        res = sess.update(
            GraphUpdate.add_edges(au, av).merged(
                GraphUpdate.remove_edges(ru, rv)
            )
        )
        assert res.feasible and res.imbalance <= eps + 1e-6
        assert res.region_size > 0
    st = sess.stats()
    assert st["repair_calls"] == 4
    assert st["repair_compiles"] == st["repair_bucket_count"]
    # each repair dispatches 5 kernel families (frontier, gather, sweep,
    # gain, balance); a compile-per-call regression would hit ~20
    assert st["repair_compiles"] <= 12
    assert st["compact_compiles"] == st["compact_bucket_count"]
    # quality: within a loose factor of a fresh full V-cycle on the final
    # graph (the benchmark pins the tight 5% acceptance number)
    gh = sess.store.csr_host()
    full = partition(gh, PartitionerConfig(k=4, preset="fast", seed=1))
    assert sess.cut <= max(1.35 * full.cut, full.cut + 50)


def test_session_repair_is_deterministic():
    """Same initial graph + config + stream => bit-identical labels."""
    g = planted_partition(1200, 6, p_in=0.04, p_out=0.003, seed=4)

    def run():
        sess = _mk_session(g, k=2)
        rng = np.random.default_rng(3)
        for _ in range(2):
            u = rng.integers(0, g.n, 25)
            v = (u + 1 + rng.integers(0, g.n - 1, 25)) % g.n
            sess.update(GraphUpdate.add_edges(u, v))
        return sess.labels_np()

    np.testing.assert_array_equal(run(), run())


def test_session_add_nodes_keeps_balance():
    g = planted_partition(1024, 8, p_in=0.04, p_out=0.002, seed=2)
    sess = _mk_session(g, k=2)
    res = sess.update(GraphUpdate.add_nodes(np.ones(24, np.int64)))
    assert res.feasible
    lab = sess.labels_np()
    assert lab.shape[0] == g.n + 24
    assert np.all(lab[g.n:] < 2)        # new nodes really assigned
    # wire the new nodes up and keep serving
    u = np.arange(g.n, g.n + 24, dtype=np.int64)
    v = np.arange(0, 24, dtype=np.int64)
    res = sess.update(GraphUpdate.add_edges(u, v))
    assert res.feasible and sess.n == g.n + 24


def test_session_node_growth_past_arena_rebuilds_engine():
    """n crossing the pow2 label arena forces a fresh engine; labels carry
    over and serving continues."""
    g = planted_partition(1000, 8, p_in=0.04, p_out=0.002, seed=3)
    sess = _mk_session(g, k=2)
    assert sess.engine.A == 1024
    lab_before = sess.labels_np().copy()
    res = sess.update(GraphUpdate.add_nodes(np.ones(40, np.int64)))
    assert sess.engine_rebuilds == 1 and sess.engine.A >= 2048
    assert res.feasible and sess.n == 1040
    np.testing.assert_array_equal(sess.labels_np()[:1000], lab_before)
    # and the new engine keeps repairing
    u = np.arange(1000, 1040, dtype=np.int64)
    v = np.arange(0, 40, dtype=np.int64)
    res = sess.update(GraphUpdate.add_edges(u, v))
    assert res.feasible and res.region_size > 0


def test_session_quality_guard_escalates_on_cut_collapse():
    """A huge random batch destroys locality; the guard must fire a full
    V-cycle and land back on a feasible partition."""
    g = planted_partition(1024, 8, p_in=0.05, p_out=0.001, seed=6)
    sess = _mk_session(g, k=2, escalate_cut_ratio=1.05, hops=1)
    rng = np.random.default_rng(5)
    u = rng.integers(0, g.n, 600)
    v = (u + 1 + rng.integers(0, g.n - 1, 600)) % g.n
    res = sess.update(GraphUpdate.add_edges(u, v))
    assert res.escalated and sess.escalations == 1
    assert res.feasible
