"""Device-memory accounting + capacity planning (PR 10).

Covers the accounting tentpole end to end:

* accountant mechanics — idempotent registration, finalizer-driven release,
  non-additive pins, hard-off fast path;
* the ``jax.live_arrays()`` oracle — on a served dynamic stream the family
  totals must match what the runtime actually holds, within padding slack;
* the capacity planner — ``estimate_footprint`` within 15% of measured
  peak family bytes on the ba-16384 acceptance graph, for both the full
  partition and the dynamic serving stream (the module fixture runs each
  once and every assertion reads the captured peaks);
* span watermarks — every per-level/per-phase footprint the tracer records
  is bounded by the global peak, which the estimate must cover;
* satellite 1 — the auto ``coarsest_factor`` makes ba-16384 actually
  coarsen (the 10000*k default meant no graph under ~40k nodes ever did).
"""

import gc
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.graph import barabasi_albert
from repro.core import PartitionerConfig, partition
from repro.core.engine import LPEngine
from repro.dynamic import GraphUpdate, PartitionSession, SessionConfig
from repro.obs import (
    MEMORY_FAMILIES, MetricsRegistry, Tracer, account, accountant,
    estimate_footprint, pin, set_accounting, set_tracer, will_fit,
)

K = 4
TOL = 0.15          # acceptance: estimate within 15% of measured peaks
MINOR = 0.01        # families below 1% of the total are noise, not gated


@pytest.fixture
def acct():
    """Enabled accountant, reset + disabled afterwards."""
    a = accountant()
    a.reset()
    prev = set_accounting(True)
    yield a
    set_accounting(prev)
    a.reset()


# --------------------------------------------------------------- mechanics


def test_register_release_and_idempotence(acct):
    x = jnp.zeros(1024, jnp.int32)
    acct.register("base_csr", x)
    assert acct.bytes_by_family["base_csr"] == x.nbytes
    assert acct.total == x.nbytes
    acct.register("base_csr", x)            # idempotent per buffer identity
    assert acct.total == x.nbytes
    acct.register("chunk_packs", x)         # even across families
    assert acct.total == x.nbytes
    nb = x.nbytes
    del x
    gc.collect()
    assert acct.bytes_by_family["base_csr"] == 0
    assert acct.total == 0
    assert acct.peak_by_family["base_csr"] == nb    # peaks survive release


def test_pin_is_non_additive(acct):
    x = jnp.ones(512, jnp.float32)
    acct.register("label_arenas", x)
    pin("snapshot_refs", x)
    assert acct.pinned_by_family["snapshot_refs"] == x.nbytes
    assert acct.total == x.nbytes           # pins never inflate the total
    del x
    gc.collect()
    assert acct.pinned_by_family["snapshot_refs"] == 0


def test_unknown_family_rejected(acct):
    with pytest.raises(KeyError):
        acct.register("not_a_family", jnp.zeros(8))


def test_disabled_is_inert_and_cheap():
    a = accountant()
    a.reset()
    assert not a.enabled
    x = jnp.zeros(4096, jnp.int32)
    account("base_csr", x)
    assert a.total == 0 and a.calls == 0
    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        account("base_csr", x)
    ns = (time.perf_counter() - t0) / n * 1e9
    assert ns < 5_000, f"disabled account() {ns:.0f}ns/call"


def test_registry_gauges_published(acct):
    reg = MetricsRegistry("t")
    set_accounting(True, registry=reg)
    x = jnp.zeros(256, jnp.int32)
    account("overlay_chunks", x)
    assert reg.get_gauge("mem.overlay_chunks_bytes") == x.nbytes
    assert reg.get_gauge("mem.total_bytes") == x.nbytes
    acct.registry = None


# ------------------------------------------------- ba-16384 acceptance run


@pytest.fixture(scope="module")
def ba16k_measured():
    """One accounted + traced run of the acceptance workloads on ba-16384:
    full partition, then a dynamic churn stream.  Returns the measured
    peaks, span watermarks, and coarse-level count for every test below."""
    g = barabasi_albert(16384, 6, seed=3)
    a = accountant()
    a.reset()
    prev = set_accounting(True)
    tracer = Tracer(enabled=True)
    prev_tracer = set_tracer(tracer)

    import repro.graph.csr as csr_mod
    coarse_levels = []
    orig_init = csr_mod.GraphDev.__init__

    def counting_init(self, *args, **kw):
        orig_init(self, *args, **kw)
        coarse_levels.append((self.n, self.m))

    csr_mod.GraphDev.__init__ = counting_init
    try:
        cfg = PartitionerConfig(k=K, preset="fast", seed=0)
        rep = partition(g, cfg)
        gc.collect()
        part_peaks = dict(a.snapshot()["peak_by_family"])
        part_marks = list(a.span_marks)
        part_levels = list(coarse_levels)
        del rep
        gc.collect()
        a.reset()

        sess = PartitionSession(g, SessionConfig(k=K, seed=0))
        a.reset_peaks()
        rng = np.random.default_rng(11)
        nb = max(g.m // 2 // 200, 64)
        for _ in range(4):
            u = rng.integers(0, g.n, nb)
            v = rng.integers(0, g.n, nb)
            keep = u != v
            sess.update(GraphUpdate.add_edges(u[keep], v[keep]))
            sess.update(GraphUpdate.remove_edges(u[keep], v[keep]))
        dyn_peaks = dict(a.snapshot()["peak_by_family"])
        dyn_cfg = sess.cfg
        slo = sess.stats()["slo_budget_remaining"]
        flight_len = len(sess.flight)
        del sess
    finally:
        csr_mod.GraphDev.__init__ = orig_init
        set_tracer(prev_tracer)
        set_accounting(prev)
        a.reset()
    return dict(
        g=g, cfg=cfg, dyn_cfg=dyn_cfg,
        part_peaks=part_peaks, part_marks=part_marks,
        part_levels=part_levels, dyn_peaks=dyn_peaks,
        slo=slo, flight_len=flight_len,
    )


def _assert_families_within(est: dict, peaks: dict, tol: float) -> None:
    total_meas = sum(peaks.values())
    assert total_meas > 0
    # planning bound: sum of family peaks (families peak in different
    # phases, the estimate models each one's peak)
    assert abs(est["total"] - total_meas) <= tol * total_meas, (
        f"total estimate {est['total']} vs measured {total_meas}"
    )
    for fam in MEMORY_FAMILIES:
        meas = peaks.get(fam, 0)
        if max(meas, est.get(fam, 0)) < MINOR * total_meas:
            continue                        # sub-1% families are noise
        assert meas > 0, f"{fam}: estimated {est[fam]} but measured 0"
        assert abs(est[fam] - meas) <= tol * meas, (
            f"{fam}: estimate {est[fam]} vs measured peak {meas}"
        )


def test_partition_estimate_within_tolerance(ba16k_measured):
    d = ba16k_measured
    g = d["g"]
    est = estimate_footprint(g.n, g.m, K, d["cfg"], workload="partition")
    _assert_families_within(est, d["part_peaks"], TOL)


def test_dynamic_estimate_within_tolerance(ba16k_measured):
    d = ba16k_measured
    g = d["g"]
    est = estimate_footprint(g.n, g.m, K, d["dyn_cfg"], workload="dynamic")
    _assert_families_within(est, d["dyn_peaks"], TOL)


def test_vcycle_watermarks_consistent_with_estimate(ba16k_measured):
    """Every span-close watermark the tracer recorded during the V-cycle is
    bounded by the global peak, and the capacity estimate covers that peak:
    watermark <= peak <= estimate * (1 + tol)."""
    d = ba16k_measured
    g = d["g"]
    marks = d["part_marks"]
    assert marks, "traced partition recorded no span watermarks"
    peak = max(m["total"] for m in marks)
    # per-phase totals are monotone-consistent: none exceeds the peak, and
    # the sum of any mark's family breakdown equals its total
    for m in marks:
        assert m["total"] <= peak
        assert sum(m["by_family"].values()) == m["total"]
    est = estimate_footprint(g.n, g.m, K, d["cfg"], workload="partition")
    assert peak <= est["total"] * (1 + TOL), (
        f"watermark peak {peak} exceeds estimate {est['total']}"
    )


def test_ba16384_coarsens_at_least_one_level(ba16k_measured):
    """Satellite 1 regression: with the auto coarsest target the ba-16384
    V-cycle contracts (the old 10000*k default meant it never did — the
    'multilevel' pipeline was flat LP on every bench-sized graph)."""
    levels = ba16k_measured["part_levels"]
    assert len(levels) >= 1, "no coarse level was ever contracted"
    n0 = ba16k_measured["g"].n
    assert all(n < n0 for n, _m in levels)
    # and the default config agrees: 0 == auto
    assert PartitionerConfig().coarsest_factor == 0


def test_flight_recorder_and_slo_gauge(ba16k_measured):
    d = ba16k_measured
    assert d["flight_len"] == 8             # 4 add + 4 remove batches
    assert 0.0 <= d["slo"] <= 1.0


# ----------------------------------------------------- live_arrays oracle


def test_family_totals_match_live_arrays_oracle():
    """Family-bytes sum vs a ``jax.live_arrays()`` sweep on a served
    stream: the accountant attributes (almost) everything the runtime
    actually holds — within padding/transient slack, never more."""
    g = barabasi_albert(4096, 6, seed=3)
    gc.collect()
    base_ids = {id(x) for x in jax.live_arrays()}
    a = accountant()
    a.reset()
    prev = set_accounting(True)
    try:
        sess = PartitionSession(g, SessionConfig(k=K, seed=0))
        rng = np.random.default_rng(11)
        for _ in range(4):
            u = rng.integers(0, g.n, 128)
            v = rng.integers(0, g.n, 128)
            keep = u != v
            sess.update(GraphUpdate.add_edges(u[keep], v[keep]))
            sess.update(GraphUpdate.remove_edges(u[keep], v[keep]))
        gc.collect()
        fresh = [x for x in jax.live_arrays() if id(x) not in base_ids]
        oracle = sum(int(x.nbytes) for x in fresh)
        snap = a.snapshot()
        assert snap["total"] == sum(snap["by_family"].values())
        assert snap["total"] <= oracle * 1.001, (
            f"accounted {snap['total']} > live {oracle}"
        )
        assert snap["total"] >= 0.85 * oracle, (
            f"accounted {snap['total']} misses too much of live {oracle}"
        )
        del sess
    finally:
        set_accounting(prev)
        a.reset()


# --------------------------------------------------------------- planning


def test_estimate_footprint_shapes():
    est = estimate_footprint(100_000, 1_200_000, 8)
    for fam in MEMORY_FAMILIES:
        assert fam in est and est[fam] >= 0
    assert est["total"] == sum(est[f] for f in MEMORY_FAMILIES)
    assert est["levels"] == 1 and est["coarsest_target"] == 12_500
    dyn = estimate_footprint(100_000, 1_200_000, 8, workload="dynamic")
    assert dyn["total"] > 0 and dyn["base_csr"] > 0
    assert dyn["evo_population"] == 0       # no GA stage while serving
    with pytest.raises(ValueError):
        estimate_footprint(1000, 4000, 2, workload="nope")


def test_will_fit_pre_upload_check():
    res = will_fit(16384, 200_000, 4, budget_bytes=1 << 40)
    assert res["fits"] is True
    res = will_fit(16384, 200_000, 4, budget_bytes=1 << 10)
    assert res["fits"] is False
    assert res["required_bytes"] > res["estimate"]["total"]  # safety margin
    # platform default: CPU exposes no bytes_limit -> degrades to None/bool
    res = will_fit(1024, 8000, 2)
    assert res["fits"] in (None, True, False)
    # and the engine exposes it as the pre-upload check
    res = LPEngine.will_fit(16384, 200_000, 4, budget_bytes=1 << 40)
    assert res["fits"] is True
