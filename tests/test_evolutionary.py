"""KaFFPaE-style island GA on the coarsest graph."""

import numpy as np

from repro.core import EvoConfig, evolve, initial_partition
from repro.core.metrics import cut_np, is_feasible, lmax
from repro.graph import planted_partition


def test_evolve_feasible_and_competitive():
    g = planted_partition(1024, 4, p_in=0.03, p_out=0.001, seed=1)
    L = lmax(g.n, 2, 0.03)
    single = initial_partition(g, 2, L, seed=7)
    lab = evolve(g, EvoConfig(k=2, Lmax=L, islands=2, pop_per_island=2,
                              generations=4, seed=0))
    assert is_feasible(g, lab, 2, 0.03)
    assert cut_np(g, lab) <= cut_np(g, single) * 1.05


def test_seeded_evolve_never_worse_than_seed():
    """V-cycle guarantee: the previous solution is an individual, so the
    result can only match or improve it."""
    g = planted_partition(1024, 4, p_in=0.03, p_out=0.001, seed=2)
    L = lmax(g.n, 2, 0.03)
    seed_lab = initial_partition(g, 2, L, seed=3)
    lab = evolve(g, EvoConfig(k=2, Lmax=L, islands=2, pop_per_island=2,
                              generations=3, seed=1,
                              seed_individuals=[seed_lab.astype(np.int64)]))
    assert cut_np(g, lab) <= cut_np(g, seed_lab)
