"""Modularity clustering — the paper's §VI generalization, built on the
same cluster-contraction machinery."""

import numpy as np

from repro.core.modularity import louvain, modularity, modularity_lp
from repro.graph import from_edges, planted_partition


def _ring_of_cliques(n_cliques=8, size=6):
    us, vs = [], []
    for c in range(n_cliques):
        base = c * size
        for i in range(size):
            for j in range(i + 1, size):
                us.append(base + i)
                vs.append(base + j)
        us.append(base)  # one bridge edge to the next clique
        vs.append(((c + 1) % n_cliques) * size)
    return from_edges(n_cliques * size, np.array(us), np.array(vs))


def test_louvain_recovers_cliques():
    g = _ring_of_cliques()
    lab, q = louvain(g, seed=0)
    assert q > 0.7
    # every clique ends up in exactly one cluster
    for c in range(8):
        assert np.unique(lab[c * 6 : (c + 1) * 6]).size == 1


def test_louvain_on_planted_partition():
    g = planted_partition(2048, 8, p_in=0.05, p_out=0.001, seed=1)
    lab, q = louvain(g, seed=0)
    rand = modularity(g, np.random.default_rng(0).integers(0, 8, g.n))
    assert q > 0.5 and q > rand + 0.3


def test_modularity_lp_monotone():
    g = planted_partition(1024, 4, p_in=0.05, p_out=0.002, seed=2)
    q0 = modularity(g, np.arange(g.n))
    lab = modularity_lp(g, np.arange(g.n), seed=0)
    assert modularity(g, lab) > q0
