"""Per-arch smoke tests: REDUCED same-family config, one forward/train step
on CPU, output shapes + no NaNs (the FULL configs are exercised only via the
dry-run)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cells
from repro.models import decode_step, forward, init_caches, init_params, loss_fn


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_loss_decode(arch):
    cfg = ARCHS[arch].smoke()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    pe = (jax.random.normal(key, (B, cfg.n_prefix, cfg.d_model), jnp.float32)
          if cfg.n_prefix else None)
    logits, aux, _ = forward(cfg, params, tokens, prefix_embeds=pe, remat=False)
    assert logits.shape == (B, S + cfg.n_prefix, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    batch = {"tokens": tokens}
    if pe is not None:
        batch["prefix_embeds"] = pe
    loss, metrics = loss_fn(cfg, params, batch, remat=True)
    assert np.isfinite(float(loss))
    caches = init_caches(cfg, B, 48)
    lg, caches = decode_step(cfg, params, tokens[:, 0], caches, jnp.int32(0))
    assert lg.shape == (B, cfg.vocab)
    assert not bool(jnp.isnan(lg).any())


def test_configs_match_assignment():
    """Exact figures from the assignment table."""
    c = ARCHS["qwen2.5-3b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (36, 2048, 16, 2, 11008, 151936) and c.qkv_bias
    c = ARCHS["qwen1.5-110b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (80, 8192, 64, 8, 49152, 152064) and c.qkv_bias
    c = ARCHS["gemma3-27b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (62, 5376, 32, 16, 21504, 262144)
    assert c.pattern_unit.count("attn_local") == 5  # 5:1 local:global
    c = ARCHS["internlm2-20b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (48, 6144, 48, 8, 16384, 92544)
    c = ARCHS["musicgen-large"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (48, 2048, 32, 8192, 2048)
    c = ARCHS["phi-3-vision-4.2b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (32, 3072, 32, 8192, 32064)
    c = ARCHS["mamba2-2.7b"]
    assert (c.n_layers, c.d_model, c.vocab, c.ssm.d_state) == (64, 2560, 50280, 128)
    assert c.pattern_unit == ("mamba",)
    c = ARCHS["dbrx-132b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == \
        (40, 6144, 48, 8, 100352)
    assert (c.moe.n_experts, c.moe.topk, c.moe.d_ff) == (16, 4, 10752)
    c = ARCHS["granite-moe-1b-a400m"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == \
        (24, 1024, 16, 8, 49155)
    assert (c.moe.n_experts, c.moe.topk, c.moe.d_ff) == (32, 8, 512)
    c = ARCHS["jamba-1.5-large-398b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (72, 8192, 64, 8, 24576, 65536)
    assert (c.moe.n_experts, c.moe.topk) == (16, 2)
    assert c.pattern_unit.count("mamba") == 7 and c.pattern_unit.count("attn") == 1


def test_cell_grid_counts():
    cs = cells()
    assert len(cs) == 40  # 10 archs x 4 shapes
    skips = [c for c in cs if c[2]]
    # long_500k skipped exactly for the 7 pure full-attention archs
    assert len(skips) == 7
    assert all(s == "long_500k" for _, s, _ in skips)
    runs_500k = {a for a, s, skip in cs if s == "long_500k" and not skip}
    assert runs_500k == {"mamba2-2.7b", "jamba-1.5-large-398b", "gemma3-27b"}


def test_attention_sliding_window_masks_correctly():
    from repro.models.layers import attention, init_attn_params
    key = jax.random.PRNGKey(0)
    D, H, dh = 32, 4, 8
    p = init_attn_params(key, D, H, H, dh, False, jnp.float32)
    x = jax.random.normal(key, (1, 12, D))
    yw, _ = attention(p, x, n_heads=H, n_kv=H, d_head=dh, window=4, q_chunk=4)
    # perturbing a token > window positions in the past must not change output
    x2 = x.at[0, 0].add(10.0)
    yw2, _ = attention(p, x2, n_heads=H, n_kv=H, d_head=dh, window=4, q_chunk=4)
    np.testing.assert_allclose(np.asarray(yw[0, 6:]), np.asarray(yw2[0, 6:]),
                               atol=1e-5)
    yf2, _ = attention(p, x2, n_heads=H, n_kv=H, d_head=dh, window=None, q_chunk=4)
    assert float(jnp.abs(yf2[0, 6:] - yw2[0, 6:]).max()) > 1e-4


def test_attention_prefill_decode_consistency():
    from repro.models.layers import attention, decode_attention, init_attn_params
    key = jax.random.PRNGKey(3)
    D, H, KV, dh = 32, 4, 2, 8
    p = init_attn_params(key, D, H, KV, dh, True, jnp.float32)
    x = jax.random.normal(key, (2, 9, D)) * 0.5
    y_full, _ = attention(p, x, n_heads=H, n_kv=KV, d_head=dh, q_chunk=4)
    _, cache = attention(p, x[:, :-1], n_heads=H, n_kv=KV, d_head=dh,
                         q_chunk=4, return_cache=True)
    cache = {k: jnp.pad(v, ((0, 0), (0, 8), (0, 0), (0, 0)))
             for k, v in cache.items()}
    y_dec, _ = decode_attention(p, x[:, -1:], cache, jnp.int32(8),
                                n_heads=H, n_kv=KV, d_head=dh)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, -1:]),
                               rtol=2e-4, atol=2e-4)
