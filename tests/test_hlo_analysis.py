"""Trip-count-aware HLO analyzer on a hand-built module + a real lowering."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo


def test_scan_flops_multiplied_by_trip_count():
    """A matmul inside lax.scan must be counted once per iteration."""
    W = jnp.ones((64, 64), jnp.float32)

    def step(x, _):
        return x @ W, None

    def f(x):
        y, _ = jax.lax.scan(step, x, None, length=10)
        return y

    txt = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    c = analyze_hlo(txt)
    expected = 10 * 2 * 64 * 64 * 64  # 10 iterations x 2*M*N*K
    assert 0.9 * expected <= c.flops <= 1.3 * expected, (c.flops, expected)
    assert c.unknown_trip_loops == 0


def test_unrolled_matches_scan():
    W = jnp.ones((32, 32), jnp.float32)

    def f_unrolled(x):
        for _ in range(6):
            x = x @ W
        return x

    def f_scan(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ W, None), x, None, length=6)
        return y

    t1 = jax.jit(f_unrolled).lower(jnp.ones((32, 32))).compile().as_text()
    t2 = jax.jit(f_scan).lower(jnp.ones((32, 32))).compile().as_text()
    f1, f2 = analyze_hlo(t1).flops, analyze_hlo(t2).flops
    assert abs(f1 - f2) / max(f1, f2) < 0.05, (f1, f2)


def test_collectives_counted():
    import os
    import subprocess
    import sys

    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_mesh
mesh = make_mesh((4,), ("x",))
sh = NamedSharding(mesh, P("x", None))
f = jax.jit(lambda a: (a @ a.T).sum(), in_shardings=sh)
txt = f.lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile().as_text()
c = analyze_hlo(txt)
assert c.collective_total > 0, c.collective_bytes
print("COLL-OK", c.collective_bytes)
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300, env=env)
    assert "COLL-OK" in r.stdout, r.stderr[-2000:]
