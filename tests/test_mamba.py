"""Mamba2 SSD: chunked scan vs naive recurrence oracle + decode consistency."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.mamba2 import (
    _ssd_chunked, init_mamba_cache, init_mamba_params, mamba_block,
    mamba_decode,
)


def _naive_ssd(X, dt, A, Bm, Cm, h0):
    """Direct O(S) recurrence: the definitional oracle."""
    B, S, H, P = X.shape
    N = Bm.shape[-1]
    h = np.array(h0, dtype=np.float64)
    Y = np.zeros((B, S, H, P))
    a = np.exp(np.array(dt) * np.array(A)[None, None, :])
    for t in range(S):
        h = h * a[:, t][:, :, None, None] + np.einsum(
            "bh,bn,bhp->bhpn", np.array(dt[:, t]), np.array(Bm[:, t]),
            np.array(X[:, t]),
        )
        Y[:, t] = np.einsum("bn,bhpn->bhp", np.array(Cm[:, t]), h)
    return Y, h


def test_chunked_ssd_matches_naive_recurrence():
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 37, 4, 8, 16  # deliberately not a chunk multiple
    ks = jax.random.split(key, 5)
    X = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, N)) * 0.3
    h0 = jnp.zeros((B, H, P, N))
    Y, hf = _ssd_chunked(X, dt, A, Bm, Cm, h0, chunk=8, head_block=2)
    Y_ref, h_ref = _naive_ssd(X, dt, A, Bm, Cm, h0)
    np.testing.assert_allclose(np.asarray(Y), Y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=2e-4, atol=2e-4)


def test_prefill_then_decode_matches_full_forward():
    key = jax.random.PRNGKey(1)
    D, dstate, headdim, expand, W = 32, 16, 8, 2, 4
    p = init_mamba_params(key, D, dstate, headdim, expand, W, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 21, D)) * 0.5
    # full forward over S+1 tokens
    y_full, _ = mamba_block(p, x, d_state=dstate, headdim=headdim, chunk=8)
    # prefill S tokens -> cache -> decode token S
    y_pre, cache = mamba_block(p, x[:, :-1], d_state=dstate, headdim=headdim,
                               chunk=8, return_cache=True)
    y_dec, _ = mamba_decode(p, x[:, -1:], cache, d_state=dstate, headdim=headdim)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full[:, -1:]),
                               rtol=2e-3, atol=2e-3)
