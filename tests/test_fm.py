"""Gain-based FM local search."""

import numpy as np

from repro.core import fm_refine
from repro.core.metrics import block_weights_np, cut_np, lmax
from repro.graph import mesh2d, rmat


def test_fm_never_worsens_and_respects_balance():
    g = rmat(11, 8, seed=4)
    rng = np.random.default_rng(0)
    k = 4
    lab = rng.integers(0, k, g.n).astype(np.int32)
    L = lmax(g.n, k, 0.03)
    out = fm_refine(g, lab, k, L, seed=1)
    assert cut_np(g, out) <= cut_np(g, lab)
    assert block_weights_np(g, out, k).max() <= max(
        block_weights_np(g, lab, k).max(), L
    )


def test_fm_improves_noisy_split():
    side = 32
    g = mesh2d(side)
    truth = (np.arange(g.n) // side >= side // 2).astype(np.int32)
    rng = np.random.default_rng(1)
    noisy = truth.copy()
    noisy[rng.random(g.n) < 0.1] ^= 1
    L = lmax(g.n, 2, 0.03)
    out = fm_refine(g, noisy, 2, L, seed=0)
    assert cut_np(g, out) < cut_np(g, noisy) / 3
