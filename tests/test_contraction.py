"""Cluster contraction: the cut/balance-preservation property the whole
multilevel scheme rests on."""

import numpy as np

from repro.core import contract, project_labels, relabel
from repro.core.metrics import cut_np
from repro.graph import rmat


def test_relabel_contiguous():
    lab = np.array([7, 3, 7, 9, 3])
    C, n = relabel(lab)
    assert n == 3
    assert set(C.tolist()) == {0, 1, 2}


def test_contraction_preserves_cut_and_weight():
    g = rmat(11, 8, seed=5)
    rng = np.random.default_rng(0)
    clusters = rng.integers(0, 200, g.n)
    coarse, C = contract(g, clusters)
    assert coarse.nw.sum() == g.nw.sum()
    # any partition of the coarse graph induces the same cut on the fine graph
    for k in (2, 5):
        lab_c = rng.integers(0, k, coarse.n).astype(np.int32)
        lab_f = project_labels(lab_c, C)
        assert abs(cut_np(coarse, lab_c) - cut_np(g, lab_f)) < 1e-3
        bw_c = np.bincount(lab_c, weights=coarse.nw, minlength=k)
        bw_f = np.bincount(lab_f, weights=g.nw, minlength=k)
        np.testing.assert_allclose(bw_c, bw_f, rtol=1e-6)


def test_contract_self_loops_dropped():
    g = rmat(10, 8, seed=6)
    coarse, C = contract(g, np.zeros(g.n, dtype=np.int64))
    assert coarse.n == 1 and coarse.m == 0
