"""Hypothesis property tests for the system's invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core import contract, project_labels, repair_balance
from repro.core.metrics import block_weights_np, cut_np, lmax
from repro.graph import from_edges


@st.composite
def graphs(draw):
    n = draw(st.integers(4, 60))
    m = draw(st.integers(1, 150))
    u = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    v = draw(st.lists(st.integers(0, n - 1), min_size=m, max_size=m))
    w = draw(st.lists(st.integers(1, 5), min_size=m, max_size=m))
    g = from_edges(n, np.array(u), np.array(v), np.array(w, dtype=np.float32))
    return g


@given(graphs(), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_contraction_preserves_cut(g, seed):
    rng = np.random.default_rng(seed)
    clusters = rng.integers(0, max(2, g.n // 3), g.n)
    coarse, C = contract(g, clusters)
    assert np.isclose(coarse.nw.sum(), g.nw.sum())
    lab_c = rng.integers(0, 3, coarse.n).astype(np.int32)
    lab_f = project_labels(lab_c, C)
    assert np.isclose(cut_np(coarse, lab_c), cut_np(g, lab_f))
    # total edge weight of coarse graph == weight of inter-cluster edges
    inter = cut_np(g, clusters.astype(np.int32))
    assert np.isclose(coarse.ew.sum() / 2.0, inter)


@given(graphs(), st.integers(2, 4), st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_repair_balance_reaches_feasibility(g, k, seed):
    rng = np.random.default_rng(seed)
    lab = rng.integers(0, k, g.n).astype(np.int32)
    L = lmax(g.total_node_weight, k, 0.3)  # generous eps: always repairable
    out = repair_balance(g, lab, k, L, seed=seed)
    assert block_weights_np(g, out, k).max() <= L + 1e-6
