"""Device-resident coarsening: LPEngine.contract must be structure-identical
to the host contract() oracle, keep the cut/balance-preservation property
under projection, chain level-to-level without host round-trips, and compile
at most once per shape bucket."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import LPEngine, PartitionerConfig, contract, partition
from repro.core.contraction import CoarseMap
from repro.core.metrics import cut_np, lmax
from repro.graph import GraphDev, barabasi_albert, mesh2d, planted_partition, rmat


def _graphs():
    return [
        rmat(10, 8, seed=5),              # power-law web stand-in
        mesh2d(20),                       # mesh type
        planted_partition(1500, 8, p_in=0.03, p_out=0.002, seed=1),
        barabasi_albert(257, 3, seed=2),  # just past a pow2 bucket boundary
        barabasi_albert(256, 3, seed=2),  # exactly on a pow2 bucket boundary
    ]


@pytest.mark.parametrize("case", range(5))
def test_contract_matches_host_oracle(case):
    """Identical node weights, identical arc multiset (in fact identical CSR:
    both paths emit arcs in (cu, cv) order with np.unique relabel semantics),
    across random clusterings and bucket-boundary sizes."""
    g = _graphs()[case]
    rng = np.random.default_rng(case)
    for trial in range(3):
        labels = rng.integers(0, max(g.n // (2 + trial), 2), g.n).astype(np.int32)
        eng = LPEngine(g, seed=0)
        cdev, cmap = eng.contract(g, labels)
        chost, C_host = contract(g, labels)
        assert isinstance(cdev, GraphDev)
        assert isinstance(cmap, CoarseMap)
        assert (cdev.n, cdev.m) == (chost.n, chost.m)
        np.testing.assert_array_equal(cmap.host(), C_host)
        gh = cdev.to_host()
        np.testing.assert_array_equal(gh.indptr, chost.indptr)
        np.testing.assert_array_equal(gh.indices, chost.indices)
        np.testing.assert_allclose(gh.ew, chost.ew, rtol=1e-6)
        np.testing.assert_allclose(gh.nw, chost.nw, rtol=1e-6)


def test_contract_preserves_cut_and_balance_under_projection():
    """The multilevel invariant, property-style on the device path: any
    partition of the coarse graph projects to the fine graph with identical
    cut and block weights."""
    g = rmat(11, 8, seed=5)
    rng = np.random.default_rng(0)
    clusters = rng.integers(0, 200, g.n)
    eng = LPEngine(g, seed=0)
    cdev, cmap = eng.contract(g, clusters)
    gh = cdev.to_host()
    assert np.isclose(gh.nw.sum(), g.nw.sum())
    for k in (2, 5):
        lab_c = rng.integers(0, k, cdev.n).astype(np.int32)
        lab_f_dev = eng.project(jnp.asarray(lab_c), cmap, fill=k)
        lab_f = np.asarray(lab_f_dev[: g.n])
        np.testing.assert_array_equal(lab_f, lab_c[cmap.host()])
        assert abs(cut_np(gh, lab_c) - cut_np(g, lab_f)) < 1e-3
        bw_c = np.bincount(lab_c, weights=gh.nw, minlength=k)
        bw_f = np.bincount(lab_f, weights=g.nw, minlength=k)
        np.testing.assert_allclose(bw_c, bw_f, rtol=1e-6)


def test_chained_device_levels_match_host_chain():
    """cluster -> contract -> cluster -> contract stays on device (GraphDev
    in, GraphDev out) and reproduces the host chain bit-for-bit."""
    g = barabasi_albert(4096, 5, seed=1)
    L = lmax(g.n, 2, 0.03)
    U = max(1.0, L / 14)
    eng = LPEngine(g, seed=0)
    lab1 = eng.cluster(g, U=U, iters=3, seed=7)
    cdev, _ = eng.contract(g, lab1)
    lab2 = eng.cluster(cdev, U=U, iters=3, seed=8)
    assert isinstance(lab2, jax.Array)
    cdev2, _ = eng.contract(cdev, lab2)
    # host oracle chain from the materialized level-1 graph
    chost2, _ = contract(cdev.to_host(), np.asarray(lab2))
    gh2 = cdev2.to_host()
    np.testing.assert_array_equal(gh2.indptr, chost2.indptr)
    np.testing.assert_array_equal(gh2.indices, chost2.indices)
    np.testing.assert_allclose(gh2.ew, chost2.ew, rtol=1e-6)
    np.testing.assert_allclose(gh2.nw, chost2.nw, rtol=1e-6)
    # the second-level pack was gathered on device, not repacked on host
    assert eng.stats.gather_builds >= 1


def test_contract_single_cluster_and_empty_quotient():
    g = rmat(9, 8, seed=6)
    eng = LPEngine(g, seed=0)
    cdev, cmap = eng.contract(g, np.zeros(g.n, dtype=np.int32))
    assert cdev.n == 1 and cdev.m == 0
    assert cmap.n_coarse == 1
    gh = cdev.to_host()
    assert gh.m == 0 and np.isclose(gh.nw.sum(), g.nw.sum())


def test_partition_device_coarsening_matches_host_coarsening():
    """The fused pipeline: engine-path partition() with device contraction
    produces the same labels as the host-contract fallback (the relabel
    order, arc order, and f32 integer-weight sums are all exact)."""
    g = barabasi_albert(8192, 6, seed=3)
    base = dict(k=2, preset="fast", coarsest_factor=100, seed=0)
    rep_dev = partition(g, PartitionerConfig(**base))
    rep_host = partition(g, PartitionerConfig(**base, coarsen_engine="host"))
    assert rep_dev.feasible
    np.testing.assert_array_equal(rep_dev.labels, rep_host.labels)
    assert rep_dev.cut == rep_host.cut
    st = rep_dev.engine_stats
    assert st["contract_calls"] >= 2          # >= 1 device level per cycle
    assert rep_host.engine_stats["contract_calls"] == 0


def test_packed_key_fallback_threshold_pinned():
    """ISSUE 4 satellite: pin the packed-key -> scatter-add fallback
    boundary (``Nb^2 * 2^wbits > PACKED_KEY_SPACE = 2^32``, plus the int32
    cumsum bound ``Mb * (2^wbits - 1) < 2^31``) so a future x64 enablement
    can't silently flip the fast path without updating this test."""
    from repro.core.contraction import PACKED_KEY_SPACE, packed_key_wbits

    assert PACKED_KEY_SPACE == 2**32
    # exactly ON the key-space boundary: (2^12)^2 * 2^8 == 2^32 -> fast path
    assert packed_key_wbits(2**12, 10_000, ew_max=255.0, ew_integral=True) == 8
    # one weight bit past it -> fallback
    assert packed_key_wbits(2**12, 10_000, ew_max=256.0, ew_integral=True) == 0
    # same overflow driven by the node bucket instead of the weight
    assert packed_key_wbits(2**13, 10_000, ew_max=255.0, ew_integral=True) == 0
    # int32 cumsum bound: Mb * (2^b - 1) must stay below 2^31
    assert packed_key_wbits(2**8, 2**24, ew_max=255.0, ew_integral=True) == 0
    assert packed_key_wbits(2**8, 2**22, ew_max=255.0, ew_integral=True) == 8
    # non-integral or sub-1 weights never pack
    assert packed_key_wbits(2**4, 100, ew_max=3.5, ew_integral=False) == 0
    assert packed_key_wbits(2**4, 100, ew_max=0.0, ew_integral=True) == 0


def test_packed_key_fallback_contract_matches_oracle():
    """Weights big enough to overflow the packed key select wbits=0 (visible
    in the engine's contract bucket keys) and still reproduce the host
    oracle; the same shape with unit weights stays on the fast path."""
    from repro.graph import from_edges

    rng = np.random.default_rng(0)
    n = 256
    u = rng.integers(0, n, 800)
    v = (u + 1 + rng.integers(0, n - 1, 800)) % n
    w_big = (rng.integers(1, 8, 800) * 2**18).astype(np.float32)
    g_big = from_edges(n, u, v, w=w_big)
    g_unit = from_edges(n, u, v, w=np.ones(800, np.float32))
    labels = rng.integers(0, 50, n).astype(np.int32)
    for g, want_packed in ((g_big, False), (g_unit, True)):
        eng = LPEngine(g, seed=0)
        cdev, cmap = eng.contract(g, labels)
        (ckey,) = eng.stats.contract_buckets
        assert (ckey[2] > 0) == want_packed
        chost, C_host = contract(g, labels)
        np.testing.assert_array_equal(cmap.host(), C_host)
        gh = cdev.to_host()
        np.testing.assert_array_equal(gh.indptr, chost.indptr)
        np.testing.assert_array_equal(gh.indices, chost.indices)
        np.testing.assert_allclose(gh.ew, chost.ew, rtol=1e-6)


def test_contract_compile_count_bounded_by_buckets():
    """Compile-count regression: a multi-level, multi-cycle run dispatches
    one contraction compile per (Nb, Mb) bucket — never per level x cycle."""
    g = barabasi_albert(8192, 6, seed=3)
    cfg = PartitionerConfig(k=2, preset="fast", coarsest_factor=20, seed=0,
                            engine="jnp", numpy_below=64)
    rep = partition(g, cfg)
    st = rep.engine_stats
    assert rep.feasible
    assert st["contract_calls"] >= 4          # multiple levels x 2 cycles
    assert st["contract_compiles"] == st["contract_bucket_count"]
    assert st["contract_compiles"] <= st["contract_calls"]
    # pack gathers for device levels also compile at most once per shape
    assert st["gather_compiles"] <= max(st["gather_builds"], 1)
    # the whole-run host traffic is scalars + the coarsest/evo materializations,
    # not per-level O(m) round-trips: far below one download of the fine graph
    assert st["d2h_bytes"] < g.m * 4
