# NOTE: do NOT set XLA_FLAGS / device counts here — smoke tests and benches
# must see 1 device.  Multi-device tests spawn subprocesses with their own
# XLA_FLAGS (see tests/_subproc.py).
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
