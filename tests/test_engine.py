"""LP engine: shape-bucketed jit caching, pack reuse, padding parity,
device-resident refinement, and the dense (Pallas) refinement wiring."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import LPEngine, PartitionerConfig, partition
from repro.core.label_propagation import lp_cluster, make_order
from repro.core.metrics import cut_np, lmax
from repro.graph import barabasi_albert, mesh2d, pack_chunks, planted_partition


def test_compile_count_bounded_across_vcycles():
    """The headline cache property: a 2-V-cycle, multi-level partition() run
    dispatches many sweeps but compiles _lp_sweep at most once per
    (bucket, statics) combination, instead of one compile per level x cycle
    as the pre-engine driver did.  Since the device-coarsening PR, coarse
    GraphDev levels carry their own pow2 live-chunk bucket (dead chunks of
    the finest bucket would multiply the pack gather), so the bound is a
    couple of chunk-shape buckets x statics — still independent of the
    V-cycle count."""
    g = barabasi_albert(4096, 5, seed=1)
    cfg = PartitionerConfig(
        k=2, preset="fast", coarsest_factor=20, seed=0, engine="jnp"
    )
    rep = partition(g, cfg)
    st = rep.engine_stats
    assert st is not None
    # at least 3 levels per cycle, 2 cycles, cluster+refine at every level
    assert st["sweep_calls"] >= 8
    assert st["sweep_compiles"] <= 8
    assert st["sweep_compiles"] <= st["bucket_count"] * 3  # statics combos
    assert st["sweep_compiles"] < st["sweep_calls"]
    # V-cycle 2 must reuse V-cycle 1's packs for the shared (finest) graph
    assert st["pack_hits"] >= 1
    assert rep.feasible


def test_bucketed_pack_parity_with_exact_shapes():
    """Padding packs/arenas to power-of-two buckets must not change a single
    move decision: the tie-break jitter is a stateless hash of integer
    coordinates, never a function of array shapes."""
    g = planted_partition(2048, 8, p_in=0.04, p_out=0.001, seed=0)
    U, iters, seed = 60.0, 3, 7
    eng = LPEngine(g, seed=0)
    n_cap, e_cap, blk = eng.N, eng._e_request, eng.pack_block  # pre-raise floors
    lab_bucketed = eng.cluster(g, U=U, iters=iters, seed=seed)
    # exact-shape path: same traversal order, same sweep seed, no padding
    pack = pack_chunks(
        g, make_order(g, "degree", 0), max_nodes=n_cap, max_edges=e_cap, block=blk
    )
    # the engine genuinely padded something relative to the exact path
    assert eng.A > g.n + 1 or eng.C_bucket > pack.nodes.shape[0]
    lab_exact = lp_cluster(g, U=U, iters=iters, seed=seed, pack=pack).labels
    np.testing.assert_array_equal(lab_bucketed, lab_exact)


def test_pack_cache_reuse_is_by_identity():
    """Same graph object -> cache hit; a different graph object (even of the
    same shape) -> rebuild.  Guards against stale packs after contraction."""
    g1 = mesh2d(32)
    g2 = mesh2d(32)
    eng = LPEngine(g1, seed=0)
    eng.cluster(g1, U=50.0, iters=1, seed=0)
    builds = eng.stats.pack_builds
    eng.cluster(g1, U=50.0, iters=1, seed=1)
    assert eng.stats.pack_builds == builds  # hit
    assert eng.stats.pack_hits >= 1
    eng.cluster(g2, U=50.0, iters=1, seed=0)
    assert eng.stats.pack_builds == builds + 1  # distinct object -> rebuild


def test_engine_refine_device_resident_recovers_split():
    """engine.refine takes/returns device arena labels and matches the
    quality of the host-wrapper path on the noisy-bisection task."""
    side = 48
    g = mesh2d(side)
    truth = (np.arange(g.n) // side >= side // 2).astype(np.int32)
    rng = np.random.default_rng(1)
    noisy = truth.copy()
    noisy[rng.random(g.n) < 0.15] ^= 1
    L = lmax(g.n, 2, 0.03)
    eng = LPEngine(g, seed=0)
    lab_dev = eng.refine(g, noisy, k=2, U=L, iters=6, seed=3)
    assert isinstance(lab_dev, jnp.ndarray) and lab_dev.shape[0] == eng.A
    # chain a second device-resident pass without any host round-trip
    lab_dev = eng.refine(g, lab_dev, k=2, U=L, iters=2, seed=4)
    lab = eng.to_host(lab_dev, g.n)
    assert cut_np(g, lab) < cut_np(g, noisy) / 5
    bw = np.bincount(lab, weights=g.nw, minlength=2)
    assert bw.max() <= L * 1.05


def test_dense_refine_engine_end_to_end():
    """partition(refine_engine='dense') — the Pallas dense path wired into
    the pipeline — stays feasible and within 10% of the chunked engine."""
    g = planted_partition(4096, 8, p_in=0.02, p_out=0.0005, seed=2)
    base = PartitionerConfig(k=2, preset="fast", coarsest_factor=100, seed=0)
    dense = PartitionerConfig(
        k=2, preset="fast", coarsest_factor=100, seed=0,
        refine_engine="dense", dense_min_n=2048,
    )
    rc = partition(g, base)
    rd = partition(g, dense)
    assert rd.feasible
    assert rd.engine_stats["dense_rounds"] > 0
    assert rd.cut <= rc.cut * 1.10


def test_engine_project_matches_host_projection():
    g = mesh2d(16)
    eng = LPEngine(g, seed=0)
    C = np.random.default_rng(0).integers(0, 7, g.n).astype(np.int32)
    coarse = np.array([0, 1, 0, 1, 1, 0, 1], dtype=np.int32)
    dev = eng.project(coarse, C, fill=2)
    assert dev.shape[0] == eng.A
    np.testing.assert_array_equal(np.asarray(dev[: g.n]), coarse[C])
