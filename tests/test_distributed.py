"""Distributed SCLaP via shard_map — run in subprocesses with 8 host devices."""

import pytest

from _subproc import run_with_devices


@pytest.mark.slow
def test_distributed_cluster_and_refine():
    out = run_with_devices("""
import numpy as np
from repro.graph import rmat, mesh2d
from repro.core.distributed_lp import build_plan, lp_cluster_distributed, lp_refine_distributed
from repro.core.metrics import cut_np, imbalance_np, lmax

g = rmat(12, 8, seed=2)
L = lmax(g.n, 2, 0.03)
plan = build_plan(g, 8, chunks_per_shard=4)
clus = lp_cluster_distributed(plan, U=L/14, iters=3, seed=1)
ncl = np.unique(clus).size
assert ncl < g.n / 2, ncl            # clustering actually merges
cw = np.bincount(clus, weights=g.nw)
assert cw.max() <= 4 * (L/14)        # soft bound (PE-local weights overshoot)

gm = mesh2d(64); side = 64
truth = (np.arange(gm.n)//side >= side//2).astype(np.int32)
rng = np.random.default_rng(0); noisy = truth.copy()
noisy[rng.random(gm.n) < 0.15] ^= 1
Lm = lmax(gm.n, 2, 0.03)
planm = build_plan(gm, 8, chunks_per_shard=4, order="random")
ref = lp_refine_distributed(planm, noisy, k=2, U=Lm, iters=6, seed=0)
assert cut_np(gm, ref) < cut_np(gm, noisy) / 5
assert imbalance_np(gm, ref, 2) <= 0.031
print("DIST-OK")
""")
    assert "DIST-OK" in out


@pytest.mark.slow
def test_distributed_multilevel_end_to_end():
    out = run_with_devices("""
import numpy as np
from repro.graph import barabasi_albert
from repro.core import partition, PartitionerConfig, hash_partition
from repro.core.metrics import cut_np

g = barabasi_albert(8192, 6, seed=3)
rep = partition(g, PartitionerConfig(k=2, preset="minimal", coarsest_factor=100,
                                     seed=0, engine="dist", dist_shards=8))
assert rep.feasible
assert rep.cut < cut_np(g, hash_partition(g.n, 2))
print("DIST-ML-OK", rep.cut)
""")
    assert "DIST-ML-OK" in out


@pytest.mark.slow
def test_distributed_contraction_matches_host():
    out = run_with_devices("""
import numpy as np
from repro.graph import rmat
from repro.core.contraction import contract
from repro.core.distributed_lp import build_plan, contract_distributed
from repro.graph.csr import validate

g = rmat(11, 8, seed=7)
rng = np.random.default_rng(0)
labels = rng.integers(0, 300, g.n)
plan = build_plan(g, 8)
c_host, C1 = contract(g, labels)
c_dist, C2 = contract_distributed(plan, labels)
assert np.array_equal(C1, C2)
validate(c_dist)
assert c_dist.n == c_host.n and c_dist.m == c_host.m
np.testing.assert_allclose(np.sort(c_dist.ew), np.sort(c_host.ew), rtol=1e-5)
np.testing.assert_allclose(c_dist.nw, c_host.nw, rtol=1e-6)
print("DIST-CONTRACT-OK")
""")
    assert "DIST-CONTRACT-OK" in out
