"""The dry-run path end-to-end on a small mesh: lower + compile + analyze
(the production 512-device version of this runs via repro.launch.dryrun)."""

import pytest

from _subproc import run_with_devices


@pytest.mark.slow
def test_compile_train_step_small_mesh():
    out = run_with_devices("""
import jax
from repro.configs import ARCHS
from repro.configs.base import Shape
from repro.launch.mesh import make_mesh
from repro.launch.steps import compile_train_step, compile_decode, input_specs
from repro.launch.hlo_analysis import analyze_hlo

cfg = ARCHS["granite-moe-1b-a400m"].smoke()
mesh = make_mesh((2, 4), ("data", "model"))
shape = Shape("t", "train", 64, 8)
lowered = compile_train_step(cfg, mesh, shape)
compiled = lowered.compile()
c = analyze_hlo(compiled.as_text())
assert c.flops > 0
assert c.collective_total > 0      # MoE a2a + grad reductions on the mesh
assert compiled.memory_analysis() is not None

shape_d = Shape("d", "decode", 64, 8)
compiled2 = compile_decode(cfg, mesh, shape_d).compile()
assert compiled2.memory_analysis() is not None
print("SMALL-DRYRUN-OK")
""", n_devices=8, timeout=900)
    assert "SMALL-DRYRUN-OK" in out
