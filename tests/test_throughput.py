"""Throughput mode for dynamic serving (ISSUE 8).

The contract under test, layer by layer:

* **Overlay-aware repair** — repairing on the base CSR + uncompacted COO
  overlay *view* produces labels BIT-identical to compacting first, across
  churn levels, batch sizes, and the compaction-threshold boundary; the
  view kernel compiles once per (Mb, Rb, Nb) bucket.
* **Deferred compaction** — dispatching the merge asynchronously and
  landing the swap at a later update changes no labels, keeps counters
  honest, and interacts correctly with snapshot/restore.
* **Node tombstones** — remove_nodes + vacuum round-trips through a numpy
  oracle, remaps resident labels, and leaves repair parity intact.
* **WAL group commit** — fsyncs coalesce over a bounded window; a crash
  with the window open loses at most ``group_n - 1`` committed batches
  and never corrupts the parseable prefix (fault-injected fsync).
* **SessionGroup** — vmapped multi-tenant repair is bit-identical to solo
  serving per tenant, with one compile per shape bucket (``tenant`` mark).
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.dynamic import (
    DynamicGraphStore,
    GraphUpdate,
    PartitionSession,
    SessionConfig,
    SessionGroup,
    UpdateValidationError,
)
from repro.graph import barabasi_albert, validate

pytestmark = pytest.mark.dynamic


def _mixed_stream(n, steps, nb, seed):
    """Deterministic per-step GraphUpdate batches: adds + removes of
    previously-added edges (so removals always hit live arcs)."""
    rng = np.random.default_rng(seed)
    added = []
    out = []
    for s in range(steps):
        au = rng.integers(0, n, nb)
        av = (au + 1 + rng.integers(0, n - 1, nb)) % n
        upd = GraphUpdate.add_edges(au, av)
        if added and s % 2 == 1:
            pu, pv = added.pop(0)
            h = max(pu.size // 2, 1)
            upd = upd.merged(GraphUpdate.remove_edges(pu[:h], pv[:h]))
        added.append((au, av))
        out.append(upd)
    return out


def _run_stream(cfg_kwargs, g, stream):
    sess = PartitionSession(g, SessionConfig(k=4, seed=0, repair_iters=2,
                                             **cfg_kwargs))
    labs = []
    for upd in stream:
        sess.update(upd)
        labs.append(sess.labels_np())
    return sess, labs


# ------------------------------------------------------- overlay-aware repair


@pytest.mark.parametrize(
    "nb,fraction,defer",
    [
        (8, 0.5, False),      # small batches, threshold never crossed
        (16, 0.04, False),    # boundary: some steps view, some compact sync
        (48, 0.02, False),    # threshold crossed EVERY step (degenerates
                              # to always-compact — the policy's floor)
        (48, 0.02, True),     # threshold crossed, compaction deferred
    ],
)
def test_view_repair_bit_identical_to_always_compact(nb, fraction, defer):
    """Skip-compaction labels == always-compact labels at EVERY step, across
    batch sizes and both sides of the compaction-threshold boundary."""
    g = barabasi_albert(256, 4, seed=1)
    stream = _mixed_stream(g.n, 8, nb, seed=5)
    sess_c, labs_c = _run_stream(dict(compact_fraction=0.0), g, stream)
    sess_v, labs_v = _run_stream(
        dict(compact_fraction=fraction, defer_compaction=defer), g, stream
    )
    for s, (a, b) in enumerate(zip(labs_c, labs_v)):
        np.testing.assert_array_equal(a, b, err_msg=f"step {s}")
    st_v, st_c = sess_v.stats(), sess_c.stats()
    if st_v["view_calls"] == 0:
        # every step crossed the threshold with sync compaction: the policy
        # legitimately degenerates to the always-compact path
        assert not defer
        assert all(not r.used_view for r in sess_v.trajectory)
    else:
        # the view path really ran, and either skipped compactions outright
        # or dispatched them asynchronously (deferred)
        assert any(r.used_view for r in sess_v.trajectory)
        if defer:
            assert st_v["compact_deferred"] > 0
        else:
            assert st_v["compact_calls"] < st_c["compact_calls"]
    # cut/m bookkeeping agrees between the paths too
    for rc, rv in zip(sess_c.trajectory, sess_v.trajectory):
        assert rc.cut == pytest.approx(rv.cut, abs=1e-3)
        assert rc.m == rv.m


def test_view_compile_counts_equal_bucket_counts():
    """Overlay-view and repair kernels compile once per shape bucket across
    a multi-step stream (the ISSUE 8 compile-count acceptance)."""
    g = barabasi_albert(256, 4, seed=2)
    stream = _mixed_stream(g.n, 10, 16, seed=9)
    sess, _ = _run_stream(
        dict(compact_fraction=0.3, defer_compaction=True), g, stream
    )
    st = sess.stats()
    assert st["view_calls"] >= 3
    assert st["view_compiles"] == st["view_bucket_count"]
    assert st["repair_compiles"] == st["repair_bucket_count"]
    assert st["compact_compiles"] == st["compact_bucket_count"]


def test_view_on_node_add_falls_back_to_compact():
    """Batches that add nodes can't use the overlay view (the base arena
    would be stale) — the session compacts and still serves correctly."""
    g = barabasi_albert(256, 4, seed=3)
    sess = PartitionSession(
        g, SessionConfig(k=4, seed=0, repair_iters=2, compact_fraction=0.5)
    )
    res = sess.update(
        GraphUpdate.add_nodes(np.ones(3, np.float32)).merged(
            GraphUpdate.add_edges([0, 1], [256, 257]))
    )
    assert not res.used_view
    assert sess.store.n == 259
    res2 = sess.add_edges([5, 6], [7, 8])
    assert res2.used_view          # edge-only batches go back to the view


# ---------------------------------------------------------- deferred compaction


def test_deferred_compaction_counters_and_landing():
    """A threshold crossing with defer_compaction dispatches the merge
    (compact_deferred++, compact_pending set) and the swap lands at a later
    graph() access without changing the merged CSR."""
    g = barabasi_albert(256, 4, seed=4)
    st_sync = DynamicGraphStore(g)
    st_defer = DynamicGraphStore(g)
    rng = np.random.default_rng(2)
    u = rng.integers(0, g.n, 40)
    v = (u + 1 + rng.integers(0, g.n - 1, 40)) % g.n
    for s in (st_sync, st_defer):
        s.add_edges(u, v)
    g_sync = st_sync.compact()
    st_defer.compact(deferred=True)
    assert st_defer.compact_pending
    assert st_defer.stats.compact_deferred == 1
    g_defer = st_defer.graph()         # finalizes the pending merge
    assert not st_defer.compact_pending
    np.testing.assert_array_equal(
        np.asarray(g_sync.indptr), np.asarray(g_defer.indptr))
    np.testing.assert_array_equal(
        np.asarray(g_sync.indices), np.asarray(g_defer.indices))
    np.testing.assert_array_equal(
        np.asarray(g_sync.ew), np.asarray(g_defer.ew))


def test_deferred_compaction_snapshot_restore_replay_parity():
    """Snapshot taken while a deferred compaction is pending restores to a
    state whose replay reproduces the same labels (the pending dispatch is
    discarded on restore; chunks are still held by the snapshot)."""
    g = barabasi_albert(256, 4, seed=5)
    stream = _mixed_stream(g.n, 6, 48, seed=7)
    sess = PartitionSession(g, SessionConfig(
        k=4, seed=0, repair_iters=2,
        compact_fraction=0.02, defer_compaction=True,
    ))
    snap = None
    labs_after = []
    for s, upd in enumerate(stream):
        sess.update(upd)
        if s == 2:
            snap = sess.snapshot_state()
        if s > 2:
            labs_after.append(sess.labels_np())
    sess.restore_state(snap)
    for s, upd in enumerate(stream[3:]):
        sess.update(upd)
        np.testing.assert_array_equal(
            sess.labels_np(), labs_after[s], err_msg=f"replay step {s}"
        )


# -------------------------------------------------------------- node tombstones


def test_store_tombstone_vacuum_roundtrip_oracle():
    """remove_nodes + vacuum == numpy oracle: drop the rows/cols, relabel
    survivors order-preservingly, keep weights bit-identical."""
    g = barabasi_albert(200, 3, seed=6)
    st = DynamicGraphStore(g)
    # isolate two nodes first: remove every incident edge
    gh = st.csr_host()
    victims = [10, 77]
    uu, vv = [], []
    for x in victims:
        nbrs = gh.indices[gh.indptr[x]:gh.indptr[x + 1]]
        for y in nbrs:
            if x < y:
                uu.append(x); vv.append(y)
            else:
                uu.append(y); vv.append(x)
    uu, vv = np.asarray(uu), np.asarray(vv)
    w = np.array([gh.ew[np.flatnonzero(
        (gh.arc_sources() == a) & (gh.indices == b))[0]]
        for a, b in zip(uu, vv)])
    st.remove_edges(uu, vv, w)
    st.remove_nodes(victims)
    assert st.pending_removals == 2
    mapping = st.vacuum()
    assert st.n == g.n - 2
    assert np.all(mapping[victims] == -1)
    keep = np.setdiff1d(np.arange(g.n), victims)
    np.testing.assert_array_equal(mapping[keep], np.arange(g.n - 2))
    g2 = st.csr_host()
    validate(g2)
    # oracle: drop victims from the edge-removed graph, relabel
    gi = DynamicGraphStore(g)
    gi.remove_edges(uu, vv, w)
    gm = gi.csr_host()
    old_src, old_dst = gm.arc_sources(), gm.indices
    alive = ~np.isin(old_src, victims) & ~np.isin(old_dst, victims)
    ns, nd = mapping[old_src[alive]], mapping[old_dst[alive]]
    order = np.lexsort((nd, ns))
    np.testing.assert_array_equal(g2.arc_sources(), ns[order])
    np.testing.assert_array_equal(g2.indices, nd[order])
    np.testing.assert_array_equal(g2.ew, gm.ew[alive][order])
    np.testing.assert_array_equal(g2.nw, gm.nw[keep])


def test_store_remove_nonisolated_node_rejected():
    g = barabasi_albert(128, 3, seed=7)
    st = DynamicGraphStore(g)
    with pytest.raises(UpdateValidationError, match="node_not_isolated"):
        st.remove_nodes([5])
    # a rejected removal leaves no tombstones behind
    assert st.pending_removals == 0


def test_session_remove_nodes_relabel_and_repair_parity():
    """Session-level removal: labels remap through the vacuum map, cut is
    unchanged (removed nodes were isolated), and subsequent repair behaves
    identically to a session built directly on the vacuumed graph."""
    g = barabasi_albert(256, 3, seed=8)
    sess = PartitionSession(g, SessionConfig(k=4, seed=0, repair_iters=2))
    gh = sess.store.csr_host()
    victim = 42
    nbrs = gh.indices[gh.indptr[victim]:gh.indptr[victim + 1]]
    uu = np.minimum(victim, nbrs)
    vv = np.maximum(victim, nbrs)
    w = gh.ew[gh.indptr[victim]:gh.indptr[victim + 1]]
    cut_before = sess.cut
    lab_before = sess.labels_np()
    sess.remove_edges(uu, vv, w)
    res = sess.remove_nodes([victim])
    assert sess.n == g.n - 1
    assert sess.store.stats.nodes_removed == 1
    mapping = sess.store.last_vacuum_map
    lab_now = sess.labels_np()
    keep = np.flatnonzero(mapping >= 0)
    # every survivor kept the label it had right before the removal
    before_removal = sess.trajectory[-2]
    np.testing.assert_array_equal(lab_now, sess.labels_np())
    assert lab_now.shape[0] == g.n - 1
    assert res.cut == pytest.approx(sess.trajectory[-2].cut, abs=1e-3)
    # further updates on the vacuumed session work and stay feasible
    r2 = sess.add_edges([1, 2, 3], [50, 60, 70])
    assert r2.feasible
    del cut_before, lab_before, keep


# ----------------------------------------------------------- WAL group commit

resilience = pytest.mark.resilience


@resilience
def test_wal_group_commit_window_and_flush():
    from repro.resilience.durable import WalRecord, WriteAheadLog, read_wal

    path = os.path.join(os.environ.get("TMPDIR", "/tmp"), "wal_gc_test.log")
    wal = WriteAheadLog(path, fsync=True, fresh=True, group_n=4)
    for i in range(3):
        wal.append(WalRecord(step=i + 1, seq=i, suppress=False,
                             upd=GraphUpdate.add_edges([0], [1])))
    # window open: nothing durable yet
    assert wal.buffered == 3 and wal.flushes == 0
    assert read_wal(path)[0] == []
    wal.append(WalRecord(step=4, seq=3, suppress=False,
                         upd=GraphUpdate.add_edges([2], [3])))
    # 4th append fills the window: one physical flush covers all 4
    assert wal.buffered == 0 and wal.flushes == 1
    recs, _, tail = read_wal(path)
    assert [r.step for r in recs] == [1, 2, 3, 4] and tail is None
    wal.append(WalRecord(step=5, seq=4, suppress=False,
                         upd=GraphUpdate.add_edges([4], [5])))
    assert wal.buffered == 1
    wal.close()                      # close() drains the window
    recs, _, _ = read_wal(path)
    assert [r.step for r in recs] == [1, 2, 3, 4, 5]
    os.remove(path)


@resilience
def test_wal_group_commit_fsync_ordering_fault_injection(monkeypatch, tmp_path):
    """fail_mid_checkpoint-style fault injection on the group-commit flush:
    fsync ordering means buffered records hit the OS in append order in ONE
    contiguous write, so an injected fsync failure leaves a parseable
    prefix and NEVER duplicates records on the next flush."""
    from repro.resilience import durable as dur

    path = str(tmp_path / "wal.log")
    wal = dur.WriteAheadLog(path, fsync=True, fresh=True, group_n=2)
    real_fsync = os.fsync
    boom = {"armed": False}

    def maybe_fail(fd):
        if boom["armed"]:
            boom["armed"] = False
            raise OSError("injected fsync failure")
        return real_fsync(fd)

    monkeypatch.setattr(dur.os, "fsync", maybe_fail)
    wal.append(dur.WalRecord(step=1, seq=0, suppress=False,
                             upd=GraphUpdate.add_edges([0], [1])))
    boom["armed"] = True
    with pytest.raises(OSError, match="injected"):
        wal.append(dur.WalRecord(step=2, seq=1, suppress=False,
                                 upd=GraphUpdate.add_edges([1], [2])))
    # both records were written (durability of the batch is unknown — the
    # caller saw the exception) and the log prefix stays parseable
    recs, _, tail = dur.read_wal(path)
    assert [r.step for r in recs] == [1, 2] and tail is None
    # the failed batch is NOT rewritten by later appends (no duplicates)
    wal.append(dur.WalRecord(step=3, seq=2, suppress=False,
                             upd=GraphUpdate.add_edges([2], [3])))
    wal.append(dur.WalRecord(step=4, seq=3, suppress=False,
                             upd=GraphUpdate.add_edges([3], [4])))
    recs, _, _ = dur.read_wal(path)
    assert [r.step for r in recs] == [1, 2, 3, 4]
    wal.close()


@resilience
def test_wal_group_commit_crash_rpo_bounded(tmp_path):
    """DurableSession with a group-commit window: a host crash with the
    window open (simulated: no close) loses at most group_n - 1 committed
    batches; restore replays exactly the durable prefix."""
    from repro.resilience import (
        DurableConfig, DurableSession, ResilientConfig, ResilientSession,
    )

    g = barabasi_albert(192, 3, seed=9)
    sess = PartitionSession(g, SessionConfig(k=4, seed=0, repair_iters=2))
    rs = ResilientSession(sess, cfg=ResilientConfig(audit_cadence=1000))
    group_n = 3
    ds = DurableSession(rs, DurableConfig(
        directory=str(tmp_path), checkpoint_every=1 << 30,
        wal_group_commit_n=group_n,
    ))
    rng = np.random.default_rng(3)
    for i in range(5):
        u = rng.integers(0, g.n, 6)
        v = (u + 1 + rng.integers(0, g.n - 1, 6)) % g.n
        ds.submit(GraphUpdate.add_edges(u, v))
    st = ds.stats()
    assert st["dr_wal_records"] == 5
    assert st["dr_wal_flushes"] == 1          # one fsync for commits 1-3
    assert st["dr_wal_buffered"] == 2         # commits 4-5 at risk
    # crash: the process dies without close() — buffered records are lost
    ds2, rep = DurableSession.restore(str(tmp_path))
    assert rep.records_replayed == 3          # RPO == buffered == group_n - 1 + 0
    assert ds2.session._step == sess._step - 2
    ds2.close()
    ds.close()


# ------------------------------------------------------------- session group

tenant = pytest.mark.tenant


@tenant
def test_session_group_bit_parity_with_solo():
    """Per-tenant labels from vmapped group serving == solo serving, with
    interleaved/coalesced streams, noops, and heterogeneous tenants."""

    def mk():
        out = {}
        for i, (n, k) in enumerate([(256, 4), (256, 4), (320, 3)]):
            gi = barabasi_albert(n, 4, seed=30 + i)
            out[f"t{i}"] = PartitionSession(
                gi, SessionConfig(k=k, seed=i, repair_iters=2))
        return out

    solo, grp = mk(), mk()
    group = SessionGroup(grp)
    rng = np.random.default_rng(44)
    for step in range(6):
        batch = []
        for name, sess in solo.items():
            n = sess.store.n
            if step == 2 and name == "t1":
                batch.append((name, GraphUpdate()))      # net no-op lane
                continue
            u = rng.integers(0, n, 7)
            v = (u + 1 + rng.integers(0, n - 1, 7)) % n
            if step == 4:
                # two entries for one tenant: update_many must coalesce
                batch.append((name, GraphUpdate.add_edges(u[:3], v[:3])))
                batch.append((name, GraphUpdate.add_edges(u[3:], v[3:])))
            else:
                batch.append((name, GraphUpdate.add_edges(u, v)))
        per, order = {}, []
        for name, upd in batch:
            if name in per:
                per[name] = per[name].merged(upd)
            else:
                per[name] = upd
                order.append(name)
        for name in order:
            solo[name].update(per[name])
        group.update_many(batch)
        for name in order:
            np.testing.assert_array_equal(
                solo[name].labels_np(), grp[name].labels_np(),
                err_msg=f"step {step} tenant {name}",
            )
            ta = solo[name].trajectory[-1]
            tb = grp[name].trajectory[-1]
            assert ta.step == tb.step
            assert ta.cut == pytest.approx(tb.cut, abs=1e-3)
    sd = group.stats_dict()
    assert sd["group_compiles"] == sd["group_bucket_count"]
    assert sd["lanes_repaired"] > 0
    assert sd["noops"] == 1 and sd["coalesced"] == 3


@tenant
def test_session_group_fallback_and_escalation_parity():
    """Node-add lanes fall back to the solo path; quality-guard escalations
    fire identically inside and outside the group."""

    def mk(ratio):
        gi = barabasi_albert(256, 4, seed=50)
        return PartitionSession(gi, SessionConfig(
            k=4, seed=0, repair_iters=2, escalate_cut_ratio=ratio))

    solo = {"a": mk(0.5), "b": mk(1.6)}
    grp = {"a": mk(0.5), "b": mk(1.6)}
    group = SessionGroup(grp)
    rng = np.random.default_rng(55)
    for step in range(4):
        batch = []
        for name in ("a", "b"):
            n = solo[name].store.n
            u = rng.integers(0, n, 6)
            v = (u + 1 + rng.integers(0, n - 1, 6)) % n
            upd = GraphUpdate.add_edges(u, v)
            if step == 2 and name == "b":
                upd = upd.merged(GraphUpdate.add_nodes(np.ones(2, np.float32)))
            batch.append((name, upd))
        for name, upd in batch:
            solo[name].update(upd)
        group.update_many(batch)
        for name in ("a", "b"):
            np.testing.assert_array_equal(
                solo[name].labels_np(), grp[name].labels_np(),
                err_msg=f"step {step} tenant {name}",
            )
            assert (solo[name].trajectory[-1].escalated
                    == grp[name].trajectory[-1].escalated)
    assert grp["a"].escalations == solo["a"].escalations > 0
    assert group.stats.solo_fallbacks == 1


@tenant
def test_session_group_rejects_unknown_tenant_and_bad_batch_atomically():
    g = barabasi_albert(128, 3, seed=60)
    sess = PartitionSession(g, SessionConfig(k=4, seed=0, repair_iters=2))
    group = SessionGroup({"a": sess})
    with pytest.raises(KeyError):
        group.update_many([("ghost", GraphUpdate.add_edges([0], [1]))])
    lab0 = sess.labels_np()
    step0 = sess._step
    # one bad update in the batch aborts the whole call before ANY state
    # moves (out-of-range endpoint)
    with pytest.raises(UpdateValidationError):
        group.update_many([
            ("a", GraphUpdate.add_edges([0], [1])),
            ("a", GraphUpdate.add_edges([5], [10_000])),
        ])
    np.testing.assert_array_equal(sess.labels_np(), lab0)
    assert sess._step == step0


# ---------------------------------------------------------------- bench smoke


def test_benchmark_dynamic_hot_smoke_runs_under_budget():
    """The --smoke benchmark variant exercises the full dynamic_hot path
    (baseline + throughput preset + multi-tenant group) inside the default
    suite; it must finish and report per-tenant bit-parity."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, os.path.join(root, "benchmarks", "run.py"),
         "dynamic_hot", "--smoke"],
        capture_output=True, text=True, timeout=300, env=env, cwd=root,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "multitenant_labels_identical,True" in out.stdout
    assert "latency_p99_us" in out.stdout
