"""Checkpointing: atomicity, recovery, async writer, elastic resharding."""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer, latest_step, load, restore, save


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)) * scale,
            "b": {"c": jax.random.normal(k2, (32,)) * scale,
                  "d": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_bitwise(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 7, t, {"step": 7})
    out, extra = restore(str(tmp_path), 7, t)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_torn_writes(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 3, t)
    save(str(tmp_path), 9, t)
    # simulate a crash mid-write: a .tmp dir and a dir with incomplete manifest
    os.makedirs(tmp_path / "step_00000011.tmp")
    os.makedirs(tmp_path / "step_00000012")
    with open(tmp_path / "step_00000012" / "manifest.json", "w") as f:
        json.dump({"step": 12, "complete": False}, f)
    assert latest_step(str(tmp_path)) == 9


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3):
        ck.submit(s, t, {"step": s})
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    # GC kept only the last two
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2


def test_load_fresh_process_roundtrip(tmp_path):
    """load() needs no ``like`` template — the disaster-restore path on a
    process that has nothing but the directory."""
    t = _tree(jax.random.PRNGKey(2))
    save(str(tmp_path), 5, t, {"tag": "dr"})
    leaves, manifest = load(str(tmp_path), 5)
    ref = [np.asarray(x) for x in jax.tree.leaves(t)]
    assert manifest["extra"]["tag"] == "dr"
    assert manifest["complete"] and manifest["n_leaves"] == len(ref)
    for a, b in zip(leaves, ref):
        np.testing.assert_array_equal(a, b)


def test_load_rejects_incomplete_and_mismatched(tmp_path):
    t = _tree(jax.random.PRNGKey(3))
    save(str(tmp_path), 1, t)
    mf = tmp_path / "step_00000001" / "manifest.json"
    m = json.loads(mf.read_text())
    m["complete"] = False
    mf.write_text(json.dumps(m))
    with pytest.raises(ValueError, match="incomplete"):
        load(str(tmp_path), 1)
    m["complete"] = True
    m["shapes"][0] = [1, 1]             # manifest disagrees with arrays
    mf.write_text(json.dumps(m))
    with pytest.raises(ValueError, match="mismatch"):
        load(str(tmp_path), 1)


def _flaky_save_once(monkeypatch, exc):
    """Patch the module-level ``save`` the async worker resolves at call
    time: first call raises, later calls hit the real writer."""
    import repro.ckpt.checkpoint as ckpt_mod

    real, calls = ckpt_mod.save, {"n": 0}

    def flaky(path, step, tree, extra=None):
        calls["n"] += 1
        if calls["n"] == 1:
            raise exc
        return real(path, step, tree, extra)

    monkeypatch.setattr(ckpt_mod, "save", flaky)


def test_async_checkpointer_surfaces_error_on_wait(tmp_path, monkeypatch):
    """A failed background write is never silent: wait() re-raises it,
    counts it, clears it — the checkpointer stays usable after."""
    _flaky_save_once(monkeypatch, OSError("disk full (injected)"))
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree(jax.random.PRNGKey(4))
    ck.submit(1, t)
    with pytest.raises(OSError, match="disk full"):
        ck.wait()
    assert ck.failed_writes == 1
    ck.submit(2, t)                     # error cleared: still usable
    ck.wait()
    assert ck.failed_writes == 1
    assert latest_step(str(tmp_path)) == 2


def test_async_checkpointer_surfaces_error_on_next_submit(tmp_path,
                                                          monkeypatch):
    _flaky_save_once(monkeypatch, OSError("injected"))
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree(jax.random.PRNGKey(5))
    ck.submit(1, t)
    with pytest.raises(OSError, match="injected"):
        ck.submit(2, t)                 # surfaced at the enqueue
    assert ck.failed_writes == 1
    ck.submit(3, t)
    ck.wait()
    assert latest_step(str(tmp_path)) == 3


def test_elastic_reshard_across_mesh_shapes(tmp_path):
    from _subproc import run_with_devices

    run_with_devices(f"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.ckpt import save
from repro.ckpt.elastic import reshard_restore, shardings_for
from repro.launch.mesh import make_mesh

t = {{"w": jnp.arange(64.0).reshape(8, 8)}}
specs = {{"w": P("data", "model")}}
mesh1 = make_mesh((2, 4), ("data", "model"))
sh1 = shardings_for(t, specs, mesh1)
t1 = jax.tree.map(lambda x, s: jax.device_put(x, s), t, sh1)
save("{tmp_path}", 0, t1, {{"step": 0}})
# restore onto a DIFFERENT mesh shape (elastic rescale 8 -> 8 reshaped)
mesh2 = make_mesh((4, 2), ("data", "model"))
out, _ = reshard_restore("{tmp_path}", 0, t, specs, mesh2)
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))
print("ELASTIC-OK")
""")
