"""Checkpointing: atomicity, recovery, async writer, elastic resharding."""

import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.ckpt import AsyncCheckpointer, latest_step, restore, save


def _tree(key, scale=1.0):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (8, 16)) * scale,
            "b": {"c": jax.random.normal(k2, (32,)) * scale,
                  "d": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_bitwise(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 7, t, {"step": 7})
    out, extra = restore(str(tmp_path), 7, t)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_torn_writes(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 3, t)
    save(str(tmp_path), 9, t)
    # simulate a crash mid-write: a .tmp dir and a dir with incomplete manifest
    os.makedirs(tmp_path / "step_00000011.tmp")
    os.makedirs(tmp_path / "step_00000012")
    with open(tmp_path / "step_00000012" / "manifest.json", "w") as f:
        json.dump({"step": 12, "complete": False}, f)
    assert latest_step(str(tmp_path)) == 9


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3):
        ck.submit(s, t, {"step": s})
    ck.wait()
    assert latest_step(str(tmp_path)) == 3
    # GC kept only the last two
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(dirs) == 2


def test_elastic_reshard_across_mesh_shapes(tmp_path):
    from _subproc import run_with_devices

    run_with_devices(f"""
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.ckpt import save
from repro.ckpt.elastic import reshard_restore, shardings_for
from repro.launch.mesh import make_mesh

t = {{"w": jnp.arange(64.0).reshape(8, 8)}}
specs = {{"w": P("data", "model")}}
mesh1 = make_mesh((2, 4), ("data", "model"))
sh1 = shardings_for(t, specs, mesh1)
t1 = jax.tree.map(lambda x, s: jax.device_put(x, s), t, sh1)
save("{tmp_path}", 0, t1, {{"step": 0}})
# restore onto a DIFFERENT mesh shape (elastic rescale 8 -> 8 reshaped)
mesh2 = make_mesh((4, 2), ("data", "model"))
out, _ = reshard_restore("{tmp_path}", 0, t, specs, mesh2)
np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(t["w"]))
print("ELASTIC-OK")
""")
