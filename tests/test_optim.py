"""Optimizer, schedule, gradient compression."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import (
    adamw_init, adamw_update, compress_decompress, ef_init, warmup_cosine,
)


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.ones((4,)) * 5.0}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=0.1, weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_grad_clip():
    params = {"w": jnp.zeros((4,))}
    opt = adamw_init(params)
    g = {"w": jnp.ones((4,)) * 1e6}
    _, _, gnorm = adamw_update(g, opt, params, lr=0.0, clip_norm=1.0)
    assert float(gnorm) > 1e5  # reported norm is pre-clip


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, peak=1.0, warmup=10, total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert lrs[99] < lrs[50] < lrs[12]


def test_compression_error_feedback_unbiased():
    """With error feedback, the cumulative compressed sum tracks the true
    cumulative sum (residual stays bounded)."""
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.standard_normal(256), jnp.float32)}
    res = ef_init(g_true)
    total_c = np.zeros(256)
    for i in range(50):
        g = {"w": g_true["w"] * (1 + 0.01 * i)}
        deq, res = compress_decompress(g, res)
        total_c += np.asarray(deq["w"])
    # residual bounded by one quantization step's worth of mass
    assert float(jnp.abs(res["w"]).max()) < 0.2
