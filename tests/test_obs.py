"""Observability subsystem (ISSUE 9): metrics registry, tracer spans,
compile watchdog, SLO export, and the no-behavior-change guarantees.

The contract under test: tracing on/off and strict-watchdog mode leave
serving labels BIT-identical across the solo, view (throughput preset),
group, and resilient paths — observability observes, it never steers.
The watchdog's sealed mode catches an intentionally unregistered
recompile; the AST static check proves every ``jax.jit`` / ``pallas_call``
callsite under ``src/repro`` is registered in the manifest; the registry
round-trips the legacy stats attribute surface; and the exporters emit
Perfetto-loadable Chrome traces and Prometheus 0.0.4 text.
"""

import json
import os

import numpy as np
import pytest

from repro.dynamic import (
    GraphUpdate,
    PartitionSession,
    SessionConfig,
    SessionGroup,
)
from repro.graph import barabasi_albert
from repro.obs import (
    CompileWatchdog,
    MetricsRegistry,
    RegistryBackedStats,
    Tracer,
    WatchdogError,
    get_tracer,
    set_tracer,
    slo_snapshot,
    span,
    to_prometheus,
    watchdog,
    write_slo,
)
from repro.obs.static_check import check_registration, find_jit_sites
from repro.obs.watchdog import KNOWN_JIT_SITES

pytestmark = pytest.mark.obs


# ------------------------------------------------------------------ registry


def test_registry_counter_lifecycle():
    reg = MetricsRegistry("t")
    reg.counter("a")
    reg.counter("a", 99)            # idempotent declare: never clobbers
    assert reg.get("a") == 0
    reg.inc("a")
    reg.inc("a", 3)
    assert reg.get("a") == 4
    reg.set_counter("a", 7)
    assert reg.get("a") == 7
    with pytest.raises(KeyError):
        reg.get("undeclared")
    reg.gauge("g", 2.5)
    assert reg.get_gauge("g") == 2.5
    reg.series_inc("span_ms", {"phase": "repair"}, 3)
    reg.reset()
    assert reg.get("a") == 0        # counters survive reset as zeros
    assert reg.get_gauge("g", -1.0) == -1.0
    snap = reg.snapshot()
    assert snap["scope"] == "t"
    assert snap["counters"] == {"a": 0}
    assert snap["series"] == []


def test_registry_histogram_log2_buckets_and_quantiles():
    reg = MetricsRegistry()
    for v in [0.001] * 98 + [0.5, 2.0]:
        reg.observe("lat", v)
    h = reg.histogram("lat")
    assert h.count == 100
    # log2 buckets are upper bounds: p50 lands in 0.001's bucket, the
    # 2.0 outlier defines p99's upper bound
    assert 0.001 <= h.quantile(0.50) <= 0.002048
    assert h.quantile(0.99) >= 0.5
    snap = h.snapshot()
    assert snap["count"] == 100
    assert snap["min"] == 0.001 and snap["max"] == 2.0
    assert abs(snap["sum"] - (0.098 + 2.5)) < 1e-9


def test_registry_prometheus_exposition():
    reg = MetricsRegistry()
    reg.counter("sweep_compiles", 3)
    reg.gauge("view_hit_ratio", 0.75)
    reg.observe("update_seconds", 0.010)
    reg.observe("update_seconds", 0.020)
    reg.series_inc("span_ms", {"phase": "repair"}, 12)
    text = reg.to_prometheus(prefix="repro_")
    assert "# TYPE repro_sweep_compiles counter" in text
    assert "repro_sweep_compiles 3" in text
    assert "# TYPE repro_view_hit_ratio gauge" in text
    assert "# TYPE repro_update_seconds histogram" in text
    assert 'repro_update_seconds_bucket{le="+Inf"} 2' in text
    assert "repro_update_seconds_count 2" in text
    assert 'repro_span_ms{phase="repair"} 12' in text


def test_registry_backed_stats_attribute_surface():
    class _St(RegistryBackedStats):
        _COUNTER_FIELDS = ("calls", "compiles")
        _SET_FIELDS = ("buckets",)

    st = _St()
    st.calls += 1
    st.calls += 1
    st.compiles = 5
    st.buckets.add(("k", 4))
    assert st.calls == 2 and st.compiles == 5
    assert st.registry.get("calls") == 2      # round-trips the registry
    (key,) = st.buckets                        # sets stay real sets
    assert key == ("k", 4)
    assert st.snapshot() == {"calls": 2, "compiles": 5, "buckets_count": 1}
    st.reset()
    assert st.calls == 0 and not st.buckets
    with pytest.raises(AttributeError):
        st.nope


def test_registry_backed_stats_shared_registry():
    reg = MetricsRegistry("stack")

    class _A(RegistryBackedStats):
        _COUNTER_FIELDS = ("x",)

    class _B(RegistryBackedStats):
        _COUNTER_FIELDS = ("y",)

    a, b = _A(reg), _B(reg)
    a.x += 1
    b.y += 2
    assert reg.snapshot()["counters"] == {"x": 1, "y": 2}


# -------------------------------------------------------------------- tracer


def test_span_disabled_is_shared_noop_and_records_nothing():
    prev = set_tracer(None)
    try:
        s1 = span("a.b", cat="a", n=1)
        s2 = span("c.d")
        assert s1 is s2                 # the cached singleton: no allocation
        with s1 as sp:
            sp.sync_on(np.zeros(2))     # all no-ops
            sp.set(x=1)
    finally:
        set_tracer(prev)


def test_tracer_records_nested_spans_and_exports_chrome(tmp_path):
    tracer = Tracer()
    prev = set_tracer(tracer)
    try:
        with span("outer.op", cat="outer", n=3):
            with span("inner.op") as sp:
                sp.set(hit=True)
    finally:
        set_tracer(prev)
    assert [e["name"] for e in tracer.events] == ["inner.op", "outer.op"]
    outer = tracer.events[1]
    assert outer["ph"] == "X" and outer["cat"] == "outer"
    assert outer["dur"] >= tracer.events[0]["dur"]
    assert outer["args"] == {"n": 3}
    assert tracer.events[0]["args"] == {"hit": True}
    path = tracer.export_chrome(str(tmp_path / "t.trace.json"))
    doc = json.load(open(path))
    assert isinstance(doc["traceEvents"], list) and len(doc["traceEvents"]) == 2
    for ev in doc["traceEvents"]:       # the Perfetto-required fields
        assert {"name", "ph", "ts", "dur", "pid", "tid"} <= set(ev)


def test_tracer_disabled_instance_returns_noop():
    tracer = Tracer(enabled=False)
    prev = set_tracer(tracer)
    try:
        with span("x.y"):
            pass
    finally:
        set_tracer(prev)
    assert tracer.events == []


# ------------------------------------------------------------------ watchdog


def test_watchdog_counts_and_snapshot():
    wd = CompileWatchdog()
    assert wd.note("engine.sweep", ("b", 1)) is True
    assert wd.note("engine.sweep", ("b", 1)) is False   # warm: not a compile
    assert wd.note("engine.sweep", ("b", 2)) is True
    assert wd.compile_count("engine.sweep") == 2
    assert wd.bucket_count("engine.sweep") == 2
    snap = wd.snapshot()
    assert snap["kernels"]["engine.sweep"]["compiles"] == 2
    wd.reset()
    assert wd.compile_count() == 0 and wd.bucket_count() == 0


def test_watchdog_strict_rejects_undeclared_family():
    wd = CompileWatchdog(strict=True)
    wd.note("engine.sweep", ("ok",))            # declared: fine
    with pytest.raises(WatchdogError, match="undeclared kernel family"):
        wd.note("rogue.kernel", ("k",))
    wd.set_strict(False)
    wd.note("rogue.kernel", ("k",))             # lenient: auto-declares


def test_watchdog_seal_catches_unregistered_recompile_unit():
    wd = CompileWatchdog()
    wd.note("engine.repair", ("warm",))
    wd.seal()
    wd.note("engine.repair", ("warm",))         # known bucket: still fine
    with pytest.raises(WatchdogError, match="sealed bucket set"):
        wd.note("engine.repair", ("cold",))
    wd.unseal()
    wd.note("engine.repair", ("cold",))


def test_watchdog_seal_catches_session_recompile():
    """The regression the seal exists for: a serving loop whose next batch
    would trace a NEW shape bucket (here: the very first update of a
    fresh session, whose repair/compact kernels were never compiled at
    this graph size) raises instead of silently recompiling."""
    # unusual n so no earlier test in this process warmed these buckets
    g = barabasi_albert(619, 4, seed=5)
    sess = PartitionSession(g, SessionConfig(k=3, seed=0, repair_iters=1))
    wd = watchdog()
    rng = np.random.default_rng(0)
    u = rng.integers(0, g.n, 37)
    v = (u + 1 + rng.integers(0, g.n - 1, 37)) % g.n
    wd.seal()
    try:
        with pytest.raises(WatchdogError, match="sealed bucket set"):
            sess.update(GraphUpdate.add_edges(u, v))
    finally:
        wd.unseal()
    # with the seal lifted the same update proceeds and registers buckets
    res = sess.update(GraphUpdate.add_edges(u, v))
    assert not res.noop


# ----------------------------------------------------------- bit-parity


def _stream(n, nb, batches, seed):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(batches):
        u = rng.integers(0, n, nb)
        v = (u + 1 + rng.integers(0, n - 1, nb)) % n
        out.append(GraphUpdate.add_edges(u, v))
    return out


def _with_obs(enabled, fn):
    """Run fn() with tracing+strict-watchdog on (enabled=True) or fully
    off (enabled=False); restores global state either way."""
    wd = watchdog()
    prev_strict = wd.strict
    prev = set_tracer(Tracer(enabled=True) if enabled else None)
    wd.set_strict(enabled)
    try:
        return fn()
    finally:
        set_tracer(prev)
        wd.set_strict(prev_strict)


@pytest.mark.parametrize("preset", ["solo", "view"])
def test_tracing_and_strict_mode_label_parity_session(preset):
    """Tracing on (with forced device syncs at span close) + strict
    watchdog vs everything off: the served labels must be bit-identical.
    Covers the default path (compact every step) and the throughput
    preset (overlay view + deferred compaction)."""
    g = barabasi_albert(512, 4, seed=7)

    def run():
        cfg = (SessionConfig(k=4, seed=0, repair_iters=2) if preset == "solo"
               else SessionConfig.throughput(k=4, seed=0))
        sess = PartitionSession(g, cfg)
        for upd in _stream(g.n, 24, 3, seed=13):
            sess.update(upd)
        return sess.labels_np()

    base = _with_obs(False, run)
    traced = _with_obs(True, run)
    np.testing.assert_array_equal(base, traced)


def test_tracing_and_strict_mode_label_parity_group():
    gs = {f"t{i}": barabasi_albert(384, 4, seed=30 + i) for i in range(2)}

    def run():
        tenants = {
            nm: PartitionSession(
                gi, SessionConfig(k=3, seed=i, repair_iters=1))
            for i, (nm, gi) in enumerate(gs.items())
        }
        group = SessionGroup(tenants)
        for s in range(3):
            batch = []
            for nm in gs:
                rng = np.random.default_rng(100 + s)
                u = rng.integers(0, 384, 16)
                v = (u + 1 + rng.integers(0, 383, 16)) % 384
                batch.append((nm, GraphUpdate.add_edges(u, v)))
            group.update_many(batch)
        return {nm: tenants[nm].labels_np() for nm in gs}

    base = _with_obs(False, run)
    traced = _with_obs(True, run)
    for nm in base:
        np.testing.assert_array_equal(base[nm], traced[nm])


def test_vcycle_spans_cover_all_phases():
    """A partition run that actually coarsens (coarsest_factor below n/k)
    emits spans for every V-cycle phase — pack, sweep, contract, project —
    and tracing + strict watchdog leave the result bit-identical."""
    from repro.core import PartitionerConfig, partition

    g = barabasi_albert(4096, 4, seed=5)
    cfg = dict(k=2, seed=0, coarsest_factor=256)

    base = _with_obs(False, lambda: partition(g, PartitionerConfig(**cfg)))

    def run():
        rep = partition(g, PartitionerConfig(**cfg))
        names = {e["name"] for e in get_tracer().events}
        return rep, names

    rep, names = _with_obs(True, run)
    assert {"vcycle.pack", "vcycle.sweep", "vcycle.contract",
            "vcycle.project"} <= names
    np.testing.assert_array_equal(base.labels, rep.labels)


def test_tracing_and_strict_mode_label_parity_resilient():
    from repro.resilience import ResilientConfig, ResilientSession

    g = barabasi_albert(512, 4, seed=9)

    def run():
        sess = PartitionSession(
            g, SessionConfig(k=4, seed=0, repair_iters=1))
        rs = ResilientSession(sess, cfg=ResilientConfig(audit_cadence=2))
        for upd in _stream(g.n, 24, 4, seed=17):
            rs.submit(upd)
        return sess.labels_np()

    base = _with_obs(False, run)
    traced = _with_obs(True, run)
    np.testing.assert_array_equal(base, traced)


# --------------------------------------------------- result timing satellite


def test_update_result_monotonic_timestamp_and_span_breakdown():
    g = barabasi_albert(512, 4, seed=7)
    sess = PartitionSession(g, SessionConfig(k=4, seed=0, repair_iters=1))
    results = [sess.update(upd) for upd in _stream(g.n, 24, 2, seed=13)]
    t_prev = 0.0
    for res in results:
        assert res.t_mono > t_prev       # monotonic across the stream
        t_prev = res.t_mono
        assert res.span_ms               # the always-on phase breakdown
        for phase in ("validate", "store", "compact", "rebuild",
                      "repair", "score"):
            assert phase in res.span_ms
            assert res.span_ms[phase] >= 0.0
        # phases account for (almost all of) the reported latency
        assert sum(res.span_ms.values()) <= res.seconds * 1e3 + 5.0


def test_session_stats_expose_updates_and_view_hits():
    g = barabasi_albert(512, 4, seed=7)
    sess = PartitionSession(g, SessionConfig.throughput(k=4, seed=0))
    for upd in _stream(g.n, 16, 3, seed=19):
        sess.update(upd)
    st = sess.stats()
    assert st["updates_applied"] == 3
    assert 0 <= st["view_hits"] <= 3
    assert sess.metrics.histogram("update_seconds").count == 3


# ---------------------------------------------------------------- SLO export


def test_slo_snapshot_and_prometheus_and_write(tmp_path):
    g = barabasi_albert(512, 4, seed=7)
    sess = PartitionSession(g, SessionConfig(k=4, seed=0, repair_iters=1))
    for upd in _stream(g.n, 16, 2, seed=23):
        sess.update(upd)
    st = sess.stats()
    snap = slo_snapshot(st, [sess.metrics])
    assert snap["slo"]["view_hit_ratio"] == st["view_hits"] / 2
    assert snap["compile_watchdog"]["total_compiles"] >= 0
    assert snap["registries"][0]["scope"] == "session"
    text = to_prometheus(st, [sess.metrics])
    assert "repro_updates_applied 2" in text
    assert "# TYPE repro_update_seconds histogram" in text
    assert "repro_compiles_total" in text
    paths = write_slo(str(tmp_path / "slo"), st, [sess.metrics])
    doc = json.load(open(paths["json"]))
    assert doc["stats"]["updates_applied"] == 2
    prom = open(paths["prom"]).read()
    assert prom.endswith("\n") and "repro_updates_applied" in prom


# -------------------------------------------------------------- static check


def test_every_jit_callsite_is_registered():
    """The tier-1 gate: an unregistered ``jax.jit`` / ``pallas_call``
    callsite under src/repro fails here with its manifest key."""
    root = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src", "repro",
    )
    assert check_registration(root) == []


def test_manifest_has_no_stale_entries():
    """Deleted/renamed callsites must leave the manifest too, or the
    registration list rots into documentation."""
    root = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src", "repro",
    )
    live = set(find_jit_sites(root))
    stale = sorted(set(KNOWN_JIT_SITES) - live)
    assert stale == []


# ------------------------------------------------- alloc-site check (PR 10)


def _src_root():
    return os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "src", "repro",
    )


def test_every_alloc_site_is_registered():
    """Memory-accounting gate: every eager device-allocation site in the
    accounted modules must map to a buffer family (or carry an ``exempt:``
    reason) in ``KNOWN_ALLOC_SITES`` — a new persistent buffer cannot land
    unaccounted."""
    from repro.obs.static_check import check_alloc_registration

    assert check_alloc_registration(_src_root()) == []


def test_alloc_manifest_has_no_stale_entries():
    from repro.obs.memory import KNOWN_ALLOC_SITES, MEMORY_FAMILIES
    from repro.obs.static_check import find_alloc_sites

    live = set(find_alloc_sites(_src_root()))
    stale = sorted(set(KNOWN_ALLOC_SITES) - live)
    assert stale == []
    # every manifest value is a real family or an explained exemption
    for site, fam in KNOWN_ALLOC_SITES.items():
        assert fam in MEMORY_FAMILIES or fam.startswith("exempt:"), (
            f"{site}: {fam!r}"
        )
