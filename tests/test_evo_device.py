"""Batched device evolution vs the sequential numpy oracle.

The contract under test (ISSUE 3): the device-batched island GA — vmapped
population over the engine's cached chunk pack, overlay-cell combine,
device-side elitism/selection/gossip — produces labels BIT-IDENTICAL to the
one-individual-at-a-time numpy oracle under the same seeds, preserves the
paper's offspring-never-worse-than-better-parent invariant, compiles once
per shape bucket, and consumes a still-resident GraphDev coarsest graph
without materializing it to host.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import LPEngine, PartitionerConfig, initial_partition, partition
from repro.core.evolutionary import EvoConfig, evolve_batched_numpy
from repro.core.metrics import cut_np, lmax
from _subproc import run_with_devices
from repro.graph import GraphDev, barabasi_albert, mesh2d, planted_partition


def _cfg(k, L, I, P, G, seed, seeds=()):
    return EvoConfig(k=k, Lmax=L, islands=I, pop_per_island=P, generations=G,
                     refine_iters=3, seed=seed,
                     seed_individuals=list(seeds))


@pytest.mark.parametrize(
    "case",
    [
        # (graph builder, k, islands, pop, generations)
        (lambda: planted_partition(700, 6, p_in=0.05, p_out=0.004, seed=1),
         2, 2, 2, 3),
        (lambda: barabasi_albert(500, 4, seed=2), 4, 4, 3, 2),
        (lambda: planted_partition(300, 4, p_in=0.06, p_out=0.01, seed=3),
         3, 1, 2, 2),
        (lambda: barabasi_albert(64, 3, seed=4), 2, 2, 1, 3),  # mutate-only
    ],
)
def test_device_matches_oracle_bit_for_bit(case):
    gbuild, k, I, P, G = case
    g = gbuild()
    L = lmax(g.n, k, 0.03)
    eng = LPEngine(g, seed=0)
    assert eng.can_evolve_device(g, k, I, P)
    cfg = _cfg(k, L, I, P, G, seed=11 + k)
    lab_dev = np.asarray(eng.evolve_device(g, cfg))
    lab_ora = eng.evolve_oracle(g, cfg)
    np.testing.assert_array_equal(lab_dev, lab_ora)


def test_seeded_device_evo_parity_and_never_worse_than_seed():
    """The V-cycle guarantee on the device path: the projected previous
    solution joins every island unrefined; elitism + gossip can only match
    or improve it — and the whole run still mirrors the oracle exactly."""
    g = planted_partition(800, 6, p_in=0.05, p_out=0.003, seed=5)
    L = lmax(g.n, 2, 0.03)
    seed_lab = initial_partition(g, 2, L, seed=3)
    eng = LPEngine(g, seed=0)
    cfg = _cfg(2, L, 2, 2, 3, seed=9, seeds=[seed_lab.astype(np.int64)])
    lab_dev = np.asarray(eng.evolve_device(g, cfg))
    lab_ora = eng.evolve_oracle(g, cfg)
    np.testing.assert_array_equal(lab_dev, lab_ora)
    assert cut_np(g, lab_dev) <= cut_np(g, seed_lab)


def test_offspring_never_worse_than_better_parent():
    """Per-generation elitism property, asserted on the oracle's trace (the
    device path is bit-identical to it, so the invariant transfers)."""
    g = planted_partition(600, 6, p_in=0.05, p_out=0.004, seed=7)
    L = lmax(g.n, 2, 0.03)
    eng = LPEngine(g, seed=0)
    cfg = _cfg(2, L, 2, 3, 4, seed=21)
    trace = []
    lab = eng.evolve_oracle(g, cfg, trace=trace)
    assert len(trace) == cfg.generations * cfg.islands
    for gen, isl, base_key, child_key in trace:
        # post-elitism the inserted key is min(child, base): never above base
        assert min(child_key, base_key) <= base_key
    # ... and parity still holds for this config
    np.testing.assert_array_equal(np.asarray(eng.evolve_device(g, cfg)), lab)


def test_graphdev_coarsest_consumed_without_host_materialization():
    """The coarsest stage must feed the still-resident GraphDev straight
    into the batched GA: no ``to_host`` materialization of the coarse CSR."""
    g = barabasi_albert(4096, 5, seed=1)
    L = lmax(g.n, 2, 0.03)
    eng = LPEngine(g, seed=0)
    clus = eng.cluster(g, U=max(1.0, L / 14), iters=3, seed=7)
    cdev, _ = eng.contract(g, clus)
    assert isinstance(cdev, GraphDev)
    cfg = _cfg(2, L, 2, 2, 1, seed=3)
    lab_dev = eng.evolve_device(cdev, cfg)
    assert isinstance(lab_dev, jax.Array)
    assert cdev._host is None            # never materialized
    lab_ora = eng.evolve_oracle(cdev, cfg)
    np.testing.assert_array_equal(np.asarray(lab_dev), lab_ora)


def test_evo_compile_count_bounded_by_buckets():
    """Compile-count regression: across a multi-V-cycle partition run the
    batched evo compiles once per (phase, shape-bucket) — never per call.

    The engine's ``evo_compiles == evo_bucket_count`` is definitional (both
    derive from the same key set), so the real assertion is against the jit
    caches of the evo entry points themselves: their growth across the run
    must not exceed the reported bucket count (a per-call shape drift would
    blow straight past it)."""
    from repro.core.evo_device import evo_generation_step, evo_seed_step

    def _jit_entries():
        try:
            return int(evo_seed_step._cache_size()) + int(
                evo_generation_step._cache_size()
            )
        except Exception:
            return None

    g = barabasi_albert(4096, 5, seed=1)
    cfg = PartitionerConfig(k=2, preset="fast", coarsest_factor=50, seed=0,
                            engine="jnp", generations=2, islands=2,
                            pop_per_island=2)
    before = _jit_entries()
    rep = partition(g, cfg)
    st = rep.engine_stats
    assert rep.feasible
    # 2 V-cycles x (1 seed step + 2 generation steps)
    assert st["evo_calls"] >= 4
    assert st["evo_compiles"] <= st["evo_calls"]
    assert st["evo_compiles"] < st["evo_calls"]
    after = _jit_entries()
    if before is not None and after is not None:
        assert after - before <= st["evo_bucket_count"]


def test_partition_evo_engine_host_fallback_matches_legacy():
    """evo_engine='host' must keep the legacy sequential KaFFPaE behaviour
    byte-for-byte (guards the fallback for non-integral-weight inputs)."""
    g = barabasi_albert(4096, 5, seed=2)
    base = dict(k=2, preset="fast", coarsest_factor=100, seed=0)
    rep_h = partition(g, PartitionerConfig(**base, evo_engine="host"))
    assert rep_h.feasible
    assert rep_h.engine_stats["evo_calls"] == 0
    rep_d = partition(g, PartitionerConfig(**base))
    assert rep_d.feasible
    assert rep_d.engine_stats["evo_calls"] >= 1


def test_non_integral_weights_fall_back_to_host_evo():
    g = planted_partition(512, 4, p_in=0.05, p_out=0.01, seed=0)
    g2 = type(g)(indptr=g.indptr, indices=g.indices,
                 ew=g.ew + np.float32(0.5), nw=g.nw)
    eng = LPEngine(g2, seed=0)
    assert not eng.can_evolve_device(g2, 2, 2, 2)


def test_greedy_growing_k_ge_n_guard():
    """Satellite regression: k >= n used to crash the degree-biased seed
    draw (rng.choice without replacement); now falls back to round-robin."""
    from repro.core import greedy_growing

    g = mesh2d(2)  # n = 4
    for k in (4, 5, 9):
        lab = greedy_growing(g, k, Lmax=10.0, seed=0)
        assert lab.shape == (g.n,)
        assert lab.min() >= 0 and lab.max() < k
        # round-robin: every node its own block (mod k)
        np.testing.assert_array_equal(lab, np.arange(g.n) % k)


def test_grow_rounds_scale_with_diameter_on_deep_path_like_graph():
    """ISSUE 4 satellite: the fixed GROW_ROUNDS=16 frontier truncated deep
    coarsest graphs — on a ring, all but ~2*(16+1) nodes used to land in the
    round-robin leftover fallback, whose alternating labels cut almost every
    edge.  The degree/diameter-proportional budget
    (evolutionary.grow_rounds_bound) lets both seeds grow to contiguous
    arcs: tiny cut, and the device path stays bit-identical to the oracle on
    the deep graph (the traced bound + stall exit change neither side's
    hash streams)."""
    from repro.core.evolutionary import GROW_ROUNDS, grow_rounds_bound
    from repro.graph import ring

    g = ring(300)
    assert grow_rounds_bound(g.n, 2, g.m) >= g.n // 2   # deep graph: ~n
    assert grow_rounds_bound(1600, 2, 1600 * 11) == max(
        GROW_ROUNDS, int(np.ceil(4 * 1600 / 2 / 11))
    )                                                   # shallow: ~n/(k*deg)
    L = lmax(g.n, 2, 0.03)
    eng = LPEngine(g, seed=0)
    cfg = _cfg(2, L, 1, 1, 0, seed=5)
    lab_dev = np.asarray(eng.evolve_device(g, cfg))
    lab_ora = eng.evolve_oracle(g, cfg)
    np.testing.assert_array_equal(lab_dev, lab_ora)
    # two contiguous blocks cut O(1) edges; 16-round truncation left the
    # leftover tail alternating (cut ~ hundreds)
    assert cut_np(g, lab_dev) <= 20


def test_device_ell_gather_matches_host_pack():
    """Satellite: dense refinement's ELL pack for a GraphDev level is now
    gathered on device — bit-identical to ell_pack on the materialized
    graph, with no O(m) adjacency download."""
    g = barabasi_albert(4096, 5, seed=3)
    L = lmax(g.n, 2, 0.03)
    eng = LPEngine(g, seed=0)
    clus = eng.cluster(g, U=max(1.0, L / 14), iters=3, seed=1)
    cdev, _ = eng.contract(g, clus)
    d2h_before = eng.stats.d2h_bytes
    ell_dev = eng._ell(cdev)
    d2h_delta = eng.stats.d2h_bytes - d2h_before
    # only the O(n) indptr may cross, never the O(m) adjacency
    assert d2h_delta <= (cdev.n + 1) * 8 + 64
    assert cdev._host is None
    # host oracle on the materialized graph through a fresh engine
    eng2 = LPEngine(g, seed=0)
    ell_host = eng2._ell(cdev.to_host())
    np.testing.assert_array_equal(np.asarray(ell_dev.dst), np.asarray(ell_host.dst))
    np.testing.assert_array_equal(np.asarray(ell_dev.w), np.asarray(ell_host.w))
    np.testing.assert_array_equal(
        np.asarray(ell_dev.row_node), np.asarray(ell_host.row_node)
    )
    assert ell_dev.nb == ell_host.nb


def test_dense_partition_on_device_levels_stays_resident():
    """refine_engine='dense' end-to-end with device coarsening: feasible,
    dense rounds ran, and the whole-run d2h stays far below one download of
    the fine graph (the old _ell host materialization would blow this)."""
    g = barabasi_albert(8192, 6, seed=3)
    cfg = PartitionerConfig(k=2, preset="fast", coarsest_factor=100, seed=0,
                            refine_engine="dense", dense_min_n=256,
                            numpy_below=64, engine="jnp")
    rep = partition(g, cfg)
    assert rep.feasible
    assert rep.engine_stats["dense_rounds"] > 0
    assert rep.engine_stats["d2h_bytes"] < g.m * 4


@pytest.mark.slow
def test_sharded_islands_match_single_device():
    """Island sharding over shard_map: per-epoch gossip as an all_gather
    collective, global island ids in every hash — bit-identical labels."""
    code = """
import numpy as np
import jax
from repro.core import LPEngine
from repro.core.evolutionary import EvoConfig
from repro.core.metrics import lmax
from repro.graph import planted_partition

assert jax.device_count() == 2
g = planted_partition(600, 6, p_in=0.05, p_out=0.004, seed=1)
L = lmax(g.n, 2, 0.03)
cfg = EvoConfig(k=2, Lmax=L, islands=4, pop_per_island=2, generations=3,
                refine_iters=3, seed=5)
eng = LPEngine(g, seed=0)
lab_single = np.asarray(eng.evolve_device(g, cfg, shard=False))
eng2 = LPEngine(g, seed=0)
lab_shard = np.asarray(eng2.evolve_device(g, cfg, shard=True))
assert np.array_equal(lab_single, lab_shard), (lab_single != lab_shard).sum()
oracle = eng.evolve_oracle(g, cfg)
assert np.array_equal(lab_single, oracle)
print("SHARDED_OK")
"""
    out = run_with_devices(code, n_devices=2)
    assert "SHARDED_OK" in out


@pytest.mark.device
def test_evo_device_on_tpu_backend():
    """TPU-only smoke (device marker): the batched GA end-to-end on real
    hardware, uncompromised by interpret-mode shims."""
    if jax.default_backend() != "tpu":
        pytest.skip("requires a real TPU backend")
    g = planted_partition(512, 4, p_in=0.05, p_out=0.01, seed=0)
    L = lmax(g.n, 2, 0.03)
    eng = LPEngine(g, seed=0)
    cfg = _cfg(2, L, 2, 2, 2, seed=1)
    lab = np.asarray(eng.evolve_device(g, cfg))
    assert lab.shape == (g.n,)
    np.testing.assert_array_equal(lab, eng.evolve_oracle(g, cfg))


def test_sweep_refine_numpy_matches_lp_sweep_bitwise():
    """The oracle's inner mirror: numpy chunk sweep == jitted _lp_sweep in
    refine mode, including the device-side chunk permutation, run-reduction
    jitter, and influx gating (integral weights)."""
    from repro.core.label_propagation import (
        _lp_sweep, make_order, sweep_refine_numpy,
    )
    from repro.graph import pack_chunks
    from repro.graph.packing import pad_pack

    g = planted_partition(600, 6, p_in=0.05, p_out=0.004, seed=1)
    n, k = g.n, 3
    Ab = 1 << n.bit_length()
    Kb = 4
    L = np.float32(lmax(g.n, k, 0.03))
    pack = pack_chunks(g, make_order(g, "random", 0), max_nodes=128,
                       max_edges=2048, block=8)
    C0 = pack.nodes.shape[0]
    pack = pad_pack(pack, 1 << (C0 - 1).bit_length(), 128,
                    pack.edge_dst.shape[1])
    rng = np.random.default_rng(0)
    lab0 = np.full(Ab, k, np.int32)
    lab0[:n] = rng.integers(0, k, n)
    nw = np.zeros(Ab, np.float32)
    nw[:n] = g.nw
    bw = np.zeros(Kb, np.float32)
    np.add.at(bw, lab0, nw)
    w0 = np.where(np.arange(Kb) < k, bw, np.float32(np.inf)).astype(np.float32)
    for seed in (7, 12345):
        out_dev, _, _ = _lp_sweep(
            jnp.asarray(pack.nodes), jnp.asarray(pack.node_valid),
            jnp.asarray(pack.edge_dst), jnp.asarray(pack.edge_w),
            jnp.asarray(pack.edge_src_slot), jnp.asarray(pack.edge_valid),
            jnp.asarray(lab0), jnp.asarray(w0), jnp.asarray(nw),
            jnp.zeros(1, jnp.int32), jnp.float32(L), jnp.int32(seed),
            jnp.int32(k), jnp.int32(pack.num_chunks),
            iters=4, refine_mode=True, use_restrict=False, permute_chunks=True,
        )
        out_np, _ = sweep_refine_numpy(
            pack.nodes, pack.node_valid, pack.edge_dst, pack.edge_w,
            pack.edge_src_slot, pack.edge_valid,
            lab0, w0, nw, L, seed, k, pack.num_chunks, 4,
        )
        np.testing.assert_array_equal(np.asarray(out_dev), out_np)
