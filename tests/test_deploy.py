"""Partition deployment subsystem (ISSUE 5): device block shard extraction,
ghost-exchange schedules, and incremental migration from the dynamic session.

The contract under test: device extraction is bit-identical to the numpy
oracle (every array, every dtype) across seeds / k / halo depths; the
exchange schedule round-trips (packing each owner's interface buffer in
slot order and scattering through (owner, slot) reproduces every ghost
table); reassembling the owned rows of all shards reproduces the global CSR
bit-for-bit (hence the global cut exactly); extraction and migration
compile once per shape bucket (deploy_compiles == deploy_bucket_count);
and a ShardDeployment tracking a PartitionSession stays consistent with a
fresh oracle extraction after every update batch.
"""

import numpy as np
import pytest

from repro.core.metrics import comm_volume_np, cut_np
from repro.deploy import (
    BlockExtractor,
    ShardDeployment,
    block_comm_metrics_np,
    extract_blocks_numpy,
    ghost_exchange_numpy,
    reassemble,
    shard_comm_metrics,
)
from repro.dynamic import GraphUpdate, PartitionSession, SessionConfig
from repro.graph import (
    barabasi_albert,
    mesh2d,
    planted_partition,
    rmat,
    to_device_csr,
    validate,
)

pytestmark = pytest.mark.deploy

_FIELDS = (
    "own_global", "ghost_global", "ghost_hop", "ghost_block", "nw",
    "ghost_nw", "indptr", "indices", "ew", "ghost_slot", "iface_global",
    "iface_local", "send_blocks", "send_ptr", "send_local",
)


def _assert_shards_equal(dev_shards, oracle):
    for s, o in zip(dev_shards, oracle):
        h = s.host()
        assert (h.block, h.n_own, h.n_ghost, h.n_rows, h.m_local) == (
            o.block, o.n_own, o.n_ghost, o.n_rows, o.m_local
        )
        for f in _FIELDS:
            a, b = getattr(h, f), getattr(o, f)
            assert a.dtype == b.dtype, (f, a.dtype, b.dtype)
            np.testing.assert_array_equal(a, b, err_msg=f"block {s.block}: {f}")


# ----------------------------------------------------------------- extraction


@pytest.mark.parametrize("k,halo,seed", [(2, 1, 0), (4, 1, 1), (4, 2, 2),
                                         (3, 3, 3), (8, 2, 4)])
def test_device_extraction_bit_parity_vs_numpy_oracle(k, halo, seed):
    """Every array of every shard — CSR, halo, id maps, schedule — matches
    the numpy oracle bit for bit, from both GraphNP and GraphDev inputs."""
    g = barabasi_albert(700, 4, seed=seed)
    rng = np.random.default_rng(seed)
    lab = rng.integers(0, k, g.n).astype(np.int32)
    oracle = extract_blocks_numpy(g, lab, k, halo=halo)
    ex = BlockExtractor()
    _assert_shards_equal(ex.extract(g, lab, k, halo=halo), oracle)
    # the device-resident path: same graph uploaded as a GraphDev handle
    ex2 = BlockExtractor()
    _assert_shards_equal(
        ex2.extract(to_device_csr(g), lab, k, halo=halo), oracle
    )


def test_extraction_on_mesh_partition_labels():
    """Structured (low-boundary) labels from a real partition, not random —
    halos are thin rings here, the opposite regime of the random-label case."""
    g = mesh2d(24)
    k = 4
    lab = ((np.arange(g.n) // 24 // 12) * 2 + (np.arange(g.n) % 24) // 12)
    lab = lab.astype(np.int32)
    for halo in (1, 2):
        ex = BlockExtractor()
        _assert_shards_equal(
            ex.extract(g, lab, k, halo=halo),
            extract_blocks_numpy(g, lab, k, halo=halo),
        )


def test_shard_structure_invariants():
    """Local id space and h-ring layout: owned ids ascending, ghosts ordered
    by (ring, id), rows = owned + interior ghosts, every row's adjacency
    fully inside the shard, ghost blocks correct."""
    g = planted_partition(900, 6, p_in=0.04, p_out=0.004, seed=5)
    k, halo = 3, 2
    rng = np.random.default_rng(1)
    lab = rng.integers(0, k, g.n).astype(np.int32)
    for h in extract_blocks_numpy(g, lab, k, halo=halo):
        assert np.all(np.diff(h.own_global) > 0)
        np.testing.assert_array_equal(lab[h.own_global], h.block)
        key = h.ghost_hop.astype(np.int64) * g.n + h.ghost_global
        assert np.all(np.diff(key) > 0)          # (ring, id) strictly sorted
        assert np.all((h.ghost_hop >= 1) & (h.ghost_hop <= halo))
        np.testing.assert_array_equal(lab[h.ghost_global], h.ghost_block)
        assert np.all(h.ghost_block != h.block)
        n_interior = int((h.ghost_hop < halo).sum())
        assert h.n_rows == h.n_own + n_interior
        assert h.indices.min(initial=0) >= 0
        assert h.indices.max(initial=-1) < h.n_own + h.n_ghost
        # row adjacency is complete: degree in-shard == global degree
        rows_g = h.local_global[: h.n_rows]
        np.testing.assert_array_equal(
            np.diff(h.indptr), g.degrees()[rows_g]
        )


# ---------------------------------------------------------------- reassembly


@pytest.mark.parametrize("halo", [1, 2])
def test_reassembly_reproduces_global_graph_and_cut(halo):
    g = rmat(10, 8, seed=3)
    k = 4
    sess_lab = np.random.default_rng(2).integers(0, k, g.n).astype(np.int32)
    ex = BlockExtractor()
    shards = ex.extract(g, sess_lab, k, halo=halo)
    g2 = reassemble(shards, g.n)
    np.testing.assert_array_equal(g2.indptr, g.indptr)
    np.testing.assert_array_equal(g2.indices, g.indices)
    np.testing.assert_array_equal(g2.ew, g.ew)      # same float bits
    np.testing.assert_array_equal(g2.nw, g.nw)
    validate(g2)
    assert cut_np(g2, sess_lab) == cut_np(g, sess_lab)
    # the shards' ghost arcs ARE the cut: heads >= n_own from owned rows
    tot = 0.0
    for s in shards:
        h = s.host()
        m_own = int(h.indptr[h.n_own])
        tot += float(h.ew[:m_own][h.indices[:m_own] >= h.n_own].sum())
    assert tot / 2.0 == pytest.approx(cut_np(g, sess_lab))


# ------------------------------------------------------------ ghost exchange


def test_ghost_exchange_round_trip():
    """Pack every owner's interface buffer in slot order, scatter through
    (owner, slot): every ghost table must equal the owners' values — for
    labels and for an arbitrary per-node payload, at halo 1 and 2."""
    g = barabasi_albert(800, 5, seed=7)
    k = 5
    rng = np.random.default_rng(7)
    lab = rng.integers(0, k, g.n).astype(np.int32)
    for halo in (1, 2):
        ex = BlockExtractor()
        shards = ex.extract(g, lab, k, halo=halo)
        for vals in (lab, rng.integers(0, 10**6, g.n)):
            recvs = ghost_exchange_numpy(shards, vals)
            for s, r in zip(shards, recvs):
                np.testing.assert_array_equal(r, vals[s.ghost_global_np()])
        # labels through the schedule reproduce ghost_block exactly
        recvs = ghost_exchange_numpy(shards, lab)
        for s, r in zip(shards, recvs):
            np.testing.assert_array_equal(r, s.ghost_block_np())


# -------------------------------------------------------------------- metrics


def test_comm_metrics_label_and_shard_views_agree():
    g = planted_partition(1200, 8, p_in=0.03, p_out=0.003, seed=9)
    k = 4
    lab = np.random.default_rng(4).integers(0, k, g.n).astype(np.int32)
    m_lab = block_comm_metrics_np(g, lab, k)
    ex = BlockExtractor()
    m_sh = shard_comm_metrics(ex.extract(g, lab, k, halo=1))
    for f in ("boundary", "send", "recv"):
        np.testing.assert_array_equal(m_lab[f], m_sh[f])
    assert m_lab["total_volume"] == int(comm_volume_np(g, lab, k))
    assert int(m_lab["send"].sum()) == int(m_lab["recv"].sum())


# ------------------------------------------------------------ compile bounds


def test_deploy_compiles_bounded_by_buckets():
    """Balanced blocks share one (mask, extract) bucket pair; repeated
    extraction over a churn stream must not add compiles."""
    g = barabasi_albert(2048, 4, seed=11)
    k = 4
    rng = np.random.default_rng(11)
    lab = rng.integers(0, k, g.n).astype(np.int32)
    ex = BlockExtractor()
    ex.extract(g, lab, k, halo=1)
    st = ex.stats
    assert st.deploy_compiles == st.deploy_bucket_count
    first = st.deploy_compiles
    assert first <= 4  # one mask bucket + a handful of sticky extract buckets
    for _ in range(3):
        lab2 = lab.copy()
        flip = rng.integers(0, g.n, 30)
        lab2[flip] = (lab2[flip] + 1) % k
        ex.extract(g, lab2, k, halo=1)
        lab = lab2
    assert st.deploy_compiles == st.deploy_bucket_count
    assert st.extract_calls == 16
    assert st.deploy_compiles <= first + 2  # sticky buckets absorb the churn


def test_extractor_reuse_across_graph_scales_and_partial_extraction():
    """One extractor serving graphs of different scales must clamp its
    sticky buckets (a small graph cannot inherit a big graph's node
    bucket), and a partial extraction must refuse schedule assembly (the
    schedule needs every ghost's owner present)."""
    ex = BlockExtractor()
    big = barabasi_albert(2048, 4, seed=1)
    small = barabasi_albert(200, 3, seed=2)
    k = 2
    lab_big = (np.arange(big.n) % k).astype(np.int32)
    lab_small = (np.arange(small.n) % k).astype(np.int32)
    ex.extract(big, lab_big, k, halo=1)
    shards = ex.extract(small, lab_small, k, halo=1)   # must not crash
    _assert_shards_equal(shards, extract_blocks_numpy(small, lab_small, k))
    with pytest.raises(ValueError, match="assemble"):
        ex.extract(small, lab_small, k, halo=1, blocks=[0])
    sub = ex.extract(small, lab_small, k, halo=1, blocks=[0], assemble=False)
    assert len(sub) == 1 and sub[0].ghost_slot is None


# ------------------------------------------------------------------ migration


def test_shard_deployment_tracks_session_and_patches_incrementally():
    """After every update batch the deployed shard set must equal a fresh
    oracle extraction of the session's current graph + labels; localized
    churn must patch a strict subset of blocks; compiles stay bounded."""
    g = planted_partition(1600, 8, p_in=0.05, p_out=0.0003, seed=13)
    k = 8
    sess = PartitionSession(g, SessionConfig(k=k, seed=0))
    dep = ShardDeployment(sess, halo=1)
    rng = np.random.default_rng(13)
    partial_steps = 0
    for step in range(4):
        # localized churn: wire random pairs among one block's INTERIOR
        # nodes (no foreign neighbour — the only nodes that are a member of
        # exactly one shard; boundary churn legitimately fans out to every
        # subscribing block)
        lab = sess.labels_np()
        gh = sess.store.csr_host()
        src = gh.arc_sources()
        bnd = np.zeros(gh.n, bool)
        np.logical_or.at(bnd, src[lab[src] != lab[gh.indices]], True)
        interior = np.bincount(lab[~bnd], minlength=k)
        b = int(np.argmax(interior))     # block with the most interior nodes
        ids = np.flatnonzero((lab == b) & ~bnd)
        assert ids.size >= 12
        u = rng.choice(ids, 12)
        v = rng.choice(ids, 12)
        keep = u != v
        res, delta = dep.update(GraphUpdate.add_edges(u[keep], v[keep]))
        assert not res.noop
        assert delta.blocks_patched.size >= 1
        if not delta.full_rebuild:
            partial_steps += 1
            assert delta.blocks_patched.size < k
        # consistency vs a fresh oracle on the current state
        gh = sess.store.csr_host()
        _assert_shards_equal(
            dep.shards, extract_blocks_numpy(gh, sess.labels_np(), k, halo=1)
        )
    assert partial_steps >= 1   # localized churn really took the cheap path
    st = dep.stats()
    assert st["deploy_compiles"] == st["deploy_bucket_count"]
    assert st["blocks_patched_total"] < st["migrate_calls"] * k + 1


def test_migration_delta_reports_moves_and_halo_churn():
    g = planted_partition(1000, 6, p_in=0.05, p_out=0.003, seed=17)
    k = 2
    sess = PartitionSession(g, SessionConfig(k=k, seed=0))
    dep = ShardDeployment(sess, halo=1)
    lab0 = sess.labels_np().copy()
    rng = np.random.default_rng(17)
    u = rng.integers(0, g.n, 30)
    v = (u + 1 + rng.integers(0, g.n - 1, 30)) % g.n
    res, delta = dep.update(GraphUpdate.add_edges(u, v))
    lab1 = sess.labels_np()
    np.testing.assert_array_equal(
        delta.moved, np.flatnonzero(lab1 != lab0)
    )
    np.testing.assert_array_equal(delta.moved_from, lab0[delta.moved])
    np.testing.assert_array_equal(delta.moved_to, lab1[delta.moved])
    # churned endpoints are dirty even when no node moved
    assert np.isin(u, delta.dirty).all() and np.isin(v, delta.dirty).all()
    for b in delta.blocks_patched:
        assert b in delta.halo_added and b in delta.halo_removed


def test_migration_noop_batch_patches_nothing():
    g = planted_partition(800, 6, p_in=0.05, p_out=0.003, seed=19)
    sess = PartitionSession(g, SessionConfig(k=2, seed=0))
    dep = ShardDeployment(sess, halo=1)
    shards_before = list(dep.shards)
    res, delta = dep.update(GraphUpdate())
    assert res.noop and delta.noop and delta.blocks_patched.size == 0
    assert all(a is b for a, b in zip(dep.shards, shards_before))


def test_migration_survives_node_growth_and_escalation():
    """add_nodes (arena growth) and a forced quality-guard escalation both
    end in a consistent (fully rebuilt) shard set."""
    g = planted_partition(1000, 8, p_in=0.05, p_out=0.001, seed=23)
    k = 2
    sess = PartitionSession(
        g, SessionConfig(k=k, seed=0, escalate_cut_ratio=1.05, hops=1)
    )
    dep = ShardDeployment(sess, halo=1)
    res, delta = dep.update(GraphUpdate.add_nodes(np.ones(50, np.int64)))
    assert sess.n == 1050
    gh = sess.store.csr_host()
    _assert_shards_equal(
        dep.shards, extract_blocks_numpy(gh, sess.labels_np(), k, halo=1)
    )
    rng = np.random.default_rng(5)
    u = rng.integers(0, sess.n, 600)
    v = (u + 1 + rng.integers(0, sess.n - 1, 600)) % sess.n
    res, delta = dep.update(GraphUpdate.add_edges(u, v))
    assert res.escalated and delta.full_rebuild
    gh = sess.store.csr_host()
    _assert_shards_equal(
        dep.shards, extract_blocks_numpy(gh, sess.labels_np(), k, halo=1)
    )
    st = dep.stats()
    assert st["deploy_compiles"] == st["deploy_bucket_count"]
