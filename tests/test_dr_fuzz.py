"""End-to-end disaster-recovery fuzzing (ISSUE 7).

Each episode drives the full serving stack (PartitionSession ->
ReplicatedDeployment -> ResilientSession -> DurableSession) with mangled
concurrent update streams while injecting seeded faults from every
:class:`FaultInjector` class, then asserts the healing property: after
every episode the session either heals in place or restores from durable
state to the numpy oracle digest, every invariant audit passes, and
reads never see a hole.

The default suite runs a fast smoke (2 episodes, fixed seeds); the full
campaign (>= 20 episodes, the ISSUE acceptance bar) is opt-in via
``-m fuzz``.
"""

import pytest

from repro.resilience import FuzzConfig, run_fuzz

pytestmark = pytest.mark.resilience


def test_fuzz_smoke(tmp_path):
    """Fast seeded smoke in the default suite: two episodes, small graph,
    every fault class reachable, zero unhealed violations."""
    cfg = FuzzConfig(
        directory=str(tmp_path / "fuzz"),
        n=300, k=3, episodes=2, batches_per_episode=5, batch_size=16,
        seed=7, checkpoint_every=3, replicas=2, audit_cadence=2,
    )
    report = run_fuzz(cfg)
    assert report.ok, report.summary()
    assert len(report.episodes) == 2
    assert sum(e.commits for e in report.episodes) > 0
    assert sum(e.strict_digest_checks for e in report.episodes) > 0


def test_fuzz_smoke_is_seeded(tmp_path):
    """The campaign is deterministic given (seed, shape): two runs inject
    the same fault sequence and land the same outcome counters."""
    kw = dict(n=300, k=3, episodes=1, batches_per_episode=4, batch_size=16,
              seed=11, checkpoint_every=3, replicas=2, audit_cadence=2)
    a = run_fuzz(FuzzConfig(directory=str(tmp_path / "a"), **kw))
    b = run_fuzz(FuzzConfig(directory=str(tmp_path / "b"), **kw))
    assert a.ok and b.ok
    ea, eb = a.episodes[0], b.episodes[0]
    for f in ("commits", "quarantined", "heals", "restores", "replayed",
              "failovers", "strict_digest_checks", "violations"):
        assert getattr(ea, f) == getattr(eb, f), f
    assert ea.faults == eb.faults


@pytest.mark.fuzz
@pytest.mark.slow
def test_fuzz_campaign(tmp_path):
    """The ISSUE acceptance bar: >= 20 seeded episodes interleaving every
    fault class against mangled concurrent streams, zero unhealed
    invariant violations."""
    cfg = FuzzConfig(directory=str(tmp_path / "fuzz"), episodes=20, seed=0)
    report = run_fuzz(cfg)
    assert report.ok, report.summary()
    assert len(report.episodes) >= 20
    # the campaign actually exercised the machinery, not just clean paths
    assert sum(len(e.faults) for e in report.episodes) >= 20
    assert sum(e.heals for e in report.episodes) > 0
    assert sum(e.restores for e in report.episodes) > 0
    assert sum(e.strict_digest_checks for e in report.episodes) >= 20
    assert not report.violations
