"""Standby shard replicas (ISSUE 7): checksum-audited failover serving.

The serving contract: ``read_block`` never sees a hole.  A healthy
primary serves after a checksum audit; a lost/corrupt primary fails over
to an audited standby while the block queues for background
re-extraction; when every copy is gone the read falls back to an
immediate synchronous recovery.  Standbys are refreshed alongside every
consistent extraction (initial deploy, incremental migrate, recovery),
so replica content always matches the block's expected checksum.
"""

import numpy as np
import pytest

from repro.deploy import ReplicatedDeployment
from repro.dynamic import GraphUpdate, PartitionSession, SessionConfig
from repro.graph import planted_partition
from repro.resilience import (
    FaultInjector,
    InvariantAuditor,
    ResilientConfig,
    ResilientSession,
)

pytestmark = [pytest.mark.deploy, pytest.mark.resilience]


def _deployed(n=400, k=3, replicas=3, seed=0):
    g = planted_partition(n, k, 10, 2, seed=seed)
    sess = PartitionSession(g, SessionConfig(k=k, seed=seed))
    dep = ReplicatedDeployment(sess, replicas=replicas)
    return sess, dep


def _batch(sess, rng, size=20):
    u = rng.integers(0, sess.n, size)
    v = (u + 1 + rng.integers(0, sess.n - 1, size)) % sess.n
    return GraphUpdate.add_edges(u, v)


def _assert_serves_everywhere(sess, dep):
    """Every block reads back a verified shard whose owned nodes carry the
    block's label — no holes, no stale ownership."""
    labels = sess.labels_np()
    for b in range(dep.k):
        s = dep.read_block(b)
        assert s is not None and dep.verify_shard(b, s)
        own = np.asarray(s.host().own_global)
        assert own.size and np.all(labels[own] == b)


# ------------------------------------------------------------ replica upkeep


def test_initial_deploy_builds_full_replica_sets():
    sess, dep = _deployed(replicas=3)
    for b in range(dep.k):
        assert len(dep._standbys[b]) == 2
        assert dep.verify_shard(b, dep.shards[b])
        for s in dep._standbys[b]:
            assert dep.verify_shard(b, s)
            assert s is not dep.shards[b]       # distinct copy objects
    _assert_serves_everywhere(sess, dep)


def test_replicas_one_degrades_to_verified_reads():
    sess, _ = _deployed(replicas=2)
    dep1 = ReplicatedDeployment(sess, replicas=1)
    assert all(not st for st in dep1._standbys)
    for b in range(dep1.k):                     # reads are still audited
        assert dep1.verify_shard(b, dep1.read_block(b))
    with pytest.raises(ValueError):
        ReplicatedDeployment(sess, replicas=0)


def test_replicas_track_migration():
    """Incremental migration refreshes standbys + expected checksums of
    every patched block, so failover candidates never serve stale
    content."""
    sess, dep = _deployed(replicas=2)
    rng = np.random.default_rng(0)
    before = dep.replica_refreshes
    for _ in range(3):
        upd = _batch(sess, rng)
        res = sess.update(upd)
        delta = dep.migrate(upd, res)
        assert not delta.failed
    assert dep.replica_refreshes > before
    _assert_serves_everywhere(sess, dep)
    # a standby of a migrated block matches the CURRENT primary content
    for b in range(dep.k):
        for s in dep._standbys[b]:
            assert dep.verify_shard(b, s)


# ----------------------------------------------------------------- failover


@pytest.mark.parametrize("fault", ["corrupt", "lose"])
def test_failover_serves_audited_standby(fault):
    sess, dep = _deployed(replicas=3)
    inj = FaultInjector(0)
    if fault == "corrupt":
        inj.corrupt_shard(dep, block=0)
    else:
        inj.lose_shard(dep, block=0)
    s = dep.read_block(0)                       # the read never sees a hole
    assert dep.failovers == 1 and dep.failover_misses == 0
    assert dep.verify_shard(0, s)
    assert dep.recovery_pending == {0}
    assert len(dep._standbys[0]) == 1           # one standby was promoted
    # while recovery is pending, EVERY block still serves verified reads
    _assert_serves_everywhere(sess, dep)
    assert InvariantAuditor(sess, deployment=dep).audit().ok
    # background recovery restores the replica count
    assert dep.run_recovery() == [0]
    assert dep.recovery_pending == set()
    assert len(dep._standbys[0]) == 2
    _assert_serves_everywhere(sess, dep)


def test_failover_skips_rotten_standby():
    """A standby that rotted (replica bit flip) is audited and skipped;
    the next clean standby is promoted instead."""
    sess, dep = _deployed(replicas=3)
    inj = FaultInjector(1)
    inj.corrupt_shard(dep, block=1)
    assert inj.corrupt_replica(dep, block=1) is not None
    # which standby rotted is seed-chosen; the promoted one must be clean
    s = dep.read_block(1)
    assert dep.verify_shard(1, s)
    assert dep.failovers == 1
    dep.run_recovery()
    _assert_serves_everywhere(sess, dep)


def test_failover_miss_recovers_synchronously():
    """Primary corrupt + the only standby corrupt: the read STILL succeeds
    via immediate re-extraction, surfaced as a failover miss."""
    sess, dep = _deployed(replicas=2)
    inj = FaultInjector(2)
    inj.corrupt_shard(dep, block=0)
    assert inj.corrupt_replica(dep, block=0) is not None
    s = dep.read_block(0)
    assert s is not None and dep.verify_shard(0, s)
    assert dep.failover_misses == 1
    assert dep.recovery_pending == set()        # recover_block refreshed it
    assert len(dep._standbys[0]) == 1
    _assert_serves_everywhere(sess, dep)


# ------------------------------------------------ transactional integration


def test_replicated_deployment_rides_transactions():
    """The full PR 7 serving stack: replicated shards migrate inside the
    transactional loop, failover serves mid-stream, audits stay green."""
    sess, dep = _deployed(replicas=2)
    rs = ResilientSession(sess, deployment=dep,
                          cfg=ResilientConfig(audit_cadence=2))
    rng = np.random.default_rng(3)
    inj = FaultInjector(4)
    for i in range(6):
        tx = rs.submit(_batch(sess, rng), seq=i)
        assert tx.committed
        if i == 2:
            inj.corrupt_shard(dep, block=0)
            assert dep.read_block(0) is not None        # failover mid-stream
            dep.run_recovery()
    assert dep.failovers >= 1
    assert rs.auditor.audit().ok
    _assert_serves_everywhere(sess, dep)


def test_stats_surface_replica_counters():
    sess, dep = _deployed(replicas=2)
    FaultInjector(5).corrupt_shard(dep, block=0)
    dep.read_block(0)
    d = dep.stats()
    assert d["replicas"] == 2
    assert d["failovers"] == 1
    assert d["recovery_pending"] == 1
    assert d["replica_reads"] >= 1
    assert d["replica_refreshes"] >= dep.k
