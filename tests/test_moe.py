"""MoE: expert-parallel shard_map path vs the dense oracle."""

import pytest

from _subproc import run_with_devices


def test_dense_moe_routing_mass():
    import jax, jax.numpy as jnp
    from repro.models.moe import init_moe_params, moe_dense

    key = jax.random.PRNGKey(0)
    p = init_moe_params(key, 32, 64, 8, True, jnp.float32)
    x = jax.random.normal(key, (2, 16, 32))
    y, aux = moe_dense(p, x, topk=2)
    assert y.shape == x.shape
    assert float(aux) > 0


@pytest.mark.slow
def test_ep_matches_dense_oracle():
    out = run_with_devices("""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from repro.launch.mesh import make_mesh
from repro.models.moe import init_moe_params, moe_dense, moe_ep

mesh = make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
E, D, F, topk = 8, 32, 64, 2
p = init_moe_params(key, D, F, E, True, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, D))
y_ref, _ = moe_dense(p, x, topk=topk)
# capacity_factor large enough that nothing drops -> exact parity
y_ep, _ = jax.jit(lambda p, x: moe_ep(p, x, mesh=mesh, topk=topk, n_experts=E,
                                      capacity_factor=8.0))(p, x)
err = float(jnp.max(jnp.abs(y_ep - y_ref)))
assert err < 2e-4, err
print("EP-PARITY-OK", err)
""")
    assert "EP-PARITY-OK" in out
