"""Data pipeline: determinism-by-step (exact replay on restart)."""

import numpy as np

from repro.data import TokenPipeline


def test_deterministic_by_step():
    p1 = TokenPipeline(vocab=256, batch=4, seq=32, seed=7)
    p2 = TokenPipeline(vocab=256, batch=4, seq=32, seed=7)
    for s in (0, 5, 17):
        np.testing.assert_array_equal(p1.batch_at(s)["tokens"],
                                      p2.batch_at(s)["tokens"])
    assert not np.array_equal(p1.batch_at(0)["tokens"], p1.batch_at(1)["tokens"])


def test_learnable_structure():
    p = TokenPipeline(vocab=97, batch=8, seq=64, seed=0)
    t = p.batch_at(0)["tokens"]
    hits = ((t[:, 1:] == (t[:, :-1] * 31 + 7) % 97).mean())
    assert hits > 0.3  # induced bigram structure present
