"""Disaster recovery (ISSUE 7): durable checkpoints, WAL replay, restore.

The contract under test: every COMMITTED transaction survives process
death (RPO 0) — restore on a fresh stack loads the newest complete
checkpoint and replays the fsynced write-ahead log through the real
``update`` path to a **bit-identical** session (``host_digest`` equality
against the pre-crash oracle).  A crash mid-checkpoint-write never
corrupts the latest restorable step; WAL media corruption is confined by
the crc framing to the torn tail; ``heal()`` timeline forks truncate
durable state so restores land on the surviving timeline.
"""

import os

import numpy as np
import pytest

from repro import ckpt
from repro.deploy import ReplicatedDeployment
from repro.dynamic import (
    GraphUpdate,
    PartitionSession,
    SessionConfig,
    UpdateValidationError,
)
from repro.graph import planted_partition
from repro.resilience import (
    DurableConfig,
    DurableSession,
    FaultInjector,
    ResilientConfig,
    ResilientSession,
    host_digest,
    read_wal,
)
from repro.resilience.durable import wal_path

pytestmark = pytest.mark.resilience


def _digests_equal(a, b):
    assert a.keys() == b.keys()
    for key in a:
        np.testing.assert_array_equal(a[key], b[key], err_msg=key)


def _stack(tmp_path, n=400, k=3, checkpoint_every=4, replicated=True,
           audit_cadence=4, seed=0):
    g = planted_partition(n, k, 10, 2, seed=seed)
    sess = PartitionSession(g, SessionConfig(k=k, seed=seed))
    dep = ReplicatedDeployment(sess, replicas=2) if replicated else None
    rs = ResilientSession(
        sess, deployment=dep,
        cfg=ResilientConfig(audit_cadence=audit_cadence),
    )
    ds = DurableSession(rs, DurableConfig(
        directory=str(tmp_path / "dr"), checkpoint_every=checkpoint_every,
    ))
    return ds


def _batch(sess, rng, size=20):
    u = rng.integers(0, sess.n, size)
    v = (u + 1 + rng.integers(0, sess.n - 1, size)) % sess.n
    return GraphUpdate.add_edges(u, v)


# ------------------------------------------------------------- wire format


def _wire_update(rng):
    return GraphUpdate(
        add_u=rng.integers(0, 100, 7), add_v=rng.integers(100, 200, 7),
        add_w=rng.integers(1, 9, 7),
        rem_u=rng.integers(0, 50, 3), rem_v=rng.integers(50, 100, 3),
        rem_w=rng.integers(1, 5, 3),
        add_node_w=rng.integers(1, 4, 2),
    )


def test_wire_roundtrip_all_fields():
    rng = np.random.default_rng(0)
    upd = _wire_update(rng)
    out = GraphUpdate.from_bytes(upd.to_bytes())
    for f in ("add_u", "add_v", "add_w", "rem_u", "rem_v", "rem_w",
              "add_node_w"):
        np.testing.assert_array_equal(getattr(upd, f), getattr(out, f), f)


def test_wire_roundtrip_empty_update():
    out = GraphUpdate.from_bytes(GraphUpdate().to_bytes())
    assert out.add_u.size == 0 and out.add_node_w.size == 0


def test_wire_rejects_bit_flips_everywhere():
    """Seeded single-bit-flip sweep over every byte region of a record:
    each flip either raises (never a partial object) or — only for the
    crc-exempt header bits (flags/reserved, which carry no payload
    meaning) — parses back to the identical update."""
    rng = np.random.default_rng(1)
    upd = _wire_update(rng)
    blob = bytearray(upd.to_bytes())
    flips = {int(rng.integers(0, len(blob))) for _ in range(64)}
    flips |= {0, 4, 5, 6, 8, 16, 18, len(blob) - 1}  # every header field
    rejected = 0
    for byte in sorted(flips):
        for bit in (0, 7):
            mut = bytearray(blob)
            mut[byte] ^= 1 << bit
            try:
                out = GraphUpdate.from_bytes(bytes(mut))
            except UpdateValidationError as e:
                assert e.reason.startswith("wal_"), e.reason
                rejected += 1
                continue
            assert 5 <= byte <= 7, (
                f"undetected flip at byte {byte} outside the crc-exempt "
                f"flags/reserved header bytes"
            )
            np.testing.assert_array_equal(out.add_u, upd.add_u)
    assert rejected > 100  # the sweep actually exercised the crc


def test_wire_rejects_truncation_and_trailing():
    blob = GraphUpdate.add_edges([1, 2], [3, 4]).to_bytes()
    for cut in (0, 3, 19, len(blob) - 1):
        with pytest.raises(UpdateValidationError) as ei:
            GraphUpdate.from_bytes(blob[:cut])
        assert ei.value.reason == "wal_truncated"
    with pytest.raises(UpdateValidationError) as ei:
        GraphUpdate.from_bytes(blob + b"x")
    assert ei.value.reason == "wal_trailing"
    with pytest.raises(UpdateValidationError) as ei:
        GraphUpdate.from_bytes(b"NOPE" + blob[4:])
    assert ei.value.reason == "wal_bad_magic"


def test_wire_records_concatenate_and_resplit():
    """Self-delimiting framing: a log of concatenated records re-splits
    via wire_size without an outer index."""
    rng = np.random.default_rng(2)
    upds = [_wire_update(rng) for _ in range(4)]
    log = b"".join(u.to_bytes() for u in upds)
    off, seen = 0, 0
    while off < len(log):
        size = GraphUpdate.wire_size(log[off:])
        out = GraphUpdate.from_bytes(log[off:off + size])
        np.testing.assert_array_equal(out.add_u, upds[seen].add_u)
        off += size
        seen += 1
    assert seen == len(upds)


# ------------------------------------------------- kill-and-restart restore


def test_restore_bit_identical_after_kill(tmp_path):
    """The acceptance drill: commits -> (no shutdown) -> fresh-process
    restore loads the checkpoint, replays the WAL, and lands bit-identical
    to the pre-crash digest — with the transactional sequence state intact
    so the stream resumes seamlessly."""
    ds = _stack(tmp_path, checkpoint_every=3)
    rng = np.random.default_rng(0)
    for i in range(8):      # 2 checkpoints + 2 WAL records past the anchor
        assert ds.submit(_batch(ds.session, rng), seq=i).committed
    assert ds.checkpoints_written >= 2
    assert ds._wal.records_appended >= 1
    pre = host_digest(ds.session)
    pre_seq = ds.rs._expected_seq

    ds2, rep = DurableSession.restore(str(tmp_path / "dr"))
    assert rep.records_replayed >= 1
    assert rep.wal_tail_error is None and rep.wal_bytes_dropped == 0
    _digests_equal(host_digest(ds2.session), pre)
    assert ds2.rs._expected_seq == pre_seq
    # the restored stack serves: deployment rebuilt, stream continues
    assert isinstance(ds2.rs.deployment, ReplicatedDeployment)
    assert ds2.rs.auditor.audit().ok
    tx = ds2.submit(_batch(ds2.session, rng), seq=pre_seq)
    assert tx.committed


def test_restore_replays_degraded_mode_flags(tmp_path):
    """WAL records carry the suppress_escalation flag the committed apply
    ran under, so a replay reproduces degraded-mode applies (repairs that
    skipped escalation) bit-for-bit."""
    ds = _stack(tmp_path, checkpoint_every=100, audit_cadence=100)
    rng = np.random.default_rng(1)
    ds.submit(_batch(ds.session, rng))
    ds.session.suppress_escalation = True   # operator-forced degraded apply
    ds.rs.degraded = True
    ds.submit(_batch(ds.session, rng, size=60))
    records, _, err = read_wal(wal_path(str(tmp_path / "dr"),
                                        ds.anchor_step))
    assert err is None
    assert [r.suppress for r in records] == [False, True]
    pre = host_digest(ds.session)
    ds2, _ = DurableSession.restore(str(tmp_path / "dr"))
    _digests_equal(host_digest(ds2.session), pre)
    assert ds2.session.suppress_escalation and ds2.rs.degraded


def test_restore_without_deployment(tmp_path):
    ds = _stack(tmp_path, replicated=False)
    rng = np.random.default_rng(2)
    for _ in range(2):
        ds.submit(_batch(ds.session, rng))
    pre = host_digest(ds.session)
    ds2, _ = DurableSession.restore(str(tmp_path / "dr"))
    assert ds2.rs.deployment is None
    _digests_equal(host_digest(ds2.session), pre)


# ------------------------------------------------------ crash-window safety


def test_mid_checkpoint_crash_never_corrupts_latest(tmp_path):
    """A kill inside the checkpoint write window (torn .tmp, no rename)
    leaves the previous checkpoint + the still-extending WAL as the
    restorable state: RPO stays 0 because the WAL covers every commit the
    failed checkpoint would have absorbed."""
    ds = _stack(tmp_path, checkpoint_every=100)
    rng = np.random.default_rng(3)
    for _ in range(3):
        ds.submit(_batch(ds.session, rng))
    anchor_before = ds.anchor_step
    FaultInjector(0).fail_mid_checkpoint(ds)
    assert ds.checkpoint() is None          # the injected crash
    assert ds.failed_checkpoints == 1
    assert ckpt.latest_step(str(tmp_path / "dr")) == anchor_before
    # the torn .tmp is on disk but invisible to recovery
    torn = [d for d in os.listdir(tmp_path / "dr") if d.endswith(".tmp")]
    assert torn
    pre = host_digest(ds.session)
    ds2, rep = DurableSession.restore(str(tmp_path / "dr"))
    assert rep.checkpoint_step == anchor_before
    assert rep.records_replayed == 3
    _digests_equal(host_digest(ds2.session), pre)
    # the next checkpoint attempt (hook consumed) succeeds and rotates
    assert ds.checkpoint() is not None
    assert ds._commits_since_ckpt == 0


def test_disarmed_injector_leaves_no_global_patch(tmp_path):
    """fail_mid_checkpoint patches the process-global ckpt.save; retiring
    the injector without the hook firing must restore it (regression: a
    leaked patch crashed the NEXT campaign's first checkpoint)."""
    ds = _stack(tmp_path, replicated=False, checkpoint_every=100)
    inj = FaultInjector(0)
    inj.fail_mid_checkpoint(ds)
    inj.disarm()
    assert ds.checkpoint() is not None
    assert ds.failed_checkpoints == 0


def test_double_armed_checkpoint_hook_does_not_stack(tmp_path):
    """Arming fail_mid_checkpoint twice must not stack patches — a
    stacked hook would capture the FIRST hook as the 'real' writer and
    re-install it on fire (regression: ckpt.save stayed hooked across
    fuzz episodes)."""
    ds = _stack(tmp_path, replicated=False, checkpoint_every=100)
    inj = FaultInjector(0)
    assert inj.fail_mid_checkpoint(ds) is not None
    assert inj.fail_mid_checkpoint(ds) is None
    assert ds.checkpoint() is None      # the one-shot fires exactly once
    assert ds.checkpoint() is not None  # and the real writer is back


def test_wal_corruption_confined_to_tail(tmp_path):
    """A bit flip in the WAL drops the torn tail, never the clean prefix:
    restore lands on the surviving step, reports the damage, truncates the
    file so future appends stay parseable, and replay stays deterministic
    (two restores from the same disk state are bit-identical)."""
    ds = _stack(tmp_path, checkpoint_every=100, audit_cadence=100)
    rng = np.random.default_rng(4)
    for _ in range(4):
        ds.submit(_batch(ds.session, rng))
    path = wal_path(str(tmp_path / "dr"), ds.anchor_step)
    clean, _, _ = read_wal(path)
    assert len(clean) == 4
    # corrupt the LAST record's payload so a clean prefix survives
    size = os.path.getsize(path)
    last = size - 8
    with open(path, "r+b") as f:
        f.seek(last)
        b = f.read(1)
        f.seek(last)
        f.write(bytes([b[0] ^ 0x10]))
    live_step = ds.session._step
    ds2, rep = DurableSession.restore(str(tmp_path / "dr"))
    assert rep.wal_tail_error is not None
    assert rep.wal_bytes_dropped > 0
    assert rep.records_replayed == 3
    assert ds2.session._step == live_step - 1
    ds3, rep3 = DurableSession.restore(str(tmp_path / "dr"))
    assert rep3.wal_tail_error is None      # restore truncated the tail
    _digests_equal(host_digest(ds3.session), host_digest(ds2.session))


# --------------------------------------------------------- timeline forks


def test_heal_truncates_forked_wal(tmp_path):
    """heal() that rolls back committed batches truncates the WAL (and
    drops newer checkpoints) so a later restore lands on the HEALED
    timeline, not the corrupt future it rolled away from."""
    ds = _stack(tmp_path, checkpoint_every=100, audit_cadence=100,
                replicated=False)
    rng = np.random.default_rng(5)
    ds.submit(_batch(ds.session, rng))
    FaultInjector(1).corrupt_base_csr(ds.session.store)
    for _ in range(2):      # commits on the corrupt base enter the WAL
        ds.submit(_batch(ds.session, rng))
    forked_step = ds.session._step
    assert forked_step == 3
    rep = ds.heal()
    assert rep.ok
    healed_step = ds.session._step
    assert healed_step < forked_step        # rolled past the corruption
    records, _, err = read_wal(wal_path(str(tmp_path / "dr"),
                                        ds.anchor_step))
    assert err is None
    assert all(r.step <= healed_step for r in records)
    pre = host_digest(ds.session)
    ds2, _ = DurableSession.restore(str(tmp_path / "dr"))
    _digests_equal(host_digest(ds2.session), pre)
    # the healed timeline keeps extending durably
    assert ds.submit(_batch(ds.session, rng)).committed
    pre = host_digest(ds.session)
    ds3, _ = DurableSession.restore(str(tmp_path / "dr"))
    _digests_equal(host_digest(ds3.session), pre)


def test_heal_below_every_checkpoint_reanchors(tmp_path):
    """A rollback below the oldest retained checkpoint re-anchors with a
    fresh one (restorability is never lost to a deep heal)."""
    g = planted_partition(300, 3, 10, 2, seed=0)
    sess = PartitionSession(g, SessionConfig(k=3, seed=0))
    rs = ResilientSession(sess, cfg=ResilientConfig(audit_cadence=100))
    rng = np.random.default_rng(6)
    rs.submit(_batch(sess, rng))            # snapshot predates durability
    ds = DurableSession(rs, DurableConfig(
        directory=str(tmp_path / "dr"), checkpoint_every=100,
    ))
    FaultInjector(2).corrupt_base_csr(sess.store)
    rep = ds.heal()                          # rolls below the anchor
    assert rep.ok
    assert ds.anchor_step == sess._step
    pre = host_digest(sess)
    ds2, rep2 = DurableSession.restore(str(tmp_path / "dr"))
    assert rep2.records_replayed == 0
    _digests_equal(host_digest(ds2.session), pre)


# ------------------------------------------------------------ housekeeping


def test_checkpoint_rotation_and_pruning(tmp_path):
    ds = _stack(tmp_path, checkpoint_every=2)
    rng = np.random.default_rng(7)
    for i in range(10):
        ds.submit(_batch(ds.session, rng), seq=i)
    d = str(tmp_path / "dr")
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                   if x.startswith("step_") and not x.endswith(".tmp"))
    assert len(steps) == ds.cfg.keep_checkpoints
    wals = sorted(x for x in os.listdir(d) if x.startswith("wal_"))
    # WALs are kept only for retained checkpoints
    assert wals == [f"wal_{s:08d}.log" for s in steps]


def test_quarantined_batches_never_enter_wal(tmp_path):
    """Only COMMITS are durably logged: a validation-rejected batch leaves
    the WAL untouched, so replay never sees poison."""
    ds = _stack(tmp_path, checkpoint_every=100, replicated=False)
    rng = np.random.default_rng(8)
    ds.submit(_batch(ds.session, rng))
    bad = GraphUpdate.add_edges([ds.session.n + 5], [0])
    tx = ds.submit(bad)
    assert tx.quarantined
    records, _, _ = read_wal(wal_path(str(tmp_path / "dr"),
                                      ds.anchor_step))
    assert len(records) == 1
