"""Continuous perf-regression gate (PR 10).

Unit tests for ``benchmarks/history.py`` (trajectory loading, min-of-window
baselines, signature-aware comparison) plus the end-to-end gate: a
``--smoke --check-regression`` run must pass against its own recorded
baseline and must *fail* (exit nonzero) when a synthetic 2.5x slowdown is
injected into the recorded latencies — the gate is exercised in both
directions inside the default suite.
"""

import json
import os
import shutil
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "benchmarks"))

import history  # noqa: E402  (benchmarks/history.py)


def _row(name, us, graph="ba-1024", n=1024, m=6138, k=4, **extra):
    d = dict(graph=graph, n=n, m=m, k=k)
    d.update(extra)
    return dict(name=name, us_per_call=us, derived=d)


def _bundle(us_steady, us_thr, **sig):
    return {
        "dynamic_hot": [
            _row("dynamic_hot_steady", us_steady, **sig),
            _row("dynamic_hot_throughput", us_thr, **sig),
        ],
        "_trajectory_delta": {"rows": []},   # metadata key: must be skipped
    }


# ------------------------------------------------------------------- units


def test_load_history_orders_by_pr_number(tmp_path):
    for pr, us in ((10, 30.0), (2, 10.0), (9, 20.0)):
        (tmp_path / f"BENCH_PR{pr}.json").write_text(
            json.dumps(_bundle(us, us)))
    (tmp_path / "BENCH_notes.json").write_text("{}")     # no PR number
    (tmp_path / "BENCH_PR3.json").write_text("not json")  # corrupt: skipped
    hist = history.load_history(str(tmp_path))
    assert [pr for pr, _, _ in hist] == [2, 9, 10]


def test_derive_baselines_min_of_recent_window(tmp_path):
    # series 100, 40, 80, 60 -> window of 3 sees (40, 80, 60) -> baseline 40
    for pr, us in ((1, 100.0), (2, 40.0), (3, 80.0), (4, 60.0)):
        (tmp_path / f"BENCH_PR{pr}.json").write_text(
            json.dumps(_bundle(us, us)))
    base = history.derive_baselines(history.load_history(str(tmp_path)))
    rec = base[("dynamic_hot", "dynamic_hot_steady")]
    assert rec["baseline_us"] == 40.0
    assert rec["window"] == 3
    assert [v for _, v in rec["series"]] == [100.0, 40.0, 80.0, 60.0]
    assert "graph=ba-1024" in rec["signature"]
    # the metadata table never becomes a baseline
    assert not any(t == "_trajectory_delta" for t, _ in base)


def test_check_regression_statuses(tmp_path):
    (tmp_path / "BENCH_PR1.json").write_text(json.dumps(_bundle(100.0, 100.0)))
    base = history.derive_baselines(history.load_history(str(tmp_path)))
    results = {
        "dynamic_hot": [
            _row("dynamic_hot_steady", 120.0),           # 1.2x: ok
            _row("dynamic_hot_throughput", 300.0),       # 3.0x: regression
            _row("brand_new_row", 50.0),                 # no baseline: new
        ],
        "_trajectory_delta": {"rows": []},               # skipped
    }
    rep = history.check_regression(results, base, tolerance=1.75)
    by = {r["name"]: r for r in rep}
    assert by["dynamic_hot_steady"]["status"] == "ok"
    assert by["dynamic_hot_throughput"]["status"] == "regression"
    assert by["dynamic_hot_throughput"]["ratio"] == pytest.approx(3.0)
    assert by["brand_new_row"]["status"] == "new"
    # improvement direction
    rep = history.check_regression(
        {"dynamic_hot": [_row("dynamic_hot_steady", 20.0)]}, base, 1.75)
    assert rep[0]["status"] == "improved"


def test_signature_mismatch_is_incomparable_not_gated(tmp_path):
    """A --smoke run (ba-1024) must never gate against the recorded
    full-size trajectory (ba-16384) — measured, reported, not compared."""
    (tmp_path / "BENCH_PR1.json").write_text(json.dumps(
        _bundle(100.0, 100.0, graph="ba-16384", n=16384, m=98148)))
    base = history.derive_baselines(history.load_history(str(tmp_path)))
    rep = history.check_regression(
        {"dynamic_hot": [_row("dynamic_hot_steady", 10_000.0)]}, base, 1.75)
    assert rep[0]["status"] == "incomparable"
    assert rep[0]["ratio"] is None
    txt = history.format_report(rep)
    assert "gate passed" in txt and "GATE FAILED" not in txt


def test_format_report_flags_failures(tmp_path):
    (tmp_path / "BENCH_PR1.json").write_text(json.dumps(_bundle(100.0, 100.0)))
    base = history.derive_baselines(history.load_history(str(tmp_path)))
    rep = history.check_regression(
        {"dynamic_hot": [_row("dynamic_hot_steady", 500.0)]}, base, 1.75)
    txt = history.format_report(rep, 1.75)
    assert "GATE FAILED" in txt
    assert "regression=1" in txt


# ------------------------------------------------------------- end to end


def _run_bench(extra_args, tmp, env_extra=None, json_name=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    if env_extra:
        env.update(env_extra)
    cmd = [sys.executable, os.path.join(ROOT, "benchmarks", "run.py"),
           "dynamic_hot", "--smoke"]
    if json_name:
        cmd += ["--json", os.path.join(tmp, json_name)]
    cmd += extra_args
    return subprocess.run(cmd, capture_output=True, text=True, timeout=300,
                          env=env, cwd=ROOT)


def test_gate_end_to_end_passes_then_catches_injected_slowdown(tmp_path):
    """Three smoke runs: (1) record a baseline bundle, (2) gate a fresh run
    against it — must pass and embed ``_trajectory_delta``, (3) gate a much
    slower run — must exit nonzero with the slow rows flagged
    ``regression``.

    Run-to-run CPU noise on the tiny smoke graph can exceed the 1.75x
    tolerance on its own (min of 2 batches, shared machine), so the
    injection hook sets the *spread* deterministically instead of trusting
    the clock: the baseline records with a 3x injected slowdown (honest
    run vs inflated baseline -> ratio ~1/3, "improved", never gated) and
    the failing run injects 10x (ratio ~10/3 vs that baseline — a >1.75x
    regression unless the machine sped up ~2x mid-test)."""
    tmp = str(tmp_path)
    hist_dir = os.path.join(tmp, "hist")
    os.makedirs(hist_dir)

    # (1) baseline recording (inflated 3x via the injection hook)
    out = _run_bench([], tmp, json_name="base.json",
                     env_extra={"REPRO_BENCH_INJECT_SLOWDOWN": "3.0"})
    assert out.returncode == 0, out.stderr[-2000:]
    shutil.copy(os.path.join(tmp, "base.json"),
                os.path.join(hist_dir, "BENCH_PR1.json"))

    # (2) honest re-run gates clean (smoke-vs-smoke signatures match)
    out = _run_bench(["--check-regression", "--history", hist_dir], tmp,
                     json_name="pass.json")
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "trajectory delta" in out.stdout
    assert "gate passed" in out.stdout
    with open(os.path.join(tmp, "pass.json")) as f:
        bundle = json.load(f)
    delta = bundle["_trajectory_delta"]
    assert delta["rows"], "gate embedded no trajectory delta rows"
    assert {"BENCH_PR1.json"} == set(delta["history_bundles"])
    assert all(r["status"] != "regression" for r in delta["rows"])
    assert any(r["status"] in ("ok", "improved") for r in delta["rows"])

    # (3) a slowdown past the tolerance trips the gate
    out = _run_bench(["--check-regression", "--history", hist_dir], tmp,
                     env_extra={"REPRO_BENCH_INJECT_SLOWDOWN": "10.0"})
    assert out.returncode != 0, "gate did not fail on the slowdown"
    assert "GATE FAILED" in out.stdout
    flagged = [ln for ln in out.stdout.splitlines()
               if ln.rstrip().endswith("regression")]
    assert any("dynamic_hot_steady" in ln for ln in flagged), flagged
