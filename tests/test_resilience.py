"""Fault-tolerance subsystem (ISSUE 6): snapshot/rollback, invariant
auditing, fault injection, and transactional serving.

The contract under test: every injected fault class is either REJECTED
before any state moves (validation faults — session and store stay
bit-identical) or DETECTED by the invariant auditor and rolled back to
bit-identical pre-fault state, with the session still serving afterwards.
Snapshots are parity-tested against a deep-copy numpy oracle; replaying a
stream from a restored version reproduces the same labels bit for bit;
audit kernels hold the compile-per-bucket discipline
(``audit_compiles == audit_bucket_count``); and the escalation satellite
(``partition()`` consuming the resident ``GraphDev``) is pinned
bit-identical to the host path.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.multilevel import PartitionerConfig, partition
from repro.dynamic import (
    GraphUpdate,
    PartitionSession,
    SessionConfig,
    UpdateValidationError,
)
from repro.deploy import ShardDeployment
from repro.graph import barabasi_albert, planted_partition, to_device_csr
from repro.resilience import (
    FaultInjector,
    InvariantAuditor,
    ResilientConfig,
    ResilientSession,
    SnapshotManager,
    host_digest,
)
from repro.resilience.faults import InjectedFailure

pytestmark = pytest.mark.resilience


def _session(n=600, k=4, seed=0, **cfg_kw):
    g = planted_partition(n, k, 12, 2, seed=seed)
    return PartitionSession(g, SessionConfig(k=k, seed=seed, **cfg_kw))


def _batch(sess, rng, size=24):
    u = rng.integers(0, sess.n, size)
    v = (u + 1 + rng.integers(0, sess.n - 1, size)) % sess.n
    return GraphUpdate.add_edges(u, v)


def _digests_equal(a, b):
    assert a.keys() == b.keys()
    for key in a:
        np.testing.assert_array_equal(a[key], b[key], err_msg=key)


# ----------------------------------------------------------------- snapshots


def test_snapshot_rollback_bit_identical_to_numpy_oracle():
    """Rollback restores every served array bit-for-bit (labels, node
    weights, base CSR, overlay, step counter) — compared against deep
    host copies, so no reference aliasing can fake the equality."""
    sess = _session()
    rng = np.random.default_rng(1)
    mgr = SnapshotManager(sess)
    sess.update(_batch(sess, rng))
    oracle = host_digest(sess)
    v = mgr.take()
    for _ in range(3):
        sess.update(_batch(sess, rng))
    assert not np.array_equal(host_digest(sess)["labels"], oracle["labels"]) \
        or sess._step != int(oracle["step"])
    mgr.rollback(v)
    _digests_equal(host_digest(sess), oracle)


def test_snapshot_restore_replays_bit_identically():
    """A restored session replays the same stream to the same labels: the
    step counter (which seeds repair) is part of the snapshot."""
    sess = _session()
    rng = np.random.default_rng(2)
    sess.update(_batch(sess, rng))
    mgr = SnapshotManager(sess)
    v = mgr.take()
    stream = [_batch(sess, np.random.default_rng(100 + i)) for i in range(4)]
    for b in stream:
        sess.update(b)
    first = sess.labels_np().copy()
    first_traj = [(r.step, r.cut, r.feasible) for r in sess.trajectory]
    mgr.rollback(v)
    for b in stream:
        sess.update(b)
    np.testing.assert_array_equal(sess.labels_np(), first)
    assert [(r.step, r.cut, r.feasible) for r in sess.trajectory] == first_traj


def test_snapshot_ring_retention_and_fork():
    sess = _session(n=200, k=2)
    mgr = SnapshotManager(sess, keep=3)
    versions = [mgr.take() for _ in range(5)]
    assert mgr.versions == versions[-3:]
    with pytest.raises(KeyError):
        mgr.get(versions[0])
    mgr.rollback(versions[-2])
    assert mgr.versions == versions[-3:-1]  # newer fork discarded


# ------------------------------------------------------------ atomic reject


@pytest.mark.parametrize("bad,reason", [
    (lambda n: GraphUpdate(add_u=np.array([0]), add_v=np.array([10**9]),
                           add_w=np.array([1])), "endpoint_out_of_range"),
    (lambda n: GraphUpdate(add_u=np.array([5]), add_v=np.array([5]),
                           add_w=np.array([1])), "self_loop"),
    (lambda n: GraphUpdate(add_u=np.array([0]), add_v=np.array([1]),
                           add_w=np.array([0.5])), "non_integral_weight"),
    (lambda n: GraphUpdate(add_u=np.array([0]), add_v=np.array([1]),
                           add_w=np.array([2**24])), "weight_overflow"),
    (lambda n: GraphUpdate(add_u=np.array([0, 1]), add_v=np.array([1]),
                           add_w=np.array([1])), "shape_mismatch"),
    (lambda n: GraphUpdate(rem_u=np.array([0]), rem_v=np.array([-3]),
                           rem_w=np.array([1])), "endpoint_out_of_range"),
])
def test_session_rejection_is_fully_atomic(bad, reason):
    """A batch failing validation leaves session AND store bit-identical —
    including the step counter that seeds every later repair, so the
    subsequent stream is unaffected by the rejected batch."""
    sess = _session(n=300, k=2)
    rng = np.random.default_rng(3)
    sess.update(_batch(sess, rng))
    before = host_digest(sess)
    traj_len = len(sess.trajectory)
    with pytest.raises(UpdateValidationError) as ei:
        sess.update(bad(sess.n))
    assert ei.value.reason == reason
    _digests_equal(host_digest(sess), before)
    assert len(sess.trajectory) == traj_len
    assert sess.store.overlay_len == 0
    # still serving: the next good batch applies normally
    res = sess.update(_batch(sess, rng))
    assert res.feasible


# --------------------------------------------------------------- audit: clean


def test_audit_passes_on_healthy_session_and_deployment():
    sess = _session()
    dep = ShardDeployment(sess, halo=1)
    aud = InvariantAuditor(sess, deployment=dep, cadence=1)
    rng = np.random.default_rng(4)
    for _ in range(3):
        u = rng.integers(0, sess.n, 24)
        v = (u + 1 + rng.integers(0, sess.n - 1, 24)) % sess.n
        dep.update(GraphUpdate.add_edges(u, v))
        rep = aud.audit()
        assert rep.ok, rep.failures
    assert any(c.startswith("shards:") for c in rep.checked)


def test_audit_compiles_bounded_by_buckets():
    """audit_compiles == audit_bucket_count across a multi-batch stream —
    the jit-cache discipline every kernel family holds."""
    sess = _session()
    aud = InvariantAuditor(sess, cadence=1)
    rng = np.random.default_rng(5)
    for _ in range(6):
        sess.update(_batch(sess, rng))
        assert aud.audit().ok
    st = sess.stats()
    assert st["audit_calls"] > 0
    assert st["audit_compiles"] == st["audit_bucket_count"]
    assert st["audit_calls"] > st["audit_compiles"]  # cache actually reused


def test_audit_cadence_gating():
    sess = _session(n=200, k=2)
    aud = InvariantAuditor(sess, cadence=3)
    ran = [aud.maybe_audit(step) for step in range(1, 10)]
    assert [r is not None for r in ran] == [
        s % 3 == 0 for s in range(1, 10)
    ]


# ----------------------------------------------------- audit: fault detection


def test_audit_detects_corrupt_labels_in_range():
    """A label moved to a wrong-but-valid block changes the cut: caught by
    the stored-vs-recomputed comparison, healed by rollback."""
    sess = _session()
    mgr = SnapshotManager(sess)
    rng = np.random.default_rng(6)
    sess.update(_batch(sess, rng))
    oracle = host_digest(sess)
    v = mgr.take()
    inj = FaultInjector(seed=1)
    inj.corrupt_labels(sess, count=3, out_of_range=False)
    rep = InvariantAuditor(sess, cadence=1).audit()
    assert not rep.ok
    assert any("cut" in f or "feasible" in f for f in rep.failures)
    mgr.rollback(v)
    _digests_equal(host_digest(sess), oracle)
    assert InvariantAuditor(sess, cadence=1).audit().ok


def test_audit_detects_corrupt_labels_out_of_range():
    sess = _session()
    inj = FaultInjector(seed=2)
    inj.corrupt_labels(sess, count=2, out_of_range=True)
    rep = InvariantAuditor(sess, cadence=1).audit()
    assert not rep.ok
    assert "partition:labels_in_range" in rep.failures


def test_audit_detects_overlay_bitflip():
    """A bit-flipped overlay weight merges into an asymmetric CSR — caught
    by the wrap-sum symmetry checksum (or the exactness/cut checks)."""
    g = barabasi_albert(512, 4, seed=7)
    sess = PartitionSession(g, SessionConfig(k=2, seed=0))
    mgr = SnapshotManager(sess)
    rng = np.random.default_rng(7)
    sess.update(_batch(sess, rng))
    oracle = host_digest(sess)
    v = mgr.take()
    # stage a pending overlay, then flip one of its weights
    u = rng.integers(0, sess.n, 16)
    vv = (u + 1) % sess.n
    sess.store._ou.append(u.astype(np.int32))
    sess.store._ov.append(vv.astype(np.int32))
    sess.store._ow.append(np.ones(16, np.float32))
    sess.store._olen += 16
    inj = FaultInjector(seed=3)
    assert inj.bitflip_overlay(sess.store) is not None
    rep = InvariantAuditor(sess, cadence=1).audit()
    assert not rep.ok
    mgr.rollback(v)
    _digests_equal(host_digest(sess), oracle)


def test_audit_detects_corrupt_base_csr():
    sess = _session()
    mgr = SnapshotManager(sess)
    oracle = host_digest(sess)
    v = mgr.take()
    inj = FaultInjector(seed=4)
    inj.corrupt_base_csr(sess.store, mode="weight")
    rep = InvariantAuditor(sess, cadence=1).audit()
    assert not rep.ok
    assert any("symmetry" in f or "cut" in f for f in rep.failures)
    mgr.rollback(v)
    _digests_equal(host_digest(sess), oracle)
    inj.corrupt_base_csr(sess.store, mode="endpoint")
    rep = InvariantAuditor(sess, cadence=1).audit()
    assert not rep.ok
    mgr.rollback(v)
    _digests_equal(host_digest(sess), oracle)


def test_audit_detects_corrupt_shard_and_recovery_restores_parity():
    sess = _session()
    dep = ShardDeployment(sess, halo=1)
    aud = InvariantAuditor(sess, deployment=dep, cadence=1)
    assert aud.audit().ok
    inj = FaultInjector(seed=5)
    f = inj.corrupt_shard(dep)
    b = int(f.detail.split()[1])
    rep = aud.audit()
    assert not rep.ok
    assert "shards:reassembly_checksum" in rep.failures
    dep.recover_block(b)
    assert aud.audit().ok
    assert dep.shard_recoveries == 1


def test_lost_shard_detected_and_reextracted():
    sess = _session()
    dep = ShardDeployment(sess, halo=1)
    aud = InvariantAuditor(sess, deployment=dep, cadence=1)
    inj = FaultInjector(seed=6)
    f = inj.lose_shard(dep)
    b = int(f.detail.split()[1])
    rep = aud.audit()
    assert not rep.ok and "shards:missing_shard" in rep.failures
    dep.recover_block(b)
    rep = aud.audit()
    assert rep.ok, rep.failures


# ----------------------------------------------------- transactional serving


def test_transactional_quarantine_keeps_serving():
    """Malformed batches are quarantined with structured reasons; the
    session state is untouched and good batches keep committing."""
    sess = _session()
    rs = ResilientSession(sess)
    rng = np.random.default_rng(8)
    tx = rs.submit(_batch(sess, rng))
    assert tx.committed
    before = host_digest(sess)
    bad = GraphUpdate(add_u=np.array([1]), add_v=np.array([1]),
                      add_w=np.array([1]))
    tx = rs.submit(bad)
    assert tx.quarantined and not tx.committed
    assert rs.quarantine[-1].reason == "self_loop"
    _digests_equal(host_digest(sess), before)
    tx = rs.submit(_batch(sess, rng))
    assert tx.committed
    assert rs.stats()["tx_quarantined"] == 1


def test_transactional_rollback_on_midflight_corruption():
    """Corruption landing between apply and audit (the classic torn write)
    is detected, rolled back bit-identically, and the clean retry
    commits."""
    sess = _session()
    rs = ResilientSession(sess, cfg=ResilientConfig(audit_cadence=1))
    rng = np.random.default_rng(9)
    rs.submit(_batch(sess, rng))
    inj = FaultInjector(seed=7)
    orig_update = sess.update
    calls = {"n": 0}

    def corrupting_update(upd):
        res = orig_update(upd)
        if calls["n"] == 0:  # corrupt only the first attempt
            calls["n"] += 1
            inj.corrupt_labels(sess, count=2, out_of_range=True)
        return res

    sess.update = corrupting_update
    try:
        tx = rs.submit(_batch(sess, rng))
    finally:
        sess.update = orig_update
    assert tx.committed and tx.rolled_back and tx.retries == 1
    assert rs.rollbacks == 1
    assert rs.auditor.audit().ok


def test_transactional_heal_walks_back_to_clean_version():
    sess = _session()
    rs = ResilientSession(sess, cfg=ResilientConfig(audit_cadence=1))
    rng = np.random.default_rng(10)
    for _ in range(3):
        assert rs.submit(_batch(sess, rng)).committed
    good = host_digest(sess)
    FaultInjector(seed=8).corrupt_labels(sess, count=4)
    rep = rs.heal()
    assert rep.ok
    # healed to the most recent clean version: the pre-corruption state
    # is the last transaction's committed state... which the newest
    # snapshot precedes by one batch — replay parity still holds
    assert rs.auditor.audit().ok
    assert rs.rollbacks >= 1
    lab = sess.labels_np()
    assert lab.min() >= 0 and lab.max() < sess.k


def test_sequence_numbers_drop_dup_reorder():
    """A seeded mangled stream: duplicates dropped, swaps parked+drained in
    order, drops declared lost past the reorder window — and the final
    labels equal an un-mangled replay of the surviving batches in order."""
    sess = _session()
    rs = ResilientSession(sess, cfg=ResilientConfig(reorder_window=2))
    batches = [_batch(sess, np.random.default_rng(200 + i)) for i in range(8)]
    inj = FaultInjector(seed=11)
    stream = inj.mangle_stream(batches, drop=0.2, dup=0.2, swap=0.3)
    kinds = {f.kind for f in inj.log}
    assert {"drop_batch", "duplicate_batch", "reorder_batches"} <= kinds
    applied = []
    for seq, b in stream:
        tx = rs.submit(b, seq=seq)
        for t in [tx] + tx.followups:
            if t.committed:
                applied.append(t.seq)
    assert applied == sorted(applied)            # commit order == seq order
    assert len(set(applied)) == len(applied)     # no duplicate commits
    assert rs.duplicates_dropped >= 1
    # parity: replay exactly the committed subsequence on a fresh session
    ref = _session()
    for s in applied:
        ref.update(batches[s])
    np.testing.assert_array_equal(sess.labels_np(), ref.labels_np())


def test_escalation_watchdog_enters_degraded_mode_and_recovers():
    """Consecutive escalations past the bound flip the session into
    degraded mode: further guard trips serve stale labels (flagged), and
    ``recover()`` re-enables escalation."""
    sess = _session(escalate_cut_ratio=1.0001)   # hair-trigger guard
    rs = ResilientSession(
        sess, cfg=ResilientConfig(max_consecutive_escalations=2)
    )
    rng = np.random.default_rng(12)
    results = [rs.submit(_batch(sess, rng, size=120)) for _ in range(5)]
    assert rs.degraded
    assert sess.suppress_escalation
    stale = [t.result.stale for t in results if t.committed and t.result]
    assert any(stale)
    assert rs.stats()["degraded"]
    assert sess.suppressed_escalations >= 1
    rep = rs.recover()
    assert not rs.degraded and not sess.suppress_escalation
    assert rep.ok


def test_escalation_crash_degrades_then_retry_commits():
    sess = _session(escalate_cut_ratio=1.0001)
    rs = ResilientSession(sess, cfg=ResilientConfig(max_retries=2))
    rng = np.random.default_rng(13)
    inj = FaultInjector(seed=12)
    inj.fail_next_escalation(sess)
    tx = rs.submit(_batch(sess, rng, size=120))
    # first attempt crashed in _escalate -> rollback -> degraded retry
    # commits WITHOUT escalating (suppressed), serving stale labels
    assert tx.committed and tx.rolled_back and tx.retries == 1
    assert rs.degraded
    assert tx.result.stale and not tx.result.escalated


def test_failed_migration_serves_stale_then_catches_up():
    sess = _session()
    dep = ShardDeployment(sess, halo=1)
    rs = ResilientSession(sess, deployment=dep)
    rng = np.random.default_rng(14)
    inj = FaultInjector(seed=13)
    inj.fail_next_extract(dep)
    tx = rs.submit(_batch(sess, rng))
    assert tx.committed and tx.migration_failed
    assert dep.stale and dep.failed_migrations == 1
    # next commit's migration catches the shard set up
    tx = rs.submit(_batch(sess, rng))
    assert tx.committed and not tx.migration_failed
    assert not dep.stale
    rep = rs.auditor.audit()
    assert rep.ok, rep.failures


def test_full_seeded_fault_suite_every_fault_recovered():
    """The acceptance sweep: inject every state-fault class against one
    serving session; each is detected by audit and healed back to a
    bit-identical clean state, with the session committing afterwards."""
    sess = _session()
    dep = ShardDeployment(sess, halo=1)
    rs = ResilientSession(
        sess, deployment=dep, cfg=ResilientConfig(audit_cadence=1)
    )
    rng = np.random.default_rng(15)
    inj = FaultInjector(seed=99)
    assert rs.submit(_batch(sess, rng)).committed

    def hit(inject, recover=None):
        inject()
        rep = rs.auditor.audit()
        assert not rep.ok, f"fault not detected: {inj.log[-1].kind}"
        if recover is None:
            assert rs.heal().ok     # heal resyncs the shard set itself
        else:
            recover()
            assert rs.auditor.audit().ok
        tx = rs.submit(_batch(sess, rng))
        assert tx.committed, f"not serving after {inj.log[-1].kind}"

    hit(lambda: inj.corrupt_labels(sess, count=2, out_of_range=False))
    hit(lambda: inj.corrupt_labels(sess, count=2, out_of_range=True))
    hit(lambda: inj.corrupt_base_csr(sess.store, mode="weight"))
    f_shard = {}
    hit(lambda: f_shard.update(b=int(inj.corrupt_shard(dep).detail.split()[1])),
        recover=lambda: dep.recover_block(f_shard["b"]))
    hit(lambda: f_shard.update(b=int(inj.lose_shard(dep).detail.split()[1])),
        recover=lambda: dep.recover_block(f_shard["b"]))
    assert len({f.kind for f in inj.log}) >= 4


# ------------------------------------------------- heal() while degraded


def test_heal_while_degraded_with_deployed_shards_exits_on_clean_audit():
    """ISSUE 7 satellite: heal() invoked while the session is in degraded
    mode WITH a deployed shard set — corruption is rolled back, lost
    shards are re-synced, and degraded mode clears precisely because the
    final audit passed."""
    sess = _session(escalate_cut_ratio=1.0001)   # hair-trigger guard
    dep = ShardDeployment(sess, halo=1)
    rs = ResilientSession(
        sess, deployment=dep,
        cfg=ResilientConfig(max_consecutive_escalations=2,
                            audit_cadence=100),
    )
    rng = np.random.default_rng(20)
    for _ in range(5):
        rs.submit(_batch(sess, rng, size=120))
    assert rs.degraded and sess.suppress_escalation
    inj = FaultInjector(seed=21)
    inj.corrupt_labels(sess)
    inj.lose_shard(dep, block=0)
    rep = rs.heal()
    assert rep.ok, rep.failures
    assert not rs.degraded and not sess.suppress_escalation
    assert not dep.stale
    assert all(s is not None for s in dep.shards)
    assert "shards:reassembly_checksum" in rep.checked
    # the healed session keeps serving transactionally
    assert rs.submit(_batch(sess, rng)).committed


def test_heal_while_degraded_catches_up_stale_shards():
    """A stale shard set (failed migration) rode into degraded mode: heal
    must catch the set up and PROVE shard health — the final audit checks
    content, it doesn't skip-as-stale."""
    sess = _session(escalate_cut_ratio=1.0001)
    dep = ShardDeployment(sess, halo=1)
    rs = ResilientSession(
        sess, deployment=dep,
        cfg=ResilientConfig(max_consecutive_escalations=2,
                            audit_cadence=100),
    )
    rng = np.random.default_rng(22)
    inj = FaultInjector(seed=23)
    for _ in range(5):
        rs.submit(_batch(sess, rng, size=120))
    assert rs.degraded
    inj.fail_next_extract(dep)
    tx = rs.submit(_batch(sess, rng))
    assert tx.committed and tx.migration_failed and dep.stale
    rep = rs.heal()
    assert rep.ok, rep.failures
    assert not dep.stale
    assert not rs.degraded
    assert "shards:reassembly_checksum" in rep.checked
    assert "shards:skipped_stale" not in rep.checked


def test_heal_unhealable_corruption_stays_degraded():
    """The negative half of the contract: with no clean version to roll
    back to, heal reports failure and degraded mode (and its escalation
    suppression) must NOT clear — a dirty bill of health never re-arms
    escalation."""
    sess = _session()
    dep = ShardDeployment(sess, halo=1)
    rs = ResilientSession(
        sess, deployment=dep, cfg=ResilientConfig(audit_cadence=100)
    )
    rs.degraded = True
    sess.suppress_escalation = True
    FaultInjector(seed=24).corrupt_base_csr(sess.store)
    rep = rs.heal()                 # empty ring: nothing rolls it back
    assert not rep.ok
    assert rs.degraded and sess.suppress_escalation


# ----------------------------------------------------- escalation satellite


def test_partition_accepts_graphdev_bit_identical():
    """partition() on the resident GraphDev == partition() on the host
    graph, bit for bit — the escalation path's correctness pin."""
    g = barabasi_albert(3000, 4, seed=21)
    cfg_h = PartitionerConfig(k=4, preset="fast", seed=5, numpy_below=256)
    cfg_d = PartitionerConfig(k=4, preset="fast", seed=5, numpy_below=256)
    rep_h = partition(g, cfg_h)
    rep_d = partition(to_device_csr(g), cfg_d)
    np.testing.assert_array_equal(rep_h.labels, rep_d.labels)
    assert rep_h.cut == rep_d.cut


def test_escalation_counts_saved_h2d_bytes():
    sess = _session(escalate_cut_ratio=1.0001)
    rng = np.random.default_rng(22)
    sess.update(_batch(sess, rng, size=150))
    assert sess.escalations >= 1
    st = sess.stats()
    assert st["escalate_h2d_saved"] > 0
    g = sess.store.base
    per = g.indices.shape[0] * 12 + g.nw.shape[0] * 4
    assert st["escalate_h2d_saved"] == sess.escalations * per
