"""End-to-end multilevel partitioner vs the paper's claims (scaled down)."""

import numpy as np
import pytest

from repro.core import (
    PartitionerConfig, hash_partition, matching_multilevel, partition,
)
from repro.core.metrics import cut_np, is_feasible
from repro.graph import barabasi_albert, mesh2d, planted_partition


@pytest.fixture(scope="module")
def social():
    return barabasi_albert(8192, 6, seed=3)


def test_fast_feasible_and_beats_hash(social):
    g = social
    rep = partition(g, PartitionerConfig(k=2, preset="fast", coarsest_factor=100,
                                         seed=0))
    assert rep.feasible
    assert rep.imbalance <= 0.031
    assert rep.cut < cut_np(g, hash_partition(g.n, 2)) * 0.85


def test_cluster_coarsening_shrinks_social_graphs(social):
    """The paper's central claim: cluster contraction shrinks complex
    networks drastically where matching cannot (Table II discussion)."""
    rep = partition(social, PartitionerConfig(k=2, preset="fast",
                                              coarsest_factor=100, seed=0))
    mb = matching_multilevel(social, 2, seed=0)
    assert rep.shrink_first < 0.35
    assert rep.shrink_first < mb.shrink_first / 2


def test_vcycles_never_worsen_final(social):
    rep = partition(social, PartitionerConfig(k=2, preset="fast",
                                              coarsest_factor=100, seed=0))
    assert rep.cut == min(rep.cycle_cuts)


def test_k32(social):
    rep = partition(social, PartitionerConfig(k=32, preset="minimal",
                                              coarsest_factor=20, seed=0))
    assert rep.feasible
    assert rep.cut < cut_np(social, hash_partition(social.n, 32))


def test_mesh_type_graph():
    g = mesh2d(48)
    rep = partition(g, PartitionerConfig(k=2, preset="fast", coarsest_factor=50,
                                         f_mesh=64, seed=0))
    assert rep.feasible
    # a 48x48 triangulated grid has a ~2*48-edge bisection; stay in its orbit
    assert rep.cut < 6 * 48


def test_strong_preset_beats_fast():
    g = planted_partition(4096, 8, p_in=0.02, p_out=0.0005, seed=2)
    fast = partition(g, PartitionerConfig(k=2, preset="fast", coarsest_factor=100,
                                          seed=0))
    strong = partition(g, PartitionerConfig(k=2, preset="strong",
                                            coarsest_factor=100, seed=0))
    assert strong.cut <= fast.cut
