"""Partitioner-guided sharding (the paper's technique applied to the LM)."""

import numpy as np

from repro.core.autoshard import (
    coactivation_graph, crossgroup_traffic, expert_placement, pipeline_stages,
)


def _team_router(E=16, k=4, T=4000, teams=4, seed=0):
    rng = np.random.default_rng(seed)
    team_of = rng.permutation(E).reshape(teams, E // teams)
    topi = np.zeros((T, k), dtype=np.int64)
    for t in range(T):
        team = team_of[rng.integers(teams)]
        picks = rng.choice(team, size=min(k, 3), replace=False)
        rest = rng.integers(0, E, k - picks.size)
        topi[t] = np.concatenate([picks, rest])
    return topi


def test_expert_placement_beats_contiguous():
    E, groups = 16, 4
    topi = _team_router(E=E)
    ours = expert_placement(topi, E, groups, seed=0)
    contiguous = np.arange(E) // (E // groups)
    assert crossgroup_traffic(topi, ours) < crossgroup_traffic(topi, contiguous)
    # balanced: every EP group gets the same number of experts
    assert np.bincount(ours, minlength=groups).max() <= E // groups + 1


def test_coactivation_graph_valid():
    topi = _team_router()
    g = coactivation_graph(topi, 16)
    assert g.n == 16 and g.m > 0


def test_pipeline_stages_balanced_contiguousish():
    L, stages = 48, 4
    pb = np.ones(L) * 100.0
    ab = np.ones(L - 1) * 10.0
    lab = pipeline_stages(pb, ab, stages, seed=0)
    sizes = np.bincount(lab, minlength=stages)
    assert sizes.max() - sizes.min() <= L // stages  # balanced
    # chain cut = number of stage boundaries; optimum is stages-1
    cuts = int((lab[1:] != lab[:-1]).sum())
    assert cuts <= 2 * (stages - 1)
