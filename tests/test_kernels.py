"""Pallas lp_score kernel: shape/dtype sweeps against the pure-jnp oracle
(interpret mode executes the kernel body on CPU)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.metrics import lmax, cut_np
from repro.graph import ell_pack, mesh2d, rmat, star
from repro.kernels.lp_score import (
    dense_eligibility, dense_round_device, dense_round_device_batched,
    lp_refine_dense_round, node_scores, node_scores_ref, pad_k,
)


@pytest.mark.parametrize("maker,k", [
    (lambda: rmat(10, 8, seed=1), 2),
    (lambda: rmat(10, 8, seed=2), 17),
    (lambda: mesh2d(24), 8),
    (lambda: star(700), 3),          # hub degree >> ELL width: row splitting
])
def test_kernel_matches_oracle(maker, k):
    g = maker()
    rng = np.random.default_rng(0)
    labels = rng.integers(0, k, g.n).astype(np.int32)
    S = node_scores(g, labels, k, use_pallas=True, interpret=True)
    S_ref = node_scores_ref(
        jnp.asarray(g.indptr), jnp.asarray(g.indices), jnp.asarray(g.ew),
        jnp.asarray(labels), k,
    )
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("width,tile", [(64, 128), (128, 256), (32, 256)])
def test_kernel_layout_sweep(width, tile):
    g = rmat(9, 8, seed=3)
    k = 5
    rng = np.random.default_rng(1)
    labels = rng.integers(0, k, g.n).astype(np.int32)
    ell = ell_pack(g, width=width, tile_rows=tile)
    S = node_scores(g, labels, k, ell=ell, use_pallas=True, interpret=True)
    S_ref = node_scores_ref(
        jnp.asarray(g.indptr), jnp.asarray(g.indices), jnp.asarray(g.ew),
        jnp.asarray(labels), k,
    )
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), rtol=1e-5,
                               atol=1e-5)


def test_weighted_edges():
    g = rmat(9, 8, seed=4)
    g = type(g)(indptr=g.indptr, indices=g.indices,
                ew=(np.arange(g.m) % 7 + 1).astype(np.float32), nw=g.nw)
    k = 4
    labels = (np.arange(g.n) % k).astype(np.int32)
    S = node_scores(g, labels, k, use_pallas=True, interpret=True)
    S_ref = node_scores_ref(
        jnp.asarray(g.indptr), jnp.asarray(g.indices), jnp.asarray(g.ew),
        jnp.asarray(labels), k,
    )
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), rtol=1e-5)


def test_dense_refine_round_converges():
    side = 32
    g = mesh2d(side)
    truth = (np.arange(g.n) // side >= side // 2).astype(np.int32)
    rng = np.random.default_rng(2)
    lab = truth.copy()
    lab[rng.random(g.n) < 0.15] ^= 1
    L = lmax(g.n, 2, 0.03)
    before = cut_np(g, lab)
    for r in range(8):
        lab = lp_refine_dense_round(g, lab, 2, L, seed=r)
    assert cut_np(g, lab) < before / 3


def test_pad_k():
    assert pad_k(2) == 128 and pad_k(128) == 128 and pad_k(129) == 256


def test_dense_eligibility_matches_sclap_numpy():
    """Regression for the operator-precedence hazard in the dense round's
    eligibility (`fits | own & ~overloaded` parsed as
    `fits | (own & ~overloaded)`): pin the vectorized rule to the sequential
    oracle's (sclap_numpy) branch structure, node by node, block by block."""
    g = rmat(9, 8, seed=5)
    k = 4
    rng = np.random.default_rng(3)
    # skewed labels so that at least one block is overloaded under U
    lab = np.where(rng.random(g.n) < 0.55, 0, rng.integers(0, k, g.n))
    lab = lab.astype(np.int32)
    bw = np.bincount(lab, weights=g.nw, minlength=k)[:k]
    U = float(np.sort(bw)[-2] + 1.0)  # biggest block overloaded, rest fit-ish
    assert (bw > U).any() and (bw <= U).any()

    S = np.asarray(node_scores(g, lab, k, use_pallas=False))
    got = np.asarray(
        dense_eligibility(
            jnp.asarray(S), jnp.asarray(lab),
            jnp.asarray(bw, jnp.float32), jnp.asarray(g.nw), jnp.float32(U), k,
        )
    )

    # oracle: exactly sclap_numpy's refine-mode candidate rule
    want = np.zeros((g.n, k), dtype=bool)
    for v in range(g.n):
        nbr = g.indices[g.indptr[v]: g.indptr[v + 1]]
        cand = np.unique(lab[nbr])  # only connected blocks are candidates
        conn = S[v, cand]
        fits = bw[cand] + g.nw[v] <= U
        own = lab[v]
        if bw[own] > U:
            elig = fits & (cand != own)
        else:
            elig = (conn > 0) & (fits | (cand == own))
        want[v, cand[elig]] = True
    np.testing.assert_array_equal(got, want)


def test_dense_round_batched_matches_per_individual():
    """Population-batched dense round: every row of the vmapped batch must be
    bit-identical to a per-individual dense_round_device call with the same
    seed (the batched evolutionary engine's dense-refinement building block)."""
    g = rmat(9, 8, seed=7)
    k, B = 4, 5
    ell = ell_pack(g)
    rng = np.random.default_rng(1)
    nb = g.n + 1
    labs = np.full((B, nb), k, np.int32)
    labs[:, : g.n] = rng.integers(0, k, (B, g.n))
    nw = np.concatenate([g.nw.astype(np.float32), np.zeros(1, np.float32)])
    U = np.float32(lmax(g.n, k, 0.05))
    seeds = np.arange(17, 17 + B, dtype=np.int32)
    batched = np.asarray(dense_round_device_batched(
        jnp.asarray(ell.dst), jnp.asarray(ell.w), jnp.asarray(ell.row_node),
        jnp.asarray(labs), jnp.asarray(nw), jnp.float32(U),
        jnp.asarray(seeds), jnp.float32(0.5), jnp.int32(g.n),
        k=k, use_pallas=False, interpret=True,
    ))
    for b in range(B):
        single = np.asarray(dense_round_device(
            jnp.asarray(ell.dst), jnp.asarray(ell.w),
            jnp.asarray(ell.row_node),
            jnp.asarray(labs[b]), jnp.asarray(nw), jnp.float32(U),
            jnp.int32(int(seeds[b])), jnp.float32(0.5), jnp.int32(g.n),
            k=k, use_pallas=False, interpret=True,
        ))
        np.testing.assert_array_equal(batched[b], single)
