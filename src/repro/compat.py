"""Version-compat shims for jax API drift.

The codebase targets current jax (``jax.shard_map``, ``check_vma``); the
container pins 0.4.x where shard_map still lives in ``jax.experimental``
with the replication check named ``check_rep``.  Route every shard_map
construction through :func:`shard_map` so both spellings work.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication/VMA checks off, on any jax."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )
        except TypeError:  # top-level shard_map that predates check_vma
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=False,
            )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
