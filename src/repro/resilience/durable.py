"""Durable disaster recovery: checkpoint + write-ahead log (resilience, layer 5).

PR 6's :class:`~repro.resilience.transact.ResilientSession` survives
in-process faults through reference-capture snapshots — but those versions
die with the host.  This module makes the serving state durable:

* **Checkpoints** — the full session state (labels, base CSR, node
  weights, pending overlay, quality-guard references, step counter,
  trajectory, transactional bookkeeping, deployment shape) is serialized
  through the atomic manifest-driven :mod:`repro.ckpt` layer (tmp dir +
  fsync + rename + parent-dir fsync).  A crash mid-checkpoint can never
  corrupt the latest restorable step: recovery reads the newest COMPLETE
  manifest and ignores torn ``.tmp`` writes.
* **Write-ahead log** — every *committed* transaction appends its
  :class:`~repro.dynamic.store.GraphUpdate` (in the length + crc32 framed
  wire format) to ``wal_<step>.log``, fsynced before ``submit`` returns.
  Each record also carries the session step after the commit, the
  transaction's sequence number, and the ``suppress_escalation`` state the
  committed apply ran under — exactly what a deterministic replay needs.
* **Restore** — on a fresh process, :meth:`DurableSession.restore` loads
  the newest complete checkpoint, rebuilds the session WITHOUT the initial
  V-cycle (:meth:`~repro.dynamic.session.PartitionSession.from_restored`),
  replays the WAL through the same ``update`` path, re-extracts the shard
  deployment from the restored labels, and returns a serving
  :class:`DurableSession` whose :func:`~repro.resilience.snapshot.
  host_digest` is **bit-identical** to the pre-crash session: every repair
  seed derives from the step counter, and the WAL's suppress flags replay
  degraded-mode decisions faithfully.

RPO/RTO: at the default ``wal_group_commit_n = 1`` committed batches are
never lost (RPO 0 — the WAL append is fsynced inside the commit path);
group commit (``wal_group_commit_n > 1``) coalesces fsyncs over a bounded
commit window, trading RPO <= ``wal_group_commit_n - 1`` batches for the
per-commit fsync latency.  Recovery time is one checkpoint load plus
the replay of at most ``checkpoint_every`` batches (RTO bounded by the
cadence knob), instead of a full re-partition.  A torn WAL tail (the
record being written when the host died) is detected by the crc framing,
dropped, and surfaced in the restore report — it was never acknowledged as
committed.  ``heal()`` timeline forks (rollback past committed batches)
truncate the WAL and drop newer checkpoints so durable state always
describes the surviving timeline.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import struct
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from .. import ckpt
from ..dynamic.session import PartitionSession, SessionConfig, UpdateResult
from ..dynamic.store import GraphUpdate, UpdateValidationError
from ..graph.csr import GraphNP
from ..obs import MetricsRegistry, span as _obs_span
from .transact import ResilientConfig, ResilientSession, TxResult

__all__ = [
    "DurableConfig",
    "DurableSession",
    "RestoreReport",
    "WalRecord",
    "read_wal",
    "wal_path",
]

# WAL record framing: a fixed prefix in front of the GraphUpdate wire
# record (which is itself length + crc framed, so the reader can both skip
# and verify it):  magic | step-after-commit u64 | tx seq u64 | flags u8
# (bit 0: suppress_escalation during the committed apply) | 3 pad bytes.
_WAL_MAGIC = b"WALR"
_WAL_PREFIX = struct.Struct("<4sQQB3x")
_FLAG_SUPPRESS = 1


@dataclass(frozen=True)
class WalRecord:
    """One committed transaction as durably logged."""

    step: int                   # session step AFTER the commit
    seq: int                    # transaction sequence number
    suppress: bool              # escalation suppressed during the apply
    upd: GraphUpdate


@dataclass
class RestoreReport:
    """What a restore did — the operator-facing recovery record."""

    checkpoint_step: int
    records_replayed: int
    wal_tail_error: Optional[str] = None   # torn/corrupt tail reason (if any)
    wal_bytes_dropped: int = 0
    seconds: float = 0.0


def wal_path(directory: str, step: int) -> str:
    return os.path.join(directory, f"wal_{step:08d}.log")


def _pack_record(rec: WalRecord) -> bytes:
    flags = _FLAG_SUPPRESS if rec.suppress else 0
    return _WAL_PREFIX.pack(_WAL_MAGIC, rec.step, rec.seq, flags) \
        + rec.upd.to_bytes()


def read_wal(path: str) -> Tuple[List[WalRecord], int, Optional[str]]:
    """Parse a WAL file up to the first torn/corrupt record.

    Returns ``(records, valid_bytes, tail_error)``: everything before the
    first framing violation parses into records; ``valid_bytes`` is the
    clean prefix length (restore truncates the file there before
    appending), and ``tail_error`` names why parsing stopped (None at a
    clean EOF).  A record that fails its crc is NEVER partially applied —
    the wire format rejects it atomically."""
    records: List[WalRecord] = []
    if not os.path.exists(path):
        return records, 0, None
    with open(path, "rb") as f:
        data = f.read()
    off, tail_error = 0, None
    while off < len(data):
        if len(data) - off < _WAL_PREFIX.size:
            tail_error = "wal_truncated"
            break
        magic, step, seq, flags = _WAL_PREFIX.unpack_from(data, off)
        if magic != _WAL_MAGIC:
            tail_error = "wal_bad_magic"
            break
        body = data[off + _WAL_PREFIX.size:]
        try:
            size = GraphUpdate.wire_size(body)
            upd = GraphUpdate.from_bytes(body[:size])
        except UpdateValidationError as e:
            tail_error = e.reason
            break
        records.append(WalRecord(
            step=int(step), seq=int(seq),
            suppress=bool(flags & _FLAG_SUPPRESS), upd=upd,
        ))
        off += _WAL_PREFIX.size + size
    return records, off, tail_error


class WriteAheadLog:
    """Append-only fsynced log of committed update batches.

    **Group commit** (ISSUE 8): with ``group_n > 1``, appends buffer in
    memory and the physical write + flush + fsync happens once per batch —
    when ``group_n`` records have accumulated, or when the oldest buffered
    record has waited ``group_timeout`` seconds (checked at append time),
    or on :meth:`flush`/:meth:`close`.  One fsync then covers the whole
    window, amortizing the dominant cost of durable logging (BENCH_PR7
    measured 14.4% overhead at fsync-per-record).  The trade is explicit:
    a crash loses at most the ``group_n - 1`` records still buffered
    (RPO <= group_n - 1 commits instead of 0).  Buffered records are
    written in append order in a single contiguous write, so the on-disk
    prefix property read_wal() depends on is preserved — a torn batch
    tail drops only the *newest* records, never reorders them.

    ``group_n = 1`` (the default) is the historical fsync-per-append
    behavior, bit-for-bit.
    """

    def __init__(self, path: str, fsync: bool = True, fresh: bool = False,
                 group_n: int = 1, group_timeout: float = 0.0,
                 registry: Optional[MetricsRegistry] = None):
        self.path = path
        self.fsync = fsync
        self.group_n = max(int(group_n), 1)
        self.group_timeout = float(group_timeout)
        # fsync-latency histogram sink; the per-WAL counters below stay
        # plain ints (a WAL rotates per checkpoint — merging rotations
        # into one registry counter would misreport the current log)
        self.metrics = registry
        self._f = open(path, "wb" if fresh else "ab")
        self._buf: List[bytes] = []
        self._buf_t0 = 0.0
        self.records_appended = 0
        self.flushes = 0            # physical write+fsync batches

    @property
    def buffered(self) -> int:
        """Records appended but not yet durable (lost if the host dies)."""
        return len(self._buf)

    def append(self, rec: WalRecord) -> None:
        if not self._buf:
            self._buf_t0 = time.monotonic()
        self._buf.append(_pack_record(rec))
        self.records_appended += 1
        if len(self._buf) >= self.group_n or (
            self.group_timeout > 0.0
            and time.monotonic() - self._buf_t0 >= self.group_timeout
        ):
            self.flush()

    def flush(self) -> None:
        """Make every buffered record durable (one write, one fsync)."""
        if not self._buf:
            return
        payload = b"".join(self._buf)
        # records are handed to the OS exactly once: a failed fsync leaves
        # their durability unknown (the caller sees the exception), but a
        # retry must never re-write them — duplicate records would corrupt
        # the replay stream, which is worse than an honest unknown tail
        n_rec = len(self._buf)
        self._buf = []
        t0 = time.perf_counter()
        with _obs_span("wal.fsync", cat="resilience",
                       records=n_rec, bytes=len(payload)):
            self._f.write(payload)
            self._f.flush()
            if self.fsync:
                os.fsync(self._f.fileno())
        if self.metrics is not None:
            self.metrics.observe(
                "wal_fsync_seconds", time.perf_counter() - t0
            )
        self.flushes += 1

    def close(self) -> None:
        if not self._f.closed:
            self.flush()
            self._f.close()


def _truncate_wal(path: str, max_step: int, fsync: bool = True) -> int:
    """Rewrite a WAL keeping records with ``step <= max_step`` (the
    timeline-fork path); returns the number of records kept."""
    records, _, _ = read_wal(path)
    keep = [r for r in records if r.step <= max_step]
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        for r in keep:
            f.write(_pack_record(r))
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    return len(keep)


@dataclass
class DurableConfig:
    directory: str
    checkpoint_every: int = 16      # commits between checkpoints (RTO knob:
                                    # bounds WAL replay length on restore)
    keep_checkpoints: int = 3       # retained restore points
    wal_fsync: bool = True          # fsync per commit (RPO 0); False trades
                                    # the last few batches for latency
    # WAL group commit (ISSUE 8): coalesce fsyncs over a commit window of
    # up to this many records / this many seconds since the first buffered
    # record (timeout 0 = count-only window).  1 = fsync per commit (RPO
    # 0, the historical behavior); n > 1 bounds loss at n - 1 committed
    # batches if the host dies with the window open (checkpoint() and
    # heal() close the WAL first, so rotation/fork points are always
    # durable).
    wal_group_commit_n: int = 1
    wal_group_commit_timeout: float = 0.0


def _json_safe(x):
    """Recursively convert numpy scalars/arrays to JSON-native types."""
    if isinstance(x, dict):
        return {k: _json_safe(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_json_safe(v) for v in x]
    if isinstance(x, np.ndarray):
        return [_json_safe(v) for v in x.tolist()]
    if isinstance(x, (np.bool_,)):
        return bool(x)
    if isinstance(x, np.integer):
        return int(x)
    if isinstance(x, np.floating):
        return float(x)
    return x


class DurableSession:
    """Durably-logged transactional serving: the disaster-recovery wrapper.

    Wraps a :class:`ResilientSession` (which wraps the
    :class:`PartitionSession` and optional deployment).  Every committed
    transaction is WAL-appended before ``submit`` returns; every
    ``checkpoint_every`` commits the full state checkpoints and the WAL
    rotates.  :meth:`restore` rebuilds the whole stack on a fresh process.
    """

    def __init__(self, rs: ResilientSession, cfg: DurableConfig,
                 _resume_step: Optional[int] = None):
        self.rs = rs
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        # share the serving stack's registry (WAL fsync + checkpoint
        # latency histograms land next to the session's update metrics)
        self.metrics = rs.session.metrics
        self.checkpoints_written = 0
        self.failed_checkpoints = 0
        self.last_checkpoint_error: Optional[BaseException] = None
        self.last_checkpoint_seconds = 0.0
        self.last_restore_seconds = 0.0
        self._commits_since_ckpt = 0
        rs.on_commit = self._on_commit
        if _resume_step is None:
            step = self.checkpoint()
            if step is None:     # initial durability anchor must exist
                raise self.last_checkpoint_error
        else:
            # resuming after restore(): the anchor checkpoint + WAL already
            # exist on disk; keep appending to the (truncated-clean) WAL
            self._anchor_step = int(_resume_step)
            self._wal = self._open_wal(self._anchor_step, fresh=False)

    # ------------------------------------------------------------- internals

    def _open_wal(self, step: int, fresh: bool) -> WriteAheadLog:
        return WriteAheadLog(
            wal_path(self.cfg.directory, step),
            fsync=self.cfg.wal_fsync, fresh=fresh,
            group_n=self.cfg.wal_group_commit_n,
            group_timeout=self.cfg.wal_group_commit_timeout,
            registry=self.metrics,
        )

    def _on_commit(self, tx: TxResult, upd: GraphUpdate, sup: bool) -> None:
        self._wal.append(WalRecord(
            step=self.rs.session._step, seq=tx.seq, suppress=sup, upd=upd,
        ))
        self._commits_since_ckpt += 1

    def _checkpoint_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.cfg.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def _capture(self) -> Tuple[dict, dict]:
        """Serialize the full serving state (host arrays + JSON metadata).

        Runs at transaction boundaries; the store's pending overlay is
        captured as-is (base + delta), so nothing is compacted or mutated
        by taking a checkpoint."""
        sess = self.rs.session
        store = sess.store
        gh = store.base.to_host()
        cat = (lambda ch, dt: np.concatenate(ch).astype(dt) if ch
               else np.zeros(0, dt))
        tree = dict(
            ew=np.asarray(gh.ew, np.float32),
            indices=np.asarray(gh.indices, np.int32),
            indptr=np.asarray(gh.indptr, np.int64),
            labels=sess.labels_np().astype(np.int32),
            nw=store._nw.astype(np.float64),
            overlay_u=cat(store._ou, np.int32),
            overlay_v=cat(store._ov, np.int32),
            overlay_w=cat(store._ow, np.float32),
        )
        scfg = dataclasses.asdict(sess.cfg)
        custom_partition_cfg = scfg.pop("partition_cfg") is not None
        dep = self.rs.deployment
        if dep is None:
            dep_info = None
        else:
            dep_info = dict(
                type=type(dep).__name__, halo=dep.halo,
                escalate_fraction=dep.escalate_fraction,
                replicas=getattr(dep, "replicas", 1),
            )
        extra = _json_safe(dict(
            kind="partition_session_dr",
            format=1,
            n=store.n, m=store.base.m, k=sess.k,
            step=sess._step, cut_ref=sess._cut_ref, ew_ref=sess._ew_ref,
            suppress_escalation=sess.suppress_escalation,
            session_cfg=scfg,
            custom_partition_cfg=custom_partition_cfg,
            trajectory=[dataclasses.asdict(r) for r in sess.trajectory],
            resilient_cfg=dataclasses.asdict(self.rs.cfg),
            expected_seq=self.rs._expected_seq,
            degraded=self.rs.degraded,
            deployment=dep_info,
        ))
        return tree, extra

    # ---------------------------------------------------------------- public

    @property
    def session(self) -> PartitionSession:
        return self.rs.session

    @property
    def anchor_step(self) -> int:
        """Step of the checkpoint the current WAL extends."""
        return self._anchor_step

    def submit(self, upd: GraphUpdate, seq: Optional[int] = None) -> TxResult:
        """Transactional submit with durable commit logging; checkpoints at
        the configured cadence AFTER the transaction completes (a
        checkpoint is always a transaction-boundary state)."""
        tx = self.rs.submit(upd, seq=seq)
        if self._commits_since_ckpt >= self.cfg.checkpoint_every:
            self.checkpoint()
        return tx

    def checkpoint(self) -> Optional[int]:
        """Write a full durable checkpoint and rotate the WAL.

        Returns the checkpoint step, or None on failure — a failed write
        (disk full, injected crash) NEVER hurts recoverability: the torn
        ``.tmp`` is invisible to ``latest_step``, the previous checkpoint
        stays intact, and the current WAL keeps extending it, so the
        latest restorable state is exactly what it was before the
        attempt."""
        t0 = time.time()
        step = self.rs.session._step
        with _obs_span("checkpoint.write", cat="resilience",
                       step=int(step)) as sp:
            try:
                tree, extra = self._capture()
                ckpt.save(self.cfg.directory, step, tree, extra)
            except BaseException as e:
                self.failed_checkpoints += 1
                self.last_checkpoint_error = e
                self.last_checkpoint_seconds = time.time() - t0
                sp.set(failed=True)
                return None
        if getattr(self, "_wal", None) is not None:
            self._wal.close()
        self._anchor_step = step
        self._wal = self._open_wal(step, fresh=True)
        self._commits_since_ckpt = 0
        self.checkpoints_written += 1
        self.last_checkpoint_seconds = time.time() - t0
        self.metrics.observe("checkpoint_seconds",
                             self.last_checkpoint_seconds)
        self._prune()
        return step

    def _prune(self) -> None:
        """Drop checkpoints (and their WALs) beyond the retention window."""
        steps = self._checkpoint_steps()
        for s in steps[: -self.cfg.keep_checkpoints]:
            shutil.rmtree(
                os.path.join(self.cfg.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )
            try:
                os.remove(wal_path(self.cfg.directory, s))
            except OSError:
                pass

    def heal(self):
        """:meth:`ResilientSession.heal` + durable timeline maintenance.

        A heal that rolled the session back past committed batches forks
        the timeline: WAL records (and any checkpoints) newer than the
        surviving step describe a future that no longer exists and are
        truncated/dropped, so a later restore lands on the healed state,
        not the corrupt one."""
        rep = self.rs.heal()
        self._refit_to_step(self.rs.session._step)
        return rep

    def _refit_to_step(self, step: int) -> None:
        step = int(step)
        dropped = [s for s in self._checkpoint_steps() if s > step]
        for s in dropped:
            shutil.rmtree(
                os.path.join(self.cfg.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )
            try:
                os.remove(wal_path(self.cfg.directory, s))
            except OSError:
                pass
        anchors = [s for s in self._checkpoint_steps() if s <= step]
        if not anchors:
            # rolled back below every retained checkpoint (snapshots can
            # predate the durable wrapper): re-anchor with a fresh one
            # (second attempt absorbs a transient/injected write failure)
            self._wal.close()
            if self.checkpoint() is None and self.checkpoint() is None:
                raise self.last_checkpoint_error
            return
        anchor = anchors[-1]
        self._wal.close()
        _truncate_wal(
            wal_path(self.cfg.directory, anchor), step,
            fsync=self.cfg.wal_fsync,
        )
        self._anchor_step = anchor
        self._wal = self._open_wal(anchor, fresh=False)

    def close(self) -> None:
        self._wal.close()

    def stats(self) -> dict:
        d = self.rs.stats()
        d.update(
            dr_anchor_step=self._anchor_step,
            dr_checkpoints_written=self.checkpoints_written,
            dr_failed_checkpoints=self.failed_checkpoints,
            dr_wal_records=self._wal.records_appended,
            dr_wal_flushes=self._wal.flushes,
            dr_wal_buffered=self._wal.buffered,
            dr_commits_since_checkpoint=self._commits_since_ckpt,
            # RPO observable: records that exist only in the current WAL —
            # the replay a restore would need (plus buffered = not yet
            # durable at all).  RTO observable: measured restore wall time.
            dr_wal_records_since_checkpoint=self._wal.records_appended,
            dr_last_checkpoint_seconds=self.last_checkpoint_seconds,
            dr_last_restore_seconds=self.last_restore_seconds,
        )
        return d

    # ---------------------------------------------------------------- restore

    @staticmethod
    def restore(
        directory: str,
        *,
        durable_cfg: Optional[DurableConfig] = None,
        session_cfg: Optional[SessionConfig] = None,
        with_deployment: Optional[bool] = None,
    ) -> Tuple["DurableSession", RestoreReport]:
        """Rebuild the full serving stack on a fresh process.

        Procedure (the DR_RUNBOOK's restore-on-fresh-process path):
        newest complete checkpoint -> session WITHOUT the initial V-cycle
        -> WAL replay through the real ``update`` path (suppress flags
        re-applied per record) -> deployment re-extraction from the
        restored labels -> transactional wrapper with the persisted
        sequence state.  The result's ``host_digest`` is bit-identical to
        the crashed process's at its last committed transaction.

        ``session_cfg`` overrides the persisted config — REQUIRED when the
        original session used a custom ``partition_cfg`` (not serialized).
        ``with_deployment=False`` skips rebuilding a persisted deployment.
        """
        t0 = time.time()
        anchor = ckpt.latest_step(directory)
        if anchor is None:
            raise FileNotFoundError(
                f"no complete checkpoint under {directory}"
            )
        leaves, manifest = ckpt.load(directory, anchor)
        extra = manifest["extra"]
        if extra.get("kind") != "partition_session_dr":
            raise ValueError(f"not a DR checkpoint: {extra.get('kind')!r}")
        # leaves are in tree-flatten (sorted-key) order of _capture's dict
        ew, indices, indptr, labels, nw, ov_u, ov_v, ov_w = leaves
        if extra["custom_partition_cfg"] and session_cfg is None:
            raise ValueError(
                "checkpoint used a custom partition_cfg (not serialized); "
                "pass session_cfg explicitly"
            )
        cfg = session_cfg or SessionConfig(**extra["session_cfg"])
        g = GraphNP(
            indptr=indptr.astype(np.int64),
            indices=indices.astype(np.int32),
            ew=ew.astype(np.float32),
            nw=nw.astype(np.float32),
        )
        traj = [UpdateResult(**r) for r in extra["trajectory"]]
        sess = PartitionSession.from_restored(
            g, cfg,
            labels=labels, step=extra["step"], cut_ref=extra["cut_ref"],
            ew_ref=extra["ew_ref"], trajectory=traj,
            suppress_escalation=extra["suppress_escalation"],
        )
        # the f64 host mirror is authoritative for L_max / feasibility;
        # restore it exactly rather than through the f32 device round-trip
        sess.store._nw = nw.astype(np.float64)
        if ov_u.size:
            sess.store._ou.append(ov_u.astype(np.int32))
            sess.store._ov.append(ov_v.astype(np.int32))
            sess.store._ow.append(ov_w.astype(np.float32))
            sess.store._olen += int(ov_u.size)
        # ---- WAL replay: committed batches since the anchor checkpoint ----
        wal_file = wal_path(directory, anchor)
        records, valid_bytes, tail_error = read_wal(wal_file)
        wal_size = os.path.getsize(wal_file) if os.path.exists(wal_file) \
            else 0
        replayed = 0
        last_suppress = bool(extra["suppress_escalation"])
        last_seq = None
        for rec in records:
            if rec.step <= sess._step:
                continue            # already inside the checkpoint
            sess.suppress_escalation = rec.suppress
            sess.update(rec.upd)
            assert sess._step == rec.step, (sess._step, rec.step)
            replayed += 1
            last_suppress = rec.suppress
            last_seq = rec.seq
        if valid_bytes < wal_size:
            # torn/corrupt tail: drop it so future appends stay parseable
            with open(wal_file, "rb") as f:
                good = f.read(valid_bytes)
            with open(wal_file, "wb") as f:
                f.write(good)
                f.flush()
                os.fsync(f.fileno())
        # ---- deployment: derived state, re-extracted from restored labels
        dep_info = extra.get("deployment")
        dep = None
        if dep_info is not None and with_deployment is not False:
            if dep_info["type"] == "ReplicatedDeployment":
                from ..deploy.replicate import ReplicatedDeployment
                dep = ReplicatedDeployment(
                    sess, halo=dep_info["halo"],
                    escalate_fraction=dep_info["escalate_fraction"],
                    replicas=dep_info["replicas"],
                )
            else:
                from ..deploy.migrate import ShardDeployment
                dep = ShardDeployment(
                    sess, halo=dep_info["halo"],
                    escalate_fraction=dep_info["escalate_fraction"],
                )
        rs = ResilientSession(
            sess, deployment=dep,
            cfg=ResilientConfig(**extra["resilient_cfg"]),
        )
        rs._expected_seq = int(extra["expected_seq"])
        if last_seq is not None:
            rs._expected_seq = max(rs._expected_seq, last_seq + 1)
        sess.suppress_escalation = last_suppress
        rs.degraded = last_suppress or (replayed == 0
                                        and bool(extra["degraded"]))
        dcfg = durable_cfg or DurableConfig(directory=directory)
        ds = DurableSession(rs, dcfg, _resume_step=anchor)
        report = RestoreReport(
            checkpoint_step=int(anchor),
            records_replayed=replayed,
            wal_tail_error=tail_error,
            wal_bytes_dropped=int(wal_size - valid_bytes),
            seconds=time.time() - t0,
        )
        ds.last_restore_seconds = report.seconds
        ds.metrics.observe("restore_seconds", report.seconds)
        return ds, report
