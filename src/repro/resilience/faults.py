"""Seeded deterministic fault injection (resilience, layer 3).

Every recovery path in this package is exercised against *injected*
faults, not hypothetical ones.  The injector draws from one
``np.random.default_rng(seed)``, so a failing test replays exactly; every
injection is logged as an :class:`InjectedFault` record.

Injection discipline: device state is corrupted by **rebinding fresh
objects**, never by mutating arrays in place.  Snapshots hold references
to the pristine arrays (see :mod:`~repro.resilience.snapshot`), so an
in-place mutation would silently corrupt the snapshot too and rollback
could not heal it — replacing the session's label binding, swapping an
overlay chunk for a flipped copy, or rebinding a new ``GraphDev`` over
the store's base leaves every captured version intact by construction.

Stream-level faults (drop / duplicate / reorder) are modelled on the
batch sequence itself via :meth:`FaultInjector.mangle_stream`; the
transactional layer detects them through sequence numbers.  Simulated
infrastructure failures (extraction/compile blow-ups, escalation
failures) install one-shot raising wrappers on the real entry points and
restore them after firing.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from ..graph.csr import GraphDev

__all__ = ["FaultInjector", "InjectedFault", "InjectedFailure"]


class InjectedFailure(RuntimeError):
    """Raised by one-shot failure hooks (simulated compile/extract crash)."""


@dataclass
class InjectedFault:
    """Log record of one injection."""

    kind: str
    detail: str
    step: int = -1


class FaultInjector:
    """Deterministic fault source over a session / deployment pair."""

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.log: List[InjectedFault] = []
        self._disarmers: List = []

    def _record(self, kind: str, detail: str) -> InjectedFault:
        f = InjectedFault(kind=kind, detail=detail)
        self.log.append(f)
        return f

    def disarm(self) -> None:
        """Restore every armed-but-unfired one-shot hook.  One-shot faults
        patch live entry points (including the process-global ``ckpt.save``)
        and restore themselves only when they FIRE — an injector retired
        with a hook still pending must disarm it, or the stale patch leaks
        into unrelated code."""
        for d in self._disarmers:
            d()
        self._disarmers.clear()

    # ------------------------------------------------------- state corruption

    def corrupt_labels(self, session, count: int = 1,
                       out_of_range: bool = False) -> InjectedFault:
        """Flip ``count`` served label entries.  ``out_of_range=False``
        moves nodes to a *valid but wrong* block (caught by the cut
        checksum), ``True`` writes garbage ``>= k`` (caught by the range
        check)."""
        n = session.store.n
        idx = self.rng.choice(n, size=min(count, n), replace=False)
        lab = np.asarray(session.labels[jnp.asarray(idx)])
        if out_of_range:
            vals = lab + session.k + 1
        else:
            vals = (lab + 1 + self.rng.integers(0, session.k - 1, idx.size)) \
                % session.k
        session.labels = session.labels.at[jnp.asarray(idx)].set(
            jnp.asarray(vals.astype(np.int32))
        )
        return self._record(
            "corrupt_labels",
            f"{idx.size} entries, out_of_range={out_of_range}",
        )

    def bitflip_overlay(self, store) -> Optional[InjectedFault]:
        """Flip one bit of one pending overlay weight (the chunk is
        REPLACED with a modified copy).  Returns None when the overlay is
        empty (nothing to corrupt)."""
        if not store._ow:
            return None
        ci = int(self.rng.integers(0, len(store._ow)))
        chunk = store._ow[ci].copy()
        ei = int(self.rng.integers(0, chunk.size))
        bits = chunk.view(np.uint32)
        bits[ei] ^= np.uint32(1 << int(self.rng.integers(0, 23)))
        store._ow[ci] = chunk
        return self._record("bitflip_overlay", f"chunk {ci} entry {ei}")

    def corrupt_base_csr(self, store, mode: str = "weight") -> InjectedFault:
        """Corrupt the resident base CSR by rebinding a NEW ``GraphDev``
        whose ``ew`` (mode="weight") or ``indices`` (mode="endpoint")
        differs in one entry — an asymmetric arc, exactly what a partial
        DMA or a flipped device page would produce."""
        g = store.base
        if g.m == 0:
            raise ValueError("cannot corrupt an edgeless base")
        ai = int(self.rng.integers(0, g.m))
        if mode == "weight":
            ew = np.asarray(g.ew).copy()
            ew[ai] += 1.0
            new = GraphDev(
                indptr=g.indptr, indices=g.indices, ew=jnp.asarray(ew),
                nw=g.nw, src=g.src, n=g.n, m=g.m, nw_max=g.nw_max,
                ew_max=g.ew_max, ew_integral=g.ew_integral,
                on_materialize=g.on_materialize,
            )
        elif mode == "endpoint":
            ind = np.asarray(g.indices).copy()
            ind[ai] = (ind[ai] + 1) % max(g.n, 1)
            new = GraphDev(
                indptr=g.indptr, indices=jnp.asarray(ind), ew=g.ew,
                nw=g.nw, src=g.src, n=g.n, m=g.m, nw_max=g.nw_max,
                ew_max=g.ew_max, ew_integral=g.ew_integral,
                on_materialize=g.on_materialize,
            )
        else:
            raise ValueError(f"unknown mode {mode!r}")
        store.base = new
        store._base_host = None
        return self._record("corrupt_base_csr", f"arc {ai} mode={mode}")

    def corrupt_shard(self, deployment, block: Optional[int] = None) -> InjectedFault:
        """Flip one edge weight inside one deployed shard (bit-flip of a
        served artifact — caught by the reassembly checksum)."""
        b = int(self.rng.integers(0, deployment.k)) if block is None else block
        s = deployment.shards[b]
        ew = np.asarray(s.ew).copy()
        if s.m_local == 0:
            raise ValueError(f"shard {b} has no local arcs")
        ei = int(self.rng.integers(0, s.m_local))
        ew[ei] += 1.0
        s.ew = jnp.asarray(ew)
        s._host = None
        return self._record("corrupt_shard", f"block {b} arc {ei}")

    def lose_shard(self, deployment, block: Optional[int] = None) -> InjectedFault:
        """Drop a deployed shard entirely (a lost PE)."""
        b = int(self.rng.integers(0, deployment.k)) if block is None else block
        deployment.shards[b] = None
        return self._record("lose_shard", f"block {b}")

    # --------------------------------------------------------- stream mangling

    def mangle_stream(self, batches: List, drop: float = 0.0,
                      dup: float = 0.0, swap: float = 0.0) -> List[Tuple[int, object]]:
        """Turn a batch list into a sequenced ``(seq, batch)`` stream with
        seeded drops, duplicates, and adjacent swaps (reordering).  The
        assigned sequence numbers reflect the ORIGINAL order, so the
        receiver can detect every mangle."""
        seq = list(enumerate(batches))
        out: List[Tuple[int, object]] = []
        for item in seq:
            r = self.rng.random()
            if r < drop:
                self._record("drop_batch", f"seq {item[0]}")
                continue
            out.append(item)
            if self.rng.random() < dup:
                self._record("duplicate_batch", f"seq {item[0]}")
                out.append(item)
        i = 0
        while i + 1 < len(out):
            if self.rng.random() < swap:
                self._record(
                    "reorder_batches", f"seq {out[i][0]} <-> {out[i+1][0]}"
                )
                out[i], out[i + 1] = out[i + 1], out[i]
                i += 2
            else:
                i += 1
        return out

    # ------------------------------------------------------- one-shot failures

    def fail_next_extract(self, deployment) -> Optional[InjectedFault]:
        """Make the deployment's next ``extractor.extract`` raise once
        (simulated compile/DMA failure during migration).  Returns None
        when a hook is already armed: stacking one-shot patches would
        capture the first hook as the "real" entry point and re-arm it on
        fire/disarm."""
        extractor = deployment.extractor
        real = extractor.extract
        if getattr(real, "_injected_hook", False):
            return None

        def boom(*a, **kw):
            extractor.extract = real
            raise InjectedFailure("injected extract failure")

        def disarm():
            if extractor.extract is boom:
                extractor.extract = real

        boom._injected_hook = True
        extractor.extract = boom
        self._disarmers.append(disarm)
        return self._record("fail_next_extract", "one-shot")

    def fail_next_escalation(self, session) -> Optional[InjectedFault]:
        """Make the session's next ``_escalate`` raise once (simulated
        V-cycle crash — the watchdog/degraded-mode trigger).  Returns
        None when a hook is already armed (no stacking)."""
        real = session._escalate
        if getattr(real, "_injected_hook", False):
            return None

        def boom(*a, **kw):
            session._escalate = real
            raise InjectedFailure("injected escalation failure")

        def disarm():
            if session._escalate is boom:
                session._escalate = real

        boom._injected_hook = True
        session._escalate = boom
        self._disarmers.append(disarm)
        return self._record("fail_next_escalation", "one-shot")

    # ------------------------------------------------ disaster-recovery faults

    def fail_mid_checkpoint(self, durable) -> Optional[InjectedFault]:
        """Kill the next checkpoint mid-write: the state capture runs, a
        torn ``step_X.tmp`` partial is left behind, and the save dies
        BEFORE the atomic rename (simulated power loss inside the
        checkpoint window).  The latest complete checkpoint must remain
        the restorable one.  Returns None when a hook is already armed —
        ``ckpt.save`` is process-global, and stacking patches would
        restore the first hook instead of the real writer."""
        import os

        from .. import ckpt

        durable_cfg = durable.cfg
        real_save = ckpt.save
        if getattr(real_save, "_injected_hook", False):
            return None

        def boom(path, step, tree, extra=None):
            ckpt.save = real_save
            tmp = os.path.join(path, f"step_{step:08d}.tmp")
            os.makedirs(tmp, exist_ok=True)
            with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
                f.write(b"torn partial write")
            raise InjectedFailure("injected mid-checkpoint crash")

        def disarm():
            if ckpt.save is boom:
                ckpt.save = real_save

        boom._injected_hook = True
        ckpt.save = boom
        self._disarmers.append(disarm)
        return self._record(
            "fail_mid_checkpoint", f"dir {durable_cfg.directory}"
        )

    def corrupt_wal(self, durable) -> Optional[InjectedFault]:
        """Flip one bit somewhere in the current WAL file's record bytes
        (simulated disk corruption).  The framing crc must confine the
        damage: replay keeps the clean prefix and drops the tail.  Returns
        None when the WAL holds no records yet."""
        import os

        from .durable import wal_path

        path = wal_path(durable.cfg.directory, durable.anchor_step)
        size = os.path.getsize(path) if os.path.exists(path) else 0
        if size == 0:
            return None
        durable._wal._f.flush()
        byte = int(self.rng.integers(0, size))
        bit = int(self.rng.integers(0, 8))
        with open(path, "r+b") as f:
            f.seek(byte)
            old = f.read(1)
            f.seek(byte)
            f.write(bytes([old[0] ^ (1 << bit)]))
        return self._record("corrupt_wal", f"byte {byte} bit {bit}")

    def corrupt_replica(self, deployment,
                        block: Optional[int] = None) -> Optional[InjectedFault]:
        """Flip one edge weight inside one STANDBY copy (replica rot: the
        failover path must audit standbys before promoting them).  Returns
        None when the chosen block has no standbys."""
        b = int(self.rng.integers(0, deployment.k)) if block is None else block
        standbys = deployment._standbys[b]
        if not standbys:
            return None
        ri = int(self.rng.integers(0, len(standbys)))
        s = standbys[ri]
        if s.m_local == 0:
            return None
        ei = int(self.rng.integers(0, s.m_local))
        ew = np.asarray(s.ew).copy()
        ew[ei] += 1.0
        s.ew = jnp.asarray(ew)
        s._host = None
        return self._record("corrupt_replica", f"block {b} standby {ri} arc {ei}")
