"""Transactional serving (resilience, layer 4).

:class:`ResilientSession` wraps a :class:`~repro.dynamic.session.
PartitionSession` (and optionally a :class:`~repro.deploy.migrate.
ShardDeployment`) in the commit protocol the ISSUE's production framing
demands:

    validate -> snapshot -> apply -> audit -> commit-or-rollback

* **validate** — structural validation (:meth:`GraphUpdate.validate`)
  rejects malformed batches before any state moves; rejection is atomic
  by construction (the session validates again before its step counter).
* **snapshot** — every transaction opens with an O(delta) snapshot
  (:class:`~repro.resilience.snapshot.SnapshotManager`), so abort is a
  reference rebind, not a recovery procedure.
* **apply + audit** — the batch runs through the session's repair path;
  at the configured cadence (and always after a retry) the invariant
  auditor checks the committed-to-be state.
* **commit-or-rollback** — an audit failure or a raised error rolls the
  session back bit-identically and retries up to ``max_retries`` times
  (state-corruption faults are healed by the rollback itself, so a clean
  retry usually commits); a batch that keeps failing is **quarantined**
  with a structured error and the session keeps serving the last
  committed state.
* **watchdog / degraded mode** — ``max_consecutive_escalations`` bounds
  V-cycle retries; past the bound (or after an escalation crash) the
  session enters explicit degraded mode: quality-guard escalations are
  suppressed, steps serve repaired-but-stale labels flagged ``stale`` in
  the trajectory and ``degraded`` in ``stats()``.  ``recover()`` exits.
* **sequence numbers** — ``submit(upd, seq=...)`` detects duplicates
  (dropped), reorders (parked until the gap fills), and losses
  (surfaced after ``reorder_window`` newer batches) on a mangled stream.

Shard serving rides the session's transactions: migration runs inside
the transaction, BEFORE the audit, so shard health is checked against
the batch's own base; a rollback re-syncs the shard set with one more
incremental migrate.  A failed migration (or a lost/corrupt shard found
by audit) falls back to serving the stale-but-consistent set until
:meth:`ShardDeployment.recover_block` or the next successful migrate
catches up.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from ..dynamic.session import PartitionSession, UpdateResult, _reg_counter
from ..dynamic.store import GraphUpdate, UpdateValidationError
from .audit import AuditReport, InvariantAuditor
from .snapshot import SnapshotManager

__all__ = ["QuarantinedBatch", "ResilientConfig", "ResilientSession", "TxResult"]


@dataclass
class ResilientConfig:
    audit_cadence: int = 8          # full invariant pass every N commits
    max_retries: int = 2            # rollback+retry budget per batch
    snapshot_keep: int = 8          # retained rollback points
    max_consecutive_escalations: int = 3  # watchdog bound before degraded
    reorder_window: int = 4         # parked batches tolerated before a gap
                                    # is declared lost
    audit_after_retry: bool = True  # always audit a retried commit


@dataclass
class QuarantinedBatch:
    """A batch the session refused (with why) — the poison queue."""

    seq: int
    upd: GraphUpdate
    reason: str
    detail: str
    attempts: int = 1


@dataclass
class TxResult:
    """Outcome of one ``submit``."""

    seq: int
    committed: bool
    result: Optional[UpdateResult] = None
    audit: Optional[AuditReport] = None
    retries: int = 0
    rolled_back: bool = False
    quarantined: bool = False
    duplicate: bool = False
    parked: bool = False            # out-of-order: held for its turn
    reason: str = ""
    migration_failed: bool = False
    seconds: float = 0.0
    followups: List["TxResult"] = field(default_factory=list)


class ResilientSession:
    """Fault-tolerant wrapper: transactional updates over a live session."""

    # transactional counters ride in the session stack's registry so the
    # whole stack resets/snapshots/exports through one path
    committed = _reg_counter("tx_committed")
    rollbacks = _reg_counter("tx_rollbacks")
    retries = _reg_counter("tx_retries")
    duplicates_dropped = _reg_counter("tx_duplicates_dropped")
    parked_batches = _reg_counter("tx_parked")
    lost_batches = _reg_counter("tx_lost")

    def __init__(self, session: PartitionSession, deployment=None,
                 cfg: Optional[ResilientConfig] = None):
        self.cfg = cfg or ResilientConfig()
        self.session = session
        self.metrics = session.metrics
        self.deployment = deployment
        self.snapshots = SnapshotManager(session, keep=self.cfg.snapshot_keep)
        self.auditor = InvariantAuditor(
            session, deployment=deployment, cadence=self.cfg.audit_cadence
        )
        self.quarantine: List[QuarantinedBatch] = []
        self.results: List[TxResult] = []
        self.committed = 0
        self.rollbacks = 0
        self.retries = 0
        self.duplicates_dropped = 0
        self.parked_batches = 0
        self.lost_batches = 0
        self.degraded = False
        self._consecutive_escalations = 0
        self._expected_seq = 0
        self._parked: Dict[int, GraphUpdate] = {}
        # durable-logging attach point: called as on_commit(tx, upd, sup)
        # at the instant a transaction commits, BEFORE the watchdog can
        # flip degraded mode — ``sup`` is the suppress_escalation state the
        # committed apply actually ran under, which is what a WAL replay
        # must reproduce to stay bit-identical
        self.on_commit: Optional[
            Callable[[TxResult, GraphUpdate, bool], None]
        ] = None

    # ------------------------------------------------------------- internals

    def _quarantine(self, seq: int, upd: GraphUpdate, reason: str,
                    detail: str, attempts: int = 1) -> None:
        self.quarantine.append(QuarantinedBatch(
            seq=seq, upd=upd, reason=reason, detail=detail, attempts=attempts,
        ))

    def _enter_degraded(self) -> None:
        if not self.degraded:
            self.degraded = True
            self.session.suppress_escalation = True

    def _watchdog(self, res: UpdateResult) -> None:
        """Bound consecutive V-cycle escalations; past the bound the
        session stops escalating and serves (flagged) stale quality."""
        if res.escalated:
            self._consecutive_escalations += 1
            if (self._consecutive_escalations
                    >= self.cfg.max_consecutive_escalations):
                self._enter_degraded()
        elif not res.noop:
            self._consecutive_escalations = 0

    def _rollback(self, version: int, tx: TxResult,
                  upd: Optional[GraphUpdate] = None) -> None:
        self.snapshots.rollback(version)
        self.rollbacks += 1
        tx.rolled_back = True
        if self.deployment is not None:
            # re-sync the shard set to the restored state (migration ran
            # before the audit so shard health could be checked against the
            # new base); the undone batch's endpoints mark which blocks'
            # halo content has to be re-extracted
            self.deployment.resync(upd)

    def _transact(self, seq: int, upd: GraphUpdate) -> TxResult:
        t0 = time.time()
        tx = TxResult(seq=seq, committed=False)
        # ---- validate (before ANY state moves) ----
        try:
            upd.validate(self.session.store.n)
        except UpdateValidationError as e:
            self._quarantine(seq, upd, e.reason, e.detail)
            tx.quarantined = True
            tx.reason = e.reason
            tx.seconds = time.time() - t0
            return tx
        # ---- snapshot -> apply (+migrate) -> audit -> commit-or-rollback
        version = self.snapshots.take()
        attempts = 0
        while True:
            sup = self.session.suppress_escalation
            try:
                res = self.session.update(upd)
            except Exception as e:  # apply crashed (e.g. escalation failure)
                self._rollback(version, tx, upd)
                # an escalation crash means the quality guard cannot be
                # satisfied right now: degrade rather than retry forever
                self._enter_degraded()
                if attempts >= self.cfg.max_retries:
                    self._quarantine(
                        seq, upd, "apply_failed", repr(e), attempts + 1
                    )
                    tx.quarantined = True
                    tx.reason = "apply_failed"
                    tx.retries = attempts
                    tx.seconds = time.time() - t0
                    return tx
                attempts += 1
                self.retries += 1
                continue
            # migration precedes the audit so shard health is checked
            # against the batch's base; a failed migration leaves the set
            # stale (the auditor skips content checks on a stale set)
            if self.deployment is not None:
                delta = self.deployment.migrate(upd, res)
                tx.migration_failed = delta.failed
            if attempts > 0 and self.cfg.audit_after_retry:
                rep = self.auditor.audit()
            else:
                rep = self.auditor.maybe_audit(self.committed + 1)
            if rep is not None and not rep.ok:
                self._rollback(version, tx, upd)
                if attempts >= self.cfg.max_retries:
                    self._quarantine(
                        seq, upd, "audit_failed",
                        ";".join(rep.failures), attempts + 1,
                    )
                    tx.quarantined = True
                    tx.reason = "audit_failed"
                    tx.audit = rep
                    tx.retries = attempts
                    tx.seconds = time.time() - t0
                    return tx
                attempts += 1
                self.retries += 1
                continue
            break
        # ---- committed ----
        self.committed += 1
        tx.committed = True
        tx.result = res
        tx.audit = rep
        tx.retries = attempts
        if self.on_commit is not None:
            # before the watchdog: ``sup`` must be the state the committed
            # apply ran under, not whatever the watchdog flips it to next
            self.on_commit(tx, upd, sup)
        self._watchdog(res)
        tx.seconds = time.time() - t0
        return tx

    # ---------------------------------------------------------------- public

    def submit(self, upd: GraphUpdate, seq: Optional[int] = None) -> TxResult:
        """Transactionally absorb one batch.

        With ``seq`` (a sender-assigned sequence number), duplicates are
        dropped, early arrivals are parked until the gap fills, and a gap
        older than ``reorder_window`` parked batches is declared lost (the
        stream advances past it).  Without ``seq``, batches apply in
        arrival order."""
        if seq is None:
            tx = self._transact(self._expected_seq, upd)
            self._expected_seq += 1
            self.results.append(tx)
            return tx
        seq = int(seq)
        if seq < self._expected_seq or seq in self._parked:
            self.duplicates_dropped += 1
            tx = TxResult(seq=seq, committed=False, duplicate=True,
                          reason="duplicate")
            self.results.append(tx)
            return tx
        if seq > self._expected_seq:
            self._parked[seq] = upd
            self.parked_batches += 1
            tx = TxResult(seq=seq, committed=False, parked=True,
                          reason="out_of_order")
            if len(self._parked) > self.cfg.reorder_window:
                # the gap is declared lost: advance to the oldest parked
                # batch and drain everything that became in-order
                lost_upto = min(self._parked)
                self.lost_batches += lost_upto - self._expected_seq
                self._expected_seq = lost_upto
                tx.followups.extend(self._drain())
            self.results.append(tx)
            return tx
        tx = self._transact(seq, upd)
        self._expected_seq = seq + 1
        tx.followups.extend(self._drain())
        self.results.append(tx)
        return tx

    def _drain(self) -> List[TxResult]:
        """Apply parked batches that are now in order."""
        out: List[TxResult] = []
        while self._expected_seq in self._parked:
            upd = self._parked.pop(self._expected_seq)
            sub = self._transact(self._expected_seq, upd)
            self._expected_seq += 1
            out.append(sub)
        return out

    def heal(self) -> AuditReport:
        """Audit the serving state and, if corrupted, roll back through the
        retained versions (newest first) until a version passes — the
        recovery path for corruption that arrived OUTSIDE a transaction
        (a flipped device page, a corrupted served artifact).  Returns the
        final report; ``ok=False`` means no retained version was clean.

        Healing in degraded mode exits it — but ONLY when the final audit
        passes: a clean bill of health supersedes the watchdog's stale
        verdict, while an unhealed session must keep escalations
        suppressed (they were the failure mode that degraded it).  When a
        deployment rode through heal in a stale state (a failed migration
        preceded the corruption), the shard set is caught up before the
        final audit so shard health is actually re-checked, not skipped."""
        rep = self.auditor.audit()
        for v in sorted(self.snapshots.versions, reverse=True):
            if rep.ok:
                break
            self.snapshots.rollback(v)
            self.rollbacks += 1
            if self.deployment is not None:
                # the set of undone batches is unknown here, so the shard
                # set follows with a full re-extraction (heal is the rare
                # path; correctness beats incrementality)
                self.deployment.resync(full=True)
            rep = self.auditor.audit()
        if rep.ok and self.deployment is not None and self.deployment.stale:
            # a stale set passed only because the auditor skips stale
            # content checks — resync and prove shard health for real
            self.deployment.migrate(None)
            rep = self.auditor.audit()
        if rep.ok and self.degraded:
            self.degraded = False
            self.session.suppress_escalation = False
            self._consecutive_escalations = 0
        return rep

    def recover(self) -> Optional[AuditReport]:
        """Exit degraded mode: re-enable escalation, run one full audit,
        and (when deployed) catch the shard set up if it went stale."""
        self.degraded = False
        self.session.suppress_escalation = False
        self._consecutive_escalations = 0
        if self.deployment is not None and self.deployment.stale:
            self.deployment.migrate(None)
        return self.auditor.audit()

    def stats(self) -> dict:
        """Serving dashboard row: session/deployment counters + the
        transactional layer's."""
        d = (self.deployment.stats() if self.deployment is not None
             else self.session.stats())
        d.update(
            tx_committed=self.committed,
            tx_rollbacks=self.rollbacks,
            tx_retries=self.retries,
            tx_quarantined=len(self.quarantine),
            tx_duplicates_dropped=self.duplicates_dropped,
            tx_parked=self.parked_batches,
            tx_lost=self.lost_batches,
            degraded=self.degraded,
            snapshots_taken=self.snapshots.takes,
            snapshot_versions=len(self.snapshots.versions),
        )
        d.update(self.auditor.stats())
        return d
