"""End-to-end disaster-recovery fault fuzzer (resilience, layer 6).

Single-fault unit tests prove each recovery path works in isolation; real
outages stack faults.  This harness drives the full serving stack —
:class:`~repro.dynamic.session.PartitionSession` inside a
:class:`~repro.resilience.transact.ResilientSession` with a
:class:`~repro.deploy.replicate.ReplicatedDeployment` and a
:class:`~repro.resilience.durable.DurableSession` on top — through seeded
episodes that interleave EVERY :class:`~repro.resilience.faults.
FaultInjector` class (label / overlay / base-CSR / shard / replica / WAL
corruption, shard loss, stream drop + duplicate + reorder, extract and
escalation crashes, mid-checkpoint kills) against two concurrently mangled
producer streams, with serving reads mixed in.

The property checked after every episode, not per fault: **the stack
heals or restores to the oracle**.  Concretely —

* ``heal()`` normally ends with a passing invariant audit; when stacked
  faults exhaust the snapshot ring (no retained in-memory version is
  clean), the remedy is disaster recovery proper — restore from disk,
  walking back through retained checkpoints until one audits clean;
* a fresh-process :meth:`DurableSession.restore` replays the WAL to a
  session whose :func:`~repro.resilience.snapshot.host_digest` is
  **bit-identical** to the live healed session.  Two fault classes fork
  the live timeline away from the durable one in ways no audit can see
  (label corruption is a *valid* partition the next commit absorbs; WAL
  media corruption silently drops committed records — both outside the
  RPO-0 crash contract), so the harness re-anchors with a checkpoint
  before the strict digest comparison whenever such a fault fired since
  the last rotation — which is itself the documented operator remedy;
* every block is readable through the checksum-audited ``read_block``
  path at episode end, with one retry absorbing a pending injected
  infrastructure failure.

Episodes never assert mid-flight: violations are collected as strings so
one failing seed reports everything it saw, and the fixed ``(n, k)``
shapes across episodes keep every device executable cached after the
first (episode count scales the fuzzing budget, not the compile bill).
"""

from __future__ import annotations

import os
import shutil
import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from ..dynamic.session import PartitionSession, SessionConfig
from ..dynamic.store import GraphUpdate
from ..graph.generators import planted_partition
from .durable import DurableConfig, DurableSession, wal_path
from .faults import FaultInjector, InjectedFailure
from .snapshot import host_digest
from .transact import ResilientConfig, ResilientSession

__all__ = ["FuzzConfig", "EpisodeResult", "FuzzReport", "run_episode",
           "run_fuzz"]


@dataclass
class FuzzConfig:
    directory: str                  # workdir; episode e uses <dir>/ep<e>
    n: int = 600                    # fixed across episodes (jit-cache reuse)
    k: int = 4
    episodes: int = 20
    batches_per_episode: int = 12
    batch_size: int = 24
    seed: int = 0
    checkpoint_every: int = 4       # tight cadence: rotation under fire
    replicas: int = 2
    audit_cadence: int = 2
    drop: float = 0.12              # stream-mangling probabilities
    dup: float = 0.12
    swap: float = 0.15
    fault_rate: float = 0.5         # injections per submitted batch (avg)
    read_rate: float = 0.5          # serving reads per submitted batch
    invalid_batch_rate: float = 0.1  # producer emits a garbage batch


@dataclass
class EpisodeResult:
    seed: int
    commits: int = 0
    quarantined: int = 0
    faults: List[str] = field(default_factory=list)
    heals: int = 0
    heal_failures: int = 0          # ring exhausted -> disaster restore
    restores: int = 0
    replayed: int = 0
    failovers: int = 0
    strict_digest_checks: int = 0
    violations: List[str] = field(default_factory=list)
    seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return not self.violations


@dataclass
class FuzzReport:
    episodes: List[EpisodeResult] = field(default_factory=list)
    seconds: float = 0.0

    @property
    def ok(self) -> bool:
        return all(e.ok for e in self.episodes)

    @property
    def violations(self) -> List[str]:
        return [f"ep{e.seed}: {v}" for e in self.episodes
                for v in e.violations]

    def summary(self) -> dict:
        eps = self.episodes
        return dict(
            episodes=len(eps),
            ok=self.ok,
            commits=sum(e.commits for e in eps),
            quarantined=sum(e.quarantined for e in eps),
            faults=sum(len(e.faults) for e in eps),
            heals=sum(e.heals for e in eps),
            heal_failures=sum(e.heal_failures for e in eps),
            restores=sum(e.restores for e in eps),
            failovers=sum(e.failovers for e in eps),
            strict_digest_checks=sum(e.strict_digest_checks for e in eps),
            violations=self.violations,
            seconds=self.seconds,
        )


# The injection menu: every fault class the injector knows, weighted so
# cheap state corruptions dominate and process-level faults stay rare
# enough that most episodes still make forward progress.  Faults that
# fork the live timeline from the durable one undetectably (see module
# docstring) are flagged: a checkpoint must re-anchor before the strict
# digest contract holds again.
_FAULT_MENU = (
    ("corrupt_labels", 3),
    ("corrupt_base_csr", 2),
    ("corrupt_shard", 3),
    ("lose_shard", 2),
    ("corrupt_replica", 2),
    ("fail_next_extract", 1),
    ("fail_next_escalation", 1),
    ("fail_mid_checkpoint", 1),
    ("corrupt_wal", 1),
    ("bitflip_overlay", 1),
)
_TIMELINE_FORKING = frozenset({"corrupt_labels", "corrupt_wal"})


def _inject(name: str, inj: FaultInjector, ds: DurableSession) -> Optional[str]:
    """Fire one named fault against the running stack; returns the fault
    kind actually recorded (None when there was nothing to corrupt)."""
    sess, dep = ds.session, ds.rs.deployment
    if name == "corrupt_labels":
        f = inj.corrupt_labels(sess, count=2)
    elif name == "corrupt_base_csr":
        f = inj.corrupt_base_csr(
            sess.store, mode="weight" if inj.rng.random() < 0.5 else "endpoint"
        )
    elif name == "corrupt_shard":
        f = inj.corrupt_shard(dep)
    elif name == "lose_shard":
        f = inj.lose_shard(dep)
    elif name == "corrupt_replica":
        f = inj.corrupt_replica(dep)
    elif name == "fail_next_extract":
        f = inj.fail_next_extract(dep)
    elif name == "fail_next_escalation":
        f = inj.fail_next_escalation(sess)
    elif name == "fail_mid_checkpoint":
        f = inj.fail_mid_checkpoint(ds)
    elif name == "corrupt_wal":
        f = inj.corrupt_wal(ds)
    elif name == "bitflip_overlay":
        f = inj.bitflip_overlay(sess.store)
    else:  # pragma: no cover - menu/dispatch mismatch
        raise ValueError(name)
    return f.kind if f is not None else None


def _producer_batches(rng: np.random.Generator, n: int, count: int,
                      size: int, invalid_rate: float) -> List[GraphUpdate]:
    """One producer's batch list: random edge additions over the fixed
    node set, with an occasional garbage batch (endpoints past ``n``) that
    validation must quarantine without moving state."""
    out = []
    for _ in range(count):
        u = rng.integers(0, n, size)
        v = (u + 1 + rng.integers(0, n - 1, size)) % n
        if rng.random() < invalid_rate:
            u = u + n + 17        # out-of-range: the mangled-producer case
        out.append(GraphUpdate.add_edges(u, v))
    return out


def _digest_mismatch(a: dict, b: dict) -> Optional[str]:
    if a.keys() != b.keys():
        return f"digest keys differ: {sorted(a)} vs {sorted(b)}"
    for key in a:
        if not np.array_equal(a[key], b[key]):
            return f"digest field {key!r} differs"
    return None


def _force_checkpoint(ds: DurableSession, ep: EpisodeResult) -> bool:
    """Re-anchor durable state at the live session (two attempts: a
    pending one-shot mid-checkpoint kill consumes the first)."""
    for _ in range(2):
        if ds.checkpoint() is not None:
            return True
    ep.violations.append(
        f"checkpoint failed twice: {ds.last_checkpoint_error!r}"
    )
    return False


def _restore_drill(ds: DurableSession, ep: EpisodeResult,
                   tag: str) -> DurableSession:
    """Simulate process death + fresh-process restore; returns the
    restored stack (the episode continues on it).

    Call on a HEALED, re-anchored stack: the live session equals its last
    committed transaction and the WAL is intact past the anchor, so the
    restored digest must match bit-for-bit."""
    live = host_digest(ds.session)
    # no close(): a crash does not flush anything the commit path has not
    # already fsynced — restoring from exactly what is on disk is the test
    try:
        ds2, rep = DurableSession.restore(ds.cfg.directory)
    except Exception as e:
        ep.violations.append(f"{tag}: restore raised {e!r}")
        return ds
    ep.restores += 1
    ep.replayed += rep.records_replayed
    miss = _digest_mismatch(host_digest(ds2.session), live)
    ep.strict_digest_checks += 1
    if miss is not None:
        ep.violations.append(f"{tag}: restore not bit-identical: {miss}")
    audit = ds2.rs.auditor.audit()
    if not audit.ok:
        ep.violations.append(
            f"{tag}: restored session failed audit: {audit.failures}"
        )
    return ds2


def _disaster_restore(directory: str, ep: EpisodeResult,
                      tag: str) -> Optional[DurableSession]:
    """The runbook's last-resort path, exercised when no retained
    in-memory snapshot is clean: restore from disk, discarding restore
    points that audit dirty until one is healthy (``keep_checkpoints``
    retention exists precisely for this walk-back)."""
    for _ in range(8):
        try:
            ds2, _ = DurableSession.restore(directory)
        except FileNotFoundError:
            ep.violations.append(f"{tag}: no restorable checkpoint left")
            return None
        except Exception as e:
            ep.violations.append(f"{tag}: disaster restore raised {e!r}")
            return None
        ep.restores += 1
        if ds2.rs.auditor.audit().ok:
            return ds2
        bad = ds2.anchor_step
        shutil.rmtree(
            os.path.join(directory, f"step_{bad:08d}"), ignore_errors=True
        )
        try:
            os.remove(wal_path(directory, bad))
        except OSError:
            pass
    ep.violations.append(f"{tag}: no retained checkpoint audits clean")
    return None


def _read_block_checked(dep, b: int, ep: EpisodeResult) -> None:
    """A serving read; one retry absorbs a pending injected one-shot
    infrastructure failure in the synchronous-recovery fallback."""
    for attempt in (0, 1):
        try:
            shard = dep.read_block(b)
        except InjectedFailure:
            if attempt:
                ep.violations.append(f"read_block({b}) failed twice")
                return
            continue
        if shard is None or not dep.verify_shard(b, shard):
            ep.violations.append(f"read_block({b}) served a bad shard")
        return


def run_episode(cfg: FuzzConfig, ep_seed: int, g, labels0: np.ndarray,
                cut_ref: float, ew_ref: float) -> EpisodeResult:
    """One seeded episode over a fresh stack (cheap: restored from the
    golden labels, no V-cycle): mangled two-producer stream + interleaved
    faults + serving reads, a mid-episode crash/restore drill, and the
    heal-or-restore property checks at the end."""
    t0 = time.time()
    ep = EpisodeResult(seed=ep_seed)
    rng = np.random.default_rng(ep_seed)
    inj = FaultInjector(ep_seed)
    workdir = os.path.join(cfg.directory, f"ep{ep_seed}")

    sess = PartitionSession.from_restored(
        g, SessionConfig(k=cfg.k, seed=0),
        labels=labels0.copy(), step=0, cut_ref=cut_ref, ew_ref=ew_ref,
    )
    from ..deploy.replicate import ReplicatedDeployment
    dep = ReplicatedDeployment(sess, replicas=cfg.replicas)
    rs = ResilientSession(
        sess, deployment=dep,
        cfg=ResilientConfig(audit_cadence=cfg.audit_cadence),
    )
    ds = DurableSession(rs, DurableConfig(
        directory=workdir, checkpoint_every=cfg.checkpoint_every,
    ))

    # two producers, independently mangled, merged by original seq — the
    # transactional layer sees drops as gaps, dups as replays, swaps as
    # out-of-order arrivals
    half = cfg.batches_per_episode - cfg.batches_per_episode // 2
    batches = _producer_batches(
        rng, cfg.n, half, cfg.batch_size, cfg.invalid_batch_rate
    ) + _producer_batches(
        rng, cfg.n, cfg.batches_per_episode // 2, cfg.batch_size,
        cfg.invalid_batch_rate,
    )
    stream = inj.mangle_stream(
        batches, drop=cfg.drop, dup=cfg.dup, swap=cfg.swap
    )

    names = [name for name, w in _FAULT_MENU for _ in range(w)]
    forked = False                  # durable/live timelines diverged
    ckpts_seen = ds.checkpoints_written
    drill_at = int(rng.integers(1, max(2, len(stream)))) \
        if len(stream) > 1 else None

    def sync_rotation() -> None:
        # any successful checkpoint rotates the WAL and re-anchors the
        # durable timeline at the live state, healing a fork
        nonlocal forked, ckpts_seen
        if ds.checkpoints_written > ckpts_seen:
            ckpts_seen = ds.checkpoints_written
            forked = False

    def heal_or_restore(tag: str) -> bool:
        # heal in memory; when the ring is exhausted, fall back to the
        # disaster-restore walk.  Returns False when even that failed.
        nonlocal ds, dep, forked, ckpts_seen
        rep = ds.heal()
        ep.heals += 1
        sync_rotation()
        if not rep.ok:
            ep.heal_failures += 1
            nds = _disaster_restore(ds.cfg.directory, ep, tag)
            if nds is None:
                return False
            ds, dep = nds, nds.rs.deployment
            forked, ckpts_seen = False, ds.checkpoints_written
        if ds.rs.degraded:
            ep.violations.append(f"{tag}: degraded after clean heal")
        return True

    for i, (seq, upd) in enumerate(stream):
        if rng.random() < cfg.fault_rate:
            kind = _inject(str(rng.choice(names)), inj, ds)
            if kind is not None:
                ep.faults.append(kind)
                forked = forked or kind in _TIMELINE_FORKING
        tx = ds.submit(upd, seq=seq)
        for t in [tx] + tx.followups:
            ep.commits += int(t.committed)
            ep.quarantined += int(t.quarantined)
        sync_rotation()
        if rng.random() < cfg.read_rate:
            _read_block_checked(dep, int(rng.integers(0, cfg.k)), ep)
        if i == drill_at:
            # mid-episode kill: heal first (the strict digest contract
            # needs the live session at a committed, audited state)
            if heal_or_restore("mid-episode heal"):
                if forked and _force_checkpoint(ds, ep):
                    sync_rotation()
                if not forked:
                    ds = _restore_drill(ds, ep, tag="mid-episode")
                    dep = ds.rs.deployment
                    ckpts_seen = ds.checkpoints_written
            # retire the old injector (restore any armed-but-unfired
            # one-shot patches, e.g. the process-global ckpt.save hook)
            # and rebind to the (possibly new) live objects
            inj.disarm()
            inj = FaultInjector(ep_seed + 1)

    # ---- episode end: the heal-or-restore property -----------------------
    if heal_or_restore("final heal"):
        if forked and _force_checkpoint(ds, ep):
            sync_rotation()
        if not forked:
            ds = _restore_drill(ds, ep, tag="final")
            dep = ds.rs.deployment
        try:
            dep.run_recovery()
        except InjectedFailure:
            dep.run_recovery()      # one-shot hook consumed; must succeed
        for b in range(cfg.k):
            _read_block_checked(dep, b, ep)
        ep.failovers = dep.failovers
    inj.disarm()    # a hook left armed would leak into the next episode
    ep.seconds = time.time() - t0
    return ep


def run_fuzz(cfg: FuzzConfig) -> FuzzReport:
    """Run the full fuzzing campaign: one golden partition (the only
    V-cycle), then ``cfg.episodes`` seeded episodes over fresh stacks."""
    t0 = time.time()
    os.makedirs(cfg.directory, exist_ok=True)
    g = planted_partition(cfg.n, cfg.k, 12, 2, seed=0)
    golden = PartitionSession(g, SessionConfig(k=cfg.k, seed=0))
    labels0 = golden.labels_np()
    cut_ref, ew_ref = golden._cut_ref, golden._ew_ref
    report = FuzzReport()
    for e in range(cfg.episodes):
        report.episodes.append(run_episode(
            cfg, cfg.seed * 1000 + e, g, labels0, cut_ref, ew_ref
        ))
    report.seconds = time.time() - t0
    return report
