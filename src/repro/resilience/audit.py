"""Device-side invariant auditor (resilience, layer 2).

Three invariant families, each checked by cheap device reductions:

* **CSR well-formedness** of the store's resident base — monotone
  zero-based ``indptr`` closed at ``m``, inert padding (rows ``>= n`` hold
  ``m``, arcs ``>= m`` hold 0/0), endpoints in range, no self loops,
  ``src`` consistent with ``indptr`` (degree sums), and arc symmetry via a
  uint32 wrap-sum checksum (``sum H(u, v, w) == sum H(v, u, w)`` over live
  arcs — order-free, one pass, necessary-not-sufficient by design: a
  counterexample needs two corruptions whose hashes cancel mod 2^32);
* **partition health** — labels in ``[0, k)``, the stored (trajectory)
  cut bitwise-equal to a recomputation through the *same* engine
  reduction, block weights feasible against the current ``L_max``;
* **shard health** — the wrap-sum of every shard's owned-row global arcs
  equals the base CSR's arc checksum (blocks partition the node set, so
  each arc is owned exactly once — reassembly equality without
  materializing a reassembly), and every ghost's recorded owner block
  matches the served labels.

Every audit kernel is one ``jax.jit`` executable reused across the stream;
dispatch shapes are recorded through ``EngineStats.note_audit_key`` so the
``audit_compiles == audit_bucket_count`` discipline is regression-tested
like every other kernel family.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..core.metrics import lmax
from ..obs import span as _obs_span

__all__ = ["AuditReport", "InvariantAuditor"]


# --------------------------------------------------------------- device side

def _mix(u, v, wbits):
    """Order-free arc hash: identical in every checksum kernel, so shard
    sums are directly comparable with the base CSR's."""
    uu = u.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
    vv = v.astype(jnp.uint32) * jnp.uint32(0x85EBCA6B)
    h = (uu ^ vv ^ wbits) + jnp.uint32(0x165667B1)
    return h * jnp.uint32(0x27D4EB2F)


@jax.jit
def _csr_audit(indptr, src, dst, ew, nw, n, m):
    """All base-CSR invariants in one executable.

    Returns ``(flags, chk_fwd, chk_rev)``: 8 bools (see ``_CSR_FLAGS``)
    plus the forward/transposed arc checksums — ``chk_fwd`` doubles as the
    reference the shard reassembly audit compares against.
    """
    Nb = indptr.shape[0] - 1
    Mb = src.shape[0]
    iota_n = jnp.arange(Nb + 1, dtype=jnp.int32)
    iota_m = jnp.arange(Mb, dtype=jnp.int32)
    live = iota_m < m
    mono = jnp.all(indptr[1:] >= indptr[:-1])
    closed = (indptr[0] == 0) & jnp.all(
        jnp.where(iota_n >= n, indptr == m, True)
    )
    in_range = jnp.all(
        jnp.where(live, (src >= 0) & (src < n) & (dst >= 0) & (dst < n), True)
    )
    no_self = jnp.all(jnp.where(live, src != dst, True))
    # src consistent with indptr: arc i lies inside its source's row
    row_lo = jnp.take(indptr, jnp.clip(src, 0, Nb - 1))
    row_hi = jnp.take(indptr, jnp.clip(src, 0, Nb - 1) + 1)
    deg_ok = jnp.all(jnp.where(live, (row_lo <= iota_m) & (iota_m < row_hi), True))
    w_pos = jnp.all(jnp.where(live, ew > 0.0, True))
    pad_inert = jnp.all(
        jnp.where(live, True, (src == 0) & (dst == 0) & (ew == 0.0))
    )
    nw_pad = jnp.all(
        jnp.where(jnp.arange(nw.shape[0], dtype=jnp.int32) >= n, nw == 0.0, True)
    )
    wbits = jax.lax.bitcast_convert_type(ew, jnp.uint32)
    h_fwd = jnp.where(live, _mix(src, dst, wbits), jnp.uint32(0))
    h_rev = jnp.where(live, _mix(dst, src, wbits), jnp.uint32(0))
    flags = jnp.stack([
        mono, closed, in_range, no_self, deg_ok, w_pos, pad_inert, nw_pad
    ])
    return flags, jnp.sum(h_fwd), jnp.sum(h_rev)


_CSR_FLAGS = [
    "indptr_monotone", "indptr_closed", "endpoints_in_range",
    "self_loop_free", "src_indptr_consistent", "weights_positive",
    "arc_padding_inert", "nw_padding_zero",
]


@jax.jit
def _labels_audit(labels, n, k):
    iota = jnp.arange(labels.shape[0], dtype=jnp.int32)
    live = iota < n
    return jnp.all(jnp.where(live, (labels >= 0) & (labels < k), True))


@jax.jit
def _shard_owned_chk(own_g, ghost_g, indptr, indices, ew, n_own, m_local):
    """uint32 wrap-sum of one shard's owned-row arcs in GLOBAL ids.

    Local rank ``r`` maps to ``own_g[r]`` below ``n_own`` and
    ``ghost_g[r - n_own]`` above (the extractor's layout-sort order);
    heads are local ranks, rows recovered by ``searchsorted`` on the
    local indptr.  Padding arcs and non-owned rows are masked out."""
    Eb = indices.shape[0]
    Ob = own_g.shape[0]
    Gb = ghost_g.shape[0]
    iota_e = jnp.arange(Eb, dtype=jnp.int32)
    row_of = (jnp.searchsorted(indptr, iota_e, side="right") - 1).astype(
        jnp.int32
    )
    live = (iota_e < m_local) & (row_of >= 0) & (row_of < n_own)
    u_g = jnp.take(own_g, jnp.clip(row_of, 0, Ob - 1))
    head_own = jnp.take(own_g, jnp.clip(indices, 0, Ob - 1))
    head_gho = jnp.take(ghost_g, jnp.clip(indices - n_own, 0, Gb - 1))
    v_g = jnp.where(indices < n_own, head_own, head_gho)
    wbits = jax.lax.bitcast_convert_type(ew, jnp.uint32)
    return jnp.sum(jnp.where(live, _mix(u_g, v_g, wbits), jnp.uint32(0)))


@jax.jit
def _ghost_owner_audit(ghost_g, ghost_block, labels, n_ghost):
    iota = jnp.arange(ghost_g.shape[0], dtype=jnp.int32)
    live = iota < n_ghost
    A = labels.shape[0]
    lab_of = jnp.take(labels, jnp.clip(ghost_g, 0, A - 1))
    return jnp.all(jnp.where(live, lab_of == ghost_block, True))


# ---------------------------------------------------------------- host side

@dataclass
class AuditReport:
    """Outcome of one audit pass."""

    step: int
    ok: bool
    failures: List[str] = field(default_factory=list)
    checked: List[str] = field(default_factory=list)
    stored_cut: float = 0.0
    recomputed_cut: float = 0.0
    seconds: float = 0.0

    def fail(self, what: str) -> None:
        self.failures.append(what)
        self.ok = False


class InvariantAuditor:
    """Configurable-cadence auditor over a session (+ optional deployment).

    ``maybe_audit(step)`` runs a full pass every ``cadence`` committed
    steps (always at ``cadence=1``); ``audit()`` forces one.  Each pass is
    a handful of device reductions over already-resident arrays — no data
    movement beyond a few scalars — so steady-state overhead at cadence
    ``>= 8`` stays in the noise (benchmarked in ``resilience_hot``).
    """

    def __init__(self, session, deployment=None, cadence: int = 8):
        if cadence < 1:
            raise ValueError("cadence must be >= 1")
        self.session = session
        self.deployment = deployment
        self.cadence = int(cadence)
        self.audits = 0
        self.failed_audits = 0
        self.reports: List[AuditReport] = []

    # ------------------------------------------------------------- internals

    def _note(self, key) -> None:
        st = self.session.engine.stats
        st.audit_calls += 1
        st.note_audit_key(key)

    def _audit_graph(self, rep: AuditReport) -> Optional[np.uint32]:
        """CSR well-formedness of the resident base; returns the arc
        checksum for the shard pass (None when structure is broken)."""
        g = self.session.store.base
        flags, chk_f, chk_r = _csr_audit(
            g.indptr, g.src, g.indices, g.ew, g.nw,
            jnp.int32(g.n), jnp.int32(g.m),
        )
        self._note(("csr", g.indptr.shape[0], g.src.shape[0]))
        flags = np.asarray(flags)
        self.session.engine.stats.d2h_bytes += flags.nbytes + 8
        for name, okay in zip(_CSR_FLAGS, flags):
            rep.checked.append(f"csr:{name}")
            if not bool(okay):
                rep.fail(f"csr:{name}")
        chk_f, chk_r = np.uint32(chk_f), np.uint32(chk_r)
        rep.checked.append("csr:arc_symmetry")
        if chk_f != chk_r:
            rep.fail("csr:arc_symmetry")
        return chk_f if rep.ok else None

    def _audit_partition(self, rep: AuditReport) -> None:
        sess = self.session
        g = sess.store.base
        in_range = _labels_audit(
            sess.labels, jnp.int32(sess.store.n), jnp.int32(sess.k)
        )
        self._note(("labels", sess.labels.shape[0]))
        rep.checked.append("partition:labels_in_range")
        if not bool(in_range):
            rep.fail("partition:labels_in_range")
            return  # cut/bw of out-of-range labels is meaningless
        # recompute through the SAME engine reductions the serving loop
        # scored with: identical arrays, identical reduction shapes ->
        # bitwise-equal floats, so exact comparison is sound
        rep.stored_cut = float(sess.trajectory[-1].cut)
        rep.recomputed_cut = sess.engine.cut(g, sess.labels)
        rep.checked.append("partition:cut_matches")
        if rep.recomputed_cut != rep.stored_cut:
            rep.fail("partition:cut_matches")
        bw = sess.engine.block_weights(g, sess.labels, sess.k)
        L = lmax(sess.store.total_node_weight, sess.k, sess.cfg.eps)
        rep.checked.append("partition:feasible")
        if float(bw.max()) > L + 1e-6:
            rep.fail("partition:feasible")
        rep.checked.append("partition:weights_conserved")
        if not np.isclose(float(bw.sum()), sess.store.total_node_weight):
            rep.fail("partition:weights_conserved")

    def _audit_shards(self, rep: AuditReport, base_chk: Optional[np.uint32]) -> None:
        dep = self.deployment
        if dep is None:
            return
        if dep.stale:
            # a failed migration left the set on its last consistent state:
            # shards lag the session by design, so content checks against
            # the current graph would false-positive — surfaced, not failed
            rep.checked.append("shards:skipped_stale")
            return
        total = 0  # python int; reduced mod 2**32 at the end (wrap-sum)
        for s in dep.shards:
            if s is None:
                rep.fail("shards:missing_shard")
                return
            chk = _shard_owned_chk(
                s.own_g, s.ghost_g, s.indptr, s.indices, s.ew,
                jnp.int32(s.n_own), jnp.int32(s.m_local),
            )
            self._note(
                ("shard", s.own_g.shape[0], s.ghost_g.shape[0],
                 s.indices.shape[0])
            )
            gok = _ghost_owner_audit(
                s.ghost_g, s.ghost_block_dev, self.session.labels,
                jnp.int32(s.n_ghost),
            )
            self._note(("ghost", s.ghost_g.shape[0], self.session.labels.shape[0]))
            self.session.engine.stats.d2h_bytes += 5
            if not bool(gok):
                rep.fail(f"shards:ghost_owner_block_{s.block}")
            total = (total + int(chk)) & 0xFFFFFFFF
        rep.checked.append("shards:reassembly_checksum")
        rep.checked.append("shards:ghost_owner_map")
        if base_chk is not None and np.uint32(total) != base_chk:
            rep.fail("shards:reassembly_checksum")

    # ---------------------------------------------------------------- public

    def audit(self) -> AuditReport:
        """One full invariant pass; appends and returns the report."""
        t0 = time.time()
        sess = self.session
        rep = AuditReport(step=sess._step, ok=True)
        with _obs_span(
            "resilience.audit", cat="resilience", step=sess._step
        ) as sp:
            # audits run against the compacted base (the served graph); a
            # dirty overlay is pending-but-valid state, not a violation
            sess.store.graph()
            base_chk = self._audit_graph(rep)
            self._audit_partition(rep)
            self._audit_shards(rep, base_chk)
            sp.set(ok=rep.ok)
        rep.seconds = time.time() - t0
        self.audits += 1
        if not rep.ok:
            self.failed_audits += 1
        self.reports.append(rep)
        return rep

    def maybe_audit(self, step: int) -> Optional[AuditReport]:
        """Cadence gate: audit on every ``cadence``-th step."""
        if step % self.cadence == 0:
            return self.audit()
        return None

    def stats(self) -> dict:
        return dict(
            audits=self.audits,
            failed_audits=self.failed_audits,
            audit_cadence=self.cadence,
        )
