"""Versioned snapshots of the serving session (resilience, layer 1).

A snapshot is cheap by construction, not by compression: every device
payload in the session is an **immutable** jax array (labels, base-CSR
arrays, node weights) or a **rebind-only** host array (the store's ``_nw``
mirror), and the store's overlay chunks are appended but never mutated in
place.  Capturing the state is therefore taking references plus copying
the overlay chunk *lists* — O(pending-chunks) host work, zero device work,
zero data movement — and rolling back is rebinding those references.  The
cost scales with the delta since the last compaction, not with the graph.

Restoring a version makes the session bit-identical to the moment the
snapshot was taken: same labels, same base handle (so engine caches keyed
on its identity stay warm), same overlay, same step counter — replaying
the same update stream from a restored state reproduces the same labels
bit for bit, because every repair seed derives from the step counter
(parity-tested against the :func:`host_digest` numpy oracle).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..obs import span as _obs_span
from ..obs.memory import pin as _mem_pin

__all__ = ["SessionSnapshot", "SnapshotManager", "host_digest"]


@dataclass
class SessionSnapshot:
    """One captured version of the full session state."""

    version: int
    step: int                   # session step counter at capture time
    state: dict = field(repr=False)  # PartitionSession.snapshot_state()
    seconds: float = 0.0        # capture cost (host bookkeeping only)


def host_digest(session) -> Dict[str, np.ndarray]:
    """Deep host-side copy of everything the session serves — the numpy
    oracle the rollback parity tests compare against.

    Unlike :class:`SessionSnapshot` (references), every array here is a
    materialized copy: equal digests before a batch and after its rollback
    prove bit-identical restoration with no reference aliasing involved."""
    gh = session.store.csr_host()
    ou = session.store._ou
    return dict(
        labels=session.labels_np().copy(),
        nw=session.store.node_weights().copy(),
        indptr=np.asarray(gh.indptr).copy(),
        indices=np.asarray(gh.indices).copy(),
        ew=np.asarray(gh.ew).copy(),
        overlay_u=(np.concatenate(ou) if ou else np.zeros(0, np.int32)).copy(),
        step=np.int64(session._step),
        cut_ref=np.float64(session._cut_ref),
    )


class SnapshotManager:
    """Ring of versioned snapshots over one :class:`PartitionSession`.

    ``take()`` captures the current state and returns its version id;
    ``rollback(version)`` restores it (and drops every newer version — the
    timeline forks, exactly like a transactional abort).  Retention is
    bounded by ``keep``: the oldest snapshots are discarded first, so a
    long-lived session holds O(keep) extra references, and the device
    arrays they pin are freed as versions expire.
    """

    def __init__(self, session, keep: int = 8):
        if keep < 1:
            raise ValueError("keep must be >= 1")
        self.session = session
        self.keep = int(keep)
        self._snaps: List[SessionSnapshot] = []
        self._next_version = 0
        self.takes = 0
        self.rollbacks = 0

    # ---------------------------------------------------------------- queries

    @property
    def versions(self) -> List[int]:
        return [s.version for s in self._snaps]

    @property
    def latest(self) -> Optional[SessionSnapshot]:
        return self._snaps[-1] if self._snaps else None

    def get(self, version: int) -> SessionSnapshot:
        for s in self._snaps:
            if s.version == version:
                return s
        raise KeyError(f"snapshot version {version} not retained")

    # ------------------------------------------------------------------- ops

    def take(self) -> int:
        """Capture the current session state; returns the new version id."""
        t0 = time.time()
        with _obs_span(
            "resilience.snapshot", cat="resilience",
            version=self._next_version,
        ):
            snap = SessionSnapshot(
                version=self._next_version,
                step=self.session._step,
                state=self.session.snapshot_state(),
            )
        snap.seconds = time.time() - t0
        # snapshot_refs are *pins*, not owned allocations: the arrays they
        # hold belong to other families (labels arena, base CSR), so the
        # accountant tracks them non-additively — retention keeps device
        # memory alive, it does not allocate more of it
        st = snap.state
        base = st["store"]["base"]
        _mem_pin(
            "snapshot_refs", st["labels"], st["store"]["nw_dev"],
            base.indptr, base.indices, base.ew, base.nw,
            getattr(base, "src", None),
        )
        self._next_version += 1
        self._snaps.append(snap)
        if len(self._snaps) > self.keep:
            self._snaps = self._snaps[-self.keep:]
        self.takes += 1
        return snap.version

    def rollback(self, version: int) -> SessionSnapshot:
        """Restore ``version`` and discard every newer snapshot."""
        snap = self.get(version)
        self.session.restore_state(snap.state)
        self._snaps = [s for s in self._snaps if s.version <= version]
        self.rollbacks += 1
        return snap
