"""Fault-tolerance layer around the dynamic session and deploy subsystems.

The serving stack (PR 4's :class:`~repro.dynamic.session.PartitionSession`,
PR 5's :class:`~repro.deploy.migrate.ShardDeployment`) keeps partition
state resident on device across an unbounded update stream — which means a
single malformed batch, a repeatedly-failing repair, or a corrupted shard
would poison that state forever.  This package makes the partition a
transactional, auditable artifact:

* :mod:`~repro.resilience.snapshot` — versioned O(delta) snapshots of the
  full session state with bit-identical rollback;
* :mod:`~repro.resilience.audit` — device-side invariant auditor (CSR
  well-formedness, partition health, shard health) at configurable cadence;
* :mod:`~repro.resilience.faults` — seeded deterministic fault injection,
  so every recovery path is exercised in tests rather than claimed;
* :mod:`~repro.resilience.transact` — the transactional serving loop:
  validate -> apply -> audit -> commit-or-rollback, with quarantine,
  bounded retry, an escalation watchdog, and explicit degraded mode;
* :mod:`~repro.resilience.durable` — disaster recovery: atomic durable
  checkpoints + a per-commit fsynced write-ahead log, with fresh-process
  ``restore()`` replaying the WAL to a bit-identical session (ISSUE 7);
* :mod:`~repro.resilience.fuzz` — the end-to-end fault fuzzer: seeded
  episodes interleaving every fault class against mangled concurrent
  update streams, asserting the stack heals or restores to the oracle.
"""

from .audit import AuditReport, InvariantAuditor
from .faults import FaultInjector, InjectedFault
from .snapshot import SessionSnapshot, SnapshotManager, host_digest
from .transact import (
    QuarantinedBatch,
    ResilientConfig,
    ResilientSession,
    TxResult,
)
from .durable import (
    DurableConfig,
    DurableSession,
    RestoreReport,
    WalRecord,
    read_wal,
)
from .fuzz import EpisodeResult, FuzzConfig, FuzzReport, run_fuzz

__all__ = [
    "AuditReport",
    "DurableConfig",
    "DurableSession",
    "EpisodeResult",
    "FaultInjector",
    "FuzzConfig",
    "FuzzReport",
    "InjectedFault",
    "InvariantAuditor",
    "QuarantinedBatch",
    "ResilientConfig",
    "ResilientSession",
    "RestoreReport",
    "SessionSnapshot",
    "SnapshotManager",
    "TxResult",
    "WalRecord",
    "host_digest",
    "read_wal",
    "run_fuzz",
]
