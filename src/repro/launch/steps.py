"""AOT-compilable train / prefill / decode steps with explicit shardings.

These builders are shared by the real drivers (train.py, serve.py) and the
multi-pod dry-run (dryrun.py): the dry-run lowers exactly the functions the
drivers execute.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, Shape
from ..models.model import (
    decode_step as _decode,
    forward,
    init_caches,
    init_params,
    loss_fn,
    prefill as _prefill,
)
from ..models.sharding import DP, TP, act_specs, param_pspecs
from ..optim.adamw import AdamWState, adamw_init, adamw_update
from ..optim.compression import compress_decompress

__all__ = [
    "input_specs",
    "state_specs",
    "norm_spec",
    "make_train_step",
    "make_prefill",
    "make_decode_step",
    "abstract_params",
    "abstract_opt",
]


def norm_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on axes that don't divide the dimension."""
    parts = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            parts.append(None)
            continue
        size = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            size *= mesh.shape[a]
        parts.append(ax if shape[i] % size == 0 else None)
    parts += [None] * (len(shape) - len(parts))
    return P(*parts)


def _shardings(tree, specs, mesh):
    return jax.tree.map(
        lambda leaf, spec: NamedSharding(mesh, norm_spec(spec, leaf.shape, mesh)),
        tree, specs,
    )


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def abstract_opt(aparams):
    return jax.eval_shape(adamw_init, aparams)


def input_specs(cfg: ArchConfig, shape: Shape):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.batch, shape.seq
    if shape.kind == "train":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.n_prefix:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix, cfg.d_model), jnp.float32
            )
        return out
    if shape.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if cfg.n_prefix:
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_prefix, cfg.d_model), jnp.float32
            )
        return out
    # decode: one new token against an S-length cache
    caches = jax.eval_shape(lambda: init_caches(cfg, B, S))
    return {
        "token": jax.ShapeDtypeStruct((B,), jnp.int32),
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_specs(cfg: ArchConfig, mesh: Mesh, multi_pod: bool):
    """(abstract params, abstract opt, param shardings, opt shardings)."""
    ap = abstract_params(cfg)
    specs = param_pspecs(ap, multi_pod)
    psh = _shardings(ap, specs, mesh)
    ao = abstract_opt(ap)
    osh = AdamWState(
        step=NamedSharding(mesh, P()),
        mu=psh, nu=psh, master=psh,
    )
    return ap, ao, psh, osh


def _batch_shardings(cfg, shape, mesh, multi_pod):
    dp = DP(multi_pod)
    dp = dp if len(dp) > 1 else dp[0]
    ins = input_specs(cfg, shape)
    out = {}
    for k, v in ins.items():
        if k == "tokens":
            out[k] = NamedSharding(mesh, norm_spec(P(dp, None), v.shape, mesh))
        elif k == "prefix_embeds":
            out[k] = NamedSharding(mesh, norm_spec(P(dp, None, None), v.shape, mesh))
        elif k == "token":
            out[k] = NamedSharding(mesh, norm_spec(P(dp), v.shape, mesh))
        elif k == "pos":
            out[k] = NamedSharding(mesh, P())
        elif k == "caches":
            def cache_shard(path, leaf):
                # leaf names: k/v (B,S,kv,dh), h (B,H,P,N), conv (B,W,d_in);
                # scan-stacked variants carry a leading (n_units,) axis
                name = [getattr(q, "key", None) for q in path][-1]
                stacked = leaf.ndim in (4, 5) and name in ("k", "v") and leaf.ndim == 5
                stacked = stacked or (name in ("h",) and leaf.ndim == 5) or (
                    name == "conv" and leaf.ndim == 4)
                if name in ("k", "v"):
                    base = P(dp, TP, None, None)      # seq over TP
                elif name == "h":
                    base = P(dp, TP, None, None)      # SSM heads over TP
                elif name == "conv":
                    base = P(dp, None, TP)            # d_inner over TP
                else:
                    base = P(*([None] * (leaf.ndim,)))
                if stacked:
                    base = P(None, *base)
                return NamedSharding(mesh, norm_spec(base, leaf.shape, mesh))
            out[k] = jax.tree_util.tree_map_with_path(cache_shard, v)
    return out


def make_train_step(
    cfg: ArchConfig,
    mesh: Mesh,
    *,
    multi_pod: bool = False,
    lr: float = 3e-4,
    remat: bool = True,
    compress_grads: bool = False,
    donate: bool = True,
):
    """Returns (jitted train_step, batch shardings, param/opt shardings)."""
    ap, ao, psh, osh = state_specs(cfg, mesh, multi_pod)

    def train_step(params, opt, batch):
        if compress_grads:
            opt, residuals = opt

        def lf(p):
            return loss_fn(cfg, p, batch, mesh=mesh, multi_pod=multi_pod, remat=remat)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if compress_grads:
            # int8 error-feedback compression on the DP-axis reduction
            grads, residuals = compress_decompress(grads, residuals)
        new_params, new_opt, gnorm = adamw_update(grads, opt, params, lr=lr)
        if compress_grads:
            new_opt = (new_opt, residuals)
        return new_params, new_opt, {
            "loss": loss, "ce": metrics["ce"], "gnorm": gnorm,
        }

    if compress_grads:
        osh = (osh, psh)
    return train_step, psh, osh


def compile_train_step(cfg, mesh, shape, *, multi_pod=False, lr=3e-4, remat=True):
    """AOT lower+compile the train step for the dry-run."""
    ap, ao, psh, osh = state_specs(cfg, mesh, multi_pod)
    bsh = _batch_shardings(cfg, shape, mesh, multi_pod)
    fn, _, _ = make_train_step(cfg, mesh, multi_pod=multi_pod, lr=lr, remat=remat)
    jitted = jax.jit(
        fn,
        in_shardings=(psh, osh, bsh),
        out_shardings=(psh, osh, None),
        donate_argnums=(0, 1),
    )
    lowered = jitted.lower(ap, ao, input_specs(cfg, shape))
    return lowered


def compile_prefill(cfg, mesh, shape, *, multi_pod=False):
    ap, _, psh, _ = state_specs(cfg, mesh, multi_pod)
    bsh = _batch_shardings(cfg, shape, mesh, multi_pod)
    ins = input_specs(cfg, shape)

    def prefill_step(params, batch):
        return _prefill(
            cfg, params, batch["tokens"], mesh=mesh, multi_pod=multi_pod,
            prefix_embeds=batch.get("prefix_embeds"),
        )

    jitted = jax.jit(prefill_step, in_shardings=(psh, bsh))
    return jitted.lower(ap, ins)


def compile_decode(cfg, mesh, shape, *, multi_pod=False):
    ap, _, psh, _ = state_specs(cfg, mesh, multi_pod)
    bsh = _batch_shardings(cfg, shape, mesh, multi_pod)
    ins = input_specs(cfg, shape)

    def serve_step(params, token, caches, pos):
        return _decode(cfg, params, token, caches, pos, mesh=mesh,
                       multi_pod=multi_pod)

    jitted = jax.jit(
        serve_step,
        in_shardings=(psh, bsh["token"], bsh["caches"], bsh["pos"]),
        out_shardings=(None, bsh["caches"]),
        donate_argnums=(2,),
    )
    return jitted.lower(ap, ins["token"], ins["caches"], ins["pos"])


def make_prefill(cfg, mesh, *, multi_pod=False):
    _, _, psh, _ = state_specs(cfg, mesh, multi_pod)

    def prefill_step(params, batch):
        return _prefill(cfg, params, batch["tokens"], mesh=mesh,
                        multi_pod=multi_pod,
                        prefix_embeds=batch.get("prefix_embeds"))

    return jax.jit(prefill_step, in_shardings=(psh, None))


def make_decode_step(cfg, mesh, *, multi_pod=False):
    _, _, psh, _ = state_specs(cfg, mesh, multi_pod)

    def serve_step(params, token, caches, pos):
        return _decode(cfg, params, token, caches, pos, mesh=mesh,
                       multi_pod=multi_pod)

    return jax.jit(serve_step, in_shardings=(psh, None, None, None),
                   donate_argnums=(2,))
