"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run forces 512 host devices while tests/benches must see 1.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2, 2) on 4 host devices).

    ``axis_types`` only exists on newer jax (``jax.sharding.AxisType`` is
    absent in 0.4.x, where Auto is already the default) — construct with it
    when available, plainly otherwise.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(shape), tuple(axes),
                axis_types=(axis_type.Auto,) * len(axes),
            )
        except TypeError:  # AxisType present but make_mesh predates the kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))
