"""Batched serving driver: prefill a request batch, then decode tokens.

Example (CPU container, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time


def pad_caches(cfg, caches, cur_len: int, max_len: int):
    """Grow prefill caches to decode capacity (attention K/V only)."""
    import jax.numpy as jnp
    import jax

    def grow(leaf):
        # attention caches are (B, S, kv, dh); mamba caches keep their shape
        if leaf.ndim == 4 and leaf.shape[1] == cur_len and leaf.shape[3] == cfg.d_head:
            pad = max_len - cur_len
            if pad <= 0:
                return leaf
            return jnp.pad(leaf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if leaf.ndim == 5 and leaf.shape[2] == cur_len and leaf.shape[4] == cfg.d_head:
            pad = max_len - cur_len
            if pad <= 0:
                return leaf
            return jnp.pad(leaf, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        return leaf

    return jax.tree.map(grow, caches)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models.model import decode_step, init_params, prefill

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab)
    pe = (
        jax.random.normal(key, (B, cfg.n_prefix, cfg.d_model), jnp.float32)
        if cfg.n_prefix
        else None
    )

    t0 = time.time()
    last_logits, caches = prefill(cfg, params, prompts, prefix_embeds=pe)
    max_len = S + cfg.n_prefix + args.gen
    caches = pad_caches(cfg, caches, S + cfg.n_prefix, max_len)
    print(f"[prefill] {B}x{S} in {time.time()-t0:.1f}s")

    step = jax.jit(
        lambda p, t, c, pos: decode_step(cfg, p, t, c, pos),
        donate_argnums=(2,),
    )
    tok = jnp.argmax(last_logits, axis=-1)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        logits, caches = step(params, tok, caches, jnp.int32(S + cfg.n_prefix + i))
        tok = jnp.argmax(logits, axis=-1)
        out.append(tok)
    toks = jnp.stack(out, axis=1)
    dt = time.time() - t0
    print(f"[decode] {args.gen - 1} steps in {dt:.1f}s "
          f"({(args.gen - 1) * B / max(dt, 1e-9):.1f} tok/s)")
    print("[sample tokens]", np.asarray(toks[0])[:16] if (np := __import__('numpy')) else None)
    return toks


if __name__ == "__main__":
    main()
