import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes and extract roofline terms.

MUST keep the two lines above first — jax locks the device count on first
initialization, and the production meshes need 512 placeholder devices.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k --mesh single
  python -m repro.launch.dryrun --driver            # all cells, subprocesses
  python -m repro.launch.dryrun --driver --mesh multi
Results accumulate as JSON under results/dryrun/.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback


VARIANTS = {
    # name -> env toggles applied before model import (see layers.py)
    "base": {"REPRO_CACHE_UPDATE": "dus", "REPRO_ATTN_DTYPE": "f32",
             "REPRO_SSD_DTYPE": "f32"},
    "where_update": {"REPRO_CACHE_UPDATE": "where", "REPRO_ATTN_DTYPE": "f32"},
    "attn_bf16": {"REPRO_CACHE_UPDATE": "where", "REPRO_ATTN_DTYPE": "bf16"},
    "opt": {"REPRO_CACHE_UPDATE": "where", "REPRO_ATTN_DTYPE": "bf16",
            "REPRO_SSD_DTYPE": "bf16"},
    "ssd_q128": {"REPRO_SSD_DTYPE": "bf16", "REPRO_SSD_CHUNK": "128"},
    "ssd_q64": {"REPRO_SSD_DTYPE": "bf16", "REPRO_SSD_CHUNK": "64"},
    "ssd_bf16": {"REPRO_SSD_DTYPE": "bf16"},
}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             variant: str = "base") -> dict:
    for k, v in VARIANTS.get(variant, {}).items():
        os.environ[k] = v
    import gzip

    import jax

    from ..configs import SHAPES, get_config
    from .hlo_analysis import analyze_hlo
    from .mesh import make_production_mesh
    from .roofline import param_counts, roofline
    from .steps import compile_decode, compile_prefill, compile_train_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256

    t0 = time.time()
    if shape.kind == "train":
        lowered = compile_train_step(cfg, mesh, shape, multi_pod=multi_pod)
    elif shape.kind == "prefill":
        lowered = compile_prefill(cfg, mesh, shape, multi_pod=multi_pod)
    else:
        lowered = compile_decode(cfg, mesh, shape, multi_pod=multi_pod)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    hlo_path = cell_path(out_dir, arch, shape_name, mesh_kind, variant).replace(
        ".json", ".hlo.txt.gz")
    with gzip.open(hlo_path, "wt") as f:
        f.write(txt)
    # trip-count-aware analysis (cost_analysis counts loop bodies once)
    hc = analyze_hlo(txt)
    rl = roofline(hc, n_chips, cfg, shape)
    rl["xla_cost_analysis_flops"] = float(cost.get("flops", 0.0))
    rl["xla_cost_analysis_bytes"] = float(cost.get("bytes accessed", 0.0))
    rl["unknown_trip_loops"] = hc.unknown_trip_loops
    pc = param_counts(cfg)

    bytes_per_dev = None
    if mem is not None:
        bytes_per_dev = int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        )

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "kind": shape.kind,
        "n_chips": n_chips,
        "status": "ok",
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
        "bytes_per_device": bytes_per_dev,
        "gib_per_device": round(bytes_per_dev / 2**30, 3) if bytes_per_dev else None,
        "params_total": pc["total"],
        "params_active": pc["active"],
        "roofline": rl,
    }
    return rec


def cell_path(out_dir, arch, shape, mesh_kind, variant="base"):
    safe = arch.replace("/", "_").replace(".", "_")
    return os.path.join(out_dir, f"{safe}__{shape}__{mesh_kind}__{variant}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="base")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--driver", action="store_true",
                    help="run every cell in a fresh subprocess")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if args.driver:
        from ..configs import cells

        todo = []
        for aid, sname, skip in cells():
            for mesh_kind in ("single", "multi"):
                p = cell_path(args.out, aid, sname, mesh_kind)
                if skip:
                    with open(p, "w") as f:
                        json.dump({"arch": aid, "shape": sname, "mesh": mesh_kind,
                                   "status": "skip", "reason": skip}, f, indent=1)
                    continue
                if os.path.exists(p) and not args.force:
                    continue
                todo.append((aid, sname, mesh_kind, p))
        print(f"[driver] {len(todo)} cells to run")
        for i, (aid, sname, mesh_kind, p) in enumerate(todo):
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", aid, "--shape", sname, "--mesh", mesh_kind,
                   "--out", args.out]
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            ok = os.path.exists(p)
            print(f"[driver {i+1}/{len(todo)}] {aid} x {sname} x {mesh_kind}: "
                  f"{'ok' if ok and r.returncode == 0 else 'FAIL'} "
                  f"({time.time()-t0:.0f}s)")
            if r.returncode != 0:
                err = {"arch": aid, "shape": sname, "mesh": mesh_kind,
                       "status": "error",
                       "error": r.stderr[-4000:]}
                with open(p, "w") as f:
                    json.dump(err, f, indent=1)
        return

    p = cell_path(args.out, args.arch, args.shape, args.mesh, args.variant)
    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.out, args.variant)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "variant": args.variant, "status": "error",
               "error": traceback.format_exc()[-4000:]}
        with open(p, "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps({k: rec[k] for k in ("arch", "shape", "mesh", "status")}))
        sys.exit(1)
    with open(p, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
