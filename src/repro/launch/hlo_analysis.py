"""Trip-count-aware HLO analysis.

``compiled.cost_analysis()`` on the CPU backend counts while-loop bodies
ONCE, so anything under a ``lax.scan`` (the layer stack, SSD chunk scan,
attention q-chunks) is undercounted by its trip count — up to 80x here.
This module re-derives the three roofline inputs from ``compiled.as_text()``:

* **flops** — every ``dot``/``convolution``, 2 x prod(result) x prod(contracted
  dims) (elementwise flops ignored: matmuls dominate);
* **collective bytes** — operand bytes of all-gather / all-reduce /
  reduce-scatter / all-to-all / collective-permute (+ragged variants);
* **hbm bytes** — fusion-level traffic model: every top-level op (a fusion
  is one kernel) contributes operand + result bytes; bookkeeping ops
  (tuple/gte/parameter/constant/bitcast/copy) are free; dynamic-slice /
  dynamic-update-slice (raw or as a fusion root — the lax.scan stacking
  machinery) are counted at *slice* granularity, since XLA executes them
  in place (counting the full buffer per scan step would overstate scan
  traffic by the trip count);

all three propagated through the call graph with ``while`` bodies multiplied
by their ``known_trip_count`` backend_config (emitted by XLA for scan-style
loops; unknown trip counts fall back to 1 and are reported).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCosts"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_SHAPE = r"(?:\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)"
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+)\s*=\s*(?P<shape>" + _SHAPE + r")\s+"
    r"(?P<kind>[\w\-]+)\((?P<args>.*)$"
)
_SHAPE_ELEM = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\((?P<params>.*?)\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls=|body=|to_apply=)%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "all-gather-start",
    "all-reduce-start", "collective-permute-start",
)
_FREE_OPS = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "reshape", "broadcast", "iota", "get-dimension-size",
}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_ELEM.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_ELEM.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class _Op:
    name: str
    shape: str
    kind: str
    rest: str
    operands: List[str] = field(default_factory=list)


@dataclass
class HloCosts:
    flops: float
    hbm_bytes: float
    collective_bytes: Dict[str, float]
    unknown_trip_loops: int

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def _parse_computations(text: str):
    comps: Dict[str, List[_Op]] = {}
    params: Dict[str, Dict[str, str]] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group("name")
                comps[cur] = []
                params[cur] = {}
                if line.startswith("ENTRY"):
                    entry = cur
                # parameter shapes from the signature: name: shape
                for pm in re.finditer(r"([\w.\-]+):\s*(" + _SHAPE + ")",
                                      m.group("params")):
                    params[cur][pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        args = m.group("args")
        head = args.split("), ")[0] if "), " in args else args.rstrip(")")
        op = _Op(name=m.group("name"), shape=m.group("shape"),
                 kind=m.group("kind"), rest=args,
                 operands=_OPERAND_RE.findall(head))
        comps[cur].append(op)
    return comps, params, entry


def analyze_hlo(text: str) -> HloCosts:
    comps, params, entry = _parse_computations(text)
    shape_of: Dict[Tuple[str, str], str] = {}
    for cname, ops in comps.items():
        for p, s in params[cname].items():
            shape_of[(cname, p)] = s
        for op in ops:
            shape_of[(cname, op.name)] = op.shape
            if op.kind == "parameter":
                # `%p = f32[..] parameter(0)` — signature name may differ
                shape_of[(cname, op.name)] = op.shape

    memo: Dict[str, Tuple[float, float, Dict[str, float], int]] = {}

    def op_operand_bytes(cname, op) -> int:
        total = 0
        for o in op.operands:
            s = shape_of.get((cname, o))
            if s:
                total += _shape_bytes(s)
        return total

    def cost_of(cname: str):
        if cname in memo:
            return memo[cname]
        memo[cname] = (0.0, 0.0, {}, 0)  # cycle guard
        flops = 0.0
        hbm = 0.0
        coll: Dict[str, float] = {}
        unknown = 0
        for op in comps.get(cname, []):
            kind = op.kind
            if kind == "dot":
                res = 1
                for d in _shape_dims(op.shape):
                    res *= d
                lc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
                k = 1
                if lc and op.operands:
                    lhs_shape = shape_of.get((cname, op.operands[0]))
                    if lhs_shape:
                        dims = _shape_dims(lhs_shape)
                        for ci in lc.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k *= dims[int(ci)]
                flops += 2.0 * res * k
                hbm += op_operand_bytes(cname, op) + _shape_bytes(op.shape)
                continue
            if kind == "convolution":
                res = 1
                for d in _shape_dims(op.shape):
                    res *= d
                rhs = shape_of.get((cname, op.operands[1])) if len(op.operands) > 1 else None
                k = 1
                if rhs:
                    dims = _shape_dims(rhs)
                    for d in dims[:-1]:
                        k *= d
                flops += 2.0 * res * k
                hbm += op_operand_bytes(cname, op) + _shape_bytes(op.shape)
                continue
            if kind in ("dynamic-slice", "dynamic-update-slice"):
                # in-place/slice-granularity traffic
                sizes = sorted((_shape_bytes(shape_of.get((cname, o), "")) for o in op.operands), reverse=True)
                big = sizes[0] if sizes else 0
                res = _shape_bytes(op.shape)
                hbm += (sum(sizes) - big) + min(res, 2 * max(res - big, sizes[1] if len(sizes) > 1 else res))
                continue
            base_kind = kind.replace("-done", "").replace("-start", "")
            if base_kind in _COLLECTIVES or kind in _COLLECTIVES:
                b = op_operand_bytes(cname, op)
                if b == 0:
                    b = _shape_bytes(op.shape)
                key = base_kind
                coll[key] = coll.get(key, 0.0) + b
                hbm += op_operand_bytes(cname, op) + _shape_bytes(op.shape)
                continue
            # call-like ops
            trip = 1
            if kind == "while":
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    unknown += 1
                bm = _CALL_RE.search(op.rest)
                if bm:
                    f, h, c, u = cost_of(bm.group(1))
                    flops += trip * f
                    hbm += trip * h
                    for k2, v in c.items():
                        coll[k2] = coll.get(k2, 0.0) + trip * v
                    unknown += u
                cm = _COND_RE.search(op.rest)
                if cm:
                    f, h, c, u = cost_of(cm.group(1))
                    flops += trip * f
                    hbm += trip * h
                    unknown += u
                continue
            if kind in ("fusion", "call", "custom-call", "reduce", "sort",
                        "map", "scatter", "select-and-scatter", "reduce-window"):
                for cm in _CALL_RE.finditer(op.rest):
                    sub = cm.group(1)
                    if sub in comps:
                        f, h, c, u = cost_of(sub)
                        flops += f
                        # fused computations are ONE kernel: internal hbm
                        # traffic doesn't count, the fusion op's does
                        for k2, v in c.items():
                            coll[k2] = coll.get(k2, 0.0) + v
                        unknown += u
                if kind == "fusion" and ("dynamic_update_slice" in op.rest
                                         or "dynamic_slice" in op.rest
                                         or "dynamic-update-slice" in op.rest):
                    # scan stack/unstack fusions execute in place: drop the
                    # aliased big buffer from both read and write sides
                    sizes = sorted((_shape_bytes(shape_of.get((cname, o), ""))
                                    for o in op.operands), reverse=True)
                    big = sizes[0] if sizes else 0
                    res = _shape_bytes(op.shape)
                    hbm += (sum(sizes) - big) + (res - big if res >= big else res)
                    continue
                hbm += op_operand_bytes(cname, op) + _shape_bytes(op.shape)
                continue
            if kind == "conditional":
                bm = _BRANCH_RE.search(op.rest)
                if bm:
                    subs = _OPERAND_RE.findall(bm.group(1))
                    best = (0.0, 0.0, {}, 0)
                    for sub in subs:
                        c = cost_of(sub)
                        if c[0] >= best[0]:
                            best = c
                    flops += best[0]
                    hbm += best[1]
                    for k2, v in best[2].items():
                        coll[k2] = coll.get(k2, 0.0) + v
                continue
            if kind in _FREE_OPS:
                continue
            # generic elementwise/data op: count traffic, no flops
            hbm += op_operand_bytes(cname, op) + _shape_bytes(op.shape)
        memo[cname] = (flops, hbm, coll, unknown)
        return memo[cname]

    # fused computations must not be double counted: only walk from entry
    f, h, c, u = cost_of(entry) if entry else (0.0, 0.0, {}, 0)
    return HloCosts(flops=f, hbm_bytes=h, collective_bytes=c,
                    unknown_trip_loops=u)
