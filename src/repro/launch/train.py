"""End-to-end training driver with checkpoint/restart and elastic resume.

Example (CPU container, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --ckpt-every 20

Fault tolerance: the data pipeline is deterministic-by-step and checkpoints
store (params, opt, step); `--resume` restarts from the last COMPLETE step
and replays the exact stream — killing the process at any point loses at
most `ckpt_every` steps.  On a different mesh shape, elastic restore
re-places the same arrays (see repro.ckpt.elastic).
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh", default="1x1", help="e.g. 2x4 => data=2,model=4")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..data import TokenPipeline
    from ..ckpt import AsyncCheckpointer, latest_step, restore
    from ..models.model import init_params
    from ..optim import adamw_init, ef_init, warmup_cosine
    from .mesh import make_mesh
    from .steps import make_train_step

    if args.arch == "mini-lm":
        from ..configs.mini_lm import MINI_LM

        cfg = MINI_LM
    else:
        cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_mesh((d, m), ("data", "model")) if d * m > 1 else None
    if mesh is None:
        mesh = make_mesh((1, 1), ("data", "model"))

    pipe = TokenPipeline(
        vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=args.seed,
        n_prefix=cfg.n_prefix, d_model=cfg.d_model,
    )
    train_step, psh, osh = make_train_step(
        cfg, mesh, multi_pod=False, lr=args.lr, remat=True,
        compress_grads=args.compress_grads,
    )
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt = adamw_init(params)
    if args.compress_grads:
        opt = (opt, ef_init(params))
    start = 0
    ck = AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    if args.resume and args.ckpt_dir:
        s = latest_step(args.ckpt_dir)
        if s is not None:
            (params, opt), extra = restore(args.ckpt_dir, s, (params, opt))
            start = int(extra["step"]) + 1
            print(f"[resume] restored step {s}, continuing at {start}")

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        params, opt, metrics = jitted(params, opt, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:8.4f} ce {float(metrics['ce']):8.4f} "
                  f"gnorm {float(metrics['gnorm']):7.3f} ({dt:.1f}s)")
        if ck and args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ck.submit(step, (params, opt), {"step": step, "seed": args.seed})
    if ck:
        ck.submit(args.steps - 1, (params, opt), {"step": args.steps - 1,
                                                  "seed": args.seed})
        ck.wait()
    print(f"[done] first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
