import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the PAPER's own distributed SCLaP sweep at web scale.

Lowers + compiles one coarsening sweep (3 LP phases over chunked local
nodes + interface all_gather exchange) and one refinement sweep (psum block
weights, k=16) for a uk-2007-scale graph — n = 105.8M nodes, m = 3.3G arcs
— sharded over the production meshes.  This is the scale the paper
partitions in 15.2 s on 512 cores; the dry-run proves the shard_map
formulation lowers, compiles and fits on a 256/512-chip pod.

  python -m repro.launch.dryrun_paper [--mesh single|multi]
"""

import argparse
import functools
import gzip
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--n", type=float, default=105.8e6)
    ap.add_argument("--m", type=float, default=3.3e9)   # undirected edges
    ap.add_argument("--k", type=int, default=16)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..core.distributed_lp import _shard_sweep
    from .hlo_analysis import analyze_hlo
    from .roofline import HW

    multi = args.mesh == "multi"
    n_chips = 512 if multi else 256
    # flatten the production mesh into the paper's 1-D PE ring
    devs = np.array(jax.devices()[:n_chips])
    mesh = jax.sharding.Mesh(devs, ("pe",))

    Pn = n_chips
    n = int(args.n)
    arcs = int(2 * args.m)
    maxN = -(-n // Pn)
    maxM = -(-arcs // Pn)
    ghost_frac = 0.10          # paper: <0.5% (rgg) .. 40% (del); web ~10%
    maxG = int(maxN * ghost_frac) // 8 * 8 + 8
    maxI = maxG
    C = 4                       # chunks per shard
    Nc = -(-maxN // C) // 8 * 8 + 8
    Ec = -(-maxM // C) // 8 * 8 + 8

    S = jax.ShapeDtypeStruct
    i32, f32 = jnp.int32, jnp.float32
    abstract = dict(
        ch_nodes=S((Pn, C, Nc), i32), ch_nv=S((Pn, C, Nc), jnp.bool_),
        ch_ed=S((Pn, C, Ec), i32), ch_ew=S((Pn, C, Ec), f32),
        ch_es=S((Pn, C, Ec), i32), ch_ev=S((Pn, C, Ec), jnp.bool_),
        nw=S((Pn, maxN), f32), gnw=S((Pn, maxG), f32),
        gow=S((Pn, maxG), i32), gsl=S((Pn, maxG), i32),
        ifn=S((Pn, maxI), i32), nloc=S((Pn,), i32), ngho=S((Pn,), i32),
        ll=S((Pn, maxN), i32), lg=S((Pn, maxG), i32),
    )
    spec = P("pe")
    shardings = {k: NamedSharding(mesh, spec if v.shape[0] == Pn else P())
                 for k, v in abstract.items()}

    rec_all = {}
    for mode, iters, kk in (("cluster", 3, 0), ("refine", 6, args.k)):
        def body(ch_nodes, ch_nv, ch_ed, ch_ew, ch_es, ch_ev, nw, gnw, gow,
                 gsl, ifn, nloc, ngho, ll_, lg_, key,
                 _mode=mode, _iters=iters, _k=kk):
            out = _shard_sweep(
                ch_nodes[0], ch_nv[0], ch_ed[0], ch_ew[0], ch_es[0], ch_ev[0],
                nw[0], gnw[0], gow[0], gsl[0], ifn[0], nloc[0], ngho[0],
                ll_[0], lg_[0], jnp.float32(1e6), key,
                iters=_iters, refine_mode=(_mode == "refine"), k=_k,
                maxN=maxN, maxG=maxG, maxI=maxI,
            )
            return out[0][None], out[1][None], out[2]

        from repro.compat import shard_map as shard_map_compat

        shmapped = shard_map_compat(
            body, mesh=mesh,
            in_specs=(spec,) * 15 + (P(),),
            out_specs=(spec, spec, P()),
        )
        jitted = jax.jit(
            shmapped,
            in_shardings=tuple(shardings.values()) + (NamedSharding(mesh, P()),),
            donate_argnums=(13, 14),
        )
        t0 = time.time()
        lowered = jitted.lower(*abstract.values(), S((2,), jnp.uint32))
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        txt = compiled.as_text()
        hc = analyze_hlo(txt)
        bytes_dev = int(mem.argument_size_in_bytes + mem.output_size_in_bytes
                        - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
        terms = {
            "compute_s": hc.flops / HW["peak_flops"],
            "memory_s": hc.hbm_bytes / HW["hbm_bw"],
            "collective_s": hc.collective_total / HW["link_bw"],
        }
        rec = {
            "arch": "paper-sclap", "shape": f"uk2007_{mode}", "mesh": args.mesh,
            "variant": "base", "kind": mode, "n_chips": n_chips,
            "status": "ok", "t_lower_s": round(t_lower, 1),
            "t_compile_s": round(t_compile, 1),
            "bytes_per_device": bytes_dev,
            "gib_per_device": round(bytes_dev / 2**30, 3),
            "graph": {"n": n, "arcs": arcs, "ghost_frac": ghost_frac,
                      "chunks": C},
            "roofline": {
                **terms,
                "dominant": max(terms, key=terms.get),
                "hlo_flops_per_dev": hc.flops,
                "hlo_bytes_per_dev": hc.hbm_bytes,
                "collective_bytes_per_dev": hc.collective_total,
                "collectives": dict(hc.collective_bytes),
                "unknown_trip_loops": hc.unknown_trip_loops,
            },
        }
        path = os.path.join(args.out,
                            f"paper-sclap__uk2007_{mode}__{args.mesh}__base.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        with gzip.open(path.replace(".json", ".hlo.txt.gz"), "wt") as f:
            f.write(txt)
        print(json.dumps({k: rec[k] for k in
                          ("shape", "mesh", "t_compile_s", "gib_per_device")},
                         indent=None))
        rec_all[mode] = rec
    return rec_all


if __name__ == "__main__":
    main()
