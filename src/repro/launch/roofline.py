"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

  compute term    = HLO_FLOPs_per_device / 197e12            [s]
  memory term     = HLO_bytes_per_device / 819e9             [s]
  collective term = collective_bytes_per_device / 50e9       [s]

HLO_FLOPs / bytes come from ``compiled.cost_analysis()`` (the partitioned,
per-device module).  collective_bytes is NOT in cost_analysis: we parse the
compiled HLO text and sum the operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (ragged
variants included).  This counts payload entering each collective once per
device — a ring-transfer lower bound (actual wire bytes for a ring
all-reduce are ~2x operand).

MODEL_FLOPS uses the 6·N·D convention (6·N_active·D for MoE; attention
flops excluded), so MODEL_FLOPS / HLO_FLOPs is the "useful compute"
fraction — remat recompute, dense-MoE waste and padding all push it down.
"""

from __future__ import annotations

import re
from typing import Dict

import numpy as np

__all__ = ["HW", "collective_bytes", "roofline", "model_flops", "param_counts"]

HW = {
    "peak_flops": 197e12,   # bf16 / chip
    "hbm_bw": 819e9,        # B/s / chip
    "link_bw": 50e9,        # B/s / link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_]+\[[^\]]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^(]*\((?P<args>.*)$"
)
_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device operand bytes entering each collective kind."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        args = m.group("args")
        # operand shapes appear inline in the arg list: sum them
        total = 0
        for sm in _SHAPE_RE.finditer(args.split("channel_id")[0]):
            total += _shape_bytes(sm.group(1), sm.group(2))
        if total == 0:
            # fallback: result shape on the lhs
            lhs = line.split("=")[0] + "=" + line.split("=", 1)[1]
            for sm in _SHAPE_RE.finditer(line.split(" " + kind)[0]):
                total += _shape_bytes(sm.group(1), sm.group(2))
        out[kind] = out.get(kind, 0) + total
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def param_counts(cfg) -> Dict[str, float]:
    """(total params, active params) from the config analytically."""
    D, V = cfg.d_model, cfg.vocab
    n_total = 0.0
    n_active = 0.0
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    n_total += emb
    n_active += emb
    for mix, ffnk in cfg.layer_plan():
        if mix in ("attn", "attn_local"):
            h = cfg.n_heads * cfg.d_head
            kvh = cfg.n_kv_heads * cfg.d_head
            a = D * h + 2 * D * kvh + h * D
            n_total += a
            n_active += a
        else:
            s = cfg.ssm
            d_in = s.expand * D
            H = d_in // s.headdim
            a = 2 * D * d_in + 2 * D * s.d_state + D * H + d_in * D
            n_total += a
            n_active += a
        if ffnk == "dense":
            f = D * cfg.d_ff * (3 if cfg.glu else 2)
            n_total += f
            n_active += f
        elif ffnk == "moe":
            per = D * cfg.moe.d_ff * (3 if cfg.glu else 2)
            n_total += per * cfg.moe.n_experts + D * cfg.moe.n_experts
            n_active += per * cfg.moe.topk + D * cfg.moe.n_experts
    return {"total": n_total, "active": n_active}


def model_flops(cfg, shape) -> float:
    """Global MODEL_FLOPS for this cell (6ND train / 2ND inference)."""
    pc = param_counts(cfg)
    n_act = pc["active"]
    if shape.kind == "train":
        return 6.0 * n_act * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.batch * shape.seq
    return 2.0 * n_act * shape.batch  # decode: one token per sequence


def roofline(hc, n_chips: int, cfg, shape) -> dict:
    """hc: launch.hlo_analysis.HloCosts (trip-count-aware, per device)."""
    flops_dev = float(hc.flops)
    bytes_dev = float(hc.hbm_bytes)
    coll_dev = float(hc.collective_total)
    t_comp = flops_dev / HW["peak_flops"]
    t_mem = bytes_dev / HW["hbm_bw"]
    t_coll = coll_dev / HW["link_bw"]
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / n_chips
    t_bound = max(terms.values())
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_dev": flops_dev,
        "hlo_bytes_per_dev": bytes_dev,
        "collective_bytes_per_dev": coll_dev,
        "collectives": dict(hc.collective_bytes),
        "model_flops_global": mf,
        "useful_ratio": (mf_dev / flops_dev) if flops_dev else 0.0,
        # fraction of the compute roofline achieved if the step ran at the
        # bound of its dominant term (the score we hillclimb):
        "roofline_fraction": (mf_dev / HW["peak_flops"]) / t_bound if t_bound else 0.0,
    }
