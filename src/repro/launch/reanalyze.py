"""Re-derive roofline terms for every stored dry-run cell from its saved
HLO text (no recompilation)."""

import glob
import gzip
import json
import sys

from ..configs import SHAPES, get_config
from .hlo_analysis import analyze_hlo
from .roofline import HW, roofline


def main(out_dir="results/dryrun"):
    for jf in sorted(glob.glob(f"{out_dir}/*.json")):
        d = json.load(open(jf))
        if d.get("status") != "ok":
            continue
        hf = jf.replace(".json", ".hlo.txt.gz")
        try:
            txt = gzip.open(hf, "rt").read()
        except FileNotFoundError:
            continue
        hc = analyze_hlo(txt)
        if d["arch"] == "paper-sclap":
            terms = {
                "compute_s": hc.flops / HW["peak_flops"],
                "memory_s": hc.hbm_bytes / HW["hbm_bw"],
                "collective_s": hc.collective_total / HW["link_bw"],
            }
            d["roofline"].update(terms)
            d["roofline"]["dominant"] = max(terms, key=terms.get)
            d["roofline"]["hlo_bytes_per_dev"] = hc.hbm_bytes
        else:
            cfg = get_config(d["arch"])
            shape = SHAPES[d["shape"]]
            old = d["roofline"]
            rl = roofline(hc, d["n_chips"], cfg, shape)
            rl["xla_cost_analysis_flops"] = old.get("xla_cost_analysis_flops")
            rl["xla_cost_analysis_bytes"] = old.get("xla_cost_analysis_bytes")
            rl["unknown_trip_loops"] = hc.unknown_trip_loops
            d["roofline"] = rl
        json.dump(d, open(jf, "w"), indent=1)
        print(jf.split("/")[-1], "mem=%.3g" % d["roofline"]["memory_s"])


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
