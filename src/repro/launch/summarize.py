"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/."""

from __future__ import annotations

import glob
import json
import sys


def load(out_dir="results/dryrun", variant="base"):
    rows = []
    for f in sorted(glob.glob(f"{out_dir}/*__{variant}.json")):
        rows.append(json.load(open(f)))
    return rows


def fmt_dryrun(rows):
    out = ["| arch | shape | mesh | status | GiB/dev | lower s | compile s | collective mix |",
           "|---|---|---|---|---|---|---|---|"]
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"], d["mesh"])):
        if d["status"] == "skip":
            out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                       f"skip ({d['reason'][:40]}...) | – | – | – | – |")
            continue
        if d["status"] != "ok":
            out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | ERROR | – | – | – | – |")
            continue
        r = d["roofline"]
        mix = ", ".join(f"{k.replace('all-','a').replace('reduce-scatter','rs').replace('collective-permute','cp')}:"
                        f"{v/2**30:.2f}G"
                        for k, v in sorted(r["collectives"].items(),
                                           key=lambda kv: -kv[1])[:3])
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | ok | "
            f"{d['gib_per_device']:.1f} | {d['t_lower_s']} | {d['t_compile_s']} | {mix} |")
    return "\n".join(out)


def fmt_roofline(rows, mesh="single"):
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL/HLO flops | roofline frac | one-line fix |",
           "|---|---|---|---|---|---|---|---|---|"]
    for d in sorted(rows, key=lambda d: (d["arch"], d["shape"])):
        if d.get("mesh") != mesh or d["status"] != "ok":
            continue
        r = d["roofline"]
        dom = r["dominant"].replace("_s", "")
        fix = {
            "compute": "cut remat recompute / raise arithmetic intensity",
            "memory": "fuse more, bf16 intermediates, fewer materialized temps",
            "collective": "shard KV/state so decode reads stay local; overlap",
        }[dom]
        ur = r.get("useful_ratio")
        rf = r.get("roofline_fraction")
        out.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | {dom} | "
            f"{ur:.3f} | {rf:.4f} | {fix} |"
            if ur is not None and rf is not None else
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | {dom} | "
            f"n/a | n/a | {fix} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    print("## Dry-run\n")
    print(fmt_dryrun(rows))
    print("\n## Roofline (single-pod 16x16)\n")
    print(fmt_roofline(rows, "single"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(fmt_roofline(rows, "multi"))
