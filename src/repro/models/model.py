"""Unified decoder-only model covering every assigned architecture family.

One parameterized stack supports: dense GQA transformers (qwen/internlm/
musicgen/phi3v backbones), 5:1 local:global sliding-window stacks (gemma3),
pure SSD stacks (mamba2), MoE FFNs (dbrx/granite) and hybrid
mamba+attention+MoE interleaves (jamba) — driven by ``ArchConfig.pattern_unit``
/ ``ffn_unit``.

Layers are *scanned* over repeating units (HLO size ~ O(unit), not O(L));
any remainder layers are unrolled.  Each unit body is rematerialized
(``jax.checkpoint``) during training.

The modality frontends of the [audio]/[vlm] entries are STUBS per the
brief: ``prefix_embeds`` (precomputed patch/frame embeddings) are
concatenated in front of the token embeddings.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ArchConfig, Shape
from .layers import (
    attention,
    decode_attention,
    ffn,
    init_attn_params,
    init_ffn_params,
    rmsnorm,
)
from .mamba2 import init_mamba_params, mamba_block, mamba_decode
from .moe import init_moe_params, moe_dense, moe_ep
from .sharding import DP, TP, act_specs

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step", "init_caches"]


def _wsc(x, spec, mesh):
    """with_sharding_constraint that is a no-op without a mesh (CPU smoke)."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# parameter construction
# --------------------------------------------------------------------------


def _init_layer(cfg: ArchConfig, mix: str, ffnk: str, key) -> Dict[str, Any]:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"mix_norm": jnp.zeros((cfg.d_model,), jnp.float32)}
    if mix in ("attn", "attn_local"):
        p["attn"] = init_attn_params(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, cfg.qkv_bias, dt
        )
    elif mix == "mamba":
        s = cfg.ssm
        p["mamba"] = init_mamba_params(
            k1, cfg.d_model, s.d_state, s.headdim, s.expand, s.conv_width, dt
        )
    else:
        raise ValueError(mix)
    if ffnk == "dense":
        p["ffn_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ffn"] = init_ffn_params(k2, cfg.d_model, cfg.d_ff, cfg.glu, dt)
    elif ffnk == "moe":
        p["ffn_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["moe"] = init_moe_params(
            k2, cfg.d_model, cfg.moe.d_ff, cfg.moe.n_experts, cfg.glu, dt
        )
    elif ffnk != "none":
        raise ValueError(ffnk)
    return p


def init_params(cfg: ArchConfig, key) -> Dict[str, Any]:
    dt = _dtype(cfg)
    n_units, unit, rem = cfg.scan_split()
    keys = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), dt) * 0.02,
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab), dt) * cfg.d_model ** -0.5
        )
    # scanned unit params: stacked (n_units, ...) per unit position
    scan_params = []
    for i, (mix, ffnk) in enumerate(unit):
        ks = jax.random.split(jax.random.fold_in(keys[2], i), n_units)
        stacked = jax.vmap(lambda k: _init_layer(cfg, mix, ffnk, k))(ks)
        scan_params.append(stacked)
    params["scan"] = scan_params
    params["rem"] = [
        _init_layer(cfg, mix, ffnk, jax.random.fold_in(keys[3], i))
        for i, (mix, ffnk) in enumerate(rem)
    ]
    return params


# --------------------------------------------------------------------------
# layer application
# --------------------------------------------------------------------------


def _dp_axis(multi_pod):
    dp = DP(multi_pod)
    return dp if len(dp) > 1 else dp[0]


def _apply_mix(cfg, mix, lp, x, mesh, multi_pod, positions, return_cache):
    if mix in ("attn", "attn_local"):
        window = cfg.sliding_window if mix == "attn_local" else None
        theta = cfg.rope_theta_local if mix == "attn_local" else cfg.rope_theta
        y, cache = attention(
            lp["attn"], x,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
            rope_theta=theta, window=window, positions=positions,
            return_cache=return_cache,
        )
    else:
        s = cfg.ssm
        y, cache = mamba_block(
            lp["mamba"], x, d_state=s.d_state, headdim=s.headdim, chunk=s.chunk,
            return_cache=return_cache, mesh=mesh,
            dp=_dp_axis(multi_pod) if mesh is not None else None,
            tp=TP if mesh is not None else None,
        )
    return y, cache


def _apply_ffn(cfg, ffnk, lp, x, mesh, multi_pod):
    if ffnk == "none":
        return x * 0.0, 0.0
    h = rmsnorm(x, lp["ffn_norm"], cfg.norm_eps)
    if ffnk == "dense":
        return ffn(lp["ffn"], h, glu=cfg.glu, act=cfg.act), 0.0
    use_ep = mesh is not None and mesh.shape.get(TP, 1) > 1
    if use_ep:
        y, aux = moe_ep(
            lp["moe"], h, mesh=mesh, topk=cfg.moe.topk,
            n_experts=cfg.moe.n_experts, capacity_factor=cfg.moe.capacity_factor,
            glu=cfg.glu, act=cfg.act, dp_axes=DP(multi_pod), tp_axis=TP,
        )
    else:
        y, aux = moe_dense(lp["moe"], h, topk=cfg.moe.topk, glu=cfg.glu, act=cfg.act)
    return y, aux


def _apply_layer(cfg, mix, ffnk, lp, x, mesh, multi_pod, positions,
                 return_cache=False):
    h = rmsnorm(x, lp["mix_norm"], cfg.norm_eps)
    y, cache = _apply_mix(cfg, mix, lp, h, mesh, multi_pod, positions, return_cache)
    x = x + y
    y2, aux = _apply_ffn(cfg, ffnk, lp, x, mesh, multi_pod)
    return x + y2, aux, cache


# --------------------------------------------------------------------------
# full forward
# --------------------------------------------------------------------------


def _embed_tokens(cfg, params, tokens, prefix_embeds, multi_pod, mesh):
    sp = act_specs(multi_pod)
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return _wsc(x, sp["hidden"], mesh)


def forward(
    cfg: ArchConfig,
    params: Dict[str, Any],
    tokens: jnp.ndarray,                    # (B, S)
    *,
    mesh: Optional[Mesh] = None,
    multi_pod: bool = False,
    prefix_embeds: Optional[jnp.ndarray] = None,
    remat: bool = True,
    collect_caches: bool = False,
):
    """Returns (logits (B,S,V), aux, caches|None)."""
    n_units, unit, rem = cfg.scan_split()
    sp = act_specs(multi_pod)
    x = _embed_tokens(cfg, params, tokens, prefix_embeds, multi_pod, mesh)
    S = x.shape[1]
    positions = jnp.arange(S)

    def unit_body(x, unit_params):
        aux = 0.0
        caches = []
        for i, (mix, ffnk) in enumerate(unit):
            x, a, c = _apply_layer(
                cfg, mix, ffnk, unit_params[i], x, mesh, multi_pod, positions,
                return_cache=collect_caches,
            )
            x = _wsc(x, sp["hidden"], mesh)
            aux = aux + a
            caches.append(c)
        return x, (aux, caches if collect_caches else None)

    body = jax.checkpoint(unit_body) if remat else unit_body

    def scan_fn(x, unit_params):
        x, (aux, caches) = body(x, unit_params)
        return x, (aux, caches)

    x, (auxs, scan_caches) = jax.lax.scan(scan_fn, x, params["scan"])
    aux = jnp.sum(auxs)
    rem_caches = []
    for (mix, ffnk), lp in zip(rem, params["rem"]):
        x, a, c = _apply_layer(
            cfg, mix, ffnk, lp, x, mesh, multi_pod, positions,
            return_cache=collect_caches,
        )
        aux = aux + a
        rem_caches.append(c)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    logits = _wsc(logits, sp["logits"], mesh)
    caches = {"scan": scan_caches, "rem": rem_caches} if collect_caches else None
    return logits, aux, caches


def loss_fn(cfg, params, batch, *, mesh=None, multi_pod=False, remat=True):
    """Next-token CE.  The forward runs on the FULL sequence length and the
    shift happens on the label side: a 4095-long forward would break the
    sequence-divisibility that lets the MoE dispatch shard tokens over the
    model axis (16x token duplication otherwise — see EXPERIMENTS.md §Perf).
    """
    tokens = batch["tokens"]
    prefix = batch.get("prefix_embeds")
    logits, aux, _ = forward(
        cfg, params, tokens, mesh=mesh, multi_pod=multi_pod,
        prefix_embeds=prefix, remat=remat,
    )
    npfx = 0 if prefix is None else prefix.shape[1]
    if npfx:
        logits = logits[:, npfx:]
    logits = logits[:, :-1]                      # predict token t+1 from t
    labels = tokens[:, 1:]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)          # vocab-sharded reduce
    tgt = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    ce = jnp.mean(lse - tgt)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# serving: prefill + single-token decode
# --------------------------------------------------------------------------


def prefill(cfg, params, tokens, *, mesh=None, multi_pod=False,
            prefix_embeds=None):
    """Full-sequence forward that also emits per-layer caches; returns
    (last-position logits, caches)."""
    logits, _, caches = forward(
        cfg, params, tokens, mesh=mesh, multi_pod=multi_pod,
        prefix_embeds=prefix_embeds, remat=False, collect_caches=True,
    )
    return logits[:, -1], caches


def init_caches(cfg: ArchConfig, batch: int, max_seq: int):
    """Zeroed decode caches (the dry-run lowers decode against these specs)."""
    dt = _dtype(cfg)
    n_units, unit, rem = cfg.scan_split()

    def one(mix):
        if mix == "attn":
            return {
                "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.d_head), dt),
                "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.d_head), dt),
            }
        if mix == "attn_local":
            w = min(cfg.sliding_window, max_seq)
            return {
                "k": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.d_head), dt),
                "v": jnp.zeros((batch, w, cfg.n_kv_heads, cfg.d_head), dt),
            }
        s = cfg.ssm
        d_inner = s.expand * cfg.d_model
        return {
            "h": jnp.zeros((batch, d_inner // s.headdim, s.headdim, s.d_state), jnp.float32),
            "conv": jnp.zeros((batch, s.conv_width - 1, d_inner), dt),
        }

    scan_caches = [
        jax.tree.map(lambda x: jnp.broadcast_to(x, (n_units,) + x.shape), one(mix))
        for (mix, _) in unit
    ]
    rem_caches = [one(mix) for (mix, _) in rem]
    return {"scan": scan_caches, "rem": rem_caches}


def decode_step(cfg, params, token, caches, pos, *, mesh=None, multi_pod=False):
    """One-token decode: (B,) token ids + caches -> (B,V) logits + caches."""
    n_units, unit, rem = cfg.scan_split()
    sp = act_specs(multi_pod)
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(_dtype(cfg))

    def one_layer(mix, ffnk, lp, cache, x):
        h = rmsnorm(x, lp["mix_norm"], cfg.norm_eps)
        if mix in ("attn", "attn_local"):
            window = cfg.sliding_window if mix == "attn_local" else None
            theta = cfg.rope_theta_local if mix == "attn_local" else cfg.rope_theta
            y, cache = decode_attention(
                lp["attn"], h, cache, pos,
                n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, d_head=cfg.d_head,
                rope_theta=theta, window=window,
            )
        else:
            s = cfg.ssm
            y, cache = mamba_decode(lp["mamba"], h, cache, d_state=s.d_state,
                                    headdim=s.headdim)
        x = x + y
        y2, _ = _apply_ffn(cfg, ffnk, lp, x, mesh, multi_pod)
        return x + y2, cache

    def scan_fn(x, inp):
        unit_params, unit_caches = inp
        new_caches = []
        for i, (mix, ffnk) in enumerate(unit):
            x, c = one_layer(mix, ffnk, unit_params[i], unit_caches[i], x)
            new_caches.append(c)
        return x, new_caches

    x, new_scan = jax.lax.scan(scan_fn, x, (params["scan"], caches["scan"]))
    new_rem = []
    for (mix, ffnk), lp, c in zip(rem, params["rem"], caches["rem"]):
        x, c2 = one_layer(mix, ffnk, lp, c, x)
        new_rem.append(c2)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head)[:, 0]
    logits = _wsc(logits, P(sp["logits"][0], sp["logits"][2]), mesh)
    return logits, {"scan": new_scan, "rem": new_rem}
