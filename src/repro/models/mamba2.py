"""Mamba-2 (SSD — state-space duality) block, chunked scan formulation.

The SSD recurrence per head h (state size N, head dim P):

    h_t = a_t * h_{t-1} + dt_t * B_t (x) x_t        a_t = exp(dt_t * A_h)
    y_t = C_t . h_t + D_h * x_t

computed chunk-parallel (arXiv:2405.21060): within a chunk of Q tokens the
quadratic "attention-like" form runs on the MXU; across chunks a
``lax.scan`` carries the (B, H, P, N) state.  Linear in sequence length —
this is what makes the 524k-token decode/long-context shapes feasible for
the ssm/hybrid architectures.
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from .layers import rmsnorm
from .sharding import wsc

__all__ = ["init_mamba_params", "mamba_block", "mamba_decode", "init_mamba_cache"]


def init_mamba_params(key, d_model, d_state, headdim, expand, conv_width, dtype):
    d_inner = expand * d_model
    H = d_inner // headdim
    ks = jax.random.split(key, 8)
    sc = d_model ** -0.5
    return {
        "wz": jax.random.normal(ks[0], (d_model, d_inner), dtype) * sc,
        "wx": jax.random.normal(ks[1], (d_model, d_inner), dtype) * sc,
        "wB": jax.random.normal(ks[2], (d_model, d_state), dtype) * sc,
        "wC": jax.random.normal(ks[3], (d_model, d_state), dtype) * sc,
        "wdt": jax.random.normal(ks[4], (d_model, H), dtype) * sc,
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "conv_w": jax.random.normal(ks[5], (conv_width, d_inner), dtype) * 0.2,
        "conv_b": jnp.zeros((d_inner,), dtype),
        "norm_w": jnp.zeros((d_inner,), jnp.float32),
        "wo": jax.random.normal(ks[6], (d_inner, d_model), dtype) * (d_inner ** -0.5),
    }


def _causal_depthwise_conv(x, w, b):
    """x (B,S,C), w (W,C) causal depthwise conv + bias."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    return out + b[None, None, :]


def _ssd_chunked(X, dt, A, Bm, Cm, h0, chunk: int, head_block: int = 8,
                 mesh=None, dp=None, tp=None):
    """X (B,S,H,P), dt (B,S,H), A (H,), Bm/Cm (B,S,N), h0 (B,H,P,N).

    One ``lax.scan`` over chunks carries the state; within a chunk the
    quadratic term is computed per *head block* (``lax.map``) so the
    (B,Q,Q,hb) working set stays bounded for 256-head models.
    Returns (Y (B,S,H,P), h_final)."""
    B, S0, H, Pd = X.shape
    N = Bm.shape[-1]
    # REPRO_SSD_CHUNK overrides the chunk length: the intra-chunk decay
    # stream costs O(B*S*Q*H) bytes/flops while the inter-chunk state path
    # is Q-independent, so smaller Q trades MXU tile size for bandwidth
    Q = int(os.environ.get("REPRO_SSD_CHUNK", "0")) or chunk
    Q = min(Q, S0)
    nc = (S0 + Q - 1) // Q
    S = nc * Q
    if S != S0:
        # pad with dt=0 steps: decay exp(0)=1 and zero input leave the
        # carried state untouched; padded outputs are sliced away below
        pad = [(0, 0), (0, S - S0)]
        X = jnp.pad(X, pad + [(0, 0), (0, 0)])
        dt = jnp.pad(dt, pad + [(0, 0)])
        Bm = jnp.pad(Bm, pad + [(0, 0)])
        Cm = jnp.pad(Cm, pad + [(0, 0)])
    hb = head_block
    while H % hb:
        hb //= 2
    nh = H // hb
    la = dt * A[None, None, :]                      # log a_t  (B,S,H), negative
    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def to_chunks(x):                                # (B,S,...) -> (nc,B,Q,...)
        return jnp.moveaxis(x.reshape(B, nc, Q, *x.shape[2:]), 1, 0)

    # REPRO_SSD_DTYPE=bf16 keeps the big X/B/C streams in bf16 (halves the
    # SSD working set); decay cumsums/exps and the carried state stay f32
    ssd_dt = jnp.bfloat16 if os.environ.get("REPRO_SSD_DTYPE") == "bf16" \
        else jnp.float32
    Xc, dtc, lac = to_chunks(X.astype(ssd_dt)), to_chunks(dt), to_chunks(la)
    Bc, Cc = to_chunks(Bm.astype(ssd_dt)), to_chunks(Cm.astype(ssd_dt))

    def step(h, inp):
        Xq, dtq, laq, Bq, Cq = inp                  # (B,Q,H,P),(B,Q,H),(B,Q,H),(B,Q,N)
        # keep heads sharded over TP through the chunk scan
        Xq = wsc(Xq, P(dp, None, tp, None), mesh)
        h = wsc(h, P(dp, tp, None, None), mesh)
        cs = jnp.cumsum(laq, axis=1)                # (B,Q,H) inclusive
        seg = cs[:, -1, :]                          # (B,H)
        CB = jnp.einsum("bqn,bsn->bqs", Cq, Bq).astype(jnp.float32)  # MXU
        # intra-chunk, head-blocked
        cs_h = jnp.moveaxis(cs.reshape(B, Q, nh, hb), 2, 0)       # (nh,B,Q,hb)
        dt_h = jnp.moveaxis(dtq.reshape(B, Q, nh, hb), 2, 0)
        X_h = jnp.moveaxis(Xq.reshape(B, Q, nh, hb, Pd), 2, 0)    # (nh,B,Q,hb,P)

        def hblk(args):
            csb, dtb, Xb = args
            M = jnp.exp(csb[:, :, None, :] - csb[:, None, :, :])
            M = jnp.where(tri[None, :, :, None], M, 0.0)          # (B,Q,Q,hb)
            sc = (CB[:, :, :, None] * M * dtb[:, None, :, :]).astype(Xb.dtype)
            return jnp.einsum("bqsh,bshp->bqhp", sc, Xb).astype(jnp.float32)

        Yi = jax.lax.map(hblk, (cs_h, dt_h, X_h))                 # (nh,B,Q,hb,P)
        Y_intra = jnp.moveaxis(Yi, 0, 2).reshape(B, Q, H, Pd)
        # inter-chunk from carried state
        Y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", Cq.astype(jnp.float32),
                             jnp.exp(cs), h)
        # state update
        dec_to_end = jnp.exp(seg[:, None, :] - cs)                # (B,Q,H)
        st = jnp.einsum("bqh,bqn,bqhp->bhpn", dtq * dec_to_end,
                        Bq.astype(jnp.float32), Xq.astype(jnp.float32))
        h_new = jnp.exp(seg)[:, :, None, None] * h + st
        h_new = wsc(h_new, P(dp, tp, None, None), mesh)
        return h_new, wsc(Y_intra + Y_inter, P(dp, None, tp, None), mesh)

    h_fin, Ys = jax.lax.scan(step, h0.astype(jnp.float32), (Xc, dtc, lac, Bc, Cc))
    Y = jnp.moveaxis(Ys, 0, 1).reshape(B, S, H, Pd)[:, :S0]
    return Y, h_fin


def mamba_block(
    params: dict,
    x: jnp.ndarray,                  # (B,S,D)
    *,
    d_state: int,
    headdim: int,
    chunk: int = 256,
    h0: Optional[jnp.ndarray] = None,
    conv_state: Optional[jnp.ndarray] = None,
    return_cache: bool = False,
    mesh=None,
    dp=None,
    tp=None,
):
    B, S, D = x.shape
    d_inner = params["wx"].shape[1]
    H = d_inner // headdim
    z = x @ params["wz"]
    xc = x @ params["wx"]
    xc = jax.nn.silu(_causal_depthwise_conv(xc, params["conv_w"], params["conv_b"]))
    Bm = x @ params["wB"]
    Cm = x @ params["wC"]
    dt = jax.nn.softplus(
        (x @ params["wdt"]).astype(jnp.float32) + params["dt_bias"][None, None, :]
    )
    A = -jnp.exp(params["A_log"])
    X = wsc(xc.reshape(B, S, H, headdim), P(dp, None, tp, None), mesh)
    if h0 is None:
        h0 = jnp.zeros((B, H, headdim, d_state), jnp.float32)
    Y, h_fin = _ssd_chunked(X, dt, A, Bm, Cm, h0, chunk, mesh=mesh, dp=dp, tp=tp)
    Y = Y + params["D_skip"][None, None, :, None] * X.astype(jnp.float32)
    y = Y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ params["wo"]
    if not return_cache:
        return out, None
    W = params["conv_w"].shape[0]
    conv_cache = (x @ params["wx"])[:, -(W - 1) :, :] if S >= W - 1 else jnp.pad(
        (x @ params["wx"]), ((0, 0), (W - 1 - S, 0), (0, 0))
    )
    return out, {"h": h_fin, "conv": conv_cache}


def init_mamba_cache(batch, d_model, d_state, headdim, expand, conv_width, dtype):
    d_inner = expand * d_model
    H = d_inner // headdim
    return {
        "h": jnp.zeros((batch, H, headdim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), dtype),
    }


def mamba_decode(
    params: dict,
    x: jnp.ndarray,                  # (B,1,D)
    cache: dict,
    *,
    d_state: int,
    headdim: int,
):
    """Single-token recurrent step: O(1) state update (the SSM decode path)."""
    B = x.shape[0]
    d_inner = params["wx"].shape[1]
    H = d_inner // headdim
    z = x @ params["wz"]
    xr = x @ params["wx"]                            # (B,1,d_inner)
    hist = jnp.concatenate([cache["conv"], xr], axis=1)  # (B,W,d_inner)
    w = params["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + params["conv_b"]
    xc = jax.nn.silu(conv_out)[:, None, :]           # (B,1,d_inner)
    Bm = (x @ params["wB"])[:, 0]                    # (B,N)
    Cm = (x @ params["wC"])[:, 0]
    dt = jax.nn.softplus(
        (x @ params["wdt"])[:, 0].astype(jnp.float32) + params["dt_bias"][None, :]
    )                                                # (B,H)
    A = -jnp.exp(params["A_log"])
    a = jnp.exp(dt * A[None, :])                     # (B,H)
    X = xc.reshape(B, H, headdim)
    h = cache["h"] * a[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bm.astype(jnp.float32), X.astype(jnp.float32)
    )
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h)
    y = y + params["D_skip"][None, :, None] * X.astype(jnp.float32)
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"])
    out = y @ params["wo"]
    return out, {"h": h, "conv": hist[:, 1:]}
