from .model import decode_step, forward, init_caches, init_params, loss_fn, prefill
from .sharding import DP, TP, act_specs, param_pspecs

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step",
           "init_caches", "param_pspecs", "act_specs", "DP", "TP"]
