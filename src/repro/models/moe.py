"""Mixture-of-Experts layer with real expert parallelism.

Production path (``mode="ep"``): a ``jax.shard_map`` region over the
(data, model) mesh axes implementing the standard two-hop token routing:

  1. activations are *sequence-sharded* on entry (tokens split over both
     axes), so every shard owns T_local tokens;
  2. local top-k routing; tokens are packed into per-expert capacity
     buffers by a sort + positional cumsum (static shapes, dropless up to
     the capacity factor — overflow tokens fall through on the residual);
  3. ``all_to_all`` over the *model* axis ships buffers to expert owners
     (experts are sharded over "model");
  4. expert FFN (weights FSDP-sharded over "data" are all-gathered on use —
     explicit FSDP);
  5. ``all_to_all`` back + weighted combine.

A dense fallback (``mode="dense"``) computes every expert for every token —
used by CPU smoke tests and as the oracle in unit tests (the EP path must
match it wherever no token overflows capacity).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["MoEParams", "init_moe_params", "moe_dense", "moe_ep", "router_topk"]


def init_moe_params(key, d_model, d_ff, n_experts, glu, dtype):
    ks = jax.random.split(key, 4)
    si, so = d_model ** -0.5, d_ff ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d_model, n_experts), jnp.float32) * si,
        "w_up": jax.random.normal(ks[1], (n_experts, d_model, d_ff), dtype) * si,
        "w_down": jax.random.normal(ks[2], (n_experts, d_ff, d_model), dtype) * so,
    }
    if glu:
        p["w_gate"] = jax.random.normal(ks[3], (n_experts, d_model, d_ff), dtype) * si
    return p


def router_topk(x, router_w, topk):
    """x (T, D) -> (probs (T,k), idx (T,k), aux load-balancing loss)."""
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, topk)
    topv = topv / jnp.clip(topv.sum(-1, keepdims=True), 1e-9)
    E = router_w.shape[1]
    # Switch-style aux loss: E * sum_e mean_prob_e * mean_assign_e
    assign = jnp.zeros((x.shape[0], E), jnp.float32).at[
        jnp.arange(x.shape[0])[:, None], topi
    ].set(1.0)
    aux = E * jnp.sum(probs.mean(0) * assign.mean(0))
    return topv, topi, aux


def _expert_ffn(xe, w_up, w_gate, w_down, glu, act):
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    if glu:
        h = a(jnp.einsum("ecd,edf->ecf", xe, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", xe, w_up
        )
    else:
        h = a(jnp.einsum("ecd,edf->ecf", xe, w_up))
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_dense(params, x, *, topk, glu=True, act="silu"):
    """Dense fallback: every expert computes every token (oracle/smoke)."""
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    topv, topi, aux = router_topk(xt, params["router"], topk)
    E = params["router"].shape[1]
    ys = []
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    for e in range(E):
        if glu:
            h = a(xt @ params["w_gate"][e]) * (xt @ params["w_up"][e])
        else:
            h = a(xt @ params["w_up"][e])
        ys.append(h @ params["w_down"][e])
    ys = jnp.stack(ys, axis=1)  # (T, E, D)
    gate = jnp.zeros((xt.shape[0], E), ys.dtype).at[
        jnp.arange(xt.shape[0])[:, None], topi
    ].add(topv.astype(ys.dtype))
    y = jnp.einsum("ted,te->td", ys, gate)
    return y.reshape(B, S, D), aux


def moe_ep(
    params,
    x,                      # (B, S, D), sharded P(dp, None, None) on entry
    *,
    mesh: Mesh,
    topk: int,
    n_experts: int,
    capacity_factor: float = 1.25,
    glu: bool = True,
    act: str = "silu",
    dp_axes=("data",),
    tp_axis: str = "model",
):
    """Expert-parallel MoE via shard_map + all_to_all (see module docstring)."""
    B, S, D = x.shape
    P_m = mesh.shape[tp_axis]
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    dp = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    E_local = n_experts // P_m
    assert E_local * P_m == n_experts
    # adaptive activation sharding: batch over dp if divisible, sequence over
    # tp if divisible (decode steps with S == 1 replicate over tp — the small
    # redundant-compute path; B == 1 long-context decode replicates over dp)
    b_ax = dp if B % dp_size == 0 else None
    s_ax = tp_axis if (S > 1 and S % P_m == 0) else None

    glu_flag, act_name = glu, act

    def body(xl, router_w, w_up, w_gate, w_down):
        # xl: (B_local, S_local, D) — tokens sequence-sharded over tp too
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, D)
        topv, topi, aux = router_topk(xt, router_w, topk)
        cap = int(T * topk / n_experts * capacity_factor) + 1

        a_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), topk)
        a_exp = topi.reshape(-1).astype(jnp.int32)
        a_w = topv.reshape(-1)
        order = jnp.argsort(a_exp, stable=True)
        se, st, sw = a_exp[order], a_tok[order], a_w[order]
        start = jnp.searchsorted(se, jnp.arange(n_experts, dtype=jnp.int32))
        pos = jnp.arange(T * topk, dtype=jnp.int32) - start[se]
        keep = pos < cap
        slot = jnp.where(keep, se * cap + pos, n_experts * cap)  # overflow -> dump slot

        buf = jnp.zeros((n_experts * cap + 1, D), xl.dtype).at[slot].set(xt[st])
        buf = buf[:-1].reshape(n_experts, cap, D)
        # token origin bookkeeping for the combine
        src_tok = jnp.full((n_experts * cap + 1,), -1, jnp.int32).at[slot].set(st)
        src_w = jnp.zeros((n_experts * cap + 1,), jnp.float32).at[slot].set(sw)

        # ---- ship to expert owners over the model axis --------------------
        # (E, cap, D) -> (E_local, P_m * cap, D)
        recv = jax.lax.all_to_all(
            buf.reshape(P_m, E_local * cap, D), tp_axis, split_axis=0,
            concat_axis=0, tiled=True,
        ).reshape(P_m, E_local, cap, D).transpose(1, 0, 2, 3).reshape(
            E_local, P_m * cap, D
        )

        # ---- expert FFN (FSDP all-gather of weights over data axes) -------
        wu = jax.lax.all_gather(w_up, dp, axis=1, tiled=True)
        wd = jax.lax.all_gather(w_down, dp, axis=2, tiled=True)
        wg = (
            jax.lax.all_gather(w_gate, dp, axis=1, tiled=True)
            if glu_flag
            else None
        )
        ye = _expert_ffn(recv, wu, wg, wd, glu_flag, act_name)

        # ---- ship results back & combine -----------------------------------
        back = jax.lax.all_to_all(
            ye.reshape(E_local, P_m, cap, D).transpose(1, 0, 2, 3).reshape(
                P_m, E_local * cap, D
            ),
            tp_axis, split_axis=0, concat_axis=0, tiled=True,
        ).reshape(n_experts * cap, D)
        back = jnp.concatenate([back, jnp.zeros((1, D), back.dtype)], axis=0)
        contrib = back * src_w[:, None].astype(back.dtype)
        y = jnp.zeros((T, D), xl.dtype).at[jnp.maximum(src_tok, 0)].add(
            jnp.where((src_tok >= 0)[:, None], contrib, 0.0).astype(xl.dtype)
        )
        aux_g = jax.lax.pmean(jax.lax.pmean(aux, dp), tp_axis)
        return y.reshape(Bl, Sl, D), aux_g

    # sequence-shard over the tp axis on entry, restore on exit
    from jax.sharding import NamedSharding

    xs = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(b_ax, s_ax, None)))
    from ..compat import shard_map as shard_map_compat

    y, aux = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(
            P(b_ax, s_ax, None),
            P(None, None),                       # router replicated
            P(tp_axis, dp, None),                # experts E/tp, D/fsdp
            P(tp_axis, dp, None) if glu else P(None),
            P(tp_axis, None, dp),
        ),
        out_specs=(P(b_ax, s_ax, None), P()),
    )(
        xs,
        params["router"],
        params["w_up"],
        params.get("w_gate", jnp.zeros((1,), x.dtype)),
        params["w_down"],
    )
    y = jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P(b_ax, None, None)))
    return y, jnp.mean(aux)
