"""Transformer building blocks: RMSNorm, RoPE, GQA attention (full /
sliding-window / decode-with-cache), dense FFN (GLU or plain).

All functions are pure; parameters are plain dicts of jnp arrays.  Attention
is *query-chunked* (lax.scan over query blocks) so the (S, S) score matrix
is never materialized — the pure-XLA stand-in for a flash kernel that keeps
32k-token prefill inside HBM and lets remat recompute cheaply.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---- perf-variant toggles (see EXPERIMENTS.md §Perf) ----------------------
# KV-cache update strategy for decode:
#   "where" (default): elementwise predicated write — partitions cleanly
#       along a sequence-sharded cache (GSPMD keeps every shard local);
#   "dus": dynamic-update-slice — the textbook formulation, but GSPMD
#       re-gathers a sequence-sharded cache around it (baseline variant).
_CACHE_UPDATE = os.environ.get("REPRO_CACHE_UPDATE", "where")
# attention intermediate dtype: "f32" keeps K/V/P in fp32 through the
# softmax pipeline; "bf16" keeps matmul operands bf16 (softmax stats in f32)
_ATTN_DT = os.environ.get("REPRO_ATTN_DTYPE", "f32")

__all__ = [
    "rmsnorm",
    "rope_table",
    "apply_rope",
    "attention",
    "decode_attention",
    "ffn",
    "init_attn_params",
    "init_ffn_params",
]

_NEG = -1e30


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope_table(positions: jnp.ndarray, d_head: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) -> cos/sin tables (..., d_head/2)."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., H, d_head); cos/sin broadcastable (..., 1, d_head/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def _qkv(params, x, n_heads, n_kv, d_head):
    B, S, D = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, n_heads, d_head)
    k = k.reshape(B, S, n_kv, d_head)
    v = v.reshape(B, S, n_kv, d_head)
    return q, k, v


def attention(
    params: dict,
    x: jnp.ndarray,                 # (B, S, D)
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float = 10_000.0,
    window: Optional[int] = None,   # sliding-window width (None = global)
    q_chunk: int = 1024,
    positions: Optional[jnp.ndarray] = None,
    return_cache: bool = False,
):
    """Causal self-attention (training / prefill). Query-chunked."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _qkv(params, x, n_heads, n_kv, d_head)
    cos, sin = rope_table(positions, d_head, rope_theta)
    q = apply_rope(q, cos[:, None, :], sin[:, None, :])
    k = apply_rope(k, cos[:, None, :], sin[:, None, :])
    rep = n_heads // n_kv
    scale = d_head ** -0.5

    qc = max(1, min(q_chunk, S))
    n_chunks = (S + qc - 1) // qc
    Sp = n_chunks * qc
    if Sp != S:
        q = jnp.pad(q, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    qs = q.reshape(B, n_chunks, qc, n_heads, d_head).transpose(1, 0, 2, 3, 4)

    acc_dt = jnp.float32 if _ATTN_DT == "f32" else jnp.bfloat16
    kT = k.astype(acc_dt)
    vT = v.astype(acc_dt)

    def chunk(carry, inp):
        ci, qb = inp  # qb (B, qc, H, dh)
        qpos = ci * qc + jnp.arange(qc)
        kpos = jnp.arange(S)
        mask = qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        # scores: (B, H, qc, S)
        qg = qb.reshape(B, qc, n_kv, rep, d_head)
        s = jnp.einsum("bqgrd,bsgd->bgrqs", qg.astype(acc_dt), kT).astype(
            jnp.float32) * scale
        s = jnp.where(mask[None, None, None], s, _NEG)
        p = jax.nn.softmax(s, axis=-1).astype(acc_dt)
        o = jnp.einsum("bgrqs,bsgd->bqgrd", p, vT).astype(jnp.float32)
        return carry, o.reshape(B, qc, n_heads, d_head)

    _, outs = jax.lax.scan(chunk, None, (jnp.arange(n_chunks), qs))
    o = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, n_heads, d_head)[:, :S]
    y = o.astype(x.dtype).reshape(B, S, n_heads * d_head) @ params["wo"]
    if not return_cache:
        return y, None
    # serving cache: keep only the window for sliding-window layers
    if window is not None and S >= window:
        kc, vc = k[:, S - window :], v[:, S - window :]
    else:
        kc, vc = k, v
    return y, {"k": kc, "v": vc}


def decode_attention(
    params: dict,
    x: jnp.ndarray,                # (B, 1, D)
    cache: dict,                   # {"k","v"}: (B, S_cache, n_kv, d_head)
    pos: jnp.ndarray,              # () int32 — current position
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    rope_theta: float = 10_000.0,
    window: Optional[int] = None,
):
    """Single-token decode with KV cache (ring buffer for windowed layers)."""
    B = x.shape[0]
    S_c = cache["k"].shape[1]
    q, k, v = _qkv(params, x, n_heads, n_kv, d_head)
    cos, sin = rope_table(pos[None], d_head, rope_theta)
    q = apply_rope(q, cos[:, None, :], sin[:, None, :])
    k = apply_rope(k, cos[:, None, :], sin[:, None, :])
    slot = pos % S_c if window is not None else pos
    if _CACHE_UPDATE == "where":
        # predicated elementwise write: every shard of a sequence-sharded
        # cache updates (or keeps) only its local slice — no re-gather
        sel = (jnp.arange(S_c) == slot)[None, :, None, None]
        ck = jnp.where(sel, k.astype(cache["k"].dtype), cache["k"])
        cv = jnp.where(sel, v.astype(cache["v"].dtype), cache["v"])
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    rep = n_heads // n_kv
    scale = d_head ** -0.5
    acc_dt = jnp.float32 if _ATTN_DT == "f32" else cache["k"].dtype
    qg = q.reshape(B, n_kv, rep, d_head)
    # contract against the cache in ITS dtype (an f32 upcast would
    # materialize a full-cache-sized temp — 2x decode HBM traffic)
    s = jnp.einsum("bgrd,bsgd->bgrs", qg.astype(acc_dt), ck).astype(
        jnp.float32) * scale
    idx = jnp.arange(S_c)
    if window is not None:
        valid = (idx <= slot) | (pos >= S_c)  # ring buffer: all slots valid once full
    else:
        valid = idx <= pos
    s = jnp.where(valid[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1).astype(acc_dt)
    o = jnp.einsum("bgrs,bsgd->bgrd", p, cv).astype(jnp.float32)
    y = o.reshape(B, 1, n_heads * d_head).astype(x.dtype) @ params["wo"]
    return y, {"k": ck, "v": cv}


# --------------------------------------------------------------------------
# FFN
# --------------------------------------------------------------------------


def ffn(params: dict, x: jnp.ndarray, *, glu: bool = True, act: str = "silu") -> jnp.ndarray:
    a = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[act]
    if glu:
        return (a(x @ params["w_gate"]) * (x @ params["w_up"])) @ params["w_down"]
    return a(x @ params["w_up"]) @ params["w_down"]


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------


def init_attn_params(key, d_model, n_heads, n_kv, d_head, qkv_bias, dtype):
    ks = jax.random.split(key, 4)
    sc = d_model ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (d_model, n_heads * d_head), dtype) * sc,
        "wk": jax.random.normal(ks[1], (d_model, n_kv * d_head), dtype) * sc,
        "wv": jax.random.normal(ks[2], (d_model, n_kv * d_head), dtype) * sc,
        "wo": jax.random.normal(ks[3], (n_heads * d_head, d_model), dtype) * sc,
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), dtype)
        p["bk"] = jnp.zeros((n_kv * d_head,), dtype)
        p["bv"] = jnp.zeros((n_kv * d_head,), dtype)
    return p


def init_ffn_params(key, d_model, d_ff, glu, dtype):
    ks = jax.random.split(key, 3)
    si, so = d_model ** -0.5, d_ff ** -0.5
    p = {
        "w_up": jax.random.normal(ks[0], (d_model, d_ff), dtype) * si,
        "w_down": jax.random.normal(ks[1], (d_ff, d_model), dtype) * so,
    }
    if glu:
        p["w_gate"] = jax.random.normal(ks[2], (d_model, d_ff), dtype) * si
    return p
