"""Parameter/activation sharding rules: FSDP over the data (+pod) axes,
tensor parallelism over the model axis, expert parallelism for MoE.

Rules are name-based over the param pytree (the same builder produces both
params and specs, so names are authoritative).  Scanned (stacked) params get
a leading ``None`` axis for the unit dimension.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["param_pspecs", "act_specs", "DP", "TP", "wsc"]


def wsc(x, spec, mesh):
    """with_sharding_constraint that is a no-op without a mesh."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding

    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

TP = "model"


def DP(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def _rule(name: str, ndim: int, dp, tp):
    """PartitionSpec for a leaf called ``name`` with ``ndim`` dims."""
    two = {
        # (in, out) projections: FSDP on input dim, TP on output dim
        "wq": P(dp, tp), "wk": P(dp, tp), "wv": P(dp, tp),
        "w_up": P(dp, tp), "w_gate": P(dp, tp),
        "wz": P(dp, tp), "wx": P(dp, tp),
        "wB": P(dp, None), "wC": P(dp, None), "wdt": P(dp, None),
        # (in, out) with TP on input dim (row-parallel)
        "wo": P(tp, dp), "w_down": P(tp, dp),
        "embed": P(tp, dp),          # vocab-sharded embedding
        "lm_head": P(dp, tp),        # vocab-sharded logits
        "conv_w": P(None, tp),
        "router": P(None, None),
    }
    three = {
        # MoE expert weights: experts over TP, FSDP on d_model dim
        "w_up": P(tp, dp, None),
        "w_gate": P(tp, dp, None),
        "w_down": P(tp, None, dp),
    }
    one = {
        "bq": P(tp), "bk": P(tp), "bv": P(tp),
        "conv_b": P(tp),
    }
    if ndim >= 3 and name in three:
        spec = three[name]
        return P(*spec, *([None] * (ndim - 3)))
    if ndim >= 2 and name in two:
        spec = two[name]
        return P(*spec, *([None] * (ndim - 2)))
    if ndim == 1 and name in one:
        return one[name]
    return P(*([None] * ndim))  # norms, scalars, biases: replicated


def param_pspecs(params, multi_pod: bool, scanned_prefixes=("scan",)):
    """Mirror a params pytree with PartitionSpecs."""
    dp = DP(multi_pod)
    dp = dp if len(dp) > 1 else dp[0]

    def spec_of(path, leaf):
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        name = keys[-1]
        scanned = keys[0] in scanned_prefixes
        nd = leaf.ndim - (1 if scanned else 0)
        s = _rule(name, nd, dp, TP)
        if scanned:
            s = P(None, *s)
        return s

    return jax.tree_util.tree_map_with_path(spec_of, params)


def act_specs(multi_pod: bool):
    """Common activation PartitionSpecs."""
    dp = DP(multi_pod)
    dp = dp if len(dp) > 1 else dp[0]
    return {
        "tokens": P(dp, None),
        "hidden": P(dp, None, None),
        "hidden_tp": P(dp, None, TP),
        "logits": P(dp, None, TP),
        "kv_cache": P(dp, TP, None, None),   # (B, S, n_kv, d_head): seq over TP
        "ssm_state": P(dp, TP, None, None),  # (B, H, P, N): heads over TP
    }
