"""Elastic scaling: restore any checkpoint onto a different mesh.

Checkpoints store full (unsharded) host arrays, so resharding to a new mesh
is a pure placement problem: build the new mesh's NamedShardings from the
same name-based rules (repro.models.sharding.param_pspecs) and device_put
each leaf.  512 -> 256 -> 1024 chips works without touching the arrays;
what changes is only how XLA slices them.  The test suite round-trips a
train state across mesh shapes and checks bitwise equality of the math.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .checkpoint import restore

__all__ = ["reshard_restore", "shardings_for"]


def _norm_spec(spec, shape, mesh):
    """Drop sharding on axes that do not divide (GSPMD would pad; shard_map
    would reject) — the safe default when the new mesh is smaller/larger."""
    parts = []
    for i, ax in enumerate(spec):
        if ax is None:
            parts.append(None)
            continue
        sizes = 1
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            sizes *= mesh.shape[a]
        parts.append(ax if shape[i] % sizes == 0 else None)
    parts += [None] * (len(shape) - len(parts))
    return P(*parts)


def shardings_for(tree, specs, mesh: Mesh):
    return jax.tree.map(
        lambda leaf, spec: NamedSharding(mesh, _norm_spec(spec, leaf.shape, mesh)),
        tree,
        specs,
    )


def reshard_restore(path: str, step: int, like: Any, specs: Any, mesh: Mesh):
    """Restore ``like``-shaped state onto ``mesh`` (any shape)."""
    sh = shardings_for(like, specs, mesh)
    return restore(path, step, like, shardings=sh)
