"""Fault-tolerant checkpointing: atomic, manifest-driven, async-capable.

Layout per step::

    <dir>/step_000123/
        arrays.npz          # flattened pytree leaves (gathered to host)
        manifest.json       # step, tree structure, mesh shape, pipeline cursor,
                            # PRNG key, leaf shapes/dtypes, completion marker

Writes go to ``step_X.tmp`` and are atomically renamed after fsync — a crash
mid-write can never corrupt the latest checkpoint ("last complete step"
recovery).  ``AsyncCheckpointer`` moves serialization off the training loop
(overlap with the next step), bounding checkpoint stalls to an enqueue.

Restore is mesh-aware: arrays are host-loaded and re-placed with the current
mesh's shardings; changing the mesh between save and restore is handled by
``repro.ckpt.elastic`` (elastic scaling).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax

__all__ = ["save", "restore", "load", "latest_step", "AsyncCheckpointer"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, str(treedef)


def _fsync_dir(path: str) -> None:
    """fsync a directory entry — required for rename durability: POSIX only
    guarantees the rename itself is atomic, not that it has reached disk;
    a crash after rename but before the parent's metadata flush can revert
    to the old directory contents on ext4/xfs."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:       # platforms/filesystems without O_RDONLY dir opens
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(path: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Synchronous atomic checkpoint write; returns the final directory.

    Durability order: arrays fsynced, manifest (with the completion marker)
    fsynced, tmp dir entry fsynced, atomic rename, PARENT dir entry fsynced.
    Only after the last step is the checkpoint guaranteed to survive a
    crash; everything before it leaves a ``.tmp`` that recovery ignores."""
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    host = [np.asarray(x) for x in leaves]
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        np.savez(f, *host)
        f.flush()
        os.fsync(f.fileno())
    manifest = {
        "step": step,
        "n_leaves": len(host),
        "shapes": [list(x.shape) for x in host],
        "dtypes": [str(x.dtype) for x in host],
        "extra": extra or {},
        "complete": True,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic on POSIX
    _fsync_dir(path)        # rename alone is not crash-durable everywhere
    return final


def latest_step(path: str) -> Optional[int]:
    """Largest step with a COMPLETE manifest (ignores torn .tmp writes)."""
    if not os.path.isdir(path):
        return None
    best = None
    for d in os.listdir(path):
        if not d.startswith("step_") or d.endswith(".tmp"):
            continue
        mf = os.path.join(path, d, "manifest.json")
        try:
            with open(mf) as f:
                m = json.load(f)
            if m.get("complete"):
                s = int(m["step"])
                best = s if best is None or s > best else best
        except Exception:
            continue
    return best


def load(path: str, step: int):
    """Load a checkpoint WITHOUT a ``like`` template: returns
    ``(leaves, manifest)`` with host numpy leaves in saved (tree-flatten)
    order.  The fresh-process restore path — shapes and dtypes come from
    the manifest, not from live objects the crashed process no longer
    has.  Raises on an incomplete manifest (a torn write's ``.tmp`` never
    has one, but a copied/partial directory might)."""
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if not manifest.get("complete"):
        raise ValueError(f"checkpoint at {d} is incomplete")
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves = [data[f"arr_{i}"] for i in range(manifest["n_leaves"])]
    for leaf, shape, dt in zip(leaves, manifest["shapes"],
                               manifest["dtypes"]):
        if list(leaf.shape) != list(shape) or str(leaf.dtype) != dt:
            raise ValueError(
                f"leaf mismatch in {d}: {leaf.shape}/{leaf.dtype} "
                f"vs manifest {shape}/{dt}"
            )
    return leaves, manifest


def restore(path: str, step: int, like: Any, shardings: Any = None):
    """Load a checkpoint into the structure of ``like`` (shape/dtype checked).

    ``shardings``: optional pytree of jax.sharding.Sharding to place leaves
    directly onto the current mesh (device_put per leaf).
    """
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    host = [data[k] for k in data.files]
    leaves, treedef = jax.tree_util.tree_flatten(like)
    assert len(host) == len(leaves), (len(host), len(leaves))
    for h, l in zip(host, leaves):
        assert tuple(h.shape) == tuple(l.shape), (h.shape, l.shape)
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
        host = [jax.device_put(h.astype(l.dtype), s)
                for h, l, s in zip(host, leaves, sh_leaves)]
    else:
        host = [jax.numpy.asarray(h.astype(l.dtype)) for h, l in zip(host, leaves)]
    return jax.tree_util.tree_unflatten(treedef, host), manifest["extra"]


class AsyncCheckpointer:
    """Single-writer background checkpoint thread (overlaps training).

    A failed background write is never silent: the exception is re-raised
    on the next ``wait()`` OR the next ``submit()`` (whichever comes
    first), then cleared so the checkpointer stays usable — the caller
    decides whether to retry the step or crash.  ``failed_writes`` counts
    surfaced failures for monitoring."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None
        self.failed_writes = 0

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err:
            err, self._err = self._err, None  # surface once, stay usable
            self.failed_writes += 1
            raise err

    def submit(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()  # one in flight at a time
        host = jax.tree.map(np.asarray, tree)  # device->host on caller thread

        def work():
            try:
                save(self.path, step, host, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.path)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"), ignore_errors=True)
