from .checkpoint import AsyncCheckpointer, latest_step, restore, save
from .elastic import reshard_restore, shardings_for

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer",
           "reshard_restore", "shardings_for"]
