from .checkpoint import AsyncCheckpointer, latest_step, load, restore, save
from .elastic import reshard_restore, shardings_for

__all__ = ["save", "restore", "load", "latest_step", "AsyncCheckpointer",
           "reshard_restore", "shardings_for"]
