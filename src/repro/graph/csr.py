"""CSR graph container used throughout the partitioning engine.

An undirected graph G = (V, E, c, omega) is stored as a *symmetric* CSR
adjacency structure: every undirected edge {u, v} appears as the two arcs
(u, v) and (v, u).  Edge weights ``ew`` are per-arc (both arcs of one edge
carry the same weight); node weights ``nw`` are per-node.  This mirrors the
adjacency-array representation of the paper (Section IV-A) and is the native
layout for the sort/segment primitives the TPU adaptation is built on.

Three twin types exist:

* :class:`GraphNP` — host-side numpy arrays.  Generators, shard splitting,
  and the host fallback contraction live here.
* :class:`Graph` — a registered JAX pytree with the same fields, used inside
  jitted/shard_mapped computations whose shapes are static per level.
* :class:`GraphDev` — a *device-resident* bucket-padded CSR handle: the
  output of the LP engine's device contraction
  (``repro.core.contraction.contract_device``).  Arrays are padded to
  power-of-two buckets (so one compiled contraction/pack executable serves
  many levels); only the ``(n, m)`` scalars live on host.  ``to_host()``
  materializes a :class:`GraphNP` lazily — the escape hatch for the host
  engines (numpy SCLaP, FM) and the evolutionary coarsest stage.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..obs.memory import account as _mem_account

__all__ = [
    "Graph",
    "GraphDev",
    "GraphNP",
    "arc_bucket",
    "from_edges",
    "pow2",
    "to_device",
    "to_device_csr",
    "to_host",
    "validate",
]


def pow2(x: int) -> int:
    """Smallest power of two >= x (the node/label-axis bucket policy)."""
    return 1 << max(0, int(x) - 1).bit_length()


def arc_bucket(m: int) -> int:
    """Arc-axis bucket: pow2 below 16384, then 16384-arc rungs.

    Single source of truth shared by the LP engine's contraction buckets and
    the dynamic store's compaction buckets: value-only key sorts over the
    arc axis are the critical path and scale with the PADDED arc count, so
    hot (large) levels get a tight rung (<= 8% padding) instead of the
    up-to-2x tax of pure pow2; small levels keep pow2 rungs so the bucket
    count stays O(log m)."""
    if m <= 16384:
        return pow2(max(m, 8))
    return -(-m // 16384) * 16384


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Graph:
    """Device-side CSR graph (a JAX pytree).

    Attributes:
      indptr:  (n + 1,) int32 — CSR row pointers.
      indices: (m,)     int32 — arc heads (m counts *arcs*, i.e. 2x edges).
      ew:      (m,)     float32 — arc weights.
      nw:      (n,)     float32 — node weights.
    """

    indptr: jax.Array
    indices: jax.Array
    ew: jax.Array
    nw: jax.Array

    @property
    def n(self) -> int:
        return self.indptr.shape[0] - 1

    @property
    def m(self) -> int:  # number of arcs (2x undirected edges)
        return self.indices.shape[0]

    @property
    def total_node_weight(self) -> jax.Array:
        return jnp.sum(self.nw)

    def degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def arc_sources(self) -> jax.Array:
        """(m,) int32 — source node of each arc (CSR row expansion)."""
        return jnp.repeat(
            jnp.arange(self.n, dtype=jnp.int32),
            self.degrees(),
            total_repeat_length=self.m,
        )

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        return (self.indptr, self.indices, self.ew, self.nw), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


@dataclass(frozen=True)
class GraphNP:
    """Host-side CSR graph (numpy); see :class:`Graph` for field semantics."""

    indptr: np.ndarray
    indices: np.ndarray
    ew: np.ndarray
    nw: np.ndarray

    @property
    def n(self) -> int:
        return int(self.indptr.shape[0] - 1)

    @property
    def m(self) -> int:
        return int(self.indices.shape[0])

    @property
    def total_node_weight(self) -> float:
        return float(self.nw.sum())

    def degrees(self) -> np.ndarray:
        return self.indptr[1:] - self.indptr[:-1]

    def arc_sources(self) -> np.ndarray:
        return np.repeat(np.arange(self.n, dtype=np.int32), self.degrees())


class GraphDev:
    """Device-resident bucket-padded CSR graph (coarse levels of the V-cycle).

    Invariants (as emitted by ``contract_device`` and relied on by the LP
    engine's device pack builder and arena):

    * ``indptr`` has ``Nb + 1`` entries with ``Nb = 2^ceil(log2 n)``; rows
      ``>= n`` all hold ``m`` (so sentinel-node gathers read degree 0).
    * ``indices`` / ``ew`` / ``src`` have ``Mb = 2^ceil(log2 m)`` entries;
      arcs ``>= m`` hold index 0 / weight 0 (inert under any masked use).
    * ``nw`` has ``Nb`` entries, 0 beyond ``n``.

    Only ``n``, ``m``, and ``nw_max`` are host scalars.  ``degrees()`` and
    ``to_host()`` materialize lazily and cache; ``on_materialize(nbytes)``
    (when set) lets the owning engine account the device->host traffic.
    """

    def __init__(self, indptr, indices, ew, nw, src, n: int, m: int,
                 nw_max: float = 0.0, ew_max: float = 0.0,
                 ew_integral: bool = False, on_materialize=None):
        self.indptr = indptr
        self.indices = indices
        self.ew = ew
        self.nw = nw
        self.src = src
        self._n = int(n)
        self._m = int(m)
        self.nw_max = float(nw_max)
        # weight metadata for the next contraction's packed-key decision:
        # integral weights stay integral under contraction (sums)
        self.ew_max = float(ew_max)
        self.ew_integral = bool(ew_integral)
        self.on_materialize = on_materialize
        self._indptr_host: np.ndarray | None = None
        self._host: GraphNP | None = None
        # every base-CSR level flows through this constructor (upload,
        # contraction output, store merge/vacuum) — the one accounting
        # chokepoint for the base_csr family
        _mem_account("base_csr", indptr, indices, ew, nw, src)

    @property
    def n(self) -> int:
        return self._n

    @property
    def m(self) -> int:
        return self._m

    @property
    def total_node_weight(self) -> float:
        """Total node weight, reduced on device (padding is 0 — inert)."""
        return float(jnp.sum(self.nw))

    def _indptr_np(self) -> np.ndarray:
        if self._indptr_host is None:
            self._indptr_host = np.asarray(self.indptr[: self._n + 1], dtype=np.int64)
            if self.on_materialize is not None:
                self.on_materialize(self._indptr_host.nbytes)
        return self._indptr_host

    def degrees(self) -> np.ndarray:
        return np.diff(self._indptr_np())

    def to_host(self) -> GraphNP:
        """Materialize a :class:`GraphNP` (cached) — one O(n + m) download."""
        if self._host is None:
            self._host = GraphNP(
                indptr=self._indptr_np(),
                indices=np.asarray(self.indices[: self._m], dtype=np.int32),
                ew=np.asarray(self.ew[: self._m], dtype=np.float32),
                nw=np.asarray(self.nw[: self._n], dtype=np.float32),
            )
            if self.on_materialize is not None:
                self.on_materialize(self._m * 8 + self._n * 4)
        return self._host


def from_edges(
    n: int,
    u: np.ndarray,
    v: np.ndarray,
    w: np.ndarray | None = None,
    nw: np.ndarray | None = None,
    symmetrize: bool = True,
    dedup: bool = True,
) -> GraphNP:
    """Build a :class:`GraphNP` from an edge list.

    Args:
      n: number of nodes.
      u, v: int arrays of endpoints.  Self loops are dropped.
      w: optional edge weights (default: all ones).
      nw: optional node weights (default: all ones).
      symmetrize: if True, adds both arcs per input edge.
      dedup: if True, parallel arcs are merged (weights summed).
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    if w is None:
        w = np.ones(u.shape[0], dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)

    keep = u != v
    u, v, w = u[keep], v[keep], w[keep]

    if symmetrize:
        uu = np.concatenate([u, v])
        vv = np.concatenate([v, u])
        ww = np.concatenate([w, w])
    else:
        uu, vv, ww = u, v, w

    if dedup and uu.size:
        key = uu * np.int64(n) + vv
        order = np.argsort(key, kind="stable")
        key = key[order]
        ww = ww[order]
        boundary = np.empty(key.shape[0], dtype=bool)
        boundary[0] = True
        boundary[1:] = key[1:] != key[:-1]
        run_id = np.cumsum(boundary) - 1
        n_runs = int(run_id[-1]) + 1
        merged_w = np.zeros(n_runs, dtype=np.float64)
        np.add.at(merged_w, run_id, ww)
        first = np.flatnonzero(boundary)
        uu = (key[first] // n).astype(np.int32)
        vv = (key[first] % n).astype(np.int32)
        ww = merged_w.astype(np.float32)
    else:
        order = np.argsort(uu * np.int64(n) + vv, kind="stable")
        uu = uu[order].astype(np.int32)
        vv = vv[order].astype(np.int32)
        ww = ww[order]

    counts = np.bincount(uu, minlength=n).astype(np.int64)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    if nw is None:
        nw = np.ones(n, dtype=np.float32)
    return GraphNP(
        indptr=indptr.astype(np.int64),
        indices=vv.astype(np.int32),
        ew=ww.astype(np.float32),
        nw=np.asarray(nw, dtype=np.float32),
    )


def to_device(g: GraphNP) -> Graph:
    dev = Graph(
        indptr=jnp.asarray(g.indptr, dtype=jnp.int32)
        if g.m < 2**31
        else jnp.asarray(g.indptr),
        indices=jnp.asarray(g.indices, dtype=jnp.int32),
        ew=jnp.asarray(g.ew, dtype=jnp.float32),
        nw=jnp.asarray(g.nw, dtype=jnp.float32),
    )
    _mem_account("base_csr", dev.indptr, dev.indices, dev.ew, dev.nw)
    return dev


def to_device_csr(g: GraphNP, on_materialize=None, on_upload=None) -> GraphDev:
    """Upload a host CSR into a bucket-padded device-resident :class:`GraphDev`.

    The handle satisfies exactly the invariants ``contract_device`` outputs
    satisfy (pow2 node bucket, ``arc_bucket`` arc bucket, inert padding:
    rows >= n hold m, arcs >= m hold index 0 / weight 0), so downstream
    consumers (the LP engine's device pack gather, the dynamic store's
    compaction) cannot tell an uploaded finest graph from a contracted
    coarse level.  ``on_upload(nbytes)``, when set, lets the owner account
    the host->device traffic of the one-time upload."""
    n, m = g.n, g.m
    Nb = pow2(max(n, 8))
    Mb = arc_bucket(m)
    indptr = np.full(Nb + 1, m, dtype=np.int64)
    indptr[: n + 1] = g.indptr
    indices = np.zeros(Mb, dtype=np.int32)
    indices[:m] = g.indices
    ew = np.zeros(Mb, dtype=np.float32)
    ew[:m] = g.ew
    src = np.zeros(Mb, dtype=np.int32)
    src[:m] = g.arc_sources()
    nw = np.zeros(Nb, dtype=np.float32)
    nw[:n] = g.nw
    if on_upload is not None:
        on_upload(indptr.nbytes // 2 + indices.nbytes + ew.nbytes
                  + src.nbytes + nw.nbytes)
    return GraphDev(
        indptr=jnp.asarray(indptr, dtype=jnp.int32),
        indices=jnp.asarray(indices),
        ew=jnp.asarray(ew),
        nw=jnp.asarray(nw),
        src=jnp.asarray(src),
        n=n, m=m,
        nw_max=float(g.nw.max()) if n else 0.0,
        ew_max=float(g.ew.max()) if m else 0.0,
        ew_integral=bool(np.all(g.ew == np.round(g.ew))) if m else True,
        on_materialize=on_materialize,
    )


def to_host(g: Graph) -> GraphNP:
    return GraphNP(
        indptr=np.asarray(g.indptr, dtype=np.int64),
        indices=np.asarray(g.indices),
        ew=np.asarray(g.ew),
        nw=np.asarray(g.nw),
    )


def validate(g: GraphNP) -> None:
    """Raise AssertionError if the CSR structure is inconsistent/asymmetric."""
    assert g.indptr[0] == 0 and g.indptr[-1] == g.m
    assert np.all(np.diff(g.indptr) >= 0)
    assert g.nw.shape == (g.n,)
    assert g.ew.shape == (g.m,)
    if g.m == 0:
        return
    assert g.indices.min() >= 0 and g.indices.max() < g.n
    # symmetry: the multiset of (u, v, w) must equal the multiset of (v, u, w)
    src = g.arc_sources().astype(np.int64)
    dst = g.indices.astype(np.int64)
    fwd = np.lexsort((dst, src))
    bwd = np.lexsort((src, dst))
    assert np.array_equal(src[fwd], dst[bwd])
    assert np.array_equal(dst[fwd], src[bwd])
    np.testing.assert_allclose(g.ew[fwd], g.ew[bwd], rtol=1e-5)
