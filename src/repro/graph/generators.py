"""Synthetic graph families for benchmarking the partitioner.

The paper evaluates on (a) mesh-type networks — random geometric graphs
``rggX`` and Delaunay triangulations ``delX`` — and (b) complex networks —
social networks and web graphs.  The original instances (uk-2007 etc.) are
multi-GB downloads and unavailable offline, so the benchmark harness uses
faithful synthetic stand-ins:

* :func:`rgg` — exactly the paper's rggX family: 2^X random points in the
  unit square, connect within radius ``0.55 * sqrt(ln n / n)``.
* :func:`mesh2d` — triangulated regular grid; stand-in for the delX family
  (planar, bounded degree, strong locality — the properties the paper's
  "mesh type" classification relies on).
* :func:`rmat` — Kronecker/R-MAT generator; stand-in for web graphs
  (heavy-tailed degrees, low diameter, community structure).
* :func:`barabasi_albert` — preferential attachment; stand-in for social
  networks.
* :func:`planted_partition` — stochastic block model with known ground-truth
  communities; used by tests because the optimal cut is known by design.
"""

from __future__ import annotations

import numpy as np

from .csr import GraphNP, from_edges

__all__ = [
    "rgg",
    "mesh2d",
    "rmat",
    "barabasi_albert",
    "planted_partition",
    "ring",
    "star",
]


def rgg(scale: int, seed: int = 0) -> GraphNP:
    """Random geometric graph with ``n = 2**scale`` nodes (paper's rggX).

    Uses a cell grid of side ``r`` so each point only compares against the 9
    neighbouring cells; this is the standard O(n) expected-time construction.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    pts = rng.random((n, 2))
    r = 0.55 * np.sqrt(np.log(n) / n)
    ncell = max(1, int(1.0 / r))
    cell = (pts[:, 0] * ncell).astype(np.int64) * ncell + (
        pts[:, 1] * ncell
    ).astype(np.int64)
    order = np.argsort(cell, kind="stable")
    cell_sorted = cell[order]
    # start offset of every occupied cell
    uniq, starts = np.unique(cell_sorted, return_index=True)
    starts = np.append(starts, n)
    cell_to_slot = {int(c): i for i, c in enumerate(uniq)}

    us, vs = [], []
    r2 = r * r
    # For each occupied cell, compare its points with points in the
    # 5 "forward" neighbour cells (self, E, SW, S, SE) — each unordered pair
    # of cells is visited once.
    offsets = [(0, 0), (0, 1), (1, -1), (1, 0), (1, 1)]
    for slot in range(uniq.shape[0]):
        c = int(uniq[slot])
        cx, cy = divmod(c, ncell)
        a = order[starts[slot] : starts[slot + 1]]
        pa = pts[a]
        for dx, dy in offsets:
            nx, ny = cx + dx, cy + dy
            if not (0 <= nx < ncell and 0 <= ny < ncell):
                continue
            nb = nx * ncell + ny
            s2 = cell_to_slot.get(nb)
            if s2 is None:
                continue
            b = order[starts[s2] : starts[s2 + 1]]
            pb = pts[b]
            d2 = ((pa[:, None, :] - pb[None, :, :]) ** 2).sum(-1)
            if dx == 0 and dy == 0:
                iu, iv = np.triu_indices(a.shape[0], k=1)
                hit = d2[iu, iv] <= r2
                us.append(a[iu[hit]])
                vs.append(a[iv[hit]])
            else:
                iu, iv = np.nonzero(d2 <= r2)
                us.append(a[iu])
                vs.append(b[iv])
    u = np.concatenate(us) if us else np.empty(0, np.int64)
    v = np.concatenate(vs) if vs else np.empty(0, np.int64)
    return from_edges(n, u, v)


def mesh2d(side: int) -> GraphNP:
    """Triangulated ``side x side`` grid (Delaunay-family stand-in).

    Every node connects to its E and S neighbours plus the SE diagonal,
    giving a planar triangulation of the unit square grid.
    """
    idx = np.arange(side * side, dtype=np.int64).reshape(side, side)
    e = [
        (idx[:, :-1].ravel(), idx[:, 1:].ravel()),  # east
        (idx[:-1, :].ravel(), idx[1:, :].ravel()),  # south
        (idx[:-1, :-1].ravel(), idx[1:, 1:].ravel()),  # south-east diagonal
    ]
    u = np.concatenate([a for a, _ in e])
    v = np.concatenate([b for _, b in e])
    return from_edges(side * side, u, v)


def rmat(
    scale: int,
    edge_factor: int = 8,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> GraphNP:
    """R-MAT graph with ``2**scale`` nodes (web-graph stand-in, Graph500 params)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    u = np.zeros(m, dtype=np.int64)
    v = np.zeros(m, dtype=np.int64)
    ab = a + b
    a_norm = a / ab if ab > 0 else 0.5
    c_norm = c / (1.0 - ab) if ab < 1 else 0.5
    for _ in range(scale):
        u <<= 1
        v <<= 1
        go_down = rng.random(m) >= ab  # 1 => lower half for u-bit
        r2 = rng.random(m)
        u |= go_down.astype(np.int64)
        v |= np.where(go_down, r2 >= c_norm, r2 >= a_norm).astype(np.int64)
    # permute IDs so degree is not correlated with node id (matters for the
    # contiguous-range sharding used by the distributed algorithms)
    perm = rng.permutation(n)
    return from_edges(n, perm[u], perm[v])


def barabasi_albert(n: int, m_attach: int = 4, seed: int = 0) -> GraphNP:
    """Preferential-attachment graph (social-network stand-in).

    Vectorized batched variant: nodes arrive in geometric batches and attach
    to endpoints sampled from the edge list *before the batch* (a standard
    approximation that preserves the power-law degree distribution).
    """
    rng = np.random.default_rng(seed)
    n0 = max(m_attach + 1, 8)
    # seed clique-ish core
    core_u, core_v = np.triu_indices(n0, k=1)
    targets = np.concatenate([core_u, core_v]).astype(np.int64)
    us = [core_u.astype(np.int64)]
    vs = [core_v.astype(np.int64)]
    cur = n0
    while cur < n:
        batch = min(max(64, cur // 4), n - cur)
        new_nodes = np.repeat(np.arange(cur, cur + batch, dtype=np.int64), m_attach)
        picked = targets[rng.integers(0, targets.shape[0], new_nodes.shape[0])]
        us.append(new_nodes)
        vs.append(picked)
        targets = np.concatenate([targets, new_nodes, picked])
        cur += batch
    u = np.concatenate(us)
    v = np.concatenate(vs)
    perm = rng.permutation(n).astype(np.int64)
    return from_edges(n, perm[u], perm[v])


def planted_partition(
    n: int,
    k: int,
    p_in: float = 0.02,
    p_out: float = 0.0005,
    seed: int = 0,
) -> GraphNP:
    """Stochastic block model with k equal communities (known ground truth)."""
    rng = np.random.default_rng(seed)
    comm = np.arange(n, dtype=np.int64) % k
    # sample via expected counts (sparse SBM sampler)
    m_in = int(p_in * n * (n / k) / 2)
    m_out = int(p_out * n * n * (k - 1) / k / 2)
    ui = rng.integers(0, n, m_in * 2)
    vi_off = rng.integers(1, max(2, n // k), m_in * 2)
    vi = (ui + vi_off * k) % n  # same community (ids are mod-k striped)
    uo = rng.integers(0, n, m_out * 2)
    vo = rng.integers(0, n, m_out * 2)
    diff = comm[uo] != comm[vo]
    u = np.concatenate([ui, uo[diff]])
    v = np.concatenate([vi, vo[diff]])
    perm = rng.permutation(n).astype(np.int64)
    return from_edges(n, perm[u], perm[v])


def ring(n: int) -> GraphNP:
    u = np.arange(n, dtype=np.int64)
    return from_edges(n, u, (u + 1) % n)


def star(n: int) -> GraphNP:
    u = np.zeros(n - 1, dtype=np.int64)
    return from_edges(n, u, np.arange(1, n, dtype=np.int64))
