"""Host-side packing: static-shape layouts consumed by the jitted LP engine.

XLA requires static shapes, so all ragged-CSR → fixed-shape conversion
happens here (numpy, once per multilevel level):

* :func:`pack_chunks` — groups nodes (in a given traversal order) into
  fixed-size *chunks* with bounded node and edge counts.  The label
  propagation sweep is a ``lax.fori_loop`` over chunks: synchronous within a
  chunk, sequential across chunks.  chunk=1 node reproduces the paper's
  sequential sweep; one big chunk is fully synchronous LP.
* :func:`plan_chunks` / :func:`gather_pack_device` — the split form of the
  same layout used for *device-resident* coarse graphs: the greedy chunk
  assignment (which needs only the O(n) degree sequence) stays on host,
  while the O(m) edge arrays are gathered **on device** from a
  still-resident CSR (``repro.graph.csr.GraphDev``) — the coarse graph's
  adjacency never round-trips through numpy between levels.  The emitted
  arrays are bit-identical to :func:`pack_chunks` on the materialized graph.
* :func:`ell_pack` — ELL layout with *row splitting* (a node of degree d
  occupies ``ceil(d / width)`` rows) for the Pallas ``lp_score`` kernel.
  Row splitting bounds the padding blow-up on power-law graphs.
* :func:`shard_graph` — the paper's distributed graph structure (§IV-A):
  contiguous node ranges per PE, local+ghost index spaces, interface-node
  send buffers, owner/slot maps for the bulk-synchronous label exchange.

Pack invariants (relied upon by the jitted LP sweep and the LP engine):

* **Slot grouping** — within every chunk, the valid arcs are emitted in
  source-slot order: arc ``j`` belongs to the node in slot
  ``edge_src_slot[c, j]`` and slots appear as contiguous non-decreasing
  runs (``np.repeat(arange(cnt), degree)``).  Padded arcs trail the valid
  region with ``edge_valid == False`` and slot 0.  This grouping is what
  makes the sweep's fused single-key sort ``slot * A + cand`` equivalent to
  the two-pass ``lexsort((cand, slot))``: the key's high bits preserve the
  slot partition while the low bits order candidate labels within it.
* **No adjacency splits** — a node's arcs never straddle chunks
  (``max_edges`` is raised to the max block degree sum), so a chunk's move
  decisions see every incident edge.
* **Bucket padding** (:func:`pad_pack`) — padding chunks/slots/arcs to a
  larger bucket shape is *semantically inert*: padded nodes carry the
  sentinel id ``n`` with ``node_valid == False``, padded arcs carry
  ``edge_valid == False`` and weight 0.  The LP engine
  (``repro.core.engine``) exploits this by rounding every level's pack up
  to shared power-of-two buckets so one compiled sweep serves the whole
  hierarchy.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

import jax
import jax.numpy as jnp

from .csr import GraphNP

__all__ = [
    "ChunkPack",
    "EllPack",
    "ShardedGraph",
    "chunk_geometry",
    "plan_chunks",
    "plan_region_pack",
    "layout_nodes",
    "pack_chunks",
    "gather_pack_device",
    "gather_ell_device",
    "plan_ell_rows",
    "pad_pack",
    "ell_pack",
    "shard_graph",
]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def chunk_geometry(n: int, m: int, target_chunks: int = 64) -> tuple:
    """Per-chunk (max_nodes, max_edges) request for an (n, m)-graph.

    Single source of truth for the chunk-shape floors shared by the
    multilevel driver's legacy per-level path, the LP engine's frozen
    geometry, and the benchmark harness — tune it here, not in callers.
    """
    max_nodes = max(256, -(-n // target_chunks))
    max_edges = max(4096, -(-m // max(target_chunks // 2, 1)))
    return max_nodes, max_edges


@dataclass(frozen=True)
class ChunkPack:
    """Fixed-shape chunked traversal layout (all numpy, ready for jnp.asarray).

    Shapes: C = number of chunks, N = max nodes/chunk, E = max arcs/chunk.
    Sentinel for padded node slots is ``n`` (the graph order); padded edges
    carry ``valid == False`` and weight 0.
    """

    nodes: np.ndarray        # (C, N) int32, node ids, padded with n
    node_valid: np.ndarray   # (C, N) bool
    edge_dst: np.ndarray     # (C, E) int32, arc heads, padded with n
    edge_w: np.ndarray       # (C, E) float32, padded with 0
    edge_src_slot: np.ndarray  # (C, E) int32 in [0, N)
    edge_valid: np.ndarray   # (C, E) bool
    n: int

    @property
    def num_chunks(self) -> int:
        return self.nodes.shape[0]


def plan_chunks(
    deg_ordered: np.ndarray,
    n: int,
    max_nodes: int = 4096,
    max_edges: int = 32768,
    block: int = 32,
):
    """Greedy chunk assignment from the O(n) degree sequence alone.

    ``deg_ordered`` is the degree of each node *in traversal order*.  Greedy
    runs over mini-blocks of ``block`` consecutive nodes so the host loop is
    O(n / block).  ``max_edges`` is automatically raised to the maximum block
    degree sum so no node's adjacency is ever split across chunks (a split
    would corrupt the move decision).

    Returns ``(node_chunk, C, N, E)``: the chunk of each ordered node, the
    chunk count, and the rounded per-chunk node/edge capacities.  This is the
    host half of packing; the O(m) edge fill is either :func:`pack_chunks`
    (numpy) or :func:`gather_pack_device` (device gather).
    """
    deg = np.asarray(deg_ordered, dtype=np.int64)
    nb = _round_up(n, block) // block
    pad_n = nb * block - n
    deg_b = np.concatenate([deg, np.zeros(pad_n, np.int64)]).reshape(nb, block)
    bdeg = deg_b.sum(axis=1)
    max_edges = max(max_edges, int(bdeg.max(initial=0)))
    max_nodes = max(block, min(max_nodes, n if n > 0 else block))

    # greedy over blocks
    chunk_of_block = np.zeros(nb, dtype=np.int64)
    cur, ce, cn = 0, 0, 0
    for i in range(nb):
        if (ce + bdeg[i] > max_edges or cn + block > max_nodes) and (ce > 0 or cn > 0):
            cur += 1
            ce, cn = 0, 0
        chunk_of_block[i] = cur
        ce += int(bdeg[i])
        cn += block
    C = cur + 1

    node_chunk = np.repeat(chunk_of_block, block)[:n]  # per ordered node
    N = int(np.bincount(node_chunk, minlength=C).max())
    N = _round_up(N, 8)
    E = int(np.bincount(node_chunk, weights=deg, minlength=C).max())
    E = max(8, _round_up(E, 8))
    return node_chunk, C, N, E


def layout_nodes(order: np.ndarray, node_chunk: np.ndarray, C: int, N: int, n: int):
    """(C, N) node-id layout + validity mask for a chunk plan (host, O(n)).

    ``node_chunk`` is non-decreasing over the ordered nodes (the greedy
    assigns blocks in traversal order), so slots follow from one cumulative
    count — fully vectorized, no per-chunk loop."""
    nodes = np.full((C * N,), n, dtype=np.int32)
    node_valid = np.zeros((C * N,), dtype=bool)
    if node_chunk.size:
        counts = np.bincount(node_chunk, minlength=C)
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        slot = np.arange(node_chunk.size, dtype=np.int64) - starts[node_chunk]
        pos = node_chunk * np.int64(N) + slot
        nodes[pos] = order
        node_valid[pos] = True
    return nodes.reshape(C, N), node_valid.reshape(C, N)


def plan_region_pack(
    deg_ordered: np.ndarray,
    order: np.ndarray,
    n: int,
    max_nodes: int = 4096,
    max_edges: int = 32768,
    block: int = 8,
):
    """Chunk plan + node layout for a SUBSET of the graph's nodes.

    The dynamic repairer packs only the nodes of the affected region into
    chunks (``order`` holds region node ids, ``deg_ordered`` their degrees
    in that order); the rest of the graph participates in the sweep solely
    as (label, weight) context through the arena arrays.  Reuses
    :func:`plan_chunks` / :func:`layout_nodes` with the region size as the
    packed-node count but the GLOBAL ``n`` as the slot sentinel, so the
    emitted layout feeds :func:`gather_pack_device` against the full
    resident CSR unchanged.  Returns ``(nodes, node_valid, C, N, E)``.
    """
    r = int(order.shape[0])
    node_chunk, C, N, E = plan_chunks(
        deg_ordered, r, max_nodes=max_nodes, max_edges=max_edges, block=block
    )
    nodes, node_valid = layout_nodes(order, node_chunk, C, N, n)
    return nodes, node_valid, C, N, E


def pack_chunks(
    g: GraphNP,
    order: np.ndarray,
    max_nodes: int = 4096,
    max_edges: int = 32768,
    block: int = 32,
) -> ChunkPack:
    """Greedy-pack nodes (taken in ``order``) into chunks (host/numpy fill).

    The chunk assignment is :func:`plan_chunks`; this fills the edge arrays
    with numpy CSR slices.
    """
    n = g.n
    order = np.asarray(order, dtype=np.int64)
    deg = g.degrees().astype(np.int64)[order]
    node_chunk, C, N, E = plan_chunks(
        deg, n, max_nodes=max_nodes, max_edges=max_edges, block=block
    )

    nodes = np.full((C, N), n, dtype=np.int32)
    node_valid = np.zeros((C, N), dtype=bool)
    edge_dst = np.full((C, E), n, dtype=np.int32)
    edge_w = np.zeros((C, E), dtype=np.float32)
    edge_src_slot = np.zeros((C, E), dtype=np.int32)
    edge_valid = np.zeros((C, E), dtype=bool)

    # slot of each ordered node within its chunk
    slot = np.zeros(n, dtype=np.int64)
    fill_n = np.zeros(C, dtype=np.int64)
    fill_e = np.zeros(C, dtype=np.int64)
    # vectorized cumulative counts per chunk
    for c in range(C):
        sel = np.flatnonzero(node_chunk == c)
        ids = order[sel]
        cnt = sel.shape[0]
        nodes[c, :cnt] = ids
        node_valid[c, :cnt] = True
        slot[sel] = np.arange(cnt)
        fill_n[c] = cnt
        # edges
        ptr = 0
        starts = g.indptr[ids]
        ends = g.indptr[ids + 1]
        lens = (ends - starts).astype(np.int64)
        tot = int(lens.sum())
        if tot:
            # gather adjacency of all chunk nodes
            idx = np.concatenate([np.arange(s, e) for s, e in zip(starts, ends)])
            edge_dst[c, :tot] = g.indices[idx]
            edge_w[c, :tot] = g.ew[idx]
            edge_src_slot[c, :tot] = np.repeat(np.arange(cnt), lens)
            edge_valid[c, :tot] = True
            ptr = tot
        fill_e[c] = ptr

    return ChunkPack(
        nodes=nodes,
        node_valid=node_valid,
        edge_dst=edge_dst,
        edge_w=edge_w,
        edge_src_slot=edge_src_slot,
        edge_valid=edge_valid,
        n=n,
    )


def pad_pack(pack: ChunkPack, C: int, N: int, E: int) -> ChunkPack:
    """Pad a :class:`ChunkPack` to bucket shape ``(C, N, E)`` (no-op if equal).

    Padding is semantically inert (see module docstring): extra chunks are
    fully invalid, extra node slots carry the sentinel ``n``, extra arcs are
    invalid with weight 0 and slot 0.  Used by the LP engine to map every
    level of a hierarchy onto a small set of compiled sweep shapes.
    """
    c0, n0 = pack.nodes.shape
    e0 = pack.edge_dst.shape[1]
    if (c0, n0, e0) == (C, N, E):
        return pack
    assert C >= c0 and N >= n0 and E >= e0, (
        f"bucket {(C, N, E)} smaller than pack {(c0, n0, e0)}"
    )
    pc, pn, pe = C - c0, N - n0, E - e0
    return ChunkPack(
        nodes=np.pad(pack.nodes, ((0, pc), (0, pn)), constant_values=pack.n),
        node_valid=np.pad(pack.node_valid, ((0, pc), (0, pn))),
        edge_dst=np.pad(pack.edge_dst, ((0, pc), (0, pe)), constant_values=pack.n),
        edge_w=np.pad(pack.edge_w, ((0, pc), (0, pe))),
        edge_src_slot=np.pad(pack.edge_src_slot, ((0, pc), (0, pe))),
        edge_valid=np.pad(pack.edge_valid, ((0, pc), (0, pe))),
        n=pack.n,
    )


@functools.partial(jax.jit, static_argnames=("E",))
def gather_pack_device(
    nodes,       # (C, N) int32 — host-planned layout, sentinel n
    node_valid,  # (C, N) bool
    indptr,      # (Nb + 1,) int32 — device CSR, rows >= n hold m
    indices,     # (Mb,) int32
    ew,          # (Mb,) f32
    n,           # traced scalar int32
    *,
    E: int,
):
    """Device-side edge fill for a chunk plan: the O(m) half of packing.

    Consumes a still-device-resident CSR (bucket-padded, as emitted by
    ``repro.core.contraction.contract_device``) and emits the same
    ``(edge_dst, edge_w, edge_src_slot, edge_valid)`` arrays that
    :func:`pack_chunks` would produce on the materialized graph — arcs
    grouped by source slot in CSR order, padding trailing with sentinel
    ``n`` / weight 0 / slot 0.  One compiled executable per
    ``(layout shape, CSR bucket, E)`` combination.
    """
    C, N = nodes.shape
    last = indptr.shape[0] - 1
    starts = indptr[nodes]                                    # (C, N)
    ends = indptr[jnp.minimum(nodes + 1, last)]
    deg = jnp.where(node_valid, ends - starts, 0).astype(jnp.int32)
    cum = jnp.cumsum(deg, axis=1)                             # (C, N)
    tot = cum[:, -1]                                          # (C,)
    e_iota = jnp.arange(E, dtype=jnp.int32)
    # slot owning arc e == (#slot starts <= e) - 1: one mark per slot at its
    # first-arc offset, then a running count along the arc axis — far
    # cheaper than a per-arc binary search (empty slots mark the same
    # offset as their successor, which keeps the count correct)
    start_off = cum - deg                                     # (C, N)
    flat = (jnp.arange(C, dtype=jnp.int32)[:, None] * E + start_off).reshape(-1)
    flat = jnp.where(
        (node_valid & (start_off < E)).reshape(-1), flat, C * E
    )
    marks = jnp.zeros((C * E,), jnp.int32).at[flat].add(1, mode="drop")
    slot = jnp.cumsum(marks.reshape(C, E), axis=1) - 1        # (C, E)
    valid_e = e_iota[None, :] < tot[:, None]
    slot_c = jnp.clip(slot, 0, N - 1)
    before = jnp.take_along_axis(start_off, slot_c, axis=1)   # arcs in earlier slots
    pos = jnp.take_along_axis(starts, slot_c, axis=1) + (e_iota[None, :] - before)
    pos = jnp.where(valid_e, pos, 0)
    edge_dst = jnp.where(valid_e, indices[pos], n).astype(jnp.int32)
    edge_w = jnp.where(valid_e, ew[pos], 0.0)
    edge_src_slot = jnp.where(valid_e, slot_c, 0).astype(jnp.int32)
    return edge_dst, edge_w, edge_src_slot, valid_e


@dataclass(frozen=True)
class EllPack:
    """Row-split ELL layout for the Pallas ``lp_score`` kernel.

    R rows of fixed ``width``; node of degree d owns ceil(d/width)
    consecutive rows.  R is padded to a multiple of the kernel's node tile.
    """

    dst: np.ndarray       # (R, width) int32, padded with n
    w: np.ndarray         # (R, width) float32, padded 0
    row_node: np.ndarray  # (R,) int32, owning node, padded with n
    n: int

    @property
    def rows(self) -> int:
        return self.row_node.shape[0]

    @property
    def width(self) -> int:
        return self.dst.shape[1]


def plan_ell_rows(
    indptr: np.ndarray, n: int, width: int = 128, tile_rows: int = 256
):
    """Host half of a *device* ELL pack: the O(n + R) row plan.

    Mirrors :func:`ell_pack`'s row-splitting exactly (same widths, same
    tile-rounding) but emits only the per-row ``(row_node, row_first,
    row_end)`` adjacency offsets; the O(m) ``dst``/``w`` fill is gathered on
    device by :func:`gather_ell_device` from a still-resident CSR.  The
    emitted arrays are bit-identical to the host pack on the materialized
    graph — the dense-refinement analogue of :func:`plan_chunks` +
    :func:`gather_pack_device`.
    """
    deg = np.diff(np.asarray(indptr[: n + 1], dtype=np.int64))
    nrows = np.maximum(1, (deg + width - 1) // width)
    R = int(nrows.sum())
    Rp = _round_up(max(R, 1), tile_rows)
    row_node = np.full(Rp, n, dtype=np.int32)
    row_node[:R] = np.repeat(np.arange(n, dtype=np.int32), nrows)
    starts = np.cumsum(np.concatenate([[0], nrows]))[:-1]
    within = np.arange(R, dtype=np.int64) - np.repeat(starts, nrows)
    row_first = np.zeros(Rp, dtype=np.int32)
    row_end = np.zeros(Rp, dtype=np.int32)
    row_first[:R] = (
        np.repeat(np.asarray(indptr[:-1], dtype=np.int64), nrows)
        + within * width
    ).astype(np.int32)
    row_end[:R] = np.repeat(
        np.asarray(indptr[1:], dtype=np.int64), nrows
    ).astype(np.int32)
    return row_node, row_first, row_end


@functools.partial(jax.jit, static_argnames=("width",))
def gather_ell_device(
    row_first,   # (R,) int32 — first adjacency offset of each row
    row_end,     # (R,) int32 — row's exclusive end offset (== indptr[v + 1])
    indices,     # (Mb,) int32 — device CSR heads
    ew,          # (Mb,) f32
    n,           # traced scalar int32 — sentinel destination for padding
    *,
    width: int = 128,
):
    """Device edge fill for an ELL row plan: ``dst``/``w`` bit-identical to
    :func:`ell_pack` on the materialized graph, gathered from the
    device-resident CSR (one executable per ``(R, Mb)`` shape)."""
    pos = row_first[:, None] + jnp.arange(width, dtype=jnp.int32)[None, :]
    valid = pos < row_end[:, None]
    pos_c = jnp.clip(pos, 0, indices.shape[0] - 1)
    dst = jnp.where(valid, indices[pos_c], n).astype(jnp.int32)
    w = jnp.where(valid, ew[pos_c], 0.0)
    return dst, w


def ell_pack(g: GraphNP, width: int = 128, tile_rows: int = 256) -> EllPack:
    n = g.n
    deg = g.degrees().astype(np.int64)
    nrows = np.maximum(1, (deg + width - 1) // width)
    R = int(nrows.sum())
    Rp = _round_up(max(R, 1), tile_rows)

    row_node = np.full(Rp, n, dtype=np.int32)
    row_node[:R] = np.repeat(np.arange(n, dtype=np.int32), nrows)
    # per-row start offset inside the owning node's adjacency
    row_first = np.zeros(R, dtype=np.int64)
    starts = np.cumsum(np.concatenate([[0], nrows]))[:-1]  # first row of node
    within = np.arange(R, dtype=np.int64) - np.repeat(starts, nrows)
    row_first = np.repeat(g.indptr[:-1].astype(np.int64), nrows) + within * width
    row_end = np.repeat(g.indptr[1:].astype(np.int64), nrows)

    pos = row_first[:, None] + np.arange(width, dtype=np.int64)[None, :]
    valid = pos < row_end[:, None]
    pos_c = np.minimum(pos, max(g.m - 1, 0))
    dst = np.full((Rp, width), n, dtype=np.int32)
    w = np.zeros((Rp, width), dtype=np.float32)
    if g.m:
        dst[:R] = np.where(valid, g.indices[pos_c], n)
        w[:R] = np.where(valid, g.ew[pos_c], 0.0)
    return EllPack(dst=dst, w=w, row_node=row_node, n=n)


@dataclass(frozen=True)
class ShardedGraph:
    """The paper's distributed graph (§IV-A) in stacked, padded numpy arrays.

    All arrays have a leading PE axis of size P and are padded to the
    per-field maxima across PEs, so they can be fed straight into
    ``shard_map``.  Local index space per PE p: ``[0, n_p)`` are the owned
    nodes (globals ``range_start[p] .. range_start[p] + n_p``), and
    ``[n_p, n_p + g_p)`` are ghosts (sorted by global id).
    """

    P: int
    n: int                       # global node count
    range_start: np.ndarray      # (P,) int64 — first owned global id
    n_local: np.ndarray          # (P,) int32 — owned nodes per PE
    n_ghost: np.ndarray          # (P,) int32 — ghosts per PE
    n_iface: np.ndarray          # (P,) int32 — interface nodes per PE
    m_local: np.ndarray          # (P,) int32 — arcs per PE
    indptr: np.ndarray           # (P, maxN + 1) int64 (local CSR, padded flat)
    indices: np.ndarray          # (P, maxM) int32 — heads in LOCAL-EXT space
    ew: np.ndarray               # (P, maxM) float32
    nw: np.ndarray               # (P, maxN) float32 — owned node weights
    ghost_global: np.ndarray     # (P, maxG) int64 — global id of each ghost
    ghost_owner: np.ndarray      # (P, maxG) int32 — owning PE
    ghost_slot: np.ndarray       # (P, maxG) int32 — slot in owner's iface buffer
    ghost_nw: np.ndarray         # (P, maxG) float32 — ghost node weights
    iface_nodes: np.ndarray      # (P, maxI) int32 — local ids of interface nodes

    @property
    def max_local(self) -> int:
        return self.nw.shape[1]

    @property
    def max_ghost(self) -> int:
        return self.ghost_global.shape[1]

    @property
    def max_iface(self) -> int:
        return self.iface_nodes.shape[1]


def shard_graph(g: GraphNP, P: int) -> ShardedGraph:
    """Split ``g`` into P contiguous node-range shards with ghost/iface maps."""
    n = g.n
    per = (n + P - 1) // P
    range_start = np.minimum(np.arange(P, dtype=np.int64) * per, n)
    range_end = np.minimum(range_start + per, n)
    src_all = g.arc_sources().astype(np.int64)
    owner_of = lambda ids: np.minimum(ids // per, P - 1)

    locals_per_pe = []
    for p in range(P):
        a, b = int(range_start[p]), int(range_end[p])
        n_p = b - a
        lo, hi = int(g.indptr[a]), int(g.indptr[b])
        dst = g.indices[lo:hi].astype(np.int64)
        is_ghost = (dst < a) | (dst >= b)
        ghosts = np.unique(dst[is_ghost])
        g_p = ghosts.shape[0]
        # remap heads to local-ext space
        heads = np.where(is_ghost, n_p + np.searchsorted(ghosts, dst), dst - a)
        indptr_local = (g.indptr[a : b + 1] - lo).astype(np.int64)
        # interface nodes: owned nodes with >= 1 ghost neighbour
        deg = np.diff(indptr_local)
        owns_ghost = np.zeros(n_p, dtype=bool)
        if hi > lo:
            src_local = np.repeat(np.arange(n_p), deg)
            np.logical_or.at(owns_ghost, src_local[is_ghost], True)
        iface = np.flatnonzero(owns_ghost).astype(np.int32)
        locals_per_pe.append(
            dict(
                a=a,
                n_p=n_p,
                m_p=hi - lo,
                indptr=indptr_local,
                heads=heads.astype(np.int32),
                ew=g.ew[lo:hi],
                nw=g.nw[a:b],
                ghosts=ghosts,
                iface=iface,
            )
        )

    maxN = max(1, _round_up(max(d["n_p"] for d in locals_per_pe), 8))
    maxM = max(8, _round_up(max(d["m_p"] for d in locals_per_pe), 8))
    maxG = max(8, _round_up(max(d["ghosts"].shape[0] for d in locals_per_pe), 8))
    maxI = max(8, _round_up(max(d["iface"].shape[0] for d in locals_per_pe), 8))

    # slot of every owned node in its PE's interface buffer (for ghost_slot)
    iface_slot_of_global = np.full(n, -1, dtype=np.int64)
    for p, d in enumerate(locals_per_pe):
        iface_slot_of_global[d["a"] + d["iface"]] = np.arange(d["iface"].shape[0])

    Z = lambda shape, dt, fill=0: np.full(shape, fill, dtype=dt)
    out = ShardedGraph(
        P=P,
        n=n,
        range_start=range_start,
        n_local=np.array([d["n_p"] for d in locals_per_pe], np.int32),
        n_ghost=np.array([d["ghosts"].shape[0] for d in locals_per_pe], np.int32),
        n_iface=np.array([d["iface"].shape[0] for d in locals_per_pe], np.int32),
        m_local=np.array([d["m_p"] for d in locals_per_pe], np.int32),
        indptr=Z((P, maxN + 1), np.int64),
        indices=Z((P, maxM), np.int32, fill=0),
        ew=Z((P, maxM), np.float32),
        nw=Z((P, maxN), np.float32),
        ghost_global=Z((P, maxG), np.int64, fill=-1),
        ghost_owner=Z((P, maxG), np.int32),
        ghost_slot=Z((P, maxG), np.int32),
        ghost_nw=Z((P, maxG), np.float32),
        iface_nodes=Z((P, maxI), np.int32),
    )
    for p, d in enumerate(locals_per_pe):
        n_p, m_p = d["n_p"], d["m_p"]
        out.indptr[p, : n_p + 1] = d["indptr"]
        out.indptr[p, n_p + 1 :] = d["indptr"][-1]
        out.indices[p, :m_p] = d["heads"]
        out.ew[p, :m_p] = d["ew"]
        out.nw[p, :n_p] = d["nw"]
        gs = d["ghosts"]
        out.ghost_global[p, : gs.shape[0]] = gs
        out.ghost_owner[p, : gs.shape[0]] = owner_of(gs)
        out.ghost_slot[p, : gs.shape[0]] = iface_slot_of_global[gs]
        out.ghost_nw[p, : gs.shape[0]] = g.nw[gs]
        out.iface_nodes[p, : d["iface"].shape[0]] = d["iface"]
    # every ghost must be an interface node of its owner
    assert np.all(out.ghost_slot[out.ghost_global >= 0] >= 0)
    return out
