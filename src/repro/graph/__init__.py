"""Graph substrate: CSR containers, generators, static-shape packing."""

from .csr import Graph, GraphDev, GraphNP, from_edges, to_device, to_host, validate
from .generators import (
    barabasi_albert,
    mesh2d,
    planted_partition,
    rgg,
    ring,
    rmat,
    star,
)
from .packing import (
    ChunkPack,
    EllPack,
    ShardedGraph,
    chunk_geometry,
    ell_pack,
    gather_pack_device,
    layout_nodes,
    pack_chunks,
    pad_pack,
    plan_chunks,
    shard_graph,
)

__all__ = [
    "Graph",
    "GraphDev",
    "GraphNP",
    "from_edges",
    "to_device",
    "to_host",
    "validate",
    "rgg",
    "mesh2d",
    "rmat",
    "barabasi_albert",
    "planted_partition",
    "ring",
    "star",
    "ChunkPack",
    "EllPack",
    "ShardedGraph",
    "chunk_geometry",
    "plan_chunks",
    "layout_nodes",
    "pack_chunks",
    "gather_pack_device",
    "pad_pack",
    "ell_pack",
    "shard_graph",
]
