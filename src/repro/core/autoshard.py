"""Partitioner-guided sharding for the LM runtime (beyond-paper bridge).

The paper's §VI names large-scale graph-processing toolkits as the target
application.  Here the "graph being processed" is the *model itself*:

* :func:`expert_placement` — build the expert co-activation graph (nodes =
  experts, edge weight = how often two experts are co-routed for the same
  token by a top-k router) and partition it into EP groups with SCLaP, so
  co-activated experts land on the same shard and the MoE all_to_all
  payload (tokens duplicated across shards) shrinks.
* :func:`pipeline_stages` — partition the layer dependency chain (nodes =
  layers, node weight = parameter bytes, edge weight = activation bytes)
  into balanced pipeline stages with minimal inter-stage traffic.

Both produce *assignments* the runtime can apply (expert permutation /
stage maps); `examples/autoshard_moe.py` measures the co-routing traffic
reduction end-to-end.
"""

from __future__ import annotations

import numpy as np

from ..graph.csr import from_edges
from .metrics import cut_np, lmax
from .multilevel import PartitionerConfig, partition

__all__ = ["coactivation_graph", "expert_placement", "pipeline_stages",
           "crossgroup_traffic"]


def coactivation_graph(topi: np.ndarray, n_experts: int):
    """topi (T, k) expert indices per token -> weighted co-activation graph."""
    T, k = topi.shape
    u, v = [], []
    for i in range(k):
        for j in range(i + 1, k):
            u.append(topi[:, i])
            v.append(topi[:, j])
    u = np.concatenate(u)
    v = np.concatenate(v)
    return from_edges(n_experts, u.astype(np.int64), v.astype(np.int64))


def expert_placement(topi: np.ndarray, n_experts: int, n_groups: int,
                     eps: float = 0.0, seed: int = 0) -> np.ndarray:
    """Assign experts to EP groups minimizing cross-group co-activation."""
    g = coactivation_graph(topi, n_experts)
    rep = partition(g, PartitionerConfig(
        k=n_groups, eps=max(eps, 1e-6), preset="strong", coarsest_factor=4,
        seed=seed, engine="numpy",
    ))
    return rep.labels


def crossgroup_traffic(topi: np.ndarray, placement: np.ndarray) -> float:
    """Fraction of token->expert assignments whose top-k set spans >1 group
    (each extra group = one extra all_to_all hop for that token)."""
    groups = placement[topi]  # (T, k)
    spans = np.array([np.unique(row).size for row in groups])
    return float((spans - 1).sum() / topi.shape[0])


def pipeline_stages(param_bytes: np.ndarray, act_bytes: np.ndarray,
                    n_stages: int, seed: int = 0) -> np.ndarray:
    """Partition the layer chain into contiguous-ish balanced stages.

    param_bytes: (L,) per-layer parameter bytes (node weights = memory).
    act_bytes:   (L-1,) activation bytes between consecutive layers.
    """
    L = param_bytes.shape[0]
    u = np.arange(L - 1, dtype=np.int64)
    g = from_edges(L, u, u + 1, w=act_bytes.astype(np.float32),
                   nw=param_bytes.astype(np.float32))
    rep = partition(g, PartitionerConfig(
        k=n_stages, eps=0.05, preset="strong", coarsest_factor=4, seed=seed,
        engine="numpy",
    ))
    return rep.labels
