"""Modularity graph clustering via the paper's own machinery (paper §VI:
"It will be very interesting to generalize our algorithm for graph
clustering w.r.t. modularity").

Louvain-style multilevel: a sequential modularity-gain label propagation
(local-move) phase — structurally the SCLaP sweep with the size constraint
replaced by the modularity gain — followed by *our cluster contraction*,
repeated until Q stops improving.  This is exactly the generalization the
paper sketches: same hierarchy construction, different move objective.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from ..graph.csr import GraphNP
from .contraction import contract, project_labels

__all__ = ["modularity", "modularity_lp", "louvain"]


def modularity(g: GraphNP, labels: np.ndarray) -> float:
    """Newman modularity Q of a clustering (weighted)."""
    m2 = float(g.ew.sum())  # = 2m for symmetric storage
    if m2 == 0:
        return 0.0
    src = g.arc_sources()
    internal = float(g.ew[labels[src] == labels[g.indices]].sum())
    deg = np.zeros(int(labels.max()) + 1)
    wdeg = np.bincount(src, weights=g.ew, minlength=g.n)
    np.add.at(deg, labels, wdeg)
    return internal / m2 - float((deg / m2) ** 2 @ np.ones_like(deg))


def modularity_lp(
    g: GraphNP, labels: np.ndarray, iters: int = 8, seed: int = 0
) -> np.ndarray:
    """Sequential modularity-gain local moves (the Louvain phase-1 sweep).

    Move v to the neighbouring cluster maximizing
    dQ ∝ k_{v,c} − k_v · Σ_tot(c) / 2m  (resolution 1)."""
    rng = np.random.default_rng(seed)
    labels = labels.astype(np.int64).copy()
    m2 = float(g.ew.sum())
    src = g.arc_sources()
    wdeg = np.bincount(src, weights=g.ew, minlength=g.n).astype(np.float64)
    sigma = np.zeros(g.n, dtype=np.float64)  # cluster total degree
    np.add.at(sigma, labels, wdeg)
    for it in range(iters):
        moved = 0
        for v in rng.permutation(g.n):
            lo, hi = g.indptr[v], g.indptr[v + 1]
            if hi == lo:
                continue
            nbr = g.indices[lo:hi]
            w = g.ew[lo:hi].astype(np.float64)
            own = labels[v]
            cand, inv = np.unique(labels[nbr], return_inverse=True)
            k_vc = np.zeros(cand.shape[0])
            np.add.at(k_vc, inv, w)
            sig = sigma[cand] - np.where(cand == own, wdeg[v], 0.0)
            gain = k_vc - wdeg[v] * sig / m2
            gain += rng.random(cand.shape[0]) * 1e-9
            best = int(np.argmax(gain))
            tgt = int(cand[best])
            own_i = np.nonzero(cand == own)[0]
            if tgt != own and (own_i.size == 0 or gain[best] > gain[own_i[0]] + 1e-12):
                sigma[own] -= wdeg[v]
                sigma[tgt] += wdeg[v]
                labels[v] = tgt
                moved += 1
        if moved == 0:
            break
    return labels


def louvain(g: GraphNP, seed: int = 0, max_levels: int = 20) -> Tuple[np.ndarray, float]:
    """Multilevel modularity clustering (local moves + cluster contraction)."""
    gg = g
    maps = []
    labels = np.arange(g.n, dtype=np.int64)
    for lev in range(max_levels):
        q0 = modularity(gg, np.arange(gg.n))
        lab = modularity_lp(gg, np.arange(gg.n), seed=seed + lev)
        coarse, C = contract(gg, lab)
        if coarse.n == gg.n:
            break
        maps.append(C)
        q1 = modularity(coarse, np.arange(coarse.n))
        gg = coarse
        if q1 <= q0 + 1e-9:
            break
    # project coarsest singleton clustering down the hierarchy
    lab = np.arange(gg.n, dtype=np.int64)
    for C in reversed(maps):
        lab = project_labels(lab, C)
    return lab.astype(np.int32), modularity(g, lab)
