"""The paper's contribution: parallel multilevel graph partitioning via
size-constrained label propagation, cluster contraction, and a distributed
evolutionary algorithm on the coarsest graph."""

from .autoshard import expert_placement, pipeline_stages
from .baselines import hash_partition, matching_multilevel, random_balanced
from .contraction import contract, project_labels, relabel
from .engine import EngineStats, LPEngine
from .evolutionary import EvoConfig, EvoInputs, evolve, evolve_batched_numpy
from .fm import fm_refine, gain_round_np
from .initial_partition import greedy_growing, initial_partition, repair_balance
from .label_propagation import LPResult, lp_cluster, lp_refine, sclap_numpy
from .metrics import (
    block_weights_np,
    comm_volume_np,
    cut_jnp,
    cut_np,
    imbalance_np,
    is_feasible,
    quotient_graph_np,
)
from .modularity import louvain, modularity
from .multilevel import PartitionerConfig, PartitionReport, partition

__all__ = [
    "partition",
    "PartitionerConfig",
    "PartitionReport",
    "lp_cluster",
    "lp_refine",
    "sclap_numpy",
    "LPResult",
    "LPEngine",
    "EngineStats",
    "contract",
    "project_labels",
    "relabel",
    "EvoConfig",
    "EvoInputs",
    "evolve",
    "evolve_batched_numpy",
    "fm_refine",
    "gain_round_np",
    "greedy_growing",
    "initial_partition",
    "repair_balance",
    "hash_partition",
    "random_balanced",
    "matching_multilevel",
    "cut_np",
    "cut_jnp",
    "imbalance_np",
    "is_feasible",
    "block_weights_np",
    "quotient_graph_np",
    "comm_volume_np",
    "louvain",
    "modularity",
    "expert_placement",
    "pipeline_stages",
]
