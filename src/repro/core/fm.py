"""Gain-based k-way local search (FM-style) for small replicated graphs.

KaFFPaE's combine operator runs the full KaFFPa multilevel partitioner per
individual, whose local search is much stronger than plain LP (flow-based
and "more-localized" searches, §II-C).  We approximate that strength on the
*coarsest level only* — the graph there is <= coarsest_factor * k nodes and
replicated on every PE, exactly where the paper itself runs sequential
high-quality code.  Classic Fiduccia–Mattheyses scheme: greedy best-gain
moves with balance constraint, hill-climbing through negative-gain plateaus
with rollback to the best seen state, node locking per pass.
"""

from __future__ import annotations

import heapq

import numpy as np

from ..graph.csr import GraphNP
from .metrics import block_weights_np

__all__ = ["fm_refine", "gain_round_np"]


def gain_round_np(
    src: np.ndarray,
    dst: np.ndarray,
    ew: np.ndarray,
    nw: np.ndarray,
    labels: np.ndarray,
    n: int,
    k: int,
    Kb: int,
    Lmax,
    base_score: int,
    base_gate: int,
    region: np.ndarray | None = None,
    influx_gate: bool = False,
) -> np.ndarray:
    """One synchronous best-gain move round — the FM-lite step of the
    batched evolutionary refinement (numpy spec twin of ``_gain_round`` in
    repro.core.evo_device; the device version is vmapped over the
    population and must stay op-for-op identical).  With ``region`` set
    (an arena-sized bool mask) only region nodes may move, and with
    ``influx_gate=True`` each block's net synchronous inflow is capped at
    its headroom in expectation (the chunked sweep's refine-mode gate) —
    together these are the spec of the dynamic repairer's
    ``repro.dynamic.repair.gain_round_device``, which must stay op-for-op
    identical to this variant.  The evolution's own round keeps both off:
    its fitness keys absorb transient infeasibility, a repair step cannot.

    Unlike :func:`fm_refine`'s sequential heap walk, all nodes see the same
    stale state and move together: eligibility is a *strict* connection gain
    (``conn[v, b] > conn[v, own]``) under the balance bound, tie-broken by
    stateless hash jitter, and damped by a 0.5 move gate.  Synchronous moves
    can transiently worsen the cut; the caller's elitism step absorbs that.

    ``labels`` is an arena-sized (``Ab >= n + 1``) int32 array with label
    ``k`` beyond ``n``; arc arrays may carry trailing zero-weight padding.
    """
    Ab = labels.shape[0]
    iota = np.arange(Ab, dtype=np.int32)
    kio = np.arange(Kb, dtype=np.int32)
    conn = np.zeros((Ab, Kb), np.float32)
    np.add.at(conn, (src, labels[dst]), ew)
    own = conn[iota, np.minimum(labels, Kb - 1)]
    bw = np.zeros(Kb, np.float32)
    np.add.at(bw, labels, nw)
    bwx = np.where(kio < k, bw, np.float32(np.inf)).astype(np.float32)
    from .label_propagation import hash_jitter_np, hash_unit_np

    jit = hash_jitter_np(base_score, iota[:, None], kio[None, :])
    fits = bwx[None, :] + nw[:, None] <= np.float32(Lmax)
    elig = fits & (kio[None, :] != labels[:, None]) & (conn > own[:, None])
    score = np.where(elig, conn + jit, np.float32(-1e30)).astype(np.float32)
    b = np.argmax(score, axis=1).astype(np.int32)
    has = score[iota, b] > np.float32(-5e29)
    u = hash_unit_np(base_gate, iota, np.int32(0))
    move = has & (u < np.float32(0.5)) & (iota < n)
    if region is not None:
        move &= region
    if influx_gate:
        mv_w = np.where(move, nw, np.float32(0.0)).astype(np.float32)
        inflow = np.zeros(Kb, np.float32)
        outflow = np.zeros(Kb, np.float32)
        np.add.at(inflow, np.where(move, b, k), mv_w)
        np.add.at(outflow, np.where(move, np.minimum(labels, Kb - 1), k), mv_w)
        head = (np.float32(Lmax) - bw + outflow).astype(np.float32)
        with np.errstate(invalid="ignore", over="ignore"):
            p_in = np.clip(
                head / np.maximum(inflow, np.float32(1e-9)),
                np.float32(0.0), np.float32(1.0),
            )
        u2 = hash_unit_np(base_gate, iota, np.int32(1))
        move &= u2 < p_in[np.minimum(b, k)]
    return np.where(move, b, labels).astype(np.int32)


def fm_refine(
    g: GraphNP,
    labels: np.ndarray,
    k: int,
    Lmax: float,
    passes: int = 3,
    max_neg_width: int = 64,
    seed: int = 0,
) -> np.ndarray:
    """k-way FM local search; never returns a worse (feasible) partition."""
    rng = np.random.default_rng(seed)
    n = g.n
    labels = labels.astype(np.int64).copy()
    src = g.arc_sources().astype(np.int64)
    dst = g.indices.astype(np.int64)

    conn = np.zeros((n, k))
    np.add.at(conn, (src, labels[dst]), g.ew)
    bw = block_weights_np(g, labels, k).astype(np.float64)

    def node_best(v):
        """Returns (jittered score for ordering, true gain, target block)."""
        a = labels[v]
        gains = conn[v] - conn[v, a]
        gains[a] = -np.inf
        jittered = gains + rng.random(k) * 1e-3
        fits = bw + g.nw[v] <= Lmax
        fits[a] = False
        masked = np.where(fits, jittered, -np.inf)
        b = int(np.argmax(masked))
        return (masked[b], gains[b] if masked[b] > -np.inf else -np.inf, b)

    cur_cut = float(g.ew.sum() / 2.0 - conn[np.arange(n), labels].sum() / 2.0)

    for _ in range(passes):
        improved = False
        boundary = np.unique(src[labels[src] != labels[dst]])
        if boundary.size == 0:
            break
        locked = np.zeros(n, dtype=bool)
        heap = []
        for v in boundary:
            score, _, b = node_best(v)
            if score > -np.inf:
                heapq.heappush(heap, (-score, int(v), b, labels[v]))
        best_cut = cur_cut
        journal = []  # (v, from, to)
        neg_run = 0
        while heap and neg_run < max_neg_width:
            ns, v, b, frm = heapq.heappop(heap)
            if locked[v] or labels[v] != frm:
                continue
            score, gain, b = node_best(v)  # recompute (heap entries go stale)
            if score == -np.inf:
                continue
            if -ns > score + 1e-9:  # stale optimistic entry: reinsert fresh
                heapq.heappush(heap, (-score, v, b, labels[v]))
                continue
            a = labels[v]
            if bw[b] + g.nw[v] > Lmax:
                continue
            # apply
            labels[v] = b
            bw[a] -= g.nw[v]
            bw[b] += g.nw[v]
            cur_cut -= gain
            journal.append((v, a, b))
            locked[v] = True
            lo, hi = g.indptr[v], g.indptr[v + 1]
            nbr = g.indices[lo:hi]
            w = g.ew[lo:hi]
            np.add.at(conn[:, a], nbr, -w)
            np.add.at(conn[:, b], nbr, +w)
            for u in nbr:
                if not locked[u]:
                    su, _, bu = node_best(u)
                    if su > -np.inf:
                        heapq.heappush(heap, (-su, int(u), bu, labels[u]))
            if cur_cut < best_cut - 1e-9:
                best_cut = cur_cut
                journal.clear()
                improved = True
                neg_run = 0
            else:
                neg_run += 1
        # rollback moves made after the best state
        for v, a, b in reversed(journal):
            labels[v] = a
            bw[b] -= g.nw[v]
            bw[a] += g.nw[v]
            lo, hi = g.indptr[v], g.indptr[v + 1]
            nbr = g.indices[lo:hi]
            w = g.ew[lo:hi]
            np.add.at(conn[:, b], nbr, -w)
            np.add.at(conn[:, a], nbr, +w)
        cur_cut = best_cut
        if not improved:
            break
    return labels.astype(np.int32)
