"""Device-batched island evolutionary search (KaFFPaE, §II-C/IV-E).

The production twin of the numpy oracle in ``repro.core.evolutionary``: the
whole population is a ``(pop, n)`` label batch on device and one generation
runs as ONE bucketed jitted executable —

* **batched greedy-growing seeds** — hash-scored degree-biased seed draw,
  degree/diameter-proportional synchronous frontier rounds
  (``evolutionary.grow_rounds_bound``, traced; converged/stalled frontiers
  exit early), round-robin leftovers;
* **batched LP refinement** — a ``vmap`` population axis over the engine's
  cached ``_lp_sweep`` chunk pack (the graph uploads once per run, not once
  per individual), followed by synchronous gain (FM-lite) and balance-repair
  rounds;
* **overlay-cell combine** — ``(P1(v), P2(v))`` cell ids via the same
  packed-key sort/rank relabel the device contraction uses, cell-granular
  block moves instead of a per-individual host contraction;
* **device-side elitism/selection/gossip** — int32 fitness keys
  (feasibility-first, then cut; exact because the engine gates this path on
  integral weights), stateless hash jitter for every tie-break, and the
  offspring-never-worse-than-better-parent elitism step of the paper.

Islands optionally map onto ``shard_map`` shards (``launch.mesh``); the
per-epoch best-individual gossip then becomes an ``all_gather`` collective.
Island hashes are keyed on *global* island ids, so the sharded run is
bit-identical to the single-device run (and hence to the numpy oracle).

Shape bucketing: arrays carry a pow2 population bucket ``Sb`` (seed phase) /
``Ib`` (children) and the node arena ``Ab = 2^ceil(log2(n + 1))``; the live
``(I, P, n, k, num_chunks)`` are traced scalars, so one compiled executable
per bucket serves every V-cycle (counted by ``LPEngine``'s ``evo_compiles``
against ``evo_buckets``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .evolutionary import (
    CELL_ROUNDS,
    COMBINE_PROB,
    GAIN_ROUNDS,
    INFEAS_PENALTY,
    MUTATE_FRAC,
    REPAIR_ROUNDS,
    TAG_CELL,
    TAG_CELL_GATE,
    TAG_GAIN,
    TAG_GAIN_GATE,
    TAG_GROW,
    TAG_MUT_FLIP,
    TAG_MUT_LBL,
    TAG_OP,
    TAG_P1,
    TAG_P2,
    TAG_REPAIR,
    TAG_SEEDKEY,
    TAG_SWEEP,
)
from .label_propagation import _hash_base, _hash_jitter, _hash_mix, _lp_sweep
from .metrics import block_weights_dense_jnp, cut_from_arcs_jnp

__all__ = ["evo_seed_step", "evo_generation_step", "make_generation_sharded"]

_NEG = -1e30
_IMAX = 2**31 - 1
_IMIN = -(2**31)


def _hash_unit(base, a, b):
    """Uniform-ish float32 in [0, 1) (twin of ``hash_unit_np``)."""
    h = _hash_mix(_hash_mix(base, a), b)
    return (h & jnp.uint32(0xFFFFFF)).astype(jnp.float32) / float(1 << 24)


def _hash_u32(base, a, b):
    """Raw uint32 stream (twin of ``hash_u32_np``)."""
    return _hash_mix(_hash_mix(base, a), b)


# --------------------------------------------------------------------------
# per-individual building blocks (all vmapped over the population axis;
# every op mirrors its numpy-oracle twin bit-for-bit)
# --------------------------------------------------------------------------


def _bw_dev(lab, nw, k, Kb):
    kio = jnp.arange(Kb, dtype=jnp.int32)
    bw = block_weights_dense_jnp(lab, nw, k, Kb)
    return bw, jnp.where(kio < k, bw, jnp.inf)


def _evaluate(lab, src, dst, ew, nw, k, Kb, Lmax):
    """int32 fitness key: cut + INFEAS_PENALTY if infeasible (oracle twin)."""
    kio = jnp.arange(Kb, dtype=jnp.int32)
    cut = cut_from_arcs_jnp(lab, src, dst, ew)
    bw, _ = _bw_dev(lab, nw, k, Kb)
    bwmax = jnp.max(jnp.where(kio < k, bw, -jnp.inf))
    feas = bwmax <= Lmax + 1e-6
    return cut.astype(jnp.int32) + jnp.where(feas, 0, INFEAS_PENALTY)


def _greedy_one(s_idx, src, dst, ew, nw, deg_f, n, k, Kb, Lmax, seed, rounds):
    """Batched greedy growing, one individual (oracle: ``_greedy_grow_np``).

    ``rounds`` is the traced degree/diameter-proportional budget
    (``evolutionary.grow_rounds_bound``) — one executable still serves
    every coarsest graph in the bucket."""
    Ab = nw.shape[0]
    iota = jnp.arange(Ab, dtype=jnp.int32)
    kio = jnp.arange(Kb, dtype=jnp.int32)
    unit = _hash_unit(_hash_base(seed, jnp.int32(0), TAG_SEEDKEY), iota, s_idx)
    skey = jnp.where(iota < n, unit * (deg_f + 1.0), -jnp.inf)
    order = jnp.argsort(-skey)
    rank = jnp.zeros((Ab,), jnp.int32).at[order].set(iota)
    lab0 = jnp.where((rank < k) & (iota < n), rank, jnp.int32(-1))

    def grow_round(r, lab):
        tgt = lab[dst]
        mask = tgt >= 0
        conn = jnp.zeros((Ab, Kb), jnp.float32).at[
            src, jnp.where(mask, tgt, 0)
        ].add(jnp.where(mask, ew, 0.0))
        asg = lab >= 0
        bw = jnp.zeros((Kb,), jnp.float32).at[jnp.where(asg, lab, 0)].add(
            jnp.where(asg, nw, 0.0)
        )
        bwx = jnp.where(kio < k, bw, jnp.inf)
        base_r = _hash_u32(_hash_base(seed, r, TAG_GROW), s_idx, jnp.int32(0))
        jit = _hash_jitter(base_r, iota[:, None], kio[None, :])
        fits = bwx[None, :] + nw[:, None] <= Lmax
        elig = (conn > 0) & fits
        score = jnp.where(elig, conn + jit, _NEG)
        b = jnp.argmax(score, axis=1).astype(jnp.int32)
        has = jnp.take_along_axis(score, b[:, None], 1)[:, 0] > _NEG / 2
        unas = (lab < 0) & (iota < n)
        return jnp.where(unas & has, b, lab)

    # while_loop instead of a fixed fori: once every node is assigned — or a
    # round assigns nothing (a stalled frontier can never recover, since
    # assignments are the only state a round reads) — the remaining rounds
    # are no-ops by construction (the oracle early-exits on exactly these
    # conditions), so skipping them cannot change a label.  Under vmap the
    # loop runs until the slowest individual converges, with converged rows
    # riding along untouched; the stall exit is what keeps the
    # diameter-proportional budget from costing anything on disconnected
    # graphs.
    def _unas_count(lab):
        return jnp.sum(((lab < 0) & (iota < n)).astype(jnp.int32))

    def grow_cond(state):
        r, lab, prev = state
        cnt = _unas_count(lab)
        return (r < rounds) & (cnt > 0) & ((r == 0) | (cnt < prev))

    def grow_body(state):
        r, lab, prev = state
        return r + 1, grow_round(r, lab), _unas_count(lab)

    _, lab, _ = lax.while_loop(
        grow_cond, grow_body, (jnp.int32(0), lab0, jnp.int32(_IMAX))
    )
    unas = (lab < 0) & (iota < n)
    pos = jnp.cumsum(unas.astype(jnp.int32)) - 1
    lab = jnp.where(unas, pos % k, lab)
    return jnp.where(iota < n, lab, k).astype(jnp.int32)


def _gain_round(src, dst, ew, nw, lab, n, k, Kb, Lmax, base_score, base_gate):
    """Synchronous best-gain round (oracle: ``repro.core.fm.gain_round_np``)."""
    Ab = lab.shape[0]
    iota = jnp.arange(Ab, dtype=jnp.int32)
    kio = jnp.arange(Kb, dtype=jnp.int32)
    conn = jnp.zeros((Ab, Kb), jnp.float32).at[src, lab[dst]].add(ew)
    own = jnp.take_along_axis(conn, jnp.minimum(lab, Kb - 1)[:, None], 1)[:, 0]
    _, bwx = _bw_dev(lab, nw, k, Kb)
    jit = _hash_jitter(base_score, iota[:, None], kio[None, :])
    fits = bwx[None, :] + nw[:, None] <= Lmax
    elig = fits & (kio[None, :] != lab[:, None]) & (conn > own[:, None])
    score = jnp.where(elig, conn + jit, _NEG)
    b = jnp.argmax(score, axis=1).astype(jnp.int32)
    has = jnp.take_along_axis(score, b[:, None], 1)[:, 0] > _NEG / 2
    u = _hash_unit(base_gate, iota, jnp.int32(0))
    move = has & (u < 0.5) & (iota < n)
    return jnp.where(move, b, lab)


def _repair_rounds(src, dst, ew, nw, lab, ctx, phase, n, k, Kb, Lmax, seed):
    """Synchronous repair rounds (oracle: ``_repair_rounds_np``)."""
    del src, dst, ew
    Ab = lab.shape[0]
    iota = jnp.arange(Ab, dtype=jnp.int32)

    def rep_round(r, lab):
        _, bwx = _bw_dev(lab, nw, k, Kb)
        tgt = jnp.argmin(bwx).astype(jnp.int32)
        excess = jnp.clip((bwx - Lmax) / jnp.maximum(bwx, 1.0), 0.0, 1.0)
        base_r = _hash_u32(_hash_base(seed, phase, TAG_REPAIR), ctx, r)
        u = _hash_unit(base_r, iota, jnp.int32(0))
        over = bwx > Lmax
        movable = (
            (iota < n)
            & over[jnp.minimum(lab, k)]
            & (lab != tgt)
            & (bwx[tgt] + nw <= Lmax)
        )
        gate = u < 1.5 * excess[jnp.minimum(lab, k)]
        return jnp.where(movable & gate, tgt, lab)

    return lax.fori_loop(0, REPAIR_ROUNDS, rep_round, lab)


def _mutate_init(src, dst, nw, lab, i_ctx, gen, n, k, seed):
    """Boundary perturbation (oracle: ``_mutate_init_np``)."""
    Ab = lab.shape[0]
    iota = jnp.arange(Ab, dtype=jnp.int32)
    bnd = jnp.zeros((Ab,), bool).at[src].max(lab[src] != lab[dst])
    u = _hash_unit(
        _hash_u32(_hash_base(seed, gen + 1, TAG_MUT_FLIP), i_ctx, jnp.int32(0)),
        iota, jnp.int32(0),
    )
    newl = (
        _hash_u32(
            _hash_u32(_hash_base(seed, gen + 1, TAG_MUT_LBL), i_ctx,
                      jnp.int32(0)),
            iota, jnp.int32(0),
        ) % k.astype(jnp.uint32)
    ).astype(jnp.int32)
    flip = bnd & (u < MUTATE_FRAC) & (iota < n)
    return jnp.where(flip, newl, lab)


def _combine_init(src, dst, ew, nw, lab1, lab2, lab_better, i_ctx, gen, n, k,
                  Kb, Lmax, seed):
    """Overlay-cell combine (oracle: ``_combine_init_np``): packed-key
    relabel of the ``(P1(v), P2(v))`` cells, better-parent seeding, and
    CELL_ROUNDS synchronous cell-granular moves."""
    Ab = lab1.shape[0]
    iota = jnp.arange(Ab, dtype=jnp.int32)
    kio = jnp.arange(Kb, dtype=jnp.int32)
    ov = jnp.where(iota < n, lab1 * k + lab2, jnp.int32(_IMAX))
    sl = jnp.sort(ov)
    newrun = jnp.concatenate(
        [sl[:1] < _IMAX, (sl[1:] != sl[:-1]) & (sl[1:] < _IMAX)]
    )
    rank = (jnp.cumsum(newrun) - 1).astype(jnp.int32)
    posn = jnp.minimum(jnp.searchsorted(sl, ov), Ab - 1)
    cf = jnp.where(iota < n, rank[posn], jnp.int32(Ab - 1))
    blk_raw = jnp.full((Ab,), -1, jnp.int32).at[cf].max(
        jnp.where(iota < n, lab_better, jnp.int32(-1))
    )
    blk0 = jnp.where(blk_raw >= 0, blk_raw, k).astype(jnp.int32)
    cw = jnp.zeros((Ab,), jnp.float32).at[cf].add(nw)
    cu = cf[src]
    cv = cf[dst]
    mask = cu != cv
    blk = blk0
    for r in range(CELL_ROUNDS):
        bw = jnp.zeros((Kb,), jnp.float32).at[blk].add(cw)
        bwx = jnp.where(kio < k, bw, jnp.inf)
        conn = jnp.zeros((Ab, Kb), jnp.float32).at[cu, blk[cv]].add(
            jnp.where(mask, ew, 0.0)
        )
        own = jnp.take_along_axis(conn, jnp.minimum(blk, Kb - 1)[:, None], 1)[:, 0]
        jit = _hash_jitter(
            _hash_u32(_hash_base(seed, gen + 1, TAG_CELL), i_ctx, jnp.int32(r)),
            iota[:, None], kio[None, :],
        )
        fits = bwx[None, :] + cw[:, None] <= Lmax
        elig = fits & (kio[None, :] != blk[:, None]) & (conn > own[:, None])
        score = jnp.where(elig, conn + jit, _NEG)
        b = jnp.argmax(score, axis=1).astype(jnp.int32)
        has = jnp.take_along_axis(score, b[:, None], 1)[:, 0] > _NEG / 2
        u = _hash_unit(
            _hash_u32(_hash_base(seed, gen + 1, TAG_CELL_GATE), i_ctx,
                      jnp.int32(r)),
            iota, jnp.int32(0),
        )
        blk = jnp.where(has & (u < 0.5), b, blk)
    return jnp.where(iota < n, blk[cf], k).astype(jnp.int32)


def _refine_batch(pack, labs, ctxs, phase, src, dst, ew, nw, n, k, Kb, Lmax,
                  num_chunks, seed, refine_iters):
    """Batched refine: vmapped ``_lp_sweep`` + gain rounds + repair rounds.

    ``labs`` is ``(B, Ab)``; ``ctxs`` the per-row hash contexts (flat
    individual index in the seed phase, global island id in generations);
    ``phase`` 0 for seeding, ``gen + 1`` for generations (oracle twin:
    ``_refine_np``)."""
    nodes, node_valid, edge_dst, edge_w, edge_src_slot, edge_valid = pack
    kio = jnp.arange(Kb, dtype=jnp.int32)
    sw = (
        _hash_u32(_hash_base(seed, phase, TAG_SWEEP), ctxs, jnp.int32(0))
        & jnp.uint32(0x7FFFFFFF)
    ).astype(jnp.int32)

    def bw_init(lab):
        bw = jnp.zeros((Kb,), jnp.float32).at[lab].add(nw)
        return jnp.where(kio < k, bw, jnp.inf)

    ws = jax.vmap(bw_init)(labs)

    def sweep_one(lab, w, sd):
        out, _, _ = _lp_sweep(
            nodes, node_valid, edge_dst, edge_w, edge_src_slot, edge_valid,
            lab, w, nw, jnp.zeros(1, jnp.int32),
            Lmax, sd, k, num_chunks,
            iters=refine_iters, refine_mode=True, use_restrict=False,
            permute_chunks=True,
        )
        return out

    labs = jax.vmap(sweep_one)(labs, ws, sw)
    for r in range(GAIN_ROUNDS):
        base_s = _hash_u32(_hash_base(seed, phase, TAG_GAIN), ctxs, jnp.int32(r))
        base_g = _hash_u32(
            _hash_base(seed, phase, TAG_GAIN_GATE), ctxs, jnp.int32(r)
        )
        labs = jax.vmap(
            lambda lab, bs, bg: _gain_round(
                src, dst, ew, nw, lab, n, k, Kb, Lmax, bs, bg
            )
        )(labs, base_s, base_g)
    labs = jax.vmap(
        lambda lab, ctx: _repair_rounds(
            src, dst, ew, nw, lab, ctx, phase, n, k, Kb, Lmax, seed
        )
    )(labs, ctxs)
    return labs


def _worst_slots(keys, I, P, Sb):
    """Per-island replacement victim: max key, first member (oracle twin of
    ``_worst_member_np``).  Returns flat slot ids, valid for islands < I."""
    iota_s = jnp.arange(Sb, dtype=jnp.int32)
    isl = iota_s // P
    valid = iota_s < I * P
    seg = jnp.where(valid, isl, Sb)
    wk = jnp.full((Sb,), _IMIN, jnp.int32).at[seg].max(keys, mode="drop")
    member = iota_s - isl * P
    is_worst = valid & (keys == wk[jnp.minimum(isl, Sb - 1)])
    wmem = jnp.full((Sb,), _IMAX, jnp.int32).at[seg].min(
        jnp.where(is_worst, member, _IMAX), mode="drop"
    )
    return wk, wmem


# --------------------------------------------------------------------------
# jitted phase entry points
# --------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("refine_iters", "Kb"))
def evo_seed_step(
    nodes, node_valid, edge_dst, edge_w, edge_src_slot, edge_valid,
    seed_labels,        # (Sb, Ab) int32 — V-cycle seed rows; fill k elsewhere
    seed_mask,          # (Sb,) bool — rows taken verbatim from seed_labels
    src, dst, ew,       # arc arrays (zero-weight padding allowed)
    nw,                 # (Ab,) f32, 0 beyond n
    deg_f,              # (Ab,) f32 degrees, 0 beyond n
    Lmax,               # scalar f32
    seed,               # scalar int32
    I, P, n, k, num_chunks, grow_rounds,   # traced scalars
    *,
    refine_iters: int,
    Kb: int,
):
    """Build + evaluate the initial population: batched greedy growing for
    unseeded rows (``grow_rounds`` frontier-round budget — traced, computed
    by ``evolutionary.grow_rounds_bound``), verbatim seed rows (the
    V-cycle's projected solution), batched refine, int32 fitness keys.  ONE
    executable per ``(pack bucket, Sb, Ab, Kb)`` shape."""
    Sb, Ab = seed_labels.shape
    iota_s = jnp.arange(Sb, dtype=jnp.int32)
    valid_s = iota_s < I * P
    pack = (nodes, node_valid, edge_dst, edge_w, edge_src_slot, edge_valid)
    grown = jax.vmap(
        lambda s: _greedy_one(
            s, src, dst, ew, nw, deg_f, n, k, Kb, Lmax, seed, grow_rounds
        )
    )(iota_s)
    refined = _refine_batch(
        pack, grown, iota_s, jnp.int32(0), src, dst, ew, nw, n, k, Kb, Lmax,
        num_chunks, seed, refine_iters,
    )
    labs = jnp.where(seed_mask[:, None], seed_labels, refined)
    keys = jax.vmap(
        lambda lab: _evaluate(lab, src, dst, ew, nw, k, Kb, Lmax)
    )(labs)
    keys = jnp.where(valid_s, keys, jnp.int32(_IMAX))
    return labs, keys


def _generation_core(
    pack, labs, keys, src, dst, ew, nw, Lmax, seed, gen, island_offset,
    I, P, n, k, num_chunks, Kb: int, Ib: int, refine_iters: int,
    axis_name=None,
):
    """One generation: selection, combine/mutate, batched refine, elitism,
    replacement, gossip.  Shared by the single-device jit and the
    ``shard_map`` island wrapper (``axis_name`` set -> gossip is an
    ``all_gather`` collective over the island axis)."""
    Sb, Ab = labs.shape
    iota_s = jnp.arange(Sb, dtype=jnp.int32)
    valid_s = iota_s < I * P
    i_io = jnp.arange(Ib, dtype=jnp.int32)
    valid_i = i_io < I
    i_ctx = i_io + island_offset

    # ---- selection (stateless hash draws, global island ids) ----
    u_op = _hash_unit(_hash_base(seed, gen + 1, TAG_OP), i_ctx, jnp.int32(0))
    r1 = (
        _hash_u32(_hash_base(seed, gen + 1, TAG_P1), i_ctx, jnp.int32(0))
        % P.astype(jnp.uint32)
    ).astype(jnp.int32)
    off = 1 + (
        _hash_u32(_hash_base(seed, gen + 1, TAG_P2), i_ctx, jnp.int32(0))
        % jnp.maximum(P - 1, 1).astype(jnp.uint32)
    ).astype(jnp.int32)
    r2 = (r1 + off) % P
    do_combine = (P >= 2) & (u_op < COMBINE_PROB)
    p1 = jnp.minimum(i_io * P + r1, Sb - 1)
    p2 = jnp.minimum(i_io * P + r2, Sb - 1)
    k1 = keys[p1]
    k2 = keys[p2]
    better = jnp.where(k1 <= k2, p1, p2)
    base_flat = jnp.where(do_combine, better, p1)

    lab_p1 = labs[p1]
    lab_p2 = labs[p2]
    lab_base = labs[base_flat]

    comb = jax.vmap(
        lambda l1, l2, lb, ic: _combine_init(
            src, dst, ew, nw, l1, l2, lb, ic, gen, n, k, Kb, Lmax, seed
        )
    )(lab_p1, lab_p2, lab_base, i_ctx)
    mut = jax.vmap(
        lambda lb, ic: _mutate_init(src, dst, nw, lb, ic, gen, n, k, seed)
    )(lab_base, i_ctx)
    init = jnp.where(do_combine[:, None], comb, mut)

    children = _refine_batch(
        pack, init, i_ctx, gen + 1, src, dst, ew, nw, n, k, Kb, Lmax,
        num_chunks, seed, refine_iters,
    )
    ckeys = jax.vmap(
        lambda lab: _evaluate(lab, src, dst, ew, nw, k, Kb, Lmax)
    )(children)

    # ---- elitism: offspring never worse than its baseline ----
    bkeys_par = keys[base_flat]
    keep = ckeys <= bkeys_par
    children = jnp.where(keep[:, None], children, lab_base)
    ckeys = jnp.where(keep, ckeys, bkeys_par)

    # ---- synchronous replacement of each island's worst ----
    wk, wmem = _worst_slots(keys, I, P, Sb)
    wflat = jnp.minimum(i_io * P + wmem[jnp.minimum(i_io, Sb - 1)], Sb - 1)
    cond = valid_i & (ckeys <= keys[wflat])
    tgt = jnp.where(cond, wflat, Sb)
    labs = labs.at[tgt].set(children, mode="drop")
    keys = keys.at[tgt].set(ckeys, mode="drop")

    # ---- gossip: global best replaces each island's worst ----
    bkey = jnp.min(jnp.where(valid_s, keys, _IMAX))
    bidx = jnp.min(jnp.where(valid_s & (keys == bkey), iota_s, _IMAX))
    blab = labs[jnp.minimum(bidx, Sb - 1)]
    if axis_name is not None:
        bkeys_g = lax.all_gather(bkey, axis_name)          # (D,)
        blabs_g = lax.all_gather(blab, axis_name)          # (D, Ab)
        gmin = jnp.min(bkeys_g)
        d = jnp.min(
            jnp.where(bkeys_g == gmin, jnp.arange(bkeys_g.shape[0]),
                      bkeys_g.shape[0])
        )
        bkey = gmin
        blab = blabs_g[jnp.minimum(d, bkeys_g.shape[0] - 1)]
    wk2, wmem2 = _worst_slots(keys, I, P, Sb)
    wflat2 = jnp.minimum(i_io * P + wmem2[jnp.minimum(i_io, Sb - 1)], Sb - 1)
    cond2 = valid_i & (bkey < keys[wflat2])
    tgt2 = jnp.where(cond2, wflat2, Sb)
    labs = labs.at[tgt2].set(
        jnp.broadcast_to(blab, (Ib, labs.shape[1])), mode="drop"
    )
    keys = keys.at[tgt2].set(jnp.broadcast_to(bkey, (Ib,)), mode="drop")
    return labs, keys


@functools.partial(jax.jit, static_argnames=("refine_iters", "Kb", "Ib"))
def evo_generation_step(
    nodes, node_valid, edge_dst, edge_w, edge_src_slot, edge_valid,
    labs, keys,
    src, dst, ew, nw,
    Lmax, seed, gen, island_offset,
    I, P, n, k, num_chunks,
    *,
    refine_iters: int,
    Kb: int,
    Ib: int,
):
    """One generation as ONE executable per (pack bucket, Sb, Ab, Ib, Kb)."""
    pack = (nodes, node_valid, edge_dst, edge_w, edge_src_slot, edge_valid)
    return _generation_core(
        pack, labs, keys, src, dst, ew, nw, Lmax, seed, gen, island_offset,
        I, P, n, k, num_chunks, Kb, Ib, refine_iters,
    )


def make_generation_sharded(mesh, refine_iters: int, Kb: int, Ib: int):
    """Build the shard_mapped generation step: state carries a leading
    ``(D,)`` island-shard axis, gossip runs as an ``all_gather`` collective.
    Hash contexts use global island ids via the sharded ``island_offset``
    column, so results are bit-identical to the single-device step."""
    from jax.sharding import PartitionSpec as PS

    from ..compat import shard_map

    def step(pack_and_state):
        (nodes, node_valid, edge_dst, edge_w, edge_src_slot, edge_valid,
         labs, keys, src, dst, ew, nw, Lmax, seed, gen, island_offset,
         I_loc, P, n, k, num_chunks) = pack_and_state
        pack = (nodes, node_valid, edge_dst, edge_w, edge_src_slot, edge_valid)
        labs, keys = _generation_core(
            pack, labs[0], keys[0], src, dst, ew, nw, Lmax, seed, gen,
            island_offset[0, 0], I_loc, P, n, k, num_chunks,
            Kb, Ib, refine_iters, axis_name="island",
        )
        return labs[None], keys[None]

    rep = PS()
    spec_in = (
        rep, rep, rep, rep, rep, rep,                   # pack (replicated)
        PS("island"), PS("island"),                     # labs, keys
        rep, rep, rep, rep,                             # arc arrays + nw
        rep, rep, rep, PS("island"),                    # Lmax, seed, gen, off
        rep, rep, rep, rep, rep,                        # I_loc, P, n, k, chunks
    )
    sharded = shard_map(
        lambda *a: step(a), mesh,
        in_specs=spec_in, out_specs=(PS("island"), PS("island")),
    )
    return jax.jit(sharded)
