"""Cluster contraction (paper §III/IV-C).

Each cluster of a (size-constrained) clustering becomes one coarse node;
coarse node weight = sum of member node weights; coarse edge (A, B) weight =
total weight of edges running between clusters A and B.  By construction a
partition of the coarse graph projects to a partition of the fine graph with
*identical* cut and balance — the property the whole multilevel scheme rests
on (tested property-style in tests/test_property.py).

Two implementations:

* :func:`contract` — host/numpy.  The multilevel driver is a host loop
  (level shapes are data-dependent), so this is the production path between
  levels; it is the paper's parallel algorithm expressed serially: relabel
  via sort + prefix-sum to a contiguous ID range, then a sort/segment-sum
  quotient-graph build (the paper builds local quotient graphs by hashing —
  sorting is the TPU-idiomatic substitute, see DESIGN.md §2).
* :func:`contract_arcs_jnp` — the device-side building block used by the
  distributed pipeline: maps + deduplicates + weight-sums arcs for a shard's
  local subgraph entirely on device (static shapes, padded).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

import jax.numpy as jnp

from ..graph.csr import GraphNP

__all__ = ["contract", "relabel", "contract_arcs_jnp", "project_labels"]


def relabel(labels: np.ndarray) -> Tuple[np.ndarray, int]:
    """Map arbitrary cluster IDs to the contiguous range [0, n').

    Sort-based: equivalent to the paper's distributed distinct-counting +
    prefix-sum scheme (§IV-C), collapsed onto one host.
    """
    uniq, C = np.unique(labels, return_inverse=True)
    return C.astype(np.int32), int(uniq.shape[0])


def contract(g: GraphNP, labels: np.ndarray) -> Tuple[GraphNP, np.ndarray]:
    """Contract a clustering; returns (coarse graph, fine->coarse mapping C)."""
    C, n_c = relabel(labels)
    nw_c = np.zeros(n_c, dtype=np.float64)
    np.add.at(nw_c, C, g.nw)

    src = g.arc_sources()
    cu = C[src].astype(np.int64)
    cv = C[g.indices].astype(np.int64)
    keep = cu != cv
    cu, cv = cu[keep], cv[keep]
    w = g.ew[keep].astype(np.float64)

    if cu.size == 0:
        coarse = GraphNP(
            indptr=np.zeros(n_c + 1, dtype=np.int64),
            indices=np.zeros(0, dtype=np.int32),
            ew=np.zeros(0, dtype=np.float32),
            nw=nw_c.astype(np.float32),
        )
        return coarse, C

    key = cu * np.int64(n_c) + cv
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    w_s = w[order]
    boundary = np.empty(key_s.shape[0], dtype=bool)
    boundary[0] = True
    boundary[1:] = key_s[1:] != key_s[:-1]
    run = np.cumsum(boundary) - 1
    m_c = int(run[-1]) + 1
    w_c = np.zeros(m_c, dtype=np.float64)
    np.add.at(w_c, run, w_s)
    first = np.flatnonzero(boundary)
    cu_c = (key_s[first] // n_c).astype(np.int32)
    cv_c = (key_s[first] % n_c).astype(np.int32)

    counts = np.bincount(cu_c, minlength=n_c)
    indptr = np.zeros(n_c + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    coarse = GraphNP(
        indptr=indptr,
        indices=cv_c,
        ew=w_c.astype(np.float32),
        nw=nw_c.astype(np.float32),
    )
    return coarse, C


def project_labels(coarse_labels: np.ndarray, C: np.ndarray) -> np.ndarray:
    """Uncoarsening: fine node inherits the block of its coarse representative."""
    return coarse_labels[C]


def contract_arcs_jnp(
    cu: jnp.ndarray, cv: jnp.ndarray, w: jnp.ndarray, valid: jnp.ndarray, n_c: int
):
    """Device-side quotient-arc dedup for one shard (static shapes).

    Args:
      cu, cv: (E,) int32 coarse endpoints of local arcs.
      w:      (E,) f32 arc weights.
      valid:  (E,) bool — padding / self-arc mask (False entries are dropped).
      n_c:    static upper bound on coarse node count.
    Returns:
      (cu', cv', w', valid'): deduplicated arcs, padded to E.
    """
    E = cu.shape[0]
    ok = valid & (cu != cv)
    # key sorts invalid arcs to the end
    big = jnp.int64(n_c)
    key = jnp.where(ok, cu.astype(jnp.int64) * big + cv.astype(jnp.int64), big * big)
    order = jnp.argsort(key)
    key_s = key[order]
    w_s = jnp.where(ok, w, 0.0)[order]
    newrun = jnp.concatenate(
        [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]]
    ) & (key_s < big * big)
    run = jnp.cumsum(newrun) - 1
    run = jnp.where(key_s < big * big, run, E - 1)
    w_out = jnp.zeros((E,), jnp.float32).at[run].add(w_s)
    cu_out = jnp.zeros((E,), jnp.int32).at[run].set((key_s // big).astype(jnp.int32))
    cv_out = jnp.zeros((E,), jnp.int32).at[run].set((key_s % big).astype(jnp.int32))
    n_runs = jnp.sum(newrun)
    valid_out = jnp.arange(E) < n_runs
    return cu_out, cv_out, jnp.where(valid_out, w_out, 0.0), valid_out
