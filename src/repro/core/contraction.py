"""Cluster contraction (paper §III/IV-C).

Each cluster of a (size-constrained) clustering becomes one coarse node;
coarse node weight = sum of member node weights; coarse edge (A, B) weight =
total weight of edges running between clusters A and B.  By construction a
partition of the coarse graph projects to a partition of the fine graph with
*identical* cut and balance — the property the whole multilevel scheme rests
on (tested property-style in tests/test_property.py).

Three implementations:

* :func:`contract_device` — the production path.  The paper's §IV-C parallel
  hash-based quotient construction expressed as the TPU-idiomatic segment
  sort: relabel (sort + prefix-sum distinct count), coarse node-weight
  segment-sum, quotient-arc dedup, and CSR rebuild run as ONE compiled
  executable over bucket-padded device arrays.  The LP engine
  (``repro.core.engine.LPEngine.contract``) wraps it with power-of-two
  shape bucketing so a handful of compilations serve every level of every
  V-cycle, and only the ``(n_c, m_c, max nw_c)`` scalars cross to host for
  the driver's termination/bucket decision — the coarse adjacency itself
  stays device-resident (:class:`~repro.graph.csr.GraphDev`) and feeds the
  next level's pack gather directly.
* :func:`contract` — the host/numpy **fallback** (numpy engine, graphs below
  the engine threshold, and the test oracle the device path is
  parity-checked against in tests/test_device_contraction.py).  Same
  algorithm expressed serially; coarse IDs are assigned in increasing
  original-label order by both paths, so their outputs are identical
  structure-for-structure.
* :func:`contract_arcs_jnp` — the per-shard building block used by the
  distributed pipeline: maps + deduplicates + weight-sums arcs for a
  shard's local subgraph on device (static shapes, padded);
  :func:`contract_device` is its whole-graph generalization.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..graph.csr import GraphNP

__all__ = [
    "CoarseMap",
    "contract",
    "contract_device",
    "packed_key_wbits",
    "relabel",
    "contract_arcs_jnp",
    "project_labels",
]

# The packed-key fast path rides (cu, cv, weight) in ONE uint32 sort key, so
# the pair space times the weight space must fit in 2^32 — the fallback
# threshold a future x64 enablement would want to revisit (64-bit keys lift
# both bounds).  Pinned by tests/test_device_contraction.py.
PACKED_KEY_SPACE = 2**32


def packed_key_wbits(Nb: int, Mb: int, ew_max: float, ew_integral: bool) -> int:
    """Weight-bit count for :func:`contract_device`'s packed-key fast path.

    Returns ``b > 0`` when every live arc weight is an integer in
    ``[1, 2^b - 1]`` AND the fused key ``(cu * Nb + cv) << b | w`` fits a
    uint32 (``Nb^2 * 2^b <= PACKED_KEY_SPACE``) AND the exact int32 cumsum
    of per-run weights cannot overflow (``Mb * (2^b - 1) < 2^31``); 0 selects
    the general scatter-add path.  Callers evaluate this once per graph —
    it is the single place the fast-path/fallback boundary is decided."""
    if not ew_integral or ew_max < 1.0:
        return 0
    b = int(ew_max).bit_length()
    if Nb * Nb * (1 << b) <= PACKED_KEY_SPACE and Mb * ((1 << b) - 1) < 2**31:
        return b
    return 0


def relabel(labels: np.ndarray) -> Tuple[np.ndarray, int]:
    """Map arbitrary cluster IDs to the contiguous range [0, n').

    Sort-based: equivalent to the paper's distributed distinct-counting +
    prefix-sum scheme (§IV-C), collapsed onto one host.
    """
    uniq, C = np.unique(labels, return_inverse=True)
    return C.astype(np.int32), int(uniq.shape[0])


def contract(g: GraphNP, labels: np.ndarray) -> Tuple[GraphNP, np.ndarray]:
    """Host-fallback contraction; returns (coarse graph, fine->coarse map C).

    The engine path uses :func:`contract_device`; this serves the numpy
    engine, sub-threshold levels, and as the parity oracle."""
    C, n_c = relabel(labels)
    nw_c = np.zeros(n_c, dtype=np.float64)
    np.add.at(nw_c, C, g.nw)

    src = g.arc_sources()
    cu = C[src].astype(np.int64)
    cv = C[g.indices].astype(np.int64)
    keep = cu != cv
    cu, cv = cu[keep], cv[keep]
    w = g.ew[keep].astype(np.float64)

    if cu.size == 0:
        coarse = GraphNP(
            indptr=np.zeros(n_c + 1, dtype=np.int64),
            indices=np.zeros(0, dtype=np.int32),
            ew=np.zeros(0, dtype=np.float32),
            nw=nw_c.astype(np.float32),
        )
        return coarse, C

    key = cu * np.int64(n_c) + cv
    order = np.argsort(key, kind="stable")
    key_s = key[order]
    w_s = w[order]
    boundary = np.empty(key_s.shape[0], dtype=bool)
    boundary[0] = True
    boundary[1:] = key_s[1:] != key_s[:-1]
    run = np.cumsum(boundary) - 1
    m_c = int(run[-1]) + 1
    w_c = np.zeros(m_c, dtype=np.float64)
    np.add.at(w_c, run, w_s)
    first = np.flatnonzero(boundary)
    cu_c = (key_s[first] // n_c).astype(np.int32)
    cv_c = (key_s[first] % n_c).astype(np.int32)

    counts = np.bincount(cu_c, minlength=n_c)
    indptr = np.zeros(n_c + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    coarse = GraphNP(
        indptr=indptr,
        indices=cv_c,
        ew=w_c.astype(np.float32),
        nw=nw_c.astype(np.float32),
    )
    return coarse, C


def project_labels(coarse_labels: np.ndarray, C: np.ndarray) -> np.ndarray:
    """Uncoarsening: fine node inherits the block of its coarse representative."""
    return coarse_labels[C]


@dataclass
class CoarseMap:
    """Fine->coarse mapping of one device contraction (hierarchy handle).

    ``dev`` is bucket-padded to the fine level's node bucket; entries
    ``>= n_fine`` are meaningless.  ``host()`` materializes the exact-length
    numpy map lazily (for the host-path engines), caching the download.
    """

    dev: jax.Array          # (Nb,) int32, valid through n_fine
    n_fine: int
    n_coarse: int
    on_materialize: Optional[object] = None
    _host: Optional[np.ndarray] = field(default=None, repr=False)

    def host(self) -> np.ndarray:
        if self._host is None:
            self._host = np.asarray(self.dev[: self.n_fine], dtype=np.int32)
            if self.on_materialize is not None:
                self.on_materialize(self._host.nbytes)
        return self._host


@functools.partial(jax.jit, static_argnames=("wbits",))
def contract_device(src, dst, ew, nw, labels, n, m, *, wbits: int = 0):
    """Whole-graph device contraction: one executable per shape bucket.

    Args:
      src, dst: (Mb,) int32 arc endpoints; entries >= ``m`` hold in-range
        garbage (masked).
      ew:       (Mb,) f32 arc weights, 0 beyond ``m``.
      nw:       (Nb,) f32 node weights, 0 beyond ``n``.
      labels:   (Nb,) int32 cluster ids in [0, n) for valid nodes.
      n, m:     traced scalars — the live node/arc counts, so ONE compiled
        executable per padded bucket shape ``(Nb, Mb)`` serves every level
        that lands in that bucket.
      wbits:    static — when > 0, a promise that every live arc weight is
        an integer in ``[1, 2^wbits - 1]`` and ``Nb^2 * 2^wbits <= 2^32``.
        The weight is then PACKED into the low bits of the uint32 sort key,
        and the per-run weight sums become exact int32 cumsum differences:
        the whole quotient build is one value-only sort plus vectorized
        scans — no payload sort, no scatter (the fast path on every
        backend; the caller detects eligibility once per graph).  0 selects
        the general float path (scatter-add segment sums).

    Returns ``(C, n_c, nw_c, indptr_c, src_c, dst_c, ew_c, m_c, nwmax_c,
    ewmax_c)``, all device-resident and padded to the input bucket: the
    fine->coarse map, coarse node count, coarse node weights, coarse CSR
    (arcs sorted by (cu, cv) — identical order to the host
    :func:`contract`), coarse arc sources, arc count, and the max coarse
    node/arc weights (the scalars the driver and the next level's ``wbits``
    decision need).  Coarse IDs follow increasing original-label order
    (== ``np.unique`` semantics), so the result is structure-identical to
    the host path.  Quotient weights are exact for integral inputs; for
    float weights the general path's segment sums run in unspecified order
    (tolerance-level reordering vs the host oracle).
    """
    Nb = nw.shape[0]
    Mb = src.shape[0]
    iota_n = jnp.arange(Nb, dtype=jnp.int32)
    iota_m = jnp.arange(Mb, dtype=jnp.int32)
    node_valid = iota_n < n
    sent = jnp.int32(Nb)

    # ---- relabel (paper §IV-C's distinct-count + prefix-sum): value-only
    # sort of the labels, dense ranks via cumsum, and C[v] recovered by
    # binary search for the first occurrence — no payload sort needed.
    lab = jnp.where(node_valid, labels, sent)
    sl = jnp.sort(lab)
    newrun_n = jnp.concatenate(
        [sl[:1] < sent, (sl[1:] != sl[:-1]) & (sl[1:] < sent)]
    )
    rank_n = (jnp.cumsum(newrun_n) - 1).astype(jnp.int32)
    n_c = jnp.sum(newrun_n).astype(jnp.int32)
    posn = jnp.minimum(jnp.searchsorted(sl, lab), Nb - 1)
    C = jnp.where(node_valid, rank_n[posn], 0).astype(jnp.int32)

    # ---- coarse node weights (invalid nodes add 0 at slot 0: inert)
    nw_c = jnp.zeros((Nb,), jnp.float32).at[C].add(
        jnp.where(node_valid, nw, 0.0)
    )
    nwmax_c = jnp.max(nw_c)

    # ---- quotient arcs: map, drop self-arcs, sort (cu, cv) keys
    arc_valid = iota_m < m
    cu = C[jnp.where(arc_valid, src, 0)]
    cv = C[jnp.where(arc_valid, dst, 0)]
    ok = arc_valid & (cu != cv)
    if wbits:
        # weight-packed uint32 key, sorted VALUE-ONLY (XLA's fast sort
        # path).  The (cu, cv) pair lives in the high bits so run grouping
        # is unchanged; the integral weight rides in the low bits and the
        # per-run sums fall out of one exact int32 cumsum.  The sentinel
        # encodes a max-weight SELF-arc of node Nb-1 — never a valid
        # quotient arc — so it needs no key-space headroom.
        big = jnp.uint32(Nb * Nb * (1 << wbits) - 1)
        pair = cu.astype(jnp.uint32) * jnp.uint32(Nb) + cv.astype(jnp.uint32)
        key = jnp.where(
            ok, (pair << wbits) | ew.astype(jnp.uint32), big
        )
        ks = jnp.sort(key)
        oks = ks < big
        khi = ks >> wbits
        first = jnp.concatenate([oks[:1], oks[1:] & (khi[1:] != khi[:-1])])
        # compaction by sorting the masked iota: run-first positions are
        # increasing, so a second value-only sort IS the compaction (cheaper
        # than a searchsorted over Mb queries on every backend measured)
        firstpos = jnp.sort(jnp.where(first, iota_m, jnp.int32(Mb)))
        fp = jnp.minimum(firstpos, Mb - 1)
        m_c = jnp.sum(first).astype(jnp.int32)
        arc_ok = iota_m < m_c
        uk = khi[fp]
        src_c = jnp.where(arc_ok, (uk // jnp.uint32(Nb)).astype(jnp.int32), 0)
        dst_c = jnp.where(arc_ok, (uk % jnp.uint32(Nb)).astype(jnp.int32), 0)
        w_s = jnp.where(oks, ks & jnp.uint32((1 << wbits) - 1), 0)
        cumw = jnp.cumsum(w_s.astype(jnp.int32))
        n_ok = jnp.sum(oks).astype(jnp.int32)
        fpe = jnp.concatenate([firstpos[1:], jnp.full((1,), Mb, jnp.int32)])
        ends = jnp.minimum(fpe, n_ok)
        hi = cumw[jnp.clip(ends - 1, 0, Mb - 1)]
        lo = jnp.where(fp > 0, cumw[jnp.maximum(fp - 1, 0)], 0)
        ew_c = jnp.where(arc_ok, (hi - lo).astype(jnp.float32), 0.0)
    elif Nb * Nb < 2**31:
        # general weights, fused int32 key: value-only sort, then the run
        # id of each unsorted arc by binary search and a scatter-add for
        # the f32 segment sums
        big = jnp.int32(2**31 - 1)
        key = jnp.where(ok, cu * jnp.int32(Nb) + cv, big)
        ks = jnp.sort(key)
        oks = ks < big
        first = jnp.concatenate([oks[:1], oks[1:] & (ks[1:] != ks[:-1])])
        firstpos = jnp.sort(jnp.where(first, iota_m, jnp.int32(Mb)))
        fp = jnp.minimum(firstpos, Mb - 1)
        m_c = jnp.sum(first).astype(jnp.int32)
        arc_ok = iota_m < m_c
        uk = ks[fp]
        src_c = jnp.where(arc_ok, uk // jnp.int32(Nb), 0)
        dst_c = jnp.where(arc_ok, uk % jnp.int32(Nb), 0)
        run = (jnp.cumsum(first) - 1).astype(jnp.int32)
        pos_m = jnp.minimum(jnp.searchsorted(ks, key), Mb - 1)
        run_of = jnp.where(ok, run[pos_m], Mb)
        ew_c = jnp.zeros((Mb,), jnp.float32).at[run_of].add(
            jnp.where(ok, ew, 0.0), mode="drop"
        )
    else:
        # > 46k-node levels: two-pass lexicographic payload sort (rare at
        # this repo's scales; correct for any size without int64)
        aorder = jnp.lexsort(
            (jnp.where(ok, cv, sent), jnp.where(ok, cu, sent))
        )
        oks = ok[aorder]
        cu_s = jnp.where(oks, cu[aorder], sent)
        cv_s = jnp.where(oks, cv[aorder], sent)
        first = jnp.concatenate(
            [
                oks[:1],
                oks[1:] & ((cu_s[1:] != cu_s[:-1]) | (cv_s[1:] != cv_s[:-1])),
            ]
        )
        firstpos = jnp.sort(jnp.where(first, iota_m, jnp.int32(Mb)))
        fp = jnp.minimum(firstpos, Mb - 1)
        m_c = jnp.sum(first).astype(jnp.int32)
        arc_ok = iota_m < m_c
        src_c = jnp.where(arc_ok, cu_s[fp], 0)
        dst_c = jnp.where(arc_ok, cv_s[fp], 0)
        run = (jnp.cumsum(first) - 1).astype(jnp.int32)
        run_of = jnp.zeros((Mb,), jnp.int32).at[aorder].set(
            jnp.where(oks, run, Mb)
        )
        run_of = jnp.where(ok, run_of, Mb)
        ew_c = jnp.zeros((Mb,), jnp.float32).at[run_of].add(
            jnp.where(ok, ew, 0.0), mode="drop"
        )
    ewmax_c = jnp.max(ew_c)

    # ---- CSR rebuild: src_c is non-decreasing over the live prefix, so the
    # row pointers are binary searches, not scatters
    cu_sorted = jnp.where(arc_ok, src_c, sent)
    indptr_c = jnp.searchsorted(
        cu_sorted, jnp.arange(Nb + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    return C, n_c, nw_c, indptr_c, src_c, dst_c, ew_c, m_c, nwmax_c, ewmax_c


def contract_arcs_jnp(
    cu: jnp.ndarray, cv: jnp.ndarray, w: jnp.ndarray, valid: jnp.ndarray, n_c: int
):
    """Device-side quotient-arc dedup for one shard (static shapes).

    Args:
      cu, cv: (E,) int32 coarse endpoints of local arcs.
      w:      (E,) f32 arc weights.
      valid:  (E,) bool — padding / self-arc mask (False entries are dropped).
      n_c:    static upper bound on coarse node count.
    Returns:
      (cu', cv', w', valid'): deduplicated arcs, padded to E.
    """
    E = cu.shape[0]
    ok = valid & (cu != cv)
    # key sorts invalid arcs to the end
    big = jnp.int64(n_c)
    key = jnp.where(ok, cu.astype(jnp.int64) * big + cv.astype(jnp.int64), big * big)
    order = jnp.argsort(key)
    key_s = key[order]
    w_s = jnp.where(ok, w, 0.0)[order]
    newrun = jnp.concatenate(
        [jnp.ones((1,), bool), key_s[1:] != key_s[:-1]]
    ) & (key_s < big * big)
    run = jnp.cumsum(newrun) - 1
    run = jnp.where(key_s < big * big, run, E - 1)
    w_out = jnp.zeros((E,), jnp.float32).at[run].add(w_s)
    cu_out = jnp.zeros((E,), jnp.int32).at[run].set((key_s // big).astype(jnp.int32))
    cv_out = jnp.zeros((E,), jnp.int32).at[run].set((key_s % big).astype(jnp.int32))
    n_runs = jnp.sum(newrun)
    valid_out = jnp.arange(E) < n_runs
    return cu_out, cv_out, jnp.where(valid_out, w_out, 0.0), valid_out
