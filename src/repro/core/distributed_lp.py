"""Distributed-memory SCLaP via ``jax.shard_map`` (paper §IV-A/B).

Maps the paper's MPI scheme onto a 1-D device mesh:

* every PE owns a contiguous node range plus ghost copies of remote
  neighbours (:class:`~repro.graph.packing.ShardedGraph`);
* within a *phase*, a PE sweeps its local nodes (chunked-sequentially, the
  local analogue of the paper's per-PE traversal) using ghost labels from
  the previous phase — the paper's asynchronous overlap expressed
  bulk-synchronously;
* at the end of a phase, every PE packs the labels of its *interface nodes*
  into a fixed send buffer; one ``all_gather`` replaces the paper's
  per-adjacent-PE messages, and a precomputed (owner, slot) map scatters the
  received labels into each PE's ghost table;
* balance accounting follows §IV-B exactly:
  - **coarsening**: per-PE *local* weight tables over the clusters of local
    + ghost nodes only (a global table of size n per PE is infeasible).
    The table here is a sorted-unique (label -> weight) array rebuilt each
    phase and scatter-updated within it — the sort-based stand-in for the
    paper's hash map (DESIGN.md §2);
  - **refinement**: exact global block weights via one ``psum`` per phase,
    locally updated in between (the ParMetis-style scheme the paper adopts).

The full multilevel driver on top (:func:`partition_distributed`) runs
coarsening/refinement sweeps on the mesh and contracts between levels on
the host, mirroring the paper's level-synchronous structure.
"""

from __future__ import annotations

import functools
import weakref
from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map as shard_map_compat
from ..graph.csr import GraphNP
from ..graph.packing import ShardedGraph, pack_chunks, shard_graph

__all__ = [
    "DistLPPlan",
    "build_plan",
    "lp_cluster_distributed",
    "lp_refine_distributed",
]

_NEG = -1e30
_SENT = np.int32(2**30)  # sentinel label, larger than any real cluster id


@dataclass
class DistLPPlan:
    """Device-ready stacked arrays for the distributed sweep (leading axis P)."""

    sg: ShardedGraph
    # per-shard chunk layout (local node sweep order), stacked over PEs:
    ch_nodes: np.ndarray      # (P, C, Nc) int32 local node ids, pad -1
    ch_edge_dst: np.ndarray   # (P, C, Ec) int32 local-EXT ids, pad 0
    ch_edge_w: np.ndarray     # (P, C, Ec) f32
    ch_edge_slot: np.ndarray  # (P, C, Ec) int32
    ch_edge_valid: np.ndarray  # (P, C, Ec) bool
    ch_node_valid: np.ndarray  # (P, C, Nc) bool


# Plan cache: sharding + per-shard packing is a pure function of
# (graph, shard geometry, order mode, seed-epoch), and the multilevel dist
# engine used to recompute it on EVERY lp_cluster_distributed /
# lp_refine_distributed call.  Keyed by graph identity with a WEAK graph
# reference (the cache must not pin multi-GB graphs alive) and a small FIFO
# bound: coarse graphs are rebuilt per V-cycle, so only the finest graph's
# plans re-hit, and entries die with their graph.
_PLAN_CACHE: dict = {}
_PLAN_CACHE_CAP = 8


def build_plan(
    g: GraphNP,
    P_shards: int,
    chunks_per_shard: int = 8,
    order: str = "degree",
    seed: int = 0,
) -> DistLPPlan:
    """Shard the graph and pack each shard's local sweep into chunks.

    Cached per ``(graph, P, chunks_per_shard, order, seed)`` — pass the
    run's seed-epoch (not a per-sweep seed) as ``seed`` to reuse plans
    across calls; traversal re-randomization belongs to the sweep seed.
    """
    key = (id(g), P_shards, chunks_per_shard, order, seed)
    hit = _PLAN_CACHE.get(key)
    if hit is not None and hit[0]() is g:
        _PLAN_CACHE[key] = _PLAN_CACHE.pop(key)   # LRU refresh: the finest
        return hit[1]                             # graph's plans re-hit most
    plan = _build_plan_impl(g, P_shards, chunks_per_shard, order, seed)
    for k in [k for k, v in _PLAN_CACHE.items() if v[0]() is None]:
        del _PLAN_CACHE[k]          # entries whose graph was collected
    if len(_PLAN_CACHE) >= _PLAN_CACHE_CAP:
        _PLAN_CACHE.pop(next(iter(_PLAN_CACHE)))
    _PLAN_CACHE[key] = (weakref.ref(g), plan)
    return plan


def _build_plan_impl(
    g: GraphNP,
    P_shards: int,
    chunks_per_shard: int,
    order: str,
    seed: int,
) -> DistLPPlan:
    sg = shard_graph(g, P_shards)
    rng = np.random.default_rng(seed)
    packs = []
    for p in range(P_shards):
        n_p = int(sg.n_local[p])
        m_p = int(sg.m_local[p])
        local = GraphNP(
            indptr=sg.indptr[p, : n_p + 1].astype(np.int64),
            indices=sg.indices[p, :m_p],
            ew=sg.ew[p, :m_p],
            nw=sg.nw[p, :n_p],
        )
        deg = local.degrees()
        if order == "degree":
            o = np.argsort(deg + rng.random(n_p), kind="stable")
        else:
            o = rng.permutation(n_p)
        packs.append(
            pack_chunks(
                local,
                o.astype(np.int64),
                max_nodes=max(64, -(-n_p // chunks_per_shard)),
                max_edges=max(512, -(-m_p // max(1, chunks_per_shard // 2))),
            )
        )
    C = max(pk.num_chunks for pk in packs)
    Nc = max(pk.nodes.shape[1] for pk in packs)
    Ec = max(pk.edge_dst.shape[1] for pk in packs)
    Pn = P_shards
    ch_nodes = np.full((Pn, C, Nc), -1, np.int32)
    ch_node_valid = np.zeros((Pn, C, Nc), bool)
    ch_edge_dst = np.zeros((Pn, C, Ec), np.int32)
    ch_edge_w = np.zeros((Pn, C, Ec), np.float32)
    ch_edge_slot = np.zeros((Pn, C, Ec), np.int32)
    ch_edge_valid = np.zeros((Pn, C, Ec), bool)
    for p, pk in enumerate(packs):
        c, nn = pk.nodes.shape
        e = pk.edge_dst.shape[1]
        n_p = int(sg.n_local[p])
        nodes = pk.nodes.copy()
        nodes[~pk.node_valid] = -1  # pack_chunks pads with local n; use -1
        ch_nodes[p, :c, :nn] = nodes
        ch_node_valid[p, :c, :nn] = pk.node_valid
        dst = pk.edge_dst.copy()
        dst[~pk.edge_valid] = 0  # in-range garbage; masked by edge_valid
        ch_edge_dst[p, :c, :e] = dst
        ch_edge_w[p, :c, :e] = pk.edge_w
        ch_edge_slot[p, :c, :e] = pk.edge_src_slot
        ch_edge_valid[p, :c, :e] = pk.edge_valid
    return DistLPPlan(
        sg=sg,
        ch_nodes=ch_nodes,
        ch_edge_dst=ch_edge_dst,
        ch_edge_w=ch_edge_w,
        ch_edge_slot=ch_edge_slot,
        ch_edge_valid=ch_edge_valid,
        ch_node_valid=ch_node_valid,
    )


# --------------------------------------------------------------------------
# the per-shard sweep body (runs inside shard_map; axis name "pe")
# --------------------------------------------------------------------------


def _shard_sweep(
    # chunk layout (local shapes, leading P axis stripped by shard_map)
    ch_nodes, ch_node_valid, ch_edge_dst, ch_edge_w, ch_edge_slot, ch_edge_valid,
    # shard structure
    nw_local, ghost_nw, ghost_owner, ghost_slot, iface_nodes, n_local, n_ghost,
    # state
    labels_local, labels_ghost,
    # constants
    U, key,
    *,
    iters: int,
    refine_mode: bool,
    k: int,
    maxN: int,
    maxG: int,
    maxI: int,
):
    """One shard's SCLaP: iters x C phases (one chunk per phase + exchange)."""
    C, Nc = ch_nodes.shape[0], ch_nodes.shape[1]
    Ec = ch_edge_dst.shape[1]
    pe = jax.lax.axis_index("pe")
    local_valid = jnp.arange(maxN) < n_local
    ghost_valid = jnp.arange(maxG) < n_ghost

    def phase(ph, carry):
        """One phase == one local chunk sweep + ghost exchange (paper \u00a7IV-A:
        updates of phase k-1 are consumed in phase k) + weight resync."""
        c = ph % C
        labels_local, labels_ghost, key, moves = carry
        key, sub = jax.random.split(key)
        labels_ext = jnp.concatenate([labels_local, labels_ghost])

        # ---- per-phase weight tables (\u00a7IV-B) --------------------------
        if refine_mode:
            # exact global block weights via one allreduce per phase
            local_bw = (
                jnp.zeros((k + 1,), jnp.float32)
                .at[jnp.where(local_valid, labels_local, k)]
                .add(jnp.where(local_valid, nw_local, 0.0))
            )
            table_w = jax.lax.psum(local_bw, "pe")
            table_w = table_w.at[k].set(jnp.inf)
            table_ids = jnp.zeros((1,), jnp.int32)  # unused
        else:
            # local weight table over clusters of local+ghost nodes only
            ids = jnp.concatenate(
                [
                    jnp.where(local_valid, labels_local, _SENT),
                    jnp.where(ghost_valid, labels_ghost, _SENT),
                ]
            )
            wgt = jnp.concatenate(
                [
                    jnp.where(local_valid, nw_local, 0.0),
                    jnp.where(ghost_valid, ghost_nw, 0.0),
                ]
            )
            order = jnp.argsort(ids)
            sid = ids[order]
            sw = wgt[order]
            newrun = jnp.concatenate([jnp.ones((1,), bool), sid[1:] != sid[:-1]])
            rid = jnp.cumsum(newrun) - 1
            T = sid.shape[0]
            table_ids = jnp.full((T,), _SENT, jnp.int32).at[rid].set(sid)
            table_w = jnp.zeros((T,), jnp.float32).at[rid].add(sw)
            table_w = jnp.where(table_ids == _SENT, jnp.inf, table_w)

        def lookup_w(lbl):
            if refine_mode:
                return table_w[jnp.minimum(lbl, k)]
            pos = jnp.minimum(jnp.searchsorted(table_ids, lbl), table_ids.shape[0] - 1)
            return jnp.where(table_ids[pos] == lbl, table_w[pos], jnp.inf)

        # ---- the chunk sweep ---------------------------------------------
        nd = ch_nodes[c]
        ndv = ch_node_valid[c]
        dst = ch_edge_dst[c]
        ev = ch_edge_valid[c]
        slot = ch_edge_slot[c]
        w0 = jnp.where(ev, ch_edge_w[c], 0.0)
        cand = jnp.where(ev, labels_ext[dst], _SENT).astype(jnp.int32)

        perm = jnp.lexsort((cand, slot))
        s_slot = slot[perm]
        s_lbl = cand[perm]
        s_w = w0[perm]
        nr = jnp.concatenate(
            [jnp.ones((1,), bool), (s_slot[1:] != s_slot[:-1]) | (s_lbl[1:] != s_lbl[:-1])]
        )
        rid = jnp.cumsum(nr) - 1
        run_w = jnp.zeros((Ec,), jnp.float32).at[rid].add(s_w)
        run_slot = jnp.full((Ec,), Nc, jnp.int32).at[rid].set(s_slot)
        run_lbl = jnp.full((Ec,), _SENT, jnp.int32).at[rid].set(s_lbl)

        nd_c = jnp.maximum(nd, 0)
        own = jnp.where(ndv, labels_local[nd_c], _SENT)
        own_r = own[jnp.minimum(run_slot, Nc - 1)]
        nwv = jnp.where(ndv, nw_local[nd_c], 0.0)
        nw_r = nwv[jnp.minimum(run_slot, Nc - 1)]
        cand_w = lookup_w(run_lbl)
        fits = cand_w + nw_r <= U
        if refine_mode:
            own_w = lookup_w(own_r)
            overloaded = own_w > U
            eligible = jnp.where(
                overloaded,
                fits & (run_lbl != own_r),
                (run_w > 0) & (fits | (run_lbl == own_r)),
            )
        else:
            eligible = (run_w > 0) & (fits | (run_lbl == own_r))
        eligible &= (run_slot < Nc) & (run_lbl < _SENT)
        jit_ = jax.random.uniform(sub, (Ec,), jnp.float32, 0.0, 0.49)
        score = jnp.where(eligible, run_w + jit_, _NEG)

        seg = jnp.minimum(run_slot, Nc)
        best = jnp.full((Nc + 1,), _NEG, jnp.float32).at[seg].max(score)
        is_best = (score >= best[seg]) & (score > _NEG / 2)
        win = (
            jnp.full((Nc + 1,), _SENT, jnp.int32)
            .at[seg]
            .min(jnp.where(is_best, run_lbl, _SENT))
        )[:Nc]
        new_lbl = jnp.where(ndv & (win < _SENT), win, own)
        moved = ndv & (new_lbl != own)

        labels_local = labels_local.at[nd_c].set(
            jnp.where(ndv, new_lbl, labels_local[nd_c]), mode="drop"
        )
        moves = moves + jnp.sum(moved)

        # ---- phase exchange: interface labels -> ghosts -------------------
        send = labels_local[jnp.maximum(iface_nodes, 0)]
        all_buf = jax.lax.all_gather(send, "pe")           # (P, maxI)
        new_ghost = all_buf[ghost_owner, ghost_slot]
        labels_ghost = jnp.where(ghost_valid, new_ghost, labels_ghost)
        return labels_local, labels_ghost, key, moves

    key = jax.random.fold_in(key, pe)
    labels_local, labels_ghost, key, moves = jax.lax.fori_loop(
        0,
        iters * C,  # one iteration == C phases (one chunk each)
        phase,
        (labels_local, labels_ghost, key, jnp.zeros((), jnp.int32)),
    )
    return labels_local, labels_ghost, jax.lax.psum(moves, "pe")


def _make_mesh(P_shards: int) -> Mesh:
    devs = np.array(jax.devices()[:P_shards])
    return Mesh(devs, ("pe",))


def _run_distributed(
    plan: DistLPPlan,
    labels_global: Optional[np.ndarray],
    U: float,
    iters: int,
    seed: int,
    refine_mode: bool,
    k: int,
) -> np.ndarray:
    sg = plan.sg
    Pn = sg.P
    mesh = _make_mesh(Pn)
    maxN, maxG, maxI = sg.max_local, sg.max_ghost, sg.max_iface

    # initial labels: own global id (cluster mode) or the given partition
    ll = np.zeros((Pn, maxN), np.int32)
    lg = np.zeros((Pn, maxG), np.int32)
    for p in range(Pn):
        n_p, g_p = int(sg.n_local[p]), int(sg.n_ghost[p])
        if refine_mode:
            ll[p, :n_p] = labels_global[sg.range_start[p] : sg.range_start[p] + n_p]
            lg[p, :g_p] = labels_global[sg.ghost_global[p, :g_p]]
        else:
            ll[p, :n_p] = np.arange(sg.range_start[p], sg.range_start[p] + n_p)
            lg[p, :g_p] = sg.ghost_global[p, :g_p]

    spec = P("pe")
    args = [
        plan.ch_nodes, plan.ch_node_valid, plan.ch_edge_dst, plan.ch_edge_w,
        plan.ch_edge_slot, plan.ch_edge_valid,
        sg.nw, sg.ghost_nw, sg.ghost_owner, sg.ghost_slot, sg.iface_nodes,
        sg.n_local.astype(np.int32), sg.n_ghost.astype(np.int32),
    ]
    jargs = [jnp.asarray(a) for a in args]
    jll, jlg = jnp.asarray(ll), jnp.asarray(lg)

    # shard_map blocks keep a leading PE axis of size 1; strip it inside
    def body(ch_nodes, ch_nv, ch_ed, ch_ew, ch_es, ch_ev, nw, gnw, gow, gsl,
             ifn, nloc, ngho, ll_, lg_, key):
        out = _shard_sweep(
            ch_nodes[0], ch_nv[0], ch_ed[0], ch_ew[0], ch_es[0], ch_ev[0],
            nw[0], gnw[0], gow[0], gsl[0], ifn[0], nloc[0], ngho[0],
            ll_[0], lg_[0],
            jnp.float32(U), key,
            iters=iters, refine_mode=refine_mode, k=k,
            maxN=maxN, maxG=maxG, maxI=maxI,
        )
        return out[0][None], out[1][None], out[2]

    shmapped = shard_map_compat(
        body,
        mesh=mesh,
        in_specs=(spec,) * 15 + (P(),),
        out_specs=(spec, spec, P()),
    )
    key = jax.random.PRNGKey(seed)
    out_ll, out_lg, moves = jax.jit(shmapped)(
        *jargs, jll, jlg, key
    )
    out_ll = np.asarray(out_ll)
    labels = np.zeros(sg.n, np.int32)
    for p in range(Pn):
        n_p = int(sg.n_local[p])
        labels[sg.range_start[p] : sg.range_start[p] + n_p] = out_ll[p, :n_p]
    return labels


def lp_cluster_distributed(
    plan: DistLPPlan, U: float, iters: int = 3, seed: int = 0
) -> np.ndarray:
    """Distributed size-constrained LP clustering; returns global labels."""
    return _run_distributed(plan, None, U, iters, seed, refine_mode=False, k=0)


def lp_refine_distributed(
    plan: DistLPPlan,
    labels_global: np.ndarray,
    k: int,
    U: float,
    iters: int = 6,
    seed: int = 0,
) -> np.ndarray:
    """Distributed LP local search with exact psum block weights."""
    return _run_distributed(
        plan, labels_global, U, iters, seed, refine_mode=True, k=k
    )


# --------------------------------------------------------------------------
# distributed contraction (paper §IV-C): each PE builds the weighted quotient
# of its local subgraph on device (sort+dedup — the TPU stand-in for the
# paper's hashing); the deduplicated per-PE arc lists are merged on host.
# --------------------------------------------------------------------------


def contract_distributed(plan: DistLPPlan, labels_global: np.ndarray):
    """Returns (coarse GraphNP, fine->coarse mapping C) like core.contract,
    but the O(m) quotient-building runs sharded on the device mesh."""
    from ..graph.csr import GraphNP
    from .contraction import contract_arcs_jnp, relabel

    sg = plan.sg
    Pn = sg.P
    C_map, n_c = relabel(labels_global)
    maxN, maxG, maxM = sg.max_local, sg.max_ghost, sg.indices.shape[1]

    # per-shard coarse labels of local + ghost nodes
    cl = np.zeros((Pn, maxN), np.int32)
    cg = np.zeros((Pn, maxG), np.int32)
    for p in range(Pn):
        n_p, g_p = int(sg.n_local[p]), int(sg.n_ghost[p])
        a = int(sg.range_start[p])
        cl[p, :n_p] = C_map[a : a + n_p]
        cg[p, :g_p] = C_map[sg.ghost_global[p, :g_p]]

    mesh = _make_mesh(Pn)
    spec = P("pe")

    def body(indptr, indices, ew, m_local, cl_, cg_):
        indptr, indices, ew = indptr[0], indices[0], ew[0]
        m_local, cl_, cg_ = m_local[0], cl_[0], cg_[0]
        labels_ext = jnp.concatenate([cl_, cg_])
        arc = jnp.arange(maxM)
        src = jnp.searchsorted(indptr, arc, side="right") - 1
        valid = arc < m_local
        cu = jnp.where(valid, cl_[jnp.clip(src, 0, maxN - 1)], 0)
        cv = jnp.where(valid, labels_ext[indices], 0)
        cu2, cv2, w2, v2 = contract_arcs_jnp(
            cu.astype(jnp.int32), cv.astype(jnp.int32),
            jnp.where(valid, ew, 0.0), valid, n_c,
        )
        return cu2[None], cv2[None], w2[None], v2[None]

    out = jax.jit(shard_map_compat(
        body, mesh=mesh,
        in_specs=(spec,) * 6,
        out_specs=(spec,) * 4,
    ))(
        jnp.asarray(sg.indptr), jnp.asarray(sg.indices), jnp.asarray(sg.ew),
        jnp.asarray(sg.m_local), jnp.asarray(cl), jnp.asarray(cg),
    )
    cu, cv, w, v = (np.asarray(x) for x in out)
    keep = v.reshape(-1)
    uu = cu.reshape(-1)[keep]
    vv = cv.reshape(-1)[keep]
    ww = w.reshape(-1)[keep]
    # host merge of the per-PE deduplicated quotient arcs
    from ..graph.csr import from_edges

    nw_c = np.zeros(n_c, np.float64)
    np.add.at(nw_c, C_map, np.concatenate(
        [sg.nw[p, : int(sg.n_local[p])] for p in range(Pn)]))
    coarse = from_edges(n_c, uu, vv, ww, nw=nw_c.astype(np.float32),
                        symmetrize=False, dedup=True)
    return coarse, C_map
