"""Coarse-grained distributed evolutionary algorithm (KaFFPaE, §II-C/IV-E).

Island model: every "PE" (island) keeps its own population of partitions of
the (replicated) coarsest graph and performs combine/mutation operations on
it; from time to time the best local individual is sent to other islands
(randomized rumor spreading -> here: synchronous gossip each epoch, the
bulk-synchronous TPU equivalent, see DESIGN.md §2).

Two implementations share this module's *spec*:

* **Device path (production)** — ``repro.core.evo_device`` +
  ``repro.core.engine.LPEngine.evolve_device``: the whole population lives
  on device as a ``(pop, n)`` label batch and a generation step runs as ONE
  bucketed jitted executable — batched greedy-growing seeds, a vmapped
  population axis over the engine's cached ``_lp_sweep`` chunk pack,
  overlay-cell combine via the packed-key relabel machinery, synchronous
  gain/repair rounds, and device-side elitism/selection/gossip with
  stateless hash tie-breaks.  Islands optionally map onto ``shard_map``
  shards with per-epoch best-individual gossip as a collective.
* **Numpy oracle (this module)** — :func:`evolve_batched_numpy`: the same
  algorithm, one individual at a time, in plain numpy.  Every tie-break,
  gate, and float32 operation mirrors the device step bit-for-bit (for
  integral node/edge weights, whose f32 sums are exact in any order — the
  precondition ``LPEngine.can_evolve_device`` gates on), so the device
  batch is regression-tested *bit-identical* to this sequential loop
  (tests/test_evo_device.py).  It doubles as the host-sequential baseline
  the ``evo_hot`` benchmark compares against.

The combine operator follows the paper (and arXiv:1402.3281's
size-constrained clustering combine): both parents' cut edges are protected
— the overlay cells ``(P1(v), P2(v))`` are the clusters, so each cell is a
subset of one block of *both* parents; the better parent seeds the child
(consistent, cells never straddle a parent block); refinement plus final
elitism never worsen it, so the offspring is at least as good as the better
parent (property-tested).  Cell-granular moves replace the per-individual
host contraction: block scores are segment-summed over cell ids directly,
so no per-individual quotient graph is ever materialized.

:func:`evolve` (below) is the original host/numpy KaFFPaE orchestration
calling the sequential SCLaP per individual — retained for the pure-numpy
engine and as the legacy reference; the device path supersedes it in the
multilevel pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from ..graph.csr import GraphNP
from .contraction import contract, project_labels
from .fm import fm_refine, gain_round_np
from .initial_partition import greedy_growing, repair_balance
from .label_propagation import (
    hash_base_u32,
    hash_jitter_np,
    hash_u32_np,
    hash_unit_np,
    sclap_numpy,
    sweep_refine_numpy,
)
from .metrics import block_weights_np, cut_np

__all__ = [
    "EvoConfig",
    "EvoInputs",
    "evolve",
    "evolve_batched_numpy",
]

# --------------------------------------------------------------------------
# Batched-evolution spec constants — shared verbatim by the device kernels
# (repro.core.evo_device) and the numpy oracle below.  Changing any of them
# changes BOTH paths; the parity tests keep them honest.
# --------------------------------------------------------------------------

GROW_ROUNDS = 16        # frontier-round FLOOR (see grow_rounds_bound)
CELL_ROUNDS = 2         # overlay-cell move rounds inside combine
GAIN_ROUNDS = 2         # synchronous best-gain (FM-lite) rounds per refine
REPAIR_ROUNDS = 3       # synchronous balance-repair rounds per refine
MUTATE_FRAC = 0.125     # boundary-node flip probability under mutation
COMBINE_PROB = 0.7      # combine-vs-mutate draw per island per generation
INFEAS_PENALTY = 1 << 30  # int32 fitness-key offset for infeasible labels

# hash-stream tags: every random decision draws from a stateless uint32
# stream keyed (seed, phase, tag, context, coordinates) — identical in both
# implementations, invariant to array padding
TAG_SEEDKEY = 0x5EED01      # greedy seed scoring
TAG_GROW = 0x5EED02         # growth-round tie-breaks
TAG_SWEEP = 0x5EED03        # per-individual LP sweep seed derivation
TAG_GAIN = 0x5EED04         # gain-round tie-breaks
TAG_GAIN_GATE = 0x5EED05    # gain-round move gate
TAG_REPAIR = 0x5EED06       # repair-round move gate
TAG_OP = 0x5EED07           # combine-vs-mutate draw
TAG_P1 = 0x5EED08           # first parent index
TAG_P2 = 0x5EED09           # second parent offset
TAG_MUT_FLIP = 0x5EED0A     # mutation boundary flips
TAG_MUT_LBL = 0x5EED0B      # mutation replacement labels
TAG_CELL = 0x5EED0C         # cell-move tie-breaks
TAG_CELL_GATE = 0x5EED0D    # cell-move gate


def grow_rounds_bound(n: int, k: int, m: int) -> int:
    """Frontier-round budget for batched greedy growing (shared by the
    device path and the numpy oracle — both must use the same bound).

    BFS from k seeds needs ~seed-eccentricity rounds; the legacy fixed
    ``GROW_ROUNDS = 16`` truncated deep (high-diameter, low-average-degree)
    coarsest graphs and dumped the unreached tail into round-robin
    leftovers — terrible cuts on path-like graphs.  The budget now scales
    with a degree-based diameter proxy (low average degree == deep graph),
    floored at the legacy constant and capped at ``n``.  The cap is never
    the binding *cost*: both implementations exit as soon as every node is
    assigned or a round makes no progress — a stalled frontier can never
    recover, because assignments are the only state a growth round reads.
    """
    if n <= 0:
        return GROW_ROUNDS
    avg_deg = m / n
    proxy = int(np.ceil(4.0 * n / max(k, 1) / max(avg_deg, 1.0)))
    return int(min(max(GROW_ROUNDS, proxy), n))


@dataclass
class EvoConfig:
    k: int
    Lmax: float
    islands: int = 4            # simulated PEs
    pop_per_island: int = 3
    generations: int = 6
    refine_iters: int = 6
    cluster_iters: int = 2
    f_range: tuple = (10.0, 25.0)
    seed: int = 0
    seed_individuals: List[np.ndarray] = field(default_factory=list)


@dataclass
class _Ind:
    labels: np.ndarray
    cut: float
    feasible: bool


def _fitness_key(ind: _Ind):
    # feasible individuals always beat infeasible ones; then smaller cut
    return (0 if ind.feasible else 1, ind.cut)


def _mk(g: GraphNP, labels: np.ndarray, k: int, Lmax: float) -> _Ind:
    bw = block_weights_np(g, labels, k)
    return _Ind(labels=labels, cut=cut_np(g, labels), feasible=bool(bw.max() <= Lmax + 1e-6))


def _combine(
    g: GraphNP, p1: _Ind, p2: _Ind, cfg: EvoConfig, rng: np.random.Generator
) -> _Ind:
    k, Lmax = cfg.k, cfg.Lmax
    better, other = (p1, p2) if _fitness_key(p1) <= _fitness_key(p2) else (p2, p1)
    overlay = p1.labels.astype(np.int64) * k + p2.labels.astype(np.int64)
    f = rng.uniform(*cfg.f_range)
    U = max(g.nw.max(), Lmax / f)
    seed = int(rng.integers(1 << 30))
    clus = sclap_numpy(
        g,
        np.arange(g.n),
        U=U,
        iters=cfg.cluster_iters,
        seed=seed,
        restrict=overlay,
    ).labels
    coarse, C = contract(g, clus)
    # apply the better parent: every cluster lies inside one of its blocks
    rep = np.zeros(coarse.n, dtype=np.int64)
    rep[C] = np.arange(g.n)  # any representative fine node per coarse node
    lab_c = better.labels[rep].astype(np.int32)
    lab_c = sclap_numpy(
        coarse, lab_c, U=Lmax, iters=cfg.refine_iters, seed=seed + 1,
        refine_mode=True, num_labels=k,
    ).labels
    child = project_labels(lab_c, C)
    child = sclap_numpy(
        g, child, U=Lmax, iters=cfg.refine_iters, seed=seed + 2,
        refine_mode=True, num_labels=k,
    ).labels
    child = fm_refine(g, child, k, Lmax, seed=seed + 3)
    child = repair_balance(g, child, k, Lmax, seed=seed)
    ind = _mk(g, child, k, Lmax)
    return ind if _fitness_key(ind) <= _fitness_key(better) else better


def _mutate(g: GraphNP, p: _Ind, cfg: EvoConfig, rng: np.random.Generator) -> _Ind:
    """Perturb a boundary region, then refine (a V-cycle-flavoured mutation)."""
    k, Lmax = cfg.k, cfg.Lmax
    labels = p.labels.copy()
    src = g.arc_sources()
    boundary = np.unique(src[labels[src] != labels[g.indices]])
    if boundary.size:
        take = rng.choice(boundary, size=max(1, boundary.size // 8), replace=False)
        labels[take] = rng.integers(0, k, take.shape[0])
    seed = int(rng.integers(1 << 30))
    labels = sclap_numpy(
        g, labels, U=Lmax, iters=cfg.refine_iters, seed=seed,
        refine_mode=True, num_labels=k,
    ).labels
    labels = fm_refine(g, labels, k, Lmax, seed=seed + 1)
    labels = repair_balance(g, labels, k, Lmax, seed=seed)
    ind = _mk(g, labels, k, Lmax)
    return ind if _fitness_key(ind) <= _fitness_key(p) else p


def evolve(g: GraphNP, cfg: EvoConfig) -> np.ndarray:
    """Run the island GA; returns the best partition of the coarsest graph."""
    rng = np.random.default_rng(cfg.seed)
    islands: List[List[_Ind]] = []
    for isl in range(cfg.islands):
        pop: List[_Ind] = []
        for j in range(cfg.pop_per_island):
            if cfg.seed_individuals and j == 0:
                # V-cycle seeding: the previous solution joins every island
                seeded = cfg.seed_individuals[isl % len(cfg.seed_individuals)]
                pop.append(_mk(g, seeded.astype(np.int32), cfg.k, cfg.Lmax))
                continue
            s = int(rng.integers(1 << 30))
            lab = greedy_growing(g, cfg.k, cfg.Lmax, seed=s)
            lab = sclap_numpy(
                g, lab, U=cfg.Lmax, iters=cfg.refine_iters, seed=s,
                refine_mode=True, num_labels=cfg.k,
            ).labels
            lab = fm_refine(g, lab, cfg.k, cfg.Lmax, seed=s + 1)
            lab = repair_balance(g, lab, cfg.k, cfg.Lmax, seed=s)
            pop.append(_mk(g, lab, cfg.k, cfg.Lmax))
        islands.append(pop)

    for gen in range(cfg.generations):
        for pop in islands:
            if rng.random() < 0.7 and len(pop) >= 2:
                i, j = rng.choice(len(pop), size=2, replace=False)
                child = _combine(g, pop[i], pop[j], cfg, rng)
            else:
                child = _mutate(g, pop[int(rng.integers(len(pop)))], cfg, rng)
            worst = int(np.argmax([_fitness_key(x)[1] + 1e18 * _fitness_key(x)[0] for x in pop]))
            if _fitness_key(child) <= _fitness_key(pop[worst]):
                pop[worst] = child
        # gossip: global best replaces every island's worst (rumor spreading)
        best = min((ind for pop in islands for ind in pop), key=_fitness_key)
        for pop in islands:
            worst = int(np.argmax([_fitness_key(x)[1] + 1e18 * _fitness_key(x)[0] for x in pop]))
            if _fitness_key(best) < _fitness_key(pop[worst]):
                pop[worst] = best

    best = min((ind for pop in islands for ind in pop), key=_fitness_key)
    return best.labels


# --------------------------------------------------------------------------
# Batched-evolution numpy oracle
#
# The sequential (one-individual-at-a-time) reference implementation of the
# device-batched algorithm in repro.core.evo_device.  Operates on the SAME
# inputs the device path consumes — the engine's chunk pack and arc/weight
# arrays — so bit-identity is end-to-end: identical tie-break hashes,
# identical float32 operations, identical selection and gossip order.
# --------------------------------------------------------------------------


def _pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


@dataclass
class EvoInputs:
    """Host (numpy) view of everything one evolution run reads.

    Pack arrays are bucket-padded exactly as dispatched on device (padding is
    semantically inert — see graph/packing.py); arc arrays may carry trailing
    zero-weight padding.  ``nw`` and ``deg`` are arena-sized (``Ab`` slots,
    inert beyond ``n``).
    """

    nodes: np.ndarray           # (C, N) int32
    node_valid: np.ndarray      # (C, N) bool
    edge_dst: np.ndarray        # (C, E) int32
    edge_w: np.ndarray          # (C, E) float32
    edge_src_slot: np.ndarray   # (C, E) int32
    edge_valid: np.ndarray      # (C, E) bool
    num_chunks: int
    src: np.ndarray             # (>= m,) int32 arc sources (pad: node 0, w 0)
    dst: np.ndarray             # (>= m,) int32 arc heads
    ew: np.ndarray              # (>= m,) float32
    nw: np.ndarray              # (Ab,) float32, 0 beyond n
    deg: np.ndarray             # (Ab,) int32, 0 beyond n
    n: int

    @property
    def Ab(self) -> int:
        return int(self.nw.shape[0])


def _bw_np(lab, nw, k: int, Kb: int):
    """(raw, +inf-padded) block-weight vectors of one individual."""
    bw = np.zeros(Kb, np.float32)
    np.add.at(bw, lab, nw)
    bwx = np.where(np.arange(Kb) < k, bw, np.float32(np.inf)).astype(np.float32)
    return bw, bwx


def _evaluate_np(inp: EvoInputs, lab, k: int, Kb: int, Lmax) -> tuple:
    """int32 fitness key (feasibility-first, then cut; exact for integral
    weights), plus (cut, feasible)."""
    diff = lab[inp.src] != lab[inp.dst]
    cut = np.where(diff, inp.ew, np.float32(0.0)).astype(np.float32).sum(
        dtype=np.float32
    ) / np.float32(2.0)
    _, bwx = _bw_np(lab, inp.nw, k, Kb)
    bwmax = np.max(np.where(np.arange(Kb) < k, bwx, np.float32(-np.inf)))
    feas = bool(bwmax <= np.float32(Lmax) + np.float32(1e-6))
    key = int(np.int32(cut)) + (0 if feas else INFEAS_PENALTY)
    return key, float(cut), feas


def _greedy_grow_np(inp: EvoInputs, s: int, seed: int, k: int, Kb: int, Lmax):
    """Batched greedy growing, one individual: hash-scored degree-biased
    seeds, degree/diameter-proportional synchronous frontier rounds
    (:func:`grow_rounds_bound`), round-robin leftovers."""
    n, Ab = inp.n, inp.Ab
    iota = np.arange(Ab, dtype=np.int32)
    kio = np.arange(Kb, dtype=np.int32)
    unit = hash_unit_np(hash_base_u32(seed, 0, TAG_SEEDKEY), iota, np.int32(s))
    skey = np.where(
        iota < n,
        unit * (inp.deg.astype(np.float32) + np.float32(1.0)),
        np.float32(-np.inf),
    ).astype(np.float32)
    order = np.argsort(-skey, kind="stable")
    rank = np.zeros(Ab, np.int32)
    rank[order] = iota
    lab = np.where((rank < k) & (iota < n), rank, np.int32(-1)).astype(np.int32)
    rounds = grow_rounds_bound(n, k, int(inp.deg[:n].sum()))
    prev_cnt = None
    for r in range(rounds):
        unas = (lab < 0) & (iota < n)
        cnt = int(unas.sum())
        if cnt == 0 or cnt == prev_cnt:
            break  # converged / stalled: further rounds are no-ops (the
            # device while_loop exits on exactly these conditions)
        prev_cnt = cnt
        conn = np.zeros((Ab, Kb), np.float32)
        tgt = lab[inp.dst]
        mask = tgt >= 0
        np.add.at(conn, (inp.src[mask], tgt[mask]), inp.ew[mask])
        asg = lab >= 0
        bw = np.zeros(Kb, np.float32)
        np.add.at(bw, lab[asg], inp.nw[asg])
        bwx = np.where(kio < k, bw, np.float32(np.inf)).astype(np.float32)
        base_r = int(
            hash_u32_np(hash_base_u32(seed, r, TAG_GROW), np.int32(s), np.int32(0))
        )
        jit = hash_jitter_np(base_r, iota[:, None], kio[None, :])
        fits = bwx[None, :] + inp.nw[:, None] <= np.float32(Lmax)
        elig = (conn > 0) & fits
        score = np.where(elig, conn + jit, np.float32(-1e30)).astype(np.float32)
        b = np.argmax(score, axis=1).astype(np.int32)
        has = score[iota, b] > np.float32(-5e29)
        lab = np.where(unas & has, b, lab).astype(np.int32)
    unas = (lab < 0) & (iota < n)
    pos = np.cumsum(unas.astype(np.int32), dtype=np.int64).astype(np.int32) - 1
    lab = np.where(unas, pos % np.int32(k), lab)
    return np.where(iota < n, lab, np.int32(k)).astype(np.int32)


def _repair_rounds_np(inp: EvoInputs, lab, ctx: int, phase: int, seed: int,
                      k: int, Kb: int, Lmax):
    """REPAIR_ROUNDS synchronous feasibility-repair rounds: overloaded blocks
    shed (in expectation) their excess into the globally lightest block."""
    n, Ab = inp.n, inp.Ab
    iota = np.arange(Ab, dtype=np.int32)
    for r in range(REPAIR_ROUNDS):
        _, bwx = _bw_np(lab, inp.nw, k, Kb)
        if not (bwx[:k] > np.float32(Lmax)).any():
            break  # further device rounds are no-ops
        tgt = np.int32(np.argmin(bwx))
        with np.errstate(invalid="ignore"):
            excess = np.clip(
                (bwx - np.float32(Lmax)) / np.maximum(bwx, np.float32(1.0)),
                np.float32(0.0), np.float32(1.0),
            )
        base_r = int(
            hash_u32_np(
                hash_base_u32(seed, phase, TAG_REPAIR), np.int32(ctx), np.int32(r)
            )
        )
        u = hash_unit_np(base_r, iota, np.int32(0))
        over = bwx > np.float32(Lmax)
        movable = (
            (iota < n)
            & over[np.minimum(lab, k)]
            & (lab != tgt)
            & (bwx[tgt] + inp.nw <= np.float32(Lmax))
        )
        with np.errstate(invalid="ignore"):
            gate = u < np.float32(1.5) * excess[np.minimum(lab, k)]
        lab = np.where(movable & gate, tgt, lab).astype(np.int32)
    return lab


def _mutate_init_np(inp: EvoInputs, lab, i: int, gen: int, seed: int, k: int):
    """Boundary perturbation: flip a hash-chosen eighth of boundary nodes."""
    n, Ab = inp.n, inp.Ab
    iota = np.arange(Ab, dtype=np.int32)
    bnd = np.zeros(Ab, bool)
    np.logical_or.at(bnd, inp.src, lab[inp.src] != lab[inp.dst])
    u = hash_unit_np(
        int(hash_u32_np(hash_base_u32(seed, gen + 1, TAG_MUT_FLIP),
                        np.int32(i), np.int32(0))),
        iota, np.int32(0),
    )
    newl = (
        hash_u32_np(
            int(hash_u32_np(hash_base_u32(seed, gen + 1, TAG_MUT_LBL),
                            np.int32(i), np.int32(0))),
            iota, np.int32(0),
        ) % np.uint32(k)
    ).astype(np.int32)
    flip = bnd & (u < np.float32(MUTATE_FRAC)) & (iota < n)
    return np.where(flip, newl, lab).astype(np.int32)


def _combine_init_np(inp: EvoInputs, lab1, lab2, lab_better, i: int, gen: int,
                     seed: int, k: int, Kb: int, Lmax):
    """Overlay-cell combine: cells = contiguous ids of ``(P1(v), P2(v))``
    (packed-key relabel, np.unique semantics), child seeded from the better
    parent, then CELL_ROUNDS synchronous cell-granular block moves — the
    quotient-level refinement without materializing a quotient graph."""
    n, Ab = inp.n, inp.Ab
    iota = np.arange(Ab, dtype=np.int32)
    kio = np.arange(Kb, dtype=np.int32)
    ov = lab1.astype(np.int64) * k + lab2
    _, cells = np.unique(ov[:n], return_inverse=True)
    cf = np.full(Ab, Ab - 1, np.int32)          # sentinel cell for pad slots
    cf[:n] = cells.astype(np.int32)
    blk_raw = np.full(Ab, -1, np.int32)
    np.maximum.at(blk_raw, cf, np.where(iota < n, lab_better, np.int32(-1)))
    blk = np.where(blk_raw >= 0, blk_raw, np.int32(k)).astype(np.int32)
    cw = np.zeros(Ab, np.float32)
    np.add.at(cw, cf, inp.nw)
    cu = cf[inp.src]
    cv = cf[inp.dst]
    mask = cu != cv
    for r in range(CELL_ROUNDS):
        bw = np.zeros(Kb, np.float32)
        np.add.at(bw, blk, cw)
        bwx = np.where(kio < k, bw, np.float32(np.inf)).astype(np.float32)
        conn = np.zeros((Ab, Kb), np.float32)
        np.add.at(conn, (cu[mask], blk[cv[mask]]), inp.ew[mask])
        own = conn[iota, np.minimum(blk, Kb - 1)]
        jit = hash_jitter_np(
            int(hash_u32_np(hash_base_u32(seed, gen + 1, TAG_CELL),
                            np.int32(i), np.int32(r))),
            iota[:, None], kio[None, :],
        )
        fits = bwx[None, :] + cw[:, None] <= np.float32(Lmax)
        elig = fits & (kio[None, :] != blk[:, None]) & (conn > own[:, None])
        score = np.where(elig, conn + jit, np.float32(-1e30)).astype(np.float32)
        b = np.argmax(score, axis=1).astype(np.int32)
        has = score[iota, b] > np.float32(-5e29)
        u = hash_unit_np(
            int(hash_u32_np(hash_base_u32(seed, gen + 1, TAG_CELL_GATE),
                            np.int32(i), np.int32(r))),
            iota, np.int32(0),
        )
        blk = np.where(has & (u < np.float32(0.5)), b, blk).astype(np.int32)
    return np.where(iota < n, blk[cf], np.int32(k)).astype(np.int32)


def _refine_np(inp: EvoInputs, lab, ctx: int, phase: int, seed: int,
               refine_iters: int, k: int, Kb: int, Lmax):
    """LP chunk sweep + gain rounds + repair rounds (one individual)."""
    sw = int(
        hash_u32_np(hash_base_u32(seed, phase, TAG_SWEEP), np.int32(ctx),
                    np.int32(0))
    ) & 0x7FFFFFFF
    bw = np.zeros(Kb, np.float32)
    np.add.at(bw, lab, inp.nw)
    weights = np.where(
        np.arange(Kb) < k, bw, np.float32(np.inf)
    ).astype(np.float32)
    lab, _ = sweep_refine_numpy(
        inp.nodes, inp.node_valid, inp.edge_dst, inp.edge_w,
        inp.edge_src_slot, inp.edge_valid,
        lab, weights, inp.nw, Lmax, sw, k, inp.num_chunks, refine_iters,
    )
    for r in range(GAIN_ROUNDS):
        base_s = int(
            hash_u32_np(hash_base_u32(seed, phase, TAG_GAIN), np.int32(ctx),
                        np.int32(r))
        )
        base_g = int(
            hash_u32_np(hash_base_u32(seed, phase, TAG_GAIN_GATE),
                        np.int32(ctx), np.int32(r))
        )
        lab = gain_round_np(
            inp.src, inp.dst, inp.ew, inp.nw, lab, inp.n, k, Kb, Lmax,
            base_s, base_g,
        )
    return _repair_rounds_np(inp, lab, ctx, phase, seed, k, Kb, Lmax)


def _worst_member_np(keys, i: int, P: int) -> int:
    """Max fitness key, first index — the replacement victim of island i."""
    return int(np.argmax(np.asarray(keys[i * P:(i + 1) * P])))


def evolve_batched_numpy(
    inp: EvoInputs, cfg: EvoConfig, trace: Optional[list] = None
) -> np.ndarray:
    """Sequential numpy oracle of the batched island GA (device spec twin).

    Returns the best partition (length ``n``) of the coarsest graph.  With
    ``trace`` given, appends ``(gen, island, base_key, child_key)`` per
    offspring *before* elitism — the offspring-never-worse-than-better-parent
    property is then ``min(child_key, base_key) <= base_key`` post-elitism,
    asserted in tests.
    """
    k, Lmax = cfg.k, np.float32(cfg.Lmax)
    Kb = _pow2(k + 1)
    I, P, G = cfg.islands, cfg.pop_per_island, cfg.generations
    seed = int(cfg.seed) & 0x7FFFFFFF  # same masking as the device dispatch
    n, Ab = inp.n, inp.Ab
    labs: List[np.ndarray] = []
    keys: List[int] = []
    for s in range(I * P):
        isl, j = divmod(s, P)
        if cfg.seed_individuals and j == 0:
            lab = np.full(Ab, k, np.int32)
            lab[:n] = np.asarray(
                cfg.seed_individuals[isl % len(cfg.seed_individuals)][:n],
                dtype=np.int32,
            )
        else:
            lab = _greedy_grow_np(inp, s, seed, k, Kb, Lmax)
            lab = _refine_np(inp, lab, s, 0, seed, cfg.refine_iters, k, Kb, Lmax)
        labs.append(lab)
        keys.append(_evaluate_np(inp, lab, k, Kb, Lmax)[0])
    for gen in range(G):
        children = []
        for i in range(I):
            u_op = float(
                hash_unit_np(hash_base_u32(seed, gen + 1, TAG_OP),
                             np.int32(i), np.int32(0))
            )
            r1 = int(
                hash_u32_np(hash_base_u32(seed, gen + 1, TAG_P1),
                            np.int32(i), np.int32(0)) % np.uint32(P)
            )
            if P >= 2 and u_op < float(np.float32(COMBINE_PROB)):
                off = 1 + int(
                    hash_u32_np(hash_base_u32(seed, gen + 1, TAG_P2),
                                np.int32(i), np.int32(0))
                    % np.uint32(max(P - 1, 1))
                )
                p1, p2 = i * P + r1, i * P + (r1 + off) % P
                base_idx = p1 if keys[p1] <= keys[p2] else p2
                init = _combine_init_np(
                    inp, labs[p1], labs[p2], labs[base_idx], i, gen, seed,
                    k, Kb, Lmax,
                )
            else:
                base_idx = i * P + r1
                init = _mutate_init_np(inp, labs[base_idx], i, gen, seed, k)
            child = _refine_np(
                inp, init, i, gen + 1, seed, cfg.refine_iters, k, Kb, Lmax
            )
            ckey = _evaluate_np(inp, child, k, Kb, Lmax)[0]
            if trace is not None:
                trace.append((gen, i, keys[base_idx], ckey))
            if not ckey <= keys[base_idx]:      # elitism: never worse than
                child, ckey = labs[base_idx].copy(), keys[base_idx]  # baseline
            children.append((i, child, ckey))
        for i, child, ckey in children:        # synchronous replacement
            wi = i * P + _worst_member_np(keys, i, P)
            if ckey <= keys[wi]:
                labs[wi], keys[wi] = child, ckey
        b = int(np.argmin(np.asarray(keys)))   # gossip: global best
        for i in range(I):                     # replaces each island's worst
            wi = i * P + _worst_member_np(keys, i, P)
            if keys[b] < keys[wi]:
                labs[wi], keys[wi] = labs[b].copy(), keys[b]
    return labs[int(np.argmin(np.asarray(keys)))][:n].copy()
