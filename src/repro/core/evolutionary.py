"""Coarse-grained distributed evolutionary algorithm (KaFFPaE, §II-C/IV-E).

Island model: every "PE" (island) keeps its own population of partitions of
the (replicated) coarsest graph and performs combine/mutation operations on
it; from time to time the best local individual is sent to other islands
(randomized rumor spreading -> here: synchronous gossip each epoch, the
bulk-synchronous TPU equivalent, see DESIGN.md §2).

The combine operator follows the paper precisely:

1. both parents' *cut edges are protected from contraction*: SCLaP
   clustering is restricted to the overlay cells ``(P1(v), P2(v))`` so each
   cluster is a subset of one block of *both* parents;
2. the better parent is applied to the coarsest graph as initial partition
   (consistent because clusters never straddle a parent block);
3. refinement never worsens it (local search + final elitism), so the
   offspring is at least as good as the better parent.

The coarsest graph is small (<= coarsest_factor * k nodes) and replicated,
so this module is host/numpy orchestration calling the sequential SCLaP —
the same choice the paper makes (KaFFPaE runs a *sequential* multilevel
partitioner per PE; parallelism is across the population).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np

from ..graph.csr import GraphNP
from .contraction import contract, project_labels
from .fm import fm_refine
from .initial_partition import greedy_growing, repair_balance
from .label_propagation import sclap_numpy
from .metrics import block_weights_np, cut_np

__all__ = ["EvoConfig", "evolve"]


@dataclass
class EvoConfig:
    k: int
    Lmax: float
    islands: int = 4            # simulated PEs
    pop_per_island: int = 3
    generations: int = 6
    refine_iters: int = 6
    cluster_iters: int = 2
    f_range: tuple = (10.0, 25.0)
    seed: int = 0
    seed_individuals: List[np.ndarray] = field(default_factory=list)


@dataclass
class _Ind:
    labels: np.ndarray
    cut: float
    feasible: bool


def _fitness_key(ind: _Ind):
    # feasible individuals always beat infeasible ones; then smaller cut
    return (0 if ind.feasible else 1, ind.cut)


def _mk(g: GraphNP, labels: np.ndarray, k: int, Lmax: float) -> _Ind:
    bw = block_weights_np(g, labels, k)
    return _Ind(labels=labels, cut=cut_np(g, labels), feasible=bool(bw.max() <= Lmax + 1e-6))


def _combine(
    g: GraphNP, p1: _Ind, p2: _Ind, cfg: EvoConfig, rng: np.random.Generator
) -> _Ind:
    k, Lmax = cfg.k, cfg.Lmax
    better, other = (p1, p2) if _fitness_key(p1) <= _fitness_key(p2) else (p2, p1)
    overlay = p1.labels.astype(np.int64) * k + p2.labels.astype(np.int64)
    f = rng.uniform(*cfg.f_range)
    U = max(g.nw.max(), Lmax / f)
    seed = int(rng.integers(1 << 30))
    clus = sclap_numpy(
        g,
        np.arange(g.n),
        U=U,
        iters=cfg.cluster_iters,
        seed=seed,
        restrict=overlay,
    ).labels
    coarse, C = contract(g, clus)
    # apply the better parent: every cluster lies inside one of its blocks
    rep = np.zeros(coarse.n, dtype=np.int64)
    rep[C] = np.arange(g.n)  # any representative fine node per coarse node
    lab_c = better.labels[rep].astype(np.int32)
    lab_c = sclap_numpy(
        coarse, lab_c, U=Lmax, iters=cfg.refine_iters, seed=seed + 1,
        refine_mode=True, num_labels=k,
    ).labels
    child = project_labels(lab_c, C)
    child = sclap_numpy(
        g, child, U=Lmax, iters=cfg.refine_iters, seed=seed + 2,
        refine_mode=True, num_labels=k,
    ).labels
    child = fm_refine(g, child, k, Lmax, seed=seed + 3)
    child = repair_balance(g, child, k, Lmax, seed=seed)
    ind = _mk(g, child, k, Lmax)
    return ind if _fitness_key(ind) <= _fitness_key(better) else better


def _mutate(g: GraphNP, p: _Ind, cfg: EvoConfig, rng: np.random.Generator) -> _Ind:
    """Perturb a boundary region, then refine (a V-cycle-flavoured mutation)."""
    k, Lmax = cfg.k, cfg.Lmax
    labels = p.labels.copy()
    src = g.arc_sources()
    boundary = np.unique(src[labels[src] != labels[g.indices]])
    if boundary.size:
        take = rng.choice(boundary, size=max(1, boundary.size // 8), replace=False)
        labels[take] = rng.integers(0, k, take.shape[0])
    seed = int(rng.integers(1 << 30))
    labels = sclap_numpy(
        g, labels, U=Lmax, iters=cfg.refine_iters, seed=seed,
        refine_mode=True, num_labels=k,
    ).labels
    labels = fm_refine(g, labels, k, Lmax, seed=seed + 1)
    labels = repair_balance(g, labels, k, Lmax, seed=seed)
    ind = _mk(g, labels, k, Lmax)
    return ind if _fitness_key(ind) <= _fitness_key(p) else p


def evolve(g: GraphNP, cfg: EvoConfig) -> np.ndarray:
    """Run the island GA; returns the best partition of the coarsest graph."""
    rng = np.random.default_rng(cfg.seed)
    islands: List[List[_Ind]] = []
    for isl in range(cfg.islands):
        pop: List[_Ind] = []
        for j in range(cfg.pop_per_island):
            if cfg.seed_individuals and j == 0:
                # V-cycle seeding: the previous solution joins every island
                seeded = cfg.seed_individuals[isl % len(cfg.seed_individuals)]
                pop.append(_mk(g, seeded.astype(np.int32), cfg.k, cfg.Lmax))
                continue
            s = int(rng.integers(1 << 30))
            lab = greedy_growing(g, cfg.k, cfg.Lmax, seed=s)
            lab = sclap_numpy(
                g, lab, U=cfg.Lmax, iters=cfg.refine_iters, seed=s,
                refine_mode=True, num_labels=cfg.k,
            ).labels
            lab = fm_refine(g, lab, cfg.k, cfg.Lmax, seed=s + 1)
            lab = repair_balance(g, lab, cfg.k, cfg.Lmax, seed=s)
            pop.append(_mk(g, lab, cfg.k, cfg.Lmax))
        islands.append(pop)

    for gen in range(cfg.generations):
        for pop in islands:
            if rng.random() < 0.7 and len(pop) >= 2:
                i, j = rng.choice(len(pop), size=2, replace=False)
                child = _combine(g, pop[i], pop[j], cfg, rng)
            else:
                child = _mutate(g, pop[int(rng.integers(len(pop)))], cfg, rng)
            worst = int(np.argmax([_fitness_key(x)[1] + 1e18 * _fitness_key(x)[0] for x in pop]))
            if _fitness_key(child) <= _fitness_key(pop[worst]):
                pop[worst] = child
        # gossip: global best replaces every island's worst (rumor spreading)
        best = min((ind for pop in islands for ind in pop), key=_fitness_key)
        for pop in islands:
            worst = int(np.argmax([_fitness_key(x)[1] + 1e18 * _fitness_key(x)[0] for x in pop]))
            if _fitness_key(best) < _fitness_key(pop[worst]):
                pop[worst] = best

    best = min((ind for pop in islands for ind in pop), key=_fitness_key)
    return best.labels
