"""Device-resident LP engine: pack caching, shape bucketing, sweep dispatch.

The multilevel driver (``repro.core.multilevel``) used to derive fresh chunk
shapes from every level's exact ``(n, m)`` and re-jit ``_lp_sweep`` at every
level of every V-cycle, repacking and re-uploading the graph for each
``lp_cluster``/``lp_refine`` call.  :class:`LPEngine` owns all of that state
for one ``partition()`` run instead:

* **Shape bucketing** — chunk geometry is frozen from the finest graph and
  every level's :class:`~repro.graph.packing.ChunkPack` is padded
  (:func:`~repro.graph.packing.pad_pack`) up to shared power-of-two buckets
  ``(C, N, E)``; label/weight arrays live in a power-of-two *arena*
  ``A >= n_finest + 1``.  Combined with the sweep's traced ``num_labels`` /
  ``num_chunks`` scalars, one compiled executable per
  ``(iters, mode, restrict)`` combination serves the whole hierarchy —
  compile count is ``O(#buckets)``, not ``O(#levels x #cycles)``.
* **Pack caching** — packs, ELL packs, and per-graph device arrays (arena
  node weights, cluster weight bases, arc endpoints for cut evaluation) are
  cached per ``(graph, order-mode)`` and uploaded once.  The finest graph is
  identical across V-cycles, so cycles 2..N reuse cycle-1 packs; traversal
  is re-randomized by permuting chunk visit order *on device* (see
  ``_lp_sweep``), not by repacking on host.
* **Device-resident refinement** — ``refine``/``refine_dense`` take and
  return arena-sized device label arrays; projection through the hierarchy
  (``project``), cut evaluation (``cut``) and block weights
  (``block_weights``) all run on device, so uncoarsening never round-trips
  labels through numpy between levels.
* **Dense fast path** — ``refine_dense`` iterates the Pallas-backed
  synchronous round (``repro.kernels.lp_score.dense_round_device``) on a
  cached ELL pack: one kernel launch per iteration instead of a sequential
  chunk walk.

Engine state is per-``partition()``-run; it is not thread-safe and holds
strong references to every level's graph until released.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from ..graph.csr import GraphNP
from ..graph.packing import chunk_geometry, ell_pack, pack_chunks, pad_pack
from .label_propagation import _lp_sweep, make_order

__all__ = ["LPEngine", "EngineStats"]


def _pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


@dataclass
class _DevicePack:
    """A chunk pack padded to bucket shape, uploaded once."""

    graph: GraphNP          # strong ref: pins id(graph) for cache identity
    nodes: jax.Array
    node_valid: jax.Array
    edge_dst: jax.Array
    edge_w: jax.Array
    edge_src_slot: jax.Array
    edge_valid: jax.Array
    num_chunks: int         # live chunks (<= padded C)
    shape: Tuple[int, int, int]


@dataclass
class _Arena:
    """Per-graph device arrays shared by every sweep over that graph."""

    graph: GraphNP
    nw_arena: jax.Array     # (A,) f32 — node weights, 0 beyond n
    cluster_w: jax.Array    # (A,) f32 — per-node weights, +inf beyond n
    src: jax.Array          # (m,) int32 — arc sources (for cut/guard)
    dst: jax.Array          # (m,) int32
    ew: jax.Array           # (m,) f32


@dataclass
class _DeviceEll:
    graph: GraphNP
    dst: jax.Array
    w: jax.Array
    row_node: jax.Array
    nw: jax.Array           # (n,) f32


@dataclass
class EngineStats:
    """Counters surfaced through ``PartitionReport.engine_stats``."""

    sweep_calls: int = 0
    sweep_compiles: int = 0         # distinct (bucket, statics) combinations
    pack_builds: int = 0
    pack_hits: int = 0
    dense_rounds: int = 0
    buckets: set = field(default_factory=set)   # distinct (C, N, E, A, W)

    @property
    def bucket_count(self) -> int:
        return len(self.buckets)


class LPEngine:
    """Owns packing, caching, and sweep dispatch for one multilevel run."""

    def __init__(
        self,
        g0: GraphNP,
        *,
        target_chunks: int = 64,
        seed: int = 0,
        use_pallas: bool = True,
        interpret: Optional[bool] = None,
        pack_block: int = 8,
    ):
        n0, m0 = g0.n, g0.m
        # Small packing mini-blocks keep the max block-degree-sum (which
        # forces the per-chunk edge capacity) low on coarse power-law levels,
        # so levels rarely overflow the shared E bucket.
        self.pack_block = int(pack_block)
        # Chunk geometry frozen from the finest level (same request floors
        # the driver used to recompute per level).  N is rounded to a power
        # of two; the shared edge bucket E_floor is *learned* from the first
        # pack actually built (the finest, hottest level), so the hot level
        # pays near-zero edge-axis padding and coarser levels pad up into
        # its bucket.
        n_req, e_req = chunk_geometry(n0, m0, target_chunks)
        self.N = _pow2(n_req)
        self._e_request = e_req
        self.E_floor = 0
        self._g0_id = id(g0)
        self.A = _pow2(n0 + 1)              # label/weight arena size
        self.C_bucket = 8                   # grows to the finest pack's C
        self.seed = int(seed)
        self.use_pallas = bool(use_pallas)
        self.interpret = (
            (jax.default_backend() != "tpu") if interpret is None else bool(interpret)
        )
        self.stats = EngineStats()
        self._packs: Dict[Tuple[int, str], _DevicePack] = {}
        self._arenas: Dict[int, _Arena] = {}
        self._ells: Dict[int, _DeviceEll] = {}
        self._iota_cache: Optional[jax.Array] = None  # lazy: dist path may never sweep
        self._compile_keys = set()

    @property
    def _iota(self) -> jax.Array:
        if self._iota_cache is None:
            self._iota_cache = jnp.arange(self.A, dtype=jnp.int32)
        return self._iota_cache

    # ------------------------------------------------------------------ caches

    def _arena(self, g: GraphNP) -> _Arena:
        hit = self._arenas.get(id(g))
        if hit is not None and hit.graph is g:
            return hit
        n = g.n
        nw = np.zeros(self.A, np.float32)
        nw[:n] = g.nw
        cw = np.full(self.A, np.inf, np.float32)
        cw[:n] = g.nw
        ar = _Arena(
            graph=g,
            nw_arena=jnp.asarray(nw),
            cluster_w=jnp.asarray(cw),
            src=jnp.asarray(g.arc_sources(), dtype=jnp.int32),
            dst=jnp.asarray(g.indices, dtype=jnp.int32),
            ew=jnp.asarray(g.ew, dtype=jnp.float32),
        )
        self._arenas[id(g)] = ar
        return ar

    def _pack(self, g: GraphNP, mode: str) -> _DevicePack:
        key = (id(g), mode)
        hit = self._packs.get(key)
        if hit is not None and hit.graph is g:
            self.stats.pack_hits += 1
            return hit
        self.stats.pack_builds += 1
        order = make_order(g, mode, self.seed)
        pack = pack_chunks(
            g, order, max_nodes=self.N,
            max_edges=max(self._e_request, self.E_floor),
            block=self.pack_block,
        )
        C, N = pack.nodes.shape
        E = pack.edge_dst.shape[1]
        # Bucket up: N is bounded by the frozen geometry; E only exceeds the
        # floor when a level's max block-degree-sum does (rare; power-law
        # hubs on coarse levels), C only grows at the finest level.
        self.C_bucket = max(self.C_bucket, _pow2(C))
        # E snaps to 512-arc multiples, not powers of two: a pack just past
        # the current bucket (one hub-heavy block) would otherwise pay a ~2x
        # sort-width tax on every chunk.  The raise is sticky, so later
        # levels (and the next V-cycle) land in the same bucket instead of
        # re-compiling.
        Eb = max(self.E_floor, -(-E // 512) * 512)
        self.E_floor = Eb
        padded = pad_pack(pack, self.C_bucket, self.N, Eb)
        dp = _DevicePack(
            graph=g,
            nodes=jnp.asarray(padded.nodes),
            node_valid=jnp.asarray(padded.node_valid),
            edge_dst=jnp.asarray(padded.edge_dst),
            edge_w=jnp.asarray(padded.edge_w),
            edge_src_slot=jnp.asarray(padded.edge_src_slot),
            edge_valid=jnp.asarray(padded.edge_valid),
            num_chunks=pack.num_chunks,
            shape=(self.C_bucket, self.N, Eb),
        )
        self._packs[key] = dp
        return dp

    def _ell(self, g: GraphNP) -> _DeviceEll:
        hit = self._ells.get(id(g))
        if hit is not None and hit.graph is g:
            self.stats.pack_hits += 1
            return hit
        self.stats.pack_builds += 1
        ell = ell_pack(g)
        de = _DeviceEll(
            graph=g,
            dst=jnp.asarray(ell.dst),
            w=jnp.asarray(ell.w),
            row_node=jnp.asarray(ell.row_node),
            nw=jnp.asarray(g.nw, dtype=jnp.float32),
        )
        self._ells[id(g)] = de
        return de

    def _drop_single_use(self, g: GraphNP, mode: str) -> None:
        """Release a coarse level's pack right after its one use.

        Only the finest graph's packs are ever re-hit (V-cycles 2..N reuse
        them; coarse graphs are rebuilt every cycle), and every cached pack
        is padded to the finest bucket shape — so keeping a coarse pack
        around would cost O(finest pack) device memory per level for zero
        reuse.  Arenas (O(graph)) stay until cycle-end ``evict``: the same
        level's refine/guard calls still need them.
        """
        if id(g) != self._g0_id:
            self._packs.pop((id(g), mode), None)

    def evict(self, keep: Tuple[GraphNP, ...] = ()) -> None:
        """Drop cached packs/arenas/ELLs for all graphs not in ``keep``.

        Coarse graphs are rebuilt fresh every V-cycle (restricted clustering
        changes the hierarchy), so their cache entries — each padded to the
        finest bucket shape — are dead weight once the cycle ends.  The
        driver calls this at the end of each cycle keeping only the finest
        graph, whose packs are the ones cycles 2..N actually reuse.
        """
        keep_ids = {id(g) for g in keep}
        self._packs = {k: v for k, v in self._packs.items() if k[0] in keep_ids}
        self._arenas = {k: v for k, v in self._arenas.items() if k in keep_ids}
        self._ells = {k: v for k, v in self._ells.items() if k in keep_ids}

    # ------------------------------------------------------------------ sweeps

    def _sweep(self, dp, labels, weights, nw_arena, restrict, U, seed, num_labels,
               *, iters, refine_mode, use_restrict, permute_chunks):
        self.stats.sweep_calls += 1
        bucket = dp.shape + (labels.shape[0], weights.shape[0])
        self.stats.buckets.add(bucket)
        ckey = bucket + (restrict.shape[0], iters, refine_mode, use_restrict,
                         permute_chunks)
        if ckey not in self._compile_keys:
            self._compile_keys.add(ckey)
            self.stats.sweep_compiles += 1
        return _lp_sweep(
            dp.nodes, dp.node_valid, dp.edge_dst, dp.edge_w, dp.edge_src_slot,
            dp.edge_valid,
            labels, weights, nw_arena, restrict,
            jnp.float32(U),
            jnp.int32(seed & 0x7FFFFFFF),
            jnp.int32(num_labels),
            jnp.int32(dp.num_chunks),
            iters=iters,
            refine_mode=refine_mode,
            use_restrict=use_restrict,
            permute_chunks=permute_chunks,
        )

    def cluster(
        self,
        g: GraphNP,
        U: float,
        iters: int,
        seed: int,
        restrict: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """SCLaP clustering for coarsening; returns host labels (contraction
        is a host step).  Degree traversal order, packs cached per graph."""
        dp = self._pack(g, "degree")
        ar = self._arena(g)
        if restrict is not None:
            r = np.full(self.A, -1, np.int32)
            r[: g.n] = restrict
            r_dev = jnp.asarray(r)
        else:
            r_dev = jnp.zeros(1, jnp.int32)
        labels, _, _ = self._sweep(
            dp, self._iota, ar.cluster_w, ar.nw_arena, r_dev, U, seed, g.n,
            iters=iters, refine_mode=False,
            use_restrict=restrict is not None, permute_chunks=False,
        )
        self._drop_single_use(g, "degree")
        return np.asarray(labels[: g.n])

    def refine(
        self,
        g: GraphNP,
        labels: Union[np.ndarray, jax.Array],
        k: int,
        U: float,
        iters: int,
        seed: int,
    ) -> jax.Array:
        """Chunked-sequential SCLaP local search; arena labels in/out (device
        arrays stay device-resident across levels)."""
        dp = self._pack(g, "random")
        ar = self._arena(g)
        lab = self.to_arena(labels, g.n, fill=k)
        # (k + 1)-sized block weights: k is constant for the whole run, so
        # this costs no extra compiles and keeps the sweep's weight updates
        # and influx gating O(k) instead of O(arena) per chunk.
        bw = jnp.zeros((k + 1,), jnp.float32).at[jnp.minimum(lab, k)].add(
            ar.nw_arena
        )
        w0 = bw.at[k].set(jnp.inf)
        lab_out, _, _ = self._sweep(
            dp, lab, w0, ar.nw_arena, jnp.zeros(1, jnp.int32), U, seed, k,
            iters=iters, refine_mode=True,
            use_restrict=False, permute_chunks=True,
        )
        self._drop_single_use(g, "random")
        return lab_out

    def refine_dense(
        self,
        g: GraphNP,
        labels: Union[np.ndarray, jax.Array],
        k: int,
        U: float,
        iters: int,
        seed: int,
        move_fraction: float = 0.5,
    ) -> jax.Array:
        """Synchronous dense refinement: ``iters`` Pallas-scored rounds on a
        cached ELL pack, labels device-resident throughout."""
        from ..kernels.lp_score.ops import dense_round_device

        de = self._ell(g)
        lab = self.to_arena(labels, g.n, fill=k)[: g.n]
        for r in range(iters):
            lab = dense_round_device(
                de.dst, de.w, de.row_node, lab, de.nw,
                jnp.float32(U),
                jnp.int32((seed + 0x9E37 * r) & 0x7FFFFFFF),
                jnp.float32(move_fraction),
                k=k, n=g.n,
                use_pallas=self.use_pallas, interpret=self.interpret,
            )
            self.stats.dense_rounds += 1
        if id(g) != self._g0_id:
            self._ells.pop(id(g), None)
        return self.to_arena(lab, g.n, fill=k)

    # --------------------------------------------------------- device helpers

    def to_arena(
        self, labels: Union[np.ndarray, jax.Array], n: int, fill: int
    ) -> jax.Array:
        """Lift labels of length >= n into an (A,) int32 arena array."""
        if isinstance(labels, jax.Array):
            lab = labels.astype(jnp.int32)
            if lab.shape[0] == self.A:
                return lab
            lab = lab[:n]
            return jnp.concatenate(
                [lab, jnp.full((self.A - n,), fill, jnp.int32)]
            )
        out = np.full(self.A, fill, np.int32)
        out[:n] = np.asarray(labels[:n], dtype=np.int32)
        return jnp.asarray(out)

    def project(
        self,
        coarse_labels: Union[np.ndarray, jax.Array],
        C: np.ndarray,
        fill: int,
    ) -> jax.Array:
        """Project coarse labels through a contraction map C (fine -> coarse)
        entirely on device; returns arena-sized fine labels."""
        n_f = C.shape[0]
        C_dev = jnp.asarray(np.asarray(C, dtype=np.int32))
        if isinstance(coarse_labels, jax.Array):
            base = coarse_labels.astype(jnp.int32)
        else:
            base = jnp.asarray(np.asarray(coarse_labels, dtype=np.int32))
        fine = base[C_dev]
        return jnp.concatenate(
            [fine, jnp.full((self.A - n_f,), fill, jnp.int32)]
        )

    def cut(self, g: GraphNP, labels: jax.Array) -> float:
        """Edge cut of arena labels, evaluated on device (one scalar sync)."""
        ar = self._arena(g)
        diff = labels[ar.src] != labels[ar.dst]
        return float(jnp.sum(jnp.where(diff, ar.ew, 0.0)) / 2.0)

    def block_weights(self, g: GraphNP, labels: jax.Array, k: int) -> np.ndarray:
        ar = self._arena(g)
        bw = jnp.zeros((k + 1,), jnp.float32).at[jnp.minimum(labels, k)].add(
            ar.nw_arena
        )
        return np.asarray(bw[:k])

    def to_host(self, labels: jax.Array, n: int) -> np.ndarray:
        return np.asarray(labels[:n])

    # ---------------------------------------------------------------- metrics

    @property
    def compile_count(self) -> int:
        """Distinct sweep (bucket, statics) combinations dispatched — each is
        one XLA compilation of ``_lp_sweep``."""
        return self.stats.sweep_compiles

    @staticmethod
    def jit_cache_size() -> Optional[int]:
        """Size of the jit cache of ``_lp_sweep`` itself, when available."""
        try:
            return int(_lp_sweep._cache_size())
        except Exception:
            return None

    def stats_dict(self) -> dict:
        return dict(
            sweep_calls=self.stats.sweep_calls,
            sweep_compiles=self.stats.sweep_compiles,
            bucket_count=self.stats.bucket_count,
            pack_builds=self.stats.pack_builds,
            pack_hits=self.stats.pack_hits,
            dense_rounds=self.stats.dense_rounds,
            arena=self.A,
            chunk_bucket=(self.C_bucket, self.N, self.E_floor),
        )
