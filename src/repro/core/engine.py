"""Device-resident LP engine: pack caching, shape bucketing, sweep dispatch.

The multilevel driver (``repro.core.multilevel``) used to derive fresh chunk
shapes from every level's exact ``(n, m)`` and re-jit ``_lp_sweep`` at every
level of every V-cycle, repacking and re-uploading the graph for each
``lp_cluster``/``lp_refine`` call.  :class:`LPEngine` owns all of that state
for one ``partition()`` run instead:

* **Shape bucketing** — chunk geometry is frozen from the finest graph and
  every level's :class:`~repro.graph.packing.ChunkPack` is padded
  (:func:`~repro.graph.packing.pad_pack`) up to shared power-of-two buckets
  ``(C, N, E)``; label/weight arrays live in a power-of-two *arena*
  ``A >= n_finest + 1``.  Combined with the sweep's traced ``num_labels`` /
  ``num_chunks`` scalars, one compiled executable per
  ``(iters, mode, restrict)`` combination serves the whole hierarchy —
  compile count is ``O(#buckets)``, not ``O(#levels x #cycles)``.
* **Pack caching** — packs, ELL packs, and per-graph device arrays (arena
  node weights, cluster weight bases, arc endpoints for cut evaluation) are
  cached per ``(graph, order-mode)`` and uploaded once.  The finest graph is
  identical across V-cycles, so cycles 2..N reuse cycle-1 packs; traversal
  is re-randomized by permuting chunk visit order *on device* (see
  ``_lp_sweep``), not by repacking on host.
* **Device-resident refinement** — ``refine``/``refine_dense`` take and
  return arena-sized device label arrays; projection through the hierarchy
  (``project``), cut evaluation (``cut``) and block weights
  (``block_weights``) all run on device, so uncoarsening never round-trips
  labels through numpy between levels.
* **Dense fast path** — ``refine_dense`` iterates the Pallas-backed
  synchronous round (``repro.kernels.lp_score.dense_round_device``) on a
  cached ELL pack: one kernel launch per iteration instead of a sequential
  chunk walk.  ELL packs are padded to power-of-two row/node buckets so the
  dense round also compiles once per bucket, not once per level.
* **Device-resident coarsening** — ``contract`` runs the whole §IV-C
  quotient-graph construction on device (``contract_device``): relabel,
  node-weight segment-sum, arc dedup, and CSR rebuild in one bucketed
  executable.  The coarse graph stays on device as a
  :class:`~repro.graph.csr.GraphDev` handle whose adjacency feeds the next
  level's pack *gather* (``gather_pack_device``) directly — only the O(n)
  chunk plan is computed on host, so ``cluster -> contract -> next-level
  pack`` chains device-to-device and only the ``(n_c, m_c, max nw)``
  scalars cross per level.

Engine state is per-``partition()``-run; it is not thread-safe and holds
strong references to every level's graph until released.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from ..graph.csr import GraphDev, GraphNP, arc_bucket, pow2
from ..graph.packing import (
    chunk_geometry,
    ell_pack,
    gather_ell_device,
    gather_pack_device,
    layout_nodes,
    pack_chunks,
    pad_pack,
    plan_chunks,
    plan_ell_rows,
    plan_region_pack,
)
from ..obs import MetricsRegistry, RegistryBackedStats
from ..obs import span as _obs_span
from ..obs import watchdog as _obs_watchdog
from ..obs.memory import account as _mem_account
from .contraction import CoarseMap, contract_device, packed_key_wbits
from .label_propagation import _lp_sweep, make_order

__all__ = ["LPEngine", "EngineStats"]

AnyGraph = Union[GraphNP, GraphDev]


# bucket policies live in graph/csr.py (shared with the dynamic store)
_pow2 = pow2
_mbucket = arc_bucket


@dataclass
class _DevicePack:
    """A chunk pack padded to bucket shape, uploaded (or gathered) once."""

    graph: AnyGraph         # strong ref: pins id(graph) for cache identity
    nodes: jax.Array
    node_valid: jax.Array
    edge_dst: jax.Array
    edge_w: jax.Array
    edge_src_slot: jax.Array
    edge_valid: jax.Array
    num_chunks: int         # live chunks (<= padded C)
    shape: Tuple[int, int, int]


@dataclass
class _Arena:
    """Per-graph device arrays shared by every sweep over that graph."""

    graph: AnyGraph
    nw_arena: jax.Array     # (A,) f32 — node weights, 0 beyond n
    cluster_w: jax.Array    # (A,) f32 — per-node weights, +inf beyond n
    src: jax.Array          # (>= m,) int32 — arc sources (padding carries w 0)
    dst: jax.Array          # (>= m,) int32
    ew: jax.Array           # (>= m,) f32


@dataclass
class _DeviceEll:
    graph: AnyGraph
    dst: jax.Array          # (Rb, W) int32 — rows padded to a pow2 bucket
    w: jax.Array            # (Rb, W) f32
    row_node: jax.Array     # (Rb,) int32, sentinel n
    nb: int                 # node bucket: pow2(n + 1) <= arena size


class EngineStats(RegistryBackedStats):
    """Counters surfaced through ``PartitionReport.engine_stats``.

    Counter fields live in a :class:`~repro.obs.MetricsRegistry` (one per
    serving stack — the dynamic session threads its registry in so
    engine + store + session share one snapshot/reset/export path);
    bucket-key sets stay real sets (tests unpack them).
    """

    _COUNTER_FIELDS = (
        "sweep_calls",
        "sweep_compiles",       # distinct (bucket, statics) combinations
        "pack_builds",
        "pack_hits",
        "dense_rounds",
        "dense_compiles",       # distinct dense-round bucket shapes
        "evo_calls",            # batched-evolution executable dispatches
        "evo_compiles",         # distinct evo (phase, bucket) shapes
        "contract_calls",
        "contract_compiles",    # distinct (Nb, Mb) contraction buckets
        "gather_builds",        # device pack gathers (GraphDev levels)
        "gather_compiles",      # distinct gather shape combinations
        "repair_calls",         # incremental-repair dispatches (dynamic)
        "repair_compiles",      # distinct repair-kernel shape buckets
        "audit_calls",          # invariant-audit dispatches (resilience)
        "audit_compiles",       # distinct audit-kernel shape buckets
        "h2d_bytes",            # host->device uploads the engine issued
        "d2h_bytes",            # device->host downloads (scalars + lazy
                                # materializations of GraphDev/CoarseMap)
    )
    _SET_FIELDS = (
        "buckets",              # distinct (C, N, E, A, W)
        "contract_buckets",     # distinct (Nb, Mb)
        "evo_buckets",          # distinct evo shape keys
        "repair_buckets",       # distinct repair shapes
        "audit_buckets",        # distinct audit shapes
    )

    @property
    def bucket_count(self) -> int:
        return len(self.buckets)

    @property
    def contract_bucket_count(self) -> int:
        return len(self.contract_buckets)

    @property
    def evo_bucket_count(self) -> int:
        return len(self.evo_buckets)

    @property
    def repair_bucket_count(self) -> int:
        return len(self.repair_buckets)

    @property
    def audit_bucket_count(self) -> int:
        return len(self.audit_buckets)

    def note_audit_key(self, key) -> None:
        """Record one audit-kernel dispatch shape (the resilience auditor's
        compile-accounting hook — same discipline as every other kernel
        family: ``audit_compiles == audit_bucket_count``)."""
        if key not in self.audit_buckets:
            self.audit_buckets.add(key)
            self.audit_compiles += 1
            _obs_watchdog().note("engine.audit", key)


class LPEngine:
    """Owns packing, caching, and sweep dispatch for one multilevel run."""

    def __init__(
        self,
        g0: AnyGraph,
        *,
        target_chunks: int = 64,
        seed: int = 0,
        use_pallas: bool = True,
        interpret: Optional[bool] = None,
        pack_block: int = 8,
        registry: Optional[MetricsRegistry] = None,
    ):
        n0, m0 = g0.n, g0.m
        # Small packing mini-blocks keep the max block-degree-sum (which
        # forces the per-chunk edge capacity) low on coarse power-law levels,
        # so levels rarely overflow the shared E bucket.
        self.pack_block = int(pack_block)
        # Chunk geometry frozen from the finest level (same request floors
        # the driver used to recompute per level).  N is rounded to a power
        # of two; the shared edge bucket E_floor is *learned* from the first
        # pack actually built (the finest, hottest level), so the hot level
        # pays near-zero edge-axis padding and coarser levels pad up into
        # its bucket.
        n_req, e_req = chunk_geometry(n0, m0, target_chunks)
        self.N = _pow2(n_req)
        self._e_request = e_req
        self.E_floor = 0
        self._g0_id = id(g0)
        # label/weight arena; floored at the GraphDev node bucket's minimum
        # (to_device_csr/contract emit Nb >= 8) so _arena's device extend
        # never sees a negative pad on tiny graphs
        self.A = _pow2(max(n0 + 1, 8))
        self.C_bucket = 8                   # grows to the finest pack's C
        self.seed = int(seed)
        self.use_pallas = bool(use_pallas)
        self.interpret = (
            (jax.default_backend() != "tpu") if interpret is None else bool(interpret)
        )
        self.stats = EngineStats(registry)
        self._packs: Dict[Tuple[int, str], _DevicePack] = {}
        self._arenas: Dict[int, _Arena] = {}
        self._ells: Dict[int, _DeviceEll] = {}
        self._cin: Dict[int, tuple] = {}    # padded contraction inputs (GraphNP)
        self._degs: Dict[int, jax.Array] = {}  # (Ab,) f32 degree arrays (evo)
        self._indptrs: Dict[int, jax.Array] = {}  # device row ptrs (GraphNP)
        self._repair_E = 0                  # sticky region-pack edge bucket
        self._iota_cache: Optional[jax.Array] = None  # lazy: dist path may never sweep
        self._compile_keys = set()
        self._gather_keys = set()
        self._dense_keys = set()
        self._exact_weights: Optional[bool] = None  # lazily scanned from g0
        self._g0 = g0
        self._shard_steps: Dict[tuple, object] = {}

    @property
    def _iota(self) -> jax.Array:
        if self._iota_cache is None:
            self._iota_cache = jnp.arange(self.A, dtype=jnp.int32)
            _mem_account("label_arenas", self._iota_cache)
        return self._iota_cache

    @staticmethod
    def will_fit(n: int, m: int, k: int, cfg=None, *, budget_bytes=None,
                 workload: str = "partition", safety: float = 1.25) -> dict:
        """Pre-upload capacity check: closed-form footprint of partitioning
        (or serving) an (n, m, k) graph vs the device budget — call BEFORE
        ``to_device_csr`` / ``partition`` (see ``repro.obs.memory``)."""
        from ..obs.memory import will_fit as _wf

        return _wf(n, m, k, cfg, budget_bytes=budget_bytes,
                   workload=workload, safety=safety)

    # ------------------------------------------------------------------ caches

    def _arena(self, g: AnyGraph) -> _Arena:
        hit = self._arenas.get(id(g))
        if hit is not None and hit.graph is g:
            return hit
        n = g.n
        if isinstance(g, GraphDev):
            # arrays are already device-resident and inert beyond (n, m):
            # nw is 0 past n, arc padding carries weight 0 — extend to the
            # arena entirely on device, no host round-trip.
            Nb = g.nw.shape[0]
            nw_arena = jnp.concatenate(
                [g.nw, jnp.zeros((self.A - Nb,), jnp.float32)]
            )
            cw = jnp.where(self._iota < n, nw_arena, jnp.inf)
            ar = _Arena(
                graph=g, nw_arena=nw_arena, cluster_w=cw,
                src=g.src, dst=g.indices, ew=g.ew,
            )
        else:
            nw = np.zeros(self.A, np.float32)
            nw[:n] = g.nw
            cw = np.full(self.A, np.inf, np.float32)
            cw[:n] = g.nw
            ar = _Arena(
                graph=g,
                nw_arena=jnp.asarray(nw),
                cluster_w=jnp.asarray(cw),
                src=jnp.asarray(g.arc_sources(), dtype=jnp.int32),
                dst=jnp.asarray(g.indices, dtype=jnp.int32),
                ew=jnp.asarray(g.ew, dtype=jnp.float32),
            )
            self.stats.h2d_bytes += self.A * 8 + g.m * 12
        # GraphDev aliases (src/dst/ew) are already owned by base_csr —
        # registration is id-idempotent, so no double count
        _mem_account("label_arenas", ar.nw_arena, ar.cluster_w)
        _mem_account("base_csr", ar.src, ar.dst, ar.ew)
        self._arenas[id(g)] = ar
        return ar

    def _pack(self, g: AnyGraph, mode: str) -> _DevicePack:
        if isinstance(g, GraphDev):
            return self._pack_dev(g, mode)
        key = (id(g), mode)
        hit = self._packs.get(key)
        if hit is not None and hit.graph is g:
            self.stats.pack_hits += 1
            return hit
        self.stats.pack_builds += 1
        with _obs_span(
            "vcycle.pack", cat="vcycle", mode=mode, n=int(g.n), host=True
        ):
            return self._pack_host_build(g, key, mode)

    def _pack_host_build(self, g: AnyGraph, key, mode: str) -> _DevicePack:
        order = make_order(g, mode, self.seed)
        pack = pack_chunks(
            g, order, max_nodes=self.N,
            max_edges=max(self._e_request, self.E_floor),
            block=self.pack_block,
        )
        C, N = pack.nodes.shape
        E = pack.edge_dst.shape[1]
        # Bucket up: N is bounded by the frozen geometry; E only exceeds the
        # floor when a level's max block-degree-sum does (rare; power-law
        # hubs on coarse levels), C only grows at the finest level.
        self.C_bucket = max(self.C_bucket, _pow2(C))
        # E snaps to 512-arc multiples, not powers of two: a pack just past
        # the current bucket (one hub-heavy block) would otherwise pay a ~2x
        # sort-width tax on every chunk.  The raise is sticky, so later
        # levels (and the next V-cycle) land in the same bucket instead of
        # re-compiling.
        Eb = max(self.E_floor, -(-E // 512) * 512)
        self.E_floor = Eb
        padded = pad_pack(pack, self.C_bucket, self.N, Eb)
        dp = _DevicePack(
            graph=g,
            nodes=jnp.asarray(padded.nodes),
            node_valid=jnp.asarray(padded.node_valid),
            edge_dst=jnp.asarray(padded.edge_dst),
            edge_w=jnp.asarray(padded.edge_w),
            edge_src_slot=jnp.asarray(padded.edge_src_slot),
            edge_valid=jnp.asarray(padded.edge_valid),
            num_chunks=pack.num_chunks,
            shape=(self.C_bucket, self.N, Eb),
        )
        self.stats.h2d_bytes += sum(
            int(np.asarray(a).nbytes) for a in
            (padded.nodes, padded.node_valid, padded.edge_dst, padded.edge_w,
             padded.edge_src_slot, padded.edge_valid)
        )
        _mem_account("chunk_packs", dp.nodes, dp.node_valid, dp.edge_dst,
                     dp.edge_w, dp.edge_src_slot, dp.edge_valid)
        self._packs[key] = dp
        return dp

    def _pack_dev(self, g: GraphDev, mode: str) -> _DevicePack:
        """Pack a device-resident coarse graph without materializing it.

        Host work is O(n): the degree sequence (cached on the handle), the
        traversal order, and the greedy chunk plan.  The O(m) edge arrays are
        gathered on device from the still-resident CSR
        (:func:`~repro.graph.packing.gather_pack_device`) — the coarse
        adjacency never crosses to host.  Emits arrays bit-identical to the
        host ``_pack`` on the materialized graph (same plan, same order).
        """
        key = (id(g), mode)
        hit = self._packs.get(key)
        if hit is not None and hit.graph is g:
            self.stats.pack_hits += 1
            return hit
        self.stats.pack_builds += 1
        self.stats.gather_builds += 1
        order = make_order(g, mode, self.seed)
        deg = g.degrees().astype(np.int64)[order]
        node_chunk, C, N, E = plan_chunks(
            deg, g.n, max_nodes=self.N,
            max_edges=max(self._e_request, self.E_floor),
            block=self.pack_block,
        )
        # same sticky bucket raising as the host path
        self.C_bucket = max(self.C_bucket, _pow2(C))
        Eb = max(self.E_floor, -(-E // 512) * 512)
        self.E_floor = Eb
        nodes, node_valid = layout_nodes(order, node_chunk, C, N, g.n)
        # Tight pow2 LIVE-chunk prefix: the sweep's fori_loop only ever
        # visits ``num_chunks`` live chunks, so dead chunks of the finest
        # level's shared bucket are pure shape padding — emitting them would
        # multiply the gather (and every sweep dispatch) by the dead/live
        # ratio.  Coarse GraphDev levels therefore get their own pow2 chunk
        # bucket; the few extra sweep shapes are reused across levels and
        # V-cycles like every other bucket.
        Cg = _pow2(C)
        nodes = np.pad(
            nodes, ((0, Cg - C), (0, self.N - N)), constant_values=g.n
        )
        node_valid = np.pad(node_valid, ((0, Cg - C), (0, self.N - N)))
        nodes_d = jnp.asarray(nodes)
        nv_d = jnp.asarray(node_valid)
        self.stats.h2d_bytes += nodes.nbytes + node_valid.nbytes
        gkey = (nodes.shape, g.indptr.shape[0], g.indices.shape[0], Eb)
        if gkey not in self._gather_keys:
            self._gather_keys.add(gkey)
            self.stats.gather_compiles += 1
            _obs_watchdog().note("engine.gather", gkey)
        with _obs_span(
            "vcycle.pack", cat="vcycle", chunks=int(C), edge_bucket=int(Eb)
        ) as sp:
            edge_dst, edge_w, edge_slot, edge_valid = gather_pack_device(
                nodes_d, nv_d, g.indptr, g.indices, g.ew, jnp.int32(g.n), E=Eb
            )
            sp.sync_on(edge_valid)
        dp = _DevicePack(
            graph=g,
            nodes=nodes_d,
            node_valid=nv_d,
            edge_dst=edge_dst,
            edge_w=edge_w,
            edge_src_slot=edge_slot,
            edge_valid=edge_valid,
            num_chunks=C,
            shape=(Cg, self.N, Eb),
        )
        _mem_account("chunk_packs", dp.nodes, dp.node_valid, dp.edge_dst,
                     dp.edge_w, dp.edge_src_slot, dp.edge_valid)
        self._packs[key] = dp
        return dp

    def _ell(self, g: AnyGraph) -> _DeviceEll:
        hit = self._ells.get(id(g))
        if hit is not None and hit.graph is g:
            self.stats.pack_hits += 1
            return hit
        self.stats.pack_builds += 1
        # Pow2 row bucket + pow2(n + 1) node bucket: with dense_round_device's
        # traced n, one compiled round serves every level in the bucket
        # instead of compiling per level (padded rows are sentinel-owned and
        # weight-0, so they contribute nothing).
        if isinstance(g, GraphDev) and g.m > 0:
            # Device ELL gather: the O(n) row plan comes from the (cached)
            # host indptr, the O(m) dst/w fill gathers from the still-
            # resident CSR — bit-identical to ``ell_pack`` on the
            # materialized graph, without the O(m) download it used to take.
            row_node, row_first, row_end = plan_ell_rows(
                g._indptr_np(), g.n
            )
            R = row_node.shape[0]
            Rb = _pow2(R)
            row_node = np.pad(row_node, (0, Rb - R), constant_values=g.n)
            row_first = np.pad(row_first, (0, Rb - R))
            row_end = np.pad(row_end, (0, Rb - R))
            rn_d = jnp.asarray(row_node)
            rf_d = jnp.asarray(row_first)
            re_d = jnp.asarray(row_end)
            self.stats.h2d_bytes += row_node.nbytes + row_first.nbytes + row_end.nbytes
            self.stats.gather_builds += 1
            gkey = ("ell", Rb, g.indices.shape[0])
            if gkey not in self._gather_keys:
                self._gather_keys.add(gkey)
                self.stats.gather_compiles += 1
                _obs_watchdog().note("engine.gather", gkey)
            dst_d, w_d = gather_ell_device(
                rf_d, re_d, g.indices, g.ew, jnp.int32(g.n)
            )
            de = _DeviceEll(
                graph=g, dst=dst_d, w=w_d, row_node=rn_d, nb=_pow2(g.n + 1)
            )
            _mem_account("chunk_packs", de.dst, de.w, de.row_node)
            self._ells[id(g)] = de
            return de
        gh = g.to_host() if isinstance(g, GraphDev) else g
        ell = ell_pack(gh)
        R = ell.rows
        Rb = _pow2(R)
        dst = np.pad(ell.dst, ((0, Rb - R), (0, 0)), constant_values=g.n)
        w = np.pad(ell.w, ((0, Rb - R), (0, 0)))
        row_node = np.pad(ell.row_node, (0, Rb - R), constant_values=g.n)
        de = _DeviceEll(
            graph=g,
            dst=jnp.asarray(dst),
            w=jnp.asarray(w),
            row_node=jnp.asarray(row_node),
            nb=_pow2(g.n + 1),
        )
        self.stats.h2d_bytes += dst.nbytes + w.nbytes + row_node.nbytes
        _mem_account("chunk_packs", de.dst, de.w, de.row_node)
        self._ells[id(g)] = de
        return de

    def _drop_single_use(self, g: GraphNP, mode: str) -> None:
        """Release a coarse level's pack right after its one use.

        Only the finest graph's packs are ever re-hit (V-cycles 2..N reuse
        them; coarse graphs are rebuilt every cycle), and every cached pack
        is padded to the finest bucket shape — so keeping a coarse pack
        around would cost O(finest pack) device memory per level for zero
        reuse.  Arenas (O(graph)) stay until cycle-end ``evict``: the same
        level's refine/guard calls still need them.
        """
        if id(g) != self._g0_id:
            self._packs.pop((id(g), mode), None)

    def carry_from(self, old: "LPEngine") -> None:
        """Adopt a predecessor engine's cumulative stats and compile-key
        sets (the dynamic session's node-growth rebuild path).  The jit
        caches are process-global, so every shape the old engine dispatched
        is still compiled — sharing the key sets (and the stats object
        itself, so transfer/counter deltas observed across the swap stay
        coherent) keeps the compile counters honest: ``compiles ==
        bucket_count`` holds across rebuilds."""
        self.stats = old.stats
        self._compile_keys = old._compile_keys
        self._gather_keys = old._gather_keys
        self._dense_keys = old._dense_keys
        self._repair_E = max(self._repair_E, old._repair_E)

    def evict(self, keep: Tuple[GraphNP, ...] = ()) -> None:
        """Drop cached packs/arenas/ELLs for all graphs not in ``keep``.

        Coarse graphs are rebuilt fresh every V-cycle (restricted clustering
        changes the hierarchy), so their cache entries — each padded to the
        finest bucket shape — are dead weight once the cycle ends.  The
        driver calls this at the end of each cycle keeping only the finest
        graph, whose packs are the ones cycles 2..N actually reuse.
        """
        keep_ids = {id(g) for g in keep}
        self._packs = {k: v for k, v in self._packs.items() if k[0] in keep_ids}
        self._arenas = {k: v for k, v in self._arenas.items() if k in keep_ids}
        self._ells = {k: v for k, v in self._ells.items() if k in keep_ids}
        self._cin = {k: v for k, v in self._cin.items() if k in keep_ids}
        self._degs = {k: v for k, v in self._degs.items() if k in keep_ids}
        self._indptrs = {k: v for k, v in self._indptrs.items() if k in keep_ids}

    # ------------------------------------------------------------------ sweeps

    def _sweep(self, dp, labels, weights, nw_arena, restrict, U, seed, num_labels,
               *, iters, refine_mode, use_restrict, permute_chunks):
        self.stats.sweep_calls += 1
        bucket = dp.shape + (labels.shape[0], weights.shape[0])
        self.stats.buckets.add(bucket)
        ckey = bucket + (restrict.shape[0], iters, refine_mode, use_restrict,
                         permute_chunks)
        if ckey not in self._compile_keys:
            self._compile_keys.add(ckey)
            self.stats.sweep_compiles += 1
            _obs_watchdog().note("engine.sweep", ckey)
        return _lp_sweep(
            dp.nodes, dp.node_valid, dp.edge_dst, dp.edge_w, dp.edge_src_slot,
            dp.edge_valid,
            labels, weights, nw_arena, restrict,
            jnp.float32(U),
            jnp.int32(seed & 0x7FFFFFFF),
            jnp.int32(num_labels),
            jnp.int32(dp.num_chunks),
            iters=iters,
            refine_mode=refine_mode,
            use_restrict=use_restrict,
            permute_chunks=permute_chunks,
        )

    def cluster(
        self,
        g: AnyGraph,
        U: float,
        iters: int,
        seed: int,
        restrict: Optional[Union[np.ndarray, jax.Array]] = None,
    ) -> jax.Array:
        """SCLaP clustering for coarsening; returns DEVICE labels (length n)
        so the device contraction can consume them without a round-trip.
        Degree traversal order, packs cached per graph; a device ``restrict``
        must already be arena-sized (``project_restrict`` output)."""
        dp = self._pack(g, "degree")
        ar = self._arena(g)
        if restrict is None:
            r_dev = jnp.zeros(1, jnp.int32)
        elif isinstance(restrict, jax.Array):
            r_dev = restrict
        else:
            r = np.full(self.A, -1, np.int32)
            r[: g.n] = restrict
            r_dev = jnp.asarray(r)
            self.stats.h2d_bytes += r.nbytes
        with _obs_span(
            "vcycle.sweep", cat="vcycle", mode="cluster", n=int(g.n),
            iters=int(iters),
        ) as sp:
            labels, _, _ = self._sweep(
                dp, self._iota, ar.cluster_w, ar.nw_arena, r_dev, U, seed,
                g.n,
                iters=iters, refine_mode=False,
                use_restrict=restrict is not None, permute_chunks=False,
            )
            sp.sync_on(labels)
        self._drop_single_use(g, "degree")
        return labels[: g.n]

    def refine(
        self,
        g: AnyGraph,
        labels: Union[np.ndarray, jax.Array],
        k: int,
        U: float,
        iters: int,
        seed: int,
    ) -> jax.Array:
        """Chunked-sequential SCLaP local search; arena labels in/out (device
        arrays stay device-resident across levels)."""
        dp = self._pack(g, "random")
        ar = self._arena(g)
        lab = self.to_arena(labels, g.n, fill=k)
        # (k + 1)-sized block weights: k is constant for the whole run, so
        # this costs no extra compiles and keeps the sweep's weight updates
        # and influx gating O(k) instead of O(arena) per chunk.
        bw = jnp.zeros((k + 1,), jnp.float32).at[jnp.minimum(lab, k)].add(
            ar.nw_arena
        )
        w0 = bw.at[k].set(jnp.inf)
        with _obs_span(
            "vcycle.sweep", cat="vcycle", mode="refine", n=int(g.n),
            iters=int(iters),
        ) as sp:
            lab_out, _, _ = self._sweep(
                dp, lab, w0, ar.nw_arena, jnp.zeros(1, jnp.int32), U, seed,
                k,
                iters=iters, refine_mode=True,
                use_restrict=False, permute_chunks=True,
            )
            sp.sync_on(lab_out)
        self._drop_single_use(g, "random")
        return lab_out

    def refine_dense(
        self,
        g: AnyGraph,
        labels: Union[np.ndarray, jax.Array],
        k: int,
        U: float,
        iters: int,
        seed: int,
        move_fraction: float = 0.5,
    ) -> jax.Array:
        """Synchronous dense refinement: ``iters`` Pallas-scored rounds on a
        cached (bucket-padded) ELL pack, labels device-resident throughout."""
        from ..kernels.lp_score.ops import dense_round_device

        de = self._ell(g)
        ar = self._arena(g)
        # bucketed node axis: arena labels/weights sliced to the pow2 node
        # bucket (slots >= n carry label k / weight 0 — inert)
        lab = self.to_arena(labels, g.n, fill=k)[: de.nb]
        nw_nb = ar.nw_arena[: de.nb]
        dkey = (de.dst.shape, de.nb, k, self.use_pallas, self.interpret)
        if dkey not in self._dense_keys:
            self._dense_keys.add(dkey)
            self.stats.dense_compiles += 1
            _obs_watchdog().note("engine.dense", dkey)
        with _obs_span(
            "vcycle.sweep", cat="vcycle", mode="dense", n=int(g.n),
            iters=int(iters),
        ) as sp:
            for r in range(iters):
                lab = dense_round_device(
                    de.dst, de.w, de.row_node, lab, nw_nb,
                    jnp.float32(U),
                    jnp.int32((seed + 0x9E37 * r) & 0x7FFFFFFF),
                    jnp.float32(move_fraction),
                    jnp.int32(g.n),
                    k=k,
                    use_pallas=self.use_pallas, interpret=self.interpret,
                )
                self.stats.dense_rounds += 1
            sp.sync_on(lab)
        if id(g) != self._g0_id:
            self._ells.pop(id(g), None)
        return self.to_arena(lab, g.n, fill=k)

    # --------------------------------------------------------------- repair

    def _indptr_dev(self, g: AnyGraph) -> jax.Array:
        """Device CSR row pointers for region gathers; GraphDev handles carry
        their own, a GraphNP uploads its (n + 1) pointer array once."""
        if isinstance(g, GraphDev):
            return g.indptr
        hit = self._indptrs.get(id(g))
        if hit is not None:
            return hit
        ip = np.asarray(g.indptr, dtype=np.int32)
        arr = jnp.asarray(ip)
        self.stats.h2d_bytes += ip.nbytes
        _mem_account("base_csr", arr)
        self._indptrs[id(g)] = arr
        return arr

    def _note_repair_key(self, key) -> None:
        if key not in self.stats.repair_buckets:
            self.stats.repair_buckets.add(key)
            self.stats.repair_compiles += 1
            _obs_watchdog().note("engine.repair", key)

    def repair(
        self,
        g: AnyGraph,
        labels: Union[np.ndarray, jax.Array],
        touched: np.ndarray,
        k: int,
        U: float,
        *,
        hops: int = 2,
        iters: int = 6,
        gain_rounds: int = 2,
        balance_rounds: int = 3,
        seed: int = 0,
        hop_degree_cap: Optional[int] = None,
        adjacency: Optional[Tuple[jax.Array, ...]] = None,
    ) -> Tuple[jax.Array, int, float, np.ndarray]:
        """Incremental size-constrained repair after a graph mutation.

        The dynamic subsystem's hot path (ISSUE 4): expand the ``hops``-hop
        affected region around the ``touched`` node ids on device, pack only
        the region's nodes into sweep chunks (host plans O(region), device
        gathers O(region edges) from the resident CSR), and run the cached
        ``_lp_sweep`` in refine mode over that pack — against **exact
        global block weights** and the true size bound ``U = L_max``, the
        paper's §III-A refinement invariants (an overloaded block's nodes
        must leave it; eligibility is measured on real weights, never
        region-local estimates).  Region-masked gain and balance-repair
        rounds (``repro.dynamic.repair``, fm.py spec twins) follow, and a
        cut/feasibility guard — the uncoarsening monotonicity guard's twin
        — keeps the repaired labels only if the cut did not worsen or
        feasibility was restored.

        ``hop_degree_cap`` bounds the region on power-law graphs: hops past
        the first only expand *through* nodes of degree <= cap, so a hub
        adjacent to the touched set joins the region but no longer drags
        its entire neighbourhood in (the ROADMAP repair-locality item).
        ``None`` or a non-positive value disables the cap (bit-identical
        to the uncapped expansion).

        ``adjacency`` (the ISSUE-8 overlay-aware path) substitutes device
        ``(indptr, src, dst, ew)`` arrays — e.g. a
        :meth:`~repro.dynamic.store.DynamicGraphStore.view` of base CSR +
        uncompacted overlay — for ``g``'s own arcs in every arc consumer
        (region expansion, pack gather, gain rounds, the guard's cuts).
        ``g`` still supplies the node set, node weights, and cache
        identity, which must describe the SAME node set as the adjacency;
        because all those consumers are insensitive to within-row arc
        order and to inert padding, repairing on a view is bit-identical
        to compacting first (regression-tested in tests/test_throughput).

        Every kernel is shape-bucketed with traced live counts, so a steady
        update stream compiles once per bucket (``repair_compiles ==
        repair_bucket_count``).  Returns ``(arena labels, region size, cut,
        block weights)`` — the guard already evaluates the returned labels'
        cut and (k,) block-weight vector, so the serving loop scores an
        update without re-running the O(m)/O(n) reductions.  Labels outside
        the region are bit-identical to the input.
        """
        from ..dynamic.repair import (
            TAG_DYN_GAIN,
            TAG_DYN_GAIN_GATE,
            balance_rounds_device,
            expand_region_device,
            gain_round_device,
        )
        from .label_propagation import hash_base_u32

        self.stats.repair_calls += 1
        n = g.n
        ar = self._arena(g)
        if adjacency is not None:
            ip, a_src, a_dst, a_ew = adjacency[:4]
        else:
            ip = self._indptr_dev(g)
            a_src, a_dst, a_ew = ar.src, ar.dst, ar.ew

        def cut_now(labels_: jax.Array) -> float:
            if adjacency is None:
                return self.cut(g, labels_)
            diff = labels_[a_src] != labels_[a_dst]
            return float(jnp.sum(jnp.where(diff, a_ew, 0.0)) / 2.0)

        lab = self.to_arena(labels, n, fill=k)
        t_ids = np.unique(np.asarray(touched, dtype=np.int64))
        t_ids = t_ids[(t_ids >= 0) & (t_ids < n)].astype(np.int32)
        if t_ids.size == 0:
            return lab, 0, cut_now(lab), self.block_weights(g, lab, k)
        # ---- h-hop affected region (device frontier expansion) ----
        Tb = _pow2(max(t_ids.size, 8))
        tpad = np.full(Tb, n, np.int32)
        tpad[: t_ids.size] = t_ids
        self.stats.h2d_bytes += tpad.nbytes
        # None and <= 0 both disable the cap (the session's "0 = off"
        # convention holds at the engine too — a literal cap of 0 would
        # silently freeze expansion at hop 1)
        cap = (0x7FFFFFFF if hop_degree_cap is None or hop_degree_cap <= 0
               else int(hop_degree_cap))
        self._note_repair_key(
            ("frontier", Tb, a_src.shape[0], ip.shape[0], self.A)
        )
        with _obs_span("repair.expand", cat="repair",
                       touched=int(t_ids.size), hops=int(hops)):
            mask = expand_region_device(
                jnp.asarray(tpad), a_src, a_dst, ip, jnp.int32(n),
                jnp.int32(hops), jnp.int32(cap), A=self.A,
            )
            mask_np = np.asarray(mask[:n])
        self.stats.d2h_bytes += mask_np.nbytes
        region = np.flatnonzero(mask_np)
        if region.size == 0:
            return lab, 0, cut_now(lab), self.block_weights(g, lab, k)
        # ---- region pack: host O(region) plan, device O(region m) gather
        order = np.random.default_rng(seed).permutation(region).astype(np.int64)
        if adjacency is not None or isinstance(g, GraphDev):
            # region degrees gathered ON device: every compaction hands
            # repair a fresh handle whose O(n) host degree cache is cold,
            # so g.degrees() here would download the full indptr per update
            # — O(region) is all the plan needs
            oi = jnp.asarray(order.astype(np.int32))
            self.stats.h2d_bytes += order.size * 4
            deg_r = np.asarray(ip[oi + 1] - ip[oi]).astype(np.int64)
            self.stats.d2h_bytes += deg_r.nbytes // 2
        else:
            deg_r = g.degrees()[order]
        nodes, node_valid, C, N, E = plan_region_pack(
            deg_r, order, n, max_nodes=self.N,
            max_edges=self._e_request, block=self.pack_block,
        )
        Cb = _pow2(C)
        Eb = max(self._repair_E, -(-E // 512) * 512)  # sticky, like E_floor
        self._repair_E = Eb
        nodes = np.pad(
            nodes, ((0, Cb - C), (0, self.N - N)), constant_values=n
        )
        node_valid = np.pad(node_valid, ((0, Cb - C), (0, self.N - N)))
        nodes_d = jnp.asarray(nodes)
        nv_d = jnp.asarray(node_valid)
        self.stats.h2d_bytes += nodes.nbytes + node_valid.nbytes
        self._note_repair_key(
            ("gather", nodes.shape, ip.shape[0], a_dst.shape[0], Eb)
        )
        with _obs_span("repair.gather", cat="repair",
                       region=int(region.size)) as sp:
            edge_dst, edge_w, edge_slot, edge_valid = gather_pack_device(
                nodes_d, nv_d, ip, a_dst, a_ew, jnp.int32(n), E=Eb
            )
            sp.sync_on(edge_valid)
        dp = _DevicePack(
            graph=g, nodes=nodes_d, node_valid=nv_d, edge_dst=edge_dst,
            edge_w=edge_w, edge_src_slot=edge_slot, edge_valid=edge_valid,
            num_chunks=C, shape=(Cb, self.N, Eb),
        )
        _mem_account("chunk_packs", nodes_d, nv_d, edge_dst, edge_w,
                     edge_slot, edge_valid, mask)
        # ---- LP sweeps against exact global block weights ----
        bw = jnp.zeros((k + 1,), jnp.float32).at[jnp.minimum(lab, k)].add(
            ar.nw_arena
        )
        bw_old_max = float(jnp.max(bw[:k]))
        before_cut = cut_now(lab)
        w0 = bw.at[k].set(jnp.inf)
        self._note_repair_key(("sweep", dp.shape, self.A, k + 1, iters))
        with _obs_span("repair.sweep", cat="repair", iters=int(iters)) as sp:
            out, _, _ = self._sweep(
                dp, lab, w0, ar.nw_arena, jnp.zeros(1, jnp.int32), U, seed, k,
                iters=iters, refine_mode=True, use_restrict=False,
                permute_chunks=True,
            )
            sp.sync_on(out)
        # ---- region-masked gain + balance rounds ----
        Kb = k + 1
        with _obs_span("repair.gain", cat="repair",
                       rounds=int(gain_rounds)) as sp:
            for r in range(gain_rounds):
                base_s = hash_base_u32(seed, r, TAG_DYN_GAIN)
                base_g = hash_base_u32(seed, r, TAG_DYN_GAIN_GATE)
                self._note_repair_key(("gain", self.A, a_src.shape[0], Kb))
                out = gain_round_device(
                    a_src, a_dst, a_ew, ar.nw_arena, out, mask,
                    jnp.int32(n), jnp.int32(k), jnp.float32(U),
                    jnp.uint32(base_s), jnp.uint32(base_g), Kb=Kb,
                )
            sp.sync_on(out)
        if balance_rounds:
            self._note_repair_key(("balance", self.A, Kb, balance_rounds))
            with _obs_span("repair.balance", cat="repair",
                           rounds=int(balance_rounds)) as sp:
                out = balance_rounds_device(
                    ar.nw_arena, out, mask, jnp.int32(n), jnp.int32(k),
                    jnp.float32(U), jnp.int32(seed & 0x7FFFFFFF),
                    Kb=Kb, rounds=balance_rounds,
                )
                sp.sync_on(out)
        # ---- guard (the uncoarsening monotonicity guard's twin, plus a
        # feasibility clause): keep the repaired labels only if the cut did
        # not worsen AND the balance bound did not degrade, or if they
        # restored a violated bound.  Repair therefore never trades
        # feasibility for cut — the session-level invariant that edge-only
        # update streams stay feasible forever.
        bw_new = jnp.zeros((k + 1,), jnp.float32).at[jnp.minimum(out, k)].add(
            ar.nw_arena
        )
        bw_new_max = float(jnp.max(bw_new[:k]))
        after_cut = cut_now(out)
        self.stats.d2h_bytes += 16  # the guard's two cut + two bw scalars
        ok_cut = (
            after_cut <= before_cut
            and bw_new_max <= max(bw_old_max, U + 1e-6)
        )
        if ok_cut or bw_old_max > U >= bw_new_max:
            return out, int(region.size), after_cut, np.asarray(bw_new[:k])
        return lab, int(region.size), before_cut, np.asarray(bw[:k])

    # ---------------------------------------------------------- evolutionary

    def _deg_f(self, g: AnyGraph, Ab: int) -> jax.Array:
        """(Ab,) float32 degrees (0 beyond n), uploaded once per graph."""
        hit = self._degs.get(id(g))
        if hit is not None and hit.shape[0] == Ab:
            return hit
        deg = np.zeros(Ab, np.float32)
        deg[: g.n] = g.degrees()
        arr = jnp.asarray(deg)
        self.stats.h2d_bytes += deg.nbytes
        _mem_account("evo_population", arr)
        self._degs[id(g)] = arr
        return arr

    def _weights_exact(self) -> bool:
        """Integral node/edge weights with f32-exact sums (scanned once from
        the finest graph; contraction only sums, so every coarse level
        inherits the property) — the precondition for bit-exact int32
        fitness keys and order-independent f32 scatter sums."""
        if self._exact_weights is None:
            g = self._g0
            if isinstance(g, GraphDev):
                # device-resident finest graph (the dynamic session's
                # escalation path): integrality of ew is tracked metadata,
                # nw is scanned on device — padding is 0, hence inert
                self._exact_weights = bool(
                    (g.m == 0 or g.ew_integral)
                    and bool(jnp.all(g.nw == jnp.round(g.nw)))
                    and float(jnp.sum(g.ew)) < 2**24
                    and float(jnp.sum(g.nw)) < 2**24
                )
            else:
                self._exact_weights = bool(
                    (g.m == 0 or np.all(g.ew == np.round(g.ew)))
                    and np.all(g.nw == np.round(g.nw))
                    and float(g.ew.sum()) < 2**24
                    and float(g.nw.sum()) < 2**24
                )
        return self._exact_weights

    def can_evolve_device(self, g: AnyGraph, k: int, islands: int,
                          pop: int) -> bool:
        """Eligibility gate for the batched device evolution: exact-weight
        precondition plus shape guards (overlay keys fit int32, dense
        (pop, Ab, Kb) score tensors fit a sane memory budget)."""
        n = g.n
        if n < 1 or k < 1 or k * (k + 1) >= 2**31:
            return False
        Ab = _pow2(n + 1)
        Kb = _pow2(k + 1)
        Sb = _pow2(max(islands * pop, 1))
        if Sb * Ab * Kb * 4 > 2**28:
            return False
        return self._weights_exact()

    def _evo_arrays(self, g: AnyGraph):
        """(pack, arc arrays, nw, deg, Ab) for one evolution run; the pack is
        the cached "random" pack (shared with refine sweeps), so the graph
        uploads once per run, not once per individual."""
        dp = self._pack(g, "random")
        ar = self._arena(g)
        Ab = _pow2(g.n + 1)
        return dp, ar, Ab

    def evolve_device(self, g: AnyGraph, cfg, shard: bool = False) -> jax.Array:
        """Batched island GA on device; returns the best coarsest-graph
        partition as a DEVICE (n,) int32 label array (bit-identical to
        :meth:`evolve_oracle` under the same config — tested).

        ``shard=True`` maps islands onto the available devices via
        ``shard_map`` (requires ``islands %% device_count == 0``); gossip
        becomes an all_gather collective and results stay bit-identical.
        """
        from .evo_device import (
            evo_generation_step,
            evo_seed_step,
            make_generation_sharded,
        )

        n, k = g.n, cfg.k
        I, P, G = cfg.islands, cfg.pop_per_island, cfg.generations
        Ab, Kb = _pow2(n + 1), _pow2(k + 1)
        Sb, Ib = _pow2(I * P), _pow2(I)
        dp, ar, _ = self._evo_arrays(g)
        nw_ab = ar.nw_arena[:Ab]
        deg = self._deg_f(g, Ab)
        seed_eff = int(cfg.seed) & 0x7FFFFFFF
        seed_lab = np.full((Sb, Ab), k, np.int32)
        seed_mask = np.zeros(Sb, bool)
        if cfg.seed_individuals:
            for isl in range(I):
                row = isl * P
                seed_lab[row, :n] = np.asarray(
                    cfg.seed_individuals[isl % len(cfg.seed_individuals)][:n],
                    dtype=np.int32,
                )
                seed_mask[row] = True
        self.stats.h2d_bytes += seed_lab.nbytes + seed_mask.nbytes
        skey = ("evo_seed", dp.shape, Sb, Ab, Kb, cfg.refine_iters)
        self.stats.evo_calls += 1
        if skey not in self.stats.evo_buckets:
            self.stats.evo_buckets.add(skey)
            self.stats.evo_compiles += 1
            _obs_watchdog().note("engine.evo", skey)
        from .evolutionary import grow_rounds_bound

        labs, keys = evo_seed_step(
            dp.nodes, dp.node_valid, dp.edge_dst, dp.edge_w,
            dp.edge_src_slot, dp.edge_valid,
            jnp.asarray(seed_lab), jnp.asarray(seed_mask),
            ar.src, ar.dst, ar.ew, nw_ab, deg,
            jnp.float32(cfg.Lmax), jnp.int32(seed_eff),
            jnp.int32(I), jnp.int32(P), jnp.int32(n), jnp.int32(k),
            jnp.int32(dp.num_chunks),
            jnp.int32(grow_rounds_bound(n, k, g.m)),
            refine_iters=cfg.refine_iters, Kb=Kb,
        )
        _mem_account("evo_population", labs, keys)
        D = jax.device_count()
        if shard and G > 0 and D > 1 and I % D == 0:
            labs, keys = self._evolve_sharded(
                g, cfg, dp, ar, labs, keys, nw_ab, seed_eff, D,
                make_generation_sharded,
            )
        else:
            gkey = ("evo_gen", dp.shape, Sb, Ab, Ib, Kb, cfg.refine_iters)
            for gen in range(G):
                self.stats.evo_calls += 1
                if gkey not in self.stats.evo_buckets:
                    self.stats.evo_buckets.add(gkey)
                    self.stats.evo_compiles += 1
                    _obs_watchdog().note("engine.evo", gkey)
                labs, keys = evo_generation_step(
                    dp.nodes, dp.node_valid, dp.edge_dst, dp.edge_w,
                    dp.edge_src_slot, dp.edge_valid,
                    labs, keys, ar.src, ar.dst, ar.ew, nw_ab,
                    jnp.float32(cfg.Lmax), jnp.int32(seed_eff),
                    jnp.int32(gen), jnp.int32(0),
                    jnp.int32(I), jnp.int32(P), jnp.int32(n), jnp.int32(k),
                    jnp.int32(dp.num_chunks),
                    refine_iters=cfg.refine_iters, Kb=Kb, Ib=Ib,
                )
                _mem_account("evo_population", labs, keys)
        Sb_cur = labs.shape[0]
        valid = jnp.arange(Sb_cur) < I * P
        bkey = jnp.min(jnp.where(valid, keys, 2**31 - 1))
        bidx = jnp.min(
            jnp.where(valid & (keys == bkey), jnp.arange(Sb_cur), Sb_cur)
        )
        return labs[jnp.minimum(bidx, Sb_cur - 1)][:n]

    def _evolve_sharded(self, g, cfg, dp, ar, labs, keys, nw_ab, seed_eff,
                        D, make_step):
        """Generation loop over ``shard_map`` island shards (device evo's
        distributed mode); state is resharded (D, Sb_loc, Ab) around the
        single-device seed phase and flattened back for best-selection."""
        from ..launch.mesh import make_mesh

        n, k = g.n, cfg.k
        I, P, G = cfg.islands, cfg.pop_per_island, cfg.generations
        Ab = labs.shape[1]
        I_loc = I // D
        S_loc = I_loc * P
        Sb_loc = _pow2(S_loc)
        Kb = _pow2(k + 1)
        Ib_loc = _pow2(I_loc)
        lab_h = np.asarray(labs)
        key_h = np.asarray(keys)
        self.stats.d2h_bytes += lab_h.nbytes + key_h.nbytes
        lab_sh = np.full((D, Sb_loc, Ab), k, np.int32)
        key_sh = np.full((D, Sb_loc), 2**31 - 1, np.int32)
        for d in range(D):
            lab_sh[d, :S_loc] = lab_h[d * S_loc:(d + 1) * S_loc]
            key_sh[d, :S_loc] = key_h[d * S_loc:(d + 1) * S_loc]
        offs = (np.arange(D, dtype=np.int32) * I_loc)[:, None]
        stat_key = ("evo_gen_sharded", dp.shape, D, Sb_loc, Ab, Ib_loc, Kb,
                    cfg.refine_iters)
        # keyed on the step's actual statics (a mesh identity would miss on
        # every call — make_mesh returns a fresh object — and re-jit the
        # shard_map executable once per V-cycle)
        step_key = (D, cfg.refine_iters, Kb, Ib_loc)
        step = self._shard_steps.get(step_key)
        if step is None:
            step = make_step(
                make_mesh((D,), ("island",)), cfg.refine_iters, Kb, Ib_loc
            )
            self._shard_steps[step_key] = step
        labs_d = jnp.asarray(lab_sh)
        keys_d = jnp.asarray(key_sh)
        self.stats.h2d_bytes += lab_sh.nbytes + key_sh.nbytes
        offs_d = jnp.asarray(offs)
        _mem_account("evo_population", labs_d, keys_d, offs_d)
        for gen in range(G):
            self.stats.evo_calls += 1
            if stat_key not in self.stats.evo_buckets:
                self.stats.evo_buckets.add(stat_key)
                self.stats.evo_compiles += 1
                _obs_watchdog().note("engine.evo", stat_key)
            labs_d, keys_d = step(
                dp.nodes, dp.node_valid, dp.edge_dst, dp.edge_w,
                dp.edge_src_slot, dp.edge_valid,
                labs_d, keys_d, ar.src, ar.dst, ar.ew, nw_ab,
                jnp.float32(cfg.Lmax), jnp.int32(seed_eff), jnp.int32(gen),
                offs_d,
                jnp.int32(I_loc), jnp.int32(P), jnp.int32(n), jnp.int32(k),
                jnp.int32(dp.num_chunks),
            )
        # flatten back to island-major flat order (gossip already global)
        lab_fh = np.asarray(labs_d)
        key_fh = np.asarray(keys_d)
        self.stats.d2h_bytes += lab_fh.nbytes + key_fh.nbytes
        Sb = _pow2(I * P)
        lab_out = np.full((Sb, Ab), k, np.int32)
        key_out = np.full(Sb, 2**31 - 1, np.int32)
        for d in range(D):
            lab_out[d * S_loc:(d + 1) * S_loc] = lab_fh[d, :S_loc]
            key_out[d * S_loc:(d + 1) * S_loc] = key_fh[d, :S_loc]
        return jnp.asarray(lab_out), jnp.asarray(key_out)

    def evolve_oracle(self, g: AnyGraph, cfg, trace=None) -> np.ndarray:
        """Sequential host-numpy oracle on the SAME pack/arc arrays the
        device path dispatches — the parity reference and the
        host-sequential baseline of the ``evo_hot`` benchmark."""
        from .evolutionary import EvoInputs, evolve_batched_numpy

        dp, ar, Ab = self._evo_arrays(g)
        deg = np.zeros(Ab, np.int32)
        deg[: g.n] = g.degrees()
        inp = EvoInputs(
            nodes=np.asarray(dp.nodes),
            node_valid=np.asarray(dp.node_valid),
            edge_dst=np.asarray(dp.edge_dst),
            edge_w=np.asarray(dp.edge_w),
            edge_src_slot=np.asarray(dp.edge_src_slot),
            edge_valid=np.asarray(dp.edge_valid),
            num_chunks=dp.num_chunks,
            src=np.asarray(ar.src),
            dst=np.asarray(ar.dst),
            ew=np.asarray(ar.ew),
            nw=np.asarray(ar.nw_arena[:Ab]),
            deg=deg,
            n=g.n,
        )
        return evolve_batched_numpy(inp, cfg, trace=trace)

    # ------------------------------------------------------------ contraction

    def _contract_inputs(self, g: AnyGraph, Nb: int, Mb: int):
        """(src, dst, ew, nw, ew_integral, ew_max) for the (Nb, Mb) bucket.

        GraphDev handles are born exactly in their bucket (contract slices
        its outputs down), so they pass through untouched and carry their
        weight metadata; GraphNP inputs (the finest level) pad from the
        cached arena arrays on device, once per graph.  The weight scan for
        the packed-key fast path runs once here: an O(m) host scan per
        *call* would trash the CPU cache the contraction executable is
        about to use."""
        if isinstance(g, GraphDev):
            return g.src, g.indices, g.ew, g.nw, g.ew_integral, g.ew_max
        hit = self._cin.get(id(g))
        if hit is not None and hit[0] is g:
            return hit[1:]
        ar = self._arena(g)
        pm = Mb - g.m
        src = jnp.concatenate([ar.src, jnp.zeros((pm,), jnp.int32)])
        dst = jnp.concatenate([ar.dst, jnp.zeros((pm,), jnp.int32)])
        ew = jnp.concatenate([ar.ew, jnp.zeros((pm,), jnp.float32)])
        nw = ar.nw_arena[:Nb]
        integral = bool(np.all(g.ew == np.round(g.ew))) if g.m else True
        ew_max = float(g.ew.max()) if g.m else 0.0
        _mem_account("base_csr", src, dst, ew)
        self._cin[id(g)] = (g, src, dst, ew, nw, integral, ew_max)
        return src, dst, ew, nw, integral, ew_max

    def contract(
        self, g: AnyGraph, labels: Union[np.ndarray, jax.Array]
    ) -> Tuple[GraphDev, CoarseMap]:
        """Device-resident contraction: the §IV-C quotient build as one
        bucketed executable (``contract_device``).

        ``labels`` are cluster ids in ``[0, n)`` (a ``cluster`` result —
        device or host).  Returns a :class:`GraphDev` whose arrays live in
        the coarse level's own buckets plus the fine->coarse
        :class:`CoarseMap`; only the ``(n_c, m_c, max nw_c)`` scalars are
        synced to host."""
        n, m = g.n, g.m
        Nb = _pow2(max(n, 8))
        Mb = _mbucket(m)
        src, dst, ew, nw, integral, ew_max = self._contract_inputs(g, Nb, Mb)
        # packed-key fast path: integral weights small enough to ride in the
        # low bits of the uint32 sort key (see contract_device)
        wbits = packed_key_wbits(Nb, Mb, ew_max, integral)
        if isinstance(labels, jax.Array):
            lab = labels.astype(jnp.int32)
        else:
            lab = jnp.asarray(np.asarray(labels[:n], dtype=np.int32))
            self.stats.h2d_bytes += n * 4
        if lab.shape[0] != Nb:
            lab = jnp.concatenate(
                [lab[:n], jnp.zeros((Nb - n,), jnp.int32)]
            )
        self.stats.contract_calls += 1
        ckey = (Nb, Mb, wbits)
        if ckey not in self.stats.contract_buckets:
            self.stats.contract_buckets.add(ckey)
            self.stats.contract_compiles += 1
            _obs_watchdog().note("engine.contract", ckey)
        with _obs_span(
            "vcycle.contract", cat="vcycle", n=int(n), m=int(m),
        ):
            (C, n_c, nw_c, indptr_c, src_c, dst_c, ew_c, m_c, nwmax,
             ewmax) = contract_device(
                src, dst, ew, nw, lab, jnp.int32(n), jnp.int32(m),
                wbits=wbits,
            )
            # the only host sync of the level: all four scalars in one
            # transfer (it also bounds the span — no extra block needed)
            n_c, m_c, nwmax, ewmax = jax.device_get((n_c, m_c, nwmax, ewmax))
        n_c, m_c, nwmax, ewmax = int(n_c), int(m_c), float(nwmax), float(ewmax)
        self.stats.d2h_bytes += 16
        Ncb = _pow2(max(n_c, 8))
        Mcb = _mbucket(m_c)
        coarse = GraphDev(
            indptr=indptr_c[: Ncb + 1],
            indices=dst_c[:Mcb],
            ew=ew_c[:Mcb],
            nw=nw_c[:Ncb],
            src=src_c[:Mcb],
            n=n_c, m=m_c, nw_max=nwmax,
            ew_max=ewmax, ew_integral=integral,
            on_materialize=self._note_d2h,
        )
        cmap = CoarseMap(
            dev=C, n_fine=n, n_coarse=n_c, on_materialize=self._note_d2h
        )
        _mem_account("base_csr", C)
        return coarse, cmap

    def project_restrict(self, C: CoarseMap, restrict: jax.Array) -> jax.Array:
        """Push a V-cycle restriction one level down on device:
        ``r_c[C[v]] = r[v]`` (consistent — clusters never straddle cells).
        Returns an arena-sized int32 array, -1 beyond the coarse n."""
        Nb = C.dev.shape[0]
        idx = jnp.where(self._iota[:Nb] < C.n_fine, C.dev, self.A)
        out = jnp.full((self.A,), -1, jnp.int32).at[idx].set(
            restrict[:Nb].astype(jnp.int32), mode="drop"
        )
        _mem_account("label_arenas", out)
        return out

    def _note_d2h(self, nbytes: int) -> None:
        self.stats.d2h_bytes += int(nbytes)

    # --------------------------------------------------------- device helpers

    def to_arena(
        self, labels: Union[np.ndarray, jax.Array], n: int, fill: int
    ) -> jax.Array:
        """Lift labels of length >= n into an (A,) int32 arena array."""
        if isinstance(labels, jax.Array):
            lab = labels.astype(jnp.int32)
            if lab.shape[0] == self.A:
                return lab
            lab = jnp.concatenate(
                [lab[:n], jnp.full((self.A - n,), fill, jnp.int32)]
            )
            _mem_account("label_arenas", lab)
            return lab
        out = np.full(self.A, fill, np.int32)
        out[:n] = np.asarray(labels[:n], dtype=np.int32)
        arr = jnp.asarray(out)
        _mem_account("label_arenas", arr)
        return arr

    def project(
        self,
        coarse_labels: Union[np.ndarray, jax.Array],
        C: Union[np.ndarray, CoarseMap],
        fill: int,
    ) -> jax.Array:
        """Project coarse labels through a contraction map C (fine -> coarse)
        entirely on device; returns arena-sized fine labels.  ``C`` may be a
        host numpy map or a device :class:`CoarseMap` (no upload needed)."""
        if isinstance(coarse_labels, jax.Array):
            base = coarse_labels.astype(jnp.int32)
        else:
            base = jnp.asarray(np.asarray(coarse_labels, dtype=np.int32))
            self.stats.h2d_bytes += coarse_labels.shape[0] * 4
        if isinstance(C, CoarseMap):
            n_f = C.n_fine
            Nb = C.dev.shape[0]
            fine = jnp.where(
                self._iota[:Nb] < n_f, base[C.dev], jnp.int32(fill)
            )
            out = jnp.concatenate(
                [fine, jnp.full((self.A - Nb,), fill, jnp.int32)]
            )
            _mem_account("label_arenas", out)
            return out
        n_f = C.shape[0]
        C_dev = jnp.asarray(np.asarray(C, dtype=np.int32))
        self.stats.h2d_bytes += n_f * 4
        fine = base[C_dev]
        out = jnp.concatenate(
            [fine, jnp.full((self.A - n_f,), fill, jnp.int32)]
        )
        _mem_account("label_arenas", out)
        return out

    def cut(self, g: AnyGraph, labels: jax.Array) -> float:
        """Edge cut of arena labels, evaluated on device (one scalar sync)."""
        ar = self._arena(g)
        diff = labels[ar.src] != labels[ar.dst]
        return float(jnp.sum(jnp.where(diff, ar.ew, 0.0)) / 2.0)

    def block_weights(self, g: AnyGraph, labels: jax.Array, k: int) -> np.ndarray:
        ar = self._arena(g)
        bw = jnp.zeros((k + 1,), jnp.float32).at[jnp.minimum(labels, k)].add(
            ar.nw_arena
        )
        return np.asarray(bw[:k])

    def to_host(self, labels: jax.Array, n: int) -> np.ndarray:
        return np.asarray(labels[:n])

    # ---------------------------------------------------------------- metrics

    @property
    def compile_count(self) -> int:
        """Distinct sweep (bucket, statics) combinations dispatched — each is
        one XLA compilation of ``_lp_sweep``."""
        return self.stats.sweep_compiles

    @staticmethod
    def jit_cache_size() -> Optional[int]:
        """Size of the jit cache of ``_lp_sweep`` itself, when available."""
        try:
            return int(_lp_sweep._cache_size())
        except Exception:
            return None

    def stats_dict(self) -> dict:
        return dict(
            sweep_calls=self.stats.sweep_calls,
            sweep_compiles=self.stats.sweep_compiles,
            bucket_count=self.stats.bucket_count,
            pack_builds=self.stats.pack_builds,
            pack_hits=self.stats.pack_hits,
            dense_rounds=self.stats.dense_rounds,
            dense_compiles=self.stats.dense_compiles,
            evo_calls=self.stats.evo_calls,
            evo_compiles=self.stats.evo_compiles,
            evo_bucket_count=self.stats.evo_bucket_count,
            contract_calls=self.stats.contract_calls,
            contract_compiles=self.stats.contract_compiles,
            contract_bucket_count=self.stats.contract_bucket_count,
            gather_builds=self.stats.gather_builds,
            gather_compiles=self.stats.gather_compiles,
            repair_calls=self.stats.repair_calls,
            repair_compiles=self.stats.repair_compiles,
            repair_bucket_count=self.stats.repair_bucket_count,
            audit_calls=self.stats.audit_calls,
            audit_compiles=self.stats.audit_compiles,
            audit_bucket_count=self.stats.audit_bucket_count,
            h2d_bytes=self.stats.h2d_bytes,
            d2h_bytes=self.stats.d2h_bytes,
            arena=self.A,
            chunk_bucket=(self.C_bucket, self.N, self.E_floor),
        )
