"""Baseline partitioners the paper compares against.

* :func:`hash_partition` — the hash-based strategy used by cloud graph
  toolkits (paper §II-B: "hashing often leads to acceptable balance, [but]
  the edge cut ... is very high").
* :func:`random_balanced` — perfectly balanced random assignment.
* :func:`matching_multilevel` — the ParMetis stand-in: classic multilevel
  with *heavy-edge-matching* coarsening (handshaking / locally-heaviest
  matching), greedy-growing initial partitioning and the same LP refinement
  our system uses.  Differences to our system are therefore isolated to the
  coarsening scheme — exactly the paper's claim under test: matching cannot
  shrink complex networks (a star of degree d matches one of d edges per
  round), so the coarsest graph stays huge and quality/time collapse, while
  cluster contraction shrinks them by orders of magnitude.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List

import numpy as np

from ..graph.csr import GraphNP
from .contraction import contract, project_labels
from .initial_partition import greedy_growing, repair_balance
from .label_propagation import sclap_numpy
from .metrics import cut_np, imbalance_np, lmax

__all__ = ["hash_partition", "random_balanced", "matching_multilevel", "BaselineReport"]


def hash_partition(n: int, k: int) -> np.ndarray:
    ids = np.arange(n, dtype=np.uint64)
    h = ids * np.uint64(0x9E3779B97F4A7C15)
    h ^= h >> np.uint64(29)
    h *= np.uint64(0xBF58476D1CE4E5B9)
    h ^= h >> np.uint64(32)
    return (h % np.uint64(k)).astype(np.int32)


def random_balanced(n: int, k: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    lab = np.arange(n, dtype=np.int64) % k
    rng.shuffle(lab)
    return lab.astype(np.int32)


def _hem_round(g: GraphNP, match: np.ndarray, rng, heavy: bool = True) -> np.ndarray:
    """One handshaking round of heavy-edge (or random) matching."""
    n = g.n
    src = g.arc_sources().astype(np.int64)
    dst = g.indices.astype(np.int64)
    free = match < 0
    ok = free[src] & free[dst]
    if not ok.any():
        return match
    base = g.ew.astype(np.float64) if heavy else np.ones(g.m)
    w = base + rng.random(g.m) * (0.49 if heavy else 1.0)
    w = np.where(ok, w, -1.0)
    # per-source heaviest arc: sort by (src, -w), take first per src
    order = np.lexsort((-w, src))
    s_sorted = src[order]
    first = np.ones(s_sorted.shape[0], dtype=bool)
    first[1:] = s_sorted[1:] != s_sorted[:-1]
    cand_src = s_sorted[first]
    cand_dst = dst[order][first]
    cand_w = w[order][first]
    proposal = np.full(n, -1, dtype=np.int64)
    good = cand_w > 0
    proposal[cand_src[good]] = cand_dst[good]
    # mutual proposals are matched
    v = np.flatnonzero(proposal >= 0)
    mutual = proposal[proposal[v]] == v
    a = v[mutual]
    match = match.copy()
    match[a] = proposal[a]
    return match


@dataclass
class BaselineReport:
    labels: np.ndarray
    cut: float
    imbalance: float
    level_sizes: List[tuple]
    shrink_first: float
    coarsening_stalled: bool
    seconds: float


def matching_multilevel(
    g: GraphNP,
    k: int,
    eps: float = 0.03,
    seed: int = 0,
    coarsest_factor: int = 200,
    refine_iters: int = 6,
    max_levels: int = 64,
    stall: float = 0.97,
) -> BaselineReport:
    t0 = time.time()
    rng = np.random.default_rng(seed)
    L = lmax(g.total_node_weight, k, eps)
    coarsest_target = coarsest_factor * k

    hierarchy = []
    gg = g
    stalled = False
    shrink_first = 1.0
    for lev in range(max_levels):
        if gg.n <= coarsest_target:
            break
        match = np.full(gg.n, -1, dtype=np.int64)
        for _ in range(3):  # a few handshake rounds per level
            match = _hem_round(gg, match, rng, heavy=True)
        # ParMetis-style fallback: random matching among still-free nodes
        match = _hem_round(gg, match, rng, heavy=False)
        pair_label = np.where(
            match >= 0, np.minimum(np.arange(gg.n), match), np.arange(gg.n)
        )
        coarse, C = contract(gg, pair_label)
        if coarse.n >= stall * gg.n:
            stalled = True  # matching cannot shrink further (paper's ParMetis)
            break
        hierarchy.append((gg, C))
        if lev == 0:
            shrink_first = coarse.n / max(gg.n, 1)
        gg = coarse
    level_sizes = [(h[0].n, h[0].m) for h in hierarchy] + [(gg.n, gg.m)]

    lab = greedy_growing(gg, k, L, seed=seed)
    lab = sclap_numpy(
        gg, lab, U=L, iters=refine_iters, seed=seed, refine_mode=True, num_labels=k
    ).labels
    for gg_f, C in reversed(hierarchy):
        lab = project_labels(lab, C)
        if gg_f.n < 200_000:
            lab = sclap_numpy(
                gg_f, lab, U=L, iters=refine_iters, seed=seed,
                refine_mode=True, num_labels=k,
            ).labels
        else:  # keep the baseline's host refinement tractable
            from .label_propagation import lp_refine

            lab = lp_refine(gg_f, lab, k=k, U=L, iters=refine_iters, seed=seed).labels
    lab = repair_balance(g, lab, k, L, seed=seed)
    return BaselineReport(
        labels=lab,
        cut=cut_np(g, lab),
        imbalance=imbalance_np(g, lab, k),
        level_sizes=level_sizes,
        shrink_first=shrink_first,
        coarsening_stalled=stalled,
        seconds=time.time() - t0,
    )
