"""The overall multilevel system (paper §IV-E) with iterated V-cycles.

Pipeline per V-cycle:

  coarsen:   l iterations of parallel SCLaP (U = max(max_v c(v), L_max/f),
             degree order) -> cluster contraction, repeated until the graph
             has <= coarsest_factor * k nodes or contraction stalls.  On the
             jnp engine the whole chain is device-resident: clustering,
             contraction (``LPEngine.contract``), and the next level's pack
             gather all run on device over a GraphDev hierarchy; only the
             (n_c, m_c, max nw) scalars cross to host per level;
  initial:   the island evolutionary algorithm (KaFFPaE) on the replicated
             coarsest graph — seeded with the projected current solution
             from the 2nd V-cycle on, so quality never regresses;
  uncoarsen: project labels through the hierarchy, r iterations of SCLaP
             local search per level (U = L_max, random order), final
             feasibility repair at the finest level.

Presets mirror the paper §V-A: *fast* (3/6 LP iters, 2 V-cycles, GA gets
only its initial population), *eco* (5 V-cycles + GA generations), *minimal*
(1 V-cycle).  f = 14 for social/web graphs, "20000" for meshes in the first
V-cycle (scale-capped — the paper's value presumes billion-edge graphs),
random in [10, 25] afterwards.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from ..graph.csr import GraphDev, GraphNP
from ..graph.packing import chunk_geometry
from ..obs import span as _obs_span
from .contraction import CoarseMap, contract, project_labels
from .engine import LPEngine
from .evolutionary import EvoConfig, evolve
from .initial_partition import repair_balance
from .label_propagation import lp_cluster, lp_refine, sclap_numpy
from .metrics import cut_np, imbalance_np, lmax

__all__ = ["PartitionerConfig", "PartitionReport", "partition"]


@dataclass
class PartitionerConfig:
    k: int = 2
    eps: float = 0.03
    preset: str = "fast"            # fast | eco | minimal
    graph_type: str = "auto"        # social | mesh | auto
    lp_iters_coarsen: int = 3
    lp_iters_refine: int = 6
    f_social: float = 14.0
    f_mesh: float = 20000.0
    # stop coarsening at coarsest_factor * k nodes; 0 = auto-scale to the
    # input: max(k, min(10000 * k, n // 8)).  The paper's 10000*k constant
    # targets million-node graphs — as a fixed default it meant any graph
    # under ~40k nodes (at k=4) never coarsened at all, turning "multilevel"
    # into flat LP on the bench sizes.  Explicit positive values are
    # honored verbatim (tests pin small targets with e.g. 256).
    coarsest_factor: int = 0
    max_levels: int = 64
    shrink_stall: float = 0.95      # stop if n' > stall * n
    seed: int = 0
    # engine
    engine: str = "auto"            # jnp | numpy | dist | auto
    numpy_below: int = 4096         # use the sequential engine below this n
    target_chunks: int = 64
    # coarsening path for the jnp engine: "device" keeps cluster -> contract
    # -> next-level pack chained on device (GraphDev hierarchy, only scalars
    # cross to host per level); "host" is the legacy numpy contract()
    # round-trip (also the benchmark baseline).
    coarsen_engine: str = "device"  # device | host
    dist_shards: int = 0            # engine="dist": number of mesh PEs
    dist_chunks_per_shard: int = 4
    # refinement engine for the jnp path: "chunked" = chunked-sequential LP
    # sweep; "dense" = synchronous Pallas-scored dense rounds at fine levels
    # (>= dense_min_n nodes), falling back to chunked/numpy below.
    refine_engine: str = "chunked"  # chunked | dense
    dense_min_n: int = 4096
    # coarsest-stage evolutionary engine: "device" runs the batched island
    # GA on device (population as a (pop, n) batch over the still-resident
    # coarsest graph — GraphDev levels never materialize to host); "host" is
    # the legacy sequential KaFFPaE loop; "auto" picks device whenever the
    # LP engine is active and the exact-weight eligibility gate passes
    # (LPEngine.can_evolve_device), host otherwise.
    evo_engine: str = "auto"        # auto | device | host
    # map islands onto shard_map shards (one mesh axis over the local
    # devices; per-epoch gossip becomes an all_gather collective).  Requires
    # islands % device_count == 0; results stay bit-identical to the
    # single-device path, so this is purely a throughput knob.
    evo_shard_islands: bool = False
    # BEYOND-PAPER: gain-based FM pass on the finest level (the paper's fine
    # refinement is LP-only; see EXPERIMENTS.md §Paper-validation for the
    # separate accounting).  Enabled by the "strong" preset.
    fm_finest: bool = False
    fm_finest_max_n: int = 2_000_000
    # evolutionary budget (scaled by preset)
    islands: int = 2
    pop_per_island: int = 2
    generations: int = 0
    # seed the FIRST V-cycle with an existing k-way partition via the
    # restrict machinery (cycle 0 then behaves exactly like cycle >= 2 of
    # an iterated run: clustering never merges across the seed's cut edges
    # and the coarsest GA is seeded with the projected labels).  Used by
    # the dynamic session's escalation path so a full re-partition starts
    # from the served solution instead of from scratch.
    initial_labels: Optional[np.ndarray] = None

    def __post_init__(self):
        if self.preset == "eco":
            self.islands = max(self.islands, 4)
            self.pop_per_island = max(self.pop_per_island, 3)
            self.generations = max(self.generations, 8)
            self.vcycles = 5
        elif self.preset == "minimal":
            self.vcycles = 1
        elif self.preset == "strong":  # beyond-paper: eco + finest-level FM
            self.islands = max(self.islands, 4)
            self.pop_per_island = max(self.pop_per_island, 3)
            self.generations = max(self.generations, 8)
            self.vcycles = 5
            self.fm_finest = True
        else:  # fast
            self.vcycles = 2

    vcycles: int = field(default=2, init=False)


@dataclass
class PartitionReport:
    labels: np.ndarray
    cut: float
    imbalance: float
    feasible: bool
    level_sizes: List[tuple]        # [(n, m) per level incl. finest]
    shrink_first: float             # n_1 / n_0 after first contraction
    cycle_cuts: List[float]
    seconds: float
    engine_stats: Optional[dict] = None  # LPEngine counters (jnp path only)


def _detect_type(g: GraphNP) -> str:
    deg = g.degrees().astype(np.float64)
    if deg.size == 0:
        return "mesh"
    cv = deg.std() / max(deg.mean(), 1e-9)
    return "social" if cv > 0.7 else "mesh"


def _f_value(cfg: PartitionerConfig, gtype: str, cycle: int, rng) -> float:
    if cycle > 0:
        return float(rng.uniform(10.0, 25.0))
    return cfg.f_social if gtype == "social" else cfg.f_mesh


def _use_numpy(g, cfg) -> bool:
    return cfg.engine == "numpy" or (
        cfg.engine in ("auto", "dist") and g.n < cfg.numpy_below
    )


def _cluster(g, U, iters, seed, restrict, cfg, eng=None) -> np.ndarray:
    if _use_numpy(g, cfg):
        return sclap_numpy(
            g, np.arange(g.n), U=U, iters=iters, seed=seed, restrict=restrict
        ).labels
    if cfg.engine == "dist" and restrict is None:
        # V-cycle-restricted clustering keeps the single-mesh path; the
        # unrestricted (hot) first cycle runs on the device mesh.  The plan
        # is keyed on cfg.seed (the run's seed-epoch), not the per-call
        # sweep seed, so repeated calls on one graph hit the plan cache.
        from .distributed_lp import build_plan, lp_cluster_distributed

        plan = build_plan(
            g, cfg.dist_shards, chunks_per_shard=cfg.dist_chunks_per_shard,
            order="degree", seed=cfg.seed,
        )
        return lp_cluster_distributed(plan, U=U, iters=iters, seed=seed)
    if eng is not None:
        return np.asarray(
            eng.cluster(g, U=U, iters=iters, seed=seed, restrict=restrict)
        )
    max_nodes, max_edges = chunk_geometry(g.n, g.m, cfg.target_chunks)
    return lp_cluster(
        g, U=U, iters=iters, seed=seed, restrict=restrict,
        max_nodes=max_nodes, max_edges=max_edges,
    ).labels


def _refine(g, labels, k, Lmax, iters, seed, cfg) -> np.ndarray:
    """Host-path refinement (numpy / dist / legacy jnp without an engine).

    The engine-owned device-resident path lives in ``_uncoarsen``."""
    use_numpy = _use_numpy(g, cfg)
    if not use_numpy and cfg.engine == "dist":
        from .distributed_lp import build_plan, lp_refine_distributed

        plan = build_plan(
            g, cfg.dist_shards, chunks_per_shard=cfg.dist_chunks_per_shard,
            order="random", seed=cfg.seed,
        )
        return lp_refine_distributed(plan, labels, k=k, U=Lmax, iters=iters, seed=seed)
    if use_numpy:
        from .fm import fm_refine

        lab = sclap_numpy(
            g, labels, U=Lmax, iters=iters, seed=seed, refine_mode=True, num_labels=k
        ).labels
        # strong gain-based search on small (coarse) levels, like KaFFPa
        return fm_refine(g, lab, k, Lmax, seed=seed)
    max_nodes, max_edges = chunk_geometry(g.n, g.m, cfg.target_chunks)
    return lp_refine(
        g, labels, k=k, U=Lmax, iters=iters, seed=seed,
        max_nodes=max_nodes, max_edges=max_edges,
    ).labels


def _uncoarsen(g, hierarchy, lab, k, L, cfg, rng, eng):
    """Project + refine through the hierarchy (uncoarsening local search).

    On the engine (jnp) path, labels stay device-resident across levels:
    projection, the sweep/dense rounds, and the monotonicity-guard cut and
    balance evaluations all run on device; only two scalars per level cross
    back to host.  Host-path levels (numpy below ``numpy_below``, dist)
    keep the original numpy flow.
    """
    lab_dev = None  # engine arena labels, device-resident once set
    for gg_f, C in reversed(hierarchy):
        seed_r = int(rng.integers(1 << 30))
        eng_level = (
            eng is not None
            and cfg.engine in ("auto", "jnp")
            and not _use_numpy(gg_f, cfg)
        )
        if eng_level:
            with _obs_span(
                "vcycle.project", cat="vcycle", n=int(gg_f.n)
            ) as sp:
                lab_dev = eng.project(
                    lab_dev if lab_dev is not None else lab, C, fill=k
                )
                sp.sync_on(lab_dev)
            lab = None
            before = eng.cut(gg_f, lab_dev)
            if cfg.refine_engine == "dense" and gg_f.n >= cfg.dense_min_n:
                ref = eng.refine_dense(
                    gg_f, lab_dev, k, L, cfg.lp_iters_refine, seed_r
                )
            else:
                ref = eng.refine(gg_f, lab_dev, k, L, cfg.lp_iters_refine, seed_r)
            # monotonicity guard: chunked-synchronous LP may oscillate; keep
            # the refined labels only if they did not worsen the cut (unless
            # they were needed to restore feasibility)
            bw_ref = float(eng.block_weights(gg_f, ref, k).max())
            bw_old = float(eng.block_weights(gg_f, lab_dev, k).max())
            if eng.cut(gg_f, ref) <= before or bw_old > L >= bw_ref:
                lab_dev = ref
        else:
            gg_h = gg_f.to_host() if isinstance(gg_f, GraphDev) else gg_f
            C_np = C.host() if isinstance(C, CoarseMap) else C
            if lab is None:  # leaving the device path (defensive; host levels
                lab = np.asarray(lab_dev)  # precede device levels in practice)
                lab_dev = None
            elif not isinstance(lab, np.ndarray):
                lab = np.asarray(lab)  # device-evo labels entering a host level
            lab = project_labels(lab, C_np)
            before = cut_np(gg_h, lab)
            ref = _refine(gg_h, lab, k, L, cfg.lp_iters_refine, seed_r, cfg)
            bw_ref = np.bincount(ref, weights=gg_h.nw, minlength=k).max()
            bw_old = np.bincount(lab, weights=gg_h.nw, minlength=k).max()
            if cut_np(gg_h, ref) <= before or bw_old > L >= bw_ref:
                lab = ref
    if lab is None:
        lab = eng.to_host(lab_dev, g.n)
    return np.asarray(lab)  # device-evo labels may reach here untouched


def partition(g, cfg: PartitionerConfig) -> PartitionReport:
    """Iterated multilevel V-cycles on ``g`` (GraphNP or GraphDev).

    A :class:`GraphDev` finest graph keeps the whole run device-first: the
    engine and the coarsening chain consume the resident handle directly
    (no arena re-upload), and only the host-side finalization steps
    (type detection, balance repair, final metrics) touch the cached
    ``to_host()`` view.  This is the dynamic session's escalation path.
    """
    t0 = time.time()
    rng = np.random.default_rng(cfg.seed)
    k = cfg.k
    # host view for host-only ops (cached on GraphDev: one O(n+m) download,
    # which the caller typically already paid for serving)
    gh = g.to_host() if isinstance(g, GraphDev) else g
    L = lmax(gh.total_node_weight, k, cfg.eps)
    gtype = cfg.graph_type if cfg.graph_type != "auto" else _detect_type(gh)
    coarsest_target = (
        cfg.coarsest_factor * k
        if cfg.coarsest_factor > 0
        else max(k, min(10000 * k, gh.n // 8))
    )
    # One LP engine per run: owns pack/jit caches and device-resident state
    # for every level of every V-cycle (numpy engine needs none).
    eng = (
        LPEngine(g, target_chunks=cfg.target_chunks, seed=cfg.seed)
        if cfg.engine != "numpy"
        else None
    )

    best_labels: Optional[np.ndarray] = None
    best_cut = np.inf
    cycle_cuts: List[float] = []
    level_sizes: List[tuple] = []
    shrink_first = 1.0

    # device coarsening: cluster -> contract -> next-level pack chains
    # device-to-device (GraphDev hierarchy); the host contract() round-trip
    # remains for the numpy/dist engines and as an explicit fallback
    dev_coarsen = (
        eng is not None
        and cfg.coarsen_engine == "device"
        and cfg.engine in ("auto", "jnp")
    )

    cur_labels: Optional[np.ndarray] = None
    if cfg.initial_labels is not None:
        il = np.asarray(cfg.initial_labels, dtype=np.int64).reshape(-1)
        if il.shape[0] != g.n:
            raise ValueError("initial_labels length must equal g.n")
        if il.size and (il.min() < 0 or il.max() >= k):
            raise ValueError("initial_labels must lie in [0, k)")
        cur_labels = il
    for cycle in range(cfg.vcycles):
        # ---------------- coarsening ----------------
        f = _f_value(cfg, gtype, cycle, rng)
        hierarchy = []  # [(graph, C)] — C is np or CoarseMap, graph NP or Dev
        gg = g
        restrict = cur_labels  # protect cut edges from the 2nd cycle on
        # ``restrict`` mirrors the level type: numpy on host levels, an
        # arena-sized device array on device levels
        for lev in range(cfg.max_levels):
            if gg.n <= coarsest_target:
                break
            seed = int(rng.integers(1 << 30))
            if isinstance(gg, GraphDev) and (_use_numpy(gg, cfg) or not dev_coarsen):
                # below the engine threshold (or host coarsening requested):
                # hand the level chain back to the host engines (lazy
                # materialization, one download — cached on the finest level)
                gg = gg.to_host()
                if restrict is not None and not isinstance(restrict, np.ndarray):
                    restrict = np.asarray(restrict[: gg.n]).astype(np.int64)
            dev_level = dev_coarsen and not _use_numpy(gg, cfg)
            if dev_level:
                nw_max = gg.nw_max if isinstance(gg, GraphDev) else float(gg.nw.max())
                U = max(nw_max, L / f)
                if restrict is not None and isinstance(restrict, np.ndarray):
                    restrict = eng.to_arena(restrict, gg.n, fill=-1)
                clus = eng.cluster(
                    gg, U=U, iters=cfg.lp_iters_coarsen, seed=seed,
                    restrict=restrict,
                )
                coarse, C = eng.contract(gg, clus)
                # stall, or overshoot below k (the initial partitioner needs
                # at least k coarse nodes to seed blocks from)
                if coarse.n >= cfg.shrink_stall * gg.n or coarse.n < k:
                    break
                hierarchy.append((gg, C))
                if restrict is not None:
                    restrict = eng.project_restrict(C, restrict)
            else:
                U = max(float(gg.nw.max()), L / f)
                clus = _cluster(gg, U, cfg.lp_iters_coarsen, seed, restrict, cfg, eng)
                coarse, C = contract(gg, clus)
                if coarse.n >= cfg.shrink_stall * gg.n or coarse.n < k:
                    break
                hierarchy.append((gg, C))
                if restrict is not None:
                    rc = np.zeros(coarse.n, dtype=np.int64)
                    rc[C] = restrict  # consistent: clusters never straddle blocks
                    restrict = rc
            if cycle == 0 and lev == 0:
                shrink_first = coarse.n / max(gg.n, 1)
            gg = coarse
        if cycle == 0:
            level_sizes = [(h[0].n, h[0].m) for h in hierarchy] + [(gg.n, gg.m)]

        # ---------------- initial partitioning ----------------
        seeds = []
        if cur_labels is not None:
            if not isinstance(restrict, np.ndarray):
                restrict = np.asarray(restrict[: gg.n]).astype(np.int64)
            seeds.append(restrict.astype(np.int32))  # projected current solution
        evo = EvoConfig(
            k=k,
            Lmax=L,
            islands=cfg.islands,
            pop_per_island=cfg.pop_per_island,
            generations=cfg.generations,
            refine_iters=cfg.lp_iters_refine,
            seed=int(rng.integers(1 << 30)),
            seed_individuals=seeds,
        )
        use_dev_evo = (
            eng is not None
            and cfg.engine in ("auto", "jnp")
            and cfg.evo_engine in ("auto", "device")
            and eng.can_evolve_device(gg, k, cfg.islands, cfg.pop_per_island)
        )
        if use_dev_evo:
            # the coarsest stage consumes the still-resident GraphDev (or the
            # finest GraphNP) directly: batched device GA, labels stay on
            # device into the uncoarsening projection
            lab = eng.evolve_device(gg, evo, shard=cfg.evo_shard_islands)
        else:
            gg_host = gg.to_host() if isinstance(gg, GraphDev) else gg
            lab = evolve(gg_host, evo)

        # ---------------- uncoarsening + local search ----------------
        lab = _uncoarsen(g, hierarchy, lab, k, L, cfg, rng, eng)
        if cfg.fm_finest and g.n <= cfg.fm_finest_max_n:
            from .fm import fm_refine

            lab = fm_refine(gh, lab, k, L, seed=int(rng.integers(1 << 30)))
        lab = repair_balance(gh, lab, k, L, seed=cfg.seed)
        c = cut_np(gh, lab)
        cycle_cuts.append(c)
        cur_labels = lab.astype(np.int64)
        if c < best_cut:
            best_cut, best_labels = c, lab
        if eng is not None:
            eng.evict(keep=(g,))  # coarse graphs never recur across cycles

    return PartitionReport(
        labels=best_labels,
        cut=float(best_cut),
        imbalance=imbalance_np(gh, best_labels, k),
        feasible=bool(
            np.bincount(best_labels, weights=gh.nw, minlength=k).max() <= L + 1e-6
        ),
        level_sizes=level_sizes,
        shrink_first=shrink_first,
        cycle_cuts=cycle_cuts,
        seconds=time.time() - t0,
        engine_stats=eng.stats_dict() if eng is not None else None,
    )
