"""Size-constrained label propagation (SCLaP) — the paper's core algorithm.

Two modes, exactly as in the paper (§III-A):

* ``cluster`` — coarsening clustering.  Labels live in ``[0, n)`` (initially
  each node is its own cluster), the size bound is ``U = max(max_v c(v),
  L_max / f)`` and the constraint is *soft*.  Traversal order: increasing
  node degree (paper's ordering that improves quality *and* time).
* ``refine``  — local search during uncoarsening.  Labels live in ``[0, k)``,
  the bound is the partitioning problem's own ``U = L_max`` and nodes in an
  *overloaded* block must leave it (their own block is excluded from the
  argmax).  Traversal order: random.

TPU adaptation (DESIGN.md §2): the sequential sweep becomes a
*chunked-sequential* sweep.  Nodes are host-packed into fixed-shape chunks;
a ``lax.fori_loop`` walks chunks sequentially and moves all nodes of a chunk
synchronously.  The per-chunk "strongest eligible cluster" reduction is
sort-based (a single argsort on the fused key ``slot * A + cand`` + run
segmentation) instead of the paper's linear-probing hash tables — hashing is
hostile to TPUs, sorting is native.  Tie-breaking is random via sub-0.5
jitter (valid because all cluster-connection weights are integral for
integer-weight inputs).

The same kernel serves the V-cycle restriction (§IV-D): when ``restrict`` is
given, a node may only join clusters inside its own restriction cell, so cut
edges of the input partition are never contracted.

Shape-bucketing contract (PR 1, consumed by ``repro.core.engine.LPEngine``):
``_lp_sweep`` is written so that one compiled executable serves *every*
level of a multilevel hierarchy once the inputs are padded to a common
bucket shape:

* the label universe size ``num_labels`` and the live chunk count
  ``num_chunks`` are **traced** scalars, not static — padded chunks beyond
  ``num_chunks`` are simply never visited, and label/weight arrays are
  arena-sized (``A >= n + 1``) with +inf weight sentinels above
  ``num_labels``;
* the tie-break jitter is a stateless integer hash of
  ``(seed, iteration, chunk, node slot, candidate label)`` rather than a
  draw from a shape-``(E,)`` PRNG stream, so padding the edge axis cannot
  change any move decision — bucketed and exact-shape packs produce
  *bit-identical* labels (tested in tests/test_engine.py);
* refinement sweeps re-randomize the traversal *per call* (per level, per
  V-cycle) by permuting the chunk visit order **on device** (same hash
  family), which is what lets V-cycles 2..N reuse the packs built in cycle 1
  instead of repacking.  The order is deliberately held fixed across the
  iterations of one call: chunked-synchronous LP needs a stationary visit
  order to damp oscillation (re-shuffling every iteration was measured to
  blow up the cut on the mesh bisection task).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..graph.csr import GraphNP
from ..graph.packing import ChunkPack, pack_chunks

__all__ = [
    "LPResult",
    "lp_cluster",
    "lp_refine",
    "make_order",
    "sclap_numpy",
    "hash_mix_np",
    "hash_base_u32",
    "hash_jitter_np",
    "hash_unit_np",
    "hash_u32_np",
    "sweep_refine_numpy",
]

_NEG = -1e30


@dataclass
class LPResult:
    labels: np.ndarray   # (n,) final labels
    moves: int           # total number of node moves
    iters: int


def make_order(g: GraphNP, mode: str, seed: int) -> np.ndarray:
    """Traversal order: 'degree' (coarsening) or 'random' (refinement)."""
    rng = np.random.default_rng(seed)
    if mode == "degree":
        # increasing degree, random within equal degrees (paper §III-A)
        return np.argsort(g.degrees() + rng.random(g.n), kind="stable").astype(np.int64)
    return rng.permutation(g.n).astype(np.int64)


# --------------------------------------------------------------------------
# jitted chunk sweep
# --------------------------------------------------------------------------


def _hash_mix(h, x):
    """One round of a murmur-style integer mixer (uint32, wrap-around mul)."""
    h = (h ^ x.astype(jnp.uint32)) * jnp.uint32(0xC2B2AE35)
    return h ^ (h >> 15)


def _hash_jitter(base, a, b):
    """Stateless tie-break jitter in [0, 0.49) from integer coordinates.

    Unlike a ``jax.random.uniform(key, (E,))`` draw, the value of each
    element depends only on ``(base, a[i], b[i])`` — never on the array
    *shape* — so padding the edge axis to a bucket size cannot perturb any
    tie-break (the parity guarantee of the bucketed engine).
    """
    h = _hash_mix(_hash_mix(base, a), b)
    return (h & jnp.uint32(0xFFFFFF)).astype(jnp.float32) / float(1 << 24) * 0.49


def _hash_base(seed, it, extra):
    s = (
        seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
        + it.astype(jnp.uint32) * jnp.uint32(0x85EBCA77)
        + jnp.uint32(extra) * jnp.uint32(0x27D4EB2F)
    )
    return _hash_mix(jnp.uint32(0x165667B1), s)


@functools.partial(
    jax.jit,
    static_argnames=("iters", "refine_mode", "use_restrict", "permute_chunks"),
)
def _lp_sweep(
    nodes,          # (C, N) int32, padded with n
    node_valid,     # (C, N) bool
    edge_dst,       # (C, E) int32, padded with n
    edge_w,         # (C, E) f32
    edge_src_slot,  # (C, E) int32
    edge_valid,     # (C, E) bool
    labels,         # (A,) int32 arena, A >= n + 1; slots >= n are unused
    weights,        # (W,) f32 cluster/block weights; slots >= num_labels +inf
    nw_ext,         # (A,) f32 node weights; slots >= n hold 0
    restrict,       # (A,) int32 or (1,) dummy
    U,              # scalar f32
    seed,           # scalar int32 — drives the stateless tie-break hash
    num_labels,     # traced scalar int32 — T: n in cluster mode, k in refine
    num_chunks,     # traced scalar int32 — live chunks; <= C (rest is pad)
    *,
    iters: int,
    refine_mode: bool,
    use_restrict: bool,
    permute_chunks: bool,
):
    C, N = nodes.shape
    E = edge_dst.shape[1]
    A = labels.shape[0]
    sent_lbl = num_labels.astype(jnp.int32)  # padded-weight slot (holds +inf)

    def chunk_step_for(it, perm):
        def chunk_step(c, carry):
            labels, weights, moves = carry
            cc = perm[c]
            nd = nodes[cc]
            ndv = node_valid[cc]
            dst = edge_dst[cc]
            w0 = edge_w[cc]
            slot = edge_src_slot[cc]
            ev = edge_valid[cc]

            lbl_d = labels[dst]                      # candidate label per arc
            src_node = nd[slot]
            if use_restrict:
                ok = ev & (restrict[dst] == restrict[src_node])
            else:
                ok = ev
            cand = jnp.where(ok, lbl_d, sent_lbl).astype(jnp.int32)
            wv = jnp.where(ok, w0, 0.0)

            # ---- sort-based (node, label) run reduction -------------------
            # Packing emits each chunk's arcs grouped by source slot (see
            # graph/packing.py), so the fused key `slot * A + cand` both
            # orders runs correctly and keeps the sort a *single* key pass
            # instead of the two passes of lexsort((cand, slot)).  cand is
            # always <= num_labels < A, so the key is collision-free; the
            # int32 fast path is valid whenever N * A fits in 31 bits.
            if N * A < 2**31:
                perm_e = jnp.argsort(slot * jnp.int32(A) + cand)
            else:
                perm_e = jnp.lexsort((cand, slot))
            s_slot = slot[perm_e]
            s_lbl = cand[perm_e]
            s_w = wv[perm_e]
            new_run = jnp.concatenate(
                [
                    jnp.ones((1,), bool),
                    (s_slot[1:] != s_slot[:-1]) | (s_lbl[1:] != s_lbl[:-1]),
                ]
            )
            run_id = jnp.cumsum(new_run) - 1          # (E,) in [0, E)
            run_w = jnp.zeros((E,), jnp.float32).at[run_id].add(s_w)
            run_slot = jnp.full((E,), N, jnp.int32).at[run_id].set(s_slot)
            run_lbl = jnp.full((E,), sent_lbl, jnp.int32).at[run_id].set(s_lbl)

            # ---- eligibility + scoring -----------------------------------
            own = labels[nd]                          # (N,)
            own_r = own[jnp.minimum(run_slot, N - 1)]
            node_w_r = nw_ext[nd[jnp.minimum(run_slot, N - 1)]]
            cand_w = weights[jnp.minimum(run_lbl, num_labels)]
            fits = cand_w + node_w_r <= U
            if refine_mode:
                own_w = weights[jnp.minimum(own, num_labels)]
                overloaded = own_w[jnp.minimum(run_slot, N - 1)] > U
                eligible = jnp.where(
                    overloaded,
                    fits & (run_lbl != own_r),                     # must leave
                    (run_w > 0) & (fits | (run_lbl == own_r)),
                )
            else:
                eligible = (run_w > 0) & (fits | (run_lbl == own_r))
            eligible &= run_slot < N
            base = _hash_base(seed, it, 0x51ED2701) + cc.astype(jnp.uint32)
            jitter = _hash_jitter(base, run_slot, run_lbl)
            score = jnp.where(eligible, run_w + jitter, _NEG)

            # ---- per-node argmax over runs --------------------------------
            seg = jnp.minimum(run_slot, N)            # runs of padded slots -> N
            best = jnp.full((N + 1,), _NEG, jnp.float32).at[seg].max(score)
            is_best = (score >= best[seg]) & (score > _NEG / 2)
            win = (
                jnp.full((N + 1,), sent_lbl, jnp.int32)
                .at[seg]
                .min(jnp.where(is_best, run_lbl, sent_lbl))
            )[:N]
            new_lbl = jnp.where(ndv & (win < sent_lbl), win, own)

            moved = ndv & (new_lbl != own)
            nwv = nw_ext[nd]
            if refine_mode:
                # Influx gating: every node of a chunk sees the same stale
                # block weights, so a chunk can pile far more weight into a
                # block than its headroom — overshooting U and triggering a
                # synchronous "must leave" stampede out of the now-overloaded
                # block (measured: sustained oscillation at ~chunk-size moves
                # per iteration under unlucky visit orders).  Cap each
                # block's *net* inflow at its headroom in expectation:
                # accept an incoming mover with probability
                # clip((U - w + outflow) / inflow, 0, 1).  Swap-heavy
                # refinement (inflow ~ outflow) passes through untouched.
                mv_w = jnp.where(moved, nwv, 0.0)
                tgt_i = jnp.where(moved, new_lbl, num_labels)
                src_i = jnp.where(moved, own, num_labels)
                zero_w = jnp.zeros(weights.shape, jnp.float32)
                inflow = zero_w.at[tgt_i].add(mv_w, mode="drop")
                outflow = zero_w.at[src_i].add(mv_w, mode="drop")
                head = U - weights + outflow
                p_in = jnp.clip(head / jnp.maximum(inflow, 1e-9), 0.0, 1.0)
                gate_u = _hash_jitter(
                    _hash_base(seed, it, 0x2545F491) + cc.astype(jnp.uint32),
                    nd, new_lbl,
                ) / 0.49
                moved &= gate_u < p_in[jnp.minimum(new_lbl, num_labels)]
                new_lbl = jnp.where(moved, new_lbl, own)
            labels = labels.at[nd].set(jnp.where(ndv, new_lbl, own), mode="drop")
            weights = weights.at[jnp.where(moved, own, num_labels)].add(
                jnp.where(moved, -nwv, 0.0), mode="drop"
            )
            weights = weights.at[jnp.where(moved, new_lbl, num_labels)].add(
                jnp.where(moved, nwv, 0.0), mode="drop"
            )
            # keep the sentinel weight slot at +inf (the adds above target it
            # with value 0 for unmoved nodes; re-pin to be safe)
            weights = weights.at[num_labels].set(jnp.inf)
            moves = moves + jnp.sum(moved)
            return labels, weights, moves

        return chunk_step

    if permute_chunks:
        # Device-side traversal re-randomization: pseudo-random visit order
        # over the *live* chunks, padded chunks sorted last (and never
        # visited — the loop stops at num_chunks).  Hash-based, so
        # independent of the padded chunk-axis size.  The order is fixed for
        # the whole call (it varies with the per-call seed, i.e. per level
        # and per V-cycle): re-shuffling every iteration was measured to
        # *prevent* convergence — chunked-synchronous LP relies on a
        # stationary visit order to damp oscillation, exactly like the
        # sequential oracle converges under any fixed sweep order.
        hc = _hash_mix(
            _hash_base(seed, jnp.int32(0), 0x7F4A7C15),
            jnp.arange(C, dtype=jnp.int32),
        ).astype(jnp.float32)
        hc = hc + jnp.where(jnp.arange(C) >= num_chunks, jnp.float32(1e10), 0.0)
        perm = jnp.argsort(hc).astype(jnp.int32)
    else:
        perm = jnp.arange(C, dtype=jnp.int32)

    def iter_step(it, carry):
        return jax.lax.fori_loop(0, num_chunks, chunk_step_for(it, perm), carry)

    labels, weights, moves = jax.lax.fori_loop(
        0, iters, iter_step, (labels, weights, jnp.zeros((), jnp.int32))
    )
    return labels, weights, moves


# --------------------------------------------------------------------------
# host wrappers
# --------------------------------------------------------------------------


def _ext(arr: np.ndarray, fill) -> np.ndarray:
    return np.concatenate([arr, np.array([fill], dtype=arr.dtype)])


def lp_cluster(
    g: GraphNP,
    U: float,
    iters: int = 3,
    seed: int = 0,
    restrict: Optional[np.ndarray] = None,
    pack: Optional[ChunkPack] = None,
    max_nodes: int = 4096,
    max_edges: int = 65536,
    order: str = "degree",
) -> LPResult:
    """Size-constrained LP *clustering* (coarsening phase)."""
    n = g.n
    if pack is None:
        pack = pack_chunks(
            g, make_order(g, order, seed), max_nodes=max_nodes, max_edges=max_edges
        )
    labels0 = np.arange(n + 1, dtype=np.int32)
    weights0 = _ext(g.nw.astype(np.float32), np.float32(np.inf))
    nw_ext = _ext(g.nw.astype(np.float32), np.float32(0.0))
    if restrict is not None:
        r = _ext(restrict.astype(np.int32), np.int32(-1))
    else:
        r = np.zeros(1, np.int32)  # dummy
    labels, _, moves = _lp_sweep(
        jnp.asarray(pack.nodes),
        jnp.asarray(pack.node_valid),
        jnp.asarray(pack.edge_dst),
        jnp.asarray(pack.edge_w),
        jnp.asarray(pack.edge_src_slot),
        jnp.asarray(pack.edge_valid),
        jnp.asarray(labels0),
        jnp.asarray(weights0),
        jnp.asarray(nw_ext),
        jnp.asarray(r),
        jnp.float32(U),
        jnp.int32(seed & 0x7FFFFFFF),
        jnp.int32(n),
        jnp.int32(pack.num_chunks),
        iters=iters,
        refine_mode=False,
        use_restrict=restrict is not None,
        permute_chunks=False,
    )
    return LPResult(labels=np.asarray(labels[:n]), moves=int(moves), iters=iters)


def lp_refine(
    g: GraphNP,
    labels_in: np.ndarray,
    k: int,
    U: float,
    iters: int = 6,
    seed: int = 0,
    pack: Optional[ChunkPack] = None,
    max_nodes: int = 4096,
    max_edges: int = 65536,
    order: str = "random",
) -> LPResult:
    """Size-constrained LP as *local search* (uncoarsening phase)."""
    n = g.n
    if pack is None:
        pack = pack_chunks(
            g, make_order(g, order, seed), max_nodes=max_nodes, max_edges=max_edges
        )
    labels0 = _ext(labels_in.astype(np.int32), np.int32(k))
    bw = np.bincount(labels_in, weights=g.nw, minlength=k)[:k].astype(np.float32)
    weights0 = _ext(bw, np.float32(np.inf))
    nw_ext = _ext(g.nw.astype(np.float32), np.float32(0.0))
    labels, _, moves = _lp_sweep(
        jnp.asarray(pack.nodes),
        jnp.asarray(pack.node_valid),
        jnp.asarray(pack.edge_dst),
        jnp.asarray(pack.edge_w),
        jnp.asarray(pack.edge_src_slot),
        jnp.asarray(pack.edge_valid),
        jnp.asarray(labels0),
        jnp.asarray(weights0),
        jnp.asarray(nw_ext),
        jnp.zeros(1, jnp.int32),
        jnp.float32(U),
        jnp.int32(seed & 0x7FFFFFFF),
        jnp.int32(k),
        jnp.int32(pack.num_chunks),
        iters=iters,
        refine_mode=True,
        use_restrict=False,
        permute_chunks=False,
    )
    return LPResult(labels=np.asarray(labels[:n]), moves=int(moves), iters=iters)


# --------------------------------------------------------------------------
# numpy mirrors of the device hash family (bit-exact)
#
# The batched evolutionary engine's parity oracle (repro.core.evolutionary)
# re-derives every tie-break and gate on host, so the uint32 mixer above
# needs exact numpy twins.  Scalar mixing runs in python ints masked to 32
# bits (numpy SCALAR uint32 overflow warns; python ints don't); array mixing
# runs on uint32 ndarrays, whose overflow wraps silently.  All float steps
# are forced to float32 so IEEE results match XLA bit-for-bit.
# --------------------------------------------------------------------------

_M32 = 0xFFFFFFFF


def hash_u32_scalar(h: int, x: int) -> int:
    """Scalar twin of ``_hash_mix`` (python ints, wrap-around 32-bit)."""
    h = ((h ^ (x & _M32)) * 0xC2B2AE35) & _M32
    return h ^ (h >> 15)


def hash_base_u32(seed: int, it: int, extra: int) -> int:
    """Scalar twin of ``_hash_base``; returns a python int in [0, 2^32)."""
    s = (
        (seed & _M32) * 0x9E3779B1
        + (it & _M32) * 0x85EBCA77
        + (extra & _M32) * 0x27D4EB2F
    ) & _M32
    return hash_u32_scalar(0x165667B1, s)


def hash_mix_np(h, x):
    """Array twin of ``_hash_mix``: h is a python int or uint32 array."""
    xa = np.asarray(x)
    if isinstance(h, (int, np.integer)) and xa.ndim == 0:
        return np.uint32(hash_u32_scalar(int(h) & _M32, int(xa)))
    if isinstance(h, (int, np.integer)):
        h = np.uint32(h & _M32)
    h = (h ^ xa.astype(np.uint32)) * np.uint32(0xC2B2AE35)
    return h ^ (h >> np.uint32(15))


def hash_jitter_np(base, a, b) -> np.ndarray:
    """Array twin of ``_hash_jitter``: float32 jitter in [0, 0.49)."""
    h = hash_mix_np(hash_mix_np(base, a), b)
    return (
        (h & np.uint32(0xFFFFFF)).astype(np.float32)
        / np.float32(1 << 24)
        * np.float32(0.49)
    )


def hash_unit_np(base, a, b) -> np.ndarray:
    """Uniform-ish float32 in [0, 1) from integer coordinates (array twin of
    the device ``_hash_unit`` in repro.core.evo_device)."""
    h = hash_mix_np(hash_mix_np(base, a), b)
    return (h & np.uint32(0xFFFFFF)).astype(np.float32) / np.float32(1 << 24)


def hash_u32_np(base, a, b) -> np.ndarray:
    """Raw uint32 stream from integer coordinates (array twin of
    ``_hash_u32``)."""
    return hash_mix_np(hash_mix_np(base, a), b)


def sweep_refine_numpy(
    nodes: np.ndarray,          # (C, N) int32 pack layout (padded, sentinel n)
    node_valid: np.ndarray,     # (C, N) bool
    edge_dst: np.ndarray,       # (C, E) int32
    edge_w: np.ndarray,         # (C, E) float32
    edge_src_slot: np.ndarray,  # (C, E) int32
    edge_valid: np.ndarray,     # (C, E) bool
    labels: np.ndarray,         # (A,) int32, A >= n + 1; k beyond n
    weights: np.ndarray,        # (W,) float32 block weights; +inf at slots >= k
    nw_ext: np.ndarray,         # (A,) float32 node weights, 0 beyond n
    U: float,
    seed: int,
    num_labels: int,            # k
    num_chunks: int,
    iters: int,
) -> tuple:
    """Bit-exact numpy mirror of ``_lp_sweep(refine_mode=True,
    use_restrict=False, permute_chunks=True)``.

    This is the parity oracle the batched evolutionary engine refines
    against: same chunk visit permutation, same (slot, label) run sums, same
    stateless tie-break jitter, same influx gating, same weight updates.
    Bit-identity holds for integral node/edge weights (float32 sums are then
    exact in any order — the same precondition the device path is gated on);
    see tests/test_evo_device.py.  Returns ``(labels, weights)`` copies.
    """
    C, N = nodes.shape
    labels = labels.astype(np.int32).copy()
    weights = weights.astype(np.float32).copy()
    U = np.float32(U)
    k = int(num_labels)
    NEG = np.float32(_NEG)
    # device-side chunk visit permutation (uint32 hash -> f32, stable sort)
    hc = hash_mix_np(
        hash_base_u32(seed, 0, 0x7F4A7C15), np.arange(C, dtype=np.int32)
    ).astype(np.float32)
    hc = hc + np.where(
        np.arange(C) >= num_chunks, np.float32(1e10), np.float32(0.0)
    )
    perm = np.argsort(hc, kind="stable")
    for it in range(iters):
        base1 = hash_base_u32(seed, it, 0x51ED2701)
        base2 = hash_base_u32(seed, it, 0x2545F491)
        for ci in range(num_chunks):
            cc = int(perm[ci])
            nd = nodes[cc]
            ndv = node_valid[cc]
            ev = edge_valid[cc]
            dst = edge_dst[cc][ev]
            w0 = edge_w[cc][ev].astype(np.float32)
            slot = edge_src_slot[cc][ev]
            cand = labels[dst].astype(np.int64)
            # ---- (slot, label) run reduction (order-independent: integral
            # weights make the float32 segment sums exact) ----
            key = slot.astype(np.int64) * np.int64(k + 1) + cand
            uniq, inv = np.unique(key, return_inverse=True)
            run_w = np.zeros(uniq.shape[0], np.float32)
            np.add.at(run_w, inv, w0)
            run_slot = (uniq // (k + 1)).astype(np.int32)
            run_lbl = (uniq % (k + 1)).astype(np.int32)
            # ---- eligibility + scoring (mirror of the device rules) ----
            own = labels[nd]                       # (N,) label k at sentinels
            own_r = own[run_slot]
            node_w_r = nw_ext[nd[run_slot]]
            cand_w = weights[np.minimum(run_lbl, k)]
            fits = cand_w + node_w_r <= U
            overloaded = weights[np.minimum(own_r, k)] > U
            eligible = np.where(
                overloaded,
                fits & (run_lbl != own_r),
                (run_w > 0) & (fits | (run_lbl == own_r)),
            )
            base_c = (base1 + cc) & _M32
            jitter = hash_jitter_np(base_c, run_slot, run_lbl)
            score = np.where(eligible, run_w + jitter, NEG)
            # ---- per-node argmax with min-label tie-break ----
            best = np.full(N + 1, NEG, np.float32)
            np.maximum.at(best, run_slot, score)
            is_best = (score >= best[run_slot]) & (score > NEG / 2)
            win = np.full(N + 1, k, np.int32)
            np.minimum.at(
                win, run_slot, np.where(is_best, run_lbl, np.int32(k))
            )
            win = win[:N]
            new_lbl = np.where(ndv & (win < k), win, own).astype(np.int32)
            moved = ndv & (new_lbl != own)
            nwv = nw_ext[nd]
            # ---- influx gating (same expectation cap as the device) ----
            mv_w = np.where(moved, nwv, np.float32(0.0)).astype(np.float32)
            inflow = np.zeros(weights.shape[0], np.float32)
            outflow = np.zeros(weights.shape[0], np.float32)
            np.add.at(inflow, np.where(moved, new_lbl, k), mv_w)
            np.add.at(outflow, np.where(moved, own, k), mv_w)
            head = (U - weights + outflow).astype(np.float32)
            with np.errstate(invalid="ignore", over="ignore"):
                p_in = np.clip(
                    head / np.maximum(inflow, np.float32(1e-9)),
                    np.float32(0.0),
                    np.float32(1.0),
                )
            gate_u = hash_jitter_np(
                (base2 + cc) & _M32, nd, new_lbl
            ) / np.float32(0.49)
            moved &= gate_u < p_in[np.minimum(new_lbl, k)]
            new_lbl = np.where(moved, new_lbl, own).astype(np.int32)
            labels[nd[ndv]] = new_lbl[ndv]
            np.add.at(
                weights, np.where(moved, own, k),
                np.where(moved, -nwv, np.float32(0.0)).astype(np.float32),
            )
            np.add.at(
                weights, np.where(moved, new_lbl, k),
                np.where(moved, nwv, np.float32(0.0)).astype(np.float32),
            )
            weights[k] = np.inf
    return labels, weights


# --------------------------------------------------------------------------
# numpy reference: the paper's exact sequential semantics (used as test
# oracle and for the small coarsest-level graphs inside the evolutionary
# algorithm, where python-loop costs are negligible)
# --------------------------------------------------------------------------


def sclap_numpy(
    g: GraphNP,
    labels: np.ndarray,
    U: float,
    iters: int,
    seed: int = 0,
    refine_mode: bool = False,
    num_labels: Optional[int] = None,
    restrict: Optional[np.ndarray] = None,
    order: Optional[str] = None,
) -> LPResult:
    """Asynchronous sequential SCLaP — one node at a time, moves instantly
    visible (the paper's original sequential algorithm)."""
    rng = np.random.default_rng(seed)
    labels = labels.astype(np.int64).copy()
    T = num_labels if num_labels is not None else g.n
    weights = np.zeros(T, dtype=np.float64)
    np.add.at(weights, labels, g.nw)
    if order is None:
        order = "random" if refine_mode else "degree"
    total_moves = 0
    for it in range(iters):
        perm = make_order(g, order, seed + 17 * it)
        for v in perm:
            lo, hi = g.indptr[v], g.indptr[v + 1]
            if hi == lo:
                continue
            nbr = g.indices[lo:hi]
            wts = g.ew[lo:hi].astype(np.float64)
            lbl = labels[nbr]
            if restrict is not None:
                m = restrict[nbr] == restrict[v]
                nbr, wts, lbl = nbr[m], wts[m], lbl[m]
                if nbr.size == 0:
                    continue
            cand, inv = np.unique(lbl, return_inverse=True)
            conn = np.zeros(cand.shape[0])
            np.add.at(conn, inv, wts)
            own = labels[v]
            nw_v = g.nw[v]
            fits = weights[cand] + nw_v <= U
            if refine_mode and weights[own] > U:
                elig = fits & (cand != own)
            else:
                elig = (conn > 0) & (fits | (cand == own))
            if not elig.any():
                continue
            conn = conn + rng.random(conn.shape[0]) * 0.49
            conn[~elig] = -np.inf
            tgt = cand[int(np.argmax(conn))]
            if tgt != own:
                weights[own] -= nw_v
                weights[tgt] += nw_v
                labels[v] = tgt
                total_moves += 1
    return LPResult(labels=labels.astype(np.int32), moves=total_moves, iters=iters)
