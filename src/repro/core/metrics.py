"""Partition quality metrics: edge cut, balance, quotient graph, comm volume."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..graph.csr import Graph, GraphNP

__all__ = [
    "cut_np",
    "cut_jnp",
    "cut_from_arcs_jnp",
    "block_weights_np",
    "block_weights_dense_jnp",
    "imbalance_np",
    "is_feasible",
    "quotient_graph_np",
    "comm_volume_np",
]


def cut_from_arcs_jnp(labels, src, dst, ew):
    """Edge cut from flat arc arrays on device (one individual; ``vmap`` the
    labels axis for a population batch).  Trailing zero-weight arc padding is
    inert; for integral weights the f32 sum is exact in any order — the
    batched evolutionary fitness relies on that exactness."""
    diff = labels[src] != labels[dst]
    return jnp.sum(jnp.where(diff, ew, 0.0)) / 2.0


def block_weights_dense_jnp(labels, nw, k, Kb: int):
    """(Kb,) block weights of arena labels on device: slots >= ``k`` (traced)
    collect the arena's sentinel label with weight 0 — inert.  Returns the
    raw vector; callers mask or +inf-pad the dead slots as needed."""
    return jnp.zeros((Kb,), jnp.float32).at[labels].add(nw)


def cut_np(g: GraphNP, labels: np.ndarray) -> float:
    """Total weight of edges between blocks (each undirected edge once)."""
    src = g.arc_sources()
    diff = labels[src] != labels[g.indices]
    return float(g.ew[diff].sum() / 2.0)


def cut_jnp(g: Graph, labels: jnp.ndarray) -> jnp.ndarray:
    src = g.arc_sources()
    diff = labels[src] != labels[g.indices]
    return jnp.sum(jnp.where(diff, g.ew, 0.0)) / 2.0


def block_weights_np(g: GraphNP, labels: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(labels, weights=g.nw, minlength=k)[:k]


def lmax(total_weight: float, k: int, eps: float) -> float:
    """The balance bound L_max = (1 + eps) * ceil(c(V) / k)."""
    return (1.0 + eps) * np.ceil(total_weight / k)


def imbalance_np(g: GraphNP, labels: np.ndarray, k: int) -> float:
    """max_i c(V_i) * k / c(V) - 1  (0.0 == perfectly balanced)."""
    bw = block_weights_np(g, labels, k)
    return float(bw.max() * k / max(g.total_node_weight, 1e-12) - 1.0)


def is_feasible(g: GraphNP, labels: np.ndarray, k: int, eps: float) -> bool:
    bw = block_weights_np(g, labels, k)
    return bool(bw.max() <= lmax(g.total_node_weight, k, eps) + 1e-6)


def quotient_graph_np(g: GraphNP, labels: np.ndarray, k: int):
    """Weighted quotient graph: (k,k) dense inter-block weight matrix + block weights."""
    src = g.arc_sources()
    dst = g.indices
    q = np.zeros((k, k), dtype=np.float64)
    np.add.at(q, (labels[src], labels[dst]), g.ew)
    np.fill_diagonal(q, 0.0)
    return q / 2.0, block_weights_np(g, labels, k)


def comm_volume_np(g: GraphNP, labels: np.ndarray, k: int) -> float:
    """Total communication volume: sum over v of #distinct foreign blocks adjacent."""
    src = g.arc_sources().astype(np.int64)
    dst_lbl = labels[g.indices].astype(np.int64)
    key = src * np.int64(k + 1) + dst_lbl
    uniq = np.unique(key)
    usrc = uniq // (k + 1)
    ulbl = uniq % (k + 1)
    return float((ulbl != labels[usrc]).sum())
