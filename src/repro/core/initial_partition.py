"""Initial partitioning of the coarsest graph.

The paper delegates this to KaFFPaE (see evolutionary.py); the individuals
of its population are created here by *greedy graph growing*: k seeds grow
breadth-first, each unassigned node joining the eligible adjacent block with
the strongest connection, followed by SCLaP refinement.  The coarsest graph
has <= coarsest_factor * k nodes by construction, so this is host/numpy code
operating on a replicated graph — exactly the paper's setting (§IV-E: "the
distributed coarse graph is then collected on each PE").
"""

from __future__ import annotations

import numpy as np

from ..graph.csr import GraphNP
from .label_propagation import sclap_numpy
from .metrics import block_weights_np, cut_np

__all__ = ["greedy_growing", "repair_balance", "initial_partition"]


def greedy_growing(g: GraphNP, k: int, Lmax: float, seed: int = 0) -> np.ndarray:
    """Grow k blocks from random seeds under the balance bound L_max."""
    rng = np.random.default_rng(seed)
    n = g.n
    if k >= n:
        # degenerate coarsest graph: the degree-biased seed draw cannot pick
        # k distinct nodes (rng.choice(n, size=k, replace=False) raises), so
        # every node founds its own block round-robin — trivially balanced,
        # and blocks >= n simply stay empty.
        return (np.arange(n) % max(k, 1)).astype(np.int32)
    labels = np.full(n, -1, dtype=np.int64)
    deg = g.degrees().astype(np.float64)
    # degree-biased seeds: grow from inside components, not from isolated nodes
    p = (deg + 1.0) / (deg + 1.0).sum()
    seeds = rng.choice(n, size=k, replace=False, p=p)
    labels[seeds] = np.arange(k)
    bw = g.nw[seeds].astype(np.float64).copy()

    src = g.arc_sources()
    for _ in range(n):  # at most n frontier rounds
        unassigned = labels < 0
        if not unassigned.any():
            break
        # arcs from unassigned -> assigned
        m = unassigned[src] & (labels[g.indices] >= 0)
        if not m.any():
            # frontier died (disconnected graph): reseed the lightest block at
            # the highest-degree unassigned node; isolated leftovers are pure
            # ballast and go to the lightest block (bin packing, no cut cost)
            rest = np.flatnonzero(unassigned)
            if deg[rest].max() == 0:
                for v in rest[np.argsort(-g.nw[rest], kind="stable")]:
                    b = int(np.argmin(bw))
                    labels[v] = b
                    bw[b] += g.nw[v]
                break
            v = rest[int(np.argmax(deg[rest] + rng.random(rest.size)))]
            b = int(np.argmin(bw))
            labels[v] = b
            bw[b] += g.nw[v]
            continue
        fsrc = src[m]
        flbl = labels[g.indices[m]]
        fw = g.ew[m].astype(np.float64)
        # connection strength of each frontier node to each block
        conn = np.zeros((n, k))
        np.add.at(conn, (fsrc, flbl), fw)
        frontier = np.unique(fsrc)
        rng.shuffle(frontier)
        for v in frontier:  # sequential for exact balance accounting
            c = conn[v] + rng.random(k) * 0.49
            c[bw + g.nw[v] > Lmax] = -np.inf
            b = int(np.argmax(c))
            if c[b] == -np.inf:
                continue  # no block fits; retry next round (Lmax may free up)
            labels[v] = b
            bw[b] += g.nw[v]
        if (labels[frontier] < 0).all():
            # everything blocked on balance: relax by assigning to lightest
            for v in frontier:
                b = int(np.argmin(bw))
                labels[v] = b
                bw[b] += g.nw[v]
    return labels.astype(np.int32)


def repair_balance(
    g: GraphNP, labels: np.ndarray, k: int, Lmax: float, seed: int = 0
) -> np.ndarray:
    """Force feasibility: move lowest-internal-connection nodes out of
    overloaded blocks into the lightest block that fits."""
    labels = labels.astype(np.int64).copy()
    bw = block_weights_np(g, labels, k).astype(np.float64)
    if bw.max() <= Lmax:
        return labels.astype(np.int32)
    src = g.arc_sources()
    internal = np.zeros(g.n)
    same = labels[src] == labels[g.indices]
    np.add.at(internal, src[same], g.ew[same])
    order = np.argsort(internal, kind="stable")  # cheapest-to-move first
    for v in order:
        b = labels[v]
        if bw[b] <= Lmax:
            continue
        tgt = int(np.argmin(bw))
        if bw[tgt] + g.nw[v] > Lmax or tgt == b:
            continue
        labels[v] = tgt
        bw[b] -= g.nw[v]
        bw[tgt] += g.nw[v]
        if bw.max() <= Lmax:
            break
    return labels.astype(np.int32)


def initial_partition(
    g: GraphNP,
    k: int,
    Lmax: float,
    seed: int = 0,
    refine_iters: int = 6,
) -> np.ndarray:
    """One greedy-growing individual + SCLaP + FM refinement."""
    from .fm import fm_refine

    labels = greedy_growing(g, k, Lmax, seed=seed)
    labels = sclap_numpy(
        g, labels, U=Lmax, iters=refine_iters, seed=seed, refine_mode=True, num_labels=k
    ).labels
    labels = fm_refine(g, labels, k, Lmax, seed=seed)
    return repair_balance(g, labels, k, Lmax, seed=seed)


def best_of(g: GraphNP, cands: list[np.ndarray], k: int, Lmax: float) -> np.ndarray:
    """Pick the feasible candidate with the smallest cut (fallback: min cut)."""
    feasible = [c for c in cands if block_weights_np(g, c, k).max() <= Lmax + 1e-6]
    pool = feasible if feasible else cands
    cuts = [cut_np(g, c) for c in pool]
    return pool[int(np.argmin(cuts))]
