"""Deterministic synthetic token pipeline with sharded, resumable batches.

Deterministic-by-step: batch(step) is a pure function of (seed, step), so a
restarted job replays the exact stream from its checkpoint cursor — the data
half of the fault-tolerance story.  A Zipf-ish unigram mixture with induced
bigram structure gives the LM something learnable (loss drops well below
log(V) within a few hundred steps on small models).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["TokenPipeline"]


@dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    n_prefix: int = 0
    d_model: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        # zipf unigrams
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(self.vocab, size=(self.batch, self.seq), p=probs)
        # induced structure: with p=0.5, next token = (prev * 31 + 7) % vocab
        # (applied column-by-column so the bigram chain is consistent)
        mask = rng.random((self.batch, self.seq - 1)) < 0.5
        for j in range(1, self.seq):
            nxt = (toks[:, j - 1] * 31 + 7) % self.vocab
            toks[:, j] = np.where(mask[:, j - 1], nxt, toks[:, j])
        out = {"tokens": toks.astype(np.int32)}
        if self.n_prefix:
            out["prefix_embeds"] = rng.standard_normal(
                (self.batch, self.n_prefix, self.d_model), dtype=np.float32
            )
        return out
