"""qwen2.5-3b [dense]: 36L d_model=2048 16H (GQA kv=2) d_ff=11008
vocab=151936 — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b", family="dense",
    n_layers=36, d_model=2048, n_heads=16, n_kv_heads=2, head_dim=128,
    d_ff=11008, vocab=151936, qkv_bias=True, glu=True, act="silu",
    rope_theta=1_000_000.0,
    pattern_unit=("attn",), ffn_unit=("dense",),
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)
