"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (kv=32 => MHA) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP frontend (STUB: precomputed patch
embeddings) [hf:microsoft/Phi-3-vision-128k-instruct; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab=32064, qkv_bias=False, glu=True, act="silu",
    pattern_unit=("attn",), ffn_unit=("dense",),
    frontend="vision", n_prefix=576,   # 24x24 CLIP patches
    source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
)
