"""musicgen-large [audio]: 48L d_model=2048 32H (kv=32 => MHA) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].
The EnCodec/conditioning frontend is a STUB: input_specs provide
precomputed conditioning frame embeddings (prefix_embeds)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, qkv_bias=False, glu=False, act="gelu",
    pattern_unit=("attn",), ffn_unit=("dense",),
    frontend="audio", n_prefix=64,
    source="arXiv:2306.05284; hf",
)
