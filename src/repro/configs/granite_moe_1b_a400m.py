"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=0, vocab=49155, qkv_bias=False, glu=True, act="silu",
    pattern_unit=("attn",), ffn_unit=("moe",),
    moe=MoESpec(n_experts=32, topk=8, d_ff=512),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
