"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4, fine-grained
[hf:databricks/dbrx-base; unverified]."""
from .base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab=100352, qkv_bias=False, glu=True, act="silu",
    rope_theta=500_000.0,
    pattern_unit=("attn",), ffn_unit=("moe",),
    moe=MoESpec(n_experts=16, topk=4, d_ff=10752),
    source="hf:databricks/dbrx-base; unverified",
)
