"""--arch <id> registry for all assigned architectures."""

from .base import SHAPES, ArchConfig, Shape
from .dbrx_132b import CONFIG as _dbrx
from .gemma3_27b import CONFIG as _gemma3
from .granite_moe_1b_a400m import CONFIG as _granite
from .internlm2_20b import CONFIG as _internlm2
from .jamba_1_5_large_398b import CONFIG as _jamba
from .mamba2_2_7b import CONFIG as _mamba2
from .musicgen_large import CONFIG as _musicgen
from .phi_3_vision_4_2b import CONFIG as _phi3v
from .qwen1_5_110b import CONFIG as _qwen110
from .qwen2_5_3b import CONFIG as _qwen3b

ARCHS = {
    "qwen2.5-3b": _qwen3b,
    "qwen1.5-110b": _qwen110,
    "gemma3-27b": _gemma3,
    "internlm2-20b": _internlm2,
    "musicgen-large": _musicgen,
    "phi-3-vision-4.2b": _phi3v,
    "mamba2-2.7b": _mamba2,
    "dbrx-132b": _dbrx,
    "granite-moe-1b-a400m": _granite,
    "jamba-1.5-large-398b": _jamba,
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def cells():
    """All (arch, shape) dry-run cells, with skip reasons where applicable."""
    out = []
    for aid, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            skip = None
            if sname == "long_500k" and not cfg.sub_quadratic:
                skip = "pure full-attention stack: no sub-quadratic mechanism"
            out.append((aid, sname, skip))
    return out
