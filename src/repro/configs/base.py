"""Architecture & shape configuration schema for the assigned-arch pool."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

__all__ = ["MoESpec", "SSMSpec", "ArchConfig", "Shape", "SHAPES"]


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    topk: int
    d_ff: int                 # per-expert hidden size
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMSpec:
    d_state: int = 128
    headdim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                 # dense-FFN hidden size (0 = no dense FFN)
    vocab: int
    qkv_bias: bool = False
    glu: bool = True
    act: str = "silu"
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    norm_eps: float = 1e-6
    head_dim: Optional[int] = None          # default d_model // n_heads
    sliding_window: Optional[int] = None    # width for "attn_local" layers
    # repeating layer pattern; the stack is the unit repeated (+ remainder)
    pattern_unit: Tuple[str, ...] = ("attn",)        # attn | attn_local | mamba
    ffn_unit: Tuple[str, ...] = ("dense",)           # dense | moe | none
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    tie_embeddings: bool = False
    frontend: Optional[str] = None          # "audio" | "vision" (stub embeds)
    n_prefix: int = 0                       # stub frontend prefix length
    sub_quadratic: bool = False             # eligible for long_500k
    dtype: str = "bfloat16"
    source: str = ""                        # provenance tag

    @property
    def d_head(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def layer_plan(self):
        """Full per-layer (mix, ffn) list of length n_layers."""
        u, f = self.pattern_unit, self.ffn_unit
        assert len(u) == len(f), (self.name, u, f)
        plan = []
        while len(plan) < self.n_layers:
            for m, ff in zip(u, f):
                plan.append((m, ff))
        return plan[: self.n_layers]

    def scan_split(self):
        """(n_units, unit, remainder_plan): scan over whole units."""
        u = len(self.pattern_unit)
        n_units = self.n_layers // u
        rem = self.layer_plan()[n_units * u :]
        return n_units, list(zip(self.pattern_unit, self.ffn_unit)), rem

    def smoke(self) -> "ArchConfig":
        """Reduced same-family config: one forward/train step on CPU."""
        unit = len(self.pattern_unit)
        moe = (
            replace(self.moe, n_experts=min(self.moe.n_experts, 4),
                    topk=min(self.moe.topk, 2), d_ff=64)
            if self.moe
            else None
        )
        ssm = replace(self.ssm, d_state=16, headdim=8, chunk=16) if self.ssm else None
        kv = max(1, min(self.n_kv_heads, 2))
        heads = max(kv, 4 - (4 % kv))
        return replace(
            self,
            name=self.name + "-smoke",
            # two scanned units + a remainder layer iff the real config has one
            n_layers=2 * unit + (1 if self.n_layers % unit else 0),
            d_model=64,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            sliding_window=8 if self.sliding_window else None,
            moe=moe,
            ssm=ssm,
            n_prefix=4 if self.frontend else 0,
            dtype="float32",
        )


@dataclass(frozen=True)
class Shape:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4_096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32_768, 128),
    "long_500k": Shape("long_500k", "decode", 524_288, 1),
}
