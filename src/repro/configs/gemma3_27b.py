"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding-window, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144, qkv_bias=False, glu=True, act="gelu",
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
    sliding_window=1024,
    # 5 local : 1 global, repeated; 62 = 10 units + 2 remainder (local)
    pattern_unit=("attn_local",) * 5 + ("attn",),
    ffn_unit=("dense",) * 6,
    sub_quadratic=True,  # 5/6 of layers have O(S*w) attention + windowed KV
    source="hf:google/gemma-3-1b-pt; unverified",
)
