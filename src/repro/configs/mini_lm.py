"""mini-lm: a ~60M-param dense LM for the end-to-end CPU training demo
(deliverable: train a ~100M-class model for a few hundred steps).  NOT part
of the assigned-architecture pool (excluded from the dry-run cell grid)."""
from .base import ArchConfig

MINI_LM = ArchConfig(
    name="mini-lm", family="dense",
    n_layers=12, d_model=512, n_heads=8, n_kv_heads=2, head_dim=64,
    d_ff=2048, vocab=16384, qkv_bias=False, glu=True, act="silu",
    pattern_unit=("attn",), ffn_unit=("dense",),
    dtype="float32",
    source="local demo config",
)
