"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2 — Mamba+attention 1:7 interleave, MoE every
other layer [arXiv:2403.19887; hf]."""
from .base import ArchConfig, MoESpec, SSMSpec

# one Jamba block = 8 layers: attention at position 4, mamba elsewhere;
# MoE on odd positions (every other layer), dense FFN on even positions.
_MIX = ("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba")
_FFN = ("dense", "moe") * 4

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab=65536, qkv_bias=False, glu=True, act="silu",
    pattern_unit=_MIX, ffn_unit=_FFN,
    moe=MoESpec(n_experts=16, topk=2, d_ff=24576),
    ssm=SSMSpec(d_state=128, headdim=64, expand=2, conv_width=4, chunk=256),
    sub_quadratic=True,
    source="arXiv:2403.19887; hf",
)
