from .base import SHAPES, ArchConfig, MoESpec, Shape, SSMSpec
from .registry import ARCHS, cells, get_config

__all__ = ["ArchConfig", "MoESpec", "SSMSpec", "Shape", "SHAPES", "ARCHS",
           "get_config", "cells"]
