"""mamba2-2.7b [ssm]: 64L d_model=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
from .base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1,  # attention unused
    d_ff=0, vocab=50280, glu=True, act="silu",
    pattern_unit=("mamba",), ffn_unit=("none",),
    ssm=SSMSpec(d_state=128, headdim=64, expand=2, conv_width=4, chunk=256),
    tie_embeddings=True,
    sub_quadratic=True,
    source="arXiv:2405.21060; unverified",
)
