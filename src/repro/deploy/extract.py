"""Device-resident block shard extraction (deployment subsystem, layer 1).

A partition only earns its keep when it is *consumed*: each block becomes a
PE-local subgraph with ghost copies of remote neighbours and a fixed
interface-exchange schedule (paper §IV-A; dKaMinPar ships exactly these
per-block artifacts, DGL's ``partition_graph`` defines the same halo/id-map
output contract on the serving side).  This module turns a resident
CSR (:class:`~repro.graph.csr.GraphDev`, e.g. the dynamic store's base) and
a label array into one :class:`BlockShard` per block, **entirely on
device**:

* **h-ring halo** — a multi-source BFS layering per block
  (:func:`_shard_masks`: one frontier scatter per ring over the resident
  arc arrays, the deploy twin of ``dynamic.repair.expand_region_device``)
  assigns every node its hop distance from the block; ring ``r`` ghosts are
  the nodes at distance ``r`` in ``[1, h]``.
* **local id space** — owned nodes first (ascending global id), then ghosts
  ring by ring (ascending global id within a ring): ONE stable value-sort +
  scatter-rank relabel, the PR-2 contraction idiom.  Rows
  ``[0, n_rows)`` with ``n_rows = #{hop < h}`` (owned + interior ghosts)
  carry adjacency — every neighbour of a row is inside the shard, so h-hop
  computations rooted at owned nodes never leave it.
* **block-local CSR** — the O(m) edge fill *is*
  :func:`~repro.graph.packing.gather_pack_device` (called inside the jit,
  so it inlines: one bucketed executable per ``(block-size, halo-size)``
  bucket) over a single-chunk row layout, followed by the global→local head
  remap.  Padding follows the GraphDev invariants (rows >= n_rows hold
  ``m_local``, arcs >= m_local are 0/0).
* **exchange schedule** — ghosts carry their owning block; the cross-block
  (owner, slot) scatter maps and per-neighbour-block send lists are
  assembled on host from the O(boundary) id lists
  (:func:`assemble_schedule`, the deploy analogue of
  ``distributed_lp.build_plan``): every block packs the payload of its
  interface nodes in slot order, one all_gather moves the stacked buffers,
  and ``bufs[ghost_block, ghost_slot]`` fills every ghost table.

Only the ``(n_own, n_ghost, n_rows, m_local)`` scalars cross to host per
block; all shapes are shape-bucketed with traced live counts so a steady
extraction/migration stream compiles once per bucket
(``deploy_compiles == deploy_bucket_count`` — regression-tested).  The
host oracle :func:`extract_blocks_numpy` is bit-identical to the device
path, and :func:`reassemble` glues the owned rows of all shards back into
the exact global CSR (same arc order, same float bits) — the contract the
tests pin.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Union

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..graph.csr import GraphDev, GraphNP, arc_bucket, pow2, to_device_csr
from ..graph.packing import gather_pack_device
from ..obs import RegistryBackedStats
from ..obs import watchdog as _obs_watchdog
from ..obs.memory import account as _mem_account

__all__ = [
    "BlockShard",
    "BlockShardNP",
    "BlockExtractor",
    "DeployStats",
    "assemble_schedule",
    "extract_blocks_numpy",
    "ghost_exchange_numpy",
    "reassemble",
]

AnyGraph = Union[GraphNP, GraphDev]

_BIG = np.int32(0x7FFFFFF)  # hop sentinel: outside the halo (> any real h)


# --------------------------------------------------------------------------
# device kernels
# --------------------------------------------------------------------------


@jax.jit
def _shard_masks(lab, src, dst, indptr, b, n, h):
    """Hop layering + shard size counts for block ``b`` (one executable per
    ``(Nb, Mb)`` CSR bucket, shared by every block and halo depth).

    Returns ``(hop, n_own, n_ghost, n_rows, m_local)``: hop 0 = owned,
    ``r in [1, h]`` = ring-r ghost, ``_BIG`` = outside.  Trailing padding
    arcs are (0, 0) and only ever re-mark node 0 from itself — inert, the
    same argument as ``expand_region_device``.
    """
    Nb = indptr.shape[0] - 1
    iota = jnp.arange(Nb, dtype=jnp.int32)
    own = (lab == b) & (iota < n)
    hop = jnp.where(own, 0, jnp.int32(_BIG))

    def ring(r, hp):
        reach = jnp.zeros((Nb,), jnp.bool_).at[dst].max(hp[src] <= r)
        return jnp.where(reach & (hp > r + 1), r + 1, hp)

    hop = lax.fori_loop(0, h, ring, hop)
    deg = jnp.where(iota < n, indptr[1:] - indptr[:-1], 0)
    is_ghost = (hop >= 1) & (hop <= h)
    is_row = hop < h  # owned + interior ghosts: full adjacency in-shard
    n_own = jnp.sum(own).astype(jnp.int32)
    n_ghost = jnp.sum(is_ghost).astype(jnp.int32)
    n_rows = jnp.sum(is_row).astype(jnp.int32)
    m_local = jnp.sum(jnp.where(is_row, deg, 0)).astype(jnp.int32)
    return hop, n_own, n_ghost, n_rows, m_local


@functools.partial(jax.jit, static_argnames=("Ob", "Gb", "Eb"))
def _shard_extract(hop, lab, indptr, indices, ew, nw, n, h,
                   n_own, n_ghost, n_rows, m_local, *, Ob: int, Gb: int,
                   Eb: int):
    """The shard materialization: ONE bucketed executable per
    ``(Ob, Gb, Eb)`` = (block-size, halo-size, arc) bucket.

    Layout sort (stable argsort on the ``(own=0, ring, outside=BIG)`` key)
    + scatter-rank relabel give the local id space; the edge fill is a
    single-chunk :func:`~repro.graph.packing.gather_pack_device` call
    (inlined by the surrounding jit) followed by the global→local head
    remap.  All outputs are bucket-padded with the usual inert sentinels
    (ids ``n``, hop/weight 0), live counts traced.
    """
    Nb = indptr.shape[0] - 1
    iota = jnp.arange(Nb, dtype=jnp.int32)
    key = jnp.where(
        hop == 0, 0, jnp.where((hop >= 1) & (hop <= h), hop, jnp.int32(_BIG))
    )
    perm = jnp.argsort(key, stable=True).astype(jnp.int32)
    loc = jnp.zeros((Nb,), jnp.int32).at[perm].set(iota)  # global -> local

    o_iota = jnp.arange(Ob, dtype=jnp.int32)
    g_iota = jnp.arange(Gb, dtype=jnp.int32)
    own_valid = o_iota < n_own
    own_g = jnp.where(own_valid, perm[:Ob], n)
    # ghosts start at rank n_own; pad perm so the slice never clamps into
    # live ranks when n_own + Gb > Nb
    perm_ext = jnp.concatenate([perm, jnp.full((Gb,), Nb, jnp.int32)])
    gslice = lax.dynamic_slice(perm_ext, (n_own,), (Gb,))
    ghost_valid = g_iota < n_ghost
    ghost_g = jnp.where(ghost_valid, gslice, n)
    gclamp = jnp.minimum(ghost_g, Nb - 1)
    ghost_hop = jnp.where(ghost_valid, hop[gclamp], 0)
    ghost_block = jnp.where(ghost_valid, lab[gclamp], -1)
    ghost_nw = jnp.where(ghost_valid, nw[gclamp], 0.0)
    nw_own = jnp.where(own_valid, nw[jnp.minimum(own_g, Nb - 1)], 0.0)

    # rows = the first n_rows ranks (owned + interior ghosts)
    Rb = Ob + Gb
    r_iota = jnp.arange(Rb, dtype=jnp.int32)
    row_valid = (r_iota < n_rows)[None, :]
    rows = jnp.where(row_valid[0], perm_ext[:Rb], n)[None, :]
    edge_dst, edge_w, _, edge_valid = gather_pack_device(
        rows, row_valid, indptr, indices, ew, n, E=Eb
    )
    heads = jnp.where(
        edge_valid[0], loc[jnp.minimum(edge_dst[0], Nb - 1)], 0
    ).astype(jnp.int32)
    ew_loc = edge_w[0]
    rows_c = jnp.minimum(rows[0], Nb - 1)
    deg = jnp.where(row_valid[0], indptr[rows_c + 1] - indptr[rows_c], 0)
    indptr_loc = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(deg).astype(jnp.int32)]
    )
    return (own_g, ghost_g, ghost_hop, ghost_block, nw_own, ghost_nw,
            indptr_loc, heads, ew_loc)


# --------------------------------------------------------------------------
# shard containers
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class BlockShardNP:
    """Host view of one deployed block (exact live arrays, no padding).

    Local id space: ``[0, n_own)`` owned nodes (ascending global id),
    ``[n_own, n_own + n_ghost)`` ghosts ordered by (ring, global id).
    Rows ``[0, n_rows)`` of the local CSR carry adjacency (heads in local
    id space); ``n_rows == n_own`` at halo depth 1.
    """

    block: int
    halo: int
    n_own: int
    n_ghost: int
    n_rows: int
    m_local: int
    own_global: np.ndarray    # (n_own,) int32, ascending
    ghost_global: np.ndarray  # (n_ghost,) int32, (ring, id) order
    ghost_hop: np.ndarray     # (n_ghost,) int32 in [1, halo]
    ghost_block: np.ndarray   # (n_ghost,) int32 owning block
    nw: np.ndarray            # (n_own,) f32
    ghost_nw: np.ndarray      # (n_ghost,) f32
    indptr: np.ndarray        # (n_rows + 1,) int64
    indices: np.ndarray       # (m_local,) int32, local heads
    ew: np.ndarray            # (m_local,) f32
    # exchange schedule (assemble_schedule)
    ghost_slot: Optional[np.ndarray] = None   # (n_ghost,) slot in owner buf
    iface_global: Optional[np.ndarray] = None  # (n_iface,) slot order
    iface_local: Optional[np.ndarray] = None   # (n_iface,) owned local ids
    send_blocks: Optional[np.ndarray] = None   # (n_nbr,) neighbour blocks
    send_ptr: Optional[np.ndarray] = None      # (n_nbr + 1,) int64
    send_local: Optional[np.ndarray] = None    # owned local ids per nbr

    @property
    def local_global(self) -> np.ndarray:
        """(n_own + n_ghost,) local id -> global id."""
        return np.concatenate([self.own_global, self.ghost_global])


@dataclass
class BlockShard:
    """Device-resident deployed block: bucket-padded arrays + live counts.

    Arrays follow the GraphDev padding invariants (ids pad with the global
    ``n`` sentinel, rows >= n_rows hold ``m_local``, arcs >= m_local are
    0-weight); the exchange-schedule fields are host numpy, assembled
    cross-block by :func:`assemble_schedule`.  ``host()`` materializes the
    exact :class:`BlockShardNP` view lazily (cached).
    """

    block: int
    halo: int
    n_own: int
    n_ghost: int
    n_rows: int
    m_local: int
    own_g: jax.Array
    ghost_g: jax.Array
    ghost_hop: jax.Array
    ghost_block_dev: jax.Array
    nw: jax.Array
    ghost_nw: jax.Array
    indptr: jax.Array
    indices: jax.Array
    ew: jax.Array
    on_materialize: Optional[Callable[[int], None]] = None
    ghost_slot: Optional[np.ndarray] = None
    iface_global: Optional[np.ndarray] = None
    iface_local: Optional[np.ndarray] = None
    send_blocks: Optional[np.ndarray] = None
    send_ptr: Optional[np.ndarray] = None
    send_local: Optional[np.ndarray] = None
    _own_np: Optional[np.ndarray] = field(default=None, repr=False)
    _ghost_np: Optional[np.ndarray] = field(default=None, repr=False)
    _gblock_np: Optional[np.ndarray] = field(default=None, repr=False)
    _host: Optional[BlockShardNP] = field(default=None, repr=False)

    def _note(self, nbytes: int) -> None:
        if self.on_materialize is not None:
            self.on_materialize(int(nbytes))

    def own_global_np(self) -> np.ndarray:
        """Owned global ids (the O(n_own) schedule-planning download)."""
        if self._own_np is None:
            self._own_np = np.asarray(self.own_g[: self.n_own])
            self._note(self._own_np.nbytes)
        return self._own_np

    def ghost_global_np(self) -> np.ndarray:
        if self._ghost_np is None:
            self._ghost_np = np.asarray(self.ghost_g[: self.n_ghost])
            self._note(self._ghost_np.nbytes)
        return self._ghost_np

    def ghost_block_np(self) -> np.ndarray:
        if self._gblock_np is None:
            self._gblock_np = np.asarray(self.ghost_block_dev[: self.n_ghost])
            self._note(self._gblock_np.nbytes)
        return self._gblock_np

    def host(self) -> BlockShardNP:
        """Exact host view (one O(n_loc + m_loc) download, cached)."""
        if self._host is None:
            no, ng, nr, ml = self.n_own, self.n_ghost, self.n_rows, self.m_local
            self._host = BlockShardNP(
                block=self.block, halo=self.halo, n_own=no, n_ghost=ng,
                n_rows=nr, m_local=ml,
                own_global=self.own_global_np(),
                ghost_global=self.ghost_global_np(),
                ghost_hop=np.asarray(self.ghost_hop[:ng]),
                ghost_block=self.ghost_block_np(),
                nw=np.asarray(self.nw[:no]),
                ghost_nw=np.asarray(self.ghost_nw[:ng]),
                indptr=np.asarray(self.indptr[: nr + 1], dtype=np.int64),
                indices=np.asarray(self.indices[:ml]),
                ew=np.asarray(self.ew[:ml]),
                ghost_slot=self.ghost_slot,
                iface_global=self.iface_global,
                iface_local=self.iface_local,
                send_blocks=self.send_blocks,
                send_ptr=self.send_ptr,
                send_local=self.send_local,
            )
            self._note(ng * 16 + no * 4 + (nr + 1) * 4 + ml * 8)
        return self._host


# --------------------------------------------------------------------------
# extractor (owns the jit-key bookkeeping, mirrors DynamicGraphStore)
# --------------------------------------------------------------------------


class DeployStats(RegistryBackedStats):
    """Counters surfaced through ``ShardDeployment.stats()``:
    ``extract_calls`` (per-shard extraction dispatches), ``mask_calls``,
    ``deploy_compiles`` (distinct deploy kernel shape buckets), and the
    transfer byte counters."""

    _COUNTER_FIELDS = (
        "extract_calls", "mask_calls", "deploy_compiles",
        "h2d_bytes", "d2h_bytes",
    )
    _SET_FIELDS = ("deploy_buckets",)
    # registry keys are namespaced (deploy.h2d_bytes) so the extractor can
    # share the serving stack's registry without colliding with the
    # engine's transfer counters; attribute access and snapshot() keys stay
    # unprefixed (the backward-compat shim in RegistryBackedStats)
    _COUNTER_PREFIX = "deploy."

    @property
    def deploy_bucket_count(self) -> int:
        return len(self.deploy_buckets)


class BlockExtractor:
    """Materializes :class:`BlockShard` artifacts from a resident CSR.

    Shape discipline mirrors the LP engine: ``(Ob, Gb, Eb)`` buckets are
    pow2 / ``arc_bucket`` with *sticky* floors, so balanced blocks share one
    compiled extraction executable and a steady migration stream compiles
    once per bucket (``deploy_compiles == deploy_bucket_count``).
    """

    def __init__(self, on_h2d=None, on_d2h=None, registry=None):
        self.stats = DeployStats(registry)
        self._on_h2d = on_h2d or (lambda b: None)
        self._on_d2h = on_d2h or (lambda b: None)
        self._o_sticky = 0
        self._g_sticky = 0
        self._e_sticky = 0
        self._dev_cache: Dict[int, tuple] = {}   # id(GraphNP) -> (g, GraphDev)

    # ------------------------------------------------------------- internals

    def _note_h2d(self, nbytes: int) -> None:
        self.stats.h2d_bytes += int(nbytes)
        self._on_h2d(int(nbytes))

    def _note_d2h(self, nbytes: int) -> None:
        self.stats.d2h_bytes += int(nbytes)
        self._on_d2h(int(nbytes))

    def _note_key(self, key) -> None:
        if key not in self.stats.deploy_buckets:
            self.stats.deploy_buckets.add(key)
            self.stats.deploy_compiles += 1
            _obs_watchdog().note("deploy.extract", key)

    def _as_dev(self, g: AnyGraph) -> GraphDev:
        if isinstance(g, GraphDev):
            return g
        hit = self._dev_cache.get(id(g))
        if hit is not None and hit[0] is g:
            return hit[1]
        gd = to_device_csr(g, on_materialize=self._note_d2h,
                           on_upload=self._note_h2d)
        # one entry: only the current graph's upload is worth pinning (a
        # serving loop feeds a fresh host snapshot per extraction)
        self._dev_cache = {id(g): (g, gd)}
        return gd

    def _labels_nb(self, gd: GraphDev, labels, k: int) -> jax.Array:
        """Labels sliced/padded to the CSR node bucket (pad k: no block)."""
        Nb = gd.nw.shape[0]
        if isinstance(labels, jax.Array):
            lab = labels.astype(jnp.int32)
            if lab.shape[0] >= Nb:
                return lab[:Nb]
            return jnp.concatenate(
                [lab, jnp.full((Nb - lab.shape[0],), k, jnp.int32)]
            )
        out = np.full(Nb, k, np.int32)
        out[: gd.n] = np.asarray(labels[: gd.n], dtype=np.int32)
        self._note_h2d(out.nbytes)
        arr = jnp.asarray(out)
        _mem_account("label_arenas", arr)
        return arr

    # --------------------------------------------------------------- public

    def extract_one(self, g: AnyGraph, labels, block: int, k: int,
                    halo: int = 1) -> BlockShard:
        """Extract one block's shard (device; 4 scalars sync to host)."""
        if halo < 1:
            raise ValueError("halo depth must be >= 1")
        gd = self._as_dev(g)
        lab = self._labels_nb(gd, labels, k)
        return self._extract_one(gd, lab, block, halo)

    def _extract_one(self, gd: GraphDev, lab: jax.Array, block: int,
                     halo: int) -> BlockShard:
        Nb = gd.nw.shape[0]
        Mb = gd.indices.shape[0]
        self.stats.mask_calls += 1
        self._note_key(("mask", Nb, Mb))
        hop, n_own, n_ghost, n_rows, m_local = _shard_masks(
            lab, gd.src, gd.indices, gd.indptr, jnp.int32(block),
            jnp.int32(gd.n), jnp.int32(halo),
        )
        n_own, n_ghost, n_rows, m_local = (
            int(x) for x in jax.device_get((n_own, n_ghost, n_rows, m_local))
        )
        self._note_d2h(16)
        # sticky buckets: balanced blocks (and steady migration streams)
        # share one compiled extraction executable.  Clamped to the current
        # CSR's buckets so one extractor serves graphs of different scales
        # (a smaller graph must not inherit a larger graph's node bucket —
        # perm only has Nb entries).
        Ob = min(max(self._o_sticky, pow2(max(n_own, 8))), Nb)
        Gb = min(max(self._g_sticky, pow2(max(n_ghost, 8))), Nb)
        Eb = min(max(self._e_sticky, arc_bucket(m_local)), arc_bucket(Mb))
        self._o_sticky, self._g_sticky, self._e_sticky = Ob, Gb, Eb
        self.stats.extract_calls += 1
        self._note_key(("extract", Nb, Mb, Ob, Gb, Eb))
        (own_g, ghost_g, ghost_hop, ghost_block, nw_own, ghost_nw,
         indptr_loc, heads, ew_loc) = _shard_extract(
            hop, lab, gd.indptr, gd.indices, gd.ew, gd.nw,
            jnp.int32(gd.n), jnp.int32(halo),
            jnp.int32(n_own), jnp.int32(n_ghost), jnp.int32(n_rows),
            jnp.int32(m_local), Ob=Ob, Gb=Gb, Eb=Eb,
        )
        _mem_account(
            "block_shards", own_g, ghost_g, ghost_hop, ghost_block,
            nw_own, ghost_nw, indptr_loc, heads, ew_loc,
        )
        return BlockShard(
            block=block, halo=halo, n_own=n_own, n_ghost=n_ghost,
            n_rows=n_rows, m_local=m_local,
            own_g=own_g, ghost_g=ghost_g, ghost_hop=ghost_hop,
            ghost_block_dev=ghost_block, nw=nw_own, ghost_nw=ghost_nw,
            indptr=indptr_loc, indices=heads, ew=ew_loc,
            on_materialize=self._note_d2h,
        )

    def extract(self, g: AnyGraph, labels, k: int, halo: int = 1,
                blocks=None, assemble: bool = True) -> List[BlockShard]:
        """Extract shards for ``blocks`` (default: all ``k``) and assemble
        the cross-block exchange schedule.

        The schedule needs every ghost's *owner* shard present, so it can
        only be assembled over the full block set — a partial extraction
        (the migration path) must pass ``assemble=False`` and re-assemble
        over the complete patched shard list."""
        if halo < 1:
            raise ValueError("halo depth must be >= 1")
        blocks = list(range(k)) if blocks is None else list(blocks)
        if assemble and (
            len(blocks) != k or set(blocks) != set(range(k))
        ):
            raise ValueError(
                "exchange-schedule assembly needs each of the k blocks "
                "exactly once; pass assemble=False for a partial extraction"
            )
        gd = self._as_dev(g)
        lab = self._labels_nb(gd, labels, k)
        shards = [self._extract_one(gd, lab, b, halo) for b in blocks]
        if assemble:
            assemble_schedule(shards)
        return shards


# --------------------------------------------------------------------------
# exchange-schedule assembly (host, O(boundary log boundary))
# --------------------------------------------------------------------------


def _schedule_from_lists(own, ghost_g, ghost_b, blocks):
    """Shared schedule planner: per-owner iface buffers (sorted unique
    requested ids), (owner, slot) maps and per-neighbour send lists, from
    the O(boundary) id lists.  ``blocks[i]`` is the block id of entry i;
    used verbatim by the device and oracle paths so the schedule is
    identical whenever the id lists are."""
    k = len(own)
    of_block = {b: i for i, b in enumerate(blocks)}
    iface_g: List[np.ndarray] = []
    for i in range(k):
        req = [ghost_g[j][ghost_b[j] == blocks[i]] for j in range(k) if j != i]
        req = [r for r in req if r.size]
        iface_g.append(
            np.unique(np.concatenate(req)).astype(np.int32)
            if req else np.zeros(0, np.int32)
        )
    out = []
    for i in range(k):
        slot = np.zeros(ghost_g[i].shape[0], np.int32)
        nbrs, ptr, send = [], [0], []
        for c in np.unique(ghost_b[i]):
            c = int(c)
            j = of_block[c]
            sel = ghost_b[i] == c
            slot[sel] = np.searchsorted(iface_g[j], ghost_g[i][sel]).astype(
                np.int32
            )
        # send lists of block i: who ghosts MY nodes, in sorted-id order
        for j in range(k):
            if j == i:
                continue
            gids = np.sort(ghost_g[j][ghost_b[j] == blocks[i]])
            if gids.size:
                nbrs.append(blocks[j])
                send.append(
                    np.searchsorted(own[i], gids).astype(np.int32)
                )
                ptr.append(ptr[-1] + gids.size)
        out.append(dict(
            ghost_slot=slot,
            iface_global=iface_g[i],
            iface_local=np.searchsorted(own[i], iface_g[i]).astype(np.int32),
            send_blocks=np.asarray(nbrs, np.int32),
            send_ptr=np.asarray(ptr, np.int64),
            send_local=(np.concatenate(send).astype(np.int32)
                        if send else np.zeros(0, np.int32)),
        ))
    return out


def assemble_schedule(shards: List[BlockShard]) -> None:
    """Fill the exchange-schedule fields of device shards in place.

    Every ghost of every shard must point at an (owner, slot) pair such
    that packing each owner's ``iface_local`` nodes in slot order and
    all_gathering the stacked buffers reproduces every ghost table —
    the invariant :func:`ghost_exchange_numpy` executes and the tests
    round-trip."""
    plans = _schedule_from_lists(
        [s.own_global_np() for s in shards],
        [s.ghost_global_np() for s in shards],
        [s.ghost_block_np() for s in shards],
        [s.block for s in shards],
    )
    for s, p in zip(shards, plans):
        s.ghost_slot = p["ghost_slot"]
        s.iface_global = p["iface_global"]
        s.iface_local = p["iface_local"]
        s.send_blocks = p["send_blocks"]
        s.send_ptr = p["send_ptr"]
        s.send_local = p["send_local"]
        s._host = None  # host view (if any) predates the schedule


def ghost_exchange_numpy(shards, values: np.ndarray) -> List[np.ndarray]:
    """Execute one bulk-synchronous ghost exchange on host.

    ``values`` is a global per-node payload (labels, activations, ...).
    Each owner packs ``values[iface_global]`` (its send buffer, slot
    order); the stacked buffers play the role of the all_gather result;
    every shard fills its ghost table via ``bufs[ghost_block, ghost_slot]``.
    Returns the per-shard ``(n_ghost,)`` received arrays — equal to
    ``values[ghost_global]`` by the schedule invariant (tested).
    """
    hosts = [s.host() if isinstance(s, BlockShard) else s for s in shards]
    of_block = {h.block: i for i, h in enumerate(hosts)}
    bufs = [values[h.iface_global] for h in hosts]
    out = []
    for h in hosts:
        recv = np.zeros(h.n_ghost, values.dtype)
        for c in np.unique(h.ghost_block):
            sel = h.ghost_block == c
            recv[sel] = bufs[of_block[int(c)]][h.ghost_slot[sel]]
        out.append(recv)
    return out


# --------------------------------------------------------------------------
# numpy oracle + reassembly
# --------------------------------------------------------------------------


def extract_blocks_numpy(g: GraphNP, labels: np.ndarray, k: int,
                         halo: int = 1, blocks=None) -> List[BlockShardNP]:
    """Host oracle: bit-identical to the device extraction + schedule.

    Mirrors :func:`_shard_masks` / :func:`_shard_extract` op for op — the
    same synchronous BFS layering, the same stable layout sort, the same
    row-major CSR-order edge fill — so every array of every shard matches
    the device path's ``host()`` view exactly (same dtypes, same bits).
    """
    if halo < 1:
        raise ValueError("halo depth must be >= 1")
    n = g.n
    labels = np.asarray(labels[:n], dtype=np.int32)
    src = g.arc_sources().astype(np.int64)
    dst = g.indices.astype(np.int64)
    deg = g.degrees().astype(np.int64)
    blocks = range(k) if blocks is None else blocks
    cores = []
    for b in blocks:
        hop = np.where(labels == b, 0, _BIG).astype(np.int32)
        for r in range(halo):
            reach = np.zeros(n, bool)
            np.logical_or.at(reach, dst, hop[src] <= r)
            hop = np.where(reach & (hop > r + 1), r + 1, hop).astype(np.int32)
        key = np.where(hop == 0, 0, np.where(hop <= halo, hop, _BIG))
        perm = np.argsort(key, kind="stable")
        n_own = int((hop == 0).sum())
        n_ghost = int(((hop >= 1) & (hop <= halo)).sum())
        n_rows = int((hop < halo).sum())
        loc = np.zeros(n, np.int32)
        loc[perm] = np.arange(n, dtype=np.int32)
        own_global = perm[:n_own].astype(np.int32)
        ghost_global = perm[n_own : n_own + n_ghost].astype(np.int32)
        rows = perm[:n_rows]
        rdeg = deg[rows]
        indptr_loc = np.zeros(n_rows + 1, np.int64)
        np.cumsum(rdeg, out=indptr_loc[1:])
        m_local = int(indptr_loc[-1])
        if m_local:
            idx = np.concatenate(
                [np.arange(g.indptr[v], g.indptr[v + 1]) for v in rows]
            )
        else:
            idx = np.zeros(0, np.int64)
        cores.append(dict(
            block=b, n_own=n_own, n_ghost=n_ghost, n_rows=n_rows,
            m_local=m_local, own_global=own_global,
            ghost_global=ghost_global,
            ghost_hop=hop[ghost_global].astype(np.int32),
            ghost_block=labels[ghost_global].astype(np.int32),
            nw=g.nw[own_global].astype(np.float32),
            ghost_nw=g.nw[ghost_global].astype(np.float32),
            indptr=indptr_loc,
            indices=loc[g.indices[idx]].astype(np.int32),
            ew=g.ew[idx].astype(np.float32),
        ))
    plans = _schedule_from_lists(
        [c["own_global"] for c in cores],
        [c["ghost_global"] for c in cores],
        [c["ghost_block"] for c in cores],
        [c["block"] for c in cores],
    )
    return [
        BlockShardNP(halo=halo, **c, **p) for c, p in zip(cores, plans)
    ]


def reassemble(shards, n: int) -> GraphNP:
    """Glue the OWNED rows of all shards back into the global CSR.

    Blocks partition the node set, so every global row lives in exactly one
    shard; heads map back through ``local_global`` and arc order within a
    row is preserved — the result is bit-identical to the extraction input
    (tested), and its cut equals the sum of the shards' ghost-arc weights.
    """
    hosts = [s.host() if isinstance(s, BlockShard) else s for s in shards]
    deg = np.zeros(n, np.int64)
    for h in hosts:
        deg[h.own_global] = np.diff(h.indptr[: h.n_own + 1])
    indptr = np.zeros(n + 1, np.int64)
    np.cumsum(deg, out=indptr[1:])
    m = int(indptr[-1])
    indices = np.zeros(m, np.int32)
    ew = np.zeros(m, np.float32)
    nw = np.zeros(n, np.float32)
    for h in hosts:
        if h.n_own == 0:
            continue
        lg = h.local_global
        nw[h.own_global] = h.nw
        cnt = np.diff(h.indptr[: h.n_own + 1])
        m_own = int(h.indptr[h.n_own])
        rows_rep = np.repeat(np.arange(h.n_own), cnt)
        off = np.arange(m_own) - np.repeat(h.indptr[: h.n_own], cnt)
        gpos = indptr[h.own_global[rows_rep]] + off
        indices[gpos] = lg[h.indices[:m_own]]
        ew[gpos] = h.ew[:m_own]
    return GraphNP(indptr=indptr, indices=indices, ew=ew, nw=nw)
