"""Deployment-side partition objectives (deployment subsystem, layer 2).

The edge cut the partitioner optimizes is a proxy; what ParMetis-era
consumers actually pay for at serving time is **communication volume** (how
many (node, foreign block) label/feature copies cross the interconnect per
bulk-synchronous step) and **boundary size** (how many nodes participate in
the exchange at all).  This module computes those objectives two ways:

* :func:`block_comm_metrics_np` — from the global labels (the partitioner's
  view): per-block send volume (sum over owned nodes of the number of
  distinct foreign adjacent blocks), receive volume (number of distinct
  foreign nodes adjacent to the block == its 1-ring ghost count), and
  boundary-node count.  ``sum(send) == sum(recv) == comm_volume_np`` of
  ``repro.core.metrics`` by symmetry of the (node, block) incidence.
* :func:`shard_comm_metrics` — from deployed :class:`~.extract.BlockShard`
  artifacts (the consumer's view): send volume is the total send-list
  length, receive volume the ring-1 ghost count, boundary the interface
  buffer size.  At halo depth 1 both views agree exactly (tested); deeper
  halos pay proportionally more, which is precisely what the deployment
  report should surface.
"""

from __future__ import annotations

import numpy as np

from ..graph.csr import GraphNP

__all__ = ["block_comm_metrics_np", "shard_comm_metrics"]


def block_comm_metrics_np(g: GraphNP, labels: np.ndarray, k: int) -> dict:
    """Per-block exchange objectives from the global labels (1-ring)."""
    labels = np.asarray(labels[: g.n], dtype=np.int64)
    src = g.arc_sources().astype(np.int64)
    dst = g.indices.astype(np.int64)
    lab_s = labels[src]
    lab_d = labels[dst]
    foreign = lab_s != lab_d
    # boundary nodes: owned nodes with >= 1 foreign neighbour
    bnd = np.zeros(g.n, bool)
    np.logical_or.at(bnd, src[foreign], True)
    boundary = np.bincount(labels[np.flatnonzero(bnd)], minlength=k)[:k]
    # send volume: distinct (owned node, foreign block) pairs per block
    key = src[foreign] * np.int64(k + 1) + lab_d[foreign]
    uniq = np.unique(key)
    send = np.bincount(labels[uniq // (k + 1)], minlength=k)[:k]
    # recv volume: distinct (foreign node, block) pairs — arc (s, d) with
    # lab(s) = b, lab(d) != b makes d a 1-ring ghost of b
    key2 = dst[foreign] * np.int64(k + 1) + lab_s[foreign]
    recv = np.bincount(np.unique(key2) % (k + 1), minlength=k)[:k]
    return dict(
        boundary=boundary.astype(np.int64),
        send=send.astype(np.int64),
        recv=recv.astype(np.int64),
        total_volume=int(send.sum()),
        max_volume=int(send.max(initial=0)),
        total_boundary=int(boundary.sum()),
        max_boundary=int(boundary.max(initial=0)),
    )


def shard_comm_metrics(shards) -> dict:
    """The same objectives measured on deployed shard artifacts.

    Requires the exchange schedule (``assemble_schedule``).  ``send`` per
    block is the total send-list length (one entry per (owned node,
    requesting block) pair), ``recv`` the ring-1 ghost count, ``boundary``
    the interface-buffer size.  Identical to
    :func:`block_comm_metrics_np` at halo depth 1.
    """
    from .extract import BlockShard

    hosts = [s.host() if isinstance(s, BlockShard) else s for s in shards]
    k = len(hosts)
    send = np.zeros(k, np.int64)
    recv = np.zeros(k, np.int64)
    boundary = np.zeros(k, np.int64)
    for i, h in enumerate(hosts):
        if h.send_local is None:
            raise ValueError("shard has no exchange schedule; run "
                             "assemble_schedule first")
        send[i] = h.send_local.shape[0]
        recv[i] = int((h.ghost_hop == 1).sum())
        boundary[i] = h.iface_global.shape[0]
    return dict(
        boundary=boundary,
        send=send,
        recv=recv,
        total_volume=int(send.sum()),
        max_volume=int(send.max(initial=0)),
        total_boundary=int(boundary.sum()),
        max_boundary=int(boundary.max(initial=0)),
    )
