"""Partition deployment subsystem: turn labels into servable per-block
artifacts and keep them consistent under the dynamic session's updates.

Three layers (ISSUE 5):

* :mod:`repro.deploy.extract` — device-resident block shard extraction:
  one :class:`BlockShard` per block (block-local CSR, h-ring ghost halo,
  global<->local id maps, all_gather-ready interface-exchange schedule),
  materialized from a resident CSR by bucketed executables, with a
  bit-identical numpy oracle (:func:`extract_blocks_numpy`) and an exact
  reassembly inverse (:func:`reassemble`).
* :mod:`repro.deploy.metrics` — the objectives deployed partitions pay
  for: per-block communication volume and boundary-node counts, measured
  from labels and from shard artifacts (they agree at halo 1).
* :mod:`repro.deploy.migrate` — :class:`ShardDeployment`, the incremental
  bridge from :class:`~repro.dynamic.session.PartitionSession`: after each
  repair, a :class:`MigrationDelta` patches only the affected shards,
  escalating to full re-extraction when patching degenerates.
* :mod:`repro.deploy.replicate` — :class:`ReplicatedDeployment` (ISSUE 7):
  R-way standby replicas per block with checksum-audited reads; a lost or
  corrupt primary fails over to an audited standby while background
  recovery restores the replica count, so reads never see a hole.
"""

from .extract import (
    BlockExtractor,
    BlockShard,
    BlockShardNP,
    DeployStats,
    assemble_schedule,
    extract_blocks_numpy,
    ghost_exchange_numpy,
    reassemble,
)
from .metrics import block_comm_metrics_np, shard_comm_metrics
from .migrate import MigrationDelta, ShardDeployment
from .replicate import ReplicaMiss, ReplicatedDeployment

__all__ = [
    "BlockExtractor",
    "BlockShard",
    "BlockShardNP",
    "DeployStats",
    "MigrationDelta",
    "ReplicaMiss",
    "ReplicatedDeployment",
    "ShardDeployment",
    "assemble_schedule",
    "block_comm_metrics_np",
    "extract_blocks_numpy",
    "ghost_exchange_numpy",
    "reassemble",
    "shard_comm_metrics",
]
