"""Standby shard replicas: serve-through-recovery (deployment, layer 4).

PR 6's :class:`~repro.deploy.migrate.ShardDeployment` can *re-extract* a
lost or corrupted :class:`BlockShard` (``recover_block``), but between the
auditor flagging the fault and the re-extraction finishing, reads of that
block would see a hole.  :class:`ReplicatedDeployment` closes the gap with
an R-way replica set per block:

* every time a block's shard is (re)extracted consistently (initial
  deployment, incremental migration, recovery), ``R - 1`` **standby
  copies** are refreshed alongside the primary, and the shard's owned-row
  wrap-sum checksum (the same :func:`~repro.resilience.audit` hash the
  reassembly audit uses) is recorded as the block's expected content;
* :meth:`read_block` hands out the primary after a checksum verification;
  a lost (``None``) or corrupt (checksum-mismatched) primary **fails
  over**: the first standby that passes the same audit is promoted, the
  global exchange schedule is re-assembled (a promoted standby may carry a
  stale slot ordering — schedule state is globally coupled, content is
  not), and the block is queued for background re-extraction
  (:meth:`run_recovery`) to restore the replica count.  Reads never see a
  hole: if every standby is also corrupt, the fallback is an immediate
  synchronous ``recover_block``.

Replica copies are dataclass-level: the underlying jax arrays are
immutable and fault injection corrupts by *rebinding* fields on the
primary object (the PR 6 discipline), so a standby holding its own field
slots stays pristine by construction.  On a single device the copies
therefore cost O(1) handles; on a multi-host serving tier each standby is
a physical copy and memory scales as ``R x shard bytes`` — the
``replicas`` knob trades that memory for failover availability (see
docs/DR_RUNBOOK.md).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Set

import numpy as np

import jax.numpy as jnp

from ..dynamic.session import PartitionSession, UpdateResult, _reg_counter
from ..dynamic.store import GraphUpdate
from ..obs import span as _obs_span
from ..resilience.audit import _shard_owned_chk
from .extract import BlockShard, assemble_schedule
from .migrate import MigrationDelta, ShardDeployment

__all__ = ["ReplicaMiss", "ReplicatedDeployment"]


class ReplicaMiss(RuntimeError):
    """No consistent replica existed for a block (surfaced in stats; the
    read path falls back to synchronous re-extraction instead of raising
    this to callers)."""


class ReplicatedDeployment(ShardDeployment):
    """R-way replicated shard set tracking a :class:`PartitionSession`.

    ``replicas`` counts total copies per block (primary + standbys);
    ``replicas=1`` degrades to plain :class:`ShardDeployment` behavior
    with checksum-verified reads.
    """

    failovers = _reg_counter("failovers")
    failover_misses = _reg_counter("failover_misses")
    replica_refreshes = _reg_counter("replica_refreshes")
    reads = _reg_counter("replica_reads")

    def __init__(self, session: PartitionSession, halo: int = 1,
                 escalate_fraction: float = 0.5, replicas: int = 2):
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        self.replicas = int(replicas)
        # initialized before super(): super().__init__ extracts the first
        # shard set and our migrate() override fires during later calls
        # (metrics too — the registry-backed counters write through it)
        self.metrics = session.metrics
        self._standbys: List[List[BlockShard]] = []
        self._expected_chk: List[int] = []
        self.recovery_pending: Set[int] = set()
        self.failovers = 0
        self.failover_misses = 0
        self.replica_refreshes = 0
        self.reads = 0
        self.last_failover_seconds = 0.0
        self._replicas_ready = False
        super().__init__(session, halo=halo,
                         escalate_fraction=escalate_fraction)
        self._standbys = [[] for _ in range(self.k)]
        self._expected_chk = [0] * self.k
        self._replicas_ready = True
        self._refresh_replicas(range(self.k))

    # ------------------------------------------------------------- internals

    def _chk(self, s: BlockShard) -> int:
        """Owned-row wrap-sum checksum of one shard (the reassembly-audit
        hash, so expected values are comparable with the base audit)."""
        chk = _shard_owned_chk(
            s.own_g, s.ghost_g, s.indptr, s.indices, s.ew,
            jnp.int32(s.n_own), jnp.int32(s.m_local),
        )
        st = self.session.engine.stats
        st.audit_calls += 1
        st.note_audit_key(
            ("shard", s.own_g.shape[0], s.ghost_g.shape[0],
             s.indices.shape[0])
        )
        st.d2h_bytes += 4
        return int(np.uint32(chk))

    def _refresh_replicas(self, blocks) -> None:
        """Record the expected checksum and rebuild the standby copies of
        freshly-extracted blocks (the shard is consistent by construction
        at every call site: post-migrate, post-recover)."""
        if not self._replicas_ready:
            return
        for b in blocks:
            b = int(b)
            s = self.shards[b]
            self._expected_chk[b] = self._chk(s)
            self._standbys[b] = [
                dataclasses.replace(s) for _ in range(self.replicas - 1)
            ]
            self.recovery_pending.discard(b)
            self.replica_refreshes += 1

    def verify_shard(self, b: int, s: Optional[BlockShard]) -> bool:
        """Content audit of one copy: present and checksum-identical to the
        block's last consistent extraction."""
        return s is not None and self._chk(s) == self._expected_chk[b]

    # --------------------------------------------------------------- serving

    def read_block(self, b: int) -> BlockShard:
        """The serving read path: a checksum-audited shard for block ``b``.

        A healthy primary is returned directly.  A lost/corrupt primary
        fails over to the first standby that passes the same audit — the
        standby is promoted (removed from the standby set, installed as
        primary, schedule re-assembled) and the block is queued for
        :meth:`run_recovery`.  If no copy survives, falls back to an
        immediate synchronous re-extraction.  Reads never see a hole."""
        if not 0 <= b < self.k:
            raise ValueError(f"block id {b} outside [0, {self.k})")
        self.reads += 1
        if self.verify_shard(b, self.shards[b]):
            return self.shards[b]
        return self.failover(b)

    def failover(self, b: int) -> BlockShard:
        """Promote an audited standby over a lost/corrupt primary."""
        t0 = time.time()
        with _obs_span("deploy.failover", cat="deploy", block=int(b)) as sp:
            while self._standbys[b]:
                cand = self._standbys[b].pop(0)
                if self.verify_shard(b, cand):
                    self.shards[b] = cand
                    # a standby captured before later migrations carries a
                    # stale slot ordering; content is pristine (checksummed),
                    # the schedule is host-cheap to re-couple globally
                    assemble_schedule(self.shards)
                    self._refresh_member_rows([b], self.session.n)
                    self.recovery_pending.add(b)
                    self.failovers += 1
                    self.last_failover_seconds = time.time() - t0
                    self.metrics.observe(
                        "failover_seconds", self.last_failover_seconds
                    )
                    return self.shards[b]
            # every copy gone: recover synchronously (read still succeeds)
            sp.set(miss=True)
            self.failover_misses += 1
            shard = self.recover_block(b)
            self.last_failover_seconds = time.time() - t0
            self.metrics.observe(
                "failover_seconds", self.last_failover_seconds
            )
            return shard

    def run_recovery(self) -> List[int]:
        """Drain the background-recovery queue: re-extract every block that
        failed over (restoring its replica count) — the work a real
        deployment would run off the serving path while standbys serve."""
        done = []
        for b in sorted(self.recovery_pending):
            self.recover_block(b)
            done.append(b)
        return done

    # ------------------------------------------------- ShardDeployment hooks

    def migrate(self, upd: Optional[GraphUpdate],
                res: Optional[UpdateResult] = None) -> MigrationDelta:
        delta = super().migrate(upd, res)
        if not delta.failed and delta.blocks_patched.size:
            self._refresh_replicas(delta.blocks_patched)
        return delta

    def recover_block(self, b: int) -> BlockShard:
        shard = super().recover_block(b)
        self._refresh_replicas([b])
        return shard

    def stats(self) -> dict:
        d = super().stats()
        d.update(
            replicas=self.replicas,
            failovers=self.failovers,
            failover_misses=self.failover_misses,
            replica_refreshes=self.replica_refreshes,
            replica_reads=self.reads,
            recovery_pending=len(self.recovery_pending),
            last_failover_seconds=self.last_failover_seconds,
        )
        return d
