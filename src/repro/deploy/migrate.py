"""Incremental shard migration from the dynamic session (layer 3).

A deployed partition must track the partition it deploys: every
``PartitionSession.update`` moves nodes (repair) and mutates the graph
(edge/node churn), and the serving PEs need their :class:`BlockShard`
artifacts patched — re-extracting the world per batch would throw away the
entire point of incremental repair.  :class:`ShardDeployment` keeps the
shard set consistent by re-extracting only the **affected blocks** and
re-assembling the (cheap, host-side) exchange schedule globally:

* a *dirty node* is a moved node (label changed), a net-churned edge
  endpoint, or a freshly added node;
* block ``b`` is *affected* iff a dirty node is a member of its shard
  (owned or ghost) or is the source/target block of a move.  This is exact,
  not heuristic: an edge ``{u, v}`` appears in (or shifts the halo of) a
  shard only if ``u`` or ``v`` already lies within its h-ring — any path
  from the block through the new edge is longer than ``h`` otherwise — and
  a label move changes exactly the two block's node sets plus the
  ghost-owner entries of its subscribers.  Slot/send-list shifts in
  *unaffected* shards (an owner's interface buffer re-indexes when its
  requested set changes) are schedule-only and covered by the global
  re-assembly, which costs O(boundary log boundary) host work, not O(m)
  device work.

Each migration emits a :class:`MigrationDelta` — moved nodes, patched
blocks, per-block halo additions/removals — the record a PE runtime would
consume to DMA exactly the changed entries.  **Escalation**: when the
affected fraction reaches ``escalate_fraction`` (or the session itself
escalated to a full V-cycle, which moves nodes everywhere), patching
degenerates and the deployment falls back to a full re-extraction — same
executables, same buckets, so ``deploy_compiles == deploy_bucket_count``
holds across the whole stream (regression-tested).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from ..dynamic.session import PartitionSession, UpdateResult, _reg_counter
from ..dynamic.store import GraphUpdate
from ..obs import span as _obs_span
from .extract import BlockExtractor, BlockShard, assemble_schedule

__all__ = ["MigrationDelta", "ShardDeployment"]


@dataclass
class MigrationDelta:
    """What one update did to the deployed shard set."""

    step: int
    moved: np.ndarray                # global ids whose label changed
    moved_from: np.ndarray           # (len(moved),) old block (-1: new node)
    moved_to: np.ndarray             # (len(moved),) new block
    dirty: np.ndarray                # moved + churned endpoints + new nodes
    blocks_patched: np.ndarray       # block ids re-extracted this step
    full_rebuild: bool               # escalated to re-extracting all blocks
    halo_added: Dict[int, np.ndarray] = field(default_factory=dict)
    halo_removed: Dict[int, np.ndarray] = field(default_factory=dict)
    failed: bool = False             # extraction failed: shard set left on
                                     # the last consistent (stale) state
    seconds: float = 0.0

    @property
    def noop(self) -> bool:
        return self.blocks_patched.size == 0


class ShardDeployment:
    """Device-resident shard set tracking a :class:`PartitionSession`.

    ``update(upd)`` forwards the batch to the session (store -> compact ->
    repair -> guard) and then migrates the deployed shards incrementally.
    ``shards[b]`` is always consistent with the session's current graph and
    labels — the invariant the parity tests pin after every batch.
    """

    # deployment counters live in the session's registry (one stack, one
    # reset/snapshot/export path); the extractor's counters join it under
    # the "deploy." namespace so its h2d/d2h bytes stay distinct from the
    # engine's transfer counters
    migrate_calls = _reg_counter("migrate_calls")
    full_rebuilds = _reg_counter("full_rebuilds")
    blocks_patched_total = _reg_counter("blocks_patched_total")
    failed_migrations = _reg_counter("failed_migrations")
    shard_recoveries = _reg_counter("shard_recoveries")

    def __init__(self, session: PartitionSession, halo: int = 1,
                 escalate_fraction: float = 0.5):
        if halo < 1:
            raise ValueError("halo depth must be >= 1")
        self.session = session
        self.metrics = session.metrics
        self.halo = int(halo)
        self.k = session.k
        self.escalate_fraction = float(escalate_fraction)
        self.extractor = BlockExtractor(registry=session.metrics)
        self.full_rebuilds = 0
        self.migrate_calls = 0
        self.blocks_patched_total = 0
        self.failed_migrations = 0
        self.shard_recoveries = 0
        # a failed migration leaves the shard set on its last consistent
        # state: ``stale`` flags that it lags the session until the next
        # successful migrate catches up (``_labels`` is only advanced on
        # success, so moved nodes are never lost; churned endpoints of the
        # failed step are carried in ``_pending_dirty``)
        self.stale = False
        self._pending_dirty: List[np.ndarray] = []
        self._labels = session.labels_np().copy()
        self.shards: List[BlockShard] = self.extractor.extract(
            session.store.graph(), session.labels, self.k, halo=self.halo
        )
        self._member = self._membership(self.session.n)
        self.deltas: List[MigrationDelta] = []

    # ------------------------------------------------------------- internals

    def _membership(self, n: int) -> np.ndarray:
        """(k, n) bool: node is a member (owned or ghost) of block's shard —
        the subscriber index the affected-block computation reads."""
        mem = np.zeros((self.k, n), bool)
        for i, s in enumerate(self.shards):
            mem[i, s.own_global_np()] = True
            mem[i, s.ghost_global_np()] = True
        return mem

    def _refresh_member_rows(self, blocks, n: int) -> None:
        if self._member.shape[1] < n:
            self._member = np.pad(
                self._member, ((0, 0), (0, n - self._member.shape[1]))
            )
        for b in blocks:
            self._member[b, :] = False
            s = self.shards[b]
            self._member[b, s.own_global_np()] = True
            self._member[b, s.ghost_global_np()] = True

    # --------------------------------------------------------------- public

    def update(self, upd: GraphUpdate):
        """Session update + incremental shard migration.

        Returns ``(UpdateResult, MigrationDelta)``."""
        res = self.session.update(upd)
        return res, self.migrate(upd, res)

    def migrate(self, upd: Optional[GraphUpdate],
                res: Optional[UpdateResult] = None) -> MigrationDelta:
        """Patch the shard set to the session's current graph + labels."""
        with _obs_span("deploy.migrate", cat="deploy") as sp:
            delta = self._migrate_impl(upd, res)
            sp.set(
                blocks=int(delta.blocks_patched.size),
                full_rebuild=delta.full_rebuild, failed=delta.failed,
            )
        return delta

    def _migrate_impl(self, upd: Optional[GraphUpdate],
                      res: Optional[UpdateResult]) -> MigrationDelta:
        t0 = time.time()
        self.migrate_calls += 1
        sess = self.session
        lab_new = sess.labels_np()
        n_new = lab_new.shape[0]
        old = self._labels
        n_old = old.shape[0]
        both = min(n_old, n_new)
        moved = np.flatnonzero(lab_new[:both] != old[:both]).astype(np.int64)
        new_ids = np.arange(n_old, n_new, dtype=np.int64)
        moved_all = np.concatenate([moved, new_ids])
        moved_from = np.concatenate(
            [old[moved], np.full(new_ids.size, -1, old.dtype)]
        ).astype(np.int32)
        moved_to = lab_new[moved_all].astype(np.int32)
        if upd is not None:
            u, v, _ = upd.net_arcs(max(n_new, 1))
        else:
            u = v = np.zeros(0, np.int64)
        dirty = np.unique(np.concatenate(
            [moved_all, u, v] + self._pending_dirty
        ).astype(np.int64))
        # a lost shard (None — a dropped PE) is re-extracted as part of any
        # migrate pass, so the catch-up paths (resync, heal) self-repair
        # holes instead of tripping over them
        lost = {b for b in range(self.k) if self.shards[b] is None}
        step = res.step if res is not None else sess.trajectory[-1].step
        if dirty.size == 0 and not lost:
            delta = MigrationDelta(
                step=step, moved=moved_all, moved_from=moved_from,
                moved_to=moved_to, dirty=dirty,
                blocks_patched=np.zeros(0, np.int64), full_rebuild=False,
                seconds=time.time() - t0,
            )
            self.deltas.append(delta)
            return delta
        # affected = subscribers of dirty nodes + source/target of moves
        in_range = dirty[dirty < self._member.shape[1]]
        aff = set(np.flatnonzero(self._member[:, in_range].any(axis=1)))
        aff |= {int(b) for b in moved_from if b >= 0}
        aff |= {int(b) for b in moved_to}
        aff |= lost
        escalated = res.escalated if res is not None else False
        full = escalated or len(aff) > self.escalate_fraction * self.k
        blocks = list(range(self.k)) if full else sorted(aff)
        old_ghosts = {
            b: (self.shards[b].ghost_global_np()
                if self.shards[b] is not None else np.zeros(0, np.int64))
            for b in blocks
        }
        g = sess.store.graph()
        try:
            fresh = self.extractor.extract(
                g, sess.labels, self.k, halo=self.halo, blocks=blocks,
                assemble=False,
            )
        except Exception:
            # failed migration: serve the last consistent shard set (stale).
            # ``_labels`` is NOT advanced, so the next successful migrate
            # re-discovers every moved node; the failed step's churned
            # endpoints are queued so halo effects are not lost either.
            self.failed_migrations += 1
            self.stale = True
            if u.size or v.size:
                self._pending_dirty.append(
                    np.concatenate([u, v]).astype(np.int64)
                )
            delta = MigrationDelta(
                step=step, moved=moved_all, moved_from=moved_from,
                moved_to=moved_to, dirty=dirty,
                blocks_patched=np.zeros(0, np.int64), full_rebuild=full,
                failed=True, seconds=time.time() - t0,
            )
            self.deltas.append(delta)
            return delta
        for b, s in zip(blocks, fresh):
            self.shards[b] = s
        # schedule is globally coupled through the owners' buffer orderings:
        # re-assemble for ALL shards (host O(boundary), not device O(m))
        assemble_schedule(self.shards)
        self._refresh_member_rows(blocks, n_new)
        halo_added, halo_removed = {}, {}
        for b in blocks:
            new_g = self.shards[b].ghost_global_np()
            halo_added[b] = np.setdiff1d(new_g, old_ghosts[b])
            halo_removed[b] = np.setdiff1d(old_ghosts[b], new_g)
        self._labels = lab_new.copy()
        self.stale = False
        self._pending_dirty = []
        if full:
            self.full_rebuilds += 1
        self.blocks_patched_total += len(blocks)
        delta = MigrationDelta(
            step=step, moved=moved_all, moved_from=moved_from,
            moved_to=moved_to, dirty=dirty,
            blocks_patched=np.asarray(blocks, np.int64), full_rebuild=full,
            halo_added=halo_added, halo_removed=halo_removed,
            seconds=time.time() - t0,
        )
        self.deltas.append(delta)
        return delta

    def resync(self, upd: Optional[GraphUpdate] = None,
               full: bool = False) -> MigrationDelta:
        """Catch the shard set up with the session OUTSIDE the normal
        update flow — the rollback path's shard repair.

        A plain ``migrate(None)`` only re-extracts blocks with *moved*
        nodes, which is not enough after a rollback: the undone batch's
        graph churn left halo content in shards that the restored base no
        longer has.  Passing the undone ``upd`` queues its endpoints as
        dirty so those blocks are re-extracted too; ``full=True`` marks
        every node dirty (a full re-extraction through the same migrate
        machinery) for when the set of undone batches is unknown."""
        if full:
            self._pending_dirty.append(
                np.arange(self.session.n, dtype=np.int64)
            )
        elif upd is not None:
            eps = np.concatenate([
                upd.add_u, upd.add_v, upd.rem_u, upd.rem_v,
            ]).astype(np.int64)
            eps = eps[(eps >= 0) & (eps < self.session.n)]
            if eps.size:
                self._pending_dirty.append(eps)
        return self.migrate(None)

    def recover_block(self, b: int) -> BlockShard:
        """Re-extract block ``b`` from the resident global state — the
        recovery path for a lost or corrupted :class:`BlockShard`.

        If the deployment is stale (a prior migration failed), a catch-up
        ``migrate(None)`` runs first so the recovered shard is not newer
        than its peers — the schedule re-assembly couples every shard's
        buffer orderings, so consistency must be restored set-wide.  Always
        re-assembles the exchange schedule."""
        if not 0 <= b < self.k:
            raise ValueError(f"block id {b} outside [0, {self.k})")
        if self.stale:
            self.migrate(None)
        sess = self.session
        g = sess.store.graph()
        fresh = self.extractor.extract(
            g, sess.labels, self.k, halo=self.halo, blocks=[b],
            assemble=False,
        )
        self.shards[b] = fresh[0]
        assemble_schedule(self.shards)
        self._refresh_member_rows([b], sess.n)
        self.shard_recoveries += 1
        return self.shards[b]

    def stats(self) -> dict:
        """Session + extractor counters (the deployment dashboard row)."""
        d = self.session.stats()
        st = self.extractor.stats
        d.update(
            migrate_calls=self.migrate_calls,
            full_rebuilds=self.full_rebuilds,
            blocks_patched_total=self.blocks_patched_total,
            failed_migrations=self.failed_migrations,
            shard_recoveries=self.shard_recoveries,
            shards_stale=self.stale,
            extract_calls=st.extract_calls,
            deploy_compiles=st.deploy_compiles,
            deploy_bucket_count=st.deploy_bucket_count,
            deploy_h2d_bytes=st.h2d_bytes,
            deploy_d2h_bytes=st.d2h_bytes,
        )
        return d
