"""Device-memory accounting and capacity planning (PR 10).

Two halves of one question — *how many bytes does each stage hold live on
device, and will graph G fit?*

**Accounting.**  :class:`DeviceMemoryAccountant` attributes live device
buffers to named *families* (:data:`MEMORY_FAMILIES`): the base CSR levels,
the LP engine's chunk packs, the dynamic store's overlay chunks, label
arenas, the evolutionary population batch, deployed block shards, and
snapshot reference captures.  Allocation sites call :func:`account`
(``graph/csr.py``, ``core/engine.py``, ``dynamic/store.py``,
``deploy/extract.py``, …) with the arrays they just made resident; a
``weakref.finalize`` per buffer decrements the family total when the last
Python reference drops (jax arrays are immutable and refcounted, so the
finalizer fires synchronously at release — the family totals track
*liveness*, not allocation volume).  Snapshot captures :func:`pin` instead:
pins are counted per family but excluded from the additive total, because a
snapshot holds references to arrays another family already owns — the
additive total therefore stays comparable to a ``jax.live_arrays()`` sweep
(the oracle the tests use).

Accounting is **off by default** (:func:`set_accounting`); every
instrumented site pays one attribute load + one bool test when disabled —
the same contract as the span tracer, pinned under the 2% obs gate.

When enabled, the accountant feeds three surfaces:

* per-family byte gauges (``mem.<family>_bytes``) in a
  :class:`~repro.obs.registry.MetricsRegistry` handed to
  :func:`set_accounting`;
* peak watermarks — global (:attr:`peak_by_family`) and per span close
  (the tracer calls :meth:`note_span`, so every V-cycle level and repair
  phase records the footprint it peaked at);
* Perfetto counter tracks — the tracer appends a ``"ph": "C"`` event per
  span close, so the Chrome trace shows family bytes as stacked counters
  under the spans that allocated them.

**Capacity planning.**  :func:`estimate_footprint` is the closed form of
the allocator: every persistent buffer in the stack is sized by the two
bucket policies (``pow2`` node/label axes, ``arc_bucket`` arc axes) plus
the chunk geometry, so the expected footprint of partitioning or serving
an (n, m, k) graph is computable *before uploading anything*.
``LPEngine.will_fit`` exposes it as the pre-upload check.

``KNOWN_ALLOC_SITES`` is the registration manifest for the AST static
check (:mod:`repro.obs.static_check`): every syntactic device-allocation
site in the instrumented modules must map to a buffer family (or carry an
``exempt:`` reason), so new allocations cannot land unaccounted.
"""

from __future__ import annotations

import threading
import weakref
from collections import deque
from typing import Dict, Optional

from .registry import MetricsRegistry

__all__ = [
    "MEMORY_FAMILIES",
    "KNOWN_ALLOC_SITES",
    "ALLOC_CHECK_MODULES",
    "DeviceMemoryAccountant",
    "accountant",
    "set_accounting",
    "account",
    "pin",
    "estimate_footprint",
    "will_fit",
]


#: Buffer families every persistent device allocation maps to.
MEMORY_FAMILIES = (
    "base_csr",        # GraphDev levels: indptr/indices/ew/src/nw + contraction scratch
    "chunk_packs",     # LP engine packs: chunk/ELL gathers, repair region packs
    "overlay_chunks",  # dynamic store COO overlay uploads + view materializations
    "label_arenas",    # arena-sized label/weight arrays (labels, restrict, cw)
    "evo_population",  # coarsest-stage GA population batch + degree scratch
    "block_shards",    # deployed BlockShard arrays (block CSR + ghost halo)
    "snapshot_refs",   # resilience snapshots (reference captures; pinned, not additive)
)


class DeviceMemoryAccountant:
    """Attributes live device buffers to :data:`MEMORY_FAMILIES`.

    ``register`` is idempotent per buffer identity (re-registering the
    array object jax returned unchanged is free) and thread-safe; release
    is automatic via ``weakref.finalize``.  All byte totals are *live*
    bytes: peak watermarks (global and per span) are the capacity numbers.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.enabled = False
        self.registry = registry
        self._lock = threading.Lock()
        self._live: Dict[int, tuple] = {}     # id(arr) -> (family, nbytes)
        self._pins: Dict[int, tuple] = {}     # id(arr) -> (family, nbytes)
        self.bytes_by_family: Dict[str, int] = {f: 0 for f in MEMORY_FAMILIES}
        self.pinned_by_family: Dict[str, int] = {f: 0 for f in MEMORY_FAMILIES}
        self.peak_by_family: Dict[str, int] = {f: 0 for f in MEMORY_FAMILIES}
        self.total = 0
        self.peak_total = 0
        #: enabled register()/pin() invocations — the obs_overhead bench
        #: multiplies this per-update count by the disabled-path ns/call to
        #: bound the accounting-off cost (the span-overhead idiom)
        self.calls = 0
        #: bounded span-close watermark log: (span name, total, {family: bytes})
        self.span_marks = deque(maxlen=4096)

    # ------------------------------------------------------------- register

    def register(self, family: str, *arrays) -> None:
        """Attribute ``arrays`` (anything with ``.nbytes``) to ``family``."""
        if not self.enabled:
            return
        if family not in self.bytes_by_family:
            raise KeyError(f"unknown memory family {family!r}")
        self.calls += 1
        for a in arrays:
            nb = getattr(a, "nbytes", None)
            if nb is None:
                continue
            aid = id(a)
            with self._lock:
                if aid in self._live:
                    continue
                self._live[aid] = (family, nb)
                self.bytes_by_family[family] += nb
                self.total += nb
                if self.bytes_by_family[family] > self.peak_by_family[family]:
                    self.peak_by_family[family] = self.bytes_by_family[family]
                if self.total > self.peak_total:
                    self.peak_total = self.total
            try:
                weakref.finalize(a, self._release, aid)
            except TypeError:
                pass   # not weakrefable: stays attributed until reset()
            self._publish(family)

    def pin(self, family: str, *arrays) -> None:
        """Like :meth:`register`, but *non-additive*: pins record that a
        family (snapshots) holds references to buffers another family
        already owns, so they are tracked per family but excluded from
        ``total`` — keeping the additive total equal to a
        ``jax.live_arrays()`` sweep."""
        if not self.enabled:
            return
        if family not in self.pinned_by_family:
            raise KeyError(f"unknown memory family {family!r}")
        self.calls += 1
        for a in arrays:
            nb = getattr(a, "nbytes", None)
            if nb is None:
                continue
            aid = id(a)
            with self._lock:
                if aid in self._pins:
                    continue
                self._pins[aid] = (family, nb)
                self.pinned_by_family[family] += nb
            try:
                weakref.finalize(a, self._release_pin, aid)
            except TypeError:
                pass
            self._publish(family)

    def _release(self, aid: int) -> None:
        with self._lock:
            ent = self._live.pop(aid, None)
            if ent is None:
                return
            family, nb = ent
            self.bytes_by_family[family] -= nb
            self.total -= nb
        self._publish(family)

    def _release_pin(self, aid: int) -> None:
        with self._lock:
            ent = self._pins.pop(aid, None)
            if ent is None:
                return
            family, nb = ent
            self.pinned_by_family[family] -= nb
        self._publish(family)

    def _publish(self, family: str) -> None:
        reg = self.registry
        if reg is not None:
            reg.gauge(
                f"mem.{family}_bytes",
                self.bytes_by_family[family] + self.pinned_by_family[family],
            )
            reg.gauge("mem.total_bytes", self.total)

    # ------------------------------------------------------------ queries

    def live_bytes(self, family: Optional[str] = None) -> int:
        if family is None:
            return self.total
        return self.bytes_by_family[family]

    def note_span(self, name: str, args: Optional[dict] = None) -> None:
        """Span-close watermark hook (called by ``Tracer._record``): records
        the live footprint this span closed at, keyed by span name — the
        per-V-cycle-level / per-repair-phase capacity trail."""
        if not self.enabled:
            return
        rec = dict(
            name=name,
            total=self.total,
            by_family={f: b for f, b in self.bytes_by_family.items() if b},
        )
        if args:
            for key in ("n", "level", "step", "mode", "region"):
                if key in args:
                    rec[key] = args[key]
        self.span_marks.append(rec)

    def counter_event(self, ts: float, pid: int) -> dict:
        """Chrome-trace counter ("ph": "C") sample of the family bytes."""
        return dict(
            name="device_memory", cat="mem", ph="C", ts=ts, pid=pid, tid=0,
            args={f: self.bytes_by_family[f] for f in MEMORY_FAMILIES},
        )

    def snapshot(self) -> dict:
        with self._lock:
            return dict(
                enabled=self.enabled,
                total=self.total,
                peak_total=self.peak_total,
                by_family=dict(self.bytes_by_family),
                pinned_by_family=dict(self.pinned_by_family),
                peak_by_family=dict(self.peak_by_family),
                buffers=len(self._live),
            )

    # ----------------------------------------------------------- lifecycle

    def reset_peaks(self) -> None:
        with self._lock:
            self.peak_by_family = dict(self.bytes_by_family)
            self.peak_total = self.total
            self.span_marks.clear()

    def reset(self) -> None:
        """Forget every attribution (finalizers become no-ops)."""
        with self._lock:
            self._live.clear()
            self._pins.clear()
            self.bytes_by_family = {f: 0 for f in MEMORY_FAMILIES}
            self.pinned_by_family = {f: 0 for f in MEMORY_FAMILIES}
            self.peak_by_family = {f: 0 for f in MEMORY_FAMILIES}
            self.total = 0
            self.peak_total = 0
            self.calls = 0
            self.span_marks.clear()


_acct = DeviceMemoryAccountant()


def accountant() -> DeviceMemoryAccountant:
    """The process-global accountant (mirrors ``watchdog()``)."""
    return _acct


def set_accounting(
    enabled: bool, registry: Optional[MetricsRegistry] = None
) -> bool:
    """Enable/disable device-memory accounting; returns the previous state.

    ``registry``, when given, receives ``mem.<family>_bytes`` gauges on
    every attribution change (pass the serving stack's registry so the
    gauges ride the existing SLO export)."""
    prev = _acct.enabled
    if registry is not None:
        _acct.registry = registry
    _acct.enabled = bool(enabled)
    return prev


def account(family: str, *arrays) -> None:
    """Allocation-site entry point: attribute ``arrays`` to ``family``.

    Disabled fast path: one global load + one bool test (same contract as
    ``obs.span``)."""
    a = _acct
    if not a.enabled:
        return
    a.register(family, *arrays)


def pin(family: str, *arrays) -> None:
    """Reference-capture entry point (snapshots): non-additive accounting."""
    a = _acct
    if not a.enabled:
        return
    a.pin(family, *arrays)


# --------------------------------------------------------------------------
# capacity planning: the closed form of the allocator
# --------------------------------------------------------------------------


def _pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def _arc_bucket(m: int) -> int:
    if m <= 16384:
        return _pow2(max(m, 8))
    return -(-m // 16384) * 16384


def _csr_bytes(n: int, m: int) -> int:
    """One GraphDev level: indptr + nw on the pow2 node bucket, three
    arc-bucket arrays (indices/ew/src), all 4-byte dtypes."""
    Nb = _pow2(max(n, 8))
    Mb = _arc_bucket(max(m, 8))
    return 4 * (Nb + 1) + 4 * Nb + 12 * Mb


def _pack_geometry(n: int, m: int, target_chunks: int) -> tuple:
    """(Cb, N, E) of the engine's frozen chunk geometry for an (n, m)
    graph: ``chunk_geometry`` floors, pow2-snapped per-chunk edge capacity,
    and a chunk count bounded by BOTH caps — on power-law graphs the greedy
    planner closes hub chunks on the edge cap and tail chunks on the node
    cap, so the two quotas are additive (measured: ba-16384 plans 96
    chunks -> pow2 128, exactly node-quota 64 + edge-quota 32)."""
    tc = max(target_chunks, 2)
    N = max(256, -(-n // tc))
    E_raw = max(4096, -(-m // (tc // 2)))
    E = _pow2(E_raw)
    Cb = _pow2(-(-n // N) + -(-m // E_raw))
    return Cb, N, E


def _pack_bytes(Cb: int, N: int, E: int) -> int:
    """One chunk pack: nodes (Cb, N) i32 + node_valid bool + edge
    dst/w/src_slot (Cb, E) 4-byte + edge_valid bool."""
    return Cb * N * 5 + Cb * E * 13


def estimate_footprint(
    n: int,
    m: int,
    k: int,
    cfg=None,
    *,
    workload: str = "partition",
    arc_retention: float = 0.62,
    overlay_cap: int = 1 << 16,
    islands: int = 2,
    pop_per_island: int = 2,
) -> dict:
    """Closed-form expected peak device footprint for an (n, m, k) graph.

    Derived from the stack's bucket policies — pow2 node/label axes,
    ``arc_bucket`` arc axes, the engine's frozen chunk geometry — plus the
    measured structure of the pipeline on complex networks:

    * size-constrained LP clustering contracts to the coarsest target in
      ONE level (ba-16384 -> 1800 nodes in a single contraction), retaining
      ``arc_retention`` of the arcs (measured 0.616 on ba-16384; complex
      networks keep most inter-hub arcs under clustering);
    * three chunk packs over the finest level are co-resident (the engine
      caches one pack per sweep mode), plus one coarse pack in flight;
    * two V-cycles keep two coarse GraphDev levels briefly co-resident.

    ``workload="partition"`` models a full multilevel run (GraphDev
    hierarchy + packs + arenas + GA population); ``workload="dynamic"``
    models the serving peak (compaction triple-buffers the base CSR: old
    base + in-flight merge outputs + new level).  ``cfg`` may be a
    ``PartitionerConfig`` / ``SessionConfig``-like object;
    ``target_chunks`` / ``coarsest_factor`` / ``islands`` /
    ``pop_per_island`` / ``overlay_cap`` / ``compact_fraction`` are read
    off it when present.

    Returns a dict with per-family byte estimates plus ``"total"`` (sum of
    the per-family peaks — families peak in different phases, so this is
    the planning bound, not a single instant).  Validated against measured
    peak family bytes (tests/test_memory.py, 15% tolerance on ba-16384)."""
    compact_fraction = 0.0
    if cfg is not None:
        target_chunks = getattr(cfg, "target_chunks", 64)
        cf = getattr(cfg, "coarsest_factor", 0)
        islands = getattr(cfg, "islands", islands)
        pop_per_island = getattr(cfg, "pop_per_island", pop_per_island)
        overlay_cap = getattr(cfg, "overlay_cap", overlay_cap)
        compact_fraction = getattr(cfg, "compact_fraction", 0.0)
    else:
        target_chunks = 64
        cf = 0
    coarsest = cf * k if cf and cf > 0 else max(k, min(10000 * k, n // 8))

    fam = {f: 0 for f in MEMORY_FAMILIES}
    A = _pow2(max(n + 1, 8))
    Mb = _arc_bucket(max(m, 8))
    Cb, N, E = _pack_geometry(n, m, target_chunks)
    levels = 1 if coarsest < n else 0
    m1 = int(m * arc_retention)

    if workload == "partition":
        # --- base_csr ----------------------------------------------------
        # finest level stays host-resident; its device footprint is the
        # engine arena's arc triplet (src/dst/ew, exact m) + the padded
        # contraction inputs (3 arc-bucket arrays)
        fam["base_csr"] = 12 * m + 12 * Mb
        if levels:
            # two V-cycles: two coarse GraphDev levels briefly co-resident
            fam["base_csr"] += 2 * _csr_bytes(coarsest, m1)
        # CoarseMap labels + indptr scratch on the finest pow2 bucket
        fam["base_csr"] += 8 * _pow2(max(n, 8))

        # --- chunk_packs: 3 finest packs + one coarse in flight ----------
        fam["chunk_packs"] = 3 * _pack_bytes(Cb, N, E)
        if levels:
            C1 = _pow2(max(-(-m1 // E), 1))   # frozen (N, E), edge-bound
            fam["chunk_packs"] += _pack_bytes(C1, N, E)

        # --- label_arenas: labels / restrict / projected / refined + cw --
        fam["label_arenas"] = 6 * 4 * A

        # --- evo_population: (pow2(I*P), pow2(nc)) labels+keys + degrees -
        nc = max(int(coarsest), k)
        Sb = _pow2(max(islands * pop_per_island, 1))
        Ab = _pow2(max(nc, 8))
        fam["evo_population"] = Sb * Ab * 8 + Ab * 4

    elif workload == "dynamic":
        # compaction triple-buffers the base: old handle + in-flight merge
        # outputs + the fresh GraphDev all live until the swap completes
        fam["base_csr"] = 3 * _csr_bytes(n, m)
        Rb = _pow2(max(min(overlay_cap, max(m // 2, 8)), 8))
        if compact_fraction > 0.0:
            # view serving: overlay chunks accrue to the threshold and the
            # materialized view quadruplet spans base + overlay arcs
            fam["overlay_chunks"] = (
                12 * Rb + 4 * (_pow2(max(n, 8)) + 1) + 12 * (Mb + Rb)
            )
        else:
            # compact-every-step: only one batch's COO upload in flight
            fam["overlay_chunks"] = 12 * _pow2(max(overlay_cap // 64, 8))
        fam["label_arenas"] = 4 * 4 * A
        # repair region packs: 2-hop regions gather about a third of the
        # full-graph pack on power-law graphs (measured on ba-16384)
        fam["chunk_packs"] = _pack_bytes(Cb, N, E) // 3
    else:
        raise ValueError(f"unknown workload {workload!r}")

    fam["total"] = sum(v for f, v in fam.items() if f != "total")
    fam["levels"] = levels if workload == "partition" else 0
    fam["coarsest_target"] = coarsest
    return fam


def will_fit(
    n: int,
    m: int,
    k: int,
    cfg=None,
    *,
    budget_bytes: Optional[int] = None,
    workload: str = "partition",
    safety: float = 1.25,
) -> dict:
    """Pre-upload capacity check: does (n, m, k) fit the device?

    ``budget_bytes`` defaults to the backend's reported memory limit
    (``device.memory_stats()['bytes_limit']``) when the platform exposes
    one (TPU/GPU); on hosts without a limit (CPU) the check degrades to
    reporting the estimate with ``fits=None`` unless a budget is given.
    ``safety`` head-room multiplies the estimate (fragmentation + XLA
    scratch)."""
    est = estimate_footprint(n, m, k, cfg, workload=workload)
    if budget_bytes is None:
        try:
            import jax

            stats = jax.devices()[0].memory_stats()
            if stats:
                budget_bytes = stats.get("bytes_limit")
        except Exception:
            budget_bytes = None
    need = int(est["total"] * safety)
    return dict(
        estimate=est,
        required_bytes=need,
        budget_bytes=budget_bytes,
        fits=None if budget_bytes is None else bool(need <= budget_bytes),
    )


# --------------------------------------------------------------------------
# static-check manifest: device-allocation sites -> buffer family
# --------------------------------------------------------------------------

#: Modules (relative to ``src/repro``) whose device-allocation sites the
#: AST static check requires to be present in :data:`KNOWN_ALLOC_SITES`.
ALLOC_CHECK_MODULES = (
    "graph/csr.py",
    "graph/packing.py",
    "core/engine.py",
    "dynamic/store.py",
    "deploy/extract.py",
    "resilience/snapshot.py",
)

#: ``"<relpath>::<site>" -> family`` (or ``"exempt:<reason>"``).  Filled in
#: lock-step with the ``account()`` calls at the allocation chokepoints;
#: ``tests/test_obs.py`` fails if a site is missing or stale.
KNOWN_ALLOC_SITES: Dict[str, str] = {
    # graph/csr.py — GraphDev.__init__ is the single base-CSR chokepoint:
    # every level (upload, contraction output, store merge/vacuum) flows
    # through it, so upload helpers inherit its registration
    "graph/csr.py::arc_sources": "base_csr",
    "graph/csr.py::to_device": "base_csr",
    "graph/csr.py::to_device_csr": "base_csr",
    # core/engine.py
    "core/engine.py::_arena": "label_arenas",
    "core/engine.py::_contract_inputs": "base_csr",
    "core/engine.py::_deg_f": "evo_population",
    "core/engine.py::_ell": "chunk_packs",
    "core/engine.py::_evolve_sharded": "evo_population",
    "core/engine.py::_indptr_dev": "base_csr",
    "core/engine.py::_iota": "label_arenas",
    "core/engine.py::_pack_dev": "chunk_packs",
    "core/engine.py::_pack_host_build": "chunk_packs",
    "core/engine.py::contract": "base_csr",
    "core/engine.py::evolve_device": "evo_population",
    "core/engine.py::project": "label_arenas",
    "core/engine.py::project_restrict": "label_arenas",
    "core/engine.py::repair": "chunk_packs",
    "core/engine.py::to_arena": "label_arenas",
    "core/engine.py::block_weights": "exempt:O(k) reduction scratch",
    "core/engine.py::cluster": "exempt:O(k) scalar/round scratch",
    "core/engine.py::refine": "exempt:O(k) block-weight scratch",
    # dynamic/store.py
    "dynamic/store.py::_dispatch_merge": "overlay_chunks",
    "dynamic/store.py::_finalize_pending": "base_csr",
    "dynamic/store.py::vacuum": "base_csr",
    "dynamic/store.py::view": "overlay_chunks",
    "dynamic/store.py::remove_nodes": "exempt:O(removed) validation upload",
    # deploy/extract.py
    "deploy/extract.py::_labels_nb": "label_arenas",
}
