"""Static registration check: every ``jax.jit`` / ``pallas_call`` callsite
under ``src/repro`` must be registered in ``KNOWN_JIT_SITES``.

Run by the tier-1 suite (tests/test_obs.py) so a new kernel cannot land
without either wiring its compile accounting into the watchdog or
explicitly exempting it with a reason.  Detection is syntactic over the
AST: any occurrence of the attribute/name ``jit`` on a ``jax`` object or
``pallas_call`` — as a decorator, a ``functools.partial(jax.jit, ...)``
argument, or an inline call — is mapped to its *site name*: the
decorated/enclosing function, or the assignment target for module-level
``name = jax.jit(fn)`` bindings.
"""

from __future__ import annotations

import ast
import os
from typing import List, Tuple

__all__ = ["find_jit_sites", "check_registration"]


def _is_jit_ref(node: ast.AST) -> bool:
    """``jax.jit`` (or ``*.jit`` on a jax-ish module) / ``pallas_call``."""
    if isinstance(node, ast.Attribute):
        if node.attr == "pallas_call":
            return True
        if node.attr == "jit":
            v = node.value
            return isinstance(v, ast.Name) and v.id in ("jax", "pjit")
    if isinstance(node, ast.Name):
        return node.id == "pallas_call"
    return False


class _SiteVisitor(ast.NodeVisitor):
    def __init__(self):
        self.sites: List[Tuple[int, str]] = []   # (lineno, site name)
        self._stack: List[str] = []
        self._assign: List[str] = []

    def _site_name(self, lineno: int) -> str:
        if self._stack:
            return self._stack[0]       # outermost def owns the site
        if self._assign:
            return self._assign[-1]
        return f"line{lineno}"

    def visit_FunctionDef(self, node):
        # decorators evaluate in the enclosing scope, the body inside
        for dec in node.decorator_list:
            for sub in ast.walk(dec):
                if _is_jit_ref(sub):
                    name = self._stack[0] if self._stack else node.name
                    self.sites.append((node.lineno, name))
                    break
            else:
                continue
            break
        self._stack.append(node.name)
        for stmt in node.body:
            self.visit(stmt)
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        for stmt in node.body:
            self.visit(stmt)

    def visit_Assign(self, node):
        tgt = node.targets[0]
        if isinstance(tgt, ast.Name):
            self._assign.append(tgt.id)
            self.generic_visit(node.value)
            self._assign.pop()
        else:
            self.generic_visit(node.value)

    def generic_visit(self, node):
        if _is_jit_ref(node):
            self.sites.append((node.lineno, self._site_name(node.lineno)))
            return   # don't double-count jax.jit's own sub-nodes
        super().generic_visit(node)


def find_jit_sites(root: str) -> List[str]:
    """All ``<relpath>::<site>`` strings under ``root`` (a src/repro dir)."""
    found = set()
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path) as f:
                try:
                    tree = ast.parse(f.read())
                except SyntaxError:
                    continue
            v = _SiteVisitor()
            v.visit(tree)
            for _lineno, name in v.sites:
                found.add(f"{rel}::{name}")
    return sorted(found)


def check_registration(root: str) -> List[str]:
    """Return the list of UNREGISTERED sites (empty == check passes)."""
    from .watchdog import KNOWN_JIT_SITES

    return [s for s in find_jit_sites(root) if s not in KNOWN_JIT_SITES]
