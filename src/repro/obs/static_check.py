"""Static registration checks over the ``src/repro`` AST.

Two manifests, same idiom (syntactic detection -> explicit allow-list with
reasons, enforced by the tier-1 suite):

* **jit sites** (:func:`find_jit_sites` / :func:`check_registration`) —
  every ``jax.jit`` / ``pallas_call`` callsite must be registered in
  ``KNOWN_JIT_SITES``, so a new kernel cannot land without wiring its
  compile accounting into the watchdog or explicitly exempting it.
  Detection: any occurrence of the attribute/name ``jit`` on a jax-ish
  object or ``pallas_call`` — as a decorator, a
  ``functools.partial(jax.jit, ...)`` argument, or an inline call — mapped
  to its *site name*: the decorated/enclosing function, or the assignment
  target for module-level ``name = jax.jit(fn)`` bindings.

* **device-allocation sites** (:func:`find_alloc_sites` /
  :func:`check_alloc_registration`, PR 10) — every syntactic device
  allocation (``jnp.asarray/zeros/ones/full/arange/concatenate``,
  ``jax.device_put``) in *non-traced* code of the memory-accounted modules
  (:data:`repro.obs.memory.ALLOC_CHECK_MODULES`) must map to a buffer
  family in ``KNOWN_ALLOC_SITES`` (or carry an ``exempt:`` reason), so a
  new persistent buffer cannot land unaccounted.  Allocations inside
  traced code (jit-decorated defs, defs passed to ``jax.jit``) are XLA
  temporaries managed by the runtime, not Python-side residents, and are
  skipped.
"""

from __future__ import annotations

import ast
import os
from typing import List, Set, Tuple

__all__ = [
    "find_jit_sites", "check_registration",
    "find_alloc_sites", "check_alloc_registration",
]


def _is_jit_ref(node: ast.AST) -> bool:
    """``jax.jit`` (or ``*.jit`` on a jax-ish module) / ``pallas_call``."""
    if isinstance(node, ast.Attribute):
        if node.attr == "pallas_call":
            return True
        if node.attr == "jit":
            v = node.value
            return isinstance(v, ast.Name) and v.id in ("jax", "pjit")
    if isinstance(node, ast.Name):
        return node.id == "pallas_call"
    return False


class _SiteVisitor(ast.NodeVisitor):
    def __init__(self):
        self.sites: List[Tuple[int, str]] = []   # (lineno, site name)
        self._stack: List[str] = []
        self._assign: List[str] = []

    def _site_name(self, lineno: int) -> str:
        if self._stack:
            return self._stack[0]       # outermost def owns the site
        if self._assign:
            return self._assign[-1]
        return f"line{lineno}"

    def visit_FunctionDef(self, node):
        # decorators evaluate in the enclosing scope, the body inside
        for dec in node.decorator_list:
            for sub in ast.walk(dec):
                if _is_jit_ref(sub):
                    name = self._stack[0] if self._stack else node.name
                    self.sites.append((node.lineno, name))
                    break
            else:
                continue
            break
        self._stack.append(node.name)
        for stmt in node.body:
            self.visit(stmt)
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        for stmt in node.body:
            self.visit(stmt)

    def visit_Assign(self, node):
        tgt = node.targets[0]
        if isinstance(tgt, ast.Name):
            self._assign.append(tgt.id)
            self.generic_visit(node.value)
            self._assign.pop()
        else:
            self.generic_visit(node.value)

    def generic_visit(self, node):
        if _is_jit_ref(node):
            self.sites.append((node.lineno, self._site_name(node.lineno)))
            return   # don't double-count jax.jit's own sub-nodes
        super().generic_visit(node)


def find_jit_sites(root: str) -> List[str]:
    """All ``<relpath>::<site>`` strings under ``root`` (a src/repro dir)."""
    found = set()
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path) as f:
                try:
                    tree = ast.parse(f.read())
                except SyntaxError:
                    continue
            v = _SiteVisitor()
            v.visit(tree)
            for _lineno, name in v.sites:
                found.add(f"{rel}::{name}")
    return sorted(found)


def check_registration(root: str) -> List[str]:
    """Return the list of UNREGISTERED sites (empty == check passes)."""
    from .watchdog import KNOWN_JIT_SITES

    return [s for s in find_jit_sites(root) if s not in KNOWN_JIT_SITES]


# --------------------------------------------------------------------------
# device-allocation sites (memory accounting manifest, PR 10)
# --------------------------------------------------------------------------

#: jnp constructors that materialize a device buffer when called eagerly.
_ALLOC_ATTRS = (
    "asarray", "array", "zeros", "ones", "full", "arange", "concatenate",
)


def _is_alloc_ref(node: ast.AST) -> bool:
    """``jnp.<ctor>`` / ``jax.numpy.<ctor>`` / ``jax.device_put``."""
    if not isinstance(node, ast.Attribute):
        return False
    v = node.value
    if node.attr == "device_put":
        return isinstance(v, ast.Name) and v.id == "jax"
    if node.attr in _ALLOC_ATTRS:
        if isinstance(v, ast.Name):
            return v.id == "jnp"
        if isinstance(v, ast.Attribute):   # jax.numpy.<ctor>
            return (
                v.attr == "numpy"
                and isinstance(v.value, ast.Name)
                and v.value.id == "jax"
            )
    return False


def _traced_names(tree: ast.AST) -> Set[str]:
    """Function names whose bodies run under trace: jit-decorated defs and
    defs passed (by name) into a ``jax.jit(...)`` call anywhere in the
    module — covers both ``@jax.jit`` / ``@partial(jax.jit, ...)``
    decorators and the ``fn = jax.jit(_body)`` binding idiom."""
    traced: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if any(_is_jit_ref(sub) for sub in ast.walk(dec)):
                    traced.add(node.name)
        elif isinstance(node, ast.Call) and _is_jit_ref(node.func):
            for arg in node.args:
                for sub in ast.walk(arg):
                    if isinstance(sub, ast.Name):
                        traced.add(sub.id)
    return traced


class _AllocVisitor(ast.NodeVisitor):
    """Collect eager-allocation callsites outside traced code, named by the
    outermost enclosing (non-traced) def — the jit-site naming idiom."""

    def __init__(self, traced: Set[str]):
        self.traced = traced
        self.sites: List[Tuple[int, str]] = []
        self._stack: List[str] = []

    def visit_FunctionDef(self, node):
        if node.name in self.traced:
            return                      # body runs under trace: XLA temps
        self._stack.append(node.name)
        for stmt in node.body:
            self.visit(stmt)
        self._stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node):
        for stmt in node.body:
            self.visit(stmt)

    def visit_Call(self, node):
        if _is_alloc_ref(node.func):
            name = self._stack[0] if self._stack else f"line{node.lineno}"
            self.sites.append((node.lineno, name))
        self.generic_visit(node)


def find_alloc_sites(root: str) -> List[str]:
    """``<relpath>::<site>`` for every eager device allocation outside
    traced code in the accounted modules (``ALLOC_CHECK_MODULES``)."""
    from .memory import ALLOC_CHECK_MODULES

    found = set()
    for rel in ALLOC_CHECK_MODULES:
        path = os.path.join(root, rel)
        if not os.path.exists(path):
            continue
        with open(path) as f:
            try:
                tree = ast.parse(f.read())
            except SyntaxError:
                continue
        v = _AllocVisitor(_traced_names(tree))
        v.visit(tree)
        for _lineno, name in v.sites:
            found.add(f"{rel}::{name}")
    return sorted(found)


def check_alloc_registration(root: str) -> List[str]:
    """Return the list of UNREGISTERED allocation sites (empty == pass)."""
    from .memory import KNOWN_ALLOC_SITES

    return [s for s in find_alloc_sites(root) if s not in KNOWN_ALLOC_SITES]
