"""SLO export: Prometheus text format + JSON snapshots of serving metrics.

The serving stack's ``stats()`` dicts (session / resilient / durable /
deployment) stay the programmatic API; this module renders them — plus
the stack's :class:`MetricsRegistry` histograms/gauges and the compile
watchdog — into the two formats an operator scrapes:

* ``write_slo(prefix, ...)`` → ``<prefix>.metrics.json`` (snapshot) and
  ``<prefix>.prom`` (Prometheus 0.0.4 text, scrape-ready);
* ``slo_snapshot(...)`` → the dict behind the JSON file.

The catalog (docs/OBSERVABILITY.md): update latency histogram
(``update_seconds``), view-hit ratio (``view_hit_ratio``), escalations,
rollbacks, quarantine depth, failovers, WAL fsync latency
(``wal_fsync_seconds``), checkpoint duration, and the RPO/RTO
observables (``rpo_records_at_risk``, ``rto_last_restore_seconds``).
"""

from __future__ import annotations

import json
import os
from typing import Optional, Sequence

from .registry import MetricsRegistry
from .watchdog import watchdog

__all__ = ["slo_snapshot", "to_prometheus", "write_slo"]


def _derived_gauges(stats: dict) -> dict:
    """SLO ratios computable from the flat counters."""
    out = {}
    upd = stats.get("updates_applied", 0)
    if upd:
        out["view_hit_ratio"] = stats.get("view_hits", 0) / upd
    committed = stats.get("tx_committed", 0)
    if committed or stats.get("tx_rollbacks", 0):
        out["rollback_ratio"] = stats.get("tx_rollbacks", 0) / max(
            committed + stats.get("tx_rollbacks", 0), 1
        )
    if "tx_quarantined" in stats:
        out["quarantine_depth"] = stats["tx_quarantined"]
    if "dr_wal_records_since_checkpoint" in stats:
        out["rpo_records_at_risk"] = stats["dr_wal_records_since_checkpoint"]
    if "dr_last_restore_seconds" in stats:
        out["rto_last_restore_seconds"] = stats["dr_last_restore_seconds"]
    # burn-rate SLO gauge fed by the session's flight recorder (the ring
    # buffer of recent per-update latencies): 1.0 = full error budget left
    if "slo_budget_remaining" in stats:
        out["slo_budget_remaining"] = stats["slo_budget_remaining"]
    return out


def slo_snapshot(
    stats: Optional[dict] = None,
    registries: Sequence[MetricsRegistry] = (),
    include_watchdog: bool = True,
) -> dict:
    snap = dict(stats=dict(stats or {}))
    snap["slo"] = _derived_gauges(snap["stats"])
    for reg in registries:
        snap.setdefault("registries", []).append(reg.snapshot())
    if include_watchdog:
        snap["compile_watchdog"] = watchdog().snapshot()
    return snap


def _num(v):
    if isinstance(v, bool):
        return float(v)
    if isinstance(v, (int, float)):
        return float(v)
    return None


def to_prometheus(
    stats: Optional[dict] = None,
    registries: Sequence[MetricsRegistry] = (),
    prefix: str = "repro_",
) -> str:
    """One scrape body: flat counters as untyped samples, registry
    histograms/gauges in full, watchdog totals."""
    lines = []
    merged = dict(stats or {})
    merged.update(_derived_gauges(merged))
    for key in sorted(merged):
        val = _num(merged[key])
        if val is None:
            continue
        name = prefix + "".join(
            c if (c.isalnum() or c == "_") else "_" for c in key
        )
        lines.append(f"{name} {val:g}")
    for reg in registries:
        lines.append(reg.to_prometheus(prefix=prefix))
    wd = watchdog().snapshot()
    lines.append(f"{prefix}compiles_total {wd['total_compiles']}")
    for fam, d in wd["kernels"].items():
        flab = fam.replace('"', "")
        lines.append(
            f'{prefix}compiles{{kernel="{flab}"}} {d["compiles"]}'
        )
        lines.append(
            f'{prefix}compile_wall_ms{{kernel="{flab}"}} {d["wall_ms"]:g}'
        )
    return "\n".join(lines) + "\n"


def write_slo(
    prefix: str,
    stats: Optional[dict] = None,
    registries: Sequence[MetricsRegistry] = (),
) -> dict:
    """Write ``<prefix>.metrics.json`` + ``<prefix>.prom``; returns paths."""
    snap = slo_snapshot(stats, registries)
    json_path = prefix + ".metrics.json"
    tmp = json_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    os.replace(tmp, json_path)
    prom_path = prefix + ".prom"
    tmp = prom_path + ".tmp"
    with open(tmp, "w") as f:
        f.write(to_prometheus(stats, registries))
    os.replace(tmp, prom_path)
    return dict(json=json_path, prom=prom_path)
