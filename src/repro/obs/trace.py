"""Span tracer with Chrome-trace-event export (Perfetto-loadable).

Spans are nested wall-clock intervals with an explicit device-sync
boundary: a span that wraps device work registers its output arrays via
``sp.sync_on(...)`` and the *close* calls ``jax.block_until_ready`` — but
only when tracing is enabled.  With tracing off, ``span()`` returns a
cached singleton no-op whose enter/exit do nothing (one module-global
load + a ``None`` check on the hot path), so the serving loop's labels
AND its timing are unchanged — the ``obs_overhead`` benchmark row pins
this at < 2% on ``dynamic_hot`` steady state.

Usage::

    from repro.obs import span, set_tracer, Tracer

    set_tracer(Tracer())            # enable (None disables again)
    with span("repair.sweep", cat="repair", region=int(nr)) as sp:
        out = _lp_sweep(...)
        sp.sync_on(out)             # close blocks until device-done
    get_tracer().export_chrome("trace.json")   # load in ui.perfetto.dev

Span taxonomy (docs/OBSERVABILITY.md has the catalog): ``vcycle.*``
(pack/sweep/contract/project), ``repair.*`` (expand/gather/sweep/gain/
balance), ``store.*`` (compact/view/vacuum), ``group.lane``,
``deploy.migrate``, ``resilience.audit``, ``resilience.snapshot``,
``wal.fsync``, ``checkpoint.write``, ``session.update``.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import List, Optional

from .memory import accountant as _mem_accountant

__all__ = ["Tracer", "Span", "span", "get_tracer", "set_tracer"]


class _NoopSpan:
    """The disabled path: every method is a no-op, one shared instance."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def sync_on(self, *arrays):
        pass

    def set(self, **args):
        pass


_NOOP = _NoopSpan()


class Span:
    __slots__ = ("tracer", "name", "cat", "args", "_sync", "t0", "tid")

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._sync = None
        self.t0 = 0.0
        self.tid = 0

    def __enter__(self):
        self.tid = threading.get_ident() & 0xFFFF
        self.t0 = time.perf_counter()
        return self

    def sync_on(self, *arrays):
        """Arrays whose device completion bounds this span (closed-over by
        ``__exit__``; the block happens only because tracing is on)."""
        self._sync = arrays

    def set(self, **args):
        self.args.update(args)

    def __exit__(self, *exc):
        if self._sync is not None:
            import jax

            try:
                jax.block_until_ready(self._sync)
            except Exception:
                pass   # tracing must never turn a serving error into another
        t1 = time.perf_counter()
        self.tracer._record(self, t1)
        return False


class Tracer:
    """Collects complete ("ph": "X") Chrome trace events, microsecond ts."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self.events: List[dict] = []
        self._origin = time.perf_counter()
        self._lock = threading.Lock()

    def span(self, name: str, cat: str = "", **args):
        if not self.enabled:
            return _NOOP
        return Span(self, name, cat, args)

    def _record(self, sp: Span, t1: float) -> None:
        ev = dict(
            name=sp.name, cat=sp.cat or sp.name.split(".")[0], ph="X",
            ts=(sp.t0 - self._origin) * 1e6, dur=(t1 - sp.t0) * 1e6,
            pid=os.getpid(), tid=sp.tid,
        )
        if sp.args:
            ev["args"] = sp.args
        # memory accounting hooks: every span close is a watermark boundary
        # (per V-cycle level, per repair phase) and a Perfetto counter-track
        # sample ("ph": "C") in the same trace
        acct = _mem_accountant()
        mem_ev = None
        if acct.enabled:
            acct.note_span(sp.name, sp.args)
            mem_ev = acct.counter_event(
                ts=(t1 - self._origin) * 1e6, pid=ev["pid"]
            )
        with self._lock:
            self.events.append(ev)
            if mem_ev is not None:
                self.events.append(mem_ev)

    def clear(self) -> None:
        with self._lock:
            self.events.clear()

    def export_chrome(self, path: str) -> str:
        """Write ``{"traceEvents": [...]}`` — drag into ui.perfetto.dev."""
        with self._lock:
            doc = dict(
                traceEvents=list(self.events),
                displayTimeUnit="ms",
            )
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)
        return path


_tracer: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    return _tracer


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or with ``None`` remove) the process-global tracer."""
    global _tracer
    prev, _tracer = _tracer, tracer
    return prev


def span(name: str, cat: str = "", **args):
    """The instrumentation entry point every subsystem calls.

    Disabled fast path: one global load, one ``None`` test, return the
    shared no-op singleton — no allocation, no branching at close.
    """
    t = _tracer
    if t is None or not t.enabled:
        return _NOOP
    return Span(t, name, cat, args)
