"""Unified observability layer (PR 9).

Four pieces, one import surface:

* :class:`MetricsRegistry` / :class:`RegistryBackedStats` — the single
  counter/gauge/histogram store behind every subsystem's stats object;
* :func:`span` / :class:`Tracer` — nested spans with device-sync close,
  Chrome-trace export (Perfetto), near-zero overhead when disabled;
* :func:`watchdog` / :class:`CompileWatchdog` — runtime guard promoting
  the "compiles == buckets" test idiom (strict + seal modes);
* :func:`write_slo` — Prometheus text + JSON snapshot of the serving
  SLO metrics.

See docs/OBSERVABILITY.md for the span taxonomy and metric catalog.
"""

from .registry import MetricsRegistry, RegistryBackedStats
from .memory import (
    ALLOC_CHECK_MODULES, KNOWN_ALLOC_SITES, MEMORY_FAMILIES,
    DeviceMemoryAccountant, account, accountant, estimate_footprint, pin,
    set_accounting, will_fit,
)
from .trace import Span, Tracer, get_tracer, set_tracer, span
from .watchdog import (
    KERNEL_FAMILIES, KNOWN_JIT_SITES, CompileRecord, CompileWatchdog,
    WatchdogError, watchdog,
)
from .export import slo_snapshot, to_prometheus, write_slo

__all__ = [
    "MetricsRegistry", "RegistryBackedStats",
    "Span", "Tracer", "get_tracer", "set_tracer", "span",
    "CompileRecord", "CompileWatchdog", "WatchdogError", "watchdog",
    "KERNEL_FAMILIES", "KNOWN_JIT_SITES",
    "DeviceMemoryAccountant", "accountant", "set_accounting",
    "account", "pin", "estimate_footprint", "will_fit",
    "MEMORY_FAMILIES", "KNOWN_ALLOC_SITES", "ALLOC_CHECK_MODULES",
    "slo_snapshot", "to_prometheus", "write_slo",
]
