"""Compile/retrace watchdog: the "compiles == buckets" idiom as a runtime
guard.

Every bucketed jit cache in the repo already does manual compile
accounting (``if key not in bucket_set: add; *_compiles += 1``) and the
test suite regression-pins ``compiles == bucket_count`` per kernel
family.  The watchdog promotes that idiom to runtime:

* each accounting site *also* calls ``watchdog().note(family, key)`` the
  moment a **new** bucket key is seen — i.e. exactly when XLA will
  compile a fresh executable;
* every compile is recorded as a :class:`CompileRecord` ``(kernel,
  bucket key, wall ms)``; wall time comes from ``jax.monitoring``'s
  compile-duration events when the API exists (attributed to the most
  recent note — best-effort, the events are not kernel-tagged), else 0;
* **strict mode** (``set_strict(True)`` or env ``REPRO_OBS_STRICT=1``)
  raises :class:`WatchdogError` on a note for a kernel family outside
  the declared set — an instrumented callsite someone forgot to
  register;
* ``seal()`` freezes the current bucket sets: any later note with a new
  key raises — the production guard against shape-bucket leaks
  (a serving loop that starts retracing per batch instead of reusing
  its buckets).  ``unseal()`` lifts it (e.g. around a planned engine
  rebuild that legitimately opens new buckets).

``KNOWN_JIT_SITES`` is the registration manifest the tier-1 static check
walks against: every ``jax.jit`` / ``pallas_call`` callsite under
``src/repro`` must appear here, mapped to its watchdog kernel family (or
an ``exempt:`` reason for host-launch scaffolding outside the bucketed
serving stack).
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

__all__ = [
    "CompileRecord", "CompileWatchdog", "WatchdogError", "watchdog",
    "KERNEL_FAMILIES", "KNOWN_JIT_SITES",
]


class WatchdogError(RuntimeError):
    """An undeclared kernel family (strict) or a post-seal new bucket."""


# Every kernel family the instrumented accounting sites note.  Declared
# up front so strict mode can run from process start.
KERNEL_FAMILIES: Tuple[str, ...] = (
    "engine.sweep",          # _lp_sweep (bucket, statics) combinations
    "engine.dense",          # dense_round_device shape buckets
    "engine.gather",         # gather_pack_device / gather_ell_device
    "engine.contract",       # contract_device (Nb, Mb, wbits)
    "engine.evo",            # evo_seed_step / evo_generation_step
    "engine.repair",         # repair expand/gather/sweep/gain/balance
    "engine.audit",          # resilience audit kernels (incl. shard chk)
    "store.compact",         # merge_overlay_device buckets
    "store.view",            # overlay_view_device buckets
    "store.vacuum",          # vacuum_device buckets
    "group.repair",          # the vmapped group lane kernels
    "deploy.extract",        # _shard_masks / _shard_extract buckets
)


# Static-check manifest: "<path relative to src/repro>::<site name>" ->
# watchdog family, or "exempt:<reason>".  The tier-1 AST walk
# (repro.obs.static_check) fails on any callsite missing from this dict.
KNOWN_JIT_SITES: Dict[str, str] = {
    "core/label_propagation.py::_lp_sweep": "engine.sweep",
    "core/contraction.py::contract_device": "engine.contract",
    "core/evo_device.py::evo_seed_step": "engine.evo",
    "core/evo_device.py::evo_generation_step": "engine.evo",
    "core/evo_device.py::make_generation_sharded": "engine.evo",
    "graph/packing.py::gather_pack_device": "engine.gather",
    "graph/packing.py::gather_ell_device": "engine.gather",
    "kernels/lp_score/lp_score.py::lp_score_rows": "engine.sweep",
    "kernels/lp_score/ops.py::_node_scores_impl": "engine.sweep",
    "kernels/lp_score/ops.py::dense_round_device": "engine.dense",
    "kernels/lp_score/ops.py::dense_round_device_batched": "engine.evo",
    "dynamic/repair.py::expand_region_device": "engine.repair",
    "dynamic/repair.py::gain_round_device": "engine.repair",
    "dynamic/repair.py::balance_rounds_device": "engine.repair",
    "dynamic/store.py::merge_overlay_device": "store.compact",
    "dynamic/store.py::overlay_view_device": "store.view",
    "dynamic/store.py::vacuum_device": "store.vacuum",
    "dynamic/group.py::_group_expand": "group.repair",
    "dynamic/group.py::_group_gather": "group.repair",
    "dynamic/group.py::_group_bw": "group.repair",
    "dynamic/group.py::_group_sweep": "group.repair",
    "dynamic/group.py::_group_gain": "group.repair",
    "dynamic/group.py::_group_balance": "group.repair",
    "dynamic/group.py::_group_score": "group.repair",
    "dynamic/group.py::_group_select": "group.repair",
    "deploy/extract.py::_shard_masks": "deploy.extract",
    "deploy/extract.py::_shard_extract": "deploy.extract",
    "resilience/audit.py::_csr_audit": "engine.audit",
    "resilience/audit.py::_labels_audit": "engine.audit",
    "resilience/audit.py::_shard_owned_chk": "engine.audit",
    "resilience/audit.py::_ghost_owner_audit": "engine.audit",
    # distributed path: one executable per (mesh, spec) pair, keyed by the
    # plan cache rather than shape buckets — noted at plan build time
    "core/distributed_lp.py::_run_distributed": "exempt:plan-cache keyed, "
    "one executable per ShardPlan (see build_plan's plan cache)",
    "core/distributed_lp.py::contract_distributed": "exempt:plan-cache "
    "keyed, one executable per ShardPlan",
    # host-launch scaffolding: whole-program jits outside the bucketed
    # serving stack (no shape polymorphism — exactly one trace each)
    "launch/steps.py::compile_train_step": "exempt:launch scaffolding",
    "launch/steps.py::compile_prefill": "exempt:launch scaffolding",
    "launch/steps.py::compile_decode": "exempt:launch scaffolding",
    "launch/steps.py::make_prefill": "exempt:launch scaffolding",
    "launch/steps.py::make_decode_step": "exempt:launch scaffolding",
    "launch/serve.py::main": "exempt:launch scaffolding",
    "launch/train.py::main": "exempt:launch scaffolding",
    "launch/dryrun_paper.py::main": "exempt:launch scaffolding",
}


@dataclass
class CompileRecord:
    kernel: str
    key: object
    seq: int
    t_mono: float
    wall_ms: float = 0.0


@dataclass
class CompileWatchdog:
    strict: bool = False
    sealed: bool = False
    records: List[CompileRecord] = field(default_factory=list)
    unattributed_compiles: int = 0
    _declared: Dict[str, Set] = field(default_factory=dict)
    _last: Optional[CompileRecord] = None

    def __post_init__(self):
        for fam in KERNEL_FAMILIES:
            self._declared[fam] = set()
        self._install_listener()

    # ------------------------------------------------------------- wall ms

    def _install_listener(self) -> None:
        """Best-effort hookup of jax's compile-duration telemetry: each
        backend-compile event's wall time is attributed to the most recent
        noted bucket (the note happens immediately before dispatch)."""
        try:
            from jax import monitoring

            monitoring.register_event_duration_secs_listener(self._on_event)
        except Exception:
            pass

    def _on_event(self, name: str, secs: float, **kw) -> None:
        if "compil" not in name:
            return
        last = self._last
        if last is not None and time.monotonic() - last.t_mono < 300.0:
            last.wall_ms += secs * 1e3
        else:
            self.unattributed_compiles += 1

    # ----------------------------------------------------------------- api

    def declare(self, kernel: str) -> None:
        self._declared.setdefault(kernel, set())

    def set_strict(self, flag: bool = True) -> None:
        self.strict = bool(flag)

    def seal(self) -> None:
        """Freeze the bucket sets: any later new-bucket note raises."""
        self.sealed = True

    def unseal(self) -> None:
        self.sealed = False

    def note(self, kernel: str, key) -> bool:
        """Record a dispatch-shape key; returns True iff the key is new
        (== one fresh XLA compile).  Called by the accounting sites only
        when *their* per-object set missed, so the per-call overhead on
        warm paths is a dict lookup they already paid."""
        buckets = self._declared.get(kernel)
        if buckets is None:
            if self.strict:
                raise WatchdogError(
                    f"compile noted for undeclared kernel family {kernel!r} "
                    f"(key={key!r}); declare it in "
                    f"repro.obs.watchdog.KERNEL_FAMILIES"
                )
            buckets = self._declared[kernel] = set()
        if key in buckets:
            return False
        if self.sealed:
            raise WatchdogError(
                f"recompile outside the sealed bucket set: kernel "
                f"{kernel!r}, new key {key!r} (declared "
                f"{len(buckets)} buckets)"
            )
        buckets.add(key)
        rec = CompileRecord(
            kernel=kernel, key=key, seq=len(self.records),
            t_mono=time.monotonic(),
        )
        self.records.append(rec)
        self._last = rec
        return True

    # ----------------------------------------------------------- reporting

    def compile_count(self, kernel: Optional[str] = None) -> int:
        if kernel is None:
            return len(self.records)
        return sum(1 for r in self.records if r.kernel == kernel)

    def bucket_count(self, kernel: Optional[str] = None) -> int:
        if kernel is None:
            return sum(len(s) for s in self._declared.values())
        return len(self._declared.get(kernel, ()))

    def snapshot(self) -> dict:
        per = {
            fam: dict(buckets=len(keys),
                      compiles=self.compile_count(fam),
                      wall_ms=sum(r.wall_ms for r in self.records
                                  if r.kernel == fam))
            for fam, keys in sorted(self._declared.items())
        }
        return dict(
            strict=self.strict, sealed=self.sealed,
            total_compiles=len(self.records),
            unattributed_compiles=self.unattributed_compiles,
            kernels=per,
        )

    def reset(self) -> None:
        self.records.clear()
        self._last = None
        self.unattributed_compiles = 0
        for s in self._declared.values():
            s.clear()


_watchdog: Optional[CompileWatchdog] = None


def watchdog() -> CompileWatchdog:
    """The process-global watchdog (jit caches are process-global too)."""
    global _watchdog
    if _watchdog is None:
        _watchdog = CompileWatchdog(
            strict=os.environ.get("REPRO_OBS_STRICT", "") not in ("", "0")
        )
    return _watchdog
