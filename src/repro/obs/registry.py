"""Metrics registry: the single store behind every subsystem's counters.

Before PR 9 each subsystem grew its own ad-hoc counter bag (``EngineStats``,
``StoreStats``, ``GroupStats``, plain ints on the session / deployment /
resilience objects) with its own reset logic and its own ``stats()``
flattening.  :class:`MetricsRegistry` consolidates them:

* **counters** — monotonically increasing ints/floats (``sweep_compiles``,
  ``h2d_bytes``, ``escalations``);
* **gauges** — point-in-time values (``last_checkpoint_seconds``,
  ``quarantine_depth``);
* **histograms** — log2-bucketed latency/size distributions
  (``update_seconds``, ``wal_fsync_seconds``): O(1) memory, exports both
  Prometheus cumulative buckets and p50/p99 estimates;
* **series** — labeled counter families (``span_ms{phase="repair"}``).

The pre-existing stats dataclasses keep their exact attribute surface
(``eng.stats.sweep_compiles``, ``stats.buckets.add(key)``) through
:class:`RegistryBackedStats`: counter *fields* read/write through to a
registry, bucket-key *sets* stay real Python sets (tests unpack and
iterate them).  One serving stack shares one registry — the session
creates it and threads it into its engine and store, so a single
``snapshot()`` / ``reset()`` / Prometheus export covers the whole stack.

Registries are per-instance, not global: two tenant sessions never share
counters (the multi-tenant group test relies on per-tenant bit-parity of
stats, not just labels).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

__all__ = ["MetricsRegistry", "RegistryBackedStats"]


def _log2_bucket(value: float) -> float:
    """Upper bound of the log2 bucket containing ``value`` (seconds/bytes).

    Buckets are powers of two of 1e-6 units, so sub-microsecond noise all
    lands in the first bucket and a 2.27 s p99 still resolves to ~12%.
    """
    if value <= 1e-6:
        return 1e-6
    return float(2 ** math.ceil(math.log2(value / 1e-6))) * 1e-6


class _Histogram:
    __slots__ = ("buckets", "count", "total", "vmin", "vmax")

    def __init__(self):
        self.buckets: Dict[float, int] = {}   # le upper bound -> count
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = 0.0

    def observe(self, value: float) -> None:
        le = _log2_bucket(float(value))
        self.buckets[le] = self.buckets.get(le, 0) + 1
        self.count += 1
        self.total += float(value)
        self.vmin = min(self.vmin, float(value))
        self.vmax = max(self.vmax, float(value))

    def quantile(self, q: float) -> float:
        """Upper-bound estimate of the q-quantile from the log2 buckets."""
        if not self.count:
            return 0.0
        target = q * self.count
        seen = 0
        for le in sorted(self.buckets):
            seen += self.buckets[le]
            if seen >= target:
                return le
        return self.vmax

    def snapshot(self) -> dict:
        return dict(
            count=self.count, sum=self.total,
            min=0.0 if self.count == 0 else self.vmin, max=self.vmax,
            p50=self.quantile(0.50), p95=self.quantile(0.95),
            p99=self.quantile(0.99),
            buckets={f"{le:.6g}": c for le, c in sorted(self.buckets.items())},
        )


class MetricsRegistry:
    """Counters + gauges + log2 histograms + labeled series, one namespace."""

    def __init__(self, scope: str = ""):
        self.scope = scope
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Histogram] = {}
        self._series: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], float] = {}

    # ------------------------------------------------------------- counters

    def counter(self, name: str, value: float = 0) -> None:
        """Declare (idempotent): existing values are never clobbered."""
        self._counters.setdefault(name, value)

    def inc(self, name: str, delta: float = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + delta

    def get(self, name: str) -> float:
        return self._counters[name]

    def set_counter(self, name: str, value: float) -> None:
        self._counters[name] = value

    # --------------------------------------------------------------- gauges

    def gauge(self, name: str, value: float) -> None:
        self._gauges[name] = float(value)

    def get_gauge(self, name: str, default: float = 0.0) -> float:
        return self._gauges.get(name, default)

    # ----------------------------------------------------------- histograms

    def observe(self, name: str, value: float) -> None:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = _Histogram()
        h.observe(value)

    def histogram(self, name: str) -> Optional[_Histogram]:
        return self._hists.get(name)

    # --------------------------------------------------------------- series

    def series_inc(self, name: str, labels: dict, delta: float = 1) -> None:
        key = (name, tuple(sorted((k, str(v)) for k, v in labels.items())))
        self._series[key] = self._series.get(key, 0) + delta

    # ------------------------------------------------------------ lifecycle

    def reset(self) -> None:
        """Zero counters, clear gauges/histograms/series.  The one reset
        path every subsystem shares (satellite: no more per-class loops)."""
        for k in self._counters:
            self._counters[k] = 0
        self._gauges.clear()
        self._hists.clear()
        self._series.clear()

    def snapshot(self) -> dict:
        return dict(
            scope=self.scope,
            counters=dict(self._counters),
            gauges=dict(self._gauges),
            histograms={k: h.snapshot() for k, h in self._hists.items()},
            series=[
                dict(name=name, labels=dict(labels), value=v)
                for (name, labels), v in sorted(self._series.items())
            ],
        )

    # ----------------------------------------------------------- prometheus

    def to_prometheus(self, prefix: str = "repro_") -> str:
        """Prometheus text exposition (0.0.4) of everything registered."""
        out = []

        def _san(name: str) -> str:
            return prefix + "".join(
                c if (c.isalnum() or c == "_") else "_" for c in name
            )

        for name in sorted(self._counters):
            mn = _san(name)
            out.append(f"# TYPE {mn} counter")
            out.append(f"{mn} {self._counters[name]:g}")
        for name in sorted(self._gauges):
            mn = _san(name)
            out.append(f"# TYPE {mn} gauge")
            out.append(f"{mn} {self._gauges[name]:g}")
        for name in sorted(self._hists):
            h = self._hists[name]
            mn = _san(name)
            out.append(f"# TYPE {mn} histogram")
            acc = 0
            for le in sorted(h.buckets):
                acc += h.buckets[le]
                out.append(f'{mn}_bucket{{le="{le:g}"}} {acc}')
            out.append(f'{mn}_bucket{{le="+Inf"}} {h.count}')
            out.append(f"{mn}_sum {h.total:g}")
            out.append(f"{mn}_count {h.count}")
        seen = set()
        for (name, labels), v in sorted(self._series.items()):
            mn = _san(name)
            if mn not in seen:
                seen.add(mn)
                out.append(f"# TYPE {mn} counter")
            lbl = ",".join(f'{k}="{val}"' for k, val in labels)
            out.append(f"{mn}{{{lbl}}} {v:g}")
        return "\n".join(out) + "\n"


class RegistryBackedStats:
    """Base for the per-subsystem stats objects: counter fields live in a
    :class:`MetricsRegistry`, bucket-key fields stay real sets.

    Subclasses declare ``_COUNTER_FIELDS`` / ``_SET_FIELDS``; the attribute
    surface is unchanged (``st.sweep_compiles += 1`` round-trips through
    the registry, ``st.buckets.add(key)`` mutates a plain set), so the
    pre-PR-9 tests and the ``carry_from`` stats-object sharing keep
    working verbatim.

    ``_COUNTER_PREFIX`` namespaces the *registry keys* (e.g. the deploy
    extractor's counters live as ``deploy.h2d_bytes`` so they can share
    the serving stack's registry without colliding with the engine's
    ``h2d_bytes``); the attribute surface and ``snapshot()`` keys stay
    unprefixed — the backward-compat shim.
    """

    _COUNTER_FIELDS: Tuple[str, ...] = ()
    _SET_FIELDS: Tuple[str, ...] = ()
    _COUNTER_PREFIX: str = ""

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        object.__setattr__(
            self, "registry",
            registry if registry is not None
            else MetricsRegistry(type(self).__name__),
        )
        p = self._COUNTER_PREFIX
        for f in self._COUNTER_FIELDS:
            self.registry.counter(p + f)
        for f in self._SET_FIELDS:
            object.__setattr__(self, f, set())

    def __getattr__(self, name):
        # only reached when normal lookup fails: counter fields are never
        # instance attributes, everything else raises as usual
        cls = type(self)
        if name in cls._COUNTER_FIELDS:
            return self.registry.get(cls._COUNTER_PREFIX + name)
        raise AttributeError(
            f"{type(self).__name__!s} has no attribute {name!r}"
        )

    def __setattr__(self, name, value):
        cls = type(self)
        if name in cls._COUNTER_FIELDS:
            self.registry.set_counter(cls._COUNTER_PREFIX + name, value)
        else:
            object.__setattr__(self, name, value)

    def reset(self) -> None:
        p = self._COUNTER_PREFIX
        for f in self._COUNTER_FIELDS:
            self.registry.set_counter(p + f, 0)
        for f in self._SET_FIELDS:
            getattr(self, f).clear()

    def snapshot(self) -> dict:
        p = self._COUNTER_PREFIX
        d = {f: self.registry.get(p + f) for f in self._COUNTER_FIELDS}
        for f in self._SET_FIELDS:
            d[f + "_count"] = len(getattr(self, f))
        return d
