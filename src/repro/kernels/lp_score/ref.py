"""Pure-jnp oracle for the lp_score kernel."""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["lp_score_rows_ref", "node_scores_ref"]


def lp_score_rows_ref(lbl: jnp.ndarray, w: jnp.ndarray, *, k_pad: int) -> jnp.ndarray:
    """(R, W) labels/weights -> (R, k_pad) scores; labels >= k_pad contribute 0."""
    onehot = (lbl[:, :, None] == jnp.arange(k_pad)[None, None, :]).astype(jnp.float32)
    return jnp.sum(onehot * w[:, :, None], axis=1)


def node_scores_ref(
    g_indptr, g_indices, g_ew, labels, k: int
) -> jnp.ndarray:
    """Direct CSR oracle: S[v, b] = sum of w(v,u) for u in Gamma(v) with label b."""
    n = g_indptr.shape[0] - 1
    m = g_indices.shape[0]
    src = jnp.repeat(
        jnp.arange(n, dtype=jnp.int32), g_indptr[1:] - g_indptr[:-1],
        total_repeat_length=m,
    )
    out = jnp.zeros((n, k), jnp.float32)
    return out.at[src, labels[g_indices]].add(g_ew)
