"""Pallas TPU kernel: per-node block-connection scores for LP refinement.

The refinement inner loop of the paper — "move v to the eligible block with
the strongest connection" — reduces, for labels in [0, k), to

    S[v, b] = sum_{u in Gamma(v), label(u) = b} w(v, u)

The paper computes this with per-node hash maps (linear probing), which has
no sensible TPU mapping.  The TPU-native formulation: adjacency in row-split
ELL layout (``repro.graph.packing.ell_pack``), neighbour labels pre-gathered
by XLA, and the kernel accumulating a dense (TILE_R, K) score tile in VMEM
with VPU compare+select one-hot accumulation, sweeping the ELL width in
small slices so the (TILE_R, WC, K) broadcast stays inside VMEM.

Layout & tiling:
  * rows (TILE_R = 256) on the grid's first axis — each grid step owns a
    (TILE_R, K) fp32 accumulator in VMEM (256 x 128 x 4 B = 128 KiB);
  * K padded to a lane multiple (128);
  * ELL width swept in WC = 8 slices: working set per step is the
    (TILE_R, WC) label/weight planes (8 KiB each) plus the one-hot
    broadcast (TILE_R x WC x K x 4 B = 1 MiB) — comfortably inside the
    ~16 MiB VMEM budget with double buffering.

A node of degree d owns ceil(d / W) consecutive rows; the caller
segment-sums row scores into node scores (XLA), so power-law degrees cannot
blow up the tile width.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lp_score_rows", "TILE_R", "LANE"]

TILE_R = 256  # rows per grid step
LANE = 128    # TPU lane width; K is padded to a multiple of this
_WC = 8       # ELL-width slice per inner step


def _kernel(lbl_ref, w_ref, out_ref, *, k_pad: int, width: int):
    """Accumulate one (TILE_R, k_pad) score tile."""
    acc = jnp.zeros((lbl_ref.shape[0], k_pad), jnp.float32)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, 1, k_pad), 2)

    def body(j, acc):
        sl = lbl_ref[:, pl.dslice(j * _WC, _WC)]          # (TILE_R, WC)
        sw = w_ref[:, pl.dslice(j * _WC, _WC)]            # (TILE_R, WC)
        onehot = (sl[:, :, None] == iota_k).astype(jnp.float32)
        return acc + jnp.sum(onehot * sw[:, :, None], axis=1)

    steps = width // _WC
    acc = jax.lax.fori_loop(0, steps, body, acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("k_pad", "interpret"))
def lp_score_rows(
    lbl: jnp.ndarray,   # (R, W) int32 — neighbour labels; invalid slots = k_pad (or any >= k)
    w: jnp.ndarray,     # (R, W) f32   — edge weights; invalid slots = 0
    *,
    k_pad: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Per-ELL-row dense block scores, shape (R, k_pad)."""
    R, W = lbl.shape
    assert R % TILE_R == 0, f"rows {R} must be a multiple of {TILE_R}"
    assert k_pad % LANE == 0, f"k_pad {k_pad} must be a multiple of {LANE}"
    assert W % _WC == 0, f"ELL width {W} must be a multiple of {_WC}"
    grid = (R // TILE_R,)
    return pl.pallas_call(
        functools.partial(_kernel, k_pad=k_pad, width=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_R, W), lambda i: (i, 0)),
            pl.BlockSpec((TILE_R, W), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_R, k_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, k_pad), jnp.float32),
        interpret=interpret,
    )(lbl, w)
