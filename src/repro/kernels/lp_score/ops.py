"""Jitted wrapper: node-level block scores + a synchronous dense refinement
round built on the Pallas kernel (the beyond-paper "SpMM refinement" path).
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from ...graph.csr import GraphNP
from ...graph.packing import EllPack, ell_pack
from .lp_score import LANE, TILE_R, lp_score_rows
from .ref import lp_score_rows_ref

__all__ = ["node_scores", "lp_refine_dense_round", "pad_k"]


def pad_k(k: int) -> int:
    return max(LANE, ((k + LANE - 1) // LANE) * LANE)


@functools.partial(jax.jit, static_argnames=("k", "n", "use_pallas", "interpret"))
def _node_scores_impl(
    ell_dst, ell_w, row_node, labels_ext, *, k: int, n: int, use_pallas: bool,
    interpret: bool,
):
    k_p = pad_k(k)
    from .lp_score import TILE_R

    R = ell_dst.shape[0]
    if R % TILE_R:
        pad = TILE_R - R % TILE_R
        ell_dst = jnp.pad(ell_dst, ((0, pad), (0, 0)), constant_values=n)
        ell_w = jnp.pad(ell_w, ((0, pad), (0, 0)))
        row_node = jnp.pad(row_node, (0, pad), constant_values=n)
    lbl = labels_ext[ell_dst]  # XLA gather; sentinel dst -> label k (no contribution)
    if use_pallas:
        row_scores = lp_score_rows(lbl, ell_w, k_pad=k_p, interpret=interpret)
    else:
        row_scores = lp_score_rows_ref(lbl, ell_w, k_pad=k_p)
    # row-split ELL: segment-sum rows into nodes
    seg = jnp.minimum(row_node, n)  # padded rows -> dummy slot n
    out = jnp.zeros((n + 1, k_p), jnp.float32).at[seg].add(row_scores)
    return out[:n, :k]


def node_scores(
    g: GraphNP,
    labels: np.ndarray,
    k: int,
    ell: EllPack | None = None,
    use_pallas: bool = True,
    interpret: bool = True,  # CPU container: interpret mode; False on real TPU
) -> jnp.ndarray:
    """S[v, b] for all nodes; Pallas on the row tiles, XLA for gather/segsum."""
    if ell is None:
        ell = ell_pack(g, width=128, tile_rows=TILE_R)
    labels_ext = jnp.concatenate(
        [jnp.asarray(labels, jnp.int32), jnp.array([k], jnp.int32)]
    )
    return _node_scores_impl(
        jnp.asarray(ell.dst),
        jnp.asarray(ell.w),
        jnp.asarray(ell.row_node),
        labels_ext,
        k=k,
        n=g.n,
        use_pallas=use_pallas,
        interpret=interpret,
    )


def lp_refine_dense_round(
    g: GraphNP,
    labels: np.ndarray,
    k: int,
    U: float,
    seed: int = 0,
    move_fraction: float = 0.5,
    ell: EllPack | None = None,
    use_pallas: bool = True,
    interpret: bool = True,
) -> np.ndarray:
    """One fully synchronous LP refinement round using dense scores.

    All nodes see consistent block weights; a random ``move_fraction`` of
    the proposed moves is applied per round (the standard damping that makes
    synchronous LP converge).  This is the maximally-parallel TPU path —
    one kernel launch + argmax instead of a sequential sweep.
    """
    S = node_scores(g, labels, k, ell=ell, use_pallas=use_pallas, interpret=interpret)
    lab = jnp.asarray(labels, jnp.int32)
    bw = jnp.zeros((k,), jnp.float32).at[lab].add(jnp.asarray(g.nw))
    nw = jnp.asarray(g.nw)
    key = jax.random.PRNGKey(seed)
    fits = bw[None, :] + nw[:, None] <= U
    own_score = jnp.take_along_axis(S, lab[:, None], axis=1)[:, 0]
    overloaded = bw[lab] > U
    eligible = fits | (jnp.arange(k)[None, :] == lab[:, None]) & ~overloaded[:, None]
    eligible &= S > 0
    masked = jnp.where(eligible, S + jax.random.uniform(key, S.shape) * 0.49, -jnp.inf)
    best = jnp.argmax(masked, axis=1).astype(jnp.int32)
    has = jnp.isfinite(jnp.max(masked, axis=1))
    gate = jax.random.uniform(jax.random.fold_in(key, 1), (g.n,)) < move_fraction
    # strict improvement only: cut-neutral moves oscillate under synchronous
    # updates (stale block weights), so they are rejected
    improve = jnp.take_along_axis(S, best[:, None], axis=1)[:, 0] > own_score
    # overloaded blocks shed only their EXCESS in expectation — a synchronous
    # "everyone leaves" stampede would just overload the destination
    excess = jnp.clip((bw[lab] - U) / jnp.maximum(bw[lab], 1.0), 0.0, 1.0)
    ov_gate = jax.random.uniform(jax.random.fold_in(key, 2), (g.n,)) < 1.5 * excess
    new = jnp.where(has & ((gate & improve) | (overloaded & ov_gate)), best, lab)
    return np.asarray(new)
