"""Jitted wrapper: node-level block scores + a synchronous dense refinement
round built on the Pallas kernel (the beyond-paper "SpMM refinement" path).

As of PR 1 this path is wired into the multilevel pipeline: with
``PartitionerConfig(refine_engine="dense")`` the LP engine
(``repro.core.engine``) calls :func:`dense_round_device` once per refinement
iteration at fine levels, reusing a per-level cached ELL pack and keeping
labels device-resident between rounds.  The chunked-sequential sweep remains
the fallback below the size threshold.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from ...graph.csr import GraphNP
from ...graph.packing import EllPack, ell_pack
from .lp_score import LANE, TILE_R, lp_score_rows
from .ref import lp_score_rows_ref

__all__ = [
    "node_scores",
    "lp_refine_dense_round",
    "dense_round_device",
    "dense_round_device_batched",
    "dense_eligibility",
    "pad_k",
]


def pad_k(k: int) -> int:
    return max(LANE, ((k + LANE - 1) // LANE) * LANE)


def _row_scores(ell_dst, ell_w, row_node, lab_pad, n, *, k, use_pallas, interpret):
    """Shared body: ELL row scores segment-summed into (nb, k) node scores.

    Shapes are *bucket* shapes: ``lab_pad`` has ``nb >= n + 1`` entries with
    label ``k`` beyond ``n`` (so sentinel destinations contribute nothing),
    and ``n`` is a TRACED scalar — one compiled executable per
    ``(row bucket, node bucket, k)`` combination serves every level that
    lands in the bucket, instead of re-compiling per level."""
    k_p = pad_k(k)
    R = ell_dst.shape[0]
    nb = lab_pad.shape[0]
    if R % TILE_R:
        pad = TILE_R - R % TILE_R
        # padded rows carry weight 0 and scatter to the dummy slot: inert
        ell_dst = jnp.pad(ell_dst, ((0, pad), (0, 0)))
        ell_w = jnp.pad(ell_w, ((0, pad), (0, 0)))
        row_node = jnp.pad(row_node, (0, pad), constant_values=nb)
    lbl = lab_pad[ell_dst]  # XLA gather; sentinel dst (== n) -> label k
    if use_pallas:
        row_scores = lp_score_rows(lbl, ell_w, k_pad=k_p, interpret=interpret)
    else:
        row_scores = lp_score_rows_ref(lbl, ell_w, k_pad=k_p)
    # row-split ELL: segment-sum rows into nodes; sentinel rows -> dummy nb
    seg = jnp.where(row_node >= n, jnp.int32(nb), row_node)
    out = jnp.zeros((nb + 1, k_p), jnp.float32).at[seg].add(row_scores)
    return out[:nb, :k]


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def _node_scores_impl(
    ell_dst, ell_w, row_node, lab_pad, n, *, k: int, use_pallas: bool,
    interpret: bool,
):
    return _row_scores(
        ell_dst, ell_w, row_node, lab_pad, n,
        k=k, use_pallas=use_pallas, interpret=interpret,
    )


def node_scores(
    g: GraphNP,
    labels: np.ndarray,
    k: int,
    ell: EllPack | None = None,
    use_pallas: bool = True,
    interpret: bool = True,  # CPU container: interpret mode; False on real TPU
) -> jnp.ndarray:
    """S[v, b] for all nodes; Pallas on the row tiles, XLA for gather/segsum."""
    if ell is None:
        ell = ell_pack(g, width=128, tile_rows=TILE_R)
    labels_ext = jnp.concatenate(
        [jnp.asarray(labels, jnp.int32), jnp.array([k], jnp.int32)]
    )
    return _node_scores_impl(
        jnp.asarray(ell.dst),
        jnp.asarray(ell.w),
        jnp.asarray(ell.row_node),
        labels_ext,
        jnp.int32(g.n),
        k=k,
        use_pallas=use_pallas,
        interpret=interpret,
    )[: g.n]


def dense_eligibility(S, lab, bw, nw, U, k: int):
    """Vectorized SCLaP refine-mode eligibility — exact mirror of the
    sequential oracle (``sclap_numpy``):

      * node in an overloaded block: may move to any *connected* block that
        fits, own block excluded ("must leave");
      * otherwise: any connected block that fits, or its own block.

    Connectivity (``S > 0``) applies in both branches because the oracle only
    ever considers neighbouring blocks as candidates.  Note the explicit
    parenthesisation: ``&`` binds tighter than ``|``, which previously turned
    this rule into ``fits | (own & ~overloaded)`` — letting overloaded nodes
    "stay put" and non-fitting moves through (regression-tested in
    tests/test_kernels.py::test_dense_eligibility_matches_sclap_numpy).
    """
    own = jnp.arange(k, dtype=lab.dtype)[None, :] == lab[:, None]
    fits = bw[None, :] + nw[:, None] <= U
    overloaded = (bw[lab] > U)[:, None]
    return (S > 0) & jnp.where(overloaded, fits & ~own, fits | own)


def _dense_round_body(
    ell_dst, ell_w, row_node, lab, nw, U, seed, move_fraction, n,
    *, k, use_pallas, interpret,
):
    nb = lab.shape[0]
    valid = jnp.arange(nb, dtype=jnp.int32) < n
    # padded slots must keep label k: that is the sentinel-destination label
    # the ELL gather relies on, and it keeps them out of every block weight
    lab = jnp.where(valid, lab, jnp.int32(k))
    nw = jnp.where(valid, nw, 0.0)
    S = _row_scores(
        ell_dst, ell_w, row_node, lab, n,
        k=k, use_pallas=use_pallas, interpret=interpret,
    )
    lab_c = jnp.minimum(lab, k - 1)         # clamp for (k,)-table lookups
    bw = jnp.zeros((k,), jnp.float32).at[jnp.minimum(lab, k)].add(
        nw, mode="drop"
    )
    key = jax.random.PRNGKey(seed)
    own_score = jnp.take_along_axis(S, lab_c[:, None], axis=1)[:, 0]
    overloaded = bw[lab_c] > U
    eligible = dense_eligibility(S, lab_c, bw, nw, U, k)
    masked = jnp.where(eligible, S + jax.random.uniform(key, S.shape) * 0.49, -jnp.inf)
    best = jnp.argmax(masked, axis=1).astype(jnp.int32)
    has = jnp.isfinite(jnp.max(masked, axis=1))
    gate = jax.random.uniform(jax.random.fold_in(key, 1), (nb,)) < move_fraction
    # strict improvement only: cut-neutral moves oscillate under synchronous
    # updates (stale block weights), so they are rejected
    improve = jnp.take_along_axis(S, best[:, None], axis=1)[:, 0] > own_score
    # overloaded blocks shed only their EXCESS in expectation — a synchronous
    # "everyone leaves" stampede would just overload the destination
    excess = jnp.clip((bw[lab_c] - U) / jnp.maximum(bw[lab_c], 1.0), 0.0, 1.0)
    ov_gate = jax.random.uniform(jax.random.fold_in(key, 2), (nb,)) < 1.5 * excess
    move = valid & has & ((gate & improve) | (overloaded & ov_gate))
    return jnp.where(move, best, lab)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def dense_round_device(
    ell_dst,            # (Rb, W) int32 — cached device ELL pack (row bucket)
    ell_w,              # (Rb, W) f32
    row_node,           # (Rb,)  int32, sentinel n
    lab,                # (nb,)  int32 — device labels, k beyond n
    nw,                 # (nb,)  f32 — node weights, 0 beyond n
    U,                  # scalar f32
    seed,               # scalar int32
    move_fraction,      # scalar f32
    n,                  # TRACED scalar int32 — live node count
    *,
    k: int,
    use_pallas: bool,
    interpret: bool,
):
    """One fully synchronous dense LP round, device arrays in and out.

    All array arguments are *bucket*-shaped (pow2 rows / pow2 node count)
    with the live node count traced, so the LP engine compiles this once per
    bucket rather than once per level; iterating it is ``iters`` kernel
    launches with zero host round-trips.
    """
    return _dense_round_body(
        ell_dst, ell_w, row_node, lab, nw, U, seed, move_fraction, n,
        k=k, use_pallas=use_pallas, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def dense_round_device_batched(
    ell_dst,            # (Rb, W) int32 — shared cached ELL pack
    ell_w,              # (Rb, W) f32
    row_node,           # (Rb,)  int32, sentinel n
    labs,               # (B, nb) int32 — population label batch
    nw,                 # (nb,)  f32 — shared node weights
    U,                  # scalar f32
    seeds,              # (B,) int32 — per-individual round seeds
    move_fraction,      # scalar f32
    n,                  # traced scalar int32
    *,
    k: int,
    use_pallas: bool,
    interpret: bool,
):
    """Population-batched synchronous dense round: a ``vmap`` label axis over
    :func:`dense_round_device`'s body with the ELL pack shared across the
    batch — one kernel dispatch refines every individual, and each row is
    bit-identical to a per-individual :func:`dense_round_device` call with
    the same seed (tested in tests/test_kernels.py)."""
    return jax.vmap(
        lambda lab, sd: _dense_round_body(
            ell_dst, ell_w, row_node, lab, nw, U, sd, move_fraction, n,
            k=k, use_pallas=use_pallas, interpret=interpret,
        )
    )(labs, seeds)


def lp_refine_dense_round(
    g: GraphNP,
    labels: np.ndarray,
    k: int,
    U: float,
    seed: int = 0,
    move_fraction: float = 0.5,
    ell: EllPack | None = None,
    use_pallas: bool = True,
    interpret: bool = True,
) -> np.ndarray:
    """One fully synchronous LP refinement round using dense scores.

    All nodes see consistent block weights; a random ``move_fraction`` of
    the proposed moves is applied per round (the standard damping that makes
    synchronous LP converge).  Host convenience wrapper around
    :func:`dense_round_device`.
    """
    if ell is None:
        ell = ell_pack(g, width=128, tile_rows=TILE_R)
    lab_pad = np.concatenate(
        [np.asarray(labels, np.int32), np.array([k], np.int32)]
    )
    nw_pad = np.concatenate([g.nw.astype(np.float32), np.zeros(1, np.float32)])
    new = dense_round_device(
        jnp.asarray(ell.dst),
        jnp.asarray(ell.w),
        jnp.asarray(ell.row_node),
        jnp.asarray(lab_pad),
        jnp.asarray(nw_pad),
        jnp.float32(U),
        jnp.int32(seed & 0x7FFFFFFF),
        jnp.float32(move_fraction),
        jnp.int32(g.n),
        k=k,
        use_pallas=use_pallas,
        interpret=interpret,
    )
    return np.asarray(new[: g.n])
