from .lp_score import lp_score_rows
from .ops import (
    dense_eligibility,
    dense_round_device,
    dense_round_device_batched,
    lp_refine_dense_round,
    node_scores,
    pad_k,
)
from .ref import lp_score_rows_ref, node_scores_ref

__all__ = [
    "lp_score_rows",
    "lp_score_rows_ref",
    "node_scores",
    "node_scores_ref",
    "lp_refine_dense_round",
    "dense_round_device",
    "dense_round_device_batched",
    "dense_eligibility",
    "pad_k",
]
