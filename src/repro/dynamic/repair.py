"""Incremental repair kernels (dynamic subsystem, layer 2).

The paper's size-constrained label propagation is a *local-move* algorithm:
every decision reads only a node's incident edges, the candidate block
weights, and the bound ``L_max``.  That locality is what makes it a repair
kernel — after a batch of edge/node updates, only the h-hop neighbourhood
of the touched endpoints can profit from moving, so the repairer

1. expands the **affected region** on device (:func:`expand_region_device`:
   a frontier scatter per hop over the resident arc arrays; hops past the
   first are *hub-bounded* — they only expand through nodes of degree
   <= ``deg_cap``, so power-law hubs stop dragging the whole graph into a
   2-hop region while remaining movable themselves),
2. runs the engine's cached ``_lp_sweep`` over a *region pack* — chunks
   containing only region nodes, dispatched by
   :meth:`repro.core.engine.LPEngine.repair` — against **exact global block
   weights** (the §III-A refinement invariant: eligibility is
   ``c(V_b) + c(v) <= L_max`` on the true block weights, never a
   region-local estimate, and nodes of an overloaded block must leave it),
3. finishes with region-masked synchronous **gain** rounds
   (:func:`gain_round_device`, the device twin of
   :func:`repro.core.fm.gain_round_np` — op-for-op identical plus the
   region gate) and **balance-repair** rounds
   (:func:`balance_rounds_device`, the twin of the batched evolution's
   repair rounds) so the size constraint is re-established locally after
   node-weight churn.

Nodes outside the region are read-only context (their labels feed the
connection sums but never change), so a repaired partition differs from its
input only inside the region — the property the session's bit-identity
guarantees build on.  All kernels are shape-bucketed with traced live
counts: a steady update stream compiles once per bucket
(``repair_compiles == repair_bucket_count``, regression-tested).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ..core.label_propagation import _hash_base, _hash_jitter, _hash_mix

__all__ = [
    "expand_region_device",
    "gain_round_device",
    "balance_rounds_device",
    "TAG_DYN_GAIN",
    "TAG_DYN_GAIN_GATE",
    "TAG_DYN_BAL",
]

_NEG = -1e30

# hash-stream tags for the repair rounds — a namespace disjoint from the
# evolution tags (0x5EED..), so a repair round can never collide with an
# evolution decision on the same seed
TAG_DYN_GAIN = 0xD7A401
TAG_DYN_GAIN_GATE = 0xD7A402
TAG_DYN_BAL = 0xD7A403


def _hash_unit(base, a, b):
    h = _hash_mix(_hash_mix(base, a), b)
    return (h & jnp.uint32(0xFFFFFF)).astype(jnp.float32) / float(1 << 24)


@functools.partial(jax.jit, static_argnames=("A",))
def expand_region_device(touched, src, dst, indptr, n, hops, deg_cap, *,
                         A: int):
    """h-hop frontier expansion over the resident arc arrays.

    Args:
      touched: (Tb,) int32 touched node ids, padded with ``n`` (inert: the
        sentinel slot is outside the live region slice).
      src, dst: (>= m,) int32 arc endpoints; trailing padding arcs are
        (0, 0) and only ever re-mark node 0 from itself — inert.
      indptr: (>= n + 1,) int32 CSR row pointers (for per-arc source
        degrees; only read when the cap can bind).
      n: traced live node count.
      hops: traced hop count.
      deg_cap: traced degree threshold for hops past the first: hop 1 is
        always the touched nodes' full neighbourhood, but hops 2..h only
        expand *through* nodes of degree <= deg_cap.  On power-law graphs a
        2-hop region through a hub is ~the whole graph — repair quality
        doesn't need it (the hub itself is in the region and movable), so
        the cap restores the O(local) region size hubs destroy.  Pass
        ``0x7FFFFFFF`` to disable (bit-identical to the uncapped PR-4
        expansion).
      A: static mask length (the engine arena size).

    Returns an (A,) bool mask: True for every node within ``hops`` hops of a
    touched node (hub-gated past hop 1).  One executable per
    (Tb, m-bucket, indptr-bucket, A) shape.
    """
    mask = jnp.zeros((A,), jnp.bool_).at[touched].max(touched < n)
    last = indptr.shape[0] - 1
    deg_src = indptr[jnp.minimum(src + 1, last)] - indptr[src]

    def hop(i, mk):
        allow = mk[src] & ((i == 0) | (deg_src <= deg_cap))
        reach = jnp.zeros((A,), jnp.bool_).at[dst].max(allow)
        return mk | reach

    return lax.fori_loop(0, hops, hop, mask)


@functools.partial(jax.jit, static_argnames=("Kb",))
def gain_round_device(
    src, dst, ew, nw, lab, region, n, k, Lmax, base_score, base_gate, *, Kb: int
):
    """One region-masked synchronous best-gain round.

    Device twin of :func:`repro.core.fm.gain_round_np` with
    ``region=..., influx_gate=True`` (op-for-op identical — parity-tested).
    Two gates beyond the evolution's FM-lite round: only nodes inside
    ``region`` may move, and — exactly like the chunked sweep's
    refine-mode influx gating — each block's *net* synchronous inflow is
    capped at its headroom in expectation.  Without the cap a synchronous
    round on a community-less (R-MAT-like) graph piles thousands of
    individually-fitting movers into one block, blowing the balance bound
    by orders of magnitude; the evolution tolerates that (its fitness keys
    penalize infeasibility and elitism rejects), a repair step must not.
    """
    Ab = lab.shape[0]
    iota = jnp.arange(Ab, dtype=jnp.int32)
    kio = jnp.arange(Kb, dtype=jnp.int32)
    conn = jnp.zeros((Ab, Kb), jnp.float32).at[src, lab[dst]].add(ew)
    own = jnp.take_along_axis(conn, jnp.minimum(lab, Kb - 1)[:, None], 1)[:, 0]
    bw = jnp.zeros((Kb,), jnp.float32).at[jnp.minimum(lab, Kb - 1)].add(nw)
    bwx = jnp.where(kio < k, bw, jnp.inf)
    jit = _hash_jitter(base_score, iota[:, None], kio[None, :])
    fits = bwx[None, :] + nw[:, None] <= Lmax
    elig = fits & (kio[None, :] != lab[:, None]) & (conn > own[:, None])
    score = jnp.where(elig, conn + jit, _NEG)
    b = jnp.argmax(score, axis=1).astype(jnp.int32)
    has = jnp.take_along_axis(score, b[:, None], 1)[:, 0] > _NEG / 2
    u = _hash_unit(base_gate, iota, jnp.int32(0))
    move = has & (u < 0.5) & (iota < n) & region
    # influx gate (the sweep's refine-mode cap, applied synchronously):
    # accept a mover into block b with prob clip((Lmax - w_b + outflow_b)
    # / inflow_b, 0, 1), so each block's net inflow matches its headroom in
    # expectation.  Swap-heavy rounds (inflow ~ outflow) pass untouched.
    mv_w = jnp.where(move, nw, 0.0)
    inflow = jnp.zeros((Kb,), jnp.float32).at[jnp.where(move, b, k)].add(
        mv_w, mode="drop"
    )
    outflow = jnp.zeros((Kb,), jnp.float32).at[
        jnp.where(move, jnp.minimum(lab, Kb - 1), k)
    ].add(mv_w, mode="drop")
    head = Lmax - bw + outflow
    p_in = jnp.clip(head / jnp.maximum(inflow, 1e-9), 0.0, 1.0)
    u2 = _hash_unit(base_gate, iota, jnp.int32(1))
    move &= u2 < p_in[jnp.minimum(b, k)]
    return jnp.where(move, b, lab)


@functools.partial(jax.jit, static_argnames=("Kb", "rounds"))
def balance_rounds_device(
    nw, lab, region, n, k, Lmax, seed, *, Kb: int, rounds: int
):
    """Region-masked synchronous balance-repair rounds.

    Analog of the batched evolution's repair rounds
    (``repro.core.evo_device._repair_rounds``) with expectation gates
    normalized for the serving regime: an overloaded block sheds ~1.5x its
    *excess weight* (not a fraction of its total — the evolution's
    fractional gate never fires on the hairline overshoots a repair step
    sees), carried by region nodes only, into the globally lightest block;
    a second gate caps the lightest block's synchronous inflow at its own
    headroom.  Node-weight churn from ``add_nodes`` is local, so local
    shedding restores ``L_max`` whenever the overload sits inside the
    region; the caller's guard rejects/escalates when it does not.
    """
    Ab = lab.shape[0]
    iota = jnp.arange(Ab, dtype=jnp.int32)
    kio = jnp.arange(Kb, dtype=jnp.int32)

    def rep(r, lab):
        lab_c = jnp.minimum(lab, Kb - 1)
        bw = jnp.zeros((Kb,), jnp.float32).at[lab_c].add(nw)
        bwx = jnp.where(kio < k, bw, jnp.inf)
        tgt = jnp.argmin(bwx).astype(jnp.int32)
        over = bwx > Lmax
        movable = (iota < n) & region & over[jnp.minimum(lab, k)] & (lab != tgt)
        # shed ~1.5x the excess WEIGHT in expectation: p = 1.5 * excess /
        # (movable weight of the block), exact-scale for hairline overshoots
        movw = jnp.zeros((Kb,), jnp.float32).at[
            jnp.where(movable, lab_c, k)
        ].add(jnp.where(movable, nw, 0.0), mode="drop")
        excess = jnp.clip(jnp.where(kio < k, bw, 0.0) - Lmax, 0.0, None)
        p_shed = jnp.clip(1.5 * excess / jnp.maximum(movw, 1e-9), 0.0, 1.0)
        base_r = _hash_mix(
            _hash_base(seed, r, TAG_DYN_BAL), jnp.uint32(0x9E3779B1)
        )
        u = _hash_unit(base_r, iota, jnp.int32(0))
        mv = movable & (u < p_shed[jnp.minimum(lab, k)])
        # cap the lightest block's inflow at its headroom (all movers of a
        # round target the same block)
        inflow = jnp.sum(jnp.where(mv, nw, 0.0))
        p_in = jnp.clip(
            (Lmax - bw[tgt]) / jnp.maximum(inflow, 1e-9), 0.0, 1.0
        )
        u2 = _hash_unit(base_r, iota, jnp.int32(1))
        mv &= u2 < p_in
        return jnp.where(mv, tgt, lab)

    return lax.fori_loop(0, rounds, rep, lab)
