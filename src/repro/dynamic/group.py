"""Multi-tenant throughput mode (dynamic subsystem, layer 4 — ISSUE 8).

One serving process rarely hosts ONE graph: the north-star workload is many
independent (graph, partition) sessions — tenants — each absorbing its own
update stream.  Serving them one ``session.update`` at a time leaves the
device idle between small repair kernels.  :class:`SessionGroup` batches
the repair across tenants instead: every per-tenant repair kernel (frontier
expansion, region-pack gather, the chunked LP sweep, gain and balance
rounds, the guard's cut/weight reductions) is ``vmap``-ped over a tenant
axis — the same population-axis trick ``evolve_device`` plays — so a
bucket of compatible tenants costs ONE executable dispatch per kernel
instead of T.

Bucketing: tenants batch together when their compiled shapes agree —
``(arena A, arc bucket Mb, indptr bucket, k, pack geometry, repair
config)``.  Within a bucket, per-step quantities that differ (live counts
n/m, region sizes, chunk counts, seeds, L_max) ride as traced per-lane
scalars, and host-planned layouts are padded to shared pow2 buckets
(touched Tb, chunks Cb, edge capacity Eb).  All padding is label-inert —
padded touched slots carry the sentinel ``n``, padded chunks are never
visited by the sweep's traced chunk loop, padded edges are invalid — so
every lane's labels are **bit-identical to a solo** ``session.update`` of
the same stream (regression-tested), and one executable per bucket serves
the whole group (``group_compiles == group_bucket_count``).

Updates that change the node set (adds or removals), net no-ops, and
post-repair escalations fall back to the solo path per tenant — the group
only accelerates the steady edge-churn regime, which is where throughput
lives.  The merged update stream API (:meth:`SessionGroup.update_many`)
accepts an interleaved ``(tenant, update)`` stream and coalesces multiple
updates per tenant into one batch per step (:meth:`GraphUpdate.merged`).
"""

from __future__ import annotations

import functools
import time
from typing import Dict, Iterable, List, Mapping, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.engine import _pow2
from ..core.label_propagation import _lp_sweep, hash_base_u32
from ..graph.packing import gather_pack_device, plan_region_pack
from ..obs import RegistryBackedStats
from ..obs import span as _obs_span
from ..obs import watchdog as _obs_watchdog
from .repair import (
    TAG_DYN_GAIN,
    TAG_DYN_GAIN_GATE,
    balance_rounds_device,
    expand_region_device,
    gain_round_device,
)
from .session import PartitionSession, UpdateResult
from .store import GraphUpdate

__all__ = ["SessionGroup", "GroupStats"]


# ---------------------------------------------------------------- kernels
#
# Each group kernel is jit(vmap(solo kernel)): the solo kernel's traced
# scalars become (T,) per-lane arrays, shared statics stay static, and
# values identical across lanes (hops, k, the restrict dummy) ride as
# unbatched closure captures.  Compilation caches on the batched shapes,
# so a steady group stream compiles once per bucket.

@functools.partial(jax.jit, static_argnames=("A",))
def _group_expand(touched, src, dst, indptr, n, hops, cap, *, A: int):
    return jax.vmap(
        lambda t, s, d, i, nn, cc: expand_region_device(
            t, s, d, i, nn, hops, cc, A=A
        )
    )(touched, src, dst, indptr, n, cap)


@functools.partial(jax.jit, static_argnames=("E",))
def _group_gather(nodes, nv, indptr, indices, ew, n, *, E: int):
    return jax.vmap(
        lambda a, b, c, d, e, f: gather_pack_device(a, b, c, d, e, f, E=E)
    )(nodes, nv, indptr, indices, ew, n)


@functools.partial(jax.jit, static_argnames=("Kb",))
def _group_bw(nwa, lab, *, Kb: int):
    return jax.vmap(
        lambda nw, l: jnp.zeros((Kb,), jnp.float32)
        .at[jnp.minimum(l, Kb - 1)].add(nw)
    )(nwa, lab)


@functools.partial(jax.jit, static_argnames=("iters",))
def _group_sweep(nodes, nv, ed, ew_, es, ev, lab, w0, nwa, U, seed, k,
                 nchunks, *, iters: int):
    restrict = jnp.zeros(1, jnp.int32)

    def one(a, b, c, d, e, f, l, w, nw, u, s, nc):
        out, _, _ = _lp_sweep(
            a, b, c, d, e, f, l, w, nw, restrict, u, s, k, nc,
            iters=iters, refine_mode=True, use_restrict=False,
            permute_chunks=True,
        )
        return out

    return jax.vmap(one)(nodes, nv, ed, ew_, es, ev, lab, w0, nwa, U, seed,
                         nchunks)


@functools.partial(jax.jit, static_argnames=("Kb",))
def _group_gain(src, dst, ew, nwa, lab, region, n, k, U, bs, bg, *, Kb: int):
    return jax.vmap(
        lambda s, d, e, w, l, r, nn, u, a, b: gain_round_device(
            s, d, e, w, l, r, nn, k, u, a, b, Kb=Kb
        )
    )(src, dst, ew, nwa, lab, region, n, U, bs, bg)


@functools.partial(jax.jit, static_argnames=("Kb", "rounds"))
def _group_balance(nwa, lab, region, n, k, U, seed, *, Kb: int, rounds: int):
    return jax.vmap(
        lambda w, l, r, nn, u, s: balance_rounds_device(
            w, l, r, nn, k, u, s, Kb=Kb, rounds=rounds
        )
    )(nwa, lab, region, n, U, seed)


@functools.partial(jax.jit, static_argnames=("Kb",))
def _group_score(src, dst, ew, nwa, lab_in, lab_out, *, Kb: int):
    def one(s, d, e, nw, li, lo):
        cut_i = jnp.sum(jnp.where(li[s] != li[d], e, 0.0)) / 2.0
        cut_o = jnp.sum(jnp.where(lo[s] != lo[d], e, 0.0)) / 2.0
        bw_o = jnp.zeros((Kb,), jnp.float32).at[
            jnp.minimum(lo, Kb - 1)
        ].add(nw)
        ews = jnp.sum(e) / 2.0
        return cut_i, cut_o, bw_o, ews

    return jax.vmap(one)(src, dst, ew, nwa, lab_in, lab_out)


@jax.jit
def _group_select(ok, out, lab):
    return jnp.where(ok[:, None], out, lab)


class GroupStats(RegistryBackedStats):
    """Counters surfaced through ``SessionGroup.stats()``: ``group_steps``
    (update_many calls that dispatched a group), ``lanes_repaired``
    (tenant-updates served by vmapped repair), ``solo_fallbacks``
    (served by session.update), ``noops``, ``coalesced`` (extra updates
    merged into a tenant batch), ``group_compiles`` (distinct group-kernel
    shape buckets)."""

    _COUNTER_FIELDS = (
        "group_steps", "lanes_repaired", "solo_fallbacks", "noops",
        "coalesced", "group_compiles",
    )
    _SET_FIELDS = ("group_buckets",)

    @property
    def group_bucket_count(self) -> int:
        return len(self.group_buckets)


class SessionGroup:
    """Serve a fleet of :class:`PartitionSession` tenants with vmapped
    repair.  Tenants keep their full solo identity (store, engine, labels,
    trajectory, escalation guard) — the group only batches the device work
    of compatible tenants, so any tenant can leave the group and continue
    solo bit-identically at any step."""

    def __init__(self, sessions: Mapping[str, PartitionSession]):
        if not sessions:
            raise ValueError("SessionGroup needs at least one session")
        self.sessions: Dict[str, PartitionSession] = dict(sessions)
        self.stats = GroupStats()
        self._bucket_E: Dict[tuple, int] = {}   # sticky shared edge buckets

    def _note(self, key) -> None:
        if key not in self.stats.group_buckets:
            self.stats.group_buckets.add(key)
            self.stats.group_compiles += 1
            _obs_watchdog().note("group.repair", key)

    # ------------------------------------------------------------- public

    def update_many(
        self, updates: Iterable[Tuple[str, GraphUpdate]]
    ) -> Dict[str, UpdateResult]:
        """Absorb one merged update stream: coalesce per tenant, batch the
        eligible lanes into vmapped repair buckets, fall back to solo
        ``session.update`` for the rest (node adds, no-ops that aren't,
        anything the group cannot batch).  Returns the newest
        :class:`UpdateResult` per updated tenant; per-lane ``seconds`` is
        the group step's wall time amortized over its lanes (the per-update
        cost a throughput consumer sees).

        Every update is validated up front, before ANY tenant's state
        moves — a bad batch aborts the whole call with all sessions
        bit-identical to entry (the solo path's atomicity, lifted to the
        group)."""
        # ---- coalesce the interleaved stream: one batch per tenant ----
        per: Dict[str, GraphUpdate] = {}
        order: List[str] = []
        for name, upd in updates:
            if name not in self.sessions:
                raise KeyError(f"unknown tenant {name!r}")
            if name in per:
                per[name] = per[name].merged(upd)
                self.stats.coalesced += 1
            else:
                per[name] = upd
                order.append(name)
        for name in order:
            per[name].validate(self.sessions[name].store.n)
        results: Dict[str, UpdateResult] = {}
        lanes = []      # eligible: (sess, upd, net_u, net_v)
        for name in order:
            sess, upd = self.sessions[name], per[name]
            net_u, net_v, _ = upd.net_arcs(
                max(sess.store.n + upd.num_new_nodes, 1)
            )
            if net_u.size == 0 and upd.num_new_nodes == 0:
                results[name] = sess.update(upd)     # solo no-op (cheap)
                self.stats.noops += 1
            elif upd.num_new_nodes:
                results[name] = sess.update(upd)     # node churn: solo
                self.stats.solo_fallbacks += 1
            else:
                lanes.append((name, sess, upd, net_u, net_v))
        if not lanes:
            return results
        t0 = time.time()
        # ---- apply + compact per lane, bucket by compiled shapes ----
        buckets: Dict[tuple, list] = {}
        for name, sess, upd, net_u, net_v in lanes:
            sess._step += 1
            sess.store.apply(upd)
            g = sess.store.graph()
            sess._maybe_rebuild_engine()
            if id(g) != sess._base_id:
                sess.engine.evict(keep=(g,))
                sess._base_id = id(g)
            eng, cfg = sess.engine, sess.cfg
            gkey = (
                eng.A, g.indices.shape[0], g.indptr.shape[0], sess.k,
                eng.N, eng._e_request, eng.pack_block, cfg.hops,
                cfg.repair_iters, cfg.gain_rounds, cfg.balance_rounds,
            )
            buckets.setdefault(gkey, []).append(
                (name, sess, g, net_u, net_v)
            )
        for gkey, members in buckets.items():
            with _obs_span(
                "group.lane", cat="group", lanes=len(members),
                tenants=",".join(m[0] for m in members),
            ):
                self._dispatch_bucket(gkey, members, results)
        elapsed = time.time() - t0
        nl = max(len(lanes), 1)
        for name, *_ in lanes:
            results[name].seconds = elapsed / nl
        self.stats.group_steps += 1
        return results

    # ------------------------------------------------------------ internals

    def _dispatch_bucket(self, gkey, members, results) -> None:
        (A, Mb, ipb, k, Npack, e_req, pblock, hops, iters, gain_rounds,
         balance_rounds) = gkey
        T = len(members)
        Kb = k + 1
        # ---- per-lane host planning (mirrors LPEngine.repair 1:1) ----
        seeds, caps, ns, Us = [], [], [], []
        tpads, labs, nwas, srcs, dsts, ews, ips = [], [], [], [], [], [], []
        t_sizes = []
        for name, sess, g, net_u, net_v in members:
            ar = sess.engine._arena(g)
            seeds.append(
                (sess.cfg.seed * 0x9E3779B1 + sess._step) & 0x7FFFFFFF
            )
            hc = sess._hop_cap()
            # same conversion LPEngine.repair applies: None / <= 0 = uncapped
            caps.append(0x7FFFFFFF if hc is None or hc <= 0 else int(hc))
            ns.append(g.n)
            Us.append(sess._lmax())
            touched = np.concatenate([net_u, net_v])
            t_ids = np.unique(touched.astype(np.int64))
            t_ids = t_ids[(t_ids >= 0) & (t_ids < g.n)].astype(np.int32)
            t_sizes.append(max(t_ids.size, 8))
            tpads.append(t_ids)
            labs.append(sess.labels)
            nwas.append(ar.nw_arena)
            srcs.append(ar.src)
            dsts.append(ar.dst)
            ews.append(ar.ew)
            ips.append(g.indptr)
        Tb = _pow2(max(t_sizes))
        tp = np.empty((T, Tb), np.int32)
        for i, t_ids in enumerate(tpads):
            tp[i] = ns[i]
            tp[i, : t_ids.size] = t_ids
        n_d = jnp.asarray(np.asarray(ns, np.int32))
        cap_d = jnp.asarray(np.asarray(caps, np.int32))
        seed_d = jnp.asarray(np.asarray(seeds, np.int32))
        U_d = jnp.asarray(np.asarray(Us, np.float32))
        src_s = jnp.stack(srcs)
        dst_s = jnp.stack(dsts)
        ew_s = jnp.stack(ews)
        ip_s = jnp.stack(ips)
        lab_s = jnp.stack(labs)
        nwa_s = jnp.stack(nwas)
        self._note(("gexpand", T, Tb, Mb, ipb, A))
        masks = _group_expand(
            jnp.asarray(tp), src_s, dst_s, ip_s, n_d, jnp.int32(hops),
            cap_d, A=A,
        )
        masks_np = np.asarray(masks)
        # ---- region pack per lane, padded to shared (Cb, Npack, Eb) ----
        plans = []
        E_need = 0
        C_need = 1
        for i, (name, sess, g, _, _) in enumerate(members):
            region = np.flatnonzero(masks_np[i, : ns[i]])
            order = np.random.default_rng(seeds[i]).permutation(
                region
            ).astype(np.int64)
            oi = jnp.asarray(order.astype(np.int32))
            deg_r = np.asarray(ip_s[i][oi + 1] - ip_s[i][oi]).astype(np.int64)
            nodes, node_valid, C, N, E = plan_region_pack(
                deg_r, order, ns[i], max_nodes=Npack, max_edges=e_req,
                block=pblock,
            )
            plans.append((nodes, node_valid, C, N, region.size))
            E_need = max(E_need, E)
            C_need = max(C_need, C)
        Cb = _pow2(C_need)
        ekey = gkey
        Eb = max(self._bucket_E.get(ekey, 0), -(-E_need // 512) * 512)
        self._bucket_E[ekey] = Eb
        nodes_b = np.empty((T, Cb, Npack), np.int32)
        nv_b = np.zeros((T, Cb, Npack), bool)
        nchunks = np.empty(T, np.int32)
        for i, (nodes, node_valid, C, N, _) in enumerate(plans):
            nodes_b[i] = ns[i]
            nodes_b[i, :C, :N] = nodes
            nv_b[i, :C, :N] = node_valid
            nchunks[i] = C
        nodes_d = jnp.asarray(nodes_b)
        nv_d = jnp.asarray(nv_b)
        nc_d = jnp.asarray(nchunks)
        self._note(("ggather", T, Cb, Npack, ipb, Mb, Eb))
        ed, ew_p, es, ev = _group_gather(
            nodes_d, nv_d, ip_s, dst_s, ew_s, n_d, E=Eb
        )
        # ---- sweep + gain + balance, all lanes at once ----
        bw0 = _group_bw(nwa_s, lab_s, Kb=Kb)
        w0 = bw0.at[:, Kb - 1].set(jnp.inf)
        self._note(("gsweep", T, Cb, Npack, Eb, A, Kb, iters))
        out = _group_sweep(
            nodes_d, nv_d, ed, ew_p, es, ev, lab_s, w0, nwa_s, U_d,
            seed_d, jnp.int32(k), nc_d, iters=iters,
        )
        for r in range(gain_rounds):
            bs = jnp.asarray(np.asarray(
                [hash_base_u32(s, r, TAG_DYN_GAIN) for s in seeds],
                np.uint32,
            ))
            bg = jnp.asarray(np.asarray(
                [hash_base_u32(s, r, TAG_DYN_GAIN_GATE) for s in seeds],
                np.uint32,
            ))
            self._note(("ggain", T, A, Mb, Kb))
            out = _group_gain(
                src_s, dst_s, ew_s, nwa_s, out, masks, n_d, jnp.int32(k),
                U_d, bs, bg, Kb=Kb,
            )
        if balance_rounds:
            self._note(("gbal", T, A, Kb, balance_rounds))
            out = _group_balance(
                nwa_s, out, masks, n_d, jnp.int32(k),
                U_d, jnp.asarray(np.asarray(seeds, np.int32) & 0x7FFFFFFF),
                Kb=Kb, rounds=balance_rounds,
            )
        # ---- guard per lane (the solo guard, batched) ----
        self._note(("gscore", T, Mb, A, Kb))
        cut_i, cut_o, bw_o, ews = _group_score(
            src_s, dst_s, ew_s, nwa_s, lab_s, out, Kb=Kb
        )
        cut_i = np.asarray(cut_i, np.float64)
        cut_o = np.asarray(cut_o, np.float64)
        bw0_np = np.asarray(bw0, np.float64)
        bw_o_np = np.asarray(bw_o, np.float64)
        ews = np.asarray(ews, np.float64)
        ok = np.empty(T, bool)
        for i, (name, sess, g, _, _) in enumerate(members):
            U = Us[i]
            bw_old_max = bw0_np[i, :k].max()
            bw_new_max = bw_o_np[i, :k].max()
            ok_cut = (
                cut_o[i] <= cut_i[i]
                and bw_new_max <= max(bw_old_max, U + 1e-6)
            )
            ok[i] = ok_cut or (bw_old_max > U >= bw_new_max)
        final = _group_select(jnp.asarray(ok), out, lab_s)
        # ---- write back + trajectory + escalation per lane ----
        for i, (name, sess, g, _, _) in enumerate(members):
            sess.labels = final[i]
            self.stats.lanes_repaired += 1
            cut = float(cut_o[i] if ok[i] else cut_i[i])
            bw = (bw_o_np if ok[i] else bw0_np)[i, :sess.k]
            W = max(sess.store.total_node_weight, 1e-9)
            imb = float(bw.max() * sess.k / W - 1.0)
            feas = bool(bw.max() <= Us[i] + 1e-6)
            scaled_ref = sess._cut_ref * (
                max(ews[i], 1e-9) / sess._ew_ref
            )
            wanted = (not feas) or (
                cut > sess.cfg.escalate_cut_ratio * max(scaled_ref, 1.0)
            )
            escalated = wanted and not sess.suppress_escalation
            stale = wanted and sess.suppress_escalation
            if stale:
                sess.suppressed_escalations += 1
            if escalated:
                sess._escalate(seeds[i])
                cut, imb, feas = sess._score(sess.store.base)
            res = UpdateResult(
                step=sess._step, n=sess.store.n, m=sess.store.m, cut=cut,
                imbalance=imb, feasible=feas,
                region_size=int(plans[i][4]),
                escalated=escalated, stale=stale,
                t_mono=time.monotonic(),
            )
            sess.updates_applied += 1
            sess.trajectory.append(res)
            results[name] = res

    def stats_dict(self) -> dict:
        return dict(
            tenants=len(self.sessions),
            group_steps=self.stats.group_steps,
            lanes_repaired=self.stats.lanes_repaired,
            solo_fallbacks=self.stats.solo_fallbacks,
            noops=self.stats.noops,
            coalesced=self.stats.coalesced,
            group_compiles=self.stats.group_compiles,
            group_bucket_count=self.stats.group_bucket_count,
        )
