"""Mutable device-resident graph store (dynamic subsystem, layer 1).

The static pipeline treats the graph as immutable: ``GraphNP`` is built once
and every device structure (arenas, chunk packs, ELL packs) is cached
against its identity.  A serving workload instead sees a *stream* of edge
and node updates.  This module keeps the graph resident on device across
that stream:

* **Base CSR** — a bucket-padded :class:`~repro.graph.csr.GraphDev`
  (uploaded once via :func:`~repro.graph.csr.to_device_csr`, or the output
  of the previous compaction).  All O(m) state stays on device.
* **Delta overlay** — a bounded host-side COO buffer of signed arc-weight
  deltas (``add_edges`` appends ``+w`` arcs, ``remove_edges`` appends
  ``-w``; both directions of each undirected edge).  Batches are cheap
  appends; nothing is re-sorted until compaction.  Weight deltas are
  integral (int32 semantics) so merged float32 sums are exact in any
  order — the precondition every bit-reproducibility guarantee of the
  subsystem rests on.
* **Compaction** — :func:`merge_overlay_device` folds the overlay back into
  CSR as ONE bucketed executable: the PR-2 contraction machinery minus the
  relabel (fused ``u * Nb + v`` value-only key sort, run segmentation,
  scatter-add weight sums, searchsorted CSR rebuild), plus a *drop* of runs
  whose merged weight reaches zero (removed edges).  Overlay batches are
  padded to pow2 buckets and the live count is traced, so a steady update
  stream compiles once per ``(Mb, Rb, Nb)`` bucket — the PR-1 jit-cache
  discipline applied to mutation.

An inverse update stream is lossless: appending ``+w`` then ``-w`` for the
same arcs and compacting reproduces the original CSR bit-for-bit (same
(u, v) sort order as :func:`~repro.graph.csr.from_edges`, exact integral
sums) — regression-tested in tests/test_dynamic.py.
"""

from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..graph.csr import GraphDev, GraphNP, arc_bucket, pow2, to_device_csr
from ..obs import MetricsRegistry, RegistryBackedStats
from ..obs import span as _obs_span
from ..obs import watchdog as _obs_watchdog
from ..obs.memory import account as _mem_account

__all__ = [
    "DynamicGraphStore",
    "GraphUpdate",
    "StoreStats",
    "UpdateValidationError",
    "merge_overlay_device",
    "overlay_view_device",
    "vacuum_device",
]


class UpdateValidationError(ValueError):
    """A :class:`GraphUpdate` failed pre-apply validation.

    Subclasses ``ValueError`` (the historical raise type) and carries a
    structured ``reason`` tag so the resilience layer can quarantine by
    fault class instead of parsing messages.
    """

    def __init__(self, reason: str, detail: str):
        super().__init__(f"{reason}: {detail}")
        self.reason = reason
        self.detail = detail


# Wire format of one serialized GraphUpdate (the WAL record body):
#
#   header  "<4sBBHQI" = magic b"GUPD" | version u8 | flags u8 (reserved 0)
#                        | reserved u16 | payload_len u64 | crc32 u32
#   payload 7 x u64 field lengths (add_u, add_v, add_w, rem_u, rem_v,
#           rem_w, add_node_w) followed by the fields as little-endian
#           int64 in that order.
#
# The crc32 covers the payload only, so a truncated header, a truncated
# payload, and a bit-flipped payload are three distinguishable rejection
# reasons — the durable WAL relies on that to stop replay at the first
# torn/corrupt record instead of applying garbage.
_WIRE_MAGIC = b"GUPD"
_WIRE_VERSION = 1
_WIRE_HEADER = struct.Struct("<4sBBHQI")
_WIRE_FIELDS = ("add_u", "add_v", "add_w", "rem_u", "rem_v", "rem_w",
                "add_node_w")


def _as_ids(a) -> np.ndarray:
    return np.asarray(a, dtype=np.int64).reshape(-1)


def _as_w(w, size: int) -> np.ndarray:
    if w is None:
        return np.ones(size, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64).reshape(-1)
    if not np.all(w == np.round(w)):
        raise ValueError("update weights must be integral (int32 deltas)")
    if w.size and np.abs(w).max() >= 2**24:
        # f32 loses integer exactness at 2^24 — the bound every
        # bit-reproducibility guarantee of the subsystem rests on
        raise ValueError("update weight deltas must stay below 2^24")
    return w.astype(np.int64)


@dataclass
class GraphUpdate:
    """One batched mutation request (all arrays host numpy, int semantics).

    ``add_u/add_v/add_w`` are undirected edges whose weight is *increased*
    by ``w`` (creating the edge if absent); ``rem_u/rem_v/rem_w`` decrease
    it (an edge whose merged weight reaches zero disappears).  ``add_node_w``
    appends new nodes with the given weights; new node ids are assigned
    contiguously from the current n, so a batch may add nodes and then wire
    them up with edges in the same request.
    """

    add_u: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    add_v: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    add_w: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    rem_u: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    rem_v: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    rem_w: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    add_node_w: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))

    @staticmethod
    def add_edges(u, v, w=None) -> "GraphUpdate":
        u, v = _as_ids(u), _as_ids(v)
        return GraphUpdate(add_u=u, add_v=v, add_w=_as_w(w, u.shape[0]))

    @staticmethod
    def remove_edges(u, v, w=None) -> "GraphUpdate":
        u, v = _as_ids(u), _as_ids(v)
        return GraphUpdate(rem_u=u, rem_v=v, rem_w=_as_w(w, u.shape[0]))

    @staticmethod
    def add_nodes(nw) -> "GraphUpdate":
        return GraphUpdate(add_node_w=_as_w(nw, len(np.atleast_1d(nw))))

    @property
    def num_new_nodes(self) -> int:
        return int(self.add_node_w.shape[0])

    def merged(self, other: "GraphUpdate") -> "GraphUpdate":
        """Concatenate two requests into one batch (other's edges may
        reference nodes this batch adds)."""
        cat = np.concatenate
        return GraphUpdate(
            add_u=cat([self.add_u, other.add_u]),
            add_v=cat([self.add_v, other.add_v]),
            add_w=cat([self.add_w, other.add_w]),
            rem_u=cat([self.rem_u, other.rem_u]),
            rem_v=cat([self.rem_v, other.rem_v]),
            rem_w=cat([self.rem_w, other.rem_w]),
            add_node_w=cat([self.add_node_w, other.add_node_w]),
        )

    def validate(self, n_before: int) -> None:
        """Raise :class:`UpdateValidationError` unless the batch is applicable
        to a graph with ``n_before`` nodes.  Covers everything the factory
        helpers enforce (integral weights below 2^24) plus the structural
        checks (endpoint range against the post-batch node set, self loops) —
        so a request built by direct field construction is held to the same
        contract.  Pure read-only: validation never touches store state,
        which is what makes rejection atomic by construction."""
        n_after = int(n_before) + self.num_new_nodes
        for tag, arr in (
            ("add_w", self.add_w), ("rem_w", self.rem_w),
            ("add_node_w", self.add_node_w),
        ):
            a = np.asarray(arr, dtype=np.float64).reshape(-1)
            if a.size and not np.all(a == np.round(a)):
                raise UpdateValidationError(
                    "non_integral_weight", f"{tag} must be integral"
                )
            if a.size and np.abs(a).max() >= 2**24:
                raise UpdateValidationError(
                    "weight_overflow", f"{tag} must stay below 2^24"
                )
        if not (self.add_u.shape[0] == self.add_v.shape[0] == self.add_w.shape[0]):
            raise UpdateValidationError("shape_mismatch", "add arrays disagree")
        if not (self.rem_u.shape[0] == self.rem_v.shape[0] == self.rem_w.shape[0]):
            raise UpdateValidationError("shape_mismatch", "rem arrays disagree")
        u, v, _ = self.arcs()
        if u.size:
            if u.min() < 0 or v.min() < 0 or max(u.max(), v.max()) >= n_after:
                raise UpdateValidationError(
                    "endpoint_out_of_range",
                    f"edge endpoint outside [0, {n_after})",
                )
            if np.any(u == v):
                raise UpdateValidationError(
                    "self_loop", "self loops are not representable"
                )

    # ------------------------------------------------------------ wire format

    def to_bytes(self) -> bytes:
        """Serialize to the length + checksum framed wire format (the WAL
        record body).  Self-delimiting: the header carries the payload
        length, so records can be concatenated into a log and re-split
        without an outer index."""
        fields = [np.ascontiguousarray(getattr(self, f), dtype="<i8")
                  for f in _WIRE_FIELDS]
        payload = struct.pack("<7Q", *(f.size for f in fields))
        payload += b"".join(f.tobytes() for f in fields)
        crc = zlib.crc32(payload) & 0xFFFFFFFF
        return _WIRE_HEADER.pack(
            _WIRE_MAGIC, _WIRE_VERSION, 0, 0, len(payload), crc
        ) + payload

    @staticmethod
    def wire_size(data: bytes) -> int:
        """Total record size (header + payload) of the record at the start
        of ``data``; raises :class:`UpdateValidationError` when even the
        header is torn or unrecognizable."""
        if len(data) < _WIRE_HEADER.size:
            raise UpdateValidationError(
                "wal_truncated",
                f"{len(data)} bytes < {_WIRE_HEADER.size}-byte header",
            )
        magic, ver, _, _, plen, _ = _WIRE_HEADER.unpack_from(data)
        if magic != _WIRE_MAGIC:
            raise UpdateValidationError("wal_bad_magic", repr(magic))
        if ver != _WIRE_VERSION:
            raise UpdateValidationError("wal_bad_version", str(ver))
        return _WIRE_HEADER.size + plen

    @staticmethod
    def from_bytes(data: bytes) -> "GraphUpdate":
        """Parse one record produced by :meth:`to_bytes`.

        Rejects (with :class:`UpdateValidationError`, never a partial
        object) torn headers/payloads (``wal_truncated``), foreign bytes
        (``wal_bad_magic`` / ``wal_bad_version``), bit flips anywhere in
        the payload (``wal_corrupt``, via crc32), and internally
        inconsistent field lengths (``wal_corrupt``).  Trailing bytes
        beyond the framed record are rejected too (``wal_trailing``) so a
        mis-split log cannot silently drop records."""
        total = GraphUpdate.wire_size(data)
        if len(data) < total:
            raise UpdateValidationError(
                "wal_truncated", f"{len(data)} bytes < {total}-byte record"
            )
        if len(data) > total:
            raise UpdateValidationError(
                "wal_trailing", f"{len(data) - total} bytes past the record"
            )
        _, _, _, _, plen, crc = _WIRE_HEADER.unpack_from(data)
        payload = data[_WIRE_HEADER.size:total]
        if (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
            raise UpdateValidationError("wal_corrupt", "payload crc mismatch")
        if plen < 56:
            raise UpdateValidationError(
                "wal_corrupt", f"payload {plen} bytes < 56-byte length block"
            )
        counts = struct.unpack_from("<7Q", payload)
        if 56 + 8 * sum(counts) != plen:
            raise UpdateValidationError(
                "wal_corrupt",
                f"field lengths {counts} disagree with payload size {plen}",
            )
        out, off = {}, 56
        for name, c in zip(_WIRE_FIELDS, counts):
            out[name] = np.frombuffer(
                payload, dtype="<i8", count=c, offset=off
            ).astype(np.int64)
            off += 8 * c
        return GraphUpdate(**out)

    def arcs(self) -> tuple:
        """Symmetric signed arc deltas ``(u, v, w)`` of the batch: both arcs
        per undirected edge, ``+w`` for adds, ``-w`` for removals."""
        u = np.concatenate([self.add_u, self.add_v, self.rem_u, self.rem_v])
        v = np.concatenate([self.add_v, self.add_u, self.rem_v, self.rem_u])
        w = np.concatenate([self.add_w, self.add_w, -self.rem_w, -self.rem_w])
        return u, v, w

    def net_arcs(self, n: int) -> tuple:
        """Deduplicated net arc deltas over the batch — the batch's true
        effect.  Arcs whose adds and removals cancel vanish here, which is
        what makes a net-no-op batch leave labels bit-identical: the session
        skips repair entirely when this comes back empty."""
        u, v, w = self.arcs()
        if u.size == 0:
            return u.astype(np.int64), v.astype(np.int64), w
        key = u * np.int64(n) + v
        order = np.argsort(key, kind="stable")
        key_s, w_s = key[order], w[order]
        boundary = np.empty(key_s.shape[0], dtype=bool)
        boundary[0] = True
        boundary[1:] = key_s[1:] != key_s[:-1]
        run = np.cumsum(boundary) - 1
        net = np.zeros(int(run[-1]) + 1, dtype=np.int64)
        np.add.at(net, run, w_s)
        first = key_s[np.flatnonzero(boundary)]
        live = net != 0
        return (first[live] // n, first[live] % n, net[live])


class StoreStats(RegistryBackedStats):
    """Counters surfaced through ``PartitionSession.stats()``.

    Counter fields live in a :class:`~repro.obs.MetricsRegistry` (attribute
    access reads/writes through); bucket-key sets stay plain sets — tests
    unpack them.  ``compact_compiles`` counts distinct (Mb, Rb, Nb) merge
    buckets, ``view_compiles`` the view buckets, ``vacuum_compiles`` the
    (Mb, Nb) relabel buckets; ``compact_deferred`` counts compactions
    dispatched asynchronously."""

    _COUNTER_FIELDS = (
        "update_batches", "edges_added", "edges_removed",
        "nodes_added", "nodes_removed",
        "compact_calls", "compact_compiles", "compact_deferred",
        "view_calls", "view_compiles",
        "vacuum_calls", "vacuum_compiles",
    )
    _SET_FIELDS = ("compact_buckets", "view_buckets", "vacuum_buckets")

    @property
    def compact_bucket_count(self) -> int:
        return len(self.compact_buckets)

    @property
    def view_bucket_count(self) -> int:
        return len(self.view_buckets)

    @property
    def vacuum_bucket_count(self) -> int:
        return len(self.vacuum_buckets)


def _merge_body(src, dst, ew, ou, ov, ow, nw, n, m, r):
    Mb = src.shape[0]
    Rb = ou.shape[0]
    Nb = nw.shape[0]
    T = Mb + Rb
    iota = jnp.arange(T, dtype=jnp.int32)
    u = jnp.concatenate([src, ou])
    v = jnp.concatenate([dst, ov])
    w = jnp.concatenate([ew, ow])
    valid = jnp.concatenate(
        [jnp.arange(Mb, dtype=jnp.int32) < m, jnp.arange(Rb, dtype=jnp.int32) < r]
    )
    if Nb * Nb < 2**31:
        # fused int32 key, value-only sort (the PR-2 general path): run ids
        # recovered by binary search, weights merged by scatter-add — exact
        # for the integral deltas the store enforces
        big = jnp.int32(2**31 - 1)
        key = jnp.where(valid, u * jnp.int32(Nb) + v, big)
        ks = jnp.sort(key)
        oks = ks < big
        first = jnp.concatenate([oks[:1], oks[1:] & (ks[1:] != ks[:-1])])
        run = (jnp.cumsum(first) - 1).astype(jnp.int32)
        pos = jnp.minimum(jnp.searchsorted(ks, key), T - 1)
        run_of = jnp.where(valid, run[pos], T)
        firstpos = jnp.sort(jnp.where(first, iota, jnp.int32(T)))
        fp = jnp.minimum(firstpos, T - 1)
        uk = ks[fp]
        ru = (uk // jnp.int32(Nb)).astype(jnp.int32)
        rv = (uk % jnp.int32(Nb)).astype(jnp.int32)
    else:
        # > 46k-node graphs: two-pass payload lexsort (mirrors the
        # contract_device fallback; rare at this repo's scales)
        sent = jnp.int32(Nb)
        aorder = jnp.lexsort((jnp.where(valid, v, sent), jnp.where(valid, u, sent)))
        oks = valid[aorder]
        u_s = jnp.where(oks, u[aorder], sent)
        v_s = jnp.where(oks, v[aorder], sent)
        first = jnp.concatenate(
            [oks[:1], oks[1:] & ((u_s[1:] != u_s[:-1]) | (v_s[1:] != v_s[:-1]))]
        )
        run = (jnp.cumsum(first) - 1).astype(jnp.int32)
        run_of = jnp.zeros((T,), jnp.int32).at[aorder].set(
            jnp.where(oks, run, T)
        )
        run_of = jnp.where(valid, run_of, T)
        firstpos = jnp.sort(jnp.where(first, iota, jnp.int32(T)))
        fp = jnp.minimum(firstpos, T - 1)
        ru = u_s[fp]
        rv = v_s[fp]
    nrun = jnp.sum(first).astype(jnp.int32)
    rw = jnp.zeros((T,), jnp.float32).at[run_of].add(
        jnp.where(valid, w, 0.0), mode="drop"
    )
    # drop runs whose merged weight hit zero (removed edges); kept runs stay
    # in (u, v) key order, so a second value-only sort IS the compaction
    keep = (iota < nrun) & (rw > 0.0)
    kpos = jnp.sort(jnp.where(keep, iota, jnp.int32(T)))
    kp = jnp.minimum(kpos, T - 1)
    m_new = jnp.sum(keep).astype(jnp.int32)
    arc_ok = iota < m_new
    src_c = jnp.where(arc_ok, ru[kp], 0).astype(jnp.int32)
    dst_c = jnp.where(arc_ok, rv[kp], 0).astype(jnp.int32)
    ew_c = jnp.where(arc_ok, rw[kp], 0.0)
    cu_sorted = jnp.where(arc_ok, src_c, jnp.int32(Nb))
    indptr_c = jnp.searchsorted(
        cu_sorted, jnp.arange(Nb + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    return indptr_c, src_c, dst_c, ew_c, m_new, jnp.max(nw), jnp.max(ew_c)


merge_overlay_device = jax.jit(_merge_body)
merge_overlay_device.__doc__ = """Fold a COO delta overlay into a CSR on device (one bucketed executable).

Args:
  src, dst, ew: (Mb,) base arcs; entries >= ``m`` are inert padding.
  ou, ov, ow:   (Rb,) overlay arc deltas (symmetric, signed f32 with
    integral values); entries >= ``r`` are inert padding.
  nw:           (Nb,) node weights of the POST-update node set (0 beyond n).
  n, m, r:      traced live counts — one compiled executable per
    ``(Mb, Rb, Nb)`` bucket serves the whole update stream.

Returns ``(indptr, src, dst, ew, m_new, nw_max, ew_max)``, all
device-resident: a merged CSR in (u, v) sort order — identical to what
``from_edges`` would emit for the merged edge list — with zero-weight
(fully removed) edges dropped and GraphDev padding invariants restored.
Removal is saturating: a merged weight at or below zero (removing more
weight than the edge carries, or removing an edge that never existed)
drops the edge rather than raising — the host side cannot cheaply know
per-edge weights without materializing the CSR, so over-removal is defined
as deletion.
"""


def _view_body(indptr, src, dst, ew, ou, ov, ow, n, m, r):
    """Overlay-aware CSR *view*: the merged adjacency without the merge sort.

    Instead of re-sorting all ``m + r`` arcs (``_merge_body``), the overlay
    is deduplicated alone (an O(r log r) sort), each net delta is matched
    into its base CSR row by vectorized binary search (rows are v-sorted by
    the canonical compaction order), matched weights are patched in place,
    dead arcs (merged weight <= 0) are compacted out by a rank scatter, and
    genuinely new arcs are inserted at the tail of their source row.  Total
    device work is O(m) elementwise/cumsum/scatter + O(r log r) — no
    O((m + r) log (m + r)) key sort on the hot path.

    The emitted view has exact merged row degrees and the exact merged arc
    multiset per node; only the within-row arc order differs from the
    canonical CSR (surviving base arcs stay v-sorted, new arcs append
    v-sorted after them).  Every downstream repair kernel is insensitive to
    within-row order — the sweep re-sorts by (slot, candidate label), gain
    rounds and cuts are scatter/reduce sums over integral f32 weights
    (exact in any order) — so repairing on the view is bit-identical to
    repairing on the compacted CSR (regression-tested).
    """
    Mb = src.shape[0]
    Rb = ou.shape[0]
    Nb = indptr.shape[0] - 1
    Mv = Mb + Rb
    iota_r = jnp.arange(Rb, dtype=jnp.int32)
    iota_m = jnp.arange(Mb, dtype=jnp.int32)
    valid_o = iota_r < r
    # ---- dedup the overlay: net signed delta per distinct (u, v) ----
    big = jnp.int32(2**31 - 1)
    key = jnp.where(valid_o, ou * jnp.int32(Nb) + ov, big)
    ks = jnp.sort(key)
    oks = ks < big
    first = jnp.concatenate([oks[:1], oks[1:] & (ks[1:] != ks[:-1])])
    run = (jnp.cumsum(first) - 1).astype(jnp.int32)
    pos = jnp.minimum(jnp.searchsorted(ks, key), Rb - 1)
    run_of = jnp.where(valid_o, run[pos], Rb)
    nrun = jnp.sum(first).astype(jnp.int32)
    dw = jnp.zeros((Rb,), jnp.float32).at[run_of].add(
        jnp.where(valid_o, ow, 0.0), mode="drop"
    )
    firstpos = jnp.sort(jnp.where(first, iota_r, jnp.int32(Rb)))
    fp = jnp.minimum(firstpos, Rb - 1)
    uk = ks[fp]
    run_live = iota_r < nrun
    du = jnp.where(run_live, (uk // jnp.int32(Nb)).astype(jnp.int32), 0)
    dv = jnp.where(run_live, (uk % jnp.int32(Nb)).astype(jnp.int32), 0)
    # ---- match each net delta into its base row (vectorized bisect) ----
    lo = indptr[du]
    row_end = indptr[du + 1]

    def bisect(_, lh):
        lo, hi = lh
        mid = ((lo + hi) >> 1).astype(jnp.int32)
        ltv = dst[jnp.clip(mid, 0, Mb - 1)] < dv
        cont = lo < hi
        lo2 = jnp.where(cont & ltv, mid + 1, lo)
        hi2 = jnp.where(cont & ~ltv, mid, hi)
        return lo2, hi2

    lo, _ = jax.lax.fori_loop(0, 32, bisect, (lo, row_end))
    found = run_live & (lo < row_end) \
        & (dst[jnp.clip(lo, 0, Mb - 1)] == dv)
    # ---- patch matched weights; identical saturating drop semantics to
    # the merge (a merged weight <= 0 removes the arc) ----
    idx = jnp.where(found, lo, jnp.int32(Mb))
    ew_eff = jnp.concatenate(
        [ew, jnp.zeros((1,), jnp.float32)]
    ).at[idx].add(jnp.where(found, dw, 0.0))[:Mb]
    arc_live = (iota_m < m) & (ew_eff > 0.0)
    dead = (iota_m < m) & ~arc_live
    src_s = jnp.where(iota_m < m, src, 0)
    dst_s = jnp.where(iota_m < m, dst, 0)
    dead_cnt = jnp.zeros((Nb,), jnp.int32).at[src_s].add(
        dead.astype(jnp.int32), mode="drop"
    )
    is_new = run_live & ~found & (dw > 0.0)
    new_cnt = jnp.zeros((Nb,), jnp.int32).at[du].add(
        is_new.astype(jnp.int32), mode="drop"
    )
    # ---- merged row pointers: survivors first, new arcs at the tail ----
    deg_base = (indptr[1:] - indptr[:-1]).astype(jnp.int32)
    deg_live = deg_base - dead_cnt
    cum_view = jnp.cumsum(deg_live + new_cnt).astype(jnp.int32)
    zero1 = jnp.zeros((1,), jnp.int32)
    indptr_v = jnp.concatenate([zero1, cum_view])
    live_before = jnp.concatenate(
        [zero1, jnp.cumsum(deg_live).astype(jnp.int32)]
    )[:-1]
    new_before = jnp.concatenate(
        [zero1, jnp.cumsum(new_cnt).astype(jnp.int32)]
    )[:-1]
    gr = jnp.cumsum(arc_live.astype(jnp.int32)) - 1
    pos_base = indptr_v[src_s] + (gr - live_before[src_s])
    gn = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    pos_new = indptr_v[du] + deg_live[du] + (gn - new_before[du])
    tb = jnp.where(arc_live, pos_base, jnp.int32(Mv))
    tn = jnp.where(is_new, pos_new, jnp.int32(Mv))
    # padding arcs stay (0, 0, 0.0) — the arc-array inertness invariant the
    # expansion / gain / cut kernels already rely on for base padding
    src_v = jnp.zeros((Mv,), jnp.int32) \
        .at[tb].set(src_s, mode="drop").at[tn].set(du, mode="drop")
    dst_v = jnp.zeros((Mv,), jnp.int32) \
        .at[tb].set(dst_s, mode="drop").at[tn].set(dv, mode="drop")
    ew_v = jnp.zeros((Mv,), jnp.float32) \
        .at[tb].set(jnp.where(arc_live, ew_eff, 0.0), mode="drop") \
        .at[tn].set(jnp.where(is_new, dw, 0.0), mode="drop")
    return indptr_v, src_v, dst_v, ew_v, cum_view[-1]


overlay_view_device = jax.jit(_view_body)
overlay_view_device.__doc__ = """Build the merged-adjacency view of (base CSR + COO overlay) on device.

Args:
  indptr:       (Nb + 1,) int32 base row pointers (rows >= n hold m).
  src, dst, ew: (Mb,) base arcs; entries >= ``m`` are inert (0, 0, 0).
  ou, ov, ow:   (Rb,) overlay arc deltas (symmetric, signed, integral f32);
    entries >= ``r`` are inert padding.
  n, m, r:      traced live counts — one executable per ``(Mb, Rb, Nb)``.

Returns ``(indptr_v, src_v, dst_v, ew_v, m_view)``: a per-row-contiguous
CSR over ``Mb + Rb`` arc slots whose rows, degrees, and weighted arc
multisets equal the compacted merge's exactly (within-row order differs;
downstream kernels are order-insensitive).  Requires ``Nb * Nb < 2**31``
(int32 fused keys; bigger node buckets take the compaction path).
"""


def _vacuum_body(src, dst, ew, newid, keep, nw, m):
    """Relabel-on-compact: rewrite arcs through ``newid`` and drop
    tombstoned rows.  ``newid`` must be monotone on kept ids (cumsum of
    ``keep``), so within-row v-order and global (u, v) order survive the
    remap — the canonical-CSR invariant the view's binary search needs."""
    Mb = src.shape[0]
    Nb = newid.shape[0]
    iota_m = jnp.arange(Mb, dtype=jnp.int32)
    arc_ok = iota_m < m
    src_r = jnp.where(arc_ok, newid[jnp.where(arc_ok, src, 0)], 0)
    dst_r = jnp.where(arc_ok, newid[jnp.where(arc_ok, dst, 0)], 0)
    ew_r = jnp.where(arc_ok, ew, 0.0)
    cu = jnp.where(arc_ok, src_r, jnp.int32(Nb))
    indptr_r = jnp.searchsorted(
        cu, jnp.arange(Nb + 1, dtype=jnp.int32)
    ).astype(jnp.int32)
    nw_r = jnp.zeros((Nb,), jnp.float32).at[
        jnp.where(keep, newid, jnp.int32(Nb))
    ].add(jnp.where(keep, nw, 0.0), mode="drop")
    return indptr_r, src_r, dst_r, ew_r, nw_r


vacuum_device = jax.jit(_vacuum_body)
vacuum_device.__doc__ = """Compact tombstoned nodes out of a CSR on device.

Args:
  src, dst, ew: the base CSR's arc arrays (no arc may touch a tombstoned
    node — the store enforces isolation before marking).
  newid: (Nb,) int32 old -> new id map (``cumsum(keep) - 1``, clipped 0).
  keep:  (Nb,) bool — False for tombstoned rows.
  nw:    (Nb,) f32 node weights (old id space).
  m:     traced live arc count of the INPUT graph.

Returns ``(indptr, src, dst, ew, nw)`` in the new id space: removed nodes
leave the CSR entirely (rows dropped, ids re-packed contiguously), arcs and
weights are preserved bit-for-bit under the monotone remap (arc count and
within-row order are unchanged, so the output reuses the input buckets).
"""


class DynamicGraphStore:
    """Device-resident base CSR + bounded COO delta overlay.

    ``apply`` appends update batches to the overlay (O(batch) host work,
    no device dispatch); ``compact`` merges the overlay into a fresh
    :class:`GraphDev` base.  ``graph()`` hands out the up-to-date handle,
    compacting first when dirty — callers that need merged adjacency (the
    repair's region gather, cut evaluation) go through it.  The overlay is
    bounded by ``overlay_cap`` arcs; exceeding it triggers an automatic
    compaction, so device memory for pending deltas is O(cap) regardless of
    stream length.
    """

    def __init__(
        self,
        g: GraphNP,
        *,
        overlay_cap: int = 1 << 16,
        on_h2d: Optional[Callable[[int], None]] = None,
        on_d2h: Optional[Callable[[int], None]] = None,
        registry: Optional[MetricsRegistry] = None,
    ):
        if g.m and not bool(np.all(g.ew == np.round(g.ew))):
            raise ValueError("dynamic store requires integral edge weights")
        if g.m and float(g.ew.max()) >= 2**24:
            raise ValueError("edge weights must stay below 2^24 (f32-exact)")
        self._on_h2d = on_h2d or (lambda b: None)
        self._on_d2h = on_d2h or (lambda b: None)
        self.overlay_cap = int(overlay_cap)
        self.stats = StoreStats(registry)
        self.n = g.n
        self._nw = g.nw.astype(np.float64).copy()   # host mirror, authoritative
        self.base: GraphDev = to_device_csr(
            g, on_materialize=self._on_d2h, on_upload=self._on_h2d
        )
        self._nw_dev: Optional[jax.Array] = self.base.nw  # survives compacts
        self._base_host: Optional[GraphNP] = g
        self._ou: List[np.ndarray] = []
        self._ov: List[np.ndarray] = []
        self._ow: List[np.ndarray] = []
        self._olen = 0
        self._pending: Optional[dict] = None    # in-flight deferred merge
        self._tomb: Optional[np.ndarray] = None  # (n,) bool tombstone column
        self.last_vacuum_map: Optional[np.ndarray] = None

    # ------------------------------------------------------------- properties

    @property
    def m(self) -> int:
        """Arc count of the last compacted base (overlay arcs not included
        until ``compact``)."""
        return self.base.m

    @property
    def overlay_len(self) -> int:
        return self._olen

    @property
    def dirty(self) -> bool:
        return self._olen > 0

    @property
    def compact_pending(self) -> bool:
        """A deferred compaction has been dispatched but not finalized."""
        return self._pending is not None

    @property
    def pending_removals(self) -> int:
        """Tombstoned nodes awaiting the relabel-on-compact vacuum."""
        return 0 if self._tomb is None else int(self._tomb.sum())

    @property
    def total_node_weight(self) -> float:
        return float(self._nw.sum())

    def node_weights(self) -> np.ndarray:
        return self._nw

    # ---------------------------------------------------------------- updates

    def apply(self, upd: GraphUpdate) -> None:
        """Append one batch: new nodes first (ids from the current n), then
        the batch's symmetric arc deltas into the overlay.  The whole batch
        is validated up front (:meth:`GraphUpdate.validate`), so a rejected
        request leaves the store untouched (no half-applied node adds)."""
        upd.validate(self.n)
        u, v, w = upd.arcs()
        n_after = self.n + upd.num_new_nodes
        if upd.num_new_nodes:
            self._nw = np.concatenate(
                [self._nw, upd.add_node_w.astype(np.float64)]
            )
            self.n = n_after
            self.stats.nodes_added += upd.num_new_nodes
            self._nw_dev = None         # device mirror is stale
        if u.size:
            self._ou.append(u.astype(np.int32))
            self._ov.append(v.astype(np.int32))
            self._ow.append(w.astype(np.float32))
            self._olen += u.size
        self.stats.update_batches += 1
        self.stats.edges_added += int(upd.add_u.shape[0])
        self.stats.edges_removed += int(upd.rem_u.shape[0])
        if self._olen > self.overlay_cap:
            self.compact()

    def add_edges(self, u, v, w=None) -> None:
        self.apply(GraphUpdate.add_edges(u, v, w))

    def remove_edges(self, u, v, w=None) -> None:
        self.apply(GraphUpdate.remove_edges(u, v, w))

    def add_nodes(self, nw) -> None:
        self.apply(GraphUpdate.add_nodes(nw))

    # ------------------------------------------------------------- compaction

    def _pack_overlay(self, Rb: int) -> tuple:
        """Concatenate the overlay chunk lists into Rb-padded COO arrays
        (shared by the merge dispatch and the view build)."""
        ou = np.zeros(Rb, np.int32)
        ov = np.zeros(Rb, np.int32)
        ow = np.zeros(Rb, np.float32)
        o = 0
        for cu, cv, cw in zip(self._ou, self._ov, self._ow):
            ou[o : o + cu.size] = cu
            ov[o : o + cu.size] = cv
            ow[o : o + cu.size] = cw
            o += cu.size
        return ou, ov, ow

    def _dispatch_merge(self) -> None:
        """Dispatch the overlay merge executable WITHOUT blocking on its
        result.  The merge's outputs (and the consumed overlay prefix's
        bookkeeping) park in ``_pending`` until :meth:`_finalize_pending`
        downloads the three result scalars and swaps the base — JAX async
        dispatch lets the caller overlap that device work with the next
        batch's repair."""
        self.stats.compact_calls += 1
        r = self._olen
        Rb = pow2(max(r, 8))
        ou, ov, ow = self._pack_overlay(Rb)
        Nb = pow2(max(self.n, 8))
        # node weights re-upload only after node churn (edge-only streams —
        # the common case — reuse the resident array across compactions)
        if self._nw_dev is None or self._nw_dev.shape[0] != Nb:
            nw = np.zeros(Nb, np.float32)
            nw[: self.n] = self._nw
            self._nw_dev = jnp.asarray(nw)
            _mem_account("base_csr", self._nw_dev)
            self._on_h2d(nw.nbytes)
        ou_d, ov_d, ow_d = jnp.asarray(ou), jnp.asarray(ov), jnp.asarray(ow)
        _mem_account("overlay_chunks", ou_d, ov_d, ow_d)
        self._on_h2d(ou.nbytes + ov.nbytes + ow.nbytes)
        Mb = self.base.indices.shape[0]
        ckey = (Mb, Rb, Nb)
        if ckey not in self.stats.compact_buckets:
            self.stats.compact_buckets.add(ckey)
            self.stats.compact_compiles += 1
            _obs_watchdog().note("store.compact", ckey)
        # base node bucket may be smaller than Nb after node adds; the merge
        # only reads arc arrays + the new nw, so no base re-pad is needed
        with _obs_span(
            "store.compact", cat="store", overlay=int(r), m=int(self.base.m)
        ):
            # deliberately NO sync_on: the merge's async dispatch (deferred
            # compaction overlaps the next batch's repair) must survive
            # tracing — the span covers dispatch, not device completion
            res = merge_overlay_device(
                self.base.src, self.base.indices, self.base.ew,
                ou_d, ov_d, ow_d,
                self._nw_dev,
                jnp.int32(self.n), jnp.int32(self.base.m), jnp.int32(r),
            )
            _mem_account("base_csr", *res[:4])  # in-flight merge outputs
        self._pending = dict(
            res=res, r=r, nchunks=len(self._ou), n=self.n,
            nw_dev=self._nw_dev,
        )

    def _finalize_pending(self) -> bool:
        """Block on a dispatched merge and install its result as the base.

        Returns False (discarding the pending result) when the node set
        changed since dispatch — the merge ran against a stale ``nw`` — so
        the caller re-compacts synchronously.  Overlay chunks consumed by
        the dispatch are dropped only here, which is what keeps snapshots
        and views taken while the merge was in flight consistent: they see
        (old base + full overlay), an equivalent graph."""
        p = self._pending
        self._pending = None
        if p is None:
            return False
        if p["n"] != self.n or p["nw_dev"] is not self._nw_dev:
            return False
        indptr, src_c, dst_c, ew_c, m_new, nwmax, ewmax = p["res"]
        m_new, nwmax, ewmax = jax.device_get((m_new, nwmax, ewmax))
        m_new = int(m_new)
        self._on_d2h(12)
        if float(ewmax) >= 2**24:
            # the first merge whose sums could round in f32: refuse rather
            # than silently break the exact-merge / bit-round-trip contract
            raise ValueError(
                "merged edge weight reached 2^24 — f32 exactness lost"
            )
        Mcb = arc_bucket(m_new)

        def fit(a, L, fill=0):
            if a.shape[0] == L:
                return a
            if a.shape[0] > L:
                return a[:L]
            return jnp.concatenate(
                [a, jnp.full((L - a.shape[0],), fill, a.dtype)]
            )

        self.base = GraphDev(
            indptr=indptr,
            indices=fit(dst_c, Mcb),
            ew=fit(ew_c, Mcb),
            nw=self._nw_dev,
            src=fit(src_c, Mcb),
            n=self.n, m=m_new,
            nw_max=float(nwmax), ew_max=float(ewmax), ew_integral=True,
            on_materialize=self._on_d2h,
        )
        self._base_host = None
        self._ou = self._ou[p["nchunks"]:]
        self._ov = self._ov[p["nchunks"]:]
        self._ow = self._ow[p["nchunks"]:]
        self._olen -= p["r"]
        return True

    def compact(self, deferred: bool = False) -> GraphDev:
        """Merge the overlay into a fresh base CSR (no-op when clean).

        One bucketed device executable (:func:`merge_overlay_device`); only
        the ``(m_new, nw_max, ew_max)`` scalars sync to host.  The previous
        base handle is dropped — callers caching device state against the
        old handle's identity must evict (the session does).

        ``deferred=True`` dispatches the merge and returns immediately with
        the OLD base still installed (the overlay stays queued, so views and
        snapshots remain correct); the swap happens at the next
        ``compact()``/``graph()`` call, by which time the device has
        finished the merge in the background.  Deferral requires a stable
        node set — node adds force the synchronous path."""
        if self._pending is not None and self._finalize_pending():
            if not self.dirty and self.n == self.base.n:
                return self.base
        if not self.dirty and self.n == self.base.n:
            return self.base
        if deferred and self.n == self.base.n and self.dirty:
            self._dispatch_merge()
            self.stats.compact_deferred += 1
            return self.base
        self._dispatch_merge()
        self._finalize_pending()
        return self.base

    # ------------------------------------------------------------ overlay view

    def can_view(self) -> bool:
        """True when :meth:`view` can serve the current state: pending arc
        deltas only — a stable node set (no adds since the last compaction,
        no tombstones awaiting vacuum) and a node bucket small enough for
        the view kernel's fused int32 keys."""
        Nb = self.base.indptr.shape[0] - 1
        return (
            self.dirty
            and self.n == self.base.n
            and self.pending_removals == 0
            and Nb * Nb < 2**31
        )

    def overlay_fraction(self) -> float:
        """Pending overlay arcs as a fraction of the base arc count — the
        quantity the session's ``compact_fraction`` policy thresholds on."""
        return self._olen / max(self.base.m, 1)

    def view(self) -> tuple:
        """Merged-adjacency device view of (base + overlay) WITHOUT
        compacting: ``(indptr, src, dst, ew, m_view)`` over ``Mb + Rb`` arc
        slots (see :func:`overlay_view_device`).  O(m) elementwise device
        work instead of the merge's O((m + r) log (m + r)) sort, and the
        base handle (with every cache keyed on its identity) survives.
        Requires :meth:`can_view`."""
        if not self.can_view():
            raise ValueError("store state not viewable (see can_view)")
        self.stats.view_calls += 1
        r = self._olen
        Rb = pow2(max(r, 8))
        ou, ov, ow = self._pack_overlay(Rb)
        Mb = self.base.indices.shape[0]
        Nb = self.base.indptr.shape[0] - 1
        vkey = (Mb, Rb, Nb)
        if vkey not in self.stats.view_buckets:
            self.stats.view_buckets.add(vkey)
            self.stats.view_compiles += 1
            _obs_watchdog().note("store.view", vkey)
        self._on_h2d(ou.nbytes + ov.nbytes + ow.nbytes)
        ou_d, ov_d, ow_d = jnp.asarray(ou), jnp.asarray(ov), jnp.asarray(ow)
        _mem_account("overlay_chunks", ou_d, ov_d, ow_d)
        with _obs_span(
            "store.view", cat="store", overlay=int(r), m=int(self.base.m)
        ) as sp:
            indptr_v, src_v, dst_v, ew_v, m_view = overlay_view_device(
                self.base.indptr, self.base.src, self.base.indices,
                self.base.ew,
                ou_d, ov_d, ow_d,
                jnp.int32(self.n), jnp.int32(self.base.m), jnp.int32(r),
            )
            sp.sync_on(m_view)
        _mem_account("overlay_chunks", indptr_v, src_v, dst_v, ew_v)
        return indptr_v, src_v, dst_v, ew_v, m_view

    def graph(self) -> GraphDev:
        """The up-to-date device graph: finalizes any in-flight deferred
        merge, compacts when the overlay has pending arcs OR nodes were
        added since the last compaction (node adds leave the overlay clean
        but the base's node set stale), then vacuums pending tombstones
        (relabel-on-compact; consult ``last_vacuum_map`` for the id
        remap)."""
        if self.dirty or self.n != self.base.n or self._pending is not None:
            self.compact()
        if self.pending_removals:
            self.vacuum()
        return self.base

    def csr_host(self) -> GraphNP:
        """Host CSR of the CURRENT graph (compacts, then materializes —
        the escalation path's one O(n + m) download)."""
        g = self.graph()
        if self._base_host is None:
            self._base_host = g.to_host()
        return self._base_host

    # ------------------------------------------------------------- tombstones

    def remove_nodes(self, ids) -> None:
        """Tombstone nodes for removal.  Only *isolated* nodes may be
        removed (disconnect them first with ``remove_edges``); the ids
        leave the CSR — and the id space re-packs contiguously — at the
        next vacuum (:meth:`graph` triggers one automatically)."""
        ids = np.unique(np.asarray(ids, dtype=np.int64))
        if ids.size == 0:
            return
        if ids.min() < 0 or ids.max() >= self.n:
            raise UpdateValidationError(
                "endpoint_out_of_range", f"node id outside [0, {self.n})"
            )
        # degrees must be judged on the MERGED graph: compact pending arc
        # deltas first so an edge removed in this same stream counts
        if self.dirty or self.n != self.base.n or self._pending is not None:
            self.compact()
        ii = jnp.asarray(ids.astype(np.int32))
        self._on_h2d(ids.size * 4)
        deg = np.asarray(
            jax.device_get(self.base.indptr[ii + 1] - self.base.indptr[ii])
        ).astype(np.int64)
        self._on_d2h(deg.nbytes // 2)
        if np.any(deg > 0):
            bad = ids[deg > 0][0]
            raise UpdateValidationError(
                "node_not_isolated",
                f"node {bad} still has degree {int(deg[deg > 0][0])}",
            )
        if self._tomb is None:
            self._tomb = np.zeros(self.n, dtype=bool)
        if np.any(self._tomb[ids]):
            raise UpdateValidationError(
                "node_already_removed", "duplicate tombstone"
            )
        self._tomb[ids] = True
        self.stats.nodes_removed += ids.size

    def vacuum(self) -> Optional[np.ndarray]:
        """Relabel-on-compact: physically drop tombstoned rows from the
        base CSR on device and re-pack node ids contiguously.

        Returns the old -> new id map ((old_n,) int64, -1 for removed
        nodes; also stashed as ``last_vacuum_map``), or None when no
        tombstones are pending.  Arc data survives bit-for-bit under the
        monotone remap; buckets are reused (no re-bucket churn), so the
        only host sync is the map itself."""
        if self.pending_removals == 0:
            return None
        if self.dirty or self.n != self.base.n or self._pending is not None:
            self.compact()
        self.stats.vacuum_calls += 1
        n_old = self.n
        tomb = self._tomb
        keep_h = ~tomb
        newid_h = np.cumsum(keep_h).astype(np.int32) - 1
        mapping = np.where(keep_h, newid_h.astype(np.int64), -1)
        n_new = int(keep_h.sum())
        Mb = self.base.indices.shape[0]
        Nb = self.base.indptr.shape[0] - 1
        vkey = (Mb, Nb)
        if vkey not in self.stats.vacuum_buckets:
            self.stats.vacuum_buckets.add(vkey)
            self.stats.vacuum_compiles += 1
            _obs_watchdog().note("store.vacuum", vkey)
        newid = np.zeros(Nb, np.int32)
        newid[:n_old] = np.maximum(newid_h, 0)
        keep = np.zeros(Nb, bool)
        keep[:n_old] = keep_h
        self._on_h2d(newid.nbytes + keep.nbytes)
        newid_d, keep_d = jnp.asarray(newid), jnp.asarray(keep)
        _mem_account("base_csr", newid_d, keep_d)
        with _obs_span(
            "store.vacuum", cat="store", removed=int(n_old - n_new)
        ) as sp:
            indptr_r, src_r, dst_r, ew_r, nw_r = vacuum_device(
                self.base.src, self.base.indices, self.base.ew,
                newid_d, keep_d, self.base.nw,
                jnp.int32(self.base.m),
            )
            sp.sync_on(nw_r)
        self._nw = self._nw[keep_h]
        self._nw_dev = nw_r
        self.base = GraphDev(
            indptr=indptr_r, indices=dst_r, ew=ew_r, nw=nw_r, src=src_r,
            n=n_new, m=self.base.m,
            nw_max=float(self._nw.max()) if n_new else 0.0,
            ew_max=self.base.ew_max, ew_integral=True,
            on_materialize=self._on_d2h,
        )
        self.n = n_new
        self._tomb = None
        self._base_host = None
        self.last_vacuum_map = mapping
        return mapping

    # ------------------------------------------------------- snapshot support

    def snapshot_state(self) -> dict:
        """O(overlay-chunks) structural snapshot of the store's graph state.

        Every payload array is captured *by reference*: the base
        :class:`GraphDev` holds immutable jax arrays, ``_nw`` and
        ``_nw_dev`` are rebind-only (``apply`` concatenates into a fresh
        array), and overlay chunks are appended but never mutated in place —
        so only the chunk *lists* need copying.  Counters (``stats``) are
        monitoring state, not serving state, and are deliberately excluded."""
        return dict(
            n=self.n,
            base=self.base,
            nw=self._nw,
            nw_dev=self._nw_dev,
            base_host=self._base_host,
            ou=list(self._ou),
            ov=list(self._ov),
            ow=list(self._ow),
            olen=self._olen,
            tomb=None if self._tomb is None else self._tomb.copy(),
        )

    def restore_state(self, st: dict) -> None:
        """Rebind graph state to a :meth:`snapshot_state` capture — restores
        node set, base CSR handle, and the pending overlay bit-identically.
        An in-flight deferred merge is discarded: its consumed-prefix
        bookkeeping refers to the pre-restore chunk lists, and a later
        compaction of the restored overlay reproduces the same graph."""
        self._pending = None
        self.n = st["n"]
        self.base = st["base"]
        self._nw = st["nw"]
        self._nw_dev = st["nw_dev"]
        self._base_host = st["base_host"]
        self._ou = list(st["ou"])
        self._ov = list(st["ov"])
        self._ow = list(st["ow"])
        self._olen = st["olen"]
        tomb = st.get("tomb")
        self._tomb = None if tomb is None else tomb.copy()
