"""Batched update-serving session (dynamic subsystem, layer 3).

:class:`PartitionSession` is the serving loop the ROADMAP's north star asks
for: a graph and its k-way partition stay resident on device; batched
update requests (:class:`~repro.dynamic.store.GraphUpdate`) stream in; each
batch is absorbed by the store, locally repaired by
:meth:`~repro.core.engine.LPEngine.repair`, and scored — the full
multilevel ``partition()`` V-cycle runs only at session start and when the
quality guard trips.

Quality guard (configurable):

* **feasibility** — the paper's hard constraint ``max_b c(V_b) <= L_max``
  with ``L_max = (1 + eps) * ceil(c(V) / k)`` recomputed from the *current*
  total node weight every batch (node churn moves the bound);
* **cut drift** — the running cut is compared against the cut of the last
  full partition, scaled by total edge-weight growth; exceeding
  ``escalate_cut_ratio`` times that reference escalates to a fresh V-cycle
  on the compacted graph (``escalations`` counter).

Bit-reproducibility: a batch whose *net* arc deltas are empty (an empty
batch, or adds cancelled by removals inside the batch) skips repair
entirely and leaves the label array bit-identical — no update, no hash
draw, no sweep.  Every non-trivial path is deterministic in
``(initial graph, config, update stream)``: repair seeds derive from the
step counter, all tie-breaks are stateless hashes.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

import jax.numpy as jnp

from ..core.engine import LPEngine
from ..core.metrics import lmax
from ..core.multilevel import PartitionerConfig, partition
from ..graph.csr import GraphNP
from ..obs import MetricsRegistry
from ..obs import span as _obs_span
from ..obs.memory import account as _mem_account
from .store import DynamicGraphStore, GraphUpdate

__all__ = ["PartitionSession", "SessionConfig", "UpdateResult"]


@dataclass
class SessionConfig:
    k: int = 2
    eps: float = 0.03
    # repair shape: h-hop region radius, LP sweep iterations, gain/balance
    # round counts (fm.py-spec synchronous rounds, region-masked)
    hops: int = 2
    repair_iters: int = 6
    gain_rounds: int = 2
    balance_rounds: int = 3
    # hub-bounded frontier expansion (repair locality on power-law graphs):
    # hops past the first only expand through nodes of degree <= cap, so a
    # 2-hop region no longer engulfs the graph at hubs.  None = auto
    # (8x the current average degree, floored at 64 — meshes and other
    # bounded-degree graphs are never capped), 0 = disabled, > 0 explicit.
    hop_degree_cap: Optional[int] = None
    # escalate to a full V-cycle when the running cut exceeds this ratio of
    # the (edge-weight-scaled) cut of the last full partition
    escalate_cut_ratio: float = 1.6
    overlay_cap: int = 1 << 16
    # compaction-threshold policy (ISSUE 8): 0.0 = compact before every
    # repair (the historical behavior); > 0 = repair directly on the
    # base CSR + overlay *view* while the overlay holds fewer than this
    # fraction of the base arcs, compacting only past the threshold.
    # Labels are bit-identical either way — the knob trades the merge
    # sort's latency against the view's O(m) elementwise rebuild.
    compact_fraction: float = 0.0
    # when a threshold compaction is due, dispatch it asynchronously and
    # keep serving from the view: batch t's merge overlaps batch t(+1)'s
    # repair (JAX async dispatch), and the swap lands at the next update
    defer_compaction: bool = False
    target_chunks: int = 64
    seed: int = 0
    # serving SLO: per-update latency objective + error budget.  The flight
    # recorder (a ring of the last ``flight_recorder_len`` update latencies)
    # feeds the ``slo_budget_remaining`` burn-rate gauge: 1.0 = no recent
    # update breached ``slo_target_seconds``, 0.0 = the window has consumed
    # ``slo_error_budget`` (fraction of updates allowed over target) or more
    slo_target_seconds: float = 0.25
    slo_error_budget: float = 0.1
    flight_recorder_len: int = 128
    # full-pipeline config for session start + escalations; defaults to the
    # paper's fast preset at this (k, eps)
    partition_cfg: Optional[PartitionerConfig] = None

    @classmethod
    def throughput(cls, **kw) -> "SessionConfig":
        """Preset for sustained update streams (the BENCH dynamic_hot
        throughput rows): overlay-aware repair with deferred compaction,
        and a shorter refinement sweep (2 iterations instead of 6 — on the
        ba-16384 benchmark the extra iterations buy < 1.5% cut at ~2.5x
        the latency; the escalation guard still backstops quality)."""
        kw.setdefault("repair_iters", 2)
        kw.setdefault("compact_fraction", 0.25)
        kw.setdefault("defer_compaction", True)
        return cls(**kw)

    def make_partition_cfg(self, seed: int) -> PartitionerConfig:
        if self.partition_cfg is not None:
            cfg = self.partition_cfg
            if cfg.k != self.k:
                raise ValueError("partition_cfg.k must match SessionConfig.k")
            cfg.seed = seed
            return cfg
        return PartitionerConfig(
            k=self.k, eps=self.eps, preset="fast", seed=seed,
            target_chunks=self.target_chunks,
        )


@dataclass
class UpdateResult:
    """One trajectory point of the serving loop."""

    step: int
    n: int
    m: int                      # arcs (2x undirected edges)
    cut: float
    imbalance: float
    feasible: bool
    region_size: int = 0
    escalated: bool = False
    noop: bool = False
    stale: bool = False         # degraded mode: escalation wanted but
                                # suppressed — serving last repaired labels
    used_view: bool = False     # repaired on the base + overlay view
                                # (compaction skipped this step)
    compact_deferred: bool = False  # threshold compaction dispatched async
    seconds: float = 0.0
    h2d_bytes: int = 0          # engine-accounted transfer deltas of the step
    d2h_bytes: int = 0
    t_mono: float = 0.0         # monotonic clock at step END (ordering /
                                # latency joins across restarts use deltas)
    span_ms: Dict[str, float] = field(default_factory=dict)
                                # per-phase wall-ms breakdown (validate /
                                # store / compact / repair / score / ...)


def _reg_counter(name: str):
    """Session counter stored in the stack's :class:`MetricsRegistry` —
    the attribute surface (``sess.escalations += 1``) is unchanged, but
    reset/snapshot/export all go through the one registry path."""

    def _get(self):
        return self.metrics.get(name)

    def _set(self, value):
        self.metrics.set_counter(name, value)

    return property(_get, _set, doc=f"registry-backed counter {name!r}")


class PartitionSession:
    """Device-resident graph + partition absorbing a stream of updates."""

    escalations = _reg_counter("escalations")
    engine_rebuilds = _reg_counter("engine_rebuilds")
    escalate_h2d_saved = _reg_counter("escalate_h2d_saved")
    suppressed_escalations = _reg_counter("suppressed_escalations")
    updates_applied = _reg_counter("updates_applied")
    view_hits = _reg_counter("view_hits")

    def __init__(self, g: GraphNP, cfg: SessionConfig):
        self.cfg = cfg
        self.k = cfg.k
        # one registry per serving stack: engine + store + session counters
        # share it, so a single snapshot()/reset()/Prometheus export covers
        # the whole stack (and tenant stacks never share counters)
        self.metrics = MetricsRegistry("session")
        t0 = time.time()
        rep = partition(g, cfg.make_partition_cfg(cfg.seed))
        self.engine = LPEngine(
            g, target_chunks=cfg.target_chunks, seed=cfg.seed,
            registry=self.metrics,
        )
        self.store = DynamicGraphStore(
            g, overlay_cap=cfg.overlay_cap,
            on_h2d=self._note_h2d, on_d2h=self._note_d2h,
            registry=self.metrics,
        )
        self._base_id = id(self.store.base)
        self.labels = self.engine.to_arena(rep.labels, g.n, fill=self.k)
        self.escalations = 0
        self.engine_rebuilds = 0
        self.escalate_h2d_saved = 0
        self.suppressed_escalations = 0
        self.updates_applied = 0
        self.view_hits = 0
        # degraded mode (set by the resilience watchdog): quality-guard
        # escalations are skipped and the step is flagged ``stale`` instead
        self.suppress_escalation = False
        # flight recorder: (t_mono, seconds) of the most recent updates
        self.flight = deque(maxlen=max(1, cfg.flight_recorder_len))
        self._step = 0
        self._cut_ref = float(rep.cut)
        self._ew_ref = max(float(g.ew.sum()) / 2.0, 1e-9)
        cut, imb, feas = self._score(self.store.base)
        self.trajectory: List[UpdateResult] = [UpdateResult(
            step=0, n=g.n, m=g.m, cut=cut, imbalance=imb, feasible=feas,
            escalated=True, seconds=time.time() - t0,
        )]

    @classmethod
    def from_restored(
        cls,
        g: GraphNP,
        cfg: SessionConfig,
        *,
        labels: np.ndarray,
        step: int,
        cut_ref: float,
        ew_ref: float,
        trajectory: Optional[List[UpdateResult]] = None,
        suppress_escalation: bool = False,
    ) -> "PartitionSession":
        """Rebuild a session from durably-captured state WITHOUT running the
        initial ``partition()`` V-cycle — the disaster-recovery constructor
        (:mod:`repro.resilience.durable`).  ``g`` is the checkpointed base
        graph; ``labels``/``step``/``cut_ref``/``ew_ref`` restore the exact
        serving state, so replaying the same post-checkpoint update stream
        reproduces the pre-crash labels bit for bit (every repair seed
        derives from the restored step counter)."""
        self = cls.__new__(cls)
        self.cfg = cfg
        self.k = cfg.k
        self.metrics = MetricsRegistry("session")
        self.engine = LPEngine(
            g, target_chunks=cfg.target_chunks, seed=cfg.seed,
            registry=self.metrics,
        )
        self.store = DynamicGraphStore(
            g, overlay_cap=cfg.overlay_cap,
            on_h2d=self._note_h2d, on_d2h=self._note_d2h,
            registry=self.metrics,
        )
        self._base_id = id(self.store.base)
        self.labels = self.engine.to_arena(
            np.asarray(labels, np.int32), g.n, fill=self.k
        )
        self.escalations = 0
        self.engine_rebuilds = 0
        self.escalate_h2d_saved = 0
        self.suppressed_escalations = 0
        self.updates_applied = 0
        self.view_hits = 0
        self.suppress_escalation = bool(suppress_escalation)
        self.flight = deque(maxlen=max(1, cfg.flight_recorder_len))
        self._step = int(step)
        self._cut_ref = float(cut_ref)
        self._ew_ref = float(ew_ref)
        if trajectory:
            self.trajectory = list(trajectory)
        else:
            cut, imb, feas = self._score(self.store.base)
            self.trajectory = [UpdateResult(
                step=self._step, n=g.n, m=g.m, cut=cut, imbalance=imb,
                feasible=feas,
            )]
        return self

    # --------------------------------------------------------------- internal

    def _note_h2d(self, nbytes: int) -> None:
        self.engine.stats.h2d_bytes += int(nbytes)

    def _note_d2h(self, nbytes: int) -> None:
        self.engine.stats.d2h_bytes += int(nbytes)

    def _lmax(self) -> float:
        return lmax(self.store.total_node_weight, self.k, self.cfg.eps)

    def _hop_cap(self) -> Optional[int]:
        """Effective frontier degree cap: auto scales with the current
        average degree so bounded-degree (mesh) graphs never bind."""
        c = self.cfg.hop_degree_cap
        if c is None:
            return max(64, int(8 * self.store.m / max(self.store.n, 1)))
        return None if c == 0 else int(c)

    def _record_latency(self, res: UpdateResult) -> None:
        """Push one update latency through the flight recorder and refresh
        the SLO burn-rate gauge.  ``slo_budget_remaining`` is the unburned
        fraction of the window's error budget: with budget ``b`` over a
        window of ``W`` recent updates, up to ``b * W`` of them may exceed
        ``slo_target_seconds`` before the gauge hits 0."""
        self.metrics.observe("update_seconds", res.seconds)
        self.flight.append((res.t_mono, res.seconds))
        target = self.cfg.slo_target_seconds
        bad = sum(1 for _, s in self.flight if s > target)
        allowed = max(self.cfg.slo_error_budget * len(self.flight), 1e-9)
        remaining = max(0.0, 1.0 - bad / allowed)
        self.metrics.gauge("slo_budget_remaining", remaining)

    def _score(self, g) -> tuple:
        """(cut, imbalance, feasible) of the resident labels on device."""
        cut = self.engine.cut(g, self.labels)
        bw = self.engine.block_weights(g, self.labels, self.k)
        self.engine.stats.d2h_bytes += 4 + bw.nbytes
        W = max(self.store.total_node_weight, 1e-9)
        imb = float(bw.max() * self.k / W - 1.0)
        feas = bool(bw.max() <= self._lmax() + 1e-6)
        return float(cut), imb, feas

    def _assign_new_nodes(self, g, first_new: int) -> None:
        """Greedy bin-pack freshly added nodes into the lightest blocks
        before repair (new nodes arrive unlabeled; isolated ones stay where
        bin packing puts them — zero cut cost by construction)."""
        ids = np.arange(first_new, self.store.n, dtype=np.int64)
        if ids.size == 0:
            return
        bw = self.engine.block_weights(g, self.labels, self.k).astype(
            np.float64
        )
        nw = self.store.node_weights()
        asg = np.empty(ids.size, np.int32)
        for i, v in enumerate(ids):
            b = int(np.argmin(bw))
            asg[i] = b
            bw[b] += nw[v]
        self.labels = self.labels.at[jnp.asarray(ids)].set(jnp.asarray(asg))
        _mem_account("label_arenas", self.labels)
        self.engine.stats.h2d_bytes += ids.size * 12

    def _maybe_rebuild_engine(self) -> None:
        """Node growth past the label arena forces a fresh engine (rare:
        the arena has pow2 headroom above the initial n).  Called after the
        post-update compaction, so the new arena is sized for the grown
        graph; labels carry over, fresh slots arrive unassigned (label k)
        for ``_assign_new_nodes`` to place."""
        if self.store.n < self.engine.A:
            return
        gh = self.store.csr_host()
        old_engine = self.engine
        old = np.asarray(self.labels)
        self.engine = LPEngine(
            gh, target_chunks=self.cfg.target_chunks, seed=self.cfg.seed
        )
        # cumulative counters and compile-key sets survive the swap (the
        # jit caches are process-global, so nothing actually recompiles)
        self.engine.carry_from(old_engine)
        lab = np.full(gh.n, self.k, np.int32)
        keep = min(old.shape[0], gh.n)
        lab[:keep] = old[:keep]
        self.labels = self.engine.to_arena(lab, gh.n, fill=self.k)
        self.engine_rebuilds += 1

    def _escalate(self, seed: int) -> None:
        """Full multilevel re-partition of the RESIDENT device graph (the
        quality guard's fallback); resets the cut reference.  The fresh
        V-cycle is seeded with the CURRENT labels through the restrict
        machinery (``PartitionerConfig.initial_labels``): cycle 0 behaves
        like cycle >= 2 of an iterated run, so the escalation refines the
        served solution instead of re-partitioning from scratch.

        ``partition()`` consumes the :class:`GraphDev` handle directly —
        the coarsening chain starts from the already-resident CSR instead
        of re-uploading a host copy, and ``escalate_h2d_saved`` accounts
        the bytes that no longer cross (arc triplet + node weights)."""
        gd = self.store.graph()
        cfg = self.cfg.make_partition_cfg(seed)
        lab = self.labels_np()
        cfg.initial_labels = lab if np.all(lab < self.k) else None
        try:
            rep = partition(gd, cfg)
        finally:
            cfg.initial_labels = None   # never pin O(n) labels on the cfg
        # the host path would have re-uploaded the bucketed CSR (src,
        # indices, ew) plus node weights to build the V-cycle's engine
        self.escalate_h2d_saved += (
            gd.indices.shape[0] * 12 + gd.nw.shape[0] * 4
        )
        self.labels = self.engine.to_arena(rep.labels, gd.n, fill=self.k)
        self._cut_ref = float(rep.cut)
        self._ew_ref = max(float(jnp.sum(gd.ew)) / 2.0, 1e-9)
        self.escalations += 1

    # ----------------------------------------------------------------- public

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def cut(self) -> float:
        return self.trajectory[-1].cut

    @property
    def imbalance(self) -> float:
        return self.trajectory[-1].imbalance

    def labels_np(self) -> np.ndarray:
        return self.engine.to_host(self.labels, self.store.n)

    def update(self, upd: GraphUpdate) -> UpdateResult:
        """Absorb one batched update: validate -> store -> compact -> region
        repair -> quality guard.  Returns (and appends) the new trajectory
        point.  Validation runs before ANY session state moves (including
        the step counter that seeds repair), so a rejected batch leaves the
        session and store bit-identical — replaying the stream after a
        rejection produces the same labels as if the bad batch never
        arrived."""
        with _obs_span(
            "session.update", cat="session", step=self._step + 1
        ) as sp:
            res = self._update_impl(upd)
            sp.set(
                noop=res.noop, escalated=res.escalated,
                used_view=res.used_view, region=res.region_size,
            )
        self._record_latency(res)
        return res

    def _update_impl(self, upd: GraphUpdate) -> UpdateResult:
        t0 = time.time()
        sp_ms: Dict[str, float] = {}
        t_last = time.perf_counter()

        def lap(phase: str) -> None:
            # always-on phase clock (plain perf_counter reads — the < 2%
            # tracing-off overhead budget covers it); feeds span_ms
            nonlocal t_last
            now = time.perf_counter()
            sp_ms[phase] = sp_ms.get(phase, 0.0) + (now - t_last) * 1e3
            t_last = now

        upd.validate(self.store.n)
        lap("validate")
        self._step += 1
        step = self._step
        st = self.engine.stats
        h2d0, d2h0 = st.h2d_bytes, st.d2h_bytes
        prospective_n = self.store.n + upd.num_new_nodes
        net_u, net_v, net_w = upd.net_arcs(max(prospective_n, 1))
        if net_u.size == 0 and upd.num_new_nodes == 0:
            # net no-op: nothing to store, nothing to repair — the resident
            # label array is left untouched (bit-identity guarantee)
            last = self.trajectory[-1]
            res = UpdateResult(
                step=step, n=self.store.n, m=self.store.m, cut=last.cut,
                imbalance=last.imbalance, feasible=last.feasible, noop=True,
                seconds=time.time() - t0,
                t_mono=time.monotonic(), span_ms=sp_ms,
            )
            self.trajectory.append(res)
            return res
        first_new = self.store.n
        self.store.apply(upd)
        lap("store")
        # ---- compaction policy (ISSUE 8): below the threshold, repair on
        # the base + overlay view and skip the merge sort entirely; past
        # it, compact — synchronously, or (defer_compaction) dispatch the
        # merge async and keep serving from the view while it runs
        use_view = (
            self.cfg.compact_fraction > 0.0
            and upd.num_new_nodes == 0
            and self.store.can_view()
        )
        deferred = False
        if use_view and (
            self.store.overlay_fraction() > self.cfg.compact_fraction
        ):
            if self.cfg.defer_compaction:
                self.store.compact(deferred=True)
                deferred = True
            else:
                use_view = False
        if use_view:
            g = self.store.base         # overlay stays pending; the base
            adjacency = self.store.view()   # handle (and every engine cache
        else:                           # keyed on it) survives the step
            g = self.store.graph()      # compacts the overlay
            adjacency = None
        lap("compact")
        self._maybe_rebuild_engine()
        if id(g) != self._base_id:
            # fresh base handle: drop device caches keyed on the old one
            self.engine.evict(keep=(g,))
            self._base_id = id(g)
        self._assign_new_nodes(g, first_new)
        lap("rebuild")
        touched = np.concatenate([
            net_u, net_v,
            np.arange(first_new, self.store.n, dtype=np.int64),
        ])
        seed = (self.cfg.seed * 0x9E3779B1 + step) & 0x7FFFFFFF
        self.labels, rsize, cut, bw = self.engine.repair(
            g, self.labels, touched, self.k, self._lmax(),
            hops=self.cfg.hops, iters=self.cfg.repair_iters,
            gain_rounds=self.cfg.gain_rounds,
            balance_rounds=self.cfg.balance_rounds, seed=seed,
            hop_degree_cap=self._hop_cap(),
            adjacency=None if adjacency is None else adjacency[:4],
        )
        lap("repair")
        # the repair guard already evaluated the returned labels — score
        # the step from its cut/block-weight results, no re-reduction
        W = max(self.store.total_node_weight, 1e-9)
        imb = float(bw.max() * self.k / W - 1.0)
        feas = bool(bw.max() <= self._lmax() + 1e-6)
        if adjacency is None:
            m_now = self.store.m
            ew_now = max(float(jnp.sum(g.ew)) / 2.0, 1e-9)
        else:
            # merged counts come from the view (the base is stale by the
            # pending overlay); padding arcs carry weight 0
            m_now = int(adjacency[4])
            ew_now = max(float(jnp.sum(adjacency[3])) / 2.0, 1e-9)
        st.d2h_bytes += 8
        scaled_ref = self._cut_ref * (ew_now / self._ew_ref)
        wanted = (not feas) or (
            cut > self.cfg.escalate_cut_ratio * max(scaled_ref, 1.0)
        )
        escalated = wanted and not self.suppress_escalation
        stale = wanted and self.suppress_escalation
        lap("score")
        if stale:
            self.suppressed_escalations += 1
        if escalated:
            self._escalate(seed)
            # escalation compacted the store — rescore on the fresh base
            cut, imb, feas = self._score(self.store.base)
            m_now = self.store.m
            lap("escalate")
        self.updates_applied += 1
        if use_view:
            self.view_hits += 1
        res = UpdateResult(
            step=step, n=self.store.n, m=m_now, cut=cut,
            imbalance=imb, feasible=feas, region_size=int(rsize),
            escalated=escalated, stale=stale, used_view=use_view,
            compact_deferred=deferred, seconds=time.time() - t0,
            h2d_bytes=st.h2d_bytes - h2d0, d2h_bytes=st.d2h_bytes - d2h0,
            t_mono=time.monotonic(), span_ms=sp_ms,
        )
        self.trajectory.append(res)
        return res

    def add_edges(self, u, v, w=None) -> UpdateResult:
        return self.update(GraphUpdate.add_edges(u, v, w))

    def remove_edges(self, u, v, w=None) -> UpdateResult:
        return self.update(GraphUpdate.remove_edges(u, v, w))

    def add_nodes(self, nw) -> UpdateResult:
        return self.update(GraphUpdate.add_nodes(nw))

    def remove_nodes(self, ids) -> UpdateResult:
        """Remove *isolated* nodes (disconnect them with ``remove_edges``
        first): tombstone, vacuum the CSR on device (relabel-on-compact —
        ids re-pack contiguously, see ``store.last_vacuum_map`` for the
        old -> new map), and remap the resident labels through the same
        map.  Cut is untouched by construction (no arcs on removed nodes);
        the balance bound tightens as total weight shrinks, so the step
        re-scores feasibility and escalates under the usual guard."""
        t0 = time.time()
        self._step += 1
        step = self._step
        st = self.engine.stats
        h2d0, d2h0 = st.h2d_bytes, st.d2h_bytes
        n_old = self.store.n
        self.store.remove_nodes(ids)    # validates isolation (compacts)
        mapping = self.store.vacuum()
        keep = mapping >= 0
        lab_old = np.asarray(self.labels[:n_old])
        st.d2h_bytes += lab_old.nbytes
        lab_new = lab_old[keep]
        g = self.store.base
        self.engine.evict(keep=(g,))
        self._base_id = id(g)
        self.labels = self.engine.to_arena(lab_new, self.store.n, fill=self.k)
        st.h2d_bytes += lab_new.size * 4
        cut, imb, feas = self._score(g)
        seed = (self.cfg.seed * 0x9E3779B1 + step) & 0x7FFFFFFF
        escalated = stale = False
        if not feas:
            if self.suppress_escalation:
                stale = True
                self.suppressed_escalations += 1
            else:
                escalated = True
                self._escalate(seed)
                cut, imb, feas = self._score(self.store.base)
        res = UpdateResult(
            step=step, n=self.store.n, m=self.store.m, cut=cut,
            imbalance=imb, feasible=feas, escalated=escalated, stale=stale,
            seconds=time.time() - t0,
            h2d_bytes=st.h2d_bytes - h2d0, d2h_bytes=st.d2h_bytes - d2h0,
            t_mono=time.monotonic(),
        )
        self.updates_applied += 1
        self._record_latency(res)
        self.trajectory.append(res)
        return res

    def stats(self) -> dict:
        """Engine + store + session counters (the serving dashboard row)."""
        d = self.engine.stats_dict()
        d.update(
            updates=self._step,
            updates_applied=self.updates_applied,
            view_hits=self.view_hits,
            escalations=self.escalations,
            escalate_h2d_saved=self.escalate_h2d_saved,
            suppressed_escalations=self.suppressed_escalations,
            degraded=self.suppress_escalation,
            engine_rebuilds=self.engine_rebuilds,
            compact_calls=self.store.stats.compact_calls,
            compact_compiles=self.store.stats.compact_compiles,
            compact_bucket_count=self.store.stats.compact_bucket_count,
            compact_deferred=self.store.stats.compact_deferred,
            compact_pending=self.store.compact_pending,
            view_calls=self.store.stats.view_calls,
            view_compiles=self.store.stats.view_compiles,
            view_bucket_count=self.store.stats.view_bucket_count,
            vacuum_calls=self.store.stats.vacuum_calls,
            vacuum_compiles=self.store.stats.vacuum_compiles,
            vacuum_bucket_count=self.store.stats.vacuum_bucket_count,
            overlay_len=self.store.overlay_len,
            edges_added=self.store.stats.edges_added,
            edges_removed=self.store.stats.edges_removed,
            nodes_added=self.store.stats.nodes_added,
            nodes_removed=self.store.stats.nodes_removed,
            slo_budget_remaining=self.metrics.get_gauge(
                "slo_budget_remaining", 1.0
            ),
        )
        return d

    # ------------------------------------------------------- snapshot support

    def snapshot_state(self) -> dict:
        """Capture the full serving state by reference (O(1) + overlay chunk
        lists): labels (immutable jax array), quality-guard references, the
        step counter that seeds repair, the engine handle (its jit caches
        are process-global, its arena immutable), the trajectory prefix, and
        the store's graph state.  Restoring a capture makes the session
        bit-identical to the moment it was taken — replaying the same update
        stream reproduces the same labels, because every seed derives from
        the restored step counter."""
        return dict(
            labels=self.labels,
            step=self._step,
            cut_ref=self._cut_ref,
            ew_ref=self._ew_ref,
            base_id=self._base_id,
            engine=self.engine,
            escalations=self.escalations,
            engine_rebuilds=self.engine_rebuilds,
            escalate_h2d_saved=self.escalate_h2d_saved,
            trajectory=list(self.trajectory),
            store=self.store.snapshot_state(),
        )

    def restore_state(self, st: dict) -> None:
        """Rebind session state to a :meth:`snapshot_state` capture."""
        self.labels = st["labels"]
        self._step = st["step"]
        self._cut_ref = st["cut_ref"]
        self._ew_ref = st["ew_ref"]
        self._base_id = st["base_id"]
        self.engine = st["engine"]
        self.escalations = st["escalations"]
        self.engine_rebuilds = st["engine_rebuilds"]
        self.escalate_h2d_saved = st["escalate_h2d_saved"]
        self.trajectory = list(st["trajectory"])
        self.store.restore_state(st["store"])
