"""Dynamic partitioning subsystem: keep a graph AND its partition resident
on device while absorbing streams of edge/node updates.

Three layers (ISSUE 4):

* :mod:`repro.dynamic.store` — a mutable device-resident graph: base CSR
  (:class:`~repro.graph.csr.GraphDev`) plus a bounded COO *delta overlay*,
  merged back into CSR by a bucketed device compaction.
* :mod:`repro.dynamic.repair` — the incremental repair kernels: h-hop
  affected-region expansion on device, region-masked gain/balance rounds.
  The size-constrained LP sweep itself is dispatched by
  :meth:`repro.core.engine.LPEngine.repair` over a *region pack*.
* :mod:`repro.dynamic.session` — :class:`PartitionSession`, the serving
  loop: batched update requests in, repaired device-resident labels out,
  with a cut/imbalance quality guard that escalates to a full multilevel
  ``partition()`` when local repair can no longer hold quality.
* :mod:`repro.dynamic.group` — :class:`SessionGroup`, the multi-tenant
  throughput layer (ISSUE 8): vmapped repair over a bucketed batch of
  independent sessions, serving a merged update stream with per-tenant
  solo bit-parity.
"""

from .group import GroupStats, SessionGroup
from .session import PartitionSession, SessionConfig, UpdateResult
from .store import DynamicGraphStore, GraphUpdate, UpdateValidationError

__all__ = [
    "DynamicGraphStore",
    "GraphUpdate",
    "GroupStats",
    "PartitionSession",
    "SessionConfig",
    "SessionGroup",
    "UpdateResult",
    "UpdateValidationError",
]
