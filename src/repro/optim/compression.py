"""Int8 error-feedback gradient compression for the DP/pod-axis allreduce.

Distributed-optimization trick for slow cross-pod links: quantize each
gradient leaf to int8 with a per-leaf scale before the data-parallel
reduction, keep the quantization residual locally and add it back next step
(error feedback), so the compression bias does not accumulate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "compress_decompress"]


def ef_init(params):
    return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)


def _q(x, residual):
    x = x.astype(jnp.float32) + residual
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, x - deq


def compress_decompress(grads, residuals):
    """Returns (dequantized int8-grade grads, new residuals).

    On a real pod the int8 payload is what crosses the pod axis; here the
    quantize->dequantize round trip (plus error feedback) is applied so
    training sees exactly the compressed values.
    """
    out = jax.tree.map(_q, grads, residuals)
    deq = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, res
