from .adamw import AdamWState, adamw_init, adamw_update
from .compression import compress_decompress, ef_init
from .schedule import warmup_cosine

__all__ = ["AdamWState", "adamw_init", "adamw_update", "warmup_cosine",
           "ef_init", "compress_decompress"]
