"""LR schedules: linear warmup + cosine decay."""

import jax.numpy as jnp

__all__ = ["warmup_cosine"]


def warmup_cosine(step, *, peak=3e-4, warmup=100, total=1000, floor=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = floor * peak + (1 - floor) * peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)
