"""AdamW with decoupled weight decay, global-norm clipping and mixed
precision (bf16 params + fp32 master/optimizer states), built for sharded
training: states mirror the param shardings, so FSDP shards optimizer
memory for free."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update"]


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: dict
    nu: dict
    master: dict          # fp32 master copy of params


def adamw_init(params):
    # copy=True: for fp32 params astype would alias the same buffer, and
    # donating params AND master in one call is a double-donation error
    f32 = lambda x: jnp.array(x, dtype=jnp.float32, copy=True)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params),
        nu=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params),
        master=jax.tree.map(f32, params),
    )


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr: float | jnp.ndarray = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(
        sum(jnp.sum(g * g) for g in jax.tree.leaves(g32)) + 1e-12
    )
    scale = jnp.minimum(1.0, clip_norm / gnorm)
    g32 = jax.tree.map(lambda g: g * scale, g32)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mu, nu, m):
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * g * g
        mhat = mu / c1
        nhat = nu / c2
        m = m - lr * (mhat / (jnp.sqrt(nhat) + eps) + weight_decay * m)
        return mu, nu, m

    out = jax.tree.map(upd, g32, state.mu, state.nu, state.master)
    mu = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda m, p: m.astype(p.dtype), master, params)
    return new_params, AdamWState(step=step, mu=mu, nu=nu, master=master), gnorm
