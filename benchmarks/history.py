"""Benchmark trajectory + continuous perf-regression gate (PR 10).

The repo records one ``BENCH_PR<n>.json`` per PR (the acceptance bundle of
that PR's benchmark run).  Collectively they are a *performance
trajectory*: per table, per row, a series of ``us_per_call`` measurements
across the stack's history.  This module turns that trajectory into a
regression gate:

* :func:`load_history` — parse every ``BENCH_PR*.json`` in a directory,
  ordered by PR number (underscore-prefixed keys such as
  ``_trajectory_delta`` are metadata, not tables, and are skipped);
* :func:`derive_baselines` — per ``(table, row-name)`` baseline: the
  *minimum* ``us_per_call`` over the most recent ``window`` recordings
  (min-of-recent absorbs one-off slow machines; a genuine regression
  shifts every subsequent recording, so the window eventually tracks it);
* :func:`check_regression` — compare a fresh results dict against the
  baselines with a multiplicative ``tolerance``.  CPU-container timings
  are noisy, so the default tolerance is wide (1.75x): the gate exists to
  catch *structural* slowdowns (an accidental recompile per update, a
  device sync in the hot loop, an O(n) host round-trip — all >= 2x), not
  5% drift.  Rows whose recorded graph/config signature differs from the
  baseline's (e.g. ``--smoke`` sizes vs full bench sizes) are
  ``incomparable`` — measured, reported, never gated;
* :func:`format_report` — the trajectory delta table ``--check-regression``
  prints and embeds into the results JSON under ``_trajectory_delta``.

Statuses: ``ok`` | ``regression`` | ``improved`` | ``new`` |
``incomparable``.  The gate fails (exit nonzero) iff any row is
``regression``.
"""

from __future__ import annotations

import glob
import json
import os
import re
from typing import Dict, List, Optional, Tuple

__all__ = [
    "DEFAULT_TOLERANCE", "DEFAULT_WINDOW",
    "load_history", "derive_baselines", "check_regression", "format_report",
]

DEFAULT_TOLERANCE = 1.75
DEFAULT_WINDOW = 3

_PR_RE = re.compile(r"BENCH_PR(\d+)\.json$")


def _row_signature(row: dict) -> Optional[str]:
    """Comparability signature of a bench row: same graph + problem size.

    Rows only gate against baselines with an identical signature, so a
    ``--smoke`` run (ba-1024) never compares against the recorded full-size
    trajectory (ba-16384) — those pairs are ``incomparable`` by
    construction, not falsely "improved"."""
    d = row.get("derived")
    if not isinstance(d, dict):
        return None
    sig = []
    for key in ("graph", "n", "m", "k", "repeats", "preset"):
        if key in d:
            sig.append(f"{key}={d[key]}")
    return ",".join(sig) if sig else None


def load_history(
    bench_dir: str, pattern: str = "BENCH_PR*.json"
) -> List[Tuple[int, str, dict]]:
    """All ``(pr_number, path, data)`` bundles in ``bench_dir``, PR-ordered."""
    out = []
    for path in glob.glob(os.path.join(bench_dir, pattern)):
        m = _PR_RE.search(os.path.basename(path))
        if not m:
            continue
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if isinstance(data, dict):
            out.append((int(m.group(1)), path, data))
    out.sort(key=lambda t: t[0])
    return out


def derive_baselines(
    history: List[Tuple[int, str, dict]], window: int = DEFAULT_WINDOW
) -> Dict[Tuple[str, str], dict]:
    """Per ``(table, row-name)``: min ``us_per_call`` of the last ``window``
    recordings, plus the full series and the latest row's signature."""
    series: Dict[Tuple[str, str], List[Tuple[int, float]]] = {}
    sigs: Dict[Tuple[str, str], Optional[str]] = {}
    for prn, _path, data in history:
        for table, rows in data.items():
            if table.startswith("_") or not isinstance(rows, list):
                continue
            for row in rows:
                if not isinstance(row, dict) or "name" not in row:
                    continue
                us = row.get("us_per_call")
                if not isinstance(us, (int, float)):
                    continue
                key = (table, str(row["name"]))
                series.setdefault(key, []).append((prn, float(us)))
                sigs[key] = _row_signature(row)   # latest recording wins
    out = {}
    for key, vals in series.items():
        recent = [v for _, v in vals[-max(window, 1):]]
        out[key] = dict(
            baseline_us=min(recent),
            window=len(recent),
            series=vals,
            signature=sigs.get(key),
        )
    return out


def check_regression(
    results: dict,
    baselines: Dict[Tuple[str, str], dict],
    tolerance: float = DEFAULT_TOLERANCE,
) -> List[dict]:
    """Trajectory delta of a fresh ``{table: [rows]}`` results dict."""
    report = []
    for table in sorted(k for k in results if not k.startswith("_")):
        rows = results[table]
        if not isinstance(rows, list):
            continue
        for row in rows:
            if not isinstance(row, dict) or "name" not in row:
                continue
            us = row.get("us_per_call")
            if not isinstance(us, (int, float)):
                continue
            key = (table, str(row["name"]))
            rec = dict(table=table, name=key[1], us_per_call=float(us))
            base = baselines.get(key)
            if base is None:
                rec.update(status="new", baseline_us=None, ratio=None)
            elif _row_signature(row) != base["signature"]:
                rec.update(
                    status="incomparable",
                    baseline_us=base["baseline_us"], ratio=None,
                    signature=_row_signature(row),
                    baseline_signature=base["signature"],
                )
            else:
                b = max(base["baseline_us"], 1e-9)
                ratio = float(us) / b
                status = (
                    "regression" if ratio > tolerance
                    else "improved" if ratio < 1.0 / tolerance
                    else "ok"
                )
                rec.update(
                    status=status, baseline_us=base["baseline_us"],
                    ratio=ratio,
                )
            report.append(rec)
    return report


def format_report(
    report: List[dict], tolerance: float = DEFAULT_TOLERANCE
) -> str:
    """Human-readable trajectory delta table."""
    lines = [
        f"trajectory delta (tolerance x{tolerance:g}, "
        f"baseline = min of last {DEFAULT_WINDOW} recordings)",
        f"{'table':<24} {'row':<28} {'us/call':>12} "
        f"{'baseline':>12} {'ratio':>7}  status",
    ]
    for r in report:
        base = "-" if r["baseline_us"] is None else f"{r['baseline_us']:.0f}"
        ratio = "-" if r.get("ratio") is None else f"x{r['ratio']:.2f}"
        lines.append(
            f"{r['table']:<24} {r['name']:<28} {r['us_per_call']:>12.0f} "
            f"{base:>12} {ratio:>7}  {r['status']}"
        )
    n_reg = sum(1 for r in report if r["status"] == "regression")
    lines.append(
        f"# {len(report)} rows: "
        + ", ".join(
            f"{s}={sum(1 for r in report if r['status'] == s)}"
            for s in ("ok", "improved", "regression", "new", "incomparable")
        )
        + ("  -> GATE FAILED" if n_reg else "  -> gate passed")
    )
    return "\n".join(lines)
